"""The paper's technique as a drop-in ``jax.value_and_grad``.

Backpropagates the paper's LSTM over a 2048-step sequence three ways through
``repro.api`` — store-everything, classic Revolve, asynchronous multistage —
and shows identical gradients with very different Level-1 footprints, plus
the autotuner choosing the §3-optimal interval on first call.

Run:  PYTHONPATH=src python examples/api_quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import api
from repro.configs import get_config
from repro.models import get_model


def main():
    cfg = get_config("lstm-paper", smoke=True)
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    T = 2048
    batch = {"tokens": jax.random.randint(
        jax.random.fold_in(key, 1), (4, T + 1), 0, cfg.vocab)}

    # the reference: ordinary autodiff
    ref_loss, ref_grads = jax.value_and_grad(model.train_loss)(params, batch)
    print(f"jax.value_and_grad        loss={float(ref_loss):9.3f}")

    for strategy, opts in [
        ("conventional", {}),
        ("revolve", dict(slots=32)),
        ("multistage_async", dict(interval=64, slots=32)),
        ("multistage_async", {}),     # autotuned: I = ceil(T_T / T_A)
    ]:
        vg = api.value_and_grad_offloaded(model.train_loss,
                                          strategy=strategy, **opts)
        loss, grads = vg(params, batch)
        err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
            jax.tree_util.tree_leaves(grads),
            jax.tree_util.tree_leaves(ref_grads)))
        st = api.last_stats()
        label = strategy + (" (autotuned)" if not opts and
                            strategy == "multistage_async" else "")
        print(f"{label:26s} loss={float(loss):9.3f} |dg|={err:.2e} "
              f"peak_L1_states={st.peak_l1_states:4d} "
              f"L2_stores={st.l2_stores:3d} R={st.recompute_factor:.3f}")
    tune = api.last_tune()
    print(f"autotuner: T_A={tune.t_a*1e6:.0f}us T_T={tune.t_t*1e6:.0f}us "
          f"-> interval={tune.interval} slots={tune.slots} "
          f"({tune.source}, stall-free={tune.never_stalls})")


if __name__ == "__main__":
    main()
