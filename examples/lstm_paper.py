"""Faithful reproduction of the paper's §5 experiment: a vanilla LSTM for
char-level text generation, trained with RMSProp, with a single
forward-backward iteration measured as the number of recurrences grows.

Reports, per depth (the paper's Figs 4 & 5):
  * peak Level-1 memory for conventional / Revolve / async multistage
  * measured recompute factors (flat for multistage, growing for Revolve)
  * Level-2 transfer stalls (≈0 at the paper's operating point)

Run: PYTHONPATH=src python examples/lstm_paper.py [--depths 64 128 256]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import CheckpointExecutor
from repro.core import revolve as rv
from repro.core import schedule as ms
from repro.data import text_corpus
from repro.models.lstm import (forward_loss, init_lstm, init_state,
                               make_operators)
from repro.optim import rmsprop

S_SLOTS = 16
INTERVAL = 32


def one_iteration(depth: int, batch: int = 8, hidden: int = 128):
    key = jax.random.PRNGKey(0)
    params = init_lstm(key, vocab=96, d_embed=32, d_hidden=hidden)
    corpus = text_corpus(batch * (depth + 1))
    tokens = jnp.asarray(corpus.reshape(batch, depth + 1))

    fwd, bwd, seed, n = make_operators(params, tokens)
    ex = CheckpointExecutor(fwd, bwd)
    s0 = init_state(batch, hidden)
    rows = {}
    (_, g_c), st = ex.run_conventional(s0, n, seed())
    rows["conventional"] = st
    (_, g_r), st = ex.run_revolve(s0, n, seed(), s=S_SLOTS)
    rows["revolve"] = st
    (_, g_m), st = ex.run_multistage(s0, n, seed(), interval=INTERVAL,
                                     s_l1=S_SLOTS)
    rows["async"] = st
    return rows, (params, tokens, g_m)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--depths", type=int, nargs="+",
                    default=[64, 128, 256, 512])
    ap.add_argument("--train-steps", type=int, default=5)
    args = ap.parse_args()

    print(f"{'depth':>6} {'strategy':>14} {'peak_MB':>9} {'peak_states':>11} "
          f"{'R':>6} {'R_model':>8} {'stall_ms':>9}")
    last = None
    for depth in args.depths:
        rows, last = one_iteration(depth)
        for name, st in rows.items():
            model = {"conventional": 1.0,
                     "revolve": rv.recompute_factor(depth, S_SLOTS),
                     "async": ms.multistage_recompute_factor(
                         depth, INTERVAL, S_SLOTS)}[name]
            stall = (st.store_stall_s + st.prefetch_stall_s) * 1e3
            print(f"{depth:6d} {name:>14} {st.peak_l1_bytes/1e6:9.2f} "
                  f"{st.peak_l1_states:11d} {st.recompute_factor:6.3f} "
                  f"{model:8.3f} {stall:9.2f}")

    # a short RMSProp training run through the multistage pipeline
    # (the paper's training setup; convergence is not the point, §5)
    params, tokens, grads = last
    opt = rmsprop(2e-3)
    opt_state = opt.init(params)
    from repro.models.lstm import bptt_loss_and_grad
    print("\nRMSProp training (multistage BPTT, interval=32):")
    for i in range(args.train_steps):
        loss, grads = bptt_loss_and_grad(params, tokens, interval=32)
        params, opt_state = opt.update(grads, opt_state, params,
                                       jnp.asarray(i))
        print(f"  step {i}: loss {float(loss):.4f}")


if __name__ == "__main__":
    main()
