"""Long-context BPTT with multistage checkpointing — the paper's technique
at modern scale: a Mamba-2 LM trained over a sequence far longer than the
activation budget, by scanning sequence *segments* whose boundary SSM states
are offloaded to Level 2 (host memory) and whose interiors are recomputed.

This is `multistage_scan` over the time axis with the SSM state as the
uniform carry — the exact structure of the paper's LSTM experiment, with the
SSD chunked kernel inside each segment.

Run: PYTHONPATH=src python examples/long_context_bptt.py \
        [--seq-len 8192 --interval 8 --steps 3]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.multistage_scan import choose_interval, multistage_scan
from repro.data import SyntheticDataset
from repro.configs.base import ShapeSpec
from repro.models import get_model
from repro.models.layers import chunked_ce_loss, embed, rmsnorm
from repro.models import ssm as ssm_mod, transformer as tf
from repro.optim import adamw


def segmented_loss(params, tokens, cfg, interval, seg_tokens=512):
    """Chain step = one ``seg_tokens``-token chunk; boundary (conv, ssm)
    states ride the multistage carry -> every ``interval``-th one is
    offloaded to pinned host memory, interiors recomputed."""
    dt = tf._dtypes(cfg)
    B, Tp1 = tokens.shape
    T = Tp1 - 1
    seg_tokens = min(seg_tokens, T)
    n_steps = T // seg_tokens
    seg = seg_tokens
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.headdim
    conv_dim = d_in + 2 * s.ngroups * s.d_state
    L = cfg.n_layers

    def init_states():
        return (
            jnp.zeros((L, B, s.conv_k - 1, conv_dim), jnp.float32),
            jnp.zeros((L, B, nheads, s.headdim, s.d_state), jnp.float32),
        )

    inp = tokens[:, :T].reshape(B, n_steps, seg).transpose(1, 0, 2)
    lab = tokens[:, 1:T + 1].reshape(B, n_steps, seg).transpose(1, 0, 2)

    def body(carry, x):
        conv_st, ssm_st = carry
        toks, labs = x
        h = embed(params["embed"], toks, dt)
        new_conv, new_ssm = [], []

        def layer(i, h, conv_st, ssm_st):
            lp = jax.tree_util.tree_map(lambda a: a[i],
                                        params["layers"]["pos0"])
            y = rmsnorm(lp["ln1"], h, dt=dt)
            y, (c2, s2) = ssm_mod.mamba2_block(
                lp["mamba"], y, d_state=s.d_state, headdim=s.headdim,
                expand=s.expand, ngroups=s.ngroups, conv_k=s.conv_k,
                chunk=min(s.chunk, seg), dt=dt,
                state=(conv_st[i], ssm_st[i]), return_state=True)
            return h + y, c2, s2

        for i in range(L):
            h, c2, s2 = layer(i, h, conv_st, ssm_st)
            new_conv.append(c2)
            new_ssm.append(s2)
        h = rmsnorm(params["final_norm"], h, dt=dt)
        nll = chunked_ce_loss(h, params["embed"]["emb"], labs,
                              chunk=min(cfg.ce_chunk, seg))
        return (jnp.stack(new_conv), jnp.stack(new_ssm)), nll

    _, nlls = multistage_scan(body, init_states(), (inp, lab),
                              interval=interval, offload=True)
    return jnp.mean(nlls)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seq-len", type=int, default=8192)
    ap.add_argument("--interval", type=int, default=8)
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args()

    cfg = get_config("mamba2-370m", smoke=True).replace(n_layers=2)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    ds = SyntheticDataset(cfg, ShapeSpec("x", args.seq_len, args.batch,
                                         "train"))
    opt = adamw(1e-3)
    opt_state = opt.init(params)

    n_steps = args.seq_len // 512
    interval = choose_interval(max(n_steps, 1), args.interval)
    args.interval = interval
    print(f"[long-context BPTT] mamba2 smoke, T={args.seq_len}, "
          f"{n_steps} chain steps of 512 tokens, "
          f"multistage interval={interval} "
          f"(SSM boundary states -> pinned host)")

    grad_fn = jax.jit(jax.value_and_grad(
        lambda p, t: segmented_loss(p, t, cfg, args.interval)))
    for step in range(args.steps):
        batch = jax.tree_util.tree_map(jnp.asarray, ds.batch(step))
        # reshape so T = interval * seg with seg tokens per segment
        t0 = time.time()
        loss, grads = grad_fn(params, batch["tokens"])
        params, opt_state = opt.update(grads, opt_state, params,
                                       jnp.asarray(step))
        print(f"  step {step}: loss {float(loss):.4f} "
              f"({time.time()-t0:.1f}s)")

    # cross-check against the monolithic forward (no segmentation)
    full = api.train_loss(params, {"tokens": batch["tokens"]})
    seg = segmented_loss(params, batch["tokens"], cfg, args.interval)
    print(f"  segmented loss {float(seg):.4f} vs monolithic "
          f"{float(full):.4f} (same math, different checkpointing)")


if __name__ == "__main__":
    main()
