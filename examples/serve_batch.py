"""Multi-tenant serving demo: decode sessions and an offloaded fine-tune
step sharing ONE capacity-bounded tier under per-tenant quotas.

Two tenants submit work against a shared ``TieredStorage``: "chat" runs
continuous-batching decode sessions (mixed-length prompts joined through
the model's cache spec), "lab" runs a journaled fine-tune gradient step
through ``value_and_grad_offloaded``.  A late high-priority decode burst
preempts the training job at a Level-2 store boundary; the job resumes
from its write-ahead journal and its gradients come out bit-identical to
an uninterrupted run.  Every admitted request is audited: its measured
fast-tier peak never exceeds the perfmodel prediction admission used.

Run: PYTHONPATH=src python examples/serve_batch.py [--arch qwen1.5-4b]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.chain import ChainSpec
from repro.configs import get_config
from repro.core.storage import TieredStorage
from repro.models import get_model
from repro.serve import FakeClock, LinkTimes, ServeScheduler


def toy_chain(T, B, D):
    return ChainSpec(
        prelude=lambda p, b: (jnp.zeros((B, D)), b["xs"]),
        body=lambda p, c, x, b: jnp.tanh(c @ p["W"] + x),
        readout=lambda p, c, b: jnp.sum(c ** 2),
        name="demo-finetune")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--decode-steps", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    T, B, D = 24, 2, 16
    key = jax.random.PRNGKey(1)
    tparams = {"W": jax.random.normal(key, (D, D)) * 0.3}
    tbatch = {"xs": jax.random.normal(jax.random.fold_in(key, 1),
                                      (T, B, D)) * 0.1}
    chain = toy_chain(T, B, D)
    state_bytes = B * D * 4

    tier = TieredStorage(capacity_bytes=256 * 1024)
    clock = FakeClock()
    sched = ServeScheduler(tier, clock=clock,
                           journal_root=tempfile.mkdtemp())
    sched.add_tenant("chat", quota_bytes=128 * 1024)
    sched.add_tenant("lab", quota_bytes=state_bytes * 4)
    times = LinkTimes(t_a=1e-3, t_b=2e-3, t_t_fast=1e-4, t_t_slow=1e-3)

    prompts = [rng.integers(0, cfg.vocab, size=(n,)) for n in (5, 9)]
    print(sched.submit_decode("chat-1", "chat", api, params,
                              prompts=prompts, max_len=24,
                              decode_steps=args.decode_steps))
    print(sched.submit_train("lab-ft", "lab", chain, tparams, tbatch,
                             times=times, priority=0))

    # lab-ft reserved the whole "lab" quota, so this high-priority step
    # cannot admit — the scheduler preempts the running low-priority job
    # at its next Level-2 store, runs the urgent step, then resumes the
    # preempted one from its journal
    print(sched.submit_train("lab-urgent", "lab", chain, tparams, tbatch,
                             times=times, priority=5))

    while sched.waiting or sched.running:
        sched.step()
        clock.advance(0.02)      # pretend each round takes 20 ms
    completed = sched.completed
    print(f"\n{'rid':12} {'kind':7} {'pri':>3} {'preempts':>8} "
          f"{'measured':>9} {'predicted':>9} {'latency_s':>9}")
    for r in completed:
        print(f"{r['rid']:12} {r['kind']:7} {r['priority']:>3} "
              f"{r['preemptions']:>8} {r['measured_fast_peak']:>9} "
              f"{r['predicted_fast_peak']:>9} {r['latency_s']:>9.3f}")
        assert r["measured_fast_peak"] <= r["predicted_fast_peak"]

    lab = {r["rid"]: r for r in completed if r["kind"] == "train"}
    from repro import api as rapi
    for rid, rec in lab.items():
        vg = rapi.value_and_grad_offloaded(
            chain, interval=rec["interval"], autotune=False)
        ref = vg(tparams, tbatch)
        same = all(bool(jnp.array_equal(a, b)) for a, b in
                   zip(jax.tree_util.tree_leaves(rec["result"]),
                       jax.tree_util.tree_leaves(ref)))
        print(f"{rid}: gradients bit-identical to uninterrupted run: {same}")


if __name__ == "__main__":
    main()
