"""Batched serving demo: prefill a batch of prompts, decode with donated
KV caches, report per-phase throughput — the serving-side use of the
framework (KV caches are the "states" here; on TPU the same host-offload
machinery pages cold caches to host RAM).

Run: PYTHONPATH=src python examples/serve_batch.py [--arch gemma2-2b]
"""
import argparse

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=48)
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--smoke",
                "--batch", str(args.batch),
                "--prompt-len", str(args.prompt_len),
                "--decode-steps", str(args.decode_steps),
                "--temperature", "0.8"])


if __name__ == "__main__":
    main()
