"""Quickstart: the paper's technique in 40 lines.

A long chain (here: an LSTM over 2048 tokens) is backpropagated three ways —
store-everything, classic Revolve, and the paper's asynchronous multistage
checkpointing — and all three produce identical gradients with very
different memory/compute trade-offs.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (CheckpointExecutor, optimal_advances,
                        multistage_recompute_factor)
from repro.models.lstm import (init_lstm, init_state, make_operators,
                               forward_loss, bptt_loss_and_grad)


def main():
    key = jax.random.PRNGKey(0)
    T, B, V = 2048, 8, 96
    params = init_lstm(key, vocab=V, d_embed=32, d_hidden=64)
    tokens = jax.random.randint(jax.random.fold_in(key, 1), (B, T + 1), 0, V)

    fwd, bwd, seed, n = make_operators(params, tokens)
    ex = CheckpointExecutor(fwd, bwd)
    state0 = init_state(B, 64)

    print(f"chain length n={n}")
    # 1. conventional: stores all n states
    (_, g_conv), st = ex.run_conventional(state0, n, seed())
    print(f"conventional : advances={st.advances:5d} "
          f"peak_states={st.peak_l1_states:4d} "
          f"peak_bytes={st.peak_l1_bytes/1e6:7.1f}MB")

    # 2. classic Revolve with 32 snapshot slots
    (_, g_rev), st = ex.run_revolve(state0, n, seed(), s=32)
    print(f"revolve s=32 : advances={st.advances:5d} "
          f"(optimal={optimal_advances(n, 32)}) "
          f"peak_states={st.peak_l1_states:4d} "
          f"peak_bytes={st.peak_l1_bytes/1e6:7.1f}MB")

    # 3. the paper: async multistage, interval 64, Level-2 in host RAM
    (_, g_ms), st = ex.run_multistage(state0, n, seed(), interval=64, s_l1=32)
    print(f"multistage   : advances={st.advances:5d} "
          f"(R={st.recompute_factor:.3f}, model "
          f"{multistage_recompute_factor(n, 64, 32):.3f}) "
          f"peak_states={st.peak_l1_states:4d} "
          f"peak_bytes={st.peak_l1_bytes/1e6:7.1f}MB "
          f"l2_stores={st.l2_stores} store_stall={st.store_stall_s*1e3:.1f}ms "
          f"prefetch_stall={st.prefetch_stall_s*1e3:.1f}ms")

    # all gradients identical
    ref = jax.grad(forward_loss)(params, tokens)
    for name, g in [("conventional", g_conv), ("revolve", g_rev),
                    ("multistage", g_ms)]:
        err = max(float(jnp.max(jnp.abs(a - b)))
                  for a, b in zip(jax.tree_util.tree_leaves(g),
                                  jax.tree_util.tree_leaves(ref)))
        print(f"  {name:13s} max |grad - autodiff| = {err:.2e}")

    # the compiled path (what runs on TPU pods): same math through
    # multistage_scan with XLA host offload
    loss, _ = bptt_loss_and_grad(params, tokens, interval=64)
    print(f"compiled multistage_scan loss = {float(loss):.4f} "
          f"(reference {float(forward_loss(params, tokens)):.4f})")


if __name__ == "__main__":
    main()
