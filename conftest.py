"""Repo-wide pytest configuration for deterministic CI runs.

* Forces ``jax_platform_name=cpu`` (set before jax initialises) so the suite
  behaves identically on dev boxes, CI runners and TPU hosts.
* Seeds every stdlib/numpy RNG and pins a session PRNG key fixture, so runs
  are reproducible bit-for-bit.
* Prepends ``src/`` to ``sys.path`` so ``pytest`` works from a clean checkout
  even without ``pip install -e .`` (the PYTHONPATH=src hack stays optional).
"""
import os
import random
import sys

os.environ.setdefault("JAX_PLATFORM_NAME", "cpu")
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=1")

_SRC = os.path.join(os.path.dirname(__file__), "src")
if os.path.isdir(_SRC) and _SRC not in sys.path:
    sys.path.insert(0, _SRC)

import numpy as np
import pytest

SEED = 20180611  # the paper's arXiv year+month, for want of a better constant


def pytest_configure(config):
    random.seed(SEED)
    np.random.seed(SEED)
    import jax

    # The executor engines dispatch nested segment jits from inside
    # io_callbacks; when the whole train step is jitted (launcher tests),
    # XLA's async CPU dispatch runs the outer program on its nproc-sized
    # execution pool, and on single-core runners the nested dispatch
    # starves — a hard deadlock.  Synchronous CPU dispatch makes the
    # nesting safe everywhere the suite runs.
    jax.config.update("jax_cpu_enable_async_dispatch", False)
    try:  # derandomize property tests when the optional dep is present
        from hypothesis import settings

        settings.register_profile("ci", derandomize=True, deadline=None)
        settings.load_profile("ci")
    except ImportError:
        pass


@pytest.fixture
def prng_key():
    """Session-stable JAX PRNG key."""
    import jax

    return jax.random.PRNGKey(SEED)
