"""Validate intra-repo markdown links (CI's docs-check job).

Scans every tracked ``*.md`` file for inline links/images
``[text](target)`` and checks, for each *relative* target:

* the referenced file or directory exists, and
* when the target carries a ``#fragment``, the destination file contains
  a heading whose GitHub anchor slug matches.

External targets (``http(s)://``, ``mailto:``) are not fetched.  Exits
nonzero listing every broken link, so a doc rename or heading edit fails
the PR instead of shipping a dead link.

    python tools/check_docs_links.py [root]
"""
import os
import re
import sys

# inline links/images, skipping fenced code blocks
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$")
_FENCE = re.compile(r"^(```|~~~)")

SKIP_DIRS = {".git", ".github", "node_modules", "__pycache__", ".venv"}
SKIP_FILES = {"SNIPPETS.md"}  # exemplar scrapbook, not part of the docs site


def gh_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation, spaces to dashes."""
    text = re.sub(r"`([^`]*)`", r"\1", heading.strip())
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # linked headings
    text = text.lower()
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return text.replace(" ", "-")


def md_files(root: str):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for fn in filenames:
            if fn.endswith(".md") and fn not in SKIP_FILES:
                yield os.path.join(dirpath, fn)


def parse(path: str):
    """(links, anchors) of one markdown file, code fences excluded."""
    links, anchors = [], set()
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            if _FENCE.match(line.strip()):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            m = _HEADING.match(line)
            if m:
                anchors.add(gh_slug(m.group(1)))
            for lm in _LINK.finditer(line):
                links.append((lineno, lm.group(1)))
    return links, anchors


def check(root: str):
    files = list(md_files(root))
    anchor_cache = {p: parse(p)[1] for p in files}
    errors = []
    for path in files:
        links, _ = parse(path)
        for lineno, target in links:
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, mailto:, …
                continue
            target, _, fragment = target.partition("#")
            if target:
                dest = os.path.normpath(
                    os.path.join(os.path.dirname(path), target))
                if not os.path.exists(dest):
                    errors.append(f"{path}:{lineno}: broken link -> {target}")
                    continue
            else:
                dest = path  # same-file anchor
            if fragment and dest.endswith(".md"):
                anchors = anchor_cache.get(os.path.normpath(dest))
                if anchors is None:
                    anchors = parse(dest)[1]
                if fragment not in anchors:
                    errors.append(
                        f"{path}:{lineno}: missing anchor -> "
                        f"{target or os.path.basename(dest)}#{fragment}")
    return errors


def main() -> int:
    root = sys.argv[1] if len(sys.argv) > 1 else "."
    errors = check(root)
    for e in errors:
        print(e)
    n_files = len(list(md_files(root)))
    if errors:
        print(f"\n{len(errors)} broken link(s) across {n_files} markdown "
              "files")
        return 1
    print(f"all intra-repo markdown links OK ({n_files} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
