"""Optional-dependency guard for ``hypothesis`` (declared in the ``test``
extra, see pyproject.toml).

Property-test modules import ``given``/``settings``/``st`` from here instead
of from ``hypothesis`` directly, so collection never hard-errors when the
optional dep is missing: with hypothesis installed the real objects are
re-exported; without it the property tests are individually marked skip
(the example-based tests in the same module still run).
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without the extra
    import pytest

    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Stands in for ``hypothesis.strategies`` at decoration time."""

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None

            return _strategy

    st = _StrategyStub()

    def given(*args, **kwargs):
        return pytest.mark.skip(
            reason="hypothesis not installed (pip install -e .[test])")

    def settings(*args, **kwargs):
        def _decorate(fn):
            return fn

        return _decorate
