"""Step-exact resume goldens: kill a journaled api-level run at every
segment boundary — during the forward sweep (writer death at each store)
and during the reverse sweep (fetch failure at each prefetch) — across
the io_callback engine x storage paths, then resume and assert:

* the resumed gradients and loss are bit-identical to the fault-free run;
* ``replayed_advances <= interval`` — resume replays from the last
  durable boundary, never from t=0;
* ``api.last_stats()`` matches the plan model for exactly the work a
  resume should do (forward from the restart boundary + the not-yet-
  reversed segments; a reverse resume issues no Level-2 stores at all).
"""
import os
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from _helpers import tree_equal

from repro import api
from repro.core import faults
from repro.core import revolve as rv
from repro.core.faults import FaultPlan
from repro.core.storage import make_backend

T, B, D = 12, 2, 4
INTERVAL, SLOTS = 4, 2
M = T // INTERVAL          # segments in the plan

# the four io_callback paths: engine x Level-2 storage (the disk variants
# add ~nothing in coverage per-test but prove journal-only re-hydration
# after the run's temp Level-2 directory is disposed; keep them slow-tier)
PATHS = [
    pytest.param("compiled", "ram", id="compiled-ram"),
    pytest.param("interpreted", "ram", id="interpreted-ram"),
    pytest.param("compiled", "disk", id="compiled-disk",
                 marks=pytest.mark.slow),
    pytest.param("interpreted", "disk", id="interpreted-disk",
                 marks=pytest.mark.slow),
]


def _body(p, c, x):
    c = jnp.tanh(c @ p["W"] + x)
    return c, jnp.sum(c ** 2)


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(0)
    params = {"W": jax.random.normal(key, (D, D)) * 0.3}
    xs = jax.random.normal(jax.random.fold_in(key, 1), (T, B, D)) * 0.1
    return params, jnp.zeros((B, D)), xs


@pytest.fixture(scope="module")
def baselines(problem):
    """Fault-free (loss, grads) per engine — the resume golden."""
    params, c0, xs = problem
    out = {}
    for engine in ("compiled", "interpreted"):
        bptt = api.checkpointed_bptt(_body, interval=INTERVAL, slots=SLOTS,
                                     engine=engine)
        out[engine] = (bptt, bptt(params, c0, xs))
    return out


_tree_equal = tree_equal   # the shared bit-identity predicate


def _reverse_advances(plan, engine, upto_j) -> int:
    """Plan-model advances for reversing segments 0..upto_j inclusive."""
    total = 0
    for seg in plan.segments[:upto_j + 1]:
        if engine == "interpreted":
            total += (seg.length - 1) if seg.revolve is None \
                else rv.count_advances(list(seg.revolve))
        else:  # compiled: vjp replay + one chunk rematerialisation pass
            total += seg.length * (2 if plan.inner_chunk(seg) is not None
                                   else 1)
    return total


def _crash_then_resume(problem, baselines, engine, storage, plan):
    """Inject ``plan``, expect a crash, recover + resume, and return
    (recovered, stats) for model assertions."""
    params, c0, xs = problem
    bptt, (v_ref, g_ref) = baselines[engine]
    with tempfile.TemporaryDirectory() as base:
        jd = os.path.join(base, "wal")
        jbptt = api.checkpointed_bptt(_body, interval=INTERVAL, slots=SLOTS,
                                      engine=engine, storage=storage,
                                      journal_dir=jd)
        with pytest.raises(Exception):
            with faults.inject(plan):
                jbptt(params, c0, xs)
        # peek at the journal the way resume will (any inner works for a
        # read; the real resume composes the configured backend)
        insp = make_backend("ram", journal=jd)
        recovered = insp.recover()
        insp.close()
        v, g = api.resume_offloaded(bptt.chain_spec, params, (c0, xs),
                                    journal_dir=jd, interval=INTERVAL,
                                    slots=SLOTS, engine=engine,
                                    storage=storage)
        assert float(v) == float(v_ref)
        assert _tree_equal(g, g_ref), "resume diverged from fault-free run"
        return recovered, api.last_stats()


@pytest.mark.parametrize("k", range(M + 1))   # every boundary + final state
@pytest.mark.parametrize("engine,storage", PATHS)
def test_forward_kill_at_every_boundary(problem, baselines, engine, storage,
                                        k):
    """Writer death at the k-th Level-2 store: resume replays from the
    last durable boundary — cost <= one interval — then runs one full
    reverse sweep, and the stats match that plan model exactly."""
    rec, st = _crash_then_resume(problem, baselines, engine, storage,
                                 FaultPlan(kill_writer_at_store=k))
    plan = api.last_plan()
    assert st.replayed_advances <= INTERVAL
    # what was durable when the writer died
    durable = sorted(b for b in rec.keys if isinstance(b, int))
    b_star = 0
    for seg in plan.segments:
        if seg.begin in durable:
            b_star = seg.begin
        else:
            break
    if not durable:
        b_star = 0
    cur = rec.cursor
    pos = plan.cursor_position(cur) if cur is not None \
        and cur.phase == "forward" else b_star
    assert st.replayed_advances == max(0, pos - b_star)
    assert st.advances == (T - b_star) + \
        _reverse_advances(plan, engine, M - 1)
    assert st.backwards == T
    # resume stores only what was not yet durable (+ the final state)
    assert st.l2_stores == (M - len(durable)) + 1
    assert st.l2_prefetches == M


@pytest.mark.parametrize("j", range(M))       # every reverse boundary fetch
@pytest.mark.parametrize("engine,storage", PATHS)
def test_reverse_crash_at_every_boundary(problem, baselines, engine, storage,
                                         j):
    """Fetch failure during the reverse sweep: resume restarts mid-sweep
    at the journaled cursor — zero forward replay, no Level-2 stores, and
    exactly the not-yet-reversed segments' plan-model advances."""
    rec, st = _crash_then_resume(problem, baselines, engine, storage,
                                 FaultPlan(fail_get_at=j))
    plan = api.last_plan()
    cur = rec.cursor
    assert cur is not None and cur.phase == "reverse"
    j_start = cur.segment_index
    assert 0 <= j_start < M
    assert st.replayed_advances == 0
    assert st.advances == _reverse_advances(plan, engine, j_start)
    assert st.backwards == sum(seg.length
                               for seg in plan.segments[:j_start + 1])
    assert st.l2_stores == 0
    assert st.l2_prefetches == j_start + 1


def test_resume_under_different_inputs_falls_back_to_fresh(problem):
    """Guard: a stale journal must never be resumed under different
    params/batch (e.g. a restart from an older model checkpoint) — that
    would mix two parameter sets into one gradient.  The BEGIN record's
    input fingerprint detects the mismatch and the call runs fresh."""
    params, c0, xs = problem
    params2 = {"W": params["W"] * 1.5}
    bptt = api.checkpointed_bptt(_body, interval=INTERVAL, slots=SLOTS)
    v2_ref, g2_ref = bptt(params2, c0, xs)
    with tempfile.TemporaryDirectory() as base:
        jd = os.path.join(base, "wal")
        jbptt = api.checkpointed_bptt(_body, interval=INTERVAL, slots=SLOTS,
                                      journal_dir=jd)
        with pytest.raises(Exception):
            with faults.inject(FaultPlan(fail_get_at=0)):
                jbptt(params, c0, xs)       # crash mid-reverse under params
        v, g = api.resume_offloaded(bptt.chain_spec, params2, (c0, xs),
                                    journal_dir=jd, interval=INTERVAL,
                                    slots=SLOTS)
        assert float(v) == float(v2_ref)
        assert _tree_equal(g, g2_ref), \
            "stale journal leaked into a different-input gradient"
        st = api.last_stats()
        # a fresh run, not a resume: full forward, nothing replayed
        assert st.replayed_advances == 0
        assert st.advances == T + _reverse_advances(api.last_plan(),
                                                    "compiled", M - 1)


@pytest.mark.parametrize("engine,storage", PATHS)
def test_fault_free_journaled_accounting(problem, baselines, engine,
                                         storage):
    """Baseline for the goldens above: a fault-free journaled run does the
    full plan-model work with zero replay, and its results are
    bit-identical to the unjournaled transform's."""
    params, c0, xs = problem
    _, (v_ref, g_ref) = baselines[engine]
    with tempfile.TemporaryDirectory() as base:
        jd = os.path.join(base, "wal")
        jbptt = api.checkpointed_bptt(_body, interval=INTERVAL, slots=SLOTS,
                                      engine=engine, storage=storage,
                                      journal_dir=jd)
        v, g = jbptt(params, c0, xs)
        st = api.last_stats()
        assert float(v) == float(v_ref) and _tree_equal(g, g_ref)
        assert st.replayed_advances == 0
        assert st.advances == T + _reverse_advances(api.last_plan(), engine,
                                                    M - 1)
        assert st.l2_stores == M + 1   # boundaries + the final state
        # the journal recorded a cleanly completed run
        insp = make_backend("ram", journal=jd)
        rec = insp.recover()
        insp.close()
        assert rec.cursor is not None and rec.cursor.phase == "done"
