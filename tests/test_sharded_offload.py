"""Sharded offloading: per-device Level-2 streams on multi-device meshes.

Three layers of coverage:

* ``ShardedStorage`` unit tests on duck-typed fake devices/shardings (no
  mesh needed): split/assemble round-trips, replicated-leaf placement,
  pre-split snapshots, journal/disk composition through ``make_backend``;
* mesh construction (``make_local_mesh``) and perf-env flag merging;
* end-to-end gradient parity on a forced-CPU mesh (the CI multi-device
  job runs with ``XLA_FLAGS=--xla_force_host_platform_device_count=4``):
  the offloaded gradient must match plain autodiff while the Level-2
  traffic is *actually* sharded — one stream per device, per-stream bytes
  ~ global/num_devices — and the mesh-aware autotuner must never pick a
  larger interval than the single-device baseline.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import api
from repro.api.autotune import AutoTuner
from repro.core.perfmodel import optimal_interval
from repro.core.storage import (JournaledStorage, RAMStorage, ShardedStorage,
                                _ShardedPayload, make_backend)
from repro.launch import perf_env
from repro.launch.mesh import make_local_mesh

from _helpers import max_rel_err, tree_equal  # noqa: E402

needs_multi = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >= 2 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


# ---------------------------------------------------------------------------
# duck-typed fakes: sharding semantics without a mesh
# ---------------------------------------------------------------------------


class FakeDev:
    def __init__(self, i):
        self.id = i

    def __hash__(self):
        return hash(("fake", self.id))

    def __eq__(self, other):
        return isinstance(other, FakeDev) and other.id == self.id

    def __repr__(self):
        return f"FakeDev({self.id})"


class FakeSharding:
    """Axis-0 even split of a leaf across ``devs`` (NamedSharding shape)."""

    is_fully_replicated = False

    def __init__(self, devs):
        self.devs = list(devs)
        self.addressable_devices = set(self.devs)

    def addressable_devices_indices_map(self, shape):
        k = shape[0] // len(self.devs)
        return {d: (slice(i * k, (i + 1) * k),) + (slice(None),) *
                (len(shape) - 1) for i, d in enumerate(self.devs)}


def _fake_sharded(n_streams=4):
    devs = [FakeDev(i) for i in range(n_streams)]
    store = ShardedStorage([RAMStorage() for _ in range(n_streams)],
                           devices=devs)
    sh = FakeSharding(devs)
    return store, sh


def test_sharded_storage_roundtrip_fake_devices():
    store, sh = _fake_sharded(4)
    state = {"h": np.arange(8 * 16, dtype=np.float32).reshape(8, 16),
             "acc": np.float32(3.5)}
    # None marks the replicated leaf — it must survive the flatten
    store.set_state_sharding({"h": sh, "acc": None})
    store.put(("b", 0), state)
    assert ("b", 0) in store
    got = store.get(("b", 0))
    assert np.array_equal(got["h"], state["h"])
    assert np.array_equal(got["acc"], state["acc"])
    # traffic really fanned out: every stream saw its 2x16 shard (128 B),
    # the replicated scalar rides stream 0 only
    bw = store.stream_bytes_written()
    assert store.shard_streams == 4
    assert bw[0] == 128 + 4 and bw[1:] == [128, 128, 128]
    store.delete(("b", 0))
    assert ("b", 0) not in store
    assert list(store.keys()) == []
    store.close()


def test_sharded_storage_snapshot_presplits():
    store, sh = _fake_sharded(2)
    store.set_state_sharding({"h": sh})
    state = {"h": np.random.default_rng(0).normal(size=(4, 8))
             .astype(np.float32)}
    snap = store.snapshot(state)
    assert isinstance(snap, _ShardedPayload)
    # a pre-split payload and the raw tree land identically
    store.put("a", snap)
    store.put("b", state)
    assert np.array_equal(store.get("a")["h"], store.get("b")["h"])
    store.close()


def test_sharded_storage_unsharded_tree_takes_stream0():
    store, _ = _fake_sharded(3)
    state = {"h": np.ones((5, 3), np.float32)}   # no sharding recorded
    store.put("k", state)
    assert np.array_equal(store.get("k")["h"], state["h"])
    bw = store.stream_bytes_written()
    assert bw[0] > 0 and bw[1] == 0 and bw[2] == 0
    store.close()


def test_make_backend_shards_and_journal_compose(tmp_path):
    be = make_backend("ram", shards=4,
                      devices=[FakeDev(i) for i in range(4)],
                      journal=str(tmp_path / "wal"))
    assert isinstance(be, JournaledStorage)
    assert be.shard_streams == 4            # delegated to the fan-out
    # the journal must WAL the *global* payload: its engine-facing
    # snapshot hook is pinned off so store_async gathers before logging
    assert getattr(be, "snapshot", "missing") is None
    sh = FakeSharding([FakeDev(i) for i in range(4)])
    be.inner.set_state_sharding({"h": sh})
    state = {"h": np.arange(16, dtype=np.float32).reshape(8, 2)}
    be.put(("b", 0), state)
    assert np.array_equal(be.get(("b", 0))["h"], state["h"])
    # the WAL'd global payload was re-split on the inner put
    assert all(b > 0 for b in be.inner.stream_bytes_written())
    be.close()


def test_make_backend_disk_shard_directories(tmp_path):
    devs = [FakeDev(0), FakeDev(1)]
    be = make_backend("disk", shards=2, devices=devs,
                      directory=str(tmp_path))
    sh = FakeSharding(devs)
    be.set_state_sharding({"h": sh})
    be.put("k", {"h": np.zeros((4, 4), np.float32)})
    assert be.get("k")["h"].shape == (4, 4)
    assert os.path.isdir(tmp_path / "shard0")
    assert os.path.isdir(tmp_path / "shard1")
    be.close()


def test_make_backend_tiered_budget_divides():
    devs = [FakeDev(0), FakeDev(1)]
    with tempfile.TemporaryDirectory() as d:
        be = make_backend("tiered", shards=2, devices=devs,
                          capacity_bytes=1000, directory=d)
        assert [i.capacity_bytes for i in be.inners] == [500, 500]
        be.close()


# ---------------------------------------------------------------------------
# mesh construction + perf env
# ---------------------------------------------------------------------------


def test_make_local_mesh_default_and_model_axis():
    mesh = make_local_mesh()
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape["data"] == jax.device_count()
    assert mesh.shape["model"] == 1


def test_make_local_mesh_errors_name_the_flag():
    need = jax.device_count() + 1
    with pytest.raises(ValueError,
                       match=f"xla_force_host_platform_device_count={need}"):
        make_local_mesh(data=need, model=1)
    with pytest.raises(ValueError, match="must be >= 1"):
        make_local_mesh(model=0)
    # a model axis that cannot divide the device count: clear error, and
    # the escape hatch is named
    bad = jax.device_count() + 1
    if jax.device_count() % bad != 0:
        with pytest.raises(ValueError,
                           match="xla_force_host_platform_device_count"):
            make_local_mesh(model=bad)


def test_perf_env_merges_without_clobbering():
    env = {"XLA_FLAGS": "--xla_gpu_enable_latency_hiding_scheduler=false"}
    applied = perf_env.configure_perf_env(platform="gpu", env=env)
    names = {f.split("=")[0] for f in applied}
    # the user's explicit setting wins; the other overlap flags merge in
    assert "--xla_gpu_enable_latency_hiding_scheduler" not in names
    assert "--xla_gpu_enable_async_collectives" in names
    assert "--xla_gpu_enable_latency_hiding_scheduler=false" in \
        env["XLA_FLAGS"]
    assert "--xla_gpu_enable_latency_hiding_scheduler=true" not in \
        env["XLA_FLAGS"]


def test_perf_env_cpu_and_host_devices():
    env = {}
    applied = perf_env.configure_perf_env(host_device_count=4, env=env)
    assert applied == ["--xla_force_host_platform_device_count=4"]
    # gpu-only flags stay out of a cpu/neutral environment
    assert all("gpu" not in f for f in applied)
    # idempotent: a second call applies nothing
    assert perf_env.configure_perf_env(host_device_count=4, env=env) == []
    with pytest.raises(ValueError, match=">= 1"):
        perf_env.perf_flags(host_device_count=0)


# ---------------------------------------------------------------------------
# end-to-end: sharded Level-2 streams on a forced-CPU mesh
# ---------------------------------------------------------------------------

T, B, D = 24, 8, 16


def _chain(name):
    spec = api.ChainSpec(
        prelude=lambda params, batch: (jnp.zeros((B, D), jnp.float32),
                                       batch["xs"]),
        body=lambda params, c, x, batch: jnp.tanh(c @ params["w"] + x),
        readout=lambda params, c, batch: jnp.sum(c ** 2),
        name=name)
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (D, D)) * 0.3}
    batch = {"xs": jax.random.normal(jax.random.PRNGKey(1),
                                     (T, B, D)) * 0.1}
    return spec, params, batch


@needs_multi
@pytest.mark.parametrize("engine", ["compiled", "interpreted"])
def test_mesh_gradient_parity_and_sharded_traffic(engine):
    ndev = jax.device_count()
    if B % ndev != 0:
        pytest.skip(f"batch {B} not divisible by {ndev} devices")
    mesh = make_local_mesh()
    spec, params, batch = _chain(f"shard-parity-{engine}")
    ref_loss, ref_g = jax.value_and_grad(spec.loss_fn())(params, batch)

    vg = api.value_and_grad_offloaded(spec, mesh=mesh, engine=engine,
                                      interval=6, slots=3)
    loss, grads = vg(params, batch)
    assert np.allclose(loss, ref_loss, rtol=1e-5)
    assert max_rel_err(grads, ref_g) < 1e-5

    st = api.last_stats()
    # Level-2 traffic really sharded: one stream per device, per-stream
    # bytes = global/num_devices (the carry shards evenly over the data
    # axis, so with a pinned interval the streams are exactly balanced)
    assert st.l2_shard_streams == ndev
    assert len(st.l2_stream_bytes) == ndev
    assert all(b > 0 for b in st.l2_stream_bytes)
    assert max(st.l2_stream_bytes) == min(st.l2_stream_bytes)


@needs_multi
def test_mesh_autotune_clamps_to_single_device_interval():
    ndev = jax.device_count()
    if B % ndev != 0:
        pytest.skip(f"batch {B} not divisible by {ndev} devices")
    mesh = make_local_mesh()
    spec, params, batch = _chain("shard-autotune")
    vg = api.value_and_grad_offloaded(spec, mesh=mesh, tuner=AutoTuner())
    loss, grads = vg(params, batch)
    ref_loss, ref_g = jax.value_and_grad(spec.loss_fn())(params, batch)
    assert max_rel_err(grads, ref_g) < 1e-5

    tune = api.last_tune()
    assert tune.shard_streams == ndev
    assert tune.t_t_global > 0.0
    # the clamp guarantees the per-stream time never exceeds the
    # single-stream baseline ...
    assert tune.t_t <= tune.t_t_global
    # ... so the raw §3 interval is monotone: sharded <= single-device
    # (compare unsnapped optima — divisor snapping is not monotone)
    assert optimal_interval(tune.t_t, tune.t_a) <= \
        optimal_interval(tune.t_t_global, tune.t_a)
    # per-mesh-axis single-stream T_T measured for every axis
    assert dict(tune.t_t_axes).keys() == dict(mesh.shape).keys()


@needs_multi
def test_mesh_journal_composes(tmp_path):
    ndev = jax.device_count()
    if B % ndev != 0:
        pytest.skip(f"batch {B} not divisible by {ndev} devices")
    mesh = make_local_mesh()
    spec, params, batch = _chain("shard-journal")
    ref_loss, ref_g = jax.value_and_grad(spec.loss_fn())(params, batch)
    vg = api.value_and_grad_offloaded(spec, mesh=mesh, interval=6,
                                      journal_dir=str(tmp_path))
    loss, grads = vg(params, batch)
    assert max_rel_err(grads, ref_g) < 1e-5
    st = api.last_stats()
    assert st.l2_shard_streams == ndev
    assert all(b > 0 for b in st.l2_stream_bytes)


@needs_multi
def test_mesh_state_spec_override():
    ndev = jax.device_count()
    if D % ndev != 0:
        pytest.skip(f"feature dim {D} not divisible by {ndev} devices")
    mesh = make_local_mesh()
    spec, params, batch = _chain("shard-statespec")
    ref_loss, ref_g = jax.value_and_grad(spec.loss_fn())(params, batch)
    # shard the carry's *feature* axis over data instead of the batch axis
    vg = api.value_and_grad_offloaded(spec, mesh=mesh, interval=6,
                                      state_spec=P(None, "data"))
    loss, grads = vg(params, batch)
    assert max_rel_err(grads, ref_g) < 1e-5
    st = api.last_stats()
    assert st.l2_shard_streams == ndev
    assert all(b > 0 for b in st.l2_stream_bytes)


def test_mesh_single_device_bit_identical():
    """A (1, 1) mesh must be a no-op wrapper: gradients bit-identical to
    the plain single-device compiled engine at the same pinned schedule."""
    mesh = make_local_mesh(data=1, model=1)
    spec, params, batch = _chain("shard-one-dev")
    vg_plain = api.value_and_grad_offloaded(spec, interval=6, slots=3)
    plain = vg_plain(params, batch)
    vg_mesh = api.value_and_grad_offloaded(spec, mesh=mesh, interval=6,
                                           slots=3)
    meshed = vg_mesh(params, batch)
    assert tree_equal(plain, meshed)
    # one device -> one stream, everything down it
    assert api.last_stats().l2_shard_streams == 1


def test_mesh_config_validation():
    mesh = make_local_mesh(data=1, model=1)
    with pytest.raises(ValueError, match="state_spec"):
        api.OffloadConfig(state_spec=P("data"))
    with pytest.raises(ValueError, match="multistage_async"):
        api.OffloadConfig(mesh=mesh, strategy="revolve")
    with pytest.raises(ValueError, match="trace-native"):
        api.OffloadConfig(mesh=mesh, engine="scan")
    with pytest.raises(ValueError, match="pallas"):
        api.OffloadConfig(mesh=mesh, runner="pallas")
