"""Per-architecture smoke tests (reduced same-family configs, CPU):
one train step with finite loss + grads, prefill/decode shape + finiteness,
and arch-specific feature checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, SMOKE_SHAPE, get_config
from repro.configs.base import ShapeSpec, param_count
from repro.configs.shapes import input_specs, make_batch
from repro.models import get_model

KEY = jax.random.PRNGKey(0)
ALL = ASSIGNED + ["lstm-paper"]


@pytest.mark.slow
@pytest.mark.parametrize("name", ALL)
def test_train_step_smoke(name):
    cfg = get_config(name, smoke=True)
    api = get_model(cfg)
    params = api.init(KEY)
    batch = make_batch(cfg, SMOKE_SHAPE)
    loss, grads = jax.value_and_grad(
        lambda p: api.train_loss(p, batch))(params)
    assert jnp.isfinite(loss), name
    assert float(loss) > 0
    for leaf in jax.tree_util.tree_leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf))), name


@pytest.mark.slow
@pytest.mark.parametrize("name", [n for n in ASSIGNED])
def test_prefill_and_decode_smoke(name):
    cfg = get_config(name, smoke=True)
    api = get_model(cfg)
    if api.prefill is None:
        pytest.skip("no serving path")
    params = api.init(KEY)
    bp = make_batch(cfg, ShapeSpec("s", 32, 2, "prefill"))
    logits, cache = api.prefill(params, bp)
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    bd = make_batch(cfg, ShapeSpec("s", 32, 2, "decode"))
    logits2, cache2 = api.decode(params, bd["cache"], bd)
    assert logits2.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits2)))
    # cache must actually be updated at the written position
    changed = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree_util.tree_leaves(bd["cache"]),
                        jax.tree_util.tree_leaves(cache2)))
    assert changed


@pytest.mark.slow
def test_prefill_decode_consistency_dense():
    """logits(prefill over t tokens) == logits after t-1 decode steps."""
    cfg = get_config("yi-6b", smoke=True)
    api = get_model(cfg)
    params = api.init(KEY)
    T = 8
    tokens = jax.random.randint(jax.random.fold_in(KEY, 3), (2, T), 0,
                                cfg.vocab)
    lp, _ = api.prefill(params, {"tokens": tokens})
    cache = api.init_cache(2, T)
    logits = None
    for t in range(T):
        logits, cache = api.decode(
            params, cache, {"tokens": tokens[:, t:t + 1],
                            "pos": jnp.asarray(t, jnp.int32)})
    np.testing.assert_allclose(np.array(logits), np.array(lp), rtol=2e-2,
                               atol=2e-2)


def test_gemma2_window_and_softcap_active():
    """Gemma-2's local layers must differ from a no-window ablation."""
    cfg = get_config("gemma2-2b", smoke=True)
    api = get_model(cfg)
    params = api.init(KEY)
    spec = ShapeSpec("s", 32, 2, "train")
    batch = make_batch(cfg, spec)
    base = float(api.train_loss(params, batch))
    api2 = get_model(cfg.replace(window=None))
    nowin = float(api2.train_loss(params, batch))
    assert base != pytest.approx(nowin, abs=1e-6)


def test_vlm_uses_patches():
    cfg = get_config("internvl2-1b", smoke=True)
    api = get_model(cfg)
    params = api.init(KEY)
    batch = make_batch(cfg, SMOKE_SHAPE)
    l1 = float(api.train_loss(params, batch))
    batch2 = dict(batch, patch_embeds=batch["patch_embeds"] * 0 + 1.0)
    l2 = float(api.train_loss(params, batch2))
    assert l1 != pytest.approx(l2, abs=1e-7)


def test_jamba_pattern_layout():
    cfg = get_config("jamba-v0.1-52b")
    assert cfg.period == 8
    assert cfg.layer_pattern.count("attn_moe") == 1            # 1:7 ratio
    moe_layers = sum(1 for k in cfg.layer_pattern if k.endswith("_moe"))
    assert moe_layers == 4                                      # every 2nd


def test_param_counts_match_published_scale():
    """Analytic totals should land near the published sizes."""
    expect = {
        "qwen1.5-4b": (4e9, 0.35),
        "gemma2-2b": (2.6e9, 0.4),
        "yi-6b": (6e9, 0.25),
        "granite-3-2b": (2.5e9, 0.4),
        "jamba-v0.1-52b": (52e9, 0.35),
        "llama4-scout-17b-16e": (109e9, 0.35),
        "phi3.5-moe-42b": (42e9, 0.35),
        "mamba2-370m": (370e6, 0.4),
    }
    for name, (want, tol) in expect.items():
        total, active = param_count(get_config(name))
        assert abs(total - want) / want < tol, (name, total, want)
        assert active <= total


def test_input_specs_cover_all_cells():
    from repro.configs import applicable_shapes
    for name in ASSIGNED:
        cfg = get_config(name)
        shapes = applicable_shapes(cfg)
        names = {s.name for s in shapes}
        if cfg.sub_quadratic:
            assert "long_500k" in names, name
        else:
            assert "long_500k" not in names, name
        for s in shapes:
            specs = input_specs(cfg, s)
            assert specs, (name, s.name)
