"""One planner, three engines.

A single ``segment_plan(n, I, s)`` must drive the compiled, interpreted and
trace-native scan engines — asserted by plan equivalence (same boundaries /
store events per engine) — and ``engine="scan"`` must produce gradients
matching ``jax.value_and_grad`` (and the other two engines) *inside*
``jax.jit``, under ``jax.vmap`` over a batch axis, and on a 2-device CPU
mesh with data-sharded inputs (run with
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` — the CI
multi-device job does).  The chain length is deliberately not divisible by
the interval, so every engine exercises the uneven-tail path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import api
from repro.core import schedule as ms

from _helpers import max_rel_err as _max_err  # noqa: E402

KEY = jax.random.PRNGKey(0)
T, B, D = 41, 4, 8        # 41 = 5 x 8 + 1: n % I != 0
INTERVAL, SLOTS = 8, 4

ALL_ENGINES = ("compiled", "interpreted", "scan")

needs_two_devices = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >= 2 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")


@pytest.fixture(scope="module")
def chain():
    params = {"W": jax.random.normal(KEY, (D, D)) * 0.4,
              "U": jax.random.normal(jax.random.fold_in(KEY, 1), (D, D)) * 0.2}
    xs = jax.random.normal(jax.random.fold_in(KEY, 2), (T, B, D)) * 0.1
    c0 = jnp.zeros((B, D))

    def body(p, c, x):
        c = jnp.tanh(c @ p["W"] + x @ p["U"])
        return c, jnp.sum(c ** 2)

    def ref_loss(p, c0_, xs_):
        _, ls = jax.lax.scan(lambda c, x: body(p, c, x), c0_, xs_)
        return jnp.sum(ls)

    ref_v, ref_g = jax.value_and_grad(ref_loss)(params, c0, xs)
    return params, c0, xs, body, ref_loss, float(ref_v), ref_g


def _bptt(body, engine, **opts):
    return api.checkpointed_bptt(
        body, strategy="multistage_async", interval=INTERVAL, slots=SLOTS,
        engine=engine, **opts)


# ---------------------------------------------------------------------------
# plan equivalence: the single IR behind every engine
# ---------------------------------------------------------------------------


def test_one_plan_drives_all_engines(chain):
    """Same (n, I, s) -> every engine reports the identical SegmentPlan:
    same boundaries, same segment lengths, same store events — including
    the uneven tail segment."""
    params, c0, xs, body, _, ref_v, ref_g = chain
    ref_plan = ms.segment_plan(T, INTERVAL, SLOTS)
    assert ref_plan.segments[-1].length == 1          # uneven tail exists

    plans = {}
    for engine in ALL_ENGINES:
        v, g = _bptt(body, engine)(params, c0, xs)
        assert abs(float(v) - ref_v) < 1e-4, engine
        assert _max_err(g, ref_g) < 1e-4, engine
        plan = api.last_plan()
        assert plan is not None, engine
        plans[engine] = plan
        if engine != "scan":
            # the executor engines issue exactly one Level-2 store per
            # plan boundary (the scan engine's stores are compiled: one
            # offloaded boundary tag per segment, by construction)
            assert api.last_stats().l2_stores == plan.num_segments

    for engine, plan in plans.items():
        assert plan.n == ref_plan.n, engine
        assert plan.boundaries() == ref_plan.boundaries(), engine
        assert plan.store_events() == ref_plan.store_events(), engine
        assert [s.length for s in plan.segments] == \
            [s.length for s in ref_plan.segments], engine
        assert [s.revolve is not None for s in plan.segments] == \
            [s.revolve is not None for s in ref_plan.segments], engine


def test_engines_agree_pairwise(chain):
    """The three engines' gradients agree with each other (not just with
    the reference) — interchangeable executors over one plan."""
    params, c0, xs, body, _, _, _ = chain
    grads = {e: _bptt(body, e)(params, c0, xs)[1] for e in ALL_ENGINES}
    for a in ALL_ENGINES:
        for b in ALL_ENGINES:
            assert _max_err(grads[a], grads[b]) < 1e-4, (a, b)


# ---------------------------------------------------------------------------
# scan engine under transformations
# ---------------------------------------------------------------------------


def test_scan_engine_inside_jit(chain):
    params, c0, xs, body, _, ref_v, ref_g = chain
    bptt = jax.jit(_bptt(body, "scan"))
    v, g = bptt(params, c0, xs)
    assert abs(float(v) - ref_v) < 1e-4
    assert _max_err(g, ref_g) < 1e-4
    # cached second call: no retrace, same answer
    v2, g2 = bptt(params, c0, xs)
    assert float(v2) == pytest.approx(float(v))


def test_scan_engine_under_vmap(chain):
    params, c0, xs, body, ref_loss, _, _ = chain
    K = 3
    c0s = jnp.stack([c0 + 0.1 * i for i in range(K)])
    xss = jnp.stack([xs * (1.0 + 0.2 * i) for i in range(K)])
    bptt = _bptt(body, "scan")
    v, g = jax.vmap(bptt, in_axes=(None, 0, 0))(params, c0s, xss)
    ref_v, ref_g = jax.vmap(jax.value_and_grad(ref_loss),
                            in_axes=(None, 0, 0))(params, c0s, xss)
    assert v.shape == (K,)
    np.testing.assert_allclose(np.array(v), np.array(ref_v), rtol=1e-5)
    assert _max_err(g, ref_g) < 1e-4
    # vmap composes with jit too
    vj, gj = jax.jit(jax.vmap(bptt, in_axes=(None, 0, 0)))(params, c0s, xss)
    np.testing.assert_allclose(np.array(vj), np.array(v), rtol=1e-6)


def test_scan_engine_autotunes_inside_jit(chain):
    """interval=None: the scan engine resolves its schedule at trace time
    (probes run on zero stand-ins) and caches it under the engine-qualified
    tuner name."""
    params, c0, xs, body, _, ref_v, ref_g = chain
    tuner = api.AutoTuner(repeats=1)
    bptt = api.checkpointed_bptt(body, strategy="multistage_async",
                                 engine="scan", tuner=tuner)
    v, g = jax.jit(bptt)(params, c0, xs)
    tune = api.last_tune()
    assert tune.source == "measured"
    assert 1 <= tune.interval <= T
    assert abs(float(v) - ref_v) < 1e-4
    assert _max_err(g, ref_g) < 1e-4
    assert api.last_plan().n == T


# ---------------------------------------------------------------------------
# 2-device CPU mesh: data-sharded inputs through the scan engine
# ---------------------------------------------------------------------------


@needs_two_devices
def test_scan_engine_on_mesh(chain):
    """engine='scan' under jit on a ('data',) mesh with batch-sharded
    carry/xs: gradients match the single-device reference — the sharded
    step executes the identical SegmentPlan."""
    params, c0, xs, body, _, ref_v, ref_g = chain
    mesh = Mesh(np.array(jax.devices()[:2]), ("data",))
    repl = NamedSharding(mesh, P())
    c0_sh = jax.device_put(c0, NamedSharding(mesh, P("data", None)))
    # xs is (T, B, D): the batch axis is axis 1
    xs_sh = jax.device_put(xs, NamedSharding(mesh, P(None, "data", None)))
    params_sh = jax.device_put(params, repl)

    bptt = jax.jit(_bptt(body, "scan"))
    v, g = bptt(params_sh, c0_sh, xs_sh)
    assert abs(float(v) - ref_v) < 1e-4
    assert _max_err(g, ref_g) < 1e-4
    assert api.last_plan().boundaries() == \
        ms.segment_plan(T, INTERVAL, SLOTS).boundaries()


@needs_two_devices
def test_sharded_train_step_scan_engine():
    """A jitted multi-device training step through make_train_step: the
    offloaded scan engine runs under data-sharded batches and the loss
    decreases — the production path of the tentpole."""
    from repro.configs import SMOKE_SHAPE, get_config
    from repro.configs.shapes import make_batch
    from repro.distributed.sharding import batch_shardings
    from repro.models import get_model
    from repro.optim import rmsprop
    from repro.train import init_train_state, make_train_step

    cfg = get_config("lstm-paper", smoke=True)
    m = get_model(cfg)
    opt = rmsprop(5e-3)
    state = init_train_state(m, opt, KEY)
    step = jax.jit(make_train_step(
        m, opt, strategy="multistage_async", engine="scan",
        offload_opts=dict(interval=8, slots=4)))
    mesh = Mesh(np.array(jax.devices()[:2]), ("data",))
    batch = make_batch(cfg, SMOKE_SHAPE)
    batch = jax.device_put(batch, batch_shardings(mesh, batch))
    losses = []
    for _ in range(4):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# grad_accum composes with the trace-native engine
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_train_step_grad_accum_scan_engine():
    from repro.configs import SMOKE_SHAPE, get_config
    from repro.configs.shapes import make_batch
    from repro.models import get_model
    from repro.optim import rmsprop
    from repro.train import init_train_state, make_train_step

    cfg = get_config("lstm-paper", smoke=True)
    m = get_model(cfg)
    opt = rmsprop(5e-3)
    state = init_train_state(m, opt, KEY)
    step = make_train_step(m, opt, grad_accum=2, strategy="multistage_async",
                           engine="scan", offload_opts=dict(interval=8))
    batch = make_batch(cfg, SMOKE_SHAPE)
    losses = []
    for _ in range(4):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]


def test_train_step_grad_accum_rejects_executor_engines():
    from repro.configs import get_config
    from repro.models import get_model
    from repro.optim import sgd
    from repro.train import make_train_step

    cfg = get_config("lstm-paper", smoke=True)
    m = get_model(cfg)
    with pytest.raises(ValueError, match="engine='scan'"):
        make_train_step(m, sgd(1e-3), grad_accum=2,
                        strategy="multistage_async")


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------


def test_scan_engine_config_validation():
    with pytest.raises(ValueError, match="multistage_async"):
        api.OffloadConfig(engine="scan", strategy="revolve")
    with pytest.raises(ValueError, match="XLA host memory"):
        api.OffloadConfig(engine="scan", storage="disk")
