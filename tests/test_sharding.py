"""Sharding rules: divisibility-safe param specs, cache specs, batch specs."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                        fit_spec_to_shape, param_pspec,
                                        params_shardings)


def _mesh(shape=(1, 1), names=("data", "model")):
    devs = np.array(jax.devices()[:1]).reshape((1,) * len(shape))
    devs = np.broadcast_to(devs, tuple(1 for _ in shape))
    return Mesh(devs, names)


class FakeMesh:
    """Shape-only stand-in so rules can be tested without 256 devices."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


def test_param_rules_paths():
    leaf2 = jax.ShapeDtypeStruct((64, 128), jnp.float32)       # unstacked
    leaf3 = jax.ShapeDtypeStruct((4, 64, 128), jnp.float32)    # stacked
    leaf1 = jax.ShapeDtypeStruct((64,), jnp.float32)

    def spec_for(path, leaf):
        keys = [jax.tree_util.DictKey(p) for p in path.split("/")]
        return param_pspec(keys, leaf)

    assert spec_for("embed/emb", leaf2) == P("model", None)
    # stacked leaves (leading n_periods axis) get a None prefix
    assert spec_for("layers/pos0/attn/wq/w", leaf3) == \
        P(None, "data", "model")
    assert spec_for("layers/pos0/attn/wo/w", leaf3) == \
        P(None, "model", "data")
    assert spec_for("layers/pos0/mlp/gate/w", leaf3) == \
        P(None, "data", "model")
    assert spec_for("opt/m/layers/pos0/mlp/down/w", leaf3) == \
        P(None, "model", "data")
    assert spec_for("final_norm/scale", leaf1) == P(None)
    leaf4 = jax.ShapeDtypeStruct((4, 16, 64, 128), jnp.float32)
    assert spec_for("layers/pos1/moe/w_gate", leaf4) == \
        P(None, "model", "data", None)


def test_fit_spec_drops_non_divisible():
    mesh = FakeMesh(data=16, model=16)
    # vocab 51865 not divisible by 16 -> replicated on that dim
    assert fit_spec_to_shape(mesh, P("model", None), (51865, 384)) == \
        P(None, None)
    assert fit_spec_to_shape(mesh, P("model", None), (51872, 384)) == \
        P("model", None)
    # missing axis dropped
    mesh2 = FakeMesh(data=16)
    assert fit_spec_to_shape(mesh2, P("data", "model"), (32, 32)) == \
        P("data", None)
    # tuple axes filtered
    assert fit_spec_to_shape(mesh2, P(("pod", "data"), None), (32, 4)) == \
        P(("data",), None)


def test_cache_specs_adaptive():
    mesh = FakeMesh(data=16, model=16)
    from repro.distributed.sharding import cache_pspec
    # big batch, divisible kv heads
    assert cache_pspec("pos0/k", (10, 128, 32768, 16, 128), mesh) == \
        P(None, ("data",), None, "model", None)
    # kv heads not divisible -> shard head_dim instead
    assert cache_pspec("pos0/k", (10, 128, 32768, 20, 128), mesh) == \
        P(None, ("data",), None, None, "model")
    # batch 1 -> shard the sequence axis
    assert cache_pspec("pos0/k", (10, 1, 524288, 8, 128), mesh) == \
        P(None, None, ("data",), None, "model")
    # ssm state: heads over model
    assert cache_pspec("pos0/ssm", (48, 128, 32, 64, 128), mesh) == \
        P(None, ("data",), "model", None, None)


def test_real_shardings_build_on_one_device():
    """NamedShardings must build for every arch's full param struct on the
    degenerate 1x1 mesh (smoke for the rule table)."""
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    from repro.models import get_model
    for name in ("qwen1.5-4b", "jamba-v0.1-52b", "whisper-tiny"):
        cfg = get_config(name, smoke=True)
        api = get_model(cfg)
        struct = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
        sh = params_shardings(mesh, struct)
        assert len(jax.tree_util.tree_leaves(sh)) == \
            len(jax.tree_util.tree_leaves(struct))


def test_batch_shardings_scalar_and_small_batch():
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    out = batch_shardings(mesh, {
        "tokens": jax.ShapeDtypeStruct((4, 33), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32)})
    assert out["pos"].spec == P()
