"""Sharding rules: divisibility-safe param specs, cache specs, batch specs."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import (batch_pspec, batch_shardings,
                                        cache_shardings, chain_input_shardings,
                                        fit_spec_to_shape, param_pspec,
                                        params_shardings, state_pspec,
                                        state_shardings)


def _mesh(shape=(1, 1), names=("data", "model")):
    devs = np.array(jax.devices()[:1]).reshape((1,) * len(shape))
    devs = np.broadcast_to(devs, tuple(1 for _ in shape))
    return Mesh(devs, names)


class FakeMesh:
    """Shape-only stand-in so rules can be tested without 256 devices."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


def test_param_rules_paths():
    leaf2 = jax.ShapeDtypeStruct((64, 128), jnp.float32)       # unstacked
    leaf3 = jax.ShapeDtypeStruct((4, 64, 128), jnp.float32)    # stacked
    leaf1 = jax.ShapeDtypeStruct((64,), jnp.float32)

    def spec_for(path, leaf):
        keys = [jax.tree_util.DictKey(p) for p in path.split("/")]
        return param_pspec(keys, leaf)

    assert spec_for("embed/emb", leaf2) == P("model", None)
    # stacked leaves (leading n_periods axis) get a None prefix
    assert spec_for("layers/pos0/attn/wq/w", leaf3) == \
        P(None, "data", "model")
    assert spec_for("layers/pos0/attn/wo/w", leaf3) == \
        P(None, "model", "data")
    assert spec_for("layers/pos0/mlp/gate/w", leaf3) == \
        P(None, "data", "model")
    assert spec_for("opt/m/layers/pos0/mlp/down/w", leaf3) == \
        P(None, "model", "data")
    assert spec_for("final_norm/scale", leaf1) == P(None)
    leaf4 = jax.ShapeDtypeStruct((4, 16, 64, 128), jnp.float32)
    assert spec_for("layers/pos1/moe/w_gate", leaf4) == \
        P(None, "model", "data", None)


def test_fit_spec_drops_non_divisible():
    mesh = FakeMesh(data=16, model=16)
    # vocab 51865 not divisible by 16 -> replicated on that dim
    assert fit_spec_to_shape(mesh, P("model", None), (51865, 384)) == \
        P(None, None)
    assert fit_spec_to_shape(mesh, P("model", None), (51872, 384)) == \
        P("model", None)
    # missing axis dropped
    mesh2 = FakeMesh(data=16)
    assert fit_spec_to_shape(mesh2, P("data", "model"), (32, 32)) == \
        P("data", None)
    # tuple axes filtered
    assert fit_spec_to_shape(mesh2, P(("pod", "data"), None), (32, 4)) == \
        P(("data",), None)


def test_cache_specs_adaptive():
    mesh = FakeMesh(data=16, model=16)
    from repro.distributed.sharding import cache_pspec
    # big batch, divisible kv heads
    assert cache_pspec("pos0/k", (10, 128, 32768, 16, 128), mesh) == \
        P(None, ("data",), None, "model", None)
    # kv heads not divisible -> shard head_dim instead
    assert cache_pspec("pos0/k", (10, 128, 32768, 20, 128), mesh) == \
        P(None, ("data",), None, None, "model")
    # batch 1 -> shard the sequence axis
    assert cache_pspec("pos0/k", (10, 1, 524288, 8, 128), mesh) == \
        P(None, None, ("data",), None, "model")
    # ssm state: heads over model
    assert cache_pspec("pos0/ssm", (48, 128, 32, 64, 128), mesh) == \
        P(None, ("data",), "model", None, None)


def test_real_shardings_build_on_one_device():
    """NamedShardings must build for every arch's full param struct on the
    degenerate 1x1 mesh (smoke for the rule table)."""
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    from repro.models import get_model
    for name in ("qwen1.5-4b", "jamba-v0.1-52b", "whisper-tiny"):
        cfg = get_config(name, smoke=True)
        api = get_model(cfg)
        struct = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
        sh = params_shardings(mesh, struct)
        assert len(jax.tree_util.tree_leaves(sh)) == \
            len(jax.tree_util.tree_leaves(struct))


def test_batch_shardings_scalar_and_small_batch():
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    out = batch_shardings(mesh, {
        "tokens": jax.ShapeDtypeStruct((4, 33), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32)})
    assert out["pos"].spec == P()


def test_batch_pspec_no_seq_fallback_without_data_axis():
    """Regression: the sequence-sharding fallback used to fire whenever
    ``shape[1] % mesh.shape.get("data", 1) == 0`` — i.e. *always* when the
    data axis is absent or size 1 (``x % 1 == 0``), attaching an invalid
    ``P(None, "data", ...)`` referencing a missing axis."""
    # data axis absent entirely: batch 3 not divisible by pod=4, and the
    # fallback must NOT produce a spec naming "data"
    mesh = FakeMesh(pod=4)
    assert batch_pspec((3, 33), mesh) == P(None, None)
    # data axis present but size 1: same — sharding over it is pointless
    mesh = FakeMesh(pod=4, data=1, model=2)
    assert batch_pspec((3, 32), mesh) == P(None, None)
    # genuine long-context case still shards the sequence axis
    mesh = FakeMesh(data=4)
    assert batch_pspec((1, 32), mesh) == P(None, "data")
    # and a divisible batch still takes the leading-axis path
    assert batch_pspec((8, 33), mesh) == P(("data",), None)


def test_param_pspec_optimizer_nested_and_stacked():
    """Golden specs: rules see through optimizer-state nesting, and stacked
    leading axes stay replicated for every optimizer slot."""
    leaf3 = jax.ShapeDtypeStruct((4, 64, 128), jnp.float32)
    leaf2 = jax.ShapeDtypeStruct((64, 128), jnp.float32)

    def spec_for(path, leaf):
        keys = [jax.tree_util.DictKey(p) for p in path.split("/")]
        return param_pspec(keys, leaf)

    for slot in ("m", "v"):
        assert spec_for(f"opt/{slot}/layers/pos0/attn/wq/w", leaf3) == \
            P(None, "data", "model")
        assert spec_for(f"opt/{slot}/embed/emb", leaf2) == P("model", None)
    # enc_layers/ also matches the stacked marker ("layers/")
    assert spec_for("enc_layers/pos0/mlp/down/w", leaf3) == \
        P(None, "model", "data")
    # norm scale nested in optimizer state: replicated
    leaf1 = jax.ShapeDtypeStruct((64,), jnp.float32)
    assert spec_for("opt/v/final_norm/scale", leaf1) == P(None)


def test_fit_spec_whisper_vocab_cases():
    """Whisper's 51865 vocab: every axis assignment degrades to replication
    on the non-divisible dim, on 1D and tuple axes alike."""
    mesh = FakeMesh(pod=2, data=16, model=16)
    assert fit_spec_to_shape(mesh, P("model", None), (51865, 384)) == \
        P(None, None)
    assert fit_spec_to_shape(
        mesh, P(("pod", "data"), "model"), (51865, 384)) == P(None, "model")
    # stacked embedding (n_periods, vocab, d): vocab dim still degrades
    assert fit_spec_to_shape(
        mesh, P(None, "model", None), (4, 51865, 384)) == P(None, None, None)


def test_state_pspec_derivation_and_override():
    mesh = FakeMesh(data=4, model=2)
    # leading axis shards over the batch axes when divisible
    assert state_pspec((8, 16), mesh) == P(("data",), None)
    # non-divisible leading axis replicates
    assert state_pspec((6, 16), mesh) == P(None, None)
    # scalars (loss accumulators) replicate
    assert state_pspec((), mesh) == P()
    # explicit spec is fitted per-shape: padded to rank, non-divisible
    # axes dropped
    assert state_pspec((8, 16), mesh, spec=P(None, "model")) == \
        P(None, "model")
    assert state_pspec((8, 15), mesh, spec=P(None, "model")) == P(None, None)
    assert state_pspec((8,), mesh, spec=P(None, "model")) == P(None)


def test_state_and_chain_input_shardings_build():
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    state = {"h": jax.ShapeDtypeStruct((8, 16), jnp.float32),
             "acc": jax.ShapeDtypeStruct((), jnp.float32)}
    sh = state_shardings(mesh, state)
    assert sh["acc"].spec == P()
    xs = {"x": jax.ShapeDtypeStruct((24, 8, 16), jnp.float32)}
    xsh = chain_input_shardings(mesh, xs)
    # 1-device mesh: n_b == 1, everything replicates
    assert xsh["x"].spec == P(None, None, None)
