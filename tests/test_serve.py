"""Multi-tenant serving: admission control, quotas, preemption, and the
three serving-path regressions.

Fast tests (tier-1): pure admission predicates on a fake clock, tenant
quota + per-namespace cap enforcement on ``TieredStorage``, scheduler
queue/preempt/resume flow over a toy chain.

Slow tests: real-model regressions — cache growth through the declared
``cache_spec`` (the old ``ndim == 5`` sniffing corrupts SSM caches),
mixed-length batch parity through the ``(B,)`` pos vector, decode-session
park/resume, and the end-to-end smoke asserting the admission contract
(measured fast-tier peak <= predicted) and bit-identical preempted
gradients.
"""
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from _helpers import tree_equal

from repro import api as rapi
from repro.api.chain import ChainSpec
from repro.core.storage import NamespacedStorage, RAMStorage, TieredStorage
from repro.serve import (AdmissionRejected, DecodeSession, FakeClock,
                         LinkTimes, ServeScheduler, admission_check,
                         decode_park_bytes, decode_request, train_request)

KEY = jax.random.PRNGKey(0)
TIMES = LinkTimes(t_a=1e-3, t_b=2e-3, t_t_fast=1e-4, t_t_slow=1e-3)


def toy_chain(T, B, D, name="toy"):
    return ChainSpec(
        prelude=lambda p, b: (jnp.zeros((B, D)), b["xs"]),
        body=lambda p, c, x, b: jnp.tanh(c @ p["W"] + x),
        readout=lambda p, c, b: jnp.sum(c ** 2),
        name=name)


def toy_problem(T=12, B=2, D=8, seed=0):
    key = jax.random.PRNGKey(seed)
    params = {"W": jax.random.normal(key, (D, D)) * 0.3}
    batch = {"xs": jax.random.normal(jax.random.fold_in(key, 1),
                                     (T, B, D)) * 0.1}
    return params, batch


# ---------------------------------------------------------------------------
# admission predicate (pure functions, no storage, no jax arrays)
# ---------------------------------------------------------------------------

def test_admission_train_fits():
    req = train_request("r1", "t", n=64, state_bytes=100, times=TIMES)
    d = admission_check(req, capacity_bytes=10_000, quota_bytes=1_000,
                        tenant_fast_bytes=0)
    assert d.admitted and d.interval >= 1
    assert 0 < d.predicted_fast_peak <= 1_000
    assert d.predicted_seconds > 0


def test_admission_train_rejects_below_one_state():
    """A tenant whose remaining quota cannot hold even ONE boundary state
    is rejected with the model's numbers, not admitted to thrash."""
    req = train_request("r1", "t", n=64, state_bytes=500, times=TIMES)
    d = admission_check(req, capacity_bytes=10_000, quota_bytes=1_000,
                        tenant_fast_bytes=700)   # headroom 300 < 500
    assert not d.admitted
    assert "headroom" in d.reason
    err = AdmissionRejected(d)
    assert "headroom=300B" in str(err)


def test_admission_latency_budget():
    req = train_request("r1", "t", n=10_000, state_bytes=100, times=TIMES,
                        latency_budget_s=1e-6)
    d = admission_check(req, capacity_bytes=10_000, quota_bytes=10_000,
                        tenant_fast_bytes=0)
    assert not d.admitted
    assert "latency budget" in d.reason
    assert d.predicted_seconds > 1e-6


def test_admission_decode_park_footprint():
    req = decode_request("d1", "t", batch=2, max_len=64, decode_steps=8,
                         park_bytes=5_000)
    d = admission_check(req, capacity_bytes=10_000, quota_bytes=4_000,
                        tenant_fast_bytes=0)
    assert not d.admitted and "parked session" in d.reason
    d2 = admission_check(req, capacity_bytes=10_000, quota_bytes=6_000,
                         tenant_fast_bytes=0)
    assert d2.admitted and d2.predicted_fast_peak == 5_000


def test_fake_clock():
    clk = FakeClock(10.0)
    assert clk() == 10.0
    clk.advance(2.5)
    assert clk() == 12.5
    with pytest.raises(ValueError):
        clk.advance(-1)


# ---------------------------------------------------------------------------
# tenant quotas + per-namespace caps on the shared tier
# ---------------------------------------------------------------------------

def _state(nbytes):
    return {"x": np.zeros(nbytes // 4, np.float32)}


def test_quota_evicts_own_keys_only():
    """An over-quota tenant spills ITS OWN coldest keys; the neighbour's
    fast residents are untouched."""
    tier = TieredStorage(capacity_bytes=100_000)
    tier.set_quota("a", 1_000)
    tier.set_quota("b", 1_000)
    tier.register_namespace("run_a", "a")
    tier.register_namespace("run_b", "b")
    va = NamespacedStorage(tier, "run_a")
    vb = NamespacedStorage(tier, "run_b")
    for i in range(2):
        vb.put(i, _state(400))
    for i in range(4):              # 1600B > tenant a's 1000B quota
        va.put(i, _state(400))
    assert tier.tenant_fast_bytes["a"] <= 1_000
    assert tier.tenant_fast_bytes["b"] == 800      # untouched
    assert tier.tenant_fast_peak["a"] <= 1_000
    # spilled keys remain readable (slow tier)
    for i in range(4):
        assert np.asarray(va.get(i)["x"]).nbytes == 400


def test_namespace_cap_bounds_measured_peak():
    """The admission contract is structural: a namespace registered with
    max_fast_bytes can never measure a fast peak above it, even with
    spare tenant quota."""
    tier = TieredStorage(capacity_bytes=100_000)
    tier.set_quota("a", 10_000)
    tier.register_namespace("job", "a", max_fast_bytes=900)
    v = NamespacedStorage(tier, "job")
    for i in range(8):
        v.put(i, _state(400))
    assert tier.ns_fast_peak["job"] <= 900
    assert v.fast_peak_bytes <= 900
    for i in range(8):
        assert np.asarray(v.get(i)["x"]).nbytes == 400
    assert tier.ns_fast_peak["job"] <= 900   # promotion respects the cap


def test_namespace_cap_bypass_oversized_state():
    tier = TieredStorage(capacity_bytes=100_000)
    tier.set_quota("a", 10_000)
    tier.register_namespace("job", "a", max_fast_bytes=100)
    v = NamespacedStorage(tier, "job")
    v.put(0, _state(400))            # 400 > 100: straight to the slow tier
    assert tier.ns_fast_peak["job"] == 0
    assert np.asarray(v.get(0)["x"]).nbytes == 400


def test_demote_namespace_releases_quota():
    tier = TieredStorage(capacity_bytes=100_000)
    tier.set_quota("a", 10_000)
    tier.register_namespace("sess", "a")
    v = NamespacedStorage(tier, "sess")
    v.put("parked", _state(4_000))
    assert tier.tenant_fast_bytes["a"] == 4_000
    assert v.demote() == 1
    assert tier.tenant_fast_bytes["a"] == 0
    assert np.asarray(v.get("parked")["x"]).nbytes == 4_000   # readable


def test_namespaced_close_is_noop():
    tier = TieredStorage(capacity_bytes=1_000)
    tier.set_quota("a", 1_000)
    tier.register_namespace("r", "a")
    v = NamespacedStorage(tier, "r")
    v.put(0, _state(100))
    v.close()
    assert 0 in v                    # shared tier still alive


def test_register_namespace_unknown_tenant():
    tier = TieredStorage(capacity_bytes=1_000)
    with pytest.raises(KeyError):
        tier.register_namespace("r", "nobody")


# ---------------------------------------------------------------------------
# scheduler: queue / preempt / resume over a toy chain (fast)
# ---------------------------------------------------------------------------

def _toy_sched(quota_states=8, T=12, B=2, D=8):
    state_bytes = B * D * 4
    tier = TieredStorage(capacity_bytes=state_bytes * 64)
    clk = FakeClock()
    sched = ServeScheduler(tier, clock=clk,
                           journal_root=tempfile.mkdtemp())
    sched.add_tenant("acme", quota_bytes=state_bytes * quota_states)
    return sched, tier, clk, state_bytes


def _drain(sched, clk, max_steps=50):
    steps = 0
    while sched.waiting or sched.running:
        sched.step()
        clk.advance(0.01)
        steps += 1
        assert steps < max_steps, "scheduler failed to converge"
    return {r["rid"]: r for r in sched.completed}


def test_scheduler_rejects_impossible_request():
    """state_bytes larger than the quota can NEVER fit: hard reject with
    the model's numbers, not an eternal queue."""
    sched, tier, clk, state_bytes = _toy_sched(quota_states=8)
    T, B, D = 12, 2, 128             # state = 1024B > quota impossible? no:
    # quota is 8 * 64 = 512B, this chain's state is 2*128*4 = 1024B
    params, batch = toy_problem(T, B, D)
    with pytest.raises(AdmissionRejected) as ei:
        sched.submit_train("big", "acme", toy_chain(T, B, D, "big"),
                           params, batch, times=TIMES)
    assert "headroom" in str(ei.value)
    assert not sched.waiting and not sched.running


def test_scheduler_queues_then_runs():
    """A second job that exceeds the tenant's remaining headroom queues
    (equal priority: no preemption) and runs after the first completes."""
    sched, tier, clk, state_bytes = _toy_sched(quota_states=8)
    params, batch = toy_problem()
    chain = toy_chain(12, 2, 8, "q1")
    d1 = sched.submit_train("one", "acme", chain, params, batch,
                            times=TIMES)
    assert d1.admitted
    d2 = sched.submit_train("two", "acme", chain, params, batch,
                            times=TIMES)
    assert not d2.admitted and "queued" in d2.reason
    done = _drain(sched, clk)
    assert set(done) == {"one", "two"}
    assert done["one"]["preemptions"] == 0
    assert done["two"]["preemptions"] == 0
    # equal priority: FIFO — "one" finished no later than "two"
    assert done["one"]["latency_s"] <= done["two"]["latency_s"]


def test_scheduler_preempts_low_priority_train():
    """A starved higher-priority request preempts the running low-priority
    job through the journal; both gradients come out bit-identical to the
    fault-free transform."""
    sched, tier, clk, state_bytes = _toy_sched(quota_states=8)
    params, batch = toy_problem()
    chain = toy_chain(12, 2, 8, "pre1")
    sched.submit_train("lo", "acme", chain, params, batch, times=TIMES,
                       priority=0)
    d = sched.submit_train("hi", "acme", chain, params, batch, times=TIMES,
                           priority=5)
    assert not d.admitted            # quota reserved by "lo"
    done = _drain(sched, clk)
    assert done["lo"]["preemptions"] >= 1
    assert done["hi"]["preemptions"] == 0
    # the preempted job was delayed past the preemptor
    assert done["hi"]["latency_s"] < done["lo"]["latency_s"]
    for rid in ("lo", "hi"):
        rec = done[rid]
        vg = rapi.value_and_grad_offloaded(chain, interval=rec["interval"],
                                           autotune=False)
        assert tree_equal(rec["result"], vg(params, batch)), rid
        assert rec["measured_fast_peak"] <= rec["predicted_fast_peak"], rid
    quota = tier.quota_of("acme")
    assert tier.tenant_fast_peak["acme"] <= quota


def test_scheduler_duplicate_rid_rejected():
    sched, tier, clk, _ = _toy_sched()
    params, batch = toy_problem()
    chain = toy_chain(12, 2, 8, "dup")
    sched.submit_train("x", "acme", chain, params, batch, times=TIMES)
    with pytest.raises(ValueError):
        sched.submit_train("x", "acme", chain, params, batch, times=TIMES)


def test_scheduler_unknown_tenant():
    sched, tier, clk, _ = _toy_sched()
    params, batch = toy_problem()
    with pytest.raises(KeyError):
        sched.submit_train("x", "ghost", toy_chain(12, 2, 8), params,
                           batch, times=TIMES)


# ---------------------------------------------------------------------------
# regression: cache growth must follow the model-declared cache spec
# ---------------------------------------------------------------------------

def _old_grow(cache, max_len):
    """The seed launcher's buggy growth: pad ndim==5 leaves at axis 2."""
    def grow(x):
        if x.ndim == 5:
            pad = max_len - x.shape[2]
            return jnp.pad(x, ((0, 0), (0, 0), (0, pad),
                               (0, 0), (0, 0)))
        return x
    return jax.tree_util.tree_map(grow, cache)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["mamba2-370m", "jamba-v0.1-52b"])
def test_grow_cache_ssm_regression(arch):
    """ndim sniffing corrupts SSM caches: mamba2's ssm state is 5-D but
    axis 2 is ``nheads``, not sequence — the old grow pads the wrong axis
    (and leaves the 4-D conv state at prompt length).  Growing through
    the declared cache_spec must reproduce ``init_cache(max_len)``'s
    shapes exactly, and decode must run on the grown cache."""
    from repro.configs import get_config
    from repro.models import get_model
    from repro.models.cache import grow_cache

    cfg = get_config(arch, smoke=True)
    api = get_model(cfg)
    assert api.cache_spec is not None
    params = api.init(KEY)
    B, plen, max_len = 2, 8, 16
    tokens = jax.random.randint(jax.random.fold_in(KEY, 1), (B, plen),
                                0, cfg.vocab)
    _, cache = api.prefill(params, {"tokens": tokens})

    want = jax.eval_shape(lambda: api.init_cache(B, max_len))
    want_shapes = [x.shape for x in jax.tree_util.tree_leaves(want)]

    old = _old_grow(cache, max_len)
    old_shapes = [x.shape for x in jax.tree_util.tree_leaves(old)]
    assert old_shapes != want_shapes, \
        "ndim-sniffing grow silently worked on this arch; regression moot"

    grown = grow_cache(cache, api.cache_spec, max_len)
    new_shapes = [x.shape for x in jax.tree_util.tree_leaves(grown)]
    assert new_shapes == want_shapes

    logits, _ = api.decode(
        params, grown,
        {"tokens": tokens[:, :1],
         "pos": jnp.full((B,), plen, jnp.int32)})
    assert bool(jnp.all(jnp.isfinite(logits)))


# ---------------------------------------------------------------------------
# regression: decode donation must be gated for preemptible sessions
# ---------------------------------------------------------------------------

def test_make_serve_steps_donation_gate():
    """The seed launcher jitted decode with donate_argnums=(1,)
    unconditionally — after a faulted step the donated cache is gone
    ("Array has been deleted") and the session cannot retry or park.
    make_serve_steps must expose the gate."""
    from repro.configs import get_config
    from repro.models import get_model
    from repro.train import make_serve_steps

    cfg = get_config("qwen1.5-4b", smoke=True)
    api = get_model(cfg)
    _, donating = make_serve_steps(api)
    assert donating.donates_cache
    _, gated = make_serve_steps(api, donate_cache=False)
    assert not gated.donates_cache
    _, unjitted = make_serve_steps(api, jit=False)
    assert not unjitted.donates_cache


@pytest.mark.slow
def test_decode_session_park_resume_regression():
    """A preempted (parked) decode session resumes with tokens identical
    to an uninterrupted run.  With the seed's unconditional donation the
    parked cache would be a donated (deleted) buffer."""
    from repro.configs import get_config
    from repro.models import get_model

    cfg = get_config("qwen1.5-4b", smoke=True)
    api = get_model(cfg)
    params = api.init(KEY)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=(n,)) for n in (5, 9)]

    ref = DecodeSession(api, params, batch=2, max_len=16, decode_steps=4)
    for p in prompts:
        ref.add_request(p)
    while not ref.done():
        ref.step()

    backend = RAMStorage()
    s = DecodeSession(api, params, batch=2, max_len=16, decode_steps=4,
                      backend=backend, preemptible=True)
    assert not s.decode_fn.donates_cache
    for p in prompts:
        s.add_request(p)
    s.step()                          # partial progress
    s.park()
    assert s.cache is None            # device state dropped
    s.unpark()
    while not s.done():
        s.step()
    assert s.generated == ref.generated


def test_non_preemptible_session_cannot_park():
    from repro.configs import get_config
    from repro.models import get_model

    cfg = get_config("qwen1.5-4b", smoke=True)
    api = get_model(cfg)
    params = api.init(KEY)
    s = DecodeSession(api, params, batch=1, max_len=8, decode_steps=2,
                      backend=RAMStorage(), preemptible=False)
    with pytest.raises(RuntimeError, match="non-preemptible"):
        s.park()


# ---------------------------------------------------------------------------
# regression: per-request (B,) positions for mixed-length batches
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen1.5-4b", "mamba2-370m"])
def test_mixed_length_batch_parity(arch):
    """A ragged batch decoded jointly (per-slot positions) must produce
    exactly the tokens each prompt produces alone at B=1.  With the old
    scalar ``pos`` every slot shared one write position and one causal
    horizon, so unequal prompts corrupted each other."""
    from repro.configs import get_config
    from repro.models import get_model

    cfg = get_config(arch, smoke=True)
    api = get_model(cfg)
    params = api.init(KEY)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=(n,)) for n in (4, 9, 6)]

    joint = DecodeSession(api, params, batch=3, max_len=16, decode_steps=4)
    for p in prompts:
        joint.add_request(p)
    while not joint.done():
        joint.step()

    for i, p in enumerate(prompts):
        solo = DecodeSession(api, params, batch=1, max_len=16,
                             decode_steps=4)
        solo.add_request(p)
        while not solo.done():
            solo.step()
        assert solo.generated[0] == joint.generated[i], f"slot {i}"


@pytest.mark.slow
def test_decode_attention_vector_pos_matches_scalar():
    """(B,) pos with equal entries must equal the scalar-pos path."""
    from repro.configs import get_config
    from repro.models import get_model

    cfg = get_config("qwen1.5-4b", smoke=True)
    api = get_model(cfg)
    params = api.init(KEY)
    B, plen = 2, 6
    tokens = jax.random.randint(jax.random.fold_in(KEY, 2), (B, plen),
                                0, cfg.vocab)
    _, cache = api.prefill(params, {"tokens": tokens})
    from repro.models.cache import grow_cache
    cache = grow_cache(cache, api.cache_spec, 12)
    tok = tokens[:, :1]
    l_scalar, _ = api.decode(params, cache,
                             {"tokens": tok,
                              "pos": jnp.asarray(plen, jnp.int32)})
    l_vector, _ = api.decode(params, cache,
                             {"tokens": tok,
                              "pos": jnp.full((B,), plen, jnp.int32)})
    assert bool(jnp.array_equal(l_scalar, l_vector))


# ---------------------------------------------------------------------------
# e2e smoke: decode + train multiplexed on one tier, with parking
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_scheduler_decode_parked_and_resumed_e2e():
    """Decode session parked to admit a high-priority train job, then
    unparked: tokens match the uninterrupted reference, every request's
    measured fast peak obeys its admission prediction, and the tenant
    never exceeds its quota."""
    from repro.configs import get_config
    from repro.models import get_model

    cfg = get_config("qwen1.5-4b", smoke=True)
    api = get_model(cfg)
    mparams = api.init(KEY)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab, size=(n,)) for n in (5, 8)]

    ref = DecodeSession(api, mparams, batch=2, max_len=16, decode_steps=4)
    for p in prompts:
        ref.add_request(p)
    while not ref.done():
        ref.step()

    park = decode_park_bytes(api, 2, 16)
    T, B, D = 8, 2, 8
    tparams, tbatch = toy_problem(T, B, D, seed=3)
    chain = toy_chain(T, B, D, "e2e")
    state_bytes = B * D * 4

    quota = park + state_bytes // 2   # decode fits alone; train does not
    tier = TieredStorage(capacity_bytes=quota * 4)
    clk = FakeClock()
    sched = ServeScheduler(tier, clock=clk,
                           journal_root=tempfile.mkdtemp())
    sched.add_tenant("acme", quota_bytes=quota)

    d = sched.submit_decode("dec", "acme", api, mparams, prompts=prompts,
                            max_len=16, decode_steps=4, priority=0)
    assert d.admitted and d.predicted_fast_peak == park
    sched.step()                      # one decode round of progress
    clk.advance(0.01)
    d2 = sched.submit_train("urgent", "acme", chain, tparams, tbatch,
                            times=TIMES, priority=5)
    assert not d2.admitted

    done = _drain(sched, clk)
    assert done["dec"]["preemptions"] >= 1
    assert done["dec"]["generated"] == ref.generated
    for rec in done.values():
        assert rec["measured_fast_peak"] <= rec["predicted_fast_peak"], \
            rec["rid"]
    assert tier.tenant_fast_peak["acme"] <= quota
    vg = rapi.value_and_grad_offloaded(
        chain, interval=done["urgent"]["interval"], autotune=False)
    assert tree_equal(done["urgent"]["result"], vg(tparams, tbatch))
