"""SSM: chunked SSD vs sequential oracle; block train path vs decode path."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st  # optional dep, see shim

from repro.models.layers import DTypes
from repro.models.ssm import (init_mamba2, mamba2_block, mamba2_decode_step,
                              ssd_chunked, ssd_sequential)

KEY = jax.random.PRNGKey(0)
DT = DTypes(compute=jnp.float32)


def _ssd_inputs(b, t, h, g, p, n, seed=0):
    k = jax.random.fold_in(KEY, seed)
    x = jax.random.normal(jax.random.fold_in(k, 1), (b, t, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 2),
                                           (b, t, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 3), (h,)) * 0.3)
    B = jax.random.normal(jax.random.fold_in(k, 4), (b, t, g, n)) * 0.5
    C = jax.random.normal(jax.random.fold_in(k, 5), (b, t, g, n)) * 0.5
    return x, dt, A, B, C


@settings(deadline=None, max_examples=12)
@given(t=st.sampled_from([32, 64, 128]), chunk=st.sampled_from([8, 16, 32]),
       h=st.sampled_from([2, 4]), g=st.sampled_from([1, 2]))
def test_chunked_equals_sequential(t, chunk, h, g):
    if h % g:
        g = 1
    x, dt, A, B, C = _ssd_inputs(2, t, h, g, 8, 4)
    y_ref, h_ref = ssd_sequential(x, dt, A, B, C)
    y, hf = ssd_chunked(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.array(y), np.array(y_ref), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.array(hf), np.array(h_ref), rtol=1e-4,
                               atol=1e-4)


def test_initial_state_threading():
    """Chunked processing with a carried state == one long scan — the
    uniform-state property the paper's checkpoints rely on."""
    x, dt, A, B, C = _ssd_inputs(1, 64, 2, 1, 8, 4)
    y_all, h_all = ssd_sequential(x, dt, A, B, C)
    # process in two halves, threading the state
    y1, h1 = ssd_chunked(x[:, :32], dt[:, :32], A, B[:, :32], C[:, :32],
                         chunk=16)
    y2, h2 = ssd_chunked(x[:, 32:], dt[:, 32:], A, B[:, 32:], C[:, 32:],
                         chunk=16, h0=h1)
    np.testing.assert_allclose(np.array(jnp.concatenate([y1, y2], axis=1)),
                               np.array(y_all), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.array(h2), np.array(h_all), rtol=1e-4,
                               atol=1e-4)


def test_block_train_equals_decode():
    d_model, b, t = 32, 2, 12
    p = init_mamba2(jax.random.fold_in(KEY, 9), d_model, d_state=8,
                    headdim=8, ngroups=1)
    x = jax.random.normal(jax.random.fold_in(KEY, 10), (b, t, d_model)) * 0.5
    y_train = mamba2_block(p, x, d_state=8, headdim=8, chunk=4, dt=DT)
    conv = jnp.zeros((b, 3, 2 * d_model + 16))
    ssm = jnp.zeros((b, (2 * d_model) // 8, 8, 8))
    ys = []
    for i in range(t):
        y, conv, ssm = mamba2_decode_step(p, x[:, i:i + 1], conv, ssm,
                                          d_state=8, headdim=8, dt=DT)
        ys.append(y)
    np.testing.assert_allclose(np.array(jnp.concatenate(ys, axis=1)),
                               np.array(y_train), rtol=2e-3, atol=2e-3)


def test_block_state_return_consistency():
    """prefill-style (return_state) then decode == one long train pass."""
    d_model, b = 32, 1
    p = init_mamba2(jax.random.fold_in(KEY, 11), d_model, d_state=8,
                    headdim=8)
    x = jax.random.normal(jax.random.fold_in(KEY, 12), (b, 16, d_model)) * 0.5
    y_full = mamba2_block(p, x, d_state=8, headdim=8, chunk=8, dt=DT)
    y_pre, (conv, ssm) = mamba2_block(p, x[:, :12], d_state=8, headdim=8,
                                      chunk=4, dt=DT, return_state=True)
    ys = [y_pre]
    for i in range(12, 16):
        y, conv, ssm = mamba2_decode_step(p, x[:, i:i + 1], conv,
                                          ssm.astype(jnp.float32),
                                          d_state=8, headdim=8, dt=DT)
        ys.append(y)
    np.testing.assert_allclose(np.array(jnp.concatenate(ys, axis=1)),
                               np.array(y_full), rtol=2e-3, atol=2e-3)


def test_grads_finite():
    x, dt, A, B, C = _ssd_inputs(1, 32, 2, 1, 8, 4)

    def loss(x):
        y, _ = ssd_chunked(x, dt, A, B, C, chunk=8)
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(x)
    assert bool(jnp.all(jnp.isfinite(g)))
