"""Regression tests for the benchmark harness (benchmarks/run.py).

The harness used to import every bench module eagerly at module import —
one broken module aborted the whole run — and an import failure inside a
section could drop that section without a trace.  These tests pin the
fixed contract: lazy per-section import, loud SKIPPED + traceback on
import failure, nonzero exit when *all* selected sections were skipped,
and the kernel payload merged into the overhead JSON artifact.
"""
import json
import textwrap

import pytest

from benchmarks import run as bench_run


def _write_module(tmp_path, monkeypatch, name, body):
    (tmp_path / f"{name}.py").write_text(textwrap.dedent(body))
    monkeypatch.syspath_prepend(str(tmp_path))
    return name


@pytest.fixture
def fake_modules(tmp_path, monkeypatch):
    good = _write_module(tmp_path, monkeypatch, "bench_fake_good", """
        def main(smoke=False):
            return {"ok": True, "smoke": smoke}
    """)
    broken = _write_module(tmp_path, monkeypatch, "bench_fake_broken", """
        raise ImportError("synthetic: missing optional dependency")
    """)
    failing = _write_module(tmp_path, monkeypatch, "bench_fake_failing", """
        def main():
            raise AssertionError("synthetic paper-claim violation")
    """)
    return good, broken, failing


def test_import_failure_is_loud_skip_not_abort(fake_modules, tmp_path, capsys):
    good, broken, _ = fake_modules
    code = bench_run.run(sections=[("good", good), ("broken", broken)],
                         out_path=str(tmp_path / "out.json"))
    out = capsys.readouterr().out
    assert code == 0  # one healthy section keeps the run green...
    assert "SKIPPED broken" in out            # ...but the skip is loud
    assert "synthetic: missing optional dependency" in out  # traceback shown
    assert "== good ==" in out and "-- ok in" in out


def test_all_sections_skipped_exits_nonzero(fake_modules, tmp_path, capsys):
    _, broken, _ = fake_modules
    code = bench_run.run(sections=[("b1", broken), ("b2", broken)],
                         out_path=str(tmp_path / "out.json"))
    assert code == 1
    assert "every selected benchmark section was skipped" in \
        capsys.readouterr().out


def test_section_failure_still_exits_nonzero(fake_modules, tmp_path):
    good, _, failing = fake_modules
    code = bench_run.run(sections=[("good", good), ("bad", failing)],
                         out_path=str(tmp_path / "out.json"))
    assert code == 1


def test_only_filter_selects_lazily(fake_modules, tmp_path, capsys):
    # --only must not even import the deselected (broken) module
    good, broken, _ = fake_modules
    code = bench_run.run(only="good",
                         sections=[("good", good), ("broken", broken)],
                         out_path=str(tmp_path / "out.json"))
    out = capsys.readouterr().out
    assert code == 0
    assert "SKIPPED" not in out and "broken" not in out


def test_kernel_payload_merged_into_overhead_json(tmp_path, monkeypatch):
    fig5 = _write_module(tmp_path, monkeypatch, "bench_fake_fig5", """
        def main(smoke=False):
            return {"journal_overhead": {"journal_tax": 1.2}}
    """)
    kern = _write_module(tmp_path, monkeypatch, "bench_fake_kern", """
        def main():
            return {"fused_vs_compiled": {"grad_bitwise_match": True}}
    """)
    out_path = tmp_path / "BENCH_overhead.json"
    code = bench_run.run(smoke=True, out_path=str(out_path),
                         sections=[("fig5_measured_overhead", fig5),
                                   ("kernel_rooflines", kern)])
    assert code == 0
    doc = json.loads(out_path.read_text())
    assert doc["smoke"] is True
    assert doc["payload"]["journal_overhead"]["journal_tax"] == 1.2
    assert doc["kernels"]["fused_vs_compiled"]["grad_bitwise_match"] is True


def test_serve_payload_written_without_fig5(tmp_path, monkeypatch):
    # the bench-smoke CI job runs `--only fig5,serve`; a serve-only run
    # must still produce the artifact with the "serve" section
    serve = _write_module(tmp_path, monkeypatch, "bench_fake_serve", """
        def main(smoke=False):
            return {"preemptions": 1, "p99_s": 0.1}
    """)
    out_path = tmp_path / "BENCH_overhead.json"
    code = bench_run.run(smoke=True, out_path=str(out_path),
                         sections=[("serve_scheduler", serve)])
    assert code == 0
    doc = json.loads(out_path.read_text())
    assert doc["serve"]["preemptions"] == 1
    assert "payload" not in doc


def test_only_filter_accepts_comma_list(fake_modules, tmp_path, capsys):
    good, broken, _ = fake_modules
    code = bench_run.run(only="good,also-good",
                         sections=[("good", good), ("also-good", good),
                                   ("broken", broken)],
                         out_path=str(tmp_path / "out.json"))
    out = capsys.readouterr().out
    assert code == 0
    assert out.count("-- ok in") == 2
    assert "broken" not in out


def test_real_registry_importable_and_lazy():
    # the shipped registry holds (name, module_path) string pairs — the
    # eager-import regression would turn these back into module objects
    for name, module_path in bench_run.ALL:
        assert isinstance(module_path, str) and module_path.startswith(
            "benchmarks."), (name, module_path)
