"""Parameter streaming (``offload_params="moe_experts"``): expert blobs
move through the Level-2 lane with plan-aware prefetch, gradients stay
bit-identical to the non-streamed path, boundary states and expert blobs
share one tiered capacity budget, and the fast-tier peak is exactly
replayable from the merged resource-access plan."""
import jax
import numpy as np
import pytest

from repro import api
from repro.api.frontend import _expert_leaf_ids
from repro.configs import SMOKE_SHAPE, get_config
from repro.configs.shapes import make_batch
from repro.core import perfmodel as pm
from repro.core import schedule as ms
from repro.core.executor import ParamStream
from repro.core.storage import RAMStorage, register_backend, tree_bytes
from repro.models import get_model

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def moe_setup():
    cfg = get_config("phi3.5-moe-42b", smoke=True).replace(n_layers=4)
    m = get_model(cfg)
    params = m.init(jax.random.fold_in(KEY, 8))
    batch = make_batch(cfg, SMOKE_SHAPE)
    vg = api.value_and_grad_offloaded(m.train_loss, interval=2)
    ref_v, ref_g = vg(params, batch)
    return m, params, batch, np.asarray(ref_v), ref_g


def _assert_bitwise_equal(g, ref_g):
    la, lb = jax.tree_util.tree_leaves(g), jax.tree_util.tree_leaves(ref_g)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_streamed_grads_bit_identical(moe_setup):
    m, params, batch, ref_v, ref_g = moe_setup
    vg = api.value_and_grad_offloaded(m.train_loss, interval=2,
                                      offload_params="moe_experts")
    v, g = vg(params, batch)
    np.testing.assert_array_equal(np.asarray(v), ref_v)
    _assert_bitwise_equal(g, ref_g)
    st = api.last_stats()
    assert st.param_prefetches > 0
    assert st.param_bytes_moved > 0
    assert st.param_fetch_stalls == 0      # lead=1 hides every fetch


def test_streamed_tiered_shares_capacity_and_replays_peak(moe_setup):
    """Boundary states and expert blobs under one tiered budget: the
    measured fast-tier peak equals the perfmodel replay of the merged
    ResourceAccessPlan at every capacity, and gradients never change."""
    m, params, batch, ref_v, ref_g = moe_setup
    spec = m.train_loss.chain_spec
    carry0, xs = spec.prelude(params, batch)
    state_bytes = tree_bytes(jax.tree_util.tree_map(np.asarray, carry0))
    leaf_ids = _expert_leaf_ids(xs)
    assert leaf_ids                        # the MoE chain must expose blobs
    flat = jax.tree_util.tree_leaves(xs)
    leaves = {i: np.asarray(flat[i]) for i in leaf_ids}
    n_experts = next(iter(leaves.values())).shape[1]

    for cap in (1 << 22, 1 << 19, 1 << 17):
        vg = api.value_and_grad_offloaded(
            m.train_loss, interval=2, storage="tiered",
            l2_capacity_bytes=cap, offload_params="moe_experts")
        v, g = vg(params, batch)
        np.testing.assert_array_equal(np.asarray(v), ref_v)
        _assert_bitwise_equal(g, ref_g)
        st = api.last_stats()
        assert st.l2_fast_peak_bytes <= cap
        ps = ParamStream(None, leaves, n_experts=n_experts)
        ps.bind(api.last_plan())
        puts = [(k, ps.blob_bytes[k[1]]) for k in ps.population_order()]
        puts += [(seg.begin, state_bytes)
                 for seg in api.last_plan().segments]
        dist = ms.merge_access_plans(
            ps.access_plan("forward"),
            api.last_plan().resource_access_plan(state_bytes)
            .shift(len(api.last_plan().segments))).distances()
        assert st.l2_fast_peak_bytes == \
            pm.fast_peak_bytes_resources(puts, dist, cap)


def test_expert_blobs_purged_after_run(moe_setup):
    """The transient expert blobs must not outlive the run: after the
    gradient returns, no ("xp", ...) key is left in Level-2."""
    m, params, batch, ref_v, ref_g = moe_setup
    instances = []

    def factory():
        b = RAMStorage()
        instances.append(b)
        return b

    register_backend("param-stream-probe", factory)
    vg = api.value_and_grad_offloaded(m.train_loss, interval=2,
                                      storage="param-stream-probe",
                                      offload_params="moe_experts")
    v, g = vg(params, batch)
    _assert_bitwise_equal(g, ref_g)
    assert instances
    leftover = [k for k in instances[-1]._data
                if isinstance(k, tuple) and k and k[0] == "xp"]
    assert leftover == []


def test_routing_counts_reorder_plan_not_membership():
    """Routing statistics only reorder the intra-step eviction priority;
    the set of streamed keys per segment is unchanged (every expert is
    still fetched — bit-exactness does not ride on the counts)."""
    leaves = {3: np.zeros((4, 2, 8, 16), np.float32)}
    plan = ms.segment_plan(n=4, interval=2, s_l1=2)
    counts = np.array([[0, 9]] * 4)        # expert 1 busiest every step
    ps_uniform = ParamStream(None, leaves, n_experts=2)
    ps_counts = ParamStream(None, leaves, n_experts=2, expert_counts=counts)
    ps_uniform.bind(plan)
    ps_counts.bind(plan)
    seg = plan.segments[0]
    ku = ps_uniform.segment_keys(seg)
    kc = ps_counts.segment_keys(seg)
    assert sorted(ku) == sorted(kc)        # same membership
    assert ku != kc                        # different priority order
    assert kc[0] == ms.expert_key(3, seg.end - 1, 1)   # busiest first
    # and the access-plan producer agrees with the runtime key order
    # (the reverse plan opens with the last segment, reversed sweep)
    last = plan.segments[-1]
    kl = ps_counts.segment_keys(last)
    ap = ps_counts.access_plan("reverse")
    assert [a.key for a in ap.accesses[:len(kl)]] == list(kl)


def test_offload_params_validation():
    bad = [
        dict(offload_params="fft_twiddles"),
        dict(offload_params="moe_experts", strategy="revolve"),
        dict(offload_params="moe_experts", engine="interpreted"),
        dict(offload_params="moe_experts", engine="scan"),
        dict(offload_params="moe_experts", runner="pallas"),
        dict(offload_params="moe_experts", storage="compressed"),
        dict(offload_params="moe_experts", journal_dir="/tmp/x"),
        dict(offload_params="moe_experts", step_memory_budget=1 << 20),
        dict(offload_params="moe_experts", plan_2d=(2, 1)),
    ]
    for kw in bad:
        with pytest.raises(ValueError):
            api.OffloadConfig(**kw)
    # the valid combination constructs fine
    api.OffloadConfig(offload_params="moe_experts")


def test_offload_params_needs_expert_leaves():
    """A chain with no per-expert leaves fails fast with a clear error
    instead of silently streaming nothing."""
    cfg = get_config("lstm-paper", smoke=True)
    m = get_model(cfg)
    params = m.init(KEY)
    batch = make_batch(cfg, SMOKE_SHAPE)
    vg = api.value_and_grad_offloaded(m.train_loss, interval=2,
                                      offload_params="moe_experts")
    with pytest.raises(Exception, match="no per-expert"):
        vg(params, batch)
