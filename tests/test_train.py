"""Training substrate: optimization, accumulation, checkpointing,
compression, fault tolerance, data pipeline."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional dep, see shim

from repro.ckpt import CheckpointManager
from repro.configs import SMOKE_SHAPE, get_config
from repro.data import Prefetcher, SyntheticDataset
from repro.distributed import compression as comp
from repro.distributed.fault_tolerance import (StragglerWatchdog,
                                               elastic_mesh, with_retries)
from repro.models import get_model
from repro.optim import adamw, rmsprop, sgd, clip_by_global_norm
from repro.train import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen1.5-4b", smoke=True)
    api = get_model(cfg)
    opt = adamw(3e-3)
    ds = SyntheticDataset(cfg, SMOKE_SHAPE)
    batch = jax.tree_util.tree_map(jnp.asarray, ds.batch(0))
    return cfg, api, opt, ds, batch


@pytest.mark.slow
def test_overfits_fixed_batch(setup):
    cfg, api, opt, ds, batch = setup
    state = init_train_state(api, opt, KEY)
    step = jax.jit(make_train_step(api, opt))
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0
    assert int(state["step"]) == 8


@pytest.mark.slow
def test_grad_accum_matches_full_batch(setup):
    cfg, api, opt, ds, batch = setup
    s0 = init_train_state(api, opt, jax.random.PRNGKey(7))
    s1, m1 = jax.jit(make_train_step(api, opt))(s0, batch)
    s2, m2 = jax.jit(make_train_step(api, opt, grad_accum=2))(s0, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    assert abs(float(m1["grad_norm"]) - float(m2["grad_norm"])) < \
        0.02 * float(m1["grad_norm"]) + 1e-3


@pytest.mark.parametrize("make_opt", [lambda: rmsprop(1e-3),
                                      lambda: sgd(1e-2, momentum=0.9)])
@pytest.mark.slow
def test_other_optimizers_reduce_loss(setup, make_opt):
    cfg, api, _, ds, batch = setup
    opt = make_opt()
    state = init_train_state(api, opt, KEY)
    step = jax.jit(make_train_step(api, opt))
    l0 = lN = None
    for i in range(6):
        state, m = step(state, batch)
        l0 = float(m["loss"]) if l0 is None else l0
        lN = float(m["loss"])
    assert lN < l0


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((3,), -10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    total = sum(float(jnp.sum(x ** 2))
                for x in jax.tree_util.tree_leaves(clipped))
    assert total == pytest.approx(1.0, rel=1e-5)
    assert float(gn) == pytest.approx(np.sqrt(700.0), rel=1e-6)


def test_checkpoint_roundtrip_and_resume(setup):
    cfg, api, opt, ds, batch = setup
    state = init_train_state(api, opt, KEY)
    step = jax.jit(make_train_step(api, opt))
    state, _ = step(state, batch)
    with tempfile.TemporaryDirectory() as d:
        with CheckpointManager(d, keep_last=2) as cm:
            cm.save(state, 1)
            state2, _ = step(state, batch)
            cm.save(state2, 2)
            cm.wait()
            assert cm.all_steps() == [1, 2]
            restored, s = cm.restore(state)
            assert s == 2
            for a, b in zip(jax.tree_util.tree_leaves(state2),
                            jax.tree_util.tree_leaves(restored)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # garbage collection respects keep_last
        with CheckpointManager(d, keep_last=1) as cm2:
            cm2.save(restored, 3)
            cm2.wait()
            assert cm2.all_steps()[-1] == 3


def test_checkpoint_atomic_publish():
    with tempfile.TemporaryDirectory() as d:
        with CheckpointManager(d) as cm:
            cm.save({"x": jnp.ones((8,))}, 1)
            cm.wait()
            import os
            assert not any(p.endswith(".tmp") for p in os.listdir(d))


# ---------------------------------------------------------------- compression
@settings(deadline=None, max_examples=25)
@given(scale=st.floats(1e-4, 1e3))
def test_quantization_error_bound(scale):
    x = jax.random.normal(jax.random.PRNGKey(3), (64,)) * scale
    q, s = comp.quantize(x)
    err = float(jnp.max(jnp.abs(comp.dequantize(q, s) - x)))
    assert err <= comp.quantization_error_bound(x) * 1.01 + 1e-12
    assert q.dtype == jnp.int8


def test_error_feedback_reduces_bias():
    """Repeated quantisation with EF must track the true running sum."""
    x = jax.random.normal(jax.random.PRNGKey(4), (256,)) * 0.01
    e = jnp.zeros_like(x)
    acc_q = jnp.zeros_like(x)
    for _ in range(50):
        g = x + e
        q, s = comp.quantize(g)
        dq = comp.dequantize(q, s)
        e = g - dq
        acc_q = acc_q + dq
    true = x * 50
    rel = float(jnp.linalg.norm(acc_q - true) / jnp.linalg.norm(true))
    assert rel < 0.01  # EF keeps the accumulated error tiny


def test_compressed_mean_single_axis():
    """compressed_mean over a trivial 1-device mesh axis is exact dequant."""
    from jax.sharding import Mesh
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    import numpy as np
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("pod",))
    tree = {"w": jnp.linspace(-1, 1, 32)}

    def f(t):
        m, e = comp.compressed_mean(t, "pod")
        return m, e

    m, e = shard_map(f, mesh=mesh, in_specs=(P(),), out_specs=(P(), P()))(tree)
    np.testing.assert_allclose(np.array(m["w"]), np.array(tree["w"]),
                               atol=comp.quantization_error_bound(tree["w"]))
    np.testing.assert_allclose(np.array(m["w"] + e["w"]),
                               np.array(tree["w"]), atol=1e-6)


# ------------------------------------------------------------ fault tolerance
def test_straggler_watchdog():
    import time
    wd = StragglerWatchdog(warmup=2, threshold=1.5)
    for step in range(4):
        wd.start()
        time.sleep(0.01)
        assert not wd.stop(step)
    wd.start()
    time.sleep(0.1)
    assert wd.stop(4)
    assert wd.slow_steps and wd.slow_steps[0][0] == 4


def test_elastic_mesh_shrinks_data_axis():
    mesh = elastic_mesh(1, model_parallelism=1)
    assert mesh.shape["data"] == 1 and mesh.shape["model"] == 1
    with pytest.raises(RuntimeError):
        elastic_mesh(0)


def test_with_retries():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("preempted")
        return 42

    assert with_retries(flaky, retries=3)() == 42
    assert calls["n"] == 3


def test_with_retries_recover_hook_runs_before_each_attempt():
    """The recovery path (checkpoint restore + journal resume in the
    launcher) must run between a failure and its re-attempt — and a
    typed StorageFault (a RuntimeError subclass) must be retryable."""
    from repro.core.faults import StorageFault

    seen = []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise StorageFault(f"level-2 fault {calls['n']}")
        return "ok"

    def recover(attempt, err):
        assert isinstance(err, StorageFault)
        seen.append((attempt, calls["n"]))

    assert with_retries(flaky, retries=3, recover=recover)() == "ok"
    # recover ran after failure 1 (before attempt 2) and after failure 2
    assert seen == [(0, 1), (1, 2)]


@pytest.mark.slow
def test_launcher_retries_through_injected_storage_fault(tmp_path):
    """End-to-end launcher recovery: a step that dies to an injected
    Level-2 fetch failure must be retried in-process and the run must
    complete — requires both the journal's standing resume mode and the
    no-donation-under-journaling rule (a donated state would die on
    'Array has been deleted' at the first retry)."""
    from repro.core import faults
    from repro.core.faults import FaultPlan
    from repro.launch.train import main as train_main

    with faults.inject(FaultPlan(fail_get_at=1)):
        state = train_main([
            "--arch", "lstm-paper", "--smoke", "--steps", "2",
            "--strategy", "multistage_async", "--interval", "8",
            "--slots", "4", "--journal-dir", str(tmp_path / "wal")])
    assert int(state["step"]) == 2   # the faulted step was retried, not lost


def test_restore_of_gced_step_raises():
    """Regression: restore(step=) must refuse a step that was never saved
    or has been garbage-collected instead of handing back different
    weights — and the error lists what all_steps() still holds."""
    state = {"w": jnp.arange(4.0)}
    with tempfile.TemporaryDirectory() as d:
        with CheckpointManager(d, keep_last=2) as cm:
            for s in (1, 2, 3, 4):
                cm.save(state, s)
            cm.wait()
            assert cm.all_steps() == [3, 4]      # 1 and 2 were GC'd
            with pytest.raises(ValueError, match=r"step 1 not available"):
                cm.restore(state, step=1)
            with pytest.raises(ValueError, match=r"\[3, 4\]"):
                cm.restore(state, step=99)       # never saved
            _, s = cm.restore(state, step=3)     # an existing step is fine
            assert s == 3


# --------------------------------------------------------------------- data
def test_synthetic_data_deterministic():
    cfg = get_config("yi-6b", smoke=True)
    ds1 = SyntheticDataset(cfg, SMOKE_SHAPE, seed=1)
    ds2 = SyntheticDataset(cfg, SMOKE_SHAPE, seed=1)
    np.testing.assert_array_equal(ds1.batch(5)["tokens"],
                                  ds2.batch(5)["tokens"])
    assert not np.array_equal(ds1.batch(5)["tokens"], ds1.batch(6)["tokens"])
    assert ds1.batch(0)["tokens"].max() < cfg.vocab


def test_prefetcher_order_and_close():
    it = Prefetcher(iter(range(10)), depth=3)
    assert list(it) == list(range(10))
    it2 = Prefetcher(iter(range(1000)), depth=2)
    assert next(it2) == 0
    it2.close()


def test_host_sharded_batches():
    cfg = get_config("yi-6b", smoke=True)
    a = SyntheticDataset(cfg, SMOKE_SHAPE, host_id=0, num_hosts=2).batch(0)
    b = SyntheticDataset(cfg, SMOKE_SHAPE, host_id=1, num_hosts=2).batch(0)
    assert a["tokens"].shape[0] == SMOKE_SHAPE.global_batch // 2
    assert not np.array_equal(a["tokens"], b["tokens"])
