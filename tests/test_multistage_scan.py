"""Trace-native path: multistage_scan must match lax.scan in values and
grads — including uneven tails, prime lengths, and arbitrary SegmentPlans —
and must actually offload (device_put to host in the grad jaxpr)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.core import offload as ofl
from repro.core import schedule as ms
from repro.core.multistage_scan import (bptt_grad, choose_interval,
                                        multistage_scan)

requires_host_offload = pytest.mark.skipif(
    not ofl.host_offload_supported(),
    reason="backend does not lower host-offload remat policies (needs TPU)")

W = jax.random.normal(jax.random.PRNGKey(0), (16, 16)) * 0.3
C0 = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
XS = jax.random.normal(jax.random.PRNGKey(2), (24, 4, 16)) * 0.1


def body(c, x):
    c = jnp.tanh(c @ W + x)
    return c, jnp.sum(c ** 2)


def loss_ref(c0):
    _, ys = lax.scan(body, c0, XS)
    return jnp.sum(ys)


@pytest.mark.parametrize("kw", [
    dict(interval=8), dict(interval=8, offload=False), dict(interval=24),
    dict(interval=12, nested_intervals=(4,)),
    dict(interval=24, nested_intervals=(6, 2)), dict(interval=1),
    # non-dividing intervals: the plan ends in a shorter tail segment
    dict(interval=7), dict(interval=7, s_l1=2), dict(interval=13),
    # plan-driven: the SegmentPlan IR supplies boundaries + inner chunking
    dict(plan=ms.segment_plan(24, 8, 4)),
    dict(plan=ms.segment_plan(24, 7, 2)),
    dict(plan=ms.segment_plan(24, 5, 3)),
])
def test_matches_lax_scan(kw):
    ref_v, ref_g = jax.value_and_grad(loss_ref)(C0)

    def loss_ms(c0):
        _, ys = multistage_scan(body, c0, XS, **kw)
        return jnp.sum(ys)

    v, g = jax.jit(jax.value_and_grad(loss_ms))(C0)
    np.testing.assert_allclose(float(v), float(ref_v), rtol=1e-5)
    np.testing.assert_allclose(np.array(g), np.array(ref_g),
                               rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("n", [17, 23, 19])   # prime lengths
@pytest.mark.parametrize("interval", [4, 8])
def test_prime_length_matches_lax_scan(n, interval):
    """Regression for the old divisor-snapping fallback: a prime-length
    chain used to be rejected (or degraded to I=1 via choose_interval);
    now it runs at the requested interval with an uneven tail."""
    xs = XS[:n]

    def ref(c0):
        _, ys = lax.scan(body, c0, xs)
        return jnp.sum(ys)

    def loss_ms(c0):
        _, ys = multistage_scan(body, c0, xs, interval=interval, s_l1=2)
        return jnp.sum(ys)

    ref_v, ref_g = jax.value_and_grad(ref)(C0)
    v, g = jax.jit(jax.value_and_grad(loss_ms))(C0)
    np.testing.assert_allclose(float(v), float(ref_v), rtol=1e-5)
    np.testing.assert_allclose(np.array(g), np.array(ref_g),
                               rtol=1e-4, atol=1e-6)


def test_plan_mismatch_rejected():
    with pytest.raises(ValueError, match="plan is for"):
        multistage_scan(body, C0, XS, plan=ms.segment_plan(23, 8, 4))


def test_choose_interval():
    assert choose_interval(24, 7) == 6      # nearby divisor wins
    assert choose_interval(24, 100) == 24
    # prime length: keep the target (regression — the old fallback
    # silently degraded to I=1, the worst-case recompute factor)
    assert choose_interval(17, 4) == 4
    assert choose_interval(17, 16) == 16
    assert choose_interval(97, 10) == 10
    # the divisor search never shrinks below half the optimum
    for n in (24, 37, 48, 97):
        for t in range(1, n + 1):
            i = choose_interval(n, t)
            assert max(1, -(-t // 2)) <= i <= min(t, n), (n, t, i)


@requires_host_offload
def test_offload_emits_host_device_put():
    """The boundary carries must be placed on the host in the grad jaxpr —
    this is the paper's Level-2 store, compiled."""

    def loss_ms(c0):
        _, ys = multistage_scan(body, c0, XS, interval=8)
        return jnp.sum(ys)

    jaxpr = str(jax.make_jaxpr(jax.grad(loss_ms))(C0))
    assert "<host>" in jaxpr, "no host placement found in grad jaxpr"
    assert "ms_boundary" in jaxpr


def test_no_offload_keeps_device():
    def loss_ms(c0):
        _, ys = multistage_scan(body, c0, XS, interval=8, offload=False)
        return jnp.sum(ys)

    jaxpr = str(jax.make_jaxpr(jax.grad(loss_ms))(C0))
    assert "<host>" not in jaxpr


def test_bptt_grad_params():
    params = {"W": W}

    def step_loss(p, c, x):
        c = jnp.tanh(c @ p["W"] + x)
        return c, jnp.sum(c ** 2)

    def ref(p):
        def b(c, x):
            return step_loss(p, c, x)
        _, ys = lax.scan(b, C0, XS)
        return jnp.sum(ys)

    v, g = bptt_grad(step_loss, params, C0, XS, interval=8)
    rv_, rg = jax.value_and_grad(ref)(params)
    np.testing.assert_allclose(float(v), float(rv_), rtol=1e-5)
    np.testing.assert_allclose(np.array(g["W"]), np.array(rg["W"]),
                               rtol=1e-4, atol=1e-6)


@requires_host_offload
def test_memory_scales_with_interval_not_length():
    """Compiled analogue of the paper's Fig 4: the live boundary set is
    n/I states; remat keeps the rest transient.  We check the jaxpr-level
    residual count (number of host boundary tensors) == n/I."""
    def count_host_puts(n, interval):
        xs = jnp.zeros((n, 4, 16))

        def loss_ms(c0):
            _, ys = multistage_scan(body, c0, xs, interval=interval)
            return jnp.sum(ys)

        jaxpr = str(jax.make_jaxpr(jax.grad(loss_ms))(C0))
        return jaxpr.count("<host>")

    # the stacked Level-2 residual's leading dim must be exactly n/I
    import re

    def host_stack_dims(n, interval):
        xs = jnp.zeros((n, 4, 16))

        def loss_ms(c0):
            _, ys = multistage_scan(body, c0, xs, interval=interval)
            return jnp.sum(ys)

        s = str(jax.make_jaxpr(jax.grad(loss_ms))(C0))
        return sorted({int(m.split("[")[1].split(",")[0])
                       for m in re.findall(r"f32<host>\[[0-9]+,[0-9,]*\]", s)
                       if m.count(",") == 2})

    assert 6 in host_stack_dims(48, 8)    # 48/8 boundaries on the host
    assert 4 in host_stack_dims(48, 12)   # 48/12
    assert count_host_puts(48, 8) > 0
