"""The differentiable front-end: ``value_and_grad_offloaded`` must be a
drop-in ``jax.value_and_grad`` — same values, same gradients (fp32
tolerance) — on every chain-structured model family, with executor stats
showing the paper's memory behaviour (peak Level-1 states O(interval+slots),
independent of sequence length)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.api.autotune import AutoTuner, snap_interval, default_slots
from repro.configs import SMOKE_SHAPE, get_config
from repro.configs.shapes import make_batch
from repro.models import get_model

KEY = jax.random.PRNGKey(0)


from _helpers import max_rel_err as _max_err  # noqa: E402


# ---------------------------------------------------------------------------
# checkpointed_bptt on a synthetic chain
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def rnn_chain():
    T, B, D = 37, 4, 8
    params = {"W": jax.random.normal(KEY, (D, D)) * 0.4,
              "U": jax.random.normal(jax.random.fold_in(KEY, 1), (D, D)) * 0.2}
    xs = jax.random.normal(jax.random.fold_in(KEY, 2), (T, B, D)) * 0.1
    c0 = jnp.zeros((B, D))

    def body(p, c, x):
        c = jnp.tanh(c @ p["W"] + x @ p["U"])
        return c, jnp.sum(c ** 2)

    def ref_loss(p):
        _, ls = jax.lax.scan(lambda c, x: body(p, c, x), c0, xs)
        return jnp.sum(ls)

    ref_v, ref_g = jax.value_and_grad(ref_loss)(params)
    return params, c0, xs, body, float(ref_v), ref_g


@pytest.mark.parametrize("strategy,opts", [
    ("conventional", {}),
    ("revolve", dict(slots=6)),
    ("multistage_async", dict(interval=8, slots=6)),
    ("multistage_async", dict(interval=8, slots=6, storage="disk")),
])
def test_checkpointed_bptt_matches_autodiff(rnn_chain, strategy, opts):
    params, c0, xs, body, ref_v, ref_g = rnn_chain
    bptt = api.checkpointed_bptt(body, strategy=strategy, **opts)
    v, g = bptt(params, c0, xs)
    assert abs(float(v) - ref_v) < 1e-5
    assert _max_err(g, ref_g) < 1e-5


def test_checkpointed_bptt_under_jit(rnn_chain):
    params, c0, xs, body, ref_v, ref_g = rnn_chain
    bptt = api.checkpointed_bptt(body, strategy="multistage_async",
                                 interval=8, slots=6)
    v, g = jax.jit(bptt)(params, c0, xs)
    assert abs(float(v) - ref_v) < 1e-5
    assert _max_err(g, ref_g) < 1e-5


def test_peak_l1_constant_in_sequence_length():
    """The paper's headline memory claim through the public API: peak
    Level-1 states stay bounded by slots + O(1) while the chain grows 8x."""
    B, D = 2, 8
    params = {"W": jax.random.normal(KEY, (D, D)) * 0.4}

    def body(p, c, x):
        c = jnp.tanh(c @ p["W"] + x)
        return c, jnp.sum(c ** 2)

    peaks, stores = {}, {}
    for T in (32, 256):
        xs = jax.random.normal(jax.random.fold_in(KEY, T), (T, B, D)) * 0.1
        bptt = api.checkpointed_bptt(body, strategy="multistage_async",
                                     interval=16, slots=4)
        bptt(params, jnp.zeros((B, D)), xs)
        st = api.last_stats()
        peaks[T] = st.peak_l1_states
        stores[T] = st.l2_stores
    # Level-1: bounded by slots + O(1), independent of T
    assert peaks[32] <= 4 + 2
    assert peaks[256] <= 4 + 2
    assert peaks[256] <= peaks[32] + 1
    # Level-2 stores grow with T instead (n / interval boundary states)
    assert stores[32] == 2 and stores[256] == 16


def test_recompute_factor_constant_in_length():
    B, D = 2, 8
    params = {"W": jax.random.normal(KEY, (D, D)) * 0.4}

    def body(p, c, x):
        c = jnp.tanh(c @ p["W"] + x)
        return c, jnp.sum(c ** 2)

    factors = []
    for T in (64, 512):
        xs = jnp.zeros((T, B, D))
        bptt = api.checkpointed_bptt(body, strategy="multistage_async",
                                     interval=16, slots=4)
        bptt(params, jnp.zeros((B, D)), xs)
        factors.append(api.last_stats().recompute_factor)
    assert abs(factors[1] - factors[0]) < 0.05


# ---------------------------------------------------------------------------
# model families: gradients must match jax.value_and_grad
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("arch,tol", [
    ("lstm-paper", 1e-5),      # fp32 time chain (the paper's §5 model)
    ("granite-3-2b", 2e-2),    # bf16 dense transformer, depth chain
    ("mamba2-370m", 2e-2),     # bf16 SSM, depth chain
])
def test_model_chain_matches_value_and_grad(arch, tol):
    cfg = get_config(arch, smoke=True)
    m = get_model(cfg)
    assert m.train_chain is not None
    params = m.init(jax.random.fold_in(KEY, 7))
    batch = make_batch(cfg, SMOKE_SHAPE)
    ref_v, ref_g = jax.value_and_grad(m.train_loss)(params, batch)
    vg = api.value_and_grad_offloaded(m.train_loss, interval=2, slots=2)
    v, g = vg(params, batch)
    assert abs(float(v) - float(ref_v)) <= tol
    assert _max_err(g, ref_g) <= tol
    assert jax.tree_util.tree_structure(g) == \
        jax.tree_util.tree_structure(ref_g)


@pytest.mark.slow
def test_moe_chain_matches_value_and_grad():
    cfg = get_config("phi3.5-moe-42b", smoke=True)
    m = get_model(cfg)
    params = m.init(jax.random.fold_in(KEY, 8))
    batch = make_batch(cfg, SMOKE_SHAPE)
    ref_v, ref_g = jax.value_and_grad(m.train_loss)(params, batch)
    vg = api.value_and_grad_offloaded(m.train_loss, interval=1)
    v, g = vg(params, batch)
    assert abs(float(v) - float(ref_v)) <= 2e-2
    assert _max_err(g, ref_g) <= 2e-2


def test_chain_loss_value_only_path():
    """Calling the offloaded loss without differentiation uses the plain
    scan primal — value equals the reference loss."""
    cfg = get_config("lstm-paper", smoke=True)
    m = get_model(cfg)
    params = m.init(KEY)
    batch = make_batch(cfg, SMOKE_SHAPE)
    loss = api.offloaded_loss(m.train_chain, api.OffloadConfig())
    np.testing.assert_allclose(float(loss(params, batch)),
                               float(m.train_loss(params, batch)), rtol=1e-6)


def test_fallback_without_chain_spec():
    def plain_loss(params, batch):
        return jnp.sum(params["w"] ** 2) * batch

    with pytest.warns(UserWarning, match="no chain decomposition"):
        vg = api.value_and_grad_offloaded(plain_loss)
    v, g = vg({"w": jnp.arange(3.0)}, 2.0)
    np.testing.assert_allclose(np.array(g["w"]), np.array([0., 4., 8.]))
    with pytest.raises(TypeError):
        api.value_and_grad_offloaded(plain_loss, fallback=False)


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------


def test_snap_interval():
    assert snap_interval(48, 8) == 8       # exact divisor
    assert snap_interval(48, 7) == 8       # nearby divisor wins, upward
    assert snap_interval(48, 5) == 6       # never below the optimum:
    #                                        I = ceil(T_T/T_A) is the
    #                                        minimum no-stall interval
    assert snap_interval(37, 8) == 8       # prime length: keep the optimum
    assert snap_interval(48, 1000) == 48   # capped at n
    assert snap_interval(48, 0) == 1
    # the no-stall invariant: the snap never shrinks the interval
    for n in (24, 37, 48, 97):
        for t in range(1, n + 1):
            assert t <= snap_interval(n, t) <= min(2 * t, n), (n, t)


def test_default_slots():
    assert default_slots(4, 16) == 4       # interval <= budget: store-all
    assert default_slots(64, 16) == 16


def test_autotuner_measures_and_caches():
    from repro.core.storage import RAMStorage

    tuner = AutoTuner(repeats=1)
    state0 = jnp.zeros((4, 16))

    calls = []

    def forward_step(state, k):
        calls.append(k)
        return state

    backend = RAMStorage()
    r1 = tuner.measure("m", forward_step=forward_step, state0=state0,
                       n=64, backend=backend)
    assert r1.source == "measured"
    assert 1 <= r1.interval <= 64
    assert r1.slots >= 1
    n_calls = len(calls)
    r2 = tuner.measure("m", forward_step=forward_step, state0=state0,
                       n=64, backend=backend)
    assert r2 is r1               # cached: no re-measurement
    assert len(calls) == n_calls
    assert not list(backend.keys())  # probe state cleaned up


def test_autotune_end_to_end_first_call():
    """interval=None: first call measures T_A/T_T and records the choice."""
    B, D = 2, 8
    params = {"W": jax.random.normal(KEY, (D, D)) * 0.4}

    def body(p, c, x):
        c = jnp.tanh(c @ p["W"] + x)
        return c, jnp.sum(c ** 2)

    xs = jnp.zeros((48, B, D))
    tuner = AutoTuner(repeats=1)
    bptt = api.checkpointed_bptt(body, strategy="multistage_async",
                                 tuner=tuner)
    bptt(params, jnp.zeros((B, D)), xs)
    tune = api.last_tune()
    assert tune.source == "measured"
    assert tune.t_a > 0 and tune.t_t > 0
    assert 1 <= tune.interval <= 48
    assert tune.never_stalls or tune.interval == 48


def test_roofline_tuning_path():
    from repro.core.perfmodel import TPU_V5E

    tuner = AutoTuner()
    r = tuner.from_roofline("roof", n=4096, step_flops=1e12,
                            step_hbm_bytes=1e9, state_bytes=64e6, hw=TPU_V5E)
    assert r.source == "roofline"
    # I = ceil(T_T/T_A) with T_A = max(flops, bytes) roofline terms
    t_a = max(1e12 / TPU_V5E.peak_flops, 1e9 / TPU_V5E.hbm_bw)
    t_t = 64e6 / TPU_V5E.d2h_bw
    assert r.interval >= 1
    assert r.interval * t_a >= t_t * 0.5  # never badly transfer-bound


# ---------------------------------------------------------------------------
# train-step integration
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_train_step_with_strategy():
    from repro.optim import rmsprop
    from repro.train import init_train_state, make_train_step

    cfg = get_config("lstm-paper", smoke=True)
    m = get_model(cfg)
    opt = rmsprop(5e-3)
    state = init_train_state(m, opt, KEY)
    step = make_train_step(m, opt, strategy="multistage_async",
                           offload_opts=dict(interval=8, slots=4))
    batch = make_batch(cfg, SMOKE_SHAPE)
    losses = []
    for _ in range(6):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]
    assert api.last_stats().peak_l1_states <= 8


def test_train_step_strategy_rejects_unchained_family():
    from repro.optim import sgd
    from repro.train import make_train_step

    cfg = get_config("whisper-tiny", smoke=True)
    m = get_model(cfg)
    assert m.train_chain is None
    with pytest.raises(ValueError, match="no chain decomposition"):
        make_train_step(m, sgd(1e-3), strategy="multistage_async")


def test_unknown_strategy_rejected():
    with pytest.raises(ValueError, match="unknown strategy"):
        api.OffloadConfig(strategy="nope")
