"""The plan -> compile -> execute engine.

Covers: SegmentPlan IR consistency, gradient parity of compiled
``reverse_segment`` against ``jax.value_and_grad`` (synthetic RNN plus the
LSTM/transformer/SSM model chains), uneven tail segments, compile-once
retrace accounting, host-dispatch reduction, and executor exception paths
(no leaked writer threads, Level-2 keys freed)."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import schedule as ms
from repro.core.compiled_ops import (CompiledChainOps, CompiledSegmentRunner,
                                     chunk_length)
from repro.core.executor import CheckpointExecutor
from repro.core.storage import AsyncTransferEngine, RAMStorage

from _helpers import max_rel_err as _max_err  # noqa: E402

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# SegmentPlan IR
# ---------------------------------------------------------------------------


def test_segment_plan_shape():
    plan = ms.segment_plan(37, 8, 4)
    assert plan.num_segments == 5
    assert plan.boundaries() == [0, 8, 16, 24, 32]
    assert plan.segments[-1].length == 5  # uneven tail is first-class
    assert plan.segment_lengths() == (8, 5)
    # intra-segment Revolve sub-plans exactly where the segment overflows L1
    assert all(seg.revolve is not None for seg in plan.segments[:-1])
    assert plan.segments[-1].revolve is not None  # 5 > 4 slots
    assert ms.segment_plan(37, 8, 8).segments[0].revolve is None


def test_segment_plan_matches_action_stream():
    """The legacy MAction stream is derived from the plan — counts agree."""
    for n, i, s in [(29, 8, 3), (64, 16, 4), (37, 8, 8), (5, 8, 2)]:
        plan = ms.segment_plan(n, i, s)
        sched = ms.multistage_schedule(n, i, s)
        assert sched.l2_stores() == plan.num_segments
        assert sched.total_advances() == plan.total_advances()


def test_chunk_length():
    assert chunk_length(8, 8) is None          # fits: store-all
    assert chunk_length(16, 4) == 4            # 4 chunks of 4
    assert chunk_length(24, 5) == 5            # 4 full chunks + remainder 4
    assert chunk_length(7, 2) == 4             # uneven: 4 + 3, 2 boundaries
    assert chunk_length(1024, 1) is None       # 1 slot: chunking can't help
    # budget invariant: number of chunks never exceeds s_l1
    for seg_len in (7, 13, 24, 37, 64):
        for s in (2, 3, 5, 8):
            if seg_len > s:
                c = chunk_length(seg_len, s)
                assert -(-seg_len // c) <= s, (seg_len, s, c)


# ---------------------------------------------------------------------------
# compiled ops through the executor (core level, no front-end)
# ---------------------------------------------------------------------------


T, B, D = 37, 4, 8


@pytest.fixture(scope="module")
def chain():
    params = {"W": jax.random.normal(KEY, (D, D)) * 0.4,
              "U": jax.random.normal(jax.random.fold_in(KEY, 1), (D, D)) * 0.2}
    xs = jax.random.normal(jax.random.fold_in(KEY, 2), (T, B, D)) * 0.1
    c0 = jnp.zeros((B, D))

    def body(p, c, x, batch):
        return jnp.tanh(c @ p["W"] + x @ p["U"])

    def ref_loss(p, c0_, xs_):
        def step(c, x):
            return body(p, c, x, None), None

        c, _ = jax.lax.scan(step, c0_, xs_)
        return jnp.sum(c ** 2)

    ref_g, ref_dc0, ref_dxs = jax.grad(ref_loss, argnums=(0, 1, 2))(
        params, c0, xs)
    dcarry_seed = jax.grad(lambda c: jnp.sum(c ** 2))(
        jax.lax.scan(lambda c, x: (body(params, c, x, None), None),
                     c0, xs)[0])
    return params, c0, xs, body, (ref_g, ref_dc0, ref_dxs), dcarry_seed


def _make_runner_and_ex(body, params, xs, s_l1):
    treedef, mask = jax.tree_util.tree_flatten(xs)[1], (True,)
    cops = CompiledChainOps(body, treedef, mask)
    runner = CompiledSegmentRunner(cops, params, xs, None, s_l1=s_l1)
    return cops, runner, CheckpointExecutor()


@pytest.mark.parametrize("interval,s_l1", [
    (8, 8),    # store-all segments, uneven tail (37 = 4x8 + 5)
    (16, 4),   # chunked checkpointed recomputation inside segments
    (37, 8),   # single segment
])
def test_compiled_reverse_matches_autodiff(chain, interval, s_l1):
    params, c0, xs, body, (ref_g, ref_dc0, ref_dxs), dseed = chain
    cops, runner, ex = _make_runner_and_ex(body, params, xs, s_l1)
    zero_g = jax.tree_util.tree_map(jnp.zeros_like, params)
    (dc0, gacc), st = ex.run_multistage(
        c0, T, (dseed, zero_g), interval=interval, s_l1=s_l1, runner=runner)
    assert _max_err(gacc, ref_g) < 1e-5
    assert _max_err(dc0, ref_dc0) < 1e-5
    dxs = runner.collect_dx(ms.segment_plan(T, interval, s_l1))
    assert len(dxs) == 1 and dxs[0].shape == xs.shape
    assert _max_err(dxs[0], ref_dxs) < 1e-5
    # one host dispatch per segment per sweep, not per step
    num_segments = -(-T // interval)
    assert st.host_dispatches == 2 * num_segments
    assert st.l2_stores == num_segments


def test_compile_once_per_segment_length(chain):
    """Uneven tails cost exactly one extra trace; repeated runs and other
    chain lengths with the same segment shapes cost none."""
    params, c0, xs, body, _, dseed = chain
    cops, runner, ex = _make_runner_and_ex(body, params, xs, s_l1=8)
    zero_g = jax.tree_util.tree_map(jnp.zeros_like, params)
    ex.run_multistage(c0, T, (dseed, zero_g), interval=8, s_l1=8,
                      runner=runner)
    # 37 = 8+8+8+8+5: two distinct segment lengths -> exactly two traces each
    assert cops.advance_traces == 2
    assert cops.reverse_traces == 2

    # same plan again: fully cached, zero retraces
    runner2 = CompiledSegmentRunner(cops, params, xs, None, s_l1=8)
    ex.run_multistage(c0, T, (dseed, zero_g), interval=8, s_l1=8,
                      runner=runner2)
    assert cops.advance_traces == 2
    assert cops.reverse_traces == 2

    # different chain length, same segment lengths (53 = 6x8 + 5): cached
    T2 = 53
    xs2 = jax.random.normal(jax.random.fold_in(KEY, 9), (T2, B, D)) * 0.1
    runner3 = CompiledSegmentRunner(cops, params, xs2, None, s_l1=8)
    ex.run_multistage(c0, T2, (dseed, zero_g), interval=8, s_l1=8,
                      runner=runner3)
    assert cops.advance_traces == 2
    assert cops.reverse_traces == 2

    # a genuinely new tail length (21 = 2x8 + 5? no: 16+5 -> cached; use 12)
    T3 = 12  # 8 + 4: tail length 4 is new
    xs3 = jax.random.normal(jax.random.fold_in(KEY, 10), (T3, B, D)) * 0.1
    runner4 = CompiledSegmentRunner(cops, params, xs3, None, s_l1=8)
    ex.run_multistage(c0, T3, (dseed, zero_g), interval=8, s_l1=8,
                      runner=runner4)
    assert cops.advance_traces == 3
    assert cops.reverse_traces == 3


# ---------------------------------------------------------------------------
# parity through the public front-end, both engines
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def rnn_ref():
    params = {"W": jax.random.normal(KEY, (D, D)) * 0.4}
    xs = jax.random.normal(jax.random.fold_in(KEY, 3), (41, B, D)) * 0.1
    c0 = jnp.zeros((B, D))

    def body(p, c, x):
        c = jnp.tanh(c @ p["W"] + x)
        return c, jnp.sum(c ** 2)

    def ref_loss(p):
        _, ls = jax.lax.scan(lambda c, x: body(p, c, x), c0, xs)
        return jnp.sum(ls)

    ref_v, ref_g = jax.value_and_grad(ref_loss)(params)
    return params, c0, xs, body, float(ref_v), ref_g


@pytest.mark.parametrize("engine", ["compiled", "interpreted", "scan"])
@pytest.mark.parametrize("interval", [8, 16, 41])
def test_frontend_engines_match_autodiff(rnn_ref, engine, interval):
    params, c0, xs, body, ref_v, ref_g = rnn_ref
    bptt = api.checkpointed_bptt(body, strategy="multistage_async",
                                 interval=interval, slots=4, engine=engine)
    v, g = bptt(params, c0, xs)
    assert abs(float(v) - ref_v) < 1e-4
    assert _max_err(g, ref_g) < 1e-5
    num_segments = -(-41 // interval)
    assert api.last_plan().num_segments == num_segments
    st = api.last_stats()
    if engine == "scan":
        assert st is None          # the schedule ran inside XLA
    elif engine == "compiled":
        assert st.host_dispatches == 2 * num_segments
    else:
        assert st.host_dispatches >= 2 * 41


def test_unknown_engine_rejected():
    with pytest.raises(ValueError, match="unknown engine"):
        api.OffloadConfig(engine="nope")


@pytest.mark.slow
@pytest.mark.parametrize("engine", ["compiled", "scan"])
@pytest.mark.parametrize("arch,tol", [
    ("lstm-paper", 1e-5),      # fp32 time chain (the paper's §5 model)
    ("granite-3-2b", 2e-2),    # bf16 dense transformer, depth chain
    ("mamba2-370m", 2e-2),     # bf16 SSM, depth chain
])
def test_model_chain_xla_engines(arch, tol, engine):
    from repro.configs import SMOKE_SHAPE, get_config
    from repro.configs.shapes import make_batch
    from repro.models import get_model

    cfg = get_config(arch, smoke=True)
    m = get_model(cfg)
    params = m.init(jax.random.fold_in(KEY, 7))
    batch = make_batch(cfg, SMOKE_SHAPE)
    ref_v, ref_g = jax.value_and_grad(m.train_loss)(params, batch)
    vg = api.value_and_grad_offloaded(m.train_loss, interval=2, slots=2,
                                      engine=engine)
    v, g = vg(params, batch)
    assert abs(float(v) - float(ref_v)) <= tol
    assert _max_err(g, ref_g) <= tol
    assert jax.tree_util.tree_structure(g) == \
        jax.tree_util.tree_structure(ref_g)


# ---------------------------------------------------------------------------
# exception paths: no leaked writer threads, Level-2 keys freed
# ---------------------------------------------------------------------------


class Boom(RuntimeError):
    pass


def _wait_threads_settle(n0, timeout=5.0):
    deadline = time.monotonic() + timeout
    while threading.active_count() > n0 and time.monotonic() < deadline:
        time.sleep(0.01)
    return threading.active_count()


def test_forward_failure_leaks_nothing():
    def fwd(state, k):
        if k == 9:
            raise Boom("forward died")
        return state + 1.0

    n0 = threading.active_count()
    ex = CheckpointExecutor(fwd, lambda s, a, k: a)
    with pytest.raises(Boom):
        ex.run_multistage(jnp.zeros(4), 20, jnp.zeros(4), interval=4, s_l1=2)
    assert _wait_threads_settle(n0) <= n0  # writer thread joined


def test_backward_failure_frees_l2_keys():
    calls = []

    def fwd(state, k):
        return state + 1.0

    def bwd(state, adj, k):
        calls.append(k)
        if k == 13:
            raise Boom("backward died")
        return adj

    backend = RAMStorage()
    with AsyncTransferEngine(backend) as eng:
        ex = CheckpointExecutor(fwd, bwd)
        with pytest.raises(Boom):
            ex.run_multistage(jnp.zeros(4), 20, jnp.zeros(4),
                              interval=4, s_l1=4, engine=eng)
        # MultistageRun.close purged every boundary this run created
        assert not list(backend.keys())


def test_frontend_run_leaves_no_threads():
    """A full forward+backward through the front-end disposes its run:
    the engine's writer thread must be joined, not leaked."""
    n0 = threading.active_count()
    bptt = api.checkpointed_bptt(
        lambda p, c, x: (jnp.tanh(c @ p + x), jnp.sum(c)),
        strategy="multistage_async", interval=4, slots=2)
    params = jax.random.normal(KEY, (D, D)) * 0.3
    v, g = bptt(params, jnp.zeros((B, D)), jnp.zeros((12, B, D)))
    jax.block_until_ready(g)
    assert _wait_threads_settle(n0) <= n0
