"""Level-2 backend registry, the compressed backend, and the
AsyncTransferEngine error/shutdown hardening."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core.storage import (AsyncTransferEngine, CompressedStorage,
                                DiskStorage, RAMStorage, make_backend,
                                register_backend, tree_bytes)
from repro.distributed.compression import quantization_error_bound

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_make_backend_kinds():
    assert isinstance(make_backend("ram"), RAMStorage)
    assert make_backend("ram", bandwidth=1e6).bandwidth == 1e6
    with tempfile.TemporaryDirectory() as d:
        disk = make_backend("disk", directory=d)
        assert isinstance(disk, DiskStorage)
    comp = make_backend("compressed")
    assert isinstance(comp, CompressedStorage)
    assert isinstance(comp.inner, RAMStorage)
    with tempfile.TemporaryDirectory() as d:
        comp_disk = make_backend("compressed", directory=d)
        assert isinstance(comp_disk.inner, DiskStorage)


def test_make_backend_unknown():
    with pytest.raises(ValueError, match="unknown Level-2 backend"):
        make_backend("tape")


def test_register_backend_custom():
    register_backend("null-test", lambda: RAMStorage())
    assert isinstance(make_backend("null-test"), RAMStorage)


def test_registered_backend_reachable_from_frontend():
    """A backend added via register_backend works straight through
    value_and_grad_offloaded(storage=...) — the front-end delegates
    validation to the registry instead of a hardcoded list."""
    instances = []

    def factory():
        b = RAMStorage()
        instances.append(b)
        return b

    register_backend("tracking-ram", factory)
    T, B, D = 16, 2, 4
    params = {"W": jax.random.normal(KEY, (D, D)) * 0.3}
    xs = jax.random.normal(jax.random.fold_in(KEY, 1), (T, B, D)) * 0.1

    def body(p, c, x):
        c = jnp.tanh(c @ p["W"] + x)
        return c, jnp.sum(c ** 2)

    bptt = api.checkpointed_bptt(body, strategy="multistage_async",
                                 interval=4, slots=2, storage="tracking-ram")
    v, g = bptt(params, jnp.zeros((B, D)), xs)
    jax.block_until_ready(g)
    assert instances and instances[-1].bytes_written > 0


# ---------------------------------------------------------------------------
# compressed backend
# ---------------------------------------------------------------------------


def test_compressed_roundtrip_error_bound():
    tree = {
        "big_f32": np.asarray(jax.random.normal(KEY, (64, 64))),
        "small_f32": np.ones(3, np.float32),          # below min_bytes: raw
        "ints": np.arange(512, dtype=np.int32),       # never quantised
        "nested": (np.asarray(jax.random.normal(KEY, (32, 32))) * 7.0,),
    }
    store = CompressedStorage(min_bytes=256)
    store.put("k", tree)
    got = store.get("k")
    # structure and dtypes are restored exactly
    assert jax.tree_util.tree_structure(got) == \
        jax.tree_util.tree_structure(tree)
    np.testing.assert_array_equal(got["ints"], tree["ints"])
    np.testing.assert_array_equal(got["small_f32"], tree["small_f32"])
    for name in ("big_f32",):
        bound = quantization_error_bound(tree[name])
        assert float(np.max(np.abs(got[name] - tree[name]))) <= bound
        assert got[name].dtype == tree[name].dtype
    inner = tree["nested"][0]
    assert float(np.max(np.abs(got["nested"][0] - inner))) <= \
        quantization_error_bound(inner)
    # wire accounting: int8 payloads shrink the float bulk ~4x
    assert store.bytes_written < store.raw_bytes * 0.5
    store.delete("k")
    assert "k" not in store


def test_compressed_through_engine():
    backend = CompressedStorage()
    tree = (np.asarray(jax.random.normal(KEY, (128,))) * 3.0,
            np.arange(8, dtype=np.int64))
    with AsyncTransferEngine(backend) as eng:
        eng.store_async(0, tree)
        eng.wait_stores()
        eng.prefetch_async(0)
        got = eng.wait_prefetch(0)
    assert float(np.max(np.abs(got[0] - tree[0]))) <= \
        quantization_error_bound(tree[0])
    np.testing.assert_array_equal(got[1], tree[1])


def test_compressed_storage_end_to_end_gradients():
    """Offloaded gradients with int8-quantised boundary states: replay
    starts from a bounded-error state, so gradients are close (not exact)
    to autodiff — while the loss value (pure forward) stays exact."""
    T, B, D = 32, 2, 8
    params = {"W": jax.random.normal(KEY, (D, D)) * 0.3}
    xs = jax.random.normal(jax.random.fold_in(KEY, 2), (T, B, D)) * 0.1
    c0 = jnp.zeros((B, D))

    def body(p, c, x):
        c = jnp.tanh(c @ p["W"] + x)
        return c, jnp.sum(c ** 2)

    def ref_loss(p):
        _, ls = jax.lax.scan(lambda c, x: body(p, c, x), c0, xs)
        return jnp.sum(ls)

    ref_v, ref_g = jax.value_and_grad(ref_loss)(params)
    bptt = api.checkpointed_bptt(body, strategy="multistage_async",
                                 interval=8, slots=4, storage="compressed")
    v, g = bptt(params, c0, xs)
    np.testing.assert_allclose(float(v), float(ref_v), rtol=1e-6)
    err = float(jnp.max(jnp.abs(g["W"] - ref_g["W"])))
    assert 0.0 < err < 5e-2  # bounded quantisation effect, not corruption


# ---------------------------------------------------------------------------
# engine error surfacing + shutdown robustness
# ---------------------------------------------------------------------------


class FailingBackend(RAMStorage):
    def __init__(self, fail_puts=True, fail_gets=False):
        super().__init__()
        self.fail_puts = fail_puts
        self.fail_gets = fail_gets

    def put(self, key, tree):
        if self.fail_puts:
            raise IOError(f"put({key}) failed")
        super().put(key, tree)

    def get(self, key):
        if self.fail_gets:
            raise IOError(f"get({key}) failed")
        return super().get(key)


def _tree():
    return {"a": np.ones((4, 4), np.float32)}


def test_store_error_surfaces_on_wait_stores():
    eng = AsyncTransferEngine(FailingBackend())
    eng.store_async(0, _tree())
    with pytest.raises(IOError, match="put"):
        eng.wait_stores()
    # error consumed: shutdown is then clean
    eng.close()


def test_store_error_surfaces_on_demand_fetch():
    """The demand-fetch fallback in wait_prefetch must surface pending
    writer errors instead of dying on a confusing KeyError."""
    eng = AsyncTransferEngine(FailingBackend())
    eng.store_async(0, _tree())
    eng._join_stores()  # let the writer consume the item and record the error
    with pytest.raises(IOError, match="put"):
        eng.wait_prefetch(0)   # never prefetched -> demand path
    eng.close()


def test_prefetch_error_surfaces_on_wait():
    backend = FailingBackend(fail_puts=False, fail_gets=True)
    eng = AsyncTransferEngine(backend)
    eng.store_async(0, _tree())
    eng.wait_stores()
    eng.prefetch_async(0)
    with pytest.raises(IOError, match="get"):
        eng.wait_prefetch(0)
    eng.close()


def test_close_survives_dead_writer():
    """close() must not deadlock on Queue.join() when the writer thread died
    with items still queued — it times out, raises, and leaves no thread."""
    eng = AsyncTransferEngine(RAMStorage())
    eng._stop.set()            # simulate writer death
    eng._writer.join(timeout=2.0)
    assert not eng._writer.is_alive()
    eng.store_async(0, _tree())   # lands in the queue, never drained
    with pytest.raises(RuntimeError, match="writer thread died"):
        eng.close()


def test_close_is_idempotent_after_error():
    eng = AsyncTransferEngine(FailingBackend())
    eng.store_async(0, _tree())
    with pytest.raises(IOError):
        eng.wait_stores()
    eng.close()
    eng.close()
