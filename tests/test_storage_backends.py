"""Level-2 backend registry, the compressed backend, the capacity-bounded
tiered backend, the storage-layer concurrency regressions, and the
AsyncTransferEngine error/shutdown hardening."""
import os
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import schedule as ms
from repro.core.storage import (AsyncTransferEngine, CompressedStorage,
                                DiskStorage, RAMStorage, TieredStorage,
                                make_backend, register_backend, tree_bytes)
from repro.distributed.compression import quantization_error_bound

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_make_backend_kinds():
    assert isinstance(make_backend("ram"), RAMStorage)
    assert make_backend("ram", bandwidth=1e6).bandwidth == 1e6
    with tempfile.TemporaryDirectory() as d:
        disk = make_backend("disk", directory=d)
        assert isinstance(disk, DiskStorage)
    comp = make_backend("compressed")
    assert isinstance(comp, CompressedStorage)
    assert isinstance(comp.inner, RAMStorage)
    with tempfile.TemporaryDirectory() as d:
        comp_disk = make_backend("compressed", directory=d)
        assert isinstance(comp_disk.inner, DiskStorage)


def test_make_backend_unknown():
    with pytest.raises(ValueError, match="unknown Level-2 backend"):
        make_backend("tape")


def test_register_backend_custom():
    register_backend("null-test", lambda: RAMStorage())
    assert isinstance(make_backend("null-test"), RAMStorage)


def test_registered_backend_reachable_from_frontend():
    """A backend added via register_backend works straight through
    value_and_grad_offloaded(storage=...) — the front-end delegates
    validation to the registry instead of a hardcoded list."""
    instances = []

    def factory():
        b = RAMStorage()
        instances.append(b)
        return b

    register_backend("tracking-ram", factory)
    T, B, D = 16, 2, 4
    params = {"W": jax.random.normal(KEY, (D, D)) * 0.3}
    xs = jax.random.normal(jax.random.fold_in(KEY, 1), (T, B, D)) * 0.1

    def body(p, c, x):
        c = jnp.tanh(c @ p["W"] + x)
        return c, jnp.sum(c ** 2)

    bptt = api.checkpointed_bptt(body, strategy="multistage_async",
                                 interval=4, slots=2, storage="tracking-ram")
    v, g = bptt(params, jnp.zeros((B, D)), xs)
    jax.block_until_ready(g)
    assert instances and instances[-1].bytes_written > 0


# ---------------------------------------------------------------------------
# compressed backend
# ---------------------------------------------------------------------------


def test_compressed_roundtrip_error_bound():
    tree = {
        "big_f32": np.asarray(jax.random.normal(KEY, (64, 64))),
        "small_f32": np.ones(3, np.float32),          # below min_bytes: raw
        "ints": np.arange(512, dtype=np.int32),       # never quantised
        "nested": (np.asarray(jax.random.normal(KEY, (32, 32))) * 7.0,),
    }
    store = CompressedStorage(min_bytes=256)
    store.put("k", tree)
    got = store.get("k")
    # structure and dtypes are restored exactly
    assert jax.tree_util.tree_structure(got) == \
        jax.tree_util.tree_structure(tree)
    np.testing.assert_array_equal(got["ints"], tree["ints"])
    np.testing.assert_array_equal(got["small_f32"], tree["small_f32"])
    for name in ("big_f32",):
        bound = quantization_error_bound(tree[name])
        assert float(np.max(np.abs(got[name] - tree[name]))) <= bound
        assert got[name].dtype == tree[name].dtype
    inner = tree["nested"][0]
    assert float(np.max(np.abs(got["nested"][0] - inner))) <= \
        quantization_error_bound(inner)
    # wire accounting: int8 payloads shrink the float bulk ~4x
    assert store.bytes_written < store.raw_bytes * 0.5
    store.delete("k")
    assert "k" not in store


def test_compressed_through_engine():
    backend = CompressedStorage()
    tree = (np.asarray(jax.random.normal(KEY, (128,))) * 3.0,
            np.arange(8, dtype=np.int64))
    with AsyncTransferEngine(backend) as eng:
        eng.store_async(0, tree)
        eng.wait_stores()
        eng.prefetch_async(0)
        got = eng.wait_prefetch(0)
    assert float(np.max(np.abs(got[0] - tree[0]))) <= \
        quantization_error_bound(tree[0])
    np.testing.assert_array_equal(got[1], tree[1])


def test_compressed_storage_end_to_end_gradients():
    """Offloaded gradients with int8-quantised boundary states: replay
    starts from a bounded-error state, so gradients are close (not exact)
    to autodiff — while the loss value (pure forward) stays exact."""
    T, B, D = 32, 2, 8
    params = {"W": jax.random.normal(KEY, (D, D)) * 0.3}
    xs = jax.random.normal(jax.random.fold_in(KEY, 2), (T, B, D)) * 0.1
    c0 = jnp.zeros((B, D))

    def body(p, c, x):
        c = jnp.tanh(c @ p["W"] + x)
        return c, jnp.sum(c ** 2)

    def ref_loss(p):
        _, ls = jax.lax.scan(lambda c, x: body(p, c, x), c0, xs)
        return jnp.sum(ls)

    ref_v, ref_g = jax.value_and_grad(ref_loss)(params)
    bptt = api.checkpointed_bptt(body, strategy="multistage_async",
                                 interval=8, slots=4, storage="compressed")
    v, g = bptt(params, c0, xs)
    np.testing.assert_allclose(float(v), float(ref_v), rtol=1e-6)
    err = float(jnp.max(jnp.abs(g["W"] - ref_g["W"])))
    assert 0.0 < err < 5e-2  # bounded quantisation effect, not corruption


# ---------------------------------------------------------------------------
# engine error surfacing + shutdown robustness
# ---------------------------------------------------------------------------


class FailingBackend(RAMStorage):
    def __init__(self, fail_puts=True, fail_gets=False):
        super().__init__()
        self.fail_puts = fail_puts
        self.fail_gets = fail_gets

    def put(self, key, tree):
        if self.fail_puts:
            raise IOError(f"put({key}) failed")
        super().put(key, tree)

    def get(self, key):
        if self.fail_gets:
            raise IOError(f"get({key}) failed")
        return super().get(key)


def _tree():
    return {"a": np.ones((4, 4), np.float32)}


def test_store_error_surfaces_on_wait_stores():
    eng = AsyncTransferEngine(FailingBackend())
    eng.store_async(0, _tree())
    with pytest.raises(IOError, match="put"):
        eng.wait_stores()
    # error consumed: shutdown is then clean
    eng.close()


def test_store_error_surfaces_on_demand_fetch():
    """The demand-fetch fallback in wait_prefetch must surface pending
    writer errors instead of dying on a confusing KeyError."""
    eng = AsyncTransferEngine(FailingBackend())
    eng.store_async(0, _tree())
    eng._join_stores()  # let the writer consume the item and record the error
    with pytest.raises(IOError, match="put"):
        eng.wait_prefetch(0)   # never prefetched -> demand path
    eng.close()


def test_prefetch_error_surfaces_on_wait():
    backend = FailingBackend(fail_puts=False, fail_gets=True)
    eng = AsyncTransferEngine(backend)
    eng.store_async(0, _tree())
    eng.wait_stores()
    eng.prefetch_async(0)
    with pytest.raises(IOError, match="get"):
        eng.wait_prefetch(0)
    eng.close()


def test_close_survives_dead_writer():
    """close() must not deadlock on Queue.join() when the writer thread died
    with items still queued — it times out, raises, and leaves no thread."""
    eng = AsyncTransferEngine(RAMStorage())
    eng._stop.set()            # simulate writer death
    eng._writer.join(timeout=2.0)
    assert not eng._writer.is_alive()
    eng.store_async(0, _tree())   # lands in the queue, never drained
    with pytest.raises(RuntimeError, match="writer thread died"):
        eng.close()


def test_close_is_idempotent_after_error():
    eng = AsyncTransferEngine(FailingBackend())
    eng.store_async(0, _tree())
    with pytest.raises(IOError):
        eng.wait_stores()
    eng.close()
    eng.close()


# ---------------------------------------------------------------------------
# concurrency regressions (threaded counters, stale prefetch, aliasing)
# ---------------------------------------------------------------------------


def test_compressed_raw_bytes_counter_threadsafe():
    """raw_bytes is mutated on the AsyncTransferEngine writer thread;
    unguarded `+=` loses increments under concurrent puts (regression:
    the counter was updated without the backend lock)."""
    store = CompressedStorage(min_bytes=1 << 30)  # raw passthrough: fast puts
    tree = {"a": np.ones((32,), np.float32)}
    nb = tree_bytes(tree)
    n_threads, n_puts = 8, 50

    def hammer(tid):
        for i in range(n_puts):
            store.put((tid, i), tree)

    threads = [threading.Thread(target=hammer, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert store.raw_bytes == n_threads * n_puts * nb


def test_engine_counters_threadsafe():
    """num_stores / num_prefetches are incremented on caller threads —
    they must be exact under concurrent store_async/prefetch_async."""
    eng = AsyncTransferEngine(RAMStorage())
    tree = {"a": np.ones((8,), np.float32)}
    n_threads, n_keys = 8, 40

    def stores(tid):
        for i in range(n_keys):
            eng.store_async((tid, i), tree)

    threads = [threading.Thread(target=stores, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    eng.wait_stores()
    assert eng.num_stores == n_threads * n_keys

    def prefetches(tid):
        for i in range(n_keys):
            eng.prefetch_async((tid, i))

    threads = [threading.Thread(target=prefetches, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # every key prefetched exactly once (dedup is under the lock too)
    assert eng.num_prefetches == n_threads * n_keys
    for tid in range(n_threads):
        for i in range(n_keys):
            np.testing.assert_array_equal(
                eng.wait_prefetch((tid, i))["a"], tree["a"])
    eng.close()


def test_delete_invalidates_staged_prefetch():
    """delete + re-store + prefetch must observe the NEW value (regression:
    prefetch_async returned early on the staged key, handing back the
    stale pre-delete state)."""
    eng = AsyncTransferEngine(RAMStorage())
    eng.store_async(0, {"a": np.full((4,), 1.0, np.float32)})
    eng.wait_stores()
    eng.prefetch_async(0)
    # let the prefetch land in staging before the delete
    deadline = time.monotonic() + 5.0
    while 0 not in eng._prefetched and time.monotonic() < deadline:
        time.sleep(0.01)
    assert 0 in eng._prefetched
    eng.delete(0)
    eng.store_async(0, {"a": np.full((4,), 2.0, np.float32)})
    eng.wait_stores()
    eng.prefetch_async(0)
    got = eng.wait_prefetch(0)
    np.testing.assert_array_equal(got["a"], np.full((4,), 2.0, np.float32))
    eng.close()


def test_delete_detaches_inflight_prefetch():
    """A prefetch still in flight when its key is deleted must not publish
    a stale value (or a spurious error) afterwards."""
    release = threading.Event()

    class SlowBackend(RAMStorage):
        def get(self, key):
            release.wait(5.0)
            return super().get(key)

    eng = AsyncTransferEngine(SlowBackend())
    eng.store_async(0, {"a": np.full((4,), 1.0, np.float32)})
    eng.wait_stores()
    eng.prefetch_async(0)          # blocked in SlowBackend.get
    eng.delete(0)                  # detaches the in-flight job
    eng.store_async(0, {"a": np.full((4,), 2.0, np.float32)})
    eng.wait_stores()
    release.set()                  # stale job completes -> must be discarded
    eng.prefetch_async(0)
    got = eng.wait_prefetch(0)
    np.testing.assert_array_equal(got["a"], np.full((4,), 2.0, np.float32))
    eng.close()


def test_close_drops_leaked_staged_prefetches():
    """Prefetches never waited on must not leak staging entries (or their
    events) past close()."""
    eng = AsyncTransferEngine(RAMStorage())
    for k in range(3):
        eng.store_async(k, {"a": np.ones((4,), np.float32)})
    eng.wait_stores()
    for k in range(3):
        eng.prefetch_async(k)
    deadline = time.monotonic() + 5.0
    while len(eng._prefetched) < 3 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert eng.staged_bytes > 0
    eng.close()
    assert eng._prefetched == {} and eng._prefetch_events == {}
    assert eng.staged_bytes == 0


def test_ram_get_mutation_cannot_corrupt_checkpoint():
    """RAMStorage.get returns the canonical copy: in-place mutation must
    raise (read-only views) instead of silently corrupting the state the
    next Revolve replay starts from (regression: get aliased a writable
    dict entry)."""
    store = RAMStorage()
    store.put("k", {"a": np.arange(6, dtype=np.float32)})
    got = store.get("k")
    with pytest.raises(ValueError):
        got["a"][0] = 99.0
    np.testing.assert_array_equal(
        store.get("k")["a"], np.arange(6, dtype=np.float32))


def test_staged_prefetch_bytes_accounted():
    eng = AsyncTransferEngine(RAMStorage())
    tree = {"a": np.ones((16,), np.float32)}
    nb = tree_bytes(tree)
    for k in range(2):
        eng.store_async(k, tree)
    eng.wait_stores()
    for k in range(2):
        eng.prefetch_async(k)
    deadline = time.monotonic() + 5.0
    while eng.staged_bytes < 2 * nb and time.monotonic() < deadline:
        time.sleep(0.01)
    assert eng.staged_bytes == 2 * nb
    assert eng.staged_peak_bytes == 2 * nb
    eng.wait_prefetch(0)
    eng.wait_prefetch(1)
    assert eng.staged_bytes == 0
    assert eng.staged_peak_bytes == 2 * nb
    eng.close()


# ---------------------------------------------------------------------------
# tiered backend
# ---------------------------------------------------------------------------


def _state(v, shape=(4, 4)):
    return {"a": np.full(shape, float(v), np.float32)}


_NB = tree_bytes(_state(0))


def test_tiered_capacity_respected():
    ts = TieredStorage(capacity_bytes=2 * _NB)
    for k in range(5):
        ts.put(k, _state(k))
    assert ts.fast_peak_bytes <= 2 * _NB
    assert ts.fast_live_bytes <= 2 * _NB
    assert ts.evictions == 3
    for k in range(5):
        np.testing.assert_array_equal(ts.get(k)["a"], _state(k)["a"])
        assert ts.fast_peak_bytes <= 2 * _NB  # promotions stay bounded too
    assert k in ts
    ts.delete(0)
    assert 0 not in ts


def test_tiered_eviction_order_plan_aware():
    """With the SegmentPlan registered, the eviction victim is always the
    boundary whose reverse-sweep use is farthest away (the smallest begin);
    the fast tier ends the forward sweep holding the boundaries needed
    first."""
    plan = ms.segment_plan(n=5, interval=1, s_l1=1)  # boundaries 0..4
    ts = TieredStorage(capacity_bytes=2 * _NB)
    ts.set_plan(plan)
    for k in range(5):
        ts.put(k, _state(k))
    assert sorted(ts._fast) == [3, 4]          # needed first in reverse
    for k in (0, 1, 2):                        # cold keys spilled to slow
        assert k in ts.slow
    assert ts.evictions == 3


def test_tiered_demand_promotion():
    plan = ms.segment_plan(n=4, interval=1, s_l1=1)
    ts = TieredStorage(capacity_bytes=2 * _NB)
    ts.set_plan(plan)
    for k in range(4):
        ts.put(k, _state(k))
    assert sorted(ts._fast) == [2, 3]
    # reverse-order consumption: hits are fast, spilled keys promote
    np.testing.assert_array_equal(ts.get(3)["a"], _state(3)["a"])
    ts.delete(3)
    np.testing.assert_array_equal(ts.get(2)["a"], _state(2)["a"])
    ts.delete(2)
    assert ts.promotions == 0 and ts.fast_hits == 2
    got = ts.get(1)                            # slow hit -> promotion
    np.testing.assert_array_equal(got["a"], _state(1)["a"])
    assert ts.promotions == 1 and ts.slow_hits == 1
    assert 1 in ts._fast
    assert ts.fast_peak_bytes <= 2 * _NB


def test_tiered_oversized_state_bypasses_fast_tier():
    ts = TieredStorage(capacity_bytes=_NB // 2)
    ts.put("big", _state(7))
    assert ts.fast_peak_bytes == 0
    np.testing.assert_array_equal(ts.get("big")["a"], _state(7)["a"])
    ts.delete("big")
    assert "big" not in ts


def test_tiered_get_mutation_cannot_corrupt_checkpoint():
    ts = TieredStorage(capacity_bytes=_NB)  # key 0 spills to slow
    ts.put(0, _state(1))
    ts.put(1, _state(2))
    for k in (0, 1):  # one served from slow, one from fast
        got = ts.get(k)
        with pytest.raises(ValueError):
            got["a"][0, 0] = 99.0
        np.testing.assert_array_equal(ts.get(k)["a"], _state(k + 1)["a"])


def test_tiered_delete_during_writeback_leaves_nothing():
    """delete() racing an in-flight write-behind eviction must remove the
    slow copy once the writeback lands."""
    gate = threading.Event()

    class GatedSlow(RAMStorage):
        def put(self, key, tree):
            gate.wait(5.0)
            super().put(key, tree)

    ts = TieredStorage(capacity_bytes=_NB, slow=GatedSlow())
    ts.put(0, _state(0))

    def put_evicting():
        ts.put(1, _state(1))   # evicts 0; blocks in GatedSlow.put

    t = threading.Thread(target=put_evicting)
    t.start()
    deadline = time.monotonic() + 5.0
    while 0 not in ts._writing and time.monotonic() < deadline:
        time.sleep(0.01)
    ts.delete(0)               # racing the writeback
    gate.set()
    t.join(timeout=5.0)
    assert 0 not in ts
    assert 0 not in ts.slow


def test_tiered_compressed_slow_tier():
    ts = TieredStorage(capacity_bytes=_NB, compress=True)
    big = {"x": np.asarray(jax.random.normal(KEY, (64, 64)))}
    ts.put(0, big)
    ts.put(1, big)             # evicts 0 through the int8 slow tier
    got = ts.get(0)
    bound = quantization_error_bound(big["x"])
    assert float(np.max(np.abs(got["x"] - big["x"]))) <= bound


def test_make_backend_tiered():
    ts = make_backend("tiered", capacity_bytes=1024)
    assert isinstance(ts, TieredStorage)
    assert isinstance(ts.slow, RAMStorage)
    with tempfile.TemporaryDirectory() as d:
        ts = make_backend("tiered", capacity_bytes=1024, directory=d)
        assert isinstance(ts.slow, DiskStorage)
    with pytest.raises(ValueError, match="capacity_bytes"):
        TieredStorage(capacity_bytes=0)


def test_tiered_storage_end_to_end_gradients():
    """Offloaded gradients with a fast tier sized for 2 of 4 boundary
    states: gradients stay exact (spilled replay is lossless), the fast
    tier obeys the budget, and the executor reports the tier traffic."""
    T, B, D = 32, 2, 8
    params = {"W": jax.random.normal(KEY, (D, D)) * 0.3}
    xs = jax.random.normal(jax.random.fold_in(KEY, 2), (T, B, D)) * 0.1
    c0 = jnp.zeros((B, D))

    def body(p, c, x):
        c = jnp.tanh(c @ p["W"] + x)
        return c, jnp.sum(c ** 2)

    def ref_loss(p):
        _, ls = jax.lax.scan(lambda c, x: body(p, c, x), c0, xs)
        return jnp.sum(ls)

    ref_v, ref_g = jax.value_and_grad(ref_loss)(params)
    state_bytes = tree_bytes((np.zeros((B, D), np.float32),
                              np.zeros((), np.float32)))
    cap = 2 * state_bytes
    bptt = api.checkpointed_bptt(body, strategy="multistage_async",
                                 interval=8, slots=4, storage="tiered",
                                 l2_capacity_bytes=cap)
    v, g = bptt(params, c0, xs)
    np.testing.assert_allclose(float(v), float(ref_v), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g["W"]), np.asarray(ref_g["W"]),
                               rtol=1e-4, atol=1e-6)
    st = api.last_stats()
    assert st.l2_fast_peak_bytes <= cap
    assert st.l2_evictions == 2        # 2 of 4 boundaries spilled
    assert st.l2_promotions >= 2
    assert st.prefetch_depth == 2      # plan-aware promotion lead
    assert st.l2_staged_peak_bytes > 0


def test_tiered_requires_capacity_through_frontend():
    def body(p, c, x):
        return jnp.tanh(c + x), jnp.sum(c)

    with pytest.raises(ValueError, match="l2_capacity_bytes"):
        api.checkpointed_bptt(body, storage="tiered")
    with pytest.raises(ValueError, match="tiered"):
        api.checkpointed_bptt(body, storage="ram", l2_capacity_bytes=100)


def test_tiered_autotune_capacity_aware():
    """The tuner probes both tiers and applies I = ceil(T_T/T_A) to the
    effective transfer time: a budget that forces spills must never pick a
    smaller interval than the unbounded fast tier would."""
    from repro.api.autotune import AutoTuner

    T, B, D = 32, 2, 8
    params = {"W": jax.random.normal(KEY, (D, D)) * 0.3}
    xs = jax.random.normal(jax.random.fold_in(KEY, 2), (T, B, D)) * 0.1
    c0 = jnp.zeros((B, D))

    def body(p, c, x):
        c = jnp.tanh(c @ p["W"] + x)
        return c, jnp.sum(c ** 2)

    state_bytes = tree_bytes((np.zeros((B, D), np.float32),
                              np.zeros((), np.float32)))
    tuner = AutoTuner()
    bptt = api.checkpointed_bptt(body, strategy="multistage_async",
                                 storage="tiered",
                                 l2_capacity_bytes=2 * state_bytes,
                                 tuner=tuner)
    bptt(params, c0, xs)
    tune = api.last_tune()
    assert tune.capacity_bytes == 2 * state_bytes
    assert tune.t_t_slow > 0.0
    # at most 2 boundaries may be fast-resident: the interval guarantees
    # spills are either avoided (I >= n/2) or slow-tier sustainable
    import math
    segments = math.ceil(T / tune.interval)
    if segments * state_bytes > tune.capacity_bytes:
        assert tune.interval * tune.t_a >= min(tune.t_t, tune.t_t_slow)


def test_tiered_reevict_during_writeback_keeps_newest():
    """delete + re-store + re-evict while the old writeback is still in
    flight: per-key writeback ordering must leave the NEW value in the slow
    tier (a stale payload landing last would silently resurrect v1)."""
    gate = threading.Event()

    class GatedSlow(RAMStorage):
        def put(self, key, tree):
            if key == "A" and not gate.is_set():
                gate.wait(5.0)
            super().put(key, tree)

    nb = tree_bytes(_state(0))
    ts = TieredStorage(capacity_bytes=nb, slow=GatedSlow())
    ts.put("A", _state(1))
    done = threading.Event()

    def evict_a():
        ts.put("B", _state(0))   # evicts A; its writeback blocks on the gate
        done.set()

    t = threading.Thread(target=evict_a)
    t.start()
    deadline = time.monotonic() + 5.0
    while "A" not in ts._wb_active and time.monotonic() < deadline:
        time.sleep(0.01)
    ts.delete("A")               # tombstones the in-flight writeback
    ts.put("A", _state(2))       # revokes the tombstone
    ts.put("C", _state(0))       # evicts A again: new payload, same drainer
    gate.set()                   # stale v1 write lands first, then v2
    assert done.wait(5.0)
    t.join(timeout=5.0)
    np.testing.assert_array_equal(ts.get("A")["a"], _state(2)["a"])
    np.testing.assert_array_equal(ts.slow.get("A")["a"], _state(2)["a"])


# ---------------------------------------------------------------------------
# journaled storage (crash consistency)
# ---------------------------------------------------------------------------


def _jtree(i=0):
    return {"a": np.arange(8, dtype=np.float32) + i,
            "b": np.ones((3,), np.float32) * i}


def test_journaled_roundtrip_and_delegation(tmp_path):
    from repro.core.storage import JournaledStorage

    js = make_backend("ram", journal=str(tmp_path / "wal"))
    assert isinstance(js, JournaledStorage)
    js.put(0, _jtree(0))
    js.put(1, _jtree(1))
    np.testing.assert_array_equal(js.get(1)["a"], _jtree(1)["a"])
    assert 0 in js and set(js.keys()) == {0, 1}
    js.delete(0)
    assert 0 not in js
    # instrumentation delegates to the inner backend
    assert js.bytes_written > 0 and js.live_bytes > 0
    js.close()


def test_journal_survives_process_death(tmp_path):
    """The whole point: a RAM inner store evaporates with the process, a
    fresh JournaledStorage over the same directory re-hydrates every
    store from the WAL, bit-for-bit."""
    jd = str(tmp_path / "wal")
    js = make_backend("ram", journal=jd)
    js.begin_run({"n": 8})
    js.put(0, _jtree(0))
    js.put(4, _jtree(4))
    js.delete(0)
    js.close()                      # "crash": inner RAM is gone
    js2 = make_backend("ram", journal=jd)
    rec = js2.recover()
    assert rec.keys == (4,) and rec.meta == {"n": 8}
    np.testing.assert_array_equal(js2.get(4)["a"], _jtree(4)["a"])
    js2.close()


def test_journal_torn_tail_truncated_on_open(tmp_path):
    jd = str(tmp_path / "wal")
    js = make_backend("ram", journal=jd)
    js.put(0, _jtree(0))
    js.put(4, _jtree(4))
    js.close()
    path = os.path.join(jd, "wal.log")
    size = os.path.getsize(path)
    with open(path, "r+b") as f:       # crash mid-write of the last record
        f.truncate(size - 7)
    js2 = make_backend("ram", journal=jd)
    rec = js2.recover()
    assert rec.torn and rec.keys == (0,)   # the torn record is discarded
    np.testing.assert_array_equal(js2.get(0)["a"], _jtree(0)["a"])
    js2.close()


def test_journal_checksum_flip_raises_then_repairs(tmp_path):
    from repro.core.faults import ChecksumError

    jd = str(tmp_path / "wal")
    js = make_backend("ram", journal=jd)
    js.put(0, _jtree(0))
    js.put(4, _jtree(4))
    js.close()
    path = os.path.join(jd, "wal.log")
    size = os.path.getsize(path)
    with open(path, "r+b") as f:       # bit rot inside the *last* record
        f.seek(size - 3)
        b = f.read(1)
        f.seek(size - 3)
        f.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(ChecksumError, match="CRC"):
        make_backend("ram", journal=jd)
    js2 = make_backend("ram", journal=jd, journal_repair=True)
    rec = js2.recover()
    assert rec.keys == (0,)            # truncated back to the last good one
    js2.close()


def test_journal_epoch_reset_bounds_growth(tmp_path):
    """begin_run truncates the file after a cleanly ended epoch, so a
    training loop's journal stays one gradient run long."""
    jd = str(tmp_path / "wal")
    js = make_backend("ram", journal=jd)
    sizes = []
    for step in range(3):
        js.begin_run({"step": step})
        js.put(0, _jtree(step))
        js.delete(0)
        js.end_run()
        sizes.append(js.journal_bytes)
    assert max(sizes) <= sizes[0]      # no unbounded growth across steps
    js.close()


def test_journal_over_compressed_is_read_consistent(tmp_path):
    """make_backend('compressed', journal=...) journals the *raw*
    payloads (journal outside the codec): get_exact returns the exact
    pre-crash state for resume replay, while a re-hydrated normal get
    round-trips through the codec and reproduces exactly the lossy
    values the fault-free run read back."""
    from repro.core.storage import JournaledStorage

    jd = str(tmp_path / "wal")
    js = make_backend("compressed", journal=jd, min_bytes=1)
    assert isinstance(js, JournaledStorage)
    assert isinstance(js.inner, CompressedStorage)
    big = {"w": np.linspace(-1.0, 1.0, 256).astype(np.float32)}
    js.put(0, big)
    lossy = np.asarray(js.get(0)["w"])       # int8 round-trip
    assert not np.array_equal(lossy, big["w"])   # quantization engaged
    js.close()
    js2 = make_backend("compressed", journal=jd, min_bytes=1)
    assert 0 in js2
    # exact raw record for resume replay...
    np.testing.assert_array_equal(np.asarray(js2.get_exact(0)["w"]),
                                  big["w"])
    # ...and codec-consistent values for reverse-sweep reads
    np.testing.assert_array_equal(np.asarray(js2.get(0)["w"]), lossy)
    js2.close()


def test_compressed_treedef_survives_fresh_codec(tmp_path):
    """A hand-built CompressedStorage(inner=JournaledStorage(...)) can
    unflatten re-hydrated checkpoints in a fresh process: the pickled
    treedef rides each payload as a trailing uint8 leaf."""
    from repro.core.storage import JournaledStorage

    jd = str(tmp_path / "wal")
    comp = CompressedStorage(inner=JournaledStorage(RAMStorage(), jd),
                             min_bytes=1)
    big = {"w": np.linspace(-1.0, 1.0, 256).astype(np.float32)}
    comp.put(0, big)
    first = np.asarray(comp.get(0)["w"])
    comp.inner.close()
    comp2 = CompressedStorage(inner=JournaledStorage(RAMStorage(), jd),
                              min_bytes=1)
    np.testing.assert_array_equal(np.asarray(comp2.get(0)["w"]), first)
    comp2.inner.close()


def test_journaled_tiered_recovers(tmp_path):
    js = make_backend("tiered", journal=str(tmp_path / "wal"),
                      directory=str(tmp_path / "slow"), capacity_bytes=64)
    js.put(0, _jtree(0))
    js.put(4, _jtree(4))
    js.close()
    js2 = make_backend("tiered", journal=str(tmp_path / "wal"),
                       directory=str(tmp_path / "slow2"), capacity_bytes=64)
    np.testing.assert_array_equal(js2.get(0)["a"], _jtree(0)["a"])
    js2.close()


# ---------------------------------------------------------------------------
# engine shutdown/error-path regressions (crash-consistency satellites)
# ---------------------------------------------------------------------------


def test_close_surfaces_in_flight_prefetch_error():
    """Regression: close() used to clear the prefetch staging dicts while
    a fetch job was still in flight — the job's pending error was then
    dropped on the floor and close() returned cleanly.  It must join the
    in-flight jobs first and re-raise the typed failure."""
    release = threading.Event()

    class SlowFailing(RAMStorage):
        def get(self, key):
            release.wait(5.0)
            raise IOError("backend get blew up mid-flight")

    eng = AsyncTransferEngine(SlowFailing())
    eng.prefetch_async(0)
    release.set()
    with pytest.raises(IOError, match="mid-flight"):
        eng.close()


def test_demand_get_after_writer_death_is_typed():
    """Regression: a demand fetch whose store is stuck behind a dead
    writer thread used to die on a bare KeyError, hiding the real cause.
    It must raise WriterCrashError naming the dead writer (and close()
    then reports the outstanding stores the same way)."""
    from repro.core import faults
    from repro.core.faults import FaultPlan, WriterCrashError

    with faults.inject(FaultPlan(kill_writer_at_store=0)):
        eng = AsyncTransferEngine(RAMStorage())
    eng.store_async(0, _jtree(0))
    deadline = time.monotonic() + 5.0
    while eng._writer.is_alive() and time.monotonic() < deadline:
        time.sleep(0.01)
    assert not eng._writer.is_alive()
    with pytest.raises(WriterCrashError, match="writer thread died"):
        eng.wait_prefetch(0)       # demand path: store never landed
    with pytest.raises(WriterCrashError, match="writer thread died"):
        eng.close()


def test_make_backend_journal_signature_growth():
    """Migration guard: journal kwargs are consumed by make_backend, never
    forwarded to backend factories; plain calls are unchanged."""
    assert isinstance(make_backend("ram"), RAMStorage)
    with pytest.raises(TypeError):
        RAMStorage(journal="/tmp/x")   # the kwarg belongs to make_backend


def test_journal_header_rot_is_checksum_not_torn(tmp_path):
    """Regression: bit rot in a record's *length* field used to make the
    record extend past EOF and be misclassified as a torn tail (silently
    truncated).  The header CRC must surface it as ChecksumError."""
    from repro.core.faults import ChecksumError

    jd = str(tmp_path / "wal")
    js = make_backend("ram", journal=jd)
    js.put(0, _jtree(0))
    js.put(4, _jtree(4))
    js.close()
    path = os.path.join(jd, "wal.log")
    with open(path, "r+b") as f:       # flip a bit inside record 0's pay_len
        f.seek(11)
        b = f.read(1)
        f.seek(11)
        f.write(bytes([b[0] ^ 0x40]))
    with pytest.raises(ChecksumError, match="header"):
        make_backend("ram", journal=jd)
    js2 = make_backend("ram", journal=jd, journal_repair=True)
    assert js2.recover().keys == ()    # nothing before the damage survives
    js2.close()


def test_journal_end_run_compacts_to_marker_epoch(tmp_path):
    """After a clean run the WAL is rewritten as a tiny done-marker epoch,
    so the next open (every step in standing-resume mode) is O(1) instead
    of re-scanning the whole previous sweep's Level-2 traffic."""
    from repro.core.schedule import segment_plan

    jd = str(tmp_path / "wal")
    js = make_backend("ram", journal=jd)
    js.begin_run({"n": 8})
    for k in (0, 4):
        js.put(k, {"a": np.zeros(4096, np.float32)})   # bulky payloads
        js.delete(k)
    plan = segment_plan(8, 4, 2)
    js.put_cursor(plan.cursor("done", -1))
    js.end_run()
    assert js.journal_bytes < 2048     # marker epoch, not the 32KB of puts
    js.close()
    js2 = make_backend("ram", journal=jd)
    rec = js2.recover()
    assert rec.cursor is not None and rec.cursor.phase == "done"
    assert rec.meta == {"n": 8}
    js2.close()


# ---------------------------------------------------------------------------
# generic resource plans: untracked keys, heterogeneous sizes, peek
# ---------------------------------------------------------------------------


def test_set_plan_counts_untracked_keys():
    """Keys held outside the registered plan are not silently invisible:
    set_plan counts them (untracked_keys) and they fall back to LRU/FIFO
    eviction order instead of Belady."""
    ts = TieredStorage(capacity_bytes=10 * _NB)
    for k in ("stray-a", "stray-b", 0, 1):
        ts.put(k, _state(0))
    plan = ms.ResourceAccessPlan(tuple(
        ms.ResourceAccess(key=k, use_index=i, size_bytes=_NB)
        for i, k in enumerate([1, 0])))
    ts.set_plan(plan)
    assert ts.untracked_keys == 2          # the two strays
    ts.set_plan(plan)
    assert ts.untracked_keys == 4          # cumulative across re-plans


def test_untracked_keys_evicted_before_plan_keys():
    """LRU fallback: under pressure the strays go first (oldest first),
    and among plan keys the farthest next use goes first."""
    ts = TieredStorage(capacity_bytes=2 * _NB)
    plan = ms.ResourceAccessPlan(tuple(
        ms.ResourceAccess(key=k, use_index=i, size_bytes=_NB)
        for i, k in enumerate(["hot", "warm"])))
    ts.set_plan(plan)
    ts.put("stray", _state(0))             # not in the plan
    ts.put("hot", _state(1))
    ts.put("warm", _state(2))              # evicts the stray, not a plan key
    assert sorted(ts._fast) == ["hot", "warm"]
    assert "stray" in ts.slow


def test_belady_eviction_heterogeneous_key_sizes():
    """Belady under mixed sizes: small boundary states and a large expert
    blob share one budget; eviction still picks the farthest next use and
    the fast tier never exceeds capacity even when one victim is not
    enough to admit the incoming large blob."""
    blob = {"w": np.zeros((4, 4, 4), np.float32)}   # 4x a boundary state
    blob_nb = tree_bytes(blob)
    assert blob_nb == 4 * _NB
    # access order: blob first, then boundaries nearest-first
    merged = ms.merge_access_plans(
        ms.ResourceAccessPlan((
            ms.ResourceAccess(key=("xp", 0, 0, 0), use_index=0,
                              size_bytes=blob_nb),)),
        ms.ResourceAccessPlan(tuple(
            ms.ResourceAccess(key=k, use_index=1 + i, size_bytes=_NB)
            for i, k in enumerate([0, 1, 2]))))
    ts = TieredStorage(capacity_bytes=5 * _NB)
    ts.set_plan(merged)
    for k in (0, 1, 2):
        ts.put(k, _state(k))
    ts.put(("xp", 0, 0, 0), blob)          # needs 4*_NB: evicts 2 then 1
    assert ts.fast_live_bytes <= 5 * _NB
    assert ts.fast_peak_bytes <= 5 * _NB
    assert ("xp", 0, 0, 0) in ts._fast     # nearest use stays resident
    assert 0 in ts._fast                   # next-nearest boundary survives
    assert sorted(k for k in (1, 2) if k in ts.slow) == [1, 2]
    assert ts.evictions == 2
    # replay model agrees exactly with the measured peak
    from repro.core import perfmodel as pm

    puts = [(0, _NB), (1, _NB), (2, _NB), (("xp", 0, 0, 0), blob_nb)]
    assert pm.fast_peak_bytes_resources(
        puts, merged.distances(), 5 * _NB) == ts.fast_peak_bytes


def test_tiered_peek_does_not_promote():
    """peek() is the parameter lane's read: a slow-tier hit comes back
    frozen but is NOT promoted into the fast tier, so reads can never
    perturb the plan-driven residency (what makes the fast-tier peak
    exactly replayable)."""
    plan = ms.segment_plan(n=4, interval=1, s_l1=1)
    ts = TieredStorage(capacity_bytes=2 * _NB)
    ts.set_plan(plan)
    for k in range(4):
        ts.put(k, _state(k))
    assert sorted(ts._fast) == [2, 3]
    got = ts.peek(0)                       # spilled: served from slow
    np.testing.assert_array_equal(got["a"], _state(0)["a"])
    assert ts.promotions == 0
    assert 0 not in ts._fast
    assert ts.slow_hits == 1
    got = ts.peek(3)                       # resident: served from fast
    np.testing.assert_array_equal(got["a"], _state(3)["a"])
    assert ts.fast_hits == 1
    with pytest.raises(KeyError):          # missing raises, like get()
        ts.peek("missing")
    with pytest.raises(ValueError):        # frozen like get()
        ts.peek(0)["a"][0, 0] = 99.0
