"""End-to-end behaviour: the paper's claims on a real model (LSTM BPTT),
the training launcher, the serving launcher, and checkpoint-resume — the
integration layer over everything below it."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CheckpointExecutor
from repro.core.schedule import multistage_recompute_factor
from repro.models.lstm import (bptt_loss_and_grad, forward_loss, init_lstm,
                               init_state, make_operators)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def lstm_setup():
    T, B, V = 65, 4, 64
    params = init_lstm(KEY, V, 16, 32)
    tokens = jax.random.randint(jax.random.fold_in(KEY, 1), (B, T + 1), 0, V)
    ref_loss, ref_grad = jax.value_and_grad(forward_loss)(params, tokens)
    return params, tokens, ref_loss, ref_grad


def _grads_close(g, ref):
    for k in ref:
        np.testing.assert_allclose(np.array(g[k]), np.array(ref[k]),
                                   rtol=1e-4, atol=1e-5)


def test_paper_pipeline_all_strategies_same_gradients(lstm_setup):
    """The paper's core promise: checkpointing strategies change memory and
    compute, never the result."""
    params, tokens, ref_loss, ref_grad = lstm_setup
    fwd, bwd, seed, n = make_operators(params, tokens)
    ex = CheckpointExecutor(fwd, bwd)
    s0 = init_state(tokens.shape[0], 32)

    (_, g), st_conv = ex.run_conventional(s0, n, seed())
    _grads_close(g, ref_grad)
    (_, g), st_rev = ex.run_revolve(s0, n, seed(), s=6)
    _grads_close(g, ref_grad)
    (_, g), st_ms = ex.run_multistage(s0, n, seed(), interval=8, s_l1=6)
    _grads_close(g, ref_grad)

    # memory: conventional stores n states; multistage peaks at O(interval)
    assert st_conv.peak_l1_states == n
    assert st_ms.peak_l1_states <= 8
    # compute: multistage recompute factor is the closed-form one
    assert st_ms.recompute_factor == pytest.approx(
        multistage_recompute_factor(n, 8, 6))
    # and beats Revolve's advance count at equal fast memory
    assert st_ms.advances <= st_rev.advances + n


def test_compiled_bptt_matches(lstm_setup):
    params, tokens, ref_loss, ref_grad = lstm_setup
    v, g = bptt_loss_and_grad(params, tokens, interval=13, offload=True)
    np.testing.assert_allclose(float(v), float(ref_loss), rtol=1e-5)
    _grads_close(g, ref_grad)


@pytest.mark.slow
def test_train_launcher_end_to_end():
    from repro.launch.train import main
    with tempfile.TemporaryDirectory() as d:
        state = main(["--arch", "mamba2-370m", "--smoke", "--steps", "6",
                      "--ckpt-dir", d, "--ckpt-every", "3"])
        assert int(state["step"]) == 6
        # resume continues from the checkpoint
        state2 = main(["--arch", "mamba2-370m", "--smoke", "--steps", "8",
                       "--ckpt-dir", d, "--ckpt-every", "3"])
        assert int(state2["step"]) == 8


@pytest.mark.slow
def test_serve_launcher_end_to_end():
    from repro.launch.serve import main
    toks = main(["--arch", "granite-3-2b", "--smoke", "--batch", "2",
                 "--prompt-len", "8", "--decode-steps", "6"])
    assert toks.shape == (2, 7)  # first token + 6 decoded
    cfg_vocab = 512
    assert toks.max() < cfg_vocab


@pytest.mark.slow
def test_lstm_training_converges_with_multistage():
    """A few RMSProp steps through the full multistage pipeline must reduce
    the loss on a fixed batch (the paper's §5 training setup, miniature)."""
    from repro.optim import rmsprop
    V, T, B = 64, 48, 4
    params = init_lstm(jax.random.fold_in(KEY, 5), V, 16, 32)
    tokens = jax.random.randint(jax.random.fold_in(KEY, 6), (B, T + 1), 0, V)
    opt = rmsprop(5e-3)
    opt_state = opt.init(params)
    losses = []
    for i in range(8):
        loss, grads = bptt_loss_and_grad(params, tokens, interval=8)
        params, opt_state = opt.update(grads, opt_state, params,
                                       jnp.asarray(i))
        losses.append(float(loss))
    assert losses[-1] < losses[0]
