"""Executor path: every strategy must reproduce autodiff gradients exactly,
within slot budgets, with working async Level-2 storage."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.executor import CheckpointExecutor
from repro.core.revolve import optimal_advances
from repro.core.schedule import multistage_recompute_factor
from repro.core.storage import (AsyncTransferEngine, DiskStorage, RAMStorage,
                                tree_bytes)

N = 29


@pytest.fixture(scope="module")
def chain():
    W = jax.random.normal(jax.random.PRNGKey(0), (8, 8)) * 0.5
    x0 = jax.random.normal(jax.random.PRNGKey(1), (4, 8))

    def step(x, k):
        return jnp.tanh(x @ W + k * 0.01)

    def loss(x0):
        x = x0
        for k in range(N):
            x = step(x, k)
        return jnp.sum(x ** 2)

    fwd = jax.jit(step, static_argnums=1)

    def bwd(x_k, adj, k):
        if k == N - 1:
            return jax.grad(lambda x: jnp.sum(step(x, k) ** 2))(x_k)
        _, vjp = jax.vjp(lambda x: step(x, k), x_k)
        return vjp(adj)[0]

    g_ref = jax.grad(loss)(x0)
    return fwd, bwd, x0, g_ref


def _check(g, g_ref):
    np.testing.assert_allclose(np.array(g), np.array(g_ref),
                               rtol=1e-5, atol=1e-6)


def test_conventional(chain):
    fwd, bwd, x0, g_ref = chain
    g, st = CheckpointExecutor(fwd, bwd).run_conventional(
        x0, N, jnp.zeros_like(x0))
    _check(g, g_ref)
    assert st.advances == N
    assert st.peak_l1_states == N


@pytest.mark.parametrize("s", [2, 4, 7])
def test_revolve(chain, s):
    fwd, bwd, x0, g_ref = chain
    g, st = CheckpointExecutor(fwd, bwd).run_revolve(
        x0, N, jnp.zeros_like(x0), s=s)
    _check(g, g_ref)
    assert st.advances == optimal_advances(N, s)
    assert st.peak_l1_states <= s


@pytest.mark.parametrize("interval,s", [(4, 4), (8, 3), (16, 8), (64, 4)])
def test_multistage_ram(chain, interval, s):
    fwd, bwd, x0, g_ref = chain
    g, st = CheckpointExecutor(fwd, bwd).run_multistage(
        x0, N, jnp.zeros_like(x0), interval=interval, s_l1=s)
    _check(g, g_ref)
    assert st.recompute_factor == pytest.approx(
        multistage_recompute_factor(N, interval, s))
    assert st.peak_l1_states <= max(s, min(interval, N))


def test_multistage_disk(chain):
    fwd, bwd, x0, g_ref = chain
    with tempfile.TemporaryDirectory() as d:
        with AsyncTransferEngine(DiskStorage(d)) as eng:
            g, st = CheckpointExecutor(fwd, bwd).run_multistage(
                x0, N, jnp.zeros_like(x0), interval=8, s_l1=4, engine=eng)
        _check(g, g_ref)
        assert st.l2_stores == st.l2_prefetches == 4


def test_multistage_throttled_bandwidth(chain):
    """Deterministic slow Level-2: results identical; stalls are measured."""
    fwd, bwd, x0, g_ref = chain
    backend = RAMStorage(bandwidth=50e6)
    with AsyncTransferEngine(backend) as eng:
        g, st = CheckpointExecutor(fwd, bwd).run_multistage(
            x0, N, jnp.zeros_like(x0), interval=8, s_l1=4, engine=eng)
    _check(g, g_ref)
    assert backend.bytes_written == 4 * tree_bytes(x0)


def test_storage_roundtrip_ram_and_disk():
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": (np.ones(4), np.zeros(2))}
    ram = RAMStorage()
    ram.put(0, tree)
    got = ram.get(0)
    np.testing.assert_array_equal(got["a"], tree["a"])
    with tempfile.TemporaryDirectory() as d:
        disk = DiskStorage(d)
        disk.put("x", tree)
        assert "x" in disk
        got = disk.get("x")
        np.testing.assert_array_equal(got["b"][0], tree["b"][0])
        disk.delete("x")
        assert "x" not in disk
