"""Roofline analysis: HLO collective parsing + jaxpr cost walker."""
import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.analysis.jaxpr_cost import cost_of_fn
from repro.analysis.roofline import (build_report, collective_bytes,
                                     split_fabric)

HLO = """
ENTRY %main {
  %ag = f32[256,64]{1,0} all-gather(%p1), channel_id=1, replica_groups=[8,8]<=[8,8]T(1,0), dimensions={0}
  %ar = f32[64,256]{1,0} all-reduce(%dot.1), channel_id=2, replica_groups=[16,4]<=[64], to_apply=%add
  %rs = bf16[8,32]{1,0} reduce-scatter(%x), channel_id=3, replica_groups=[2,2]<=[4], dimensions={0}
  %cp = s8[128]{0} collective-permute(%y), source_target_pairs={{0,1}}
  %a2a = f32[16,16]{1,0} all-to-all(%z), replica_groups={{0,1,2,3}}, dimensions={0}
  %ags = (f32[4,4]{1,0}, f32[16,4]{1,0}) all-gather-start(%w), channel_id=9, replica_groups=[1,4]<=[4], dimensions={0}
  %dot = f32[8,8]{1,0} dot(%a, %b)
}
"""


def test_collective_bytes_parse():
    out = collective_bytes(HLO)
    assert out["all-gather"] == 256 * 64 * 4 // 8 + 16 * 4 * 4 // 4
    assert out["all-reduce"] == 64 * 256 * 4
    assert out["reduce-scatter"] == 8 * 32 * 2 * 2
    assert out["collective-permute"] == 128
    assert out["all-to-all"] == 16 * 16 * 4
    assert out["total"] == sum(out[k] for k in (
        "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute"))
    assert out["group8"] == 256 * 64 * 4 // 8
    assert out["wire"] > 0


def test_split_fabric():
    coll = {"total": 100, "group2": 10, "group16": 60, "group512": 30}
    f = split_fabric(coll, n_pods=2)
    assert f["dcn"] == 40 and f["ici"] == 60
    f1 = split_fabric(coll, n_pods=1)
    assert f1["dcn"] == 0 and f1["ici"] == 100


def test_jaxpr_cost_scan_multiplies():
    W = jnp.ones((64, 64))

    def body(c, _):
        return jnp.tanh(c @ W), None

    x = jnp.ones((64, 64))
    in_b = 64 * 64 * 4  # top-level input read, counted once
    c1 = cost_of_fn(lambda x: lax.scan(body, x, None, length=1)[0], x)
    c8 = cost_of_fn(lambda x: lax.scan(body, x, None, length=8)[0], x)
    assert c8.flops == pytest.approx(8 * c1.flops, rel=1e-6)
    assert (c8.bytes - in_b) == pytest.approx(8 * (c1.bytes - in_b),
                                              rel=1e-6)


def test_jaxpr_cost_dot_flops_exact():
    a = jnp.ones((32, 48))
    b = jnp.ones((48, 16))
    c = cost_of_fn(lambda a, b: a @ b, a, b)
    assert c.flops == 2 * 32 * 48 * 16
    in_b = (32 * 48 + 48 * 16) * 4
    assert c.bytes_major == 2 * 32 * 16 * 4 + in_b


def test_jaxpr_cost_includes_remat_recompute():
    W = jnp.ones((64, 64))

    def f_plain(x):
        return jnp.sum((x @ W) ** 2)

    def f_remat(x):
        return jnp.sum(jax.checkpoint(lambda x: x @ W)(x) ** 2)

    x = jnp.ones((8, 64))
    g_plain = cost_of_fn(jax.grad(f_plain), x)
    g_remat = cost_of_fn(jax.grad(f_remat), x)
    assert g_remat.flops >= g_plain.flops  # replay appears in the jaxpr


def test_jaxpr_cost_pallas_grid_multiplied():
    # Regression: a gridded pallas_call body used to be counted once (one
    # opaque sub-jaxpr visit) even when wrapped in remat under a pjit
    # sub-jaxpr.  A 2-layer rematted flash-attention stack must report at
    # least the analytic 4*BH*S^2*D flops per layer.
    from repro.kernels.flash_attention import flash_attention

    BH, S, D = 2, 64, 16

    def layer(x):
        return flash_attention(x, x, x, causal=False, block_q=32, block_k=32)

    def stack(x):
        for _ in range(2):
            x = jax.checkpoint(layer)(x)
        return jnp.sum(x)

    x = jnp.ones((BH, S, D), jnp.float32)
    c = cost_of_fn(jax.jit(stack), x)
    per_layer = 4.0 * BH * S * S * D  # QK^T + PV dots
    assert c.flops >= 2 * per_layer


def test_build_report_bottleneck_and_fraction():
    r = build_report(
        arch="a", shape="s", mesh_name="m", n_chips=256,
        jaxpr_flops=256 * 197e12 * 0.1,         # 100 ms compute
        jaxpr_bytes=256 * 819e9 * 0.01,         # 10 ms memory
        score_bytes=0.0, coll_bytes=1e9,        # 5 ms collective
        coll_breakdown={"total": int(1e9), "group16": int(1e9)},
        model_flops_total=256 * 197e12 * 0.05)  # useful = half of executed
    assert r.bottleneck == "compute"
    assert r.roofline_fraction == pytest.approx(0.5, rel=1e-3)
    assert r.useful_ratio == pytest.approx(0.5, rel=1e-3)
    assert r.t_bound == pytest.approx(0.1, rel=1e-3)
