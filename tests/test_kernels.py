"""Pallas kernels vs pure-jnp oracles, interpret mode, shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.attention import reference_attention
from repro.models.ssm import ssd_sequential

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------- flash attn
@pytest.mark.parametrize("S,H,G,D,bq,bk", [
    (128, 4, 4, 32, 64, 64),      # MHA
    (256, 8, 2, 64, 64, 128),     # GQA, rectangular blocks
    (64, 2, 1, 128, 64, 64),      # MQA, big head dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(S, H, G, D, bq, bk, dtype):
    B = 2
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, D), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, G, D), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, G, D), dtype)
    out = ops.flash_attention(q, k, v, block_q=bq, block_k=bk,
                              interpret=True)
    want = reference_attention(q, k, v)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.array(out, np.float32),
                               np.array(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("kw", [
    dict(window=64), dict(softcap=30.0), dict(causal=False),
    dict(window=32, softcap=15.0),
])
def test_flash_attention_features(kw):
    B, S, H, G, D = 1, 128, 4, 2, 32
    q = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (B, S, G, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (B, S, G, D))
    out = ops.flash_attention(q, k, v, block_q=32, block_k=32,
                              interpret=True, **kw)
    want = reference_attention(q, k, v, **kw)
    np.testing.assert_allclose(np.array(out), np.array(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_ref_oracle_self_consistent():
    B, S, D = 3, 64, 16
    q = jax.random.normal(jax.random.fold_in(KEY, 7), (B, S, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 8), (B, S, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 9), (B, S, D))
    a = ref.flash_attention_ref(q, k, v)
    b = reference_attention(q[:, :, None, :], k[:, :, None, :],
                            v[:, :, None, :])[:, :, 0, :]
    np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-5,
                               atol=1e-5)


# ------------------------------------------------------------------ ssd scan
@pytest.mark.parametrize("T,H,G,P,N,chunk", [
    (64, 4, 2, 16, 8, 16),
    (128, 2, 1, 32, 16, 32),
    (32, 8, 8, 8, 8, 32),   # chunk == T
])
def test_ssd_scan_shapes(T, H, G, P, N, chunk):
    B = 2
    x = jax.random.normal(jax.random.fold_in(KEY, 11), (B, T, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 12),
                                           (B, T, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 13), (H,)) * 0.3)
    b = jax.random.normal(jax.random.fold_in(KEY, 14), (B, T, G, N)) * 0.5
    c = jax.random.normal(jax.random.fold_in(KEY, 15), (B, T, G, N)) * 0.5
    y, h = ops.ssd_scan(x, dt, A, b, c, chunk=chunk, interpret=True)
    y_ref, h_ref = ssd_sequential(x, dt, A, b, c)
    np.testing.assert_allclose(np.array(y), np.array(y_ref), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.array(h), np.array(h_ref), rtol=2e-4,
                               atol=2e-4)


def test_ssd_kernel_vs_flat_ref():
    BH, T, P, N = 3, 32, 8, 4
    x = jax.random.normal(jax.random.fold_in(KEY, 16), (BH, T, P)) * 0.5
    la = -jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 17),
                                            (BH, T)))
    b = jax.random.normal(jax.random.fold_in(KEY, 18), (BH, T, N)) * 0.5
    c = jax.random.normal(jax.random.fold_in(KEY, 19), (BH, T, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 20),
                                           (BH, T)))
    from repro.kernels.ssd_scan import ssd_scan as raw
    y, h = raw(x, la, b, c, dt, chunk=8, interpret=True)
    y_ref, h_ref = ref.ssd_scan_ref(x, la, b, c, dt)
    np.testing.assert_allclose(np.array(y), np.array(y_ref), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.array(h), np.array(h_ref), rtol=2e-4,
                               atol=2e-4)


# ----------------------------------------------------------------- lstm cell
@pytest.mark.parametrize("B,Dx,Dh,bb", [(8, 16, 32, 4), (16, 8, 8, 16),
                                        (4, 64, 128, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lstm_cell(B, Dx, Dh, bb, dtype):
    x = jax.random.normal(jax.random.fold_in(KEY, 21), (B, Dx), dtype)
    h = jax.random.normal(jax.random.fold_in(KEY, 22), (B, Dh), dtype)
    c = jax.random.normal(jax.random.fold_in(KEY, 23), (B, Dh), dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 24),
                          (Dx + Dh, 4 * Dh), dtype) * 0.1
    bias = jnp.zeros((4 * Dh,), dtype)
    hn, cn = ops.lstm_cell(x, h, c, w, bias, block_b=bb, interpret=True)
    hr, cr = ref.lstm_cell_ref(x.astype(jnp.float32), h.astype(jnp.float32),
                               c.astype(jnp.float32), w.astype(jnp.float32),
                               bias.astype(jnp.float32))
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.array(hn, np.float32), np.array(hr),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.array(cn, np.float32), np.array(cr),
                               rtol=tol, atol=tol)


# ------------------------------------------------- fused segment runner
# segment_pallas in interpret mode, driven through the public frontend:
# the pallas runner's loss/gradients must be *bit-identical* (fp32) to the
# compiled runner's, and match the undecomposed autodiff oracle — for an
# LSTM chain (int token inputs: no input cotangents), a chain built on
# kernels/ref.py's lstm_cell_ref, and an SSM chain with differentiable
# float inputs (exercises the in-kernel dxd cotangent path).  Intervals
# are chosen so segments and in-segment chunks both have uneven tails.

def _assert_bitwise(tree_a, tree_b, msg=""):
    for (pa, a), (pb, b) in zip(
            sorted(jax.tree_util.tree_leaves_with_path(tree_a),
                   key=lambda kv: str(kv[0])),
            sorted(jax.tree_util.tree_leaves_with_path(tree_b),
                   key=lambda kv: str(kv[0]))):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes(), \
            (msg, pa, pb)


def _runner_parity(spec, params, batch, *, interval, slots, monkeypatch):
    from repro import api

    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    out = {}
    for runner in ("compiled", "pallas"):
        vg = api.value_and_grad_offloaded(
            spec, strategy="multistage_async", interval=interval,
            slots=slots, engine="compiled", runner=runner)
        v, g = vg(params, batch)
        out[runner] = (np.asarray(v),
                       jax.tree_util.tree_map(np.asarray, g))
        if runner == "pallas":
            st = api.last_stats()
            n = api.last_plan().n
            assert st.fused_segments == 2 * (-(-n // interval)), st
            assert st.fused_boundary_copies > 0, st
    assert out["compiled"][0].tobytes() == out["pallas"][0].tobytes()
    _assert_bitwise(out["compiled"][1], out["pallas"][1], "runner grads")
    # and both must agree with the undecomposed autodiff oracle
    v_ref, g_ref = jax.value_and_grad(spec.loss_fn())(params, batch)
    np.testing.assert_allclose(out["pallas"][0], np.asarray(v_ref),
                               rtol=1e-5, atol=1e-6)
    for (pa, a), (pb, b) in zip(
            sorted(jax.tree_util.tree_leaves_with_path(out["pallas"][1]),
                   key=lambda kv: str(kv[0])),
            sorted(jax.tree_util.tree_leaves_with_path(
                jax.tree_util.tree_map(np.asarray, g_ref)),
                   key=lambda kv: str(kv[0]))):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5,
                                   err_msg=str((pa, pb)))


@pytest.mark.parametrize("T,interval,slots", [
    (37, 8, 4),    # uneven segment tail (5) + uneven chunk tails
    (24, 24, 5),   # single segment, chunked with short tail
])
def test_segment_pallas_lstm_chain_bitwise(T, interval, slots, monkeypatch):
    from repro.models.lstm import init_lstm, train_chain

    params = init_lstm(jax.random.fold_in(KEY, 30), vocab=17, d_embed=8,
                       d_hidden=12)
    tokens = jax.random.randint(jax.random.fold_in(KEY, 31), (3, T + 1),
                                0, 17)
    _runner_parity(train_chain(), params, {"tokens": tokens},
                   interval=interval, slots=slots, monkeypatch=monkeypatch)


def test_segment_pallas_ref_lstm_cell_chain(monkeypatch):
    """Chain whose body is kernels/ref.py's lstm_cell_ref itself."""
    from repro.api.chain import ChainSpec

    B, Dx, Dh, T = 2, 4, 6, 29
    params = {
        "w": jax.random.normal(jax.random.fold_in(KEY, 32),
                               (Dx + Dh, 4 * Dh)) * 0.2,
        "b": jnp.zeros((4 * Dh,)),
    }
    xs = jax.random.normal(jax.random.fold_in(KEY, 33), (T, B, Dx)) * 0.5

    def prelude(p, batch):
        z = jnp.zeros((B, Dh))
        return (z, z, jnp.float32(0.0)), batch["xs"]

    def body(p, carry, x, batch):
        h, c, acc = carry
        h, c = ref.lstm_cell_ref(x, h, c, p["w"], p["b"])
        return (h, c, acc + jnp.sum(h ** 2))

    def readout(p, carry, batch):
        return carry[2]

    spec = ChainSpec(prelude, body, readout, name="ref-lstm-chain")
    _runner_parity(spec, params, {"xs": xs}, interval=8, slots=4,
                   monkeypatch=monkeypatch)


def test_segment_pallas_ssm_chain_float_inputs(monkeypatch):
    """Diagonal SSM chain with differentiable float xs: the reverse kernel
    must thread per-step input cotangents (dxd) through its chunked
    in-kernel recompute, not just the carry/params adjoints."""
    from repro.api.chain import ChainSpec

    B, D, T = 3, 8, 41
    params = {
        "logA": jax.random.normal(jax.random.fold_in(KEY, 34), (D,)) * 0.1,
        "Bm": jax.random.normal(jax.random.fold_in(KEY, 35), (D, D)) * 0.3,
        "Cm": jax.random.normal(jax.random.fold_in(KEY, 36), (D, D)) * 0.3,
    }
    xs = jax.random.normal(jax.random.fold_in(KEY, 37), (T, B, D)) * 0.4

    def prelude(p, batch):
        return (jnp.zeros((B, D)), jnp.float32(0.0)), batch["xs"]

    def body(p, carry, x, batch):
        h, acc = carry
        h = jnp.exp(-jax.nn.softplus(p["logA"])) * h + x @ p["Bm"]
        y = h @ p["Cm"]
        return (h, acc + jnp.mean(y ** 2))

    def readout(p, carry, batch):
        return carry[1]

    spec = ChainSpec(prelude, body, readout, name="ssm-chain")
    _runner_parity(spec, params, {"xs": xs}, interval=16, slots=4,
                   monkeypatch=monkeypatch)


def test_segment_pallas_cpu_fallback_warns_once(monkeypatch):
    """Off-TPU without the interpret override the pallas runner must fall
    back to the compiled runner with a one-line warning — same numbers,
    zero fused segments."""
    import warnings as _warnings

    from repro import api
    from repro.models.lstm import init_lstm, train_chain

    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    params = init_lstm(jax.random.fold_in(KEY, 38), vocab=11, d_embed=4,
                       d_hidden=8)
    tokens = jax.random.randint(jax.random.fold_in(KEY, 39), (2, 25), 0, 11)
    vg = api.value_and_grad_offloaded(
        train_chain(), strategy="multistage_async", interval=8, slots=4,
        runner="pallas")
    with _warnings.catch_warnings(record=True) as w:
        _warnings.simplefilter("always")
        v, g = vg(params, {"tokens": tokens})
    msgs = [str(x.message) for x in w]
    assert any("falling back to the compiled segment runner" in m
               for m in msgs), msgs
    assert api.last_stats().fused_segments == 0
    vg_ref = api.value_and_grad_offloaded(
        train_chain(), strategy="multistage_async", interval=8, slots=4,
        runner="compiled")
    v_ref, g_ref = vg_ref(params, {"tokens": tokens})
    assert np.asarray(v).tobytes() == np.asarray(v_ref).tobytes()
    _assert_bitwise(g, g_ref, "fallback grads")
