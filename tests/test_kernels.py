"""Pallas kernels vs pure-jnp oracles, interpret mode, shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.attention import reference_attention
from repro.models.ssm import ssd_sequential

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------- flash attn
@pytest.mark.parametrize("S,H,G,D,bq,bk", [
    (128, 4, 4, 32, 64, 64),      # MHA
    (256, 8, 2, 64, 64, 128),     # GQA, rectangular blocks
    (64, 2, 1, 128, 64, 64),      # MQA, big head dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(S, H, G, D, bq, bk, dtype):
    B = 2
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, D), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, G, D), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, G, D), dtype)
    out = ops.flash_attention(q, k, v, block_q=bq, block_k=bk,
                              interpret=True)
    want = reference_attention(q, k, v)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.array(out, np.float32),
                               np.array(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("kw", [
    dict(window=64), dict(softcap=30.0), dict(causal=False),
    dict(window=32, softcap=15.0),
])
def test_flash_attention_features(kw):
    B, S, H, G, D = 1, 128, 4, 2, 32
    q = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (B, S, G, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (B, S, G, D))
    out = ops.flash_attention(q, k, v, block_q=32, block_k=32,
                              interpret=True, **kw)
    want = reference_attention(q, k, v, **kw)
    np.testing.assert_allclose(np.array(out), np.array(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_ref_oracle_self_consistent():
    B, S, D = 3, 64, 16
    q = jax.random.normal(jax.random.fold_in(KEY, 7), (B, S, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 8), (B, S, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 9), (B, S, D))
    a = ref.flash_attention_ref(q, k, v)
    b = reference_attention(q[:, :, None, :], k[:, :, None, :],
                            v[:, :, None, :])[:, :, 0, :]
    np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-5,
                               atol=1e-5)


# ------------------------------------------------------------------ ssd scan
@pytest.mark.parametrize("T,H,G,P,N,chunk", [
    (64, 4, 2, 16, 8, 16),
    (128, 2, 1, 32, 16, 32),
    (32, 8, 8, 8, 8, 32),   # chunk == T
])
def test_ssd_scan_shapes(T, H, G, P, N, chunk):
    B = 2
    x = jax.random.normal(jax.random.fold_in(KEY, 11), (B, T, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 12),
                                           (B, T, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 13), (H,)) * 0.3)
    b = jax.random.normal(jax.random.fold_in(KEY, 14), (B, T, G, N)) * 0.5
    c = jax.random.normal(jax.random.fold_in(KEY, 15), (B, T, G, N)) * 0.5
    y, h = ops.ssd_scan(x, dt, A, b, c, chunk=chunk, interpret=True)
    y_ref, h_ref = ssd_sequential(x, dt, A, b, c)
    np.testing.assert_allclose(np.array(y), np.array(y_ref), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.array(h), np.array(h_ref), rtol=2e-4,
                               atol=2e-4)


def test_ssd_kernel_vs_flat_ref():
    BH, T, P, N = 3, 32, 8, 4
    x = jax.random.normal(jax.random.fold_in(KEY, 16), (BH, T, P)) * 0.5
    la = -jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 17),
                                            (BH, T)))
    b = jax.random.normal(jax.random.fold_in(KEY, 18), (BH, T, N)) * 0.5
    c = jax.random.normal(jax.random.fold_in(KEY, 19), (BH, T, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 20),
                                           (BH, T)))
    from repro.kernels.ssd_scan import ssd_scan as raw
    y, h = raw(x, la, b, c, dt, chunk=8, interpret=True)
    y_ref, h_ref = ref.ssd_scan_ref(x, la, b, c, dt)
    np.testing.assert_allclose(np.array(y), np.array(y_ref), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.array(h), np.array(h_ref), rtol=2e-4,
                               atol=2e-4)


# ----------------------------------------------------------------- lstm cell
@pytest.mark.parametrize("B,Dx,Dh,bb", [(8, 16, 32, 4), (16, 8, 8, 16),
                                        (4, 64, 128, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lstm_cell(B, Dx, Dh, bb, dtype):
    x = jax.random.normal(jax.random.fold_in(KEY, 21), (B, Dx), dtype)
    h = jax.random.normal(jax.random.fold_in(KEY, 22), (B, Dh), dtype)
    c = jax.random.normal(jax.random.fold_in(KEY, 23), (B, Dh), dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 24),
                          (Dx + Dh, 4 * Dh), dtype) * 0.1
    bias = jnp.zeros((4 * Dh,), dtype)
    hn, cn = ops.lstm_cell(x, h, c, w, bias, block_b=bb, interpret=True)
    hr, cr = ref.lstm_cell_ref(x.astype(jnp.float32), h.astype(jnp.float32),
                               c.astype(jnp.float32), w.astype(jnp.float32),
                               bias.astype(jnp.float32))
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.array(hn, np.float32), np.array(hr),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.array(cn, np.float32), np.array(cr),
                               rtol=tol, atol=tol)
