"""MoE: both dispatch implementations vs a per-token oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional dep, see shim

from repro.models.layers import DTypes
from repro.models.moe import (_route, init_moe, mlp, moe_einsum, moe_sorted)

DT = DTypes(compute=jnp.float32)
KEY = jax.random.PRNGKey(0)


def _oracle(p, x, E, k):
    w, idx, _ = _route(p, x, E, k)

    def per_token(xi, wi, ii):
        out = jnp.zeros_like(xi)
        for j in range(k):
            e = ii[j]
            g = xi @ p["w_gate"][e]
            u = xi @ p["w_up"][e]
            out = out + wi[j] * ((jax.nn.silu(g) * u) @ p["w_down"][e])
        return out

    y = jax.vmap(jax.vmap(per_token))(x, w, idx)
    if "shared" in p:
        y = y + mlp(p["shared"], x, dt=DT)
    return y


@pytest.mark.parametrize("impl", [moe_einsum, moe_sorted])
@pytest.mark.parametrize("E,k,shared", [(8, 2, False), (8, 1, True),
                                        (4, 2, True)])
def test_matches_oracle_no_drops(impl, E, k, shared):
    p = init_moe(KEY, 32, 64, E, shared_expert=shared)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (3, 16, 32))
    y, aux = impl(p, x, n_experts=E, top_k=k, capacity_factor=8.0, dt=DT)
    np.testing.assert_allclose(np.array(y), np.array(_oracle(p, x, E, k)),
                               rtol=1e-4, atol=1e-5)
    assert float(aux) > 0


def test_einsum_equals_sorted():
    E, k = 8, 2
    p = init_moe(KEY, 32, 64, E)
    x = jax.random.normal(jax.random.fold_in(KEY, 6), (2, 24, 32))
    y1, a1 = moe_einsum(p, x, n_experts=E, top_k=k, capacity_factor=8.0,
                        dt=DT)
    y2, a2 = moe_sorted(p, x, n_experts=E, top_k=k, capacity_factor=8.0,
                        dt=DT)
    np.testing.assert_allclose(np.array(y1), np.array(y2), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-6)


def test_capacity_drops_tokens():
    """With a tiny capacity factor, outputs differ from the oracle only by
    dropped tokens (whose contribution becomes 0 / partial)."""
    E, k = 4, 1
    p = init_moe(KEY, 16, 32, E)
    x = jax.random.normal(jax.random.fold_in(KEY, 7), (1, 64, 16))
    y_full, _ = moe_einsum(p, x, n_experts=E, top_k=k, capacity_factor=8.0,
                           dt=DT)
    y_tight, _ = moe_einsum(p, x, n_experts=E, top_k=k, capacity_factor=0.25,
                            dt=DT)
    # some tokens must have been dropped
    changed = np.any(np.abs(np.array(y_full - y_tight)) > 1e-6, axis=-1)
    assert changed.any()
    # dropped tokens produce exactly zero MoE output (no shared expert here)
    zero_rows = np.all(np.abs(np.array(y_tight)) < 1e-7, axis=-1)
    assert zero_rows.any()


@settings(deadline=None, max_examples=10)
@given(b=st.integers(1, 3), s=st.sampled_from([8, 16]),
       e=st.sampled_from([4, 8]), k=st.integers(1, 2))
def test_grads_finite_property(b, s, e, k):
    p = init_moe(KEY, 16, 32, e)
    x = jax.random.normal(jax.random.fold_in(KEY, 8), (b, s, 16))
    for impl in (moe_einsum, moe_sorted):
        g = jax.grad(lambda p_: jnp.sum(
            impl(p_, x, n_experts=e, top_k=k, dt=DT)[0] ** 2))(p)
        assert all(bool(jnp.all(jnp.isfinite(l)))
                   for l in jax.tree_util.tree_leaves(g))


def test_capacity_stats_are_load_accurate():
    """with_stats=True surfaces what _capacity silently drops: routed
    counts sum to G*S*k, kept == routed - dropped, and the two dispatch
    implementations agree on every count."""
    E, k = 4, 1
    G, S = 1, 64
    p = init_moe(KEY, 16, 32, E)
    x = jax.random.normal(jax.random.fold_in(KEY, 7), (G, S, 16))
    y1, _, s1 = moe_einsum(p, x, n_experts=E, top_k=k, capacity_factor=0.25,
                           dt=DT, with_stats=True)
    y2, _, s2 = moe_sorted(p, x, n_experts=E, top_k=k, capacity_factor=0.25,
                           dt=DT, with_stats=True)
    routed1 = np.asarray(s1["routed_counts"])
    kept1 = np.asarray(s1["expert_counts"])
    assert int(routed1.sum()) == G * S * k
    assert int(s1["dropped_tokens"]) == int(routed1.sum() - kept1.sum())
    assert int(s1["dropped_tokens"]) > 0          # the tight capacity bit
    assert (kept1 <= int(s1["capacity"])).all()
    np.testing.assert_array_equal(routed1, np.asarray(s2["routed_counts"]))
    np.testing.assert_array_equal(kept1, np.asarray(s2["expert_counts"]))
    # the stats opt-in must not change the computed output
    y_plain, _ = moe_einsum(p, x, n_experts=E, top_k=k, capacity_factor=0.25,
                            dt=DT)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y_plain))
    np.testing.assert_array_equal(np.asarray(y2), np.asarray(y2))


def test_routing_stats_host_helper_matches_dispatch():
    """routing_stats (the plan producer's input) replicates the einsum
    keep-accounting exactly, as plain numpy."""
    from repro.models.moe import routing_stats

    E, k = 4, 2
    p = init_moe(KEY, 16, 32, E)
    x = jax.random.normal(jax.random.fold_in(KEY, 9), (2, 32, 16))
    rs = routing_stats(p, x, n_experts=E, top_k=k, capacity_factor=0.5)
    _, _, s = moe_einsum(p, x, n_experts=E, top_k=k, capacity_factor=0.5,
                         dt=DT, with_stats=True)
    np.testing.assert_array_equal(rs["expert_counts"],
                                  np.asarray(s["expert_counts"]))
    np.testing.assert_array_equal(rs["routed_counts"],
                                  np.asarray(s["routed_counts"]))
    assert rs["dropped_tokens"] == int(s["dropped_tokens"])
    assert rs["capacity"] == int(s["capacity"])
    assert isinstance(rs["expert_counts"], np.ndarray)
