"""Chunked (flash-style XLA) attention vs the naive oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (chunked_attention, decode_attention,
                                    init_attention, reference_attention)

KEY = jax.random.PRNGKey(0)


def _qkv(B=2, S=192, H=8, G=4, D=32, dtype=jnp.float32):
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, D), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, G, D), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, G, D), dtype)
    return q, k, v


@pytest.mark.parametrize("kw", [
    dict(), dict(window=64), dict(softcap=30.0), dict(causal=False),
    dict(window=32, softcap=20.0),
])
@pytest.mark.parametrize("chunk", [32, 64, 192])
def test_forward_matches_reference(kw, chunk):
    q, k, v = _qkv()
    ref = reference_attention(q, k, v, **kw)
    out = chunked_attention(q, k, v, kw.get("causal", True),
                            kw.get("window"), kw.get("softcap"), chunk, None)
    np.testing.assert_allclose(np.array(out), np.array(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("kw", [dict(), dict(window=48), dict(softcap=25.0)])
def test_backward_matches_reference(kw):
    q, k, v = _qkv(S=128)

    def f_ref(q, k, v):
        return jnp.sum(reference_attention(q, k, v, **kw) ** 2)

    def f_chk(q, k, v):
        return jnp.sum(chunked_attention(
            q, k, v, kw.get("causal", True), kw.get("window"),
            kw.get("softcap"), 32, None) ** 2)

    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    gc = jax.grad(f_chk, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gr, gc):
        np.testing.assert_allclose(np.array(b), np.array(a),
                                   rtol=3e-3, atol=3e-3)


def test_bf16_inputs():
    q, k, v = _qkv(dtype=jnp.bfloat16)
    out = chunked_attention(q, k, v, True, None, None, 64, None)
    ref = reference_attention(q, k, v)
    np.testing.assert_allclose(np.array(out, np.float32),
                               np.array(ref, np.float32), rtol=3e-2,
                               atol=3e-2)


def test_decode_matches_full_attention():
    """One decode step at position p == row p of full causal attention."""
    B, S, H, G, D = 2, 16, 4, 2, 16
    p = init_attention(jax.random.fold_in(KEY, 7), 32, H, G, D)
    x = jax.random.normal(jax.random.fold_in(KEY, 8), (B, S, 32))
    from repro.models.attention import attention
    full = attention(p, x, n_heads=H, n_kv_heads=G, head_dim=D, rope=None,
                     causal=True, use_chunked=False)
    # replay through the cache one token at a time (no rope for parity)
    ck = jnp.zeros((B, S, G, D), jnp.float32)
    cv = jnp.zeros((B, S, G, D), jnp.float32)
    outs = []
    for t in range(S):
        y, ck, cv = decode_attention(
            p, x[:, t:t + 1], ck, cv, jnp.asarray(t, jnp.int32),
            n_heads=H, n_kv_heads=G, head_dim=D, rope_theta=None)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.array(dec, np.float32),
                               np.array(full, np.float32), rtol=2e-2,
                               atol=2e-2)
