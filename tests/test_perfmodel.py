"""Performance model: the paper's §3 inequalities as hypothesis properties."""
import math

import pytest
from _hypothesis_compat import given, settings, st  # optional dep, see shim

from repro.core import perfmodel as pm
from repro.core import revolve as rv


@settings(deadline=None, max_examples=80)
@given(n=st.integers(2, 2000), s=st.integers(2, 64),
       t_a=st.floats(1e-5, 1e-2), t_b_ratio=st.floats(0.5, 4.0),
       t_t_ratio=st.floats(0.01, 50.0))
def test_async_never_slower_than_revolve_at_optimal_interval(
        n, s, t_a, t_b_ratio, t_t_ratio):
    """Paper's headline claim, over a broad hardware/workload space.

    Exact under the paper's §3 formula (T = n·R(I,s)·T_A + n·T_B with
    R(I,s) <= R(n,s)); our ``t_async`` additionally models prefetch stalls
    and the ceil on partial segments, so it gets a per-segment allowance.
    """
    import math
    from repro.core import revolve as rv
    from repro.core import schedule as ms
    t_b = t_a * t_b_ratio
    t_t = t_a * t_t_ratio
    interval = pm.optimal_interval(t_t, t_a)
    t_rev = pm.t_revolve(n, s, t_a, t_b)
    # the paper's formula: exact inequality
    if interval <= n:
        r_paper = ms.multistage_recompute_factor_paper(n, interval, s)
        t_paper = n * r_paper * t_a + n * t_b
        assert r_paper <= rv.recompute_factor(n, s) + 1e-9
        assert t_paper <= t_rev * (1 + 1e-9) + n * t_a * 1e-6
    # the realistic model: bounded by revolve + stall/partial-segment slack
    t_async = pm.t_async(n, interval, s, t_a, t_b, t_t)
    segs = math.ceil(n / max(interval, 1))
    slack = segs * (t_t + interval * t_a + t_b) + n * t_a
    assert t_async <= t_rev * (1 + 1e-9) + slack
    # and never beats the no-memory-limit bound
    assert t_async >= pm.t_inf(n, t_a, t_b) * (1 - 1e-9) - 1e-12


def test_overhead_constant_in_n():
    """T_async/T_inf approaches a constant as n grows (paper §3/Fig 3)."""
    s, t_a, t_b, t_t = 100, 1e-3, 2e-3, 8e-3
    i = pm.optimal_interval(t_t, t_a)
    ratios = [pm.t_async(n, i, s, t_a, t_b, t_t) / pm.t_inf(n, t_a, t_b)
              for n in (10_000, 100_000, 1_000_000)]
    assert max(ratios) - min(ratios) < 0.01
    # Revolve's ratio keeps growing
    rev = [pm.t_revolve(n, s, t_a, t_b) / pm.t_inf(n, t_a, t_b)
           for n in (10_000, 100_000, 1_000_000)]
    assert rev[-1] > rev[0] + 0.1


def test_optimal_interval_law():
    assert pm.optimal_interval(8e-3, 1e-3) == 8
    assert pm.optimal_interval(8.1e-3, 1e-3) == 9
    assert pm.optimal_interval(1e-6, 1e-3) == 1


def test_degenerates_to_revolve_for_short_chains():
    s, t_a, t_b, t_t = 10, 1e-3, 2e-3, 5e-3
    assert pm.t_async(8, 16, s, t_a, t_b, t_t) == \
        pm.t_revolve(8, s, t_a, t_b)


def test_forced_small_interval_stalls():
    """I < ceil(T_T/T_A): stores can't keep up; the model must show it."""
    s, t_a, t_b, t_t = 8, 1e-3, 2e-3, 16e-3
    fast = pm.t_async(256, 16, s, t_a, t_b, t_t)
    stalled = pm.t_async(256, 4, s, t_a, t_b, t_t)
    assert stalled > fast


def test_times_from_roofline():
    hw = pm.TPU_V5E
    st_ = pm.times_from_roofline(
        step_flops=1e12, step_hbm_bytes=1e9, state_bytes=100e6, hw=hw)
    assert st_.t_a == pytest.approx(max(1e12 / hw.peak_flops,
                                        1e9 / hw.hbm_bw))
    assert st_.interval == math.ceil(st_.t_t / st_.t_a)
    assert st_.never_stalls


# ---------------------------------------------------------------------------
# two-tier (capacity-bounded) Level-2 model
# ---------------------------------------------------------------------------


def test_effective_transfer_time_regimes():
    # 8 segments of 100 B: fast while they fit, slow-bound once they don't
    args = dict(n=64, interval=8, state_bytes=100, t_t_fast=1e-3,
                t_t_slow=8e-3)
    assert pm.effective_transfer_time(capacity_bytes=800, **args) == 1e-3
    assert pm.effective_transfer_time(capacity_bytes=799, **args) == 8e-3
    # the write-behind pipeline is bottlenecked by the slower stage
    assert pm.effective_transfer_time(
        n=64, interval=8, state_bytes=100, capacity_bytes=0,
        t_t_fast=9e-3, t_t_slow=8e-3) == 9e-3


def test_choose_tiered_interval():
    # everything fits at the fast optimum: the §3 fast-tier rule applies
    assert pm.choose_tiered_interval(
        n=64, state_bytes=100, capacity_bytes=100 * 64,
        t_a=1e-3, t_t_fast=4e-3, t_t_slow=32e-3) == 4
    # tight budget (4 states): I grows to the cheaper escape — here fitting
    # all boundaries on the fast tier (I=16) beats the slow-tier rate (I=32)
    assert pm.choose_tiered_interval(
        n=64, state_bytes=100, capacity_bytes=100 * 4,
        t_a=1e-3, t_t_fast=4e-3, t_t_slow=32e-3) == 16
    # slow tier keeps up sooner than the boundaries fit: accept the spill
    assert pm.choose_tiered_interval(
        n=64, state_bytes=100, capacity_bytes=100 * 2,
        t_a=1e-3, t_t_fast=4e-3, t_t_slow=8e-3) == 8
    # nothing ever fits (capacity < one state): the slow tier sets I
    assert pm.choose_tiered_interval(
        n=64, state_bytes=100, capacity_bytes=50,
        t_a=1e-3, t_t_fast=4e-3, t_t_slow=8e-3) == 8
    # never below the fast-tier optimum
    assert pm.choose_tiered_interval(
        n=64, state_bytes=100, capacity_bytes=50,
        t_a=1e-3, t_t_fast=8e-3, t_t_slow=1e-3) == 8


def test_t_async_tiered_constant_overhead_when_slow_keeps_up():
    """At I >= ceil(T_T_eff/T_A) the two-tier overhead is constant in n
    even when every boundary spills to the slow tier."""
    kw = dict(interval=8, s=4, t_a=1e-3, t_b=2e-3, t_t_fast=1e-3,
              t_t_slow=8e-3, state_bytes=100, capacity_bytes=100)
    per_step = [pm.t_async_tiered(n, **kw) / n for n in (64, 256, 1024)]
    assert max(per_step) < 1.05 * min(per_step)
    # a forced-small interval pays the slow tier's stall, visibly
    assert pm.t_async_tiered(256, interval=2, s=4, t_a=1e-3, t_b=2e-3,
                             t_t_fast=1e-3, t_t_slow=8e-3, state_bytes=100,
                             capacity_bytes=100) > \
        pm.t_async_tiered(256, **{**kw})


def test_fast_peak_bytes_model():
    assert pm.fast_peak_bytes_model(64, 8, 100, 100 * 64) == 800
    assert pm.fast_peak_bytes_model(64, 8, 100, 100 * 3) == 300
    assert pm.fast_peak_bytes_model(64, 8, 100, 50) == 0
    assert pm.fast_tier_slots(350, 100) == 3
    with pytest.raises(ValueError):
        pm.fast_tier_slots(100, 0)


def test_tier_plan_annotations():
    from repro.core.schedule import segment_plan

    plan = segment_plan(n=64, interval=8, s_l1=4)       # 8 segments
    assert plan.reverse_access_order() == tuple(range(56, -1, -8))
    tp = plan.tier_plan(capacity_bytes=3 * 100, state_bytes=100)
    assert tp.fast_slots == 3 and tp.spilled == 5
    # the 3 largest begins are resident when their reverse turn comes
    assert tp.resident == (False,) * 5 + (True,) * 3
    assert tp.prefetch_distance == 2
    # everything fits: plain double-buffering
    tp_all = plan.tier_plan(capacity_bytes=8 * 100, state_bytes=100)
    assert tp_all.spilled == 0 and tp_all.prefetch_distance == 1
    assert all(tp_all.resident)
    # timed distance: one slow fetch spans ~3 segments of reverse work
    tp_t = plan.tier_plan(capacity_bytes=100, state_bytes=100,
                          t_t_slow=3e-3, t_seg_reverse=1.1e-3)
    assert tp_t.prefetch_distance == 3
