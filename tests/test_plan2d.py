"""2D (time x layer) checkpoint plans.

The outer axis is the paper's multistage segmentation; the inner axis
chunks one chain step's own computation (rematted layer sub-ranges chosen
by the Gruslys-style DP, plus a chunked logits/loss head).  Covered here:

* chunked-vs-unchunked loss head gradient parity (bit-identical fp32,
  including a vocab size and sequence length no chunking divides);
* the end-to-end ``step_memory_budget=`` path: a transformer whose 1D
  per-step activations exceed the budget trains through
  ``value_and_grad_offloaded`` with gradients matching plain autodiff,
  ``last_plan()`` reporting both axes and the executor's inner counters
  matching the perfmodel count-exactly;
* infeasible budgets raise naming the smallest feasible one;
* ``OffloadConfig`` validation of the 2D knobs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _helpers import max_rel_err, tree_equal
from repro import api
from repro.api.chain import chain_length, index_xs
from repro.configs import SMOKE_SHAPE, get_config
from repro.configs.shapes import make_batch
from repro.core import perfmodel as pm
from repro.core.storage import tree_bytes
from repro.models import get_model
from repro.models.layers import chunked_ce_loss

KEY = jax.random.PRNGKey(0)


def _grads_bit_identical(g, ref) -> bool:
    return tree_equal(g, ref)


# ---------------------------------------------------------------------------
# chunked loss head: gradient parity
# ---------------------------------------------------------------------------


def test_chunked_ce_bit_identical_fp32_nondividing_vocab():
    """fp32 CE gradients are bit-identical across head chunkings — chunking
    splits the sequence, never a position's own logits row — including a
    prime vocab (97) and a prime sequence length (31) nothing divides."""
    B, S, D, V = 2, 31, 16, 97
    h = jax.random.normal(KEY, (B, S, D), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (V, D), jnp.float32)
    labels = jax.random.randint(jax.random.fold_in(KEY, 2), (B, S), 0, V)

    ref_v, ref_g = jax.value_and_grad(
        lambda hh: chunked_ce_loss(hh, w, labels, chunk=S))(h)
    for chunk in (31, 7, 5, 4, 1):
        v, g = jax.value_and_grad(
            lambda hh: chunked_ce_loss(hh, w, labels, chunk=chunk))(h)
        assert _grads_bit_identical(g, ref_g), f"chunk={chunk}"
        # the mean is a sum whose association order depends on the
        # chunking; the per-position terms themselves are bit-identical
        assert abs(float(v) - float(ref_v)) <= 1e-6


def test_whisper_tiny_chunked_head_parity():
    """The whisper-tiny decoder's real logits/CE head: chunked vs unchunked
    per-position gradients (w.r.t. the decoder output) are bit-identical at
    fp32 for every chunking, dividing or not — chunking splits the
    sequence, never a position's own logits row.  The tied-embedding
    gradient is a reduction *over* positions, so only its association
    order changes: allclose at fp32."""
    from repro.models import encdec

    cfg = get_config("whisper-tiny", smoke=True)
    m = get_model(cfg)
    params = m.init(jax.random.fold_in(KEY, 3))
    batch = make_batch(cfg, SMOKE_SHAPE)
    tokens = batch["tokens"]
    labels = tokens[:, 1:]
    S = int(labels.shape[1])

    # the decoder hidden states the head consumes (forward only)
    dt = encdec._dtypes(cfg)
    enc = encdec.encode(params, batch["frames"], cfg)
    from repro.models.layers import embed, rmsnorm, rope_table

    x = embed(params["embed"], tokens[:, :-1], dt)
    rope = rope_table(S, cfg.hd, cfg.rope_theta)
    for j in range(cfg.n_layers):
        lp = jax.tree_util.tree_map(lambda a, j=j: a[j],
                                    params["dec_layers"])
        x = encdec._dec_layer_seq(lp, x, enc, rope, cfg, dt)
    h = rmsnorm(params["final_norm"], x, dt=dt).astype(jnp.float32)
    w = params["embed"]["emb"].astype(jnp.float32)

    def head(hh, ww, chunk):
        return chunked_ce_loss(hh, ww, labels, chunk=chunk)

    ref_v, (ref_gh, ref_gw) = jax.value_and_grad(
        head, argnums=(0, 1))(h, w, S)
    for chunk in (S, 7, 3):   # S = 31 at smoke shapes: nothing divides it
        v, (gh, gw) = jax.value_and_grad(head, argnums=(0, 1))(h, w, chunk)
        assert _grads_bit_identical(gh, ref_gh), f"chunk={chunk}"
        np.testing.assert_allclose(np.asarray(gw), np.asarray(ref_gw),
                                   rtol=1e-5, atol=1e-7)
        assert abs(float(v) - float(ref_v)) <= 1e-6


def test_gemma2_chunked_readout_grad_parity():
    """gemma2-2b's ChainSpec.readout_chunked: equal to readout at
    head_chunks=1, gradients bit-identical for every head_chunks
    (3 and 5 do not divide the smoke sequence length 31)."""
    cfg = get_config("gemma2-2b", smoke=True)
    m = get_model(cfg)
    spec = m.train_chain
    assert spec.supports_2d and spec.readout_chunked is not None
    params = m.init(jax.random.fold_in(KEY, 4))
    batch = make_batch(cfg, SMOKE_SHAPE)
    carry0, xs = spec.prelude(params, batch)
    c = carry0
    for k in range(chain_length(xs)):
        c = spec.body(params, c, index_xs(xs, k), batch)

    # contract: readout_chunked == readout at head_chunks == 1 (compare
    # eager-to-eager — tracing under vjp fuses the bf16 forward differently)
    assert float(spec.readout_chunked(params, c, batch, 1)) == \
        float(spec.readout(params, c, batch))
    ref_v, ref_g = jax.value_and_grad(
        lambda cc: spec.readout(params, cc, batch))(c)
    for hc in (1, 3, 5):
        v, g = jax.value_and_grad(
            lambda cc: spec.readout_chunked(params, cc, batch, hc))(c)
        assert _grads_bit_identical(g, ref_g), f"head_chunks={hc}"
        assert abs(float(v) - float(ref_v)) <= 1e-4


# ---------------------------------------------------------------------------
# end-to-end: budget-driven 2D plans through value_and_grad_offloaded
# ---------------------------------------------------------------------------


def _byte_profile(spec, params, batch):
    from repro.analysis.jaxpr_cost import chain_step_byte_profile

    carry0, xs = spec.prelude(params, batch)
    return chain_step_byte_profile(spec, params, carry0, index_xs(xs, 0),
                                   batch), (carry0, xs)


def test_budget_forces_2d_plan_grads_match_autodiff():
    """A transformer whose 1D per-step activations exceed the budget trains
    via ``value_and_grad_offloaded(step_memory_budget=...)``: the planner
    goes 2D, gradients match plain autodiff, and the executor's inner
    counters match the perfmodel count-exactly."""
    cfg = get_config("granite-3-2b", smoke=True)
    m = get_model(cfg)
    spec = m.train_chain
    params = m.init(jax.random.fold_in(KEY, 5))
    batch = make_batch(cfg, SMOKE_SHAPE)
    (state_bytes, layer_bytes, head_bytes), (carry0, xs) = \
        _byte_profile(spec, params, batch)
    n = chain_length(xs)

    # below the 1D step bytes (forces 2D), above the smallest feasible
    budget = int(sum(layer_bytes) + head_bytes) - 1
    assert budget > pm.choose_2d_plan(
        n, t_a=1.0, t_t=0.0, s_l1=2, state_bytes=state_bytes,
        layer_bytes=layer_bytes, budget_bytes=budget,
        head_bytes=head_bytes, interval=1).min_budget_bytes

    ref_v, ref_g = jax.value_and_grad(m.train_loss)(params, batch)
    vg = api.value_and_grad_offloaded(m.train_loss, interval=2, slots=2,
                                      step_memory_budget=budget)
    v, g = vg(params, batch)
    assert abs(float(v) - float(ref_v)) <= 1e-6
    assert max_rel_err(g, ref_g) <= 1e-6

    plan = api.last_plan()
    inner = plan.inner
    assert inner is not None
    assert plan.plan_id.endswith(
        f":L={inner.layer_chunks}:H={inner.head_chunks}")

    st = api.last_stats()
    assert st.inner_layer_chunks == inner.layer_chunks
    assert st.inner_head_chunks == inner.head_chunks
    assert st.inner_layers == inner.n_layers
    # count-exact vs the 2D perfmodel
    assert st.inner_recomputed_layers == \
        pm.inner_recomputed_layers_model(n, inner)
    assert st.inner_peak_bytes == \
        int(pm.inner_boundary_bytes_model(inner, tree_bytes(carry0)))
    assert st.inner_recompute_factor == 1.0


def test_pinned_plan_2d_head_chunks():
    """plan_2d=(layer_chunks, head_chunks) pins the inner axis; gradients
    stay close to autodiff (bf16 head reassociation only)."""
    cfg = get_config("granite-3-2b", smoke=True)
    m = get_model(cfg)
    params = m.init(jax.random.fold_in(KEY, 6))
    batch = make_batch(cfg, SMOKE_SHAPE)
    ref_v, ref_g = jax.value_and_grad(m.train_loss)(params, batch)
    vg = api.value_and_grad_offloaded(m.train_loss, interval=2, slots=2,
                                      plan_2d=(1, 3))
    v, g = vg(params, batch)
    assert api.last_plan().plan_id.endswith(":L=1:H=3")
    assert abs(float(v) - float(ref_v)) <= 1e-4
    assert max_rel_err(g, ref_g) <= 1e-2


def test_infeasible_budget_names_smallest_feasible():
    cfg = get_config("granite-3-2b", smoke=True)
    m = get_model(cfg)
    params = m.init(jax.random.fold_in(KEY, 7))
    batch = make_batch(cfg, SMOKE_SHAPE)
    vg = api.value_and_grad_offloaded(m.train_loss, interval=2, slots=2,
                                      step_memory_budget=1000)
    with pytest.raises(ValueError,
                       match=r"smallest feasible budget is \d+ bytes"):
        vg(params, batch)


def test_2d_needs_layer_decomposition():
    spec = api.ChainSpec(
        prelude=lambda p, b: (jnp.float32(0.0), b["xs"]),
        body=lambda p, c, x, b: c + p * jnp.tanh(x),
        readout=lambda p, c, b: c ** 2,
        name="no-2d-chain")
    vg = api.value_and_grad_offloaded(spec, interval=2,
                                      step_memory_budget=100)
    with pytest.raises(ValueError, match="layer decomposition"):
        vg(jnp.float32(0.5), {"xs": jnp.linspace(-1.0, 1.0, 8)})


def test_offload_config_2d_validation():
    with pytest.raises(ValueError, match="positive byte count"):
        api.OffloadConfig(step_memory_budget=0)
    with pytest.raises(ValueError, match="layer_chunks, head_chunks"):
        api.OffloadConfig(plan_2d=(0, 1))
    with pytest.raises(ValueError, match="not both"):
        api.OffloadConfig(step_memory_budget=1, plan_2d=(1, 1))
    with pytest.raises(ValueError, match="compiled engine"):
        api.OffloadConfig(step_memory_budget=1, engine="interpreted")
    with pytest.raises(ValueError, match="runner='compiled'"):
        api.OffloadConfig(step_memory_budget=1, runner="pallas")
    with pytest.raises(ValueError, match="no such sweep"):
        api.OffloadConfig(plan_2d=(1, 2), strategy="revolve")
    # valid configs construct
    api.OffloadConfig(step_memory_budget=1 << 20)
    api.OffloadConfig(plan_2d=(2, 3))


# ---------------------------------------------------------------------------
# planner units: DP, perfmodel, tuner coupling
# ---------------------------------------------------------------------------


def test_gruslys_split_minmax_boundaries():
    from repro.core.schedule import gruslys_split, min_step_budget_bytes

    layer_bytes = (100.0, 10.0, 10.0, 100.0)
    state = 5.0
    # generous budget: one chunk
    p = gruslys_split(layer_bytes, 1000.0, state)
    assert p.layer_chunks == 1 and p.boundaries == (0,)
    # tight: must split around the heavy ends
    p = gruslys_split(layer_bytes, 130.0, state)
    assert p is not None
    worst = max(sum(layer_bytes[lo:hi]) for lo, hi in p.chunk_ranges())
    assert p.layer_chunks * state + worst <= 130.0
    # infeasible: even per-layer chunks overflow
    assert gruslys_split(layer_bytes, 50.0, state) is None
    assert min_step_budget_bytes(layer_bytes, state) <= 130.0


def test_choose_2d_plan_1d_when_it_fits():
    plan = pm.choose_2d_plan(16, t_a=1.0, t_t=2.0, s_l1=4,
                             state_bytes=10.0, layer_bytes=(50.0, 50.0),
                             budget_bytes=500.0, head_bytes=100.0)
    assert not plan.is_2d and plan.feasible
    assert plan.step_peak_bytes == plan.step_bytes_1d == 200.0


def test_choose_2d_plan_chunks_layers_and_head():
    plan = pm.choose_2d_plan(16, t_a=1.0, t_t=2.0, s_l1=4,
                             state_bytes=10.0,
                             layer_bytes=(50.0,) * 8,
                             budget_bytes=150.0, head_bytes=400.0)
    assert plan.is_2d and plan.feasible
    inner = plan.inner
    assert inner.layer_chunks > 1
    assert inner.head_chunks == 3          # ceil(400 / 150)
    assert plan.step_peak_bytes <= 150.0
    assert plan.inner_boundary_bytes == inner.layer_chunks * 10.0
    # recompute: outer factor plus one extra forward of the step
    base = pm.recompute_factor_2d(16, plan.interval, 4, None)
    assert plan.recompute_factor == pytest.approx(base + 16.0 / 15.0)


def test_autotuner_plan_2d_uses_measured_schedule():
    tuner = api.AutoTuner()
    tune = tuner.manual("t2d", n=32, interval=8, slots=4)
    plan = tuner.plan_2d(tune, n=32, state_bytes=8.0,
                         layer_bytes=(64.0, 64.0, 64.0),
                         budget_bytes=120.0)
    assert plan.interval == 8          # the measured outer axis is kept
    assert plan.is_2d and plan.feasible
    plan1d = tuner.plan_2d(tune, n=32, state_bytes=8.0,
                           layer_bytes=(64.0, 64.0, 64.0),
                           budget_bytes=10_000.0)
    assert not plan1d.is_2d


def test_chain_step_byte_profile_shapes_only():
    """The byte profile is computable from tracers (trace-time planning)."""
    cfg = get_config("granite-3-2b", smoke=True)
    m = get_model(cfg)
    spec = m.train_chain
    params = m.init(jax.random.fold_in(KEY, 8))
    batch = make_batch(cfg, SMOKE_SHAPE)
    (state_bytes, layer_bytes, head_bytes), _ = \
        _byte_profile(spec, params, batch)
    assert state_bytes > 0 and head_bytes > 0
    assert len(layer_bytes) == spec.n_layers
    assert all(b > 0 for b in layer_bytes)

    # same numbers when every argument is a tracer
    def probe(p, b):
        carry0, xs = spec.prelude(p, b)
        from repro.analysis.jaxpr_cost import chain_step_byte_profile

        sb, lb, hb = chain_step_byte_profile(spec, p, carry0,
                                             index_xs(xs, 0), b)
        assert (sb, lb, hb) == (state_bytes, layer_bytes, head_bytes)
        return jnp.float32(0.0)

    jax.eval_shape(probe, params, batch)
