"""Multistage schedule: the paper's central claims as executable properties."""
import math

from _hypothesis_compat import given, settings, st  # optional dep, see shim

from repro.core import revolve as rv
from repro.core import schedule as ms


@settings(deadline=None, max_examples=40)
@given(n=st.integers(1, 400), interval=st.integers(1, 64),
       s=st.integers(1, 16))
def test_schedule_accounting(n, interval, s):
    sched = ms.multistage_schedule(n, interval, s)
    assert sched.num_segments == math.ceil(n / interval)
    assert sched.l2_stores() == sched.num_segments
    assert sched.total_advances() == \
        round(ms.multistage_recompute_factor(n, interval, s) * max(n - 1, 1))


def test_paper_claim_constant_overhead_in_n():
    """T_async's recompute factor depends on I, not n (paper §3)."""
    s, interval = 10, 32
    rs = [ms.multistage_recompute_factor(n, interval, s)
          for n in (256, 1024, 4096, 16384)]
    assert max(rs) - min(rs) < 0.02
    # while classic Revolve keeps growing
    rv_rs = [rv.recompute_factor(n, s) for n in (256, 1024, 4096, 16384)]
    assert rv_rs[-1] - rv_rs[0] > 0.5


@settings(deadline=None, max_examples=40)
@given(n=st.integers(2, 600), interval=st.integers(2, 64),
       s=st.integers(2, 32))
def test_paper_claim_async_never_slower_than_revolve(n, interval, s):
    """Paper §3: R(I, s) <= R(n, s) whenever I <= n — exactly true under the
    paper's convention; the physical count adds the initial sweep
    (n/(n-1)) on the multistage side."""
    if interval > n:
        return
    assert ms.multistage_recompute_factor_paper(n, interval, s) <= \
        rv.recompute_factor(n, s) + 1e-9
    assert ms.multistage_recompute_factor(n, interval, s) <= \
        rv.recompute_factor(n, s) + n / (n - 1) + 1e-9


def test_fits_in_memory_needs_no_revolve():
    sched = ms.multistage_schedule(64, 8, s_l1=8)
    assert not sched.segment_schedules  # store-all within every segment


def test_small_l1_triggers_revolve_inside_interval():
    sched = ms.multistage_schedule(64, 16, s_l1=4)
    assert sched.segment_schedules
    for b, seg in sched.segment_schedules.items():
        assert rv.count_advances(seg) == rv.optimal_advances(16, 4)
        assert rv.peak_slots(seg) <= 4


def test_plan_store_events_and_inner_chunk():
    """The planner's engine-facing surface: store events (one per segment
    boundary) and the inner chunk projection of the Revolve sub-plans
    (what the XLA engines execute instead of the action stream)."""
    plan = ms.segment_plan(37, 8, 4)
    assert plan.store_events() == plan.boundaries() == [0, 8, 16, 24, 32]
    # 8 > 4 slots -> chunked at ceil(8/4); the length-5 tail chunks too
    assert plan.inner_chunk(plan.segments[0]) == 2
    assert plan.inner_chunk(plan.segments[-1]) == 2
    # segments that fit in Level 1 replay store-all (no chunking)
    roomy = ms.segment_plan(37, 8, 8)
    assert all(roomy.inner_chunk(s) is None for s in roomy.segments[:-1])
    # chunk_length lives with the planner; both XLA engines consume it
    assert ms.chunk_length(16, 4) == 4
    assert ms.chunk_length(8, 8) is None
    assert ms.chunk_length(1024, 1) is None
