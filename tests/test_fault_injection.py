"""Chaos suite: injected Level-2 storage faults against the journaled
multistage executor and the ``repro.api`` front-end.

The contract under test (the crash-consistency tentpole): for any chain
(n, I, s), Level-2 backend and injected fault, a journaled run either

* completes with gradients **bit-identical** to the fault-free run over
  the same backend, or
* raises a typed :class:`repro.core.faults.StorageFault`;

and after any injected crash, resuming from the journal
(``resume_from=`` / ``api.resume_offloaded``) reproduces the fault-free
gradient exactly, re-executing at most one interval of forward steps
(``ExecutionStats.replayed_advances <= interval``).

Covered fault classes: writer-thread death mid-store, demand-fetch
failure, torn journal record (crash mid-write), and checksum flip (bit
rot).  Example-based tests pin each class deterministically; the
hypothesis property sweeps random (n, I, s, backend, fault) tuples.
"""
import itertools
import os
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from _helpers import tree_equal
from _hypothesis_compat import given, settings, st  # optional dep, see shim

from repro import api
from repro.core import faults
from repro.core.executor import CheckpointExecutor
from repro.core.faults import ChecksumError, FaultPlan, StorageFault
from repro.core.storage import AsyncTransferEngine, make_backend

N, INTERVAL, SLOTS = 14, 4, 3
_UNIQ = itertools.count()


def _is_storage_fault(err: BaseException) -> bool:
    """True if ``err`` is (or wraps) a typed StorageFault.  io_callback
    re-raises host exceptions wrapped in XlaRuntimeError with the original
    type name embedded in the message, so match the chain and the text."""
    seen = set()
    e = err
    while e is not None and id(e) not in seen:
        seen.add(id(e))
        if isinstance(e, StorageFault):
            return True
        e = e.__cause__ or e.__context__
    return any(name in str(err) for name in
               ("StorageFault", "WriterCrashError", "ChecksumError",
                "TornRecordError", "InjectedFault"))


_tree_equal = tree_equal   # the shared bit-identity predicate


# ---------------------------------------------------------------------------
# executor-level harness
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def chain():
    W = jax.random.normal(jax.random.PRNGKey(0), (8, 8)) * 0.5
    x0 = jax.random.normal(jax.random.PRNGKey(1), (4, 8))

    def make(n):
        def step(x, k):
            return jnp.tanh(x @ W + k * 0.01)

        fwd = jax.jit(step, static_argnums=1)

        def bwd(x_k, adj, k):
            if k == n - 1:
                return jax.grad(lambda x: jnp.sum(step(x, k) ** 2))(x_k)
            _, vjp = jax.vjp(lambda x: step(x, k), x_k)
            return vjp(adj)[0]

        return fwd, bwd, x0

    return make


def _backend_kwargs(kind: str, base: str):
    sub = os.path.join(base, f"l2_{next(_UNIQ)}")
    if kind == "disk":
        return {"directory": sub}
    if kind == "tiered":
        return {"directory": sub, "capacity_bytes": 300}  # forces spills
    if kind == "compressed":
        # the chain state is 128 B — drop the threshold so int8
        # quantization genuinely engages and the bit-identical contract
        # is tested under a lossy codec, not raw passthrough
        return {"min_bytes": 64}
    return {}


def _exec_run(chain_make, base, jd, *, n=N, interval=INTERVAL, slots=SLOTS,
              kind="ram", fault_plan=None, resume=False, repair=False):
    """One executor-level journaled gradient; returns (grad, stats)."""
    fwd, bwd, x0 = chain_make(n)
    ctx = faults.inject(fault_plan) if fault_plan is not None else None
    if ctx is not None:
        ctx.__enter__()
    try:
        backend = make_backend(kind, journal=jd, journal_repair=repair,
                               **_backend_kwargs(kind, base))
        rec = backend.recover() if resume else None
        ex = CheckpointExecutor(fwd, bwd)
        eng = AsyncTransferEngine(backend)
        try:
            x_n, run = ex.multistage_forward(
                x0, n, interval=interval, s_l1=slots, engine=eng,
                resume_from=rec)
            g, st = ex.multistage_reverse(run, jnp.zeros_like(x0))
        finally:
            try:
                eng.close()
            except Exception:
                pass
            backend.close()
        return g, st
    finally:
        if ctx is not None:
            ctx.__exit__(None, None, None)


def _chaos_check(chain_make, *, n, interval, slots, kind, fault_plan):
    """The chaos property for one (chain, backend, fault) combination."""
    with tempfile.TemporaryDirectory() as base:
        jd_ok = os.path.join(base, "wal_ok")
        g_ref, _ = _exec_run(chain_make, base, jd_ok, n=n,
                             interval=interval, slots=slots, kind=kind)
        jd = os.path.join(base, "wal")
        try:
            g, _ = _exec_run(chain_make, base, jd, n=n, interval=interval,
                             slots=slots, kind=kind, fault_plan=fault_plan)
            assert _tree_equal(g, g_ref), \
                "faulted run completed with different gradients"
            return "completed"
        except StorageFault:
            pass  # typed — now resume must reproduce the gradient exactly
        try:
            g, st = _exec_run(chain_make, base, jd, n=n, interval=interval,
                              slots=slots, kind=kind, resume=True)
        except ChecksumError:
            g, st = _exec_run(chain_make, base, jd, n=n, interval=interval,
                              slots=slots, kind=kind, resume=True,
                              repair=True)
        assert _tree_equal(g, g_ref), "resume diverged from fault-free run"
        assert st.replayed_advances <= interval, \
            f"resume replayed {st.replayed_advances} > one interval"
        return "resumed"


# ---------------------------------------------------------------------------
# example-based chaos: one deterministic case per fault class
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", [0, 1, 2, 4])
def test_writer_death_resumes_exact(chain, k):
    """Writer thread killed before its k-th store: the run must raise a
    typed fault (the boundary never became durable) and resume must
    reproduce the fault-free gradient with <= one interval replayed."""
    outcome = _chaos_check(chain, n=N, interval=INTERVAL, slots=SLOTS,
                           kind="ram",
                           fault_plan=FaultPlan(kill_writer_at_store=k))
    assert outcome == "resumed"


@pytest.mark.parametrize("j", [0, 1, 3])
def test_demand_fetch_failure_resumes_exact(chain, j):
    """The j-th reverse-sweep fetch raises: typed InjectedFault, then a
    mid-sweep resume that never re-reverses a completed segment."""
    outcome = _chaos_check(chain, n=N, interval=INTERVAL, slots=SLOTS,
                           kind="ram", fault_plan=FaultPlan(fail_get_at=j))
    assert outcome == "resumed"


def test_torn_journal_record_resumes_exact(chain):
    """Crash tearing a STORE record mid-write: the torn tail is discarded
    on reopen (normal crash artifact — no error) and the resume replays
    from the last intact boundary."""
    outcome = _chaos_check(
        chain, n=N, interval=INTERVAL, slots=SLOTS, kind="ram",
        fault_plan=FaultPlan(truncate_journal_at_store=2))
    assert outcome == "resumed"


def test_checksum_flip_in_completed_run_is_compacted_away(chain):
    """A flipped payload byte is silent while the in-process copy serves
    reads: the run completes bit-identically, and the end-of-run
    compaction rewrites the WAL as a clean done-marker epoch — the rotted
    record was dead weight, so a reopen recovers cleanly.  (Rot in a
    *crashed* run's journal, where it matters, is the ChecksumError case
    covered by test_checksum_flip_detected_and_repaired.)"""
    with tempfile.TemporaryDirectory() as base:
        jd_ok = os.path.join(base, "wal_ok")
        g_ref, _ = _exec_run(chain, base, jd_ok)
        jd = os.path.join(base, "wal")
        g, _ = _exec_run(chain, base, jd,
                         fault_plan=FaultPlan(flip_byte_at_store=1))
        assert _tree_equal(g, g_ref)  # inner backend served intact copies
        reopened = make_backend("ram", journal=jd)
        rec = reopened.recover()
        reopened.close()
        assert rec.cursor is not None and rec.cursor.phase == "done"
        assert rec.keys == ()         # compaction dropped the dead records


def test_checksum_flip_detected_and_repaired(chain):
    """flip + crash: reopen raises ChecksumError; repair truncates to the
    last good record and resume reproduces the fault-free gradient."""
    outcome = _chaos_check(
        chain, n=N, interval=INTERVAL, slots=SLOTS, kind="ram",
        fault_plan=FaultPlan(flip_byte_at_store=1, kill_writer_at_store=3))
    assert outcome == "resumed"


@pytest.mark.parametrize("kind", ["disk", "compressed", "tiered"])
def test_writer_death_all_backends(chain, kind):
    """The chaos property holds across the backend zoo: raw payloads in
    the WAL, resume replay from exact records (get_exact), and
    re-hydrated reverse reads round-tripped through the (possibly lossy)
    codec so they match what the crashed run read back."""
    outcome = _chaos_check(chain, n=N, interval=INTERVAL, slots=SLOTS,
                           kind=kind,
                           fault_plan=FaultPlan(kill_writer_at_store=2))
    assert outcome == "resumed"


# ---------------------------------------------------------------------------
# api-level chaos (through custom_vjp + io_callback)
# ---------------------------------------------------------------------------


def _make_bptt(engine, jd=None, resume=False, repair=False):
    def body(p, c, x):
        c = jnp.tanh(c @ p["W"] + x)
        return c, jnp.sum(c ** 2)

    return api.checkpointed_bptt(body, interval=INTERVAL, slots=2,
                                 engine=engine, journal_dir=jd,
                                 resume=resume, journal_repair=repair)


@pytest.fixture(scope="module")
def api_problem():
    T, B, D = 12, 2, 4
    key = jax.random.PRNGKey(0)
    params = {"W": jax.random.normal(key, (D, D)) * 0.3}
    xs = jax.random.normal(jax.random.fold_in(key, 1), (T, B, D)) * 0.1
    return params, jnp.zeros((B, D)), xs


@pytest.mark.parametrize("engine", ["compiled", "interpreted"])
@pytest.mark.parametrize("plan", [
    FaultPlan(kill_writer_at_store=1),
    FaultPlan(fail_get_at=1),
], ids=["writer-death", "fetch-failure"])
def test_api_crash_is_typed_and_resume_is_exact(api_problem, engine, plan):
    params, c0, xs = api_problem
    v_ref, g_ref = _make_bptt(engine)(params, c0, xs)
    with tempfile.TemporaryDirectory() as base:
        jd = os.path.join(base, "wal")
        with pytest.raises(Exception) as ei:
            with faults.inject(plan):
                _make_bptt(engine, jd)(params, c0, xs)
        assert _is_storage_fault(ei.value), \
            f"crash was not a typed StorageFault: {ei.value!r}"
        spec = _make_bptt(engine).chain_spec
        v, g = api.resume_offloaded(spec, params, (c0, xs), journal_dir=jd,
                                    interval=INTERVAL, slots=2,
                                    engine=engine)
        assert float(v) == float(v_ref)
        assert _tree_equal(g, g_ref), "api resume diverged"
        assert api.last_stats().replayed_advances <= INTERVAL


def test_api_journal_is_semantically_invisible(api_problem):
    """journal_dir= must not change a healthy run's results by one bit,
    and resume of a *completed* run just recomputes (still exact)."""
    params, c0, xs = api_problem
    v0, g0 = _make_bptt("compiled")(params, c0, xs)
    with tempfile.TemporaryDirectory() as base:
        jd = os.path.join(base, "wal")
        v1, g1 = _make_bptt("compiled", jd)(params, c0, xs)
        assert float(v0) == float(v1) and _tree_equal(g0, g1)
        spec = _make_bptt("compiled").chain_spec
        v2, g2 = api.resume_offloaded(spec, params, (c0, xs),
                                      journal_dir=jd, interval=INTERVAL,
                                      slots=2)
        assert float(v2) == float(v0) and _tree_equal(g2, g0)


def test_offload_config_validation():
    with pytest.raises(ValueError, match="resume=True needs journal_dir"):
        api.OffloadConfig(resume=True)
    with pytest.raises(ValueError, match="cannot be journaled"):
        api.OffloadConfig(engine="scan", journal_dir="/tmp/x")
    with pytest.raises(ValueError, match="keeps no Level-2 state"):
        api.OffloadConfig(strategy="revolve", journal_dir="/tmp/x")


# ---------------------------------------------------------------------------
# the chaos property, hypothesis-swept (CI installs the extra; marked slow
# so the fast tier's wall time is unaffected)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=20),
    interval=st.integers(min_value=1, max_value=7),
    slots=st.integers(min_value=2, max_value=5),
    kind=st.sampled_from(["ram", "disk", "compressed", "tiered"]),
    fault=st.sampled_from(["kill", "get", "tear", "flip"]),
    at=st.integers(min_value=0, max_value=6),
)
def test_chaos_property(chain, n, interval, slots, kind, fault, at):
    """For random (n, I, s, backend, FaultPlan): bit-identical completion
    or typed StorageFault, and resume always reproduces the fault-free
    gradient with replayed_advances <= I."""
    plan = {
        "kill": FaultPlan(kill_writer_at_store=at),
        "get": FaultPlan(fail_get_at=at),
        "tear": FaultPlan(truncate_journal_at_store=at),
        # a bare flip is silent in-process; pair it with a crash so the
        # damaged journal is actually what recovery reads
        "flip": FaultPlan(flip_byte_at_store=at,
                          kill_writer_at_store=at + 1),
    }[fault]
    _chaos_check(chain, n=n, interval=interval, slots=slots, kind=kind,
                 fault_plan=plan)
