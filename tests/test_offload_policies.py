"""Offload/remat policy regressions — including the two bugs the §Perf
hillclimb surfaced (silent policy-combinator no-op; padded-vocab loss)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.ad_checkpoint import checkpoint_name

from repro.analysis.jaxpr_cost import cost_of_fn
from repro.core import offload as ofl

W1 = jnp.ones((64, 64)) * 0.02
W2 = jnp.ones((64, 64)) * 0.02
X = jnp.ones((8, 64))

requires_host_offload = pytest.mark.skipif(
    not ofl.host_offload_supported(),
    reason="backend does not lower host-offload remat policies (needs TPU)")


def _f(x):
    x = checkpoint_name(x, ofl.LAYER_INPUT)
    h = jnp.tanh(x @ W1)
    return jnp.sum(jnp.tanh(h @ W2) ** 2)


def _grad_flops(policy):
    g = jax.grad(lambda x: jax.checkpoint(_f, policy=policy)(x))
    return cost_of_fn(g, X).flops


def test_all_registered_policies_build_and_run():
    for name in ofl.policy_names():
        if "offload" in name and not ofl.host_offload_supported():
            continue  # host memory-space placement unavailable on this backend
        pol = ofl.make_policy(name)
        g = jax.grad(lambda x: jax.checkpoint(_f, policy=pol)(x))(X)
        assert bool(jnp.all(jnp.isfinite(g))), name


def test_unknown_policy_raises():
    with pytest.raises(ValueError):
        ofl.make_policy("nope")


def test_offload_plus_actually_saves_dots():
    """Regression: name-based offload policies return a truthy RecomputeType
    for unmatched primitives; a naive `if r:` combinator silently never
    consults the second policy (found in §Perf A2)."""
    base = _grad_flops(ofl.make_policy("offload_layer"))
    dots = _grad_flops(ofl.make_policy("offload_layer_save_all_dots"))
    none = _grad_flops(jax.checkpoint_policies.nothing_saveable)
    assert dots < base, "save_all_dots must eliminate the dot replay"
    assert base == pytest.approx(none, rel=1e-6)


@requires_host_offload
def test_offload_policy_places_boundary_on_host():
    pol = ofl.make_policy("offload_layer")
    jaxpr = str(jax.make_jaxpr(
        jax.grad(lambda x: jax.checkpoint(_f, policy=pol)(x)))(X))
    assert "<host>" in jaxpr
    assert "layer_input" in jaxpr


def test_save_layer_keeps_boundary_on_device():
    pol = ofl.make_policy("save_layer")
    jaxpr = str(jax.make_jaxpr(
        jax.grad(lambda x: jax.checkpoint(_f, policy=pol)(x)))(X))
    assert "<host>" not in jaxpr


def test_tag_is_identity():
    tree = {"a": jnp.arange(4.0), "b": (jnp.ones((2, 2)),)}
    out = ofl.tag(tree, "x")
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------- pad vocab
def test_padded_vocab_matches_exact_loss():
    """Padding the embedding table must not change the CE loss (padded
    logits are masked out of the partition function)."""
    from repro.configs import get_config, SMOKE_SHAPE
    from repro.configs.shapes import make_batch
    from repro.models import get_model
    key = jax.random.PRNGKey(0)
    cfg0 = get_config("yi-6b", smoke=True)
    cfg1 = cfg0.replace(pad_vocab_multiple=64)  # 512 -> 512 (already even)
    cfg2 = cfg0.replace(vocab=509, pad_vocab_multiple=16)
    api0, api2 = get_model(cfg0), get_model(cfg2)
    p2 = api2.init(key)
    assert p2["embed"]["emb"].shape[0] == 512
    b = make_batch(cfg2, SMOKE_SHAPE)
    l = api2.train_loss(p2, b)
    assert bool(jnp.isfinite(l)) and float(l) > 0
    # logits sliced back to the logical vocab on the serving path
    from repro.configs.base import ShapeSpec
    bp = make_batch(cfg2, ShapeSpec("s", 16, 2, "prefill"))
    logits, _ = api2.prefill(p2, bp)
    assert logits.shape[-1] == 509


def test_zero3_constraints_are_noop_without_context():
    """The zero3 `constrain` calls must be identity outside a MeshContext
    (models stay runnable on one CPU device)."""
    from repro.models.attention import _project_qkv, init_attention
    from repro.models.layers import DTypes
    p = init_attention(jax.random.PRNGKey(0), 32, 4, 2, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    q, k, v = _project_qkv(p, x, 4, 2, 8, DTypes(compute=jnp.float32))
    assert q.shape == (2, 16, 4, 8) and k.shape == (2, 16, 2, 8)


def test_layer_policy_unknown_name_raises_eagerly():
    """A typo'd policy name must fail at the combinator entry point with a
    ValueError listing the registry, not deep inside a trace."""
    from repro.core import layer_policy as lp

    def layer(p, x):
        return x @ p

    stacked = jnp.ones((3, 8, 8))
    with pytest.raises(ValueError, match="unknown layer policy"):
        lp.remat_layer(layer, policy_name="offload_layre")
    with pytest.raises(ValueError, match="offload_layer"):  # lists registry
        lp.scan_layers(layer, stacked, jnp.ones((4, 8)),
                       policy_name="not-a-policy")
    with pytest.raises(ValueError, match="known policies"):
        lp.scan_layers_collect(lambda p, x: (x @ p, jnp.sum(x)), stacked,
                               jnp.ones((4, 8)), policy_name="bogus")
    # the "none" passthrough still validates nothing else and works
    y = lp.scan_layers(layer, stacked, jnp.ones((4, 8)), policy_name="none")
    assert y.shape == (4, 8)
