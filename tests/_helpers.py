"""Shared test helpers (the tests directory is on sys.path under pytest)."""
import jax
import jax.numpy as jnp
import numpy as np


def tree_equal(a, b) -> bool:
    """Bit-exact equality over two pytrees — the acceptance predicate of
    the crash-consistency suites (a resumed gradient must reproduce the
    fault-free one exactly, not approximately)."""
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


def max_rel_err(g, ref):
    """Elementwise max of |a-b| / (1 + |ref|) over two pytrees.

    Scale-aware so fp32 reassociation (segment-compiled scans sum in a
    different order than per-step replay) does not register as error on
    large-magnitude gradients, while small-magnitude comparisons stay
    effectively absolute."""
    return max(
        float(jnp.max(
            jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))
            / (1.0 + jnp.abs(b.astype(jnp.float32)))))
        for a, b in zip(jax.tree_util.tree_leaves(g),
                        jax.tree_util.tree_leaves(ref)))
