"""Revolve: closed form vs DP, schedule optimality, hypothesis invariants."""
import pytest
from _hypothesis_compat import given, settings, st  # optional dep, see shim

from repro.core import revolve as rv


def test_closed_form_matches_dp():
    for n in range(1, 36):
        for s in range(1, 7):
            assert rv.optimal_advances(n, s) == rv.optimal_advances_dp(n, s)


def test_beta_binomial():
    assert rv.beta(3, 2) == 10
    assert rv.beta(1, 5) == 6
    assert rv.beta(5, 0) == 1


def test_recompute_factor_limits():
    # everything fits -> no recomputation
    assert rv.recompute_factor(50, 100) == pytest.approx(1.0, abs=0.03)
    # the paper's Fig 3 operating point
    assert rv.recompute_factor(1024, 100) == pytest.approx(1.902, abs=0.01)
    # monotone in n (fixed s)
    rs = [rv.recompute_factor(n, 16) for n in (64, 256, 1024, 4096)]
    assert rs == sorted(rs)


@settings(deadline=None, max_examples=60)
@given(n=st.integers(1, 300), s=st.integers(1, 12))
def test_schedule_is_optimal_and_slot_safe(n, s):
    sched = rv.revolve_schedule(n, s)
    assert rv.count_advances(sched) == rv.optimal_advances(n, s)
    assert rv.count_backwards(sched) == n
    assert rv.peak_slots(sched) <= s
    # backward steps must visit n-1 .. 0 exactly in order
    assert list(rv.iter_backward_indices(sched)) == list(range(n - 1, -1, -1))


@settings(deadline=None, max_examples=30)
@given(n=st.integers(2, 200), s=st.integers(1, 10))
def test_optimal_advances_bounds(n, s):
    t = rv.optimal_advances(n, s)
    assert n - 1 <= t <= n * (n - 1) // 2
    # monotone: more memory never hurts
    assert rv.optimal_advances(n, s + 1) <= t


def test_schedule_executes_with_offset():
    sched = rv.revolve_schedule(10, 3, offset=7)
    idxs = list(rv.iter_backward_indices(sched))
    assert idxs == list(range(16, 6, -1))
