"""Reproduction of "Backpropagation for long sequences: beyond memory
constraints with constant overheads" — asynchronous multistage checkpointing
in JAX, from the paper-faithful threaded executor to a drop-in
``value_and_grad_offloaded`` autodiff front-end (``repro.api``)."""

__version__ = "0.1.0"
