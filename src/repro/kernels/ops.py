"""jit'd public wrappers around the Pallas kernels.

``interpret`` resolves automatically: compiled on TPU backends, interpret
mode (Python-evaluated kernel bodies) everywhere else — so the same call
sites work on this CPU container and on a real pod.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import lstm_cell as _lstm
from repro.kernels import ssd_scan as _ssd


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "block_q", "block_k",
    "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: Optional[bool] = None):
    """Multi-head wrapper.  q: (B, Sq, H, D); k, v: (B, Sk, G, D).
    Returns (B, Sq, H, D)."""
    interp = _auto_interpret() if interpret is None else interpret
    B, Sq, H, D = q.shape
    G = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * G, -1, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * G, -1, D)
    of = _fa.flash_attention(qf, kf, vf, causal=causal, window=window,
                             softcap=softcap, scale=scale, block_q=block_q,
                             block_k=block_k, interpret=interp)
    return of.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, b, c, *, chunk: int = 128,
             interpret: Optional[bool] = None):
    """Head-structured wrapper.  x: (B, T, H, P); dt: (B, T, H); A: (H,);
    b, c: (B, T, G, N).  Returns (y (B, T, H, P), h_final (B, H, P, N))."""
    interp = _auto_interpret() if interpret is None else interpret
    B, T, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    la = dt * A[None, None, :]                               # (B, T, H)
    bh = jnp.repeat(b, rep, axis=2)
    ch = jnp.repeat(c, rep, axis=2)
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, T, P)
    laf = la.transpose(0, 2, 1).reshape(B * H, T)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, T)
    bf = bh.transpose(0, 2, 1, 3).reshape(B * H, T, N)
    cf = ch.transpose(0, 2, 1, 3).reshape(B * H, T, N)
    y, h = _ssd.ssd_scan(xf, laf, bf, cf, dtf, chunk=chunk, interpret=interp)
    return (y.reshape(B, H, T, P).transpose(0, 2, 1, 3),
            h.reshape(B, H, P, N))


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def lstm_cell(x, h, c, w, b, *, block_b: int = 128,
              interpret: Optional[bool] = None):
    interp = _auto_interpret() if interpret is None else interpret
    return _lstm.lstm_cell(x, h, c, w, b, block_b=block_b, interpret=interp)
