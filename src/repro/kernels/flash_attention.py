"""Pallas TPU flash attention (forward) — blocked causal GQA attention with
sliding-window and logit-softcap support.

TPU adaptation of the paper-era GPU flash algorithm: the grid is
``(batch*heads, q_blocks, kv_blocks)`` with the kv axis innermost; running
max / denominator / accumulator live in VMEM scratch that persists across the
kv iterations (TPU grids execute sequentially, so scratch carries state where
a GPU kernel would keep registers).  Block shapes are multiples of 128 to
align with the MXU; out-of-causal-range and out-of-window kv blocks are
skipped entirely with ``pl.when`` (real FLOP savings, unlike a masked XLA
einsum).

VMEM budget per step: q/k/v/o blocks + (block_q x block_k) scores
= (3*block_k + 2*block_q) * D * 2B + block_q*block_k*4B; defaults
(block_q=block_k=512, D=128) stay under 2 MB, far inside the 16 MB VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: Optional[int],
            softcap: Optional[float], block_q: int, block_k: int,
            n_kv: int, q_off: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_first = qi * block_q + q_off        # absolute position of first query
    q_last = q_first + block_q - 1
    k_first = kj * block_k
    k_last = k_first + block_k - 1
    run = True
    if causal:
        run = jnp.logical_and(run, k_first <= q_last)
    if window is not None:
        run = jnp.logical_and(run, q_first - k_last < window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale        # (bq, D)
        k = k_ref[0].astype(jnp.float32)                # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)         # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qp = q_first + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kp = k_first + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= kp <= qp
        if window is not None:
            mask &= qp - kp < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None, block_q: int = 512,
                    block_k: int = 512, n_kv_heads: Optional[int] = None,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (BH, Sq, D); k, v: (BG, Sk, D) where BH = B*H, BG = B*G.
    GQA is expressed through the kv index map (no materialised repeat)."""
    BH, Sq, D = q.shape
    BG, Sk, _ = k.shape
    assert BH % BG == 0
    group = BH // BG
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    n_q, n_kv = Sq // block_q, Sk // block_k
    sc = (D ** -0.5) if scale is None else scale
    q_off = Sk - Sq

    kernel = functools.partial(
        _kernel, scale=sc, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, n_kv=n_kv, q_off=q_off)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, qi, kj, g=group: (bh // g, kj, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, qi, kj, g=group: (bh // g, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
