"""Pallas TPU kernel: fused LSTM cell (the paper's per-step hot spot).

One grid step handles one batch block: both gate matmuls, the gate
nonlinearities and the state update run in a single VMEM-resident fusion —
eliminating the 7 intermediate HBM round-trips of the unfused XLA graph.
Weights are kept whole in VMEM (paper-scale LSTMs: (Dx+Dh) x 4Dh fits
easily; e.g. 320x1024 fp32 = 1.3 MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, h_ref, c_ref, w_ref, b_ref, hout_ref, cout_ref, *,
            d_hidden: int):
    x = x_ref[...].astype(jnp.float32)
    h = h_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    bias = b_ref[...].astype(jnp.float32)
    dx = x.shape[-1]
    z = jax.lax.dot(x, w[:dx], preferred_element_type=jnp.float32) \
        + jax.lax.dot(h, w[dx:], preferred_element_type=jnp.float32) + bias
    i = z[:, :d_hidden]
    f = z[:, d_hidden:2 * d_hidden]
    o = z[:, 2 * d_hidden:3 * d_hidden]
    g = z[:, 3 * d_hidden:]
    c_new = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    hout_ref[...] = h_new.astype(hout_ref.dtype)
    cout_ref[...] = c_new.astype(cout_ref.dtype)


def lstm_cell(x: jnp.ndarray, h: jnp.ndarray, c: jnp.ndarray,
              w: jnp.ndarray, b: jnp.ndarray, *, block_b: int = 128,
              interpret: bool = False):
    """x: (B, Dx); h, c: (B, Dh); w: (Dx+Dh, 4Dh); b: (4Dh,).
    Returns (h_new, c_new)."""
    B, Dx = x.shape
    Dh = h.shape[-1]
    block_b = min(block_b, B)
    assert B % block_b == 0
    kernel = functools.partial(_kernel, d_hidden=Dh)
    return pl.pallas_call(
        kernel,
        grid=(B // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, Dx), lambda i: (i, 0)),
            pl.BlockSpec((block_b, Dh), lambda i: (i, 0)),
            pl.BlockSpec((block_b, Dh), lambda i: (i, 0)),
            pl.BlockSpec((Dx + Dh, 4 * Dh), lambda i: (0, 0)),
            pl.BlockSpec((4 * Dh,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, Dh), lambda i: (i, 0)),
            pl.BlockSpec((block_b, Dh), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Dh), h.dtype),
            jax.ShapeDtypeStruct((B, Dh), c.dtype),
        ],
        interpret=interpret,
    )(x, h, c, w, b)
