"""Pallas TPU kernel for the Mamba-2 chunked SSD scan.

One grid step processes one (batch*head, chunk) cell: the L x L intra-chunk
dual form runs as two small MXU matmuls, and the (P x N) running state lives
in VMEM scratch carried across the chunk axis (innermost grid dimension) —
the TPU analogue of the GPU kernel's register-resident state.

Inputs are head-flattened (wrapper in ``ops.py``):
    x  (BH, T, P)   dt-weighted inputs are formed in-kernel
    la (BH, T)      per-step log decay (dt * A, negative)
    b, c (BH, T, N)
    dt (BH, T)
Outputs: y (BH, T, P) and the final state h (BH, P, N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, la_ref, b_ref, c_ref, dt_ref, y_ref, hout_ref, h_ref, *,
            chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)          # (L, P)
    la = la_ref[0].astype(jnp.float32)        # (L,)
    b = b_ref[0].astype(jnp.float32)          # (L, N)
    c = c_ref[0].astype(jnp.float32)          # (L, N)
    dt = dt_ref[0].astype(jnp.float32)        # (L,)
    ca = jnp.cumsum(la)                       # (L,)
    xbar = x * dt[:, None]

    # intra-chunk: y_i += sum_{j<=i} exp(ca_i - ca_j) (c_i . b_j) xbar_j
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = ca[:, None] - ca[None, :]
    seg = jnp.where(li >= lj, seg, -jnp.inf)
    m = cb * jnp.exp(seg)
    y = jax.lax.dot(m, xbar, preferred_element_type=jnp.float32)

    # inter-chunk: y_i += exp(ca_i) * (c_i @ h^T);  h: (P, N)
    h = h_ref[...]
    y += jnp.exp(ca)[:, None] * jax.lax.dot_general(
        c, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    # state update: h' = exp(ca_L) h + sum_j exp(ca_L - ca_j) xbar_j (x) b_j
    w = jnp.exp(ca[-1] - ca)                  # (L,)
    h_new = h * jnp.exp(ca[-1]) + jax.lax.dot_general(
        xbar * w[:, None], b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # (P, N)
    h_ref[...] = h_new
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        hout_ref[0] = h_new.astype(hout_ref.dtype)


def ssd_scan(x: jnp.ndarray, la: jnp.ndarray, b: jnp.ndarray,
             c: jnp.ndarray, dt: jnp.ndarray, *, chunk: int = 128,
             interpret: bool = False):
    """Chunked SSD scan.  Shapes as in the module docstring."""
    BH, T, P = x.shape
    N = b.shape[-1]
    chunk = min(chunk, T)
    assert T % chunk == 0
    nc = T // chunk
    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=nc)
    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, P, N), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, P), x.dtype),
            jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, la, b, c, dt)
