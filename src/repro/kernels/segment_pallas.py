"""Fused Pallas segment kernels — the ``runner="pallas"`` execution path.

The paper's constant-overhead bound rides on the ratio ``T_T / T_A`` between
the Level-2 transfer time of a boundary state and the compute time of one
interval.  The three existing engines pay the store as a *separate* host
event; here the store is fused **into** the segment kernel, so the boundary
copy streams out over DMA while the next chunk computes — on hardware the
effective ``T_T`` the autotuner sees shrinks toward the residual that cannot
be hidden behind compute.

Two kernels, both generic over the ``ChainSpec`` body contract
``body(params, carry, x, batch) -> carry``:

* :func:`fused_advance_segment` — the segment advance as one kernel: the
  chain carry stays in registers while the kernel's chunk loop runs one
  ``lax.scan`` per chunk; each chunk-entry carry is snapshotted into one of
  **two** VMEM slots and ``pltpu.make_async_copy``'d to an ``ANY``-space
  (host-reachable) boundary buffer while the chunk's steps compute.  The
  classic double buffer: chunk ``k``'s copy is only waited on at chunk
  ``k+2``, when its slot is next reused.  ``boundary[0]`` is the
  segment-entry state the executor journals to Level 2.
* :func:`fused_reverse_segment` — Echo-style fused recompute (PAPERS.md
  1805.08899): instead of materialising the segment's interior states to
  Level 1, the kernel first recomputes the chunk-entry boundaries from the
  Level-2 segment boundary, *streaming them out through the same double
  buffer* to an ``ANY``-space spill; the backward chunk loop then walks the
  chunks in reverse — prefetching each entry boundary back in through a
  second double buffer and running one ``jax.vjp`` of the chunk's scan
  (recompute + transpose fused, nothing materialised outside the kernel).

**Bitwise parity.**  The fused reverse reproduces the compiled runner's
gradients bit for bit (asserted in ``tests/test_kernels.py``).  This is a
sharp constraint: XLA does *not* produce bitwise-identical results for an
unrolled step loop vs. ``lax.scan``, nor for a hand-rolled per-step vjp vs.
the scan transpose.  What is stable — empirically, and by construction,
because scans compile their loop bodies as standalone computations — is the
scan itself: a chain of per-chunk ``lax.scan``/``jax.vjp``-of-scan calls
with the same step closure matches the single-scan forms bit for bit.  The
kernels therefore express **all** compute as per-chunk scans with closures
mirroring ``CompiledChainOps``, fold the parameter cotangent across full
chunks from zero in descending order, and add a short tail chunk's
contribution once at the end — the exact association of the compiled
runner's chunk-checkpointed transpose.  Uneven tails are a shorter static
chunk, never a masked pad (``x + 0.0`` is not even bitwise-neutral).

CPU has no Pallas lowering for the DMA path, so :func:`runner_supported`
gates the runner: on non-TPU backends the front-end falls back to the
compiled engine with a one-line warning, while tests/benchmarks opt into
``interpret=True`` (Python-evaluated kernels, same numerics) via
``REPRO_PALLAS_INTERPRET=1``.
"""
from __future__ import annotations

import functools
import os
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "fused_advance_segment",
    "fused_reverse_segment",
    "runner_supported",
    "default_interpret",
]

tree_flatten = jax.tree_util.tree_flatten
tree_unflatten = jax.tree_util.tree_unflatten
tree_map = jax.tree_util.tree_map

_FORCE_INTERPRET_ENV = "REPRO_PALLAS_INTERPRET"


def _force_interpret() -> bool:
    return os.environ.get(_FORCE_INTERPRET_ENV, "").lower() in ("1", "true", "yes")


def runner_supported() -> Tuple[bool, str]:
    """Whether the fused pallas runner can execute on this jax backend.

    Returns ``(ok, reason)``; ``reason`` is the one-line fallback message the
    front-end warns with when ``ok`` is False.
    """
    backend = jax.default_backend()
    if backend == "tpu":
        return True, ""
    if _force_interpret():
        return True, ""
    return False, (
        f"runner='pallas' has no DMA lowering on the '{backend}' backend; "
        f"falling back to the compiled segment runner "
        f"(set {_FORCE_INTERPRET_ENV}=1 to force interpret-mode kernels)")


def default_interpret() -> bool:
    """Interpret-mode resolution: compiled on TPU, interpreted elsewhere."""
    return jax.default_backend() != "tpu"


def _canon(shape) -> Tuple[int, ...]:
    """Pad a leaf shape to >= 2 dims (Pallas TPU refs want 2D+ blocks)."""
    shape = tuple(int(d) for d in shape)
    if len(shape) == 0:
        return (1, 1)
    if len(shape) == 1:
        return (1,) + shape
    return shape


def _full_spec(canon_shape):
    nd = len(canon_shape)
    return pl.BlockSpec(canon_shape, lambda _nd=nd: (0,) * _nd)


@functools.lru_cache(maxsize=32)
def _fused_ops(body, xs_treedef, xs_mask, interpret):
    """Build (and cache) the jitted fused advance/reverse for one chain body.

    Keyed like ``CompiledChainOps``: (body, xs structure, per-leaf inexact
    mask) — plus the interpret flag.  Shapes key ``jax.jit``'s own cache.
    """
    xs_mask = tuple(xs_mask)

    def _combine(xd_leaves, xnd_leaves):
        xd_it, xnd_it = iter(xd_leaves), iter(xnd_leaves)
        leaves = [next(xd_it) if m else next(xnd_it) for m in xs_mask]
        return tree_unflatten(xs_treedef, leaves)

    # -- forward: fused advance + double-buffered boundary store -------------

    @functools.partial(jax.jit, static_argnames=("chunk",))
    def advance(params, carry, xs_seg, batch, *, chunk):
        x_leaves, x_tree = tree_flatten(xs_seg)
        assert x_tree == xs_treedef, "xs structure does not match the chain"
        c_leaves, c_tree = tree_flatten(carry)
        p_leaves, p_tree = tree_flatten(params)
        b_leaves, b_tree = tree_flatten(batch)

        T = int(x_leaves[0].shape[0])
        chunk = min(int(chunk), T)
        # Chunk layout for the forward: [0, chunk, 2*chunk, ..., T], except a
        # length-1 tail merges into the previous chunk — XLA inlines a
        # trip-count-1 scan, and an inlined step is not bitwise-identical to
        # the same step inside a rolled scan (the compiled advance is one
        # long scan, so every fused chunk must stay a rolled scan too).
        bounds = list(range(0, T, chunk)) + [T]
        if len(bounds) > 2 and bounds[-1] - bounds[-2] == 1:
            del bounds[-2]
        nc = len(bounds) - 1

        c_shapes = [tuple(l.shape) for l in c_leaves]
        c_canon = [_canon(s) for s in c_shapes]
        p_shapes = [tuple(l.shape) for l in p_leaves]
        b_shapes = [tuple(l.shape) for l in b_leaves]
        x_step = [tuple(l.shape[1:]) for l in x_leaves]
        x_canon = [_canon(s) for s in x_step]

        xs_in = [l.reshape((T,) + cs) for l, cs in zip(x_leaves, x_canon)]
        p_in = [l.reshape(_canon(s)) for l, s in zip(p_leaves, p_shapes)]
        b_in = [l.reshape(_canon(s)) for l, s in zip(b_leaves, b_shapes)]
        c_in = [l.reshape(cs) for l, cs in zip(c_leaves, c_canon)]
        nX, nP, nB, nC = len(xs_in), len(p_in), len(b_in), len(c_in)

        def kernel(*refs):
            xs_refs = refs[:nX]
            p_refs = refs[nX:nX + nP]
            b_refs = refs[nX + nP:nX + nP + nB]
            c0_refs = refs[nX + nP + nB:nX + nP + nB + nC]
            k = nX + nP + nB + nC
            cout_refs = refs[k:k + nC]
            bnd_refs = refs[k + nC:k + 2 * nC]
            s = k + 2 * nC
            slot_scr = refs[s:s + nC]
            sems = refs[s + nC:s + 2 * nC]

            params_v = tree_unflatten(
                p_tree, [r[...].reshape(sh) for r, sh in zip(p_refs, p_shapes)])
            batch_v = tree_unflatten(
                b_tree, [r[...].reshape(sh) for r, sh in zip(b_refs, b_shapes)])

            def step(c_, x):
                return body(params_v, c_, x, batch_v), None

            carry_v = tree_unflatten(
                c_tree,
                [r[...].reshape(sh) for r, sh in zip(c0_refs, c_shapes)])
            for kk in range(nc):
                slot = kk % 2
                # double buffer: slot kk%2 was last used by chunk kk-2 —
                # wait for that copy to drain before overwriting the slot.
                if kk >= 2:
                    for scr, bnd, sem in zip(slot_scr, bnd_refs, sems):
                        pltpu.make_async_copy(
                            scr.at[slot], bnd.at[kk - 2], sem.at[slot]).wait()
                # snapshot the chunk-ENTRY carry and stream it out while
                # the chunk's steps compute below.
                leaves = tree_flatten(carry_v)[0]
                for scr, v, cs in zip(slot_scr, leaves, c_canon):
                    scr[slot] = v.reshape(cs)
                for scr, bnd, sem in zip(slot_scr, bnd_refs, sems):
                    pltpu.make_async_copy(
                        scr.at[slot], bnd.at[kk], sem.at[slot]).start()
                lo, hi = bounds[kk], bounds[kk + 1]
                xk = tree_unflatten(
                    xs_treedef,
                    [r[lo:hi].reshape((hi - lo,) + sh)
                     for r, sh in zip(xs_refs, x_step)])
                carry_v, _ = lax.scan(step, carry_v, xk)
            # drain the last two in-flight copies
            for scr, bnd, sem in zip(slot_scr, bnd_refs, sems):
                pltpu.make_async_copy(
                    scr.at[(nc - 1) % 2], bnd.at[nc - 1],
                    sem.at[(nc - 1) % 2]).wait()
            if nc >= 2:
                for scr, bnd, sem in zip(slot_scr, bnd_refs, sems):
                    pltpu.make_async_copy(
                        scr.at[(nc - 2) % 2], bnd.at[nc - 2],
                        sem.at[(nc - 2) % 2]).wait()
            out_leaves = tree_flatten(carry_v)[0]
            for dst, v, cs in zip(cout_refs, out_leaves, c_canon):
                dst[...] = v.reshape(cs)

        in_specs = (
            [_full_spec((T,) + cs) for cs in x_canon]
            + [_full_spec(_canon(sh)) for sh in p_shapes]
            + [_full_spec(_canon(sh)) for sh in b_shapes]
            + [_full_spec(cs) for cs in c_canon]
        )
        out_specs = (
            [_full_spec(cs) for cs in c_canon]
            + [pl.BlockSpec(memory_space=pltpu.ANY) for _ in c_canon]
        )
        out_shape = (
            [jax.ShapeDtypeStruct(cs, l.dtype)
             for l, cs in zip(c_leaves, c_canon)]
            + [jax.ShapeDtypeStruct((nc,) + cs, l.dtype)
               for l, cs in zip(c_leaves, c_canon)]
        )
        scratch_shapes = (
            [pltpu.VMEM((2,) + cs, l.dtype)
             for l, cs in zip(c_leaves, c_canon)]
            + [pltpu.SemaphoreType.DMA((2,)) for _ in c_canon]
        )
        outs = pl.pallas_call(
            kernel, in_specs=in_specs, out_specs=out_specs,
            out_shape=out_shape, scratch_shapes=scratch_shapes,
            interpret=interpret,
        )(*xs_in, *p_in, *b_in, *c_in)

        carry_out = tree_unflatten(
            c_tree, [o.reshape(sh) for o, sh in zip(outs[:nC], c_shapes)])
        boundaries = tree_unflatten(
            c_tree,
            [o.reshape((nc,) + sh) for o, sh in zip(outs[nC:], c_shapes)])
        return carry_out, boundaries

    # -- reverse: Echo-style fused recompute + streamed boundaries -----------

    @functools.partial(jax.jit, static_argnames=("chunk",))
    def reverse(params, carry_b, xs_seg, batch, dcarry, *, chunk):
        x_leaves, x_tree = tree_flatten(xs_seg)
        assert x_tree == xs_treedef, "xs structure does not match the chain"
        c_leaves, c_tree = tree_flatten(carry_b)
        p_leaves, p_tree = tree_flatten(params)
        b_leaves, b_tree = tree_flatten(batch)
        dc_leaves = tree_flatten(dcarry)[0]

        T = int(x_leaves[0].shape[0])
        chunk = min(int(chunk), T)
        nc = -(-T // chunk)
        rem = T - (nc - 1) * chunk  # tail chunk length (== chunk if even)

        c_shapes = [tuple(l.shape) for l in c_leaves]
        c_canon = [_canon(s) for s in c_shapes]
        p_shapes = [tuple(l.shape) for l in p_leaves]
        p_canon = [_canon(s) for s in p_shapes]
        b_shapes = [tuple(l.shape) for l in b_leaves]
        x_step = [tuple(l.shape[1:]) for l in x_leaves]
        x_canon = [_canon(s) for s in x_step]
        diff_idx = [i for i, m in enumerate(xs_mask) if m]
        d_step = [x_step[i] for i in diff_idx]
        d_canon = [x_canon[i] for i in diff_idx]

        xs_in = [l.reshape((T,) + cs) for l, cs in zip(x_leaves, x_canon)]
        p_in = [l.reshape(cs) for l, cs in zip(p_leaves, p_canon)]
        b_in = [l.reshape(_canon(sh)) for l, sh in zip(b_leaves, b_shapes)]
        cb_in = [l.reshape(cs) for l, cs in zip(c_leaves, c_canon)]
        dc_in = [l.reshape(cs) for l, cs in zip(dc_leaves, c_canon)]
        nX, nP, nB, nC = len(xs_in), len(p_in), len(b_in), len(cb_in)
        nD = len(diff_idx)

        def kernel(*refs):
            xs_refs = refs[:nX]
            p_refs = refs[nX:nX + nP]
            b_refs = refs[nX + nP:nX + nP + nB]
            cb_refs = refs[nX + nP + nB:nX + nP + nB + nC]
            dc_refs = refs[nX + nP + nB + nC:nX + nP + nB + 2 * nC]
            k = nX + nP + nB + 2 * nC
            dcout_refs = refs[k:k + nC]
            gout_refs = refs[k + nC:k + nC + nP]
            dxd_refs = refs[k + nC + nP:k + nC + nP + nD]
            bnd_refs = refs[k + nC + nP + nD:k + 2 * nC + nP + nD]
            s = k + 2 * nC + nP + nD
            out_slot = refs[s:s + nC]
            in_slot = refs[s + nC:s + 2 * nC]
            sem_out = refs[s + 2 * nC:s + 3 * nC]
            sem_in = refs[s + 3 * nC:s + 4 * nC]

            params_v = tree_unflatten(
                p_tree, [r[...].reshape(sh) for r, sh in zip(p_refs, p_shapes)])
            batch_v = tree_unflatten(
                b_tree, [r[...].reshape(sh) for r, sh in zip(b_refs, b_shapes)])

            def read_xk(lo, hi):
                return [r[lo:hi].reshape((hi - lo,) + sh)
                        for r, sh in zip(xs_refs, x_step)]

            def fwd_step(c_, x):
                return body(params_v, c_, x, batch_v), None

            # Phase A: recompute every chunk-entry boundary from the Level-2
            # segment boundary, streaming each one out through the double
            # buffer while the next chunk computes — the forward kernel's
            # store pattern, reused for the spill.
            carry_v = tree_unflatten(
                c_tree,
                [r[...].reshape(sh) for r, sh in zip(cb_refs, c_shapes)])
            for kk in range(nc):
                slot = kk % 2
                if kk >= 2:
                    for scr, bnd, sem in zip(out_slot, bnd_refs, sem_out):
                        pltpu.make_async_copy(
                            scr.at[slot], bnd.at[kk - 2], sem.at[slot]).wait()
                leaves = tree_flatten(carry_v)[0]
                for scr, v, cs in zip(out_slot, leaves, c_canon):
                    scr[slot] = v.reshape(cs)
                for scr, bnd, sem in zip(out_slot, bnd_refs, sem_out):
                    pltpu.make_async_copy(
                        scr.at[slot], bnd.at[kk], sem.at[slot]).start()
                if kk < nc - 1:
                    # the last chunk's interior is never a boundary — phase A
                    # stops (nc-1)*chunk steps in; its vjp recomputes it.
                    xk = tree_unflatten(
                        xs_treedef, read_xk(kk * chunk, (kk + 1) * chunk))
                    carry_v, _ = lax.scan(fwd_step, carry_v, xk)
            for scr, bnd, sem in zip(out_slot, bnd_refs, sem_out):
                pltpu.make_async_copy(
                    scr.at[(nc - 1) % 2], bnd.at[nc - 1],
                    sem.at[(nc - 1) % 2]).wait()
            if nc >= 2:
                for scr, bnd, sem in zip(out_slot, bnd_refs, sem_out):
                    pltpu.make_async_copy(
                        scr.at[(nc - 2) % 2], bnd.at[nc - 2],
                        sem.at[(nc - 2) % 2]).wait()

            # Backward chunk loop: prefetch each chunk's entry boundary back
            # in through the second double buffer, then fuse recompute +
            # transpose as one vjp of the chunk's scan.
            for scr, bnd, sem in zip(in_slot, bnd_refs, sem_in):
                pltpu.make_async_copy(
                    bnd.at[nc - 1], scr.at[(nc - 1) % 2],
                    sem.at[(nc - 1) % 2]).start()
            if nc >= 2:
                for scr, bnd, sem in zip(in_slot, bnd_refs, sem_in):
                    pltpu.make_async_copy(
                        bnd.at[nc - 2], scr.at[(nc - 2) % 2],
                        sem.at[(nc - 2) % 2]).start()

            dc_v = tree_unflatten(
                c_tree,
                [r[...].reshape(sh) for r, sh in zip(dc_refs, c_shapes)])
            gacc_v = tree_map(jnp.zeros_like, params_v)
            dp_tail = None
            for kk in range(nc - 1, -1, -1):
                slot = kk % 2
                for scr, bnd, sem in zip(in_slot, bnd_refs, sem_in):
                    pltpu.make_async_copy(
                        bnd.at[kk], scr.at[slot], sem.at[slot]).wait()
                entry = tree_unflatten(
                    c_tree,
                    [r[slot].reshape(sh) for r, sh in zip(in_slot, c_shapes)])
                if kk >= 2:
                    # slot consumed — prefetch the boundary it serves next
                    # while this chunk's vjp recomputes and transposes.
                    for scr, bnd, sem in zip(in_slot, bnd_refs, sem_in):
                        pltpu.make_async_copy(
                            bnd.at[kk - 2], scr.at[slot], sem.at[slot]).start()
                lo, hi = kk * chunk, min((kk + 1) * chunk, T)
                x_all = read_xk(lo, hi)
                xd_k = [x_all[i] for i in diff_idx]
                xnd_k = [x_all[i] for i, m in enumerate(xs_mask) if not m]

                def segf(p, c, xd_, _xnd=tuple(xnd_k), _n=hi - lo):
                    def step(c_, x):
                        xd_t, xnd_t = x
                        return (body(p, c_, _combine(xd_t, xnd_t), batch_v),
                                None)

                    c2, _ = lax.scan(step, c, (tuple(xd_), _xnd), length=_n)
                    return c2

                _, vjp = jax.vjp(segf, params_v, entry, list(xd_k))
                dp, dc_v, dxd_k = vjp(dc_v)
                if kk == nc - 1 and rem != chunk:
                    # short tail: keep its contribution out of the running
                    # fold and add it once at the end — the association of
                    # the compiled runner's transpose (bitwise parity).
                    dp_tail = dp
                else:
                    gacc_v = tree_map(jnp.add, gacc_v, dp)
                for dst, v, cs in zip(dxd_refs, dxd_k, d_canon):
                    dst[lo:hi] = v.reshape((hi - lo,) + cs)
            if dp_tail is not None:
                gacc_v = tree_map(jnp.add, gacc_v, dp_tail)

            for dst, v, cs in zip(dcout_refs, tree_flatten(dc_v)[0], c_canon):
                dst[...] = v.reshape(cs)
            for dst, v, cs in zip(gout_refs, tree_flatten(gacc_v)[0], p_canon):
                dst[...] = v.reshape(cs)

        in_specs = (
            [_full_spec((T,) + cs) for cs in x_canon]
            + [_full_spec(cs) for cs in p_canon]
            + [_full_spec(_canon(sh)) for sh in b_shapes]
            + [_full_spec(cs) for cs in c_canon]
            + [_full_spec(cs) for cs in c_canon]
        )
        out_specs = (
            [_full_spec(cs) for cs in c_canon]
            + [_full_spec(cs) for cs in p_canon]
            + [_full_spec((T,) + cs) for cs in d_canon]
            + [pl.BlockSpec(memory_space=pltpu.ANY) for _ in c_canon]
        )
        out_shape = (
            [jax.ShapeDtypeStruct(cs, l.dtype)
             for l, cs in zip(c_leaves, c_canon)]
            + [jax.ShapeDtypeStruct(cs, l.dtype)
               for l, cs in zip(p_leaves, p_canon)]
            + [jax.ShapeDtypeStruct((T,) + cs, x_leaves[i].dtype)
               for i, cs in zip(diff_idx, d_canon)]
            + [jax.ShapeDtypeStruct((nc,) + cs, l.dtype)
               for l, cs in zip(c_leaves, c_canon)]
        )
        scratch_shapes = (
            [pltpu.VMEM((2,) + cs, l.dtype)
             for l, cs in zip(c_leaves, c_canon)]
            + [pltpu.VMEM((2,) + cs, l.dtype)
               for l, cs in zip(c_leaves, c_canon)]
            + [pltpu.SemaphoreType.DMA((2,)) for _ in c_canon]
            + [pltpu.SemaphoreType.DMA((2,)) for _ in c_canon]
        )
        outs = pl.pallas_call(
            kernel, in_specs=in_specs, out_specs=out_specs,
            out_shape=out_shape, scratch_shapes=scratch_shapes,
            interpret=interpret,
        )(*xs_in, *p_in, *b_in, *cb_in, *dc_in)

        dc_out = tree_unflatten(
            c_tree, [o.reshape(sh) for o, sh in zip(outs[:nC], c_shapes)])
        dp_out = tree_unflatten(
            p_tree,
            [o.reshape(sh) for o, sh in zip(outs[nC:nC + nP], p_shapes)])
        dxd = [
            o.reshape((T,) + st)
            for o, st in zip(outs[nC + nP:nC + nP + nD], d_step)
        ]
        return dc_out, dp_out, dxd

    class _Fused:
        pass

    ops = _Fused()
    ops.advance = advance
    ops.reverse = reverse
    return ops


def fused_advance_segment(body, xs_treedef, xs_mask, params, carry, xs_seg,
                          batch, *, chunk: int, interpret: bool):
    """Advance the carry over one segment with the fused forward kernel.

    Returns ``(carry_out, boundaries)`` where ``boundaries`` mirrors the
    carry pytree with a leading ``num_chunks`` axis of chunk-entry states;
    ``boundaries[...][0]`` is the segment-entry state (what the executor
    stores to Level 2), already copied out of the compute buffers by DMA.
    """
    ops = _fused_ops(body, xs_treedef, tuple(xs_mask), bool(interpret))
    return ops.advance(params, carry, xs_seg, batch, chunk=int(chunk))


def fused_reverse_segment(body, xs_treedef, xs_mask, params, carry_b, xs_seg,
                          batch, dcarry, *, chunk: int, interpret: bool):
    """Reverse one segment with Echo-style fused recompute.

    Returns ``(dcarry_at_begin, dparams_for_segment, dxs_diff_leaves)``;
    the caller folds ``dparams_for_segment`` into its gradient accumulator
    (``gacc + dp``, matching ``CompiledChainOps.reverse_segment``).
    """
    ops = _fused_ops(body, xs_treedef, tuple(xs_mask), bool(interpret))
    return ops.reverse(params, carry_b, xs_seg, batch, dcarry,
                       chunk=int(chunk))
