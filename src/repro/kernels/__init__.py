"""Pallas TPU kernels for the compute hot-spots (flash attention, SSD scan,
fused LSTM cell) — ops.py jit wrappers auto-select interpret mode off-TPU;
ref.py holds the pure-jnp oracles the tests assert against."""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
