"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """q: (BH, Sq, D); k, v: (BH, Sk, D) — heads already flattened/repeated."""
    D = q.shape[-1]
    sc = (D ** -0.5) if scale is None else scale
    s = jnp.einsum("bsd,btd->bst", q * sc, k,
                   preferred_element_type=jnp.float32)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    Sq, Sk = q.shape[1], k.shape[1]
    qp = jnp.arange(Sq) + (Sk - Sq)
    kp = jnp.arange(Sk)
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= kp[None, :] <= qp[:, None]
    if window is not None:
        m &= qp[:, None] - kp[None, :] < window
    s = jnp.where(m[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bst,btd->bsd", p, v).astype(q.dtype)


def ssd_scan_ref(x: jnp.ndarray, la: jnp.ndarray, b: jnp.ndarray,
                 c: jnp.ndarray, dt: jnp.ndarray):
    """Sequential SSD oracle, heads flattened.

    x: (BH, T, P) inputs; la: (BH, T) log-decay (dt * A, negative);
    b, c: (BH, T, N); dt: (BH, T) step sizes.
    Returns (y (BH, T, P), h_final (BH, P, N))::

        h_t = exp(la_t) * h_{t-1} + (dt_t * x_t) outer b_t
        y_t = h_t @ c_t
    """
    BH, T, P = x.shape
    N = b.shape[-1]
    xf = x.astype(jnp.float32)

    def step(h, args):
        xt, lat, bt, ct, dtt = args
        h = h * jnp.exp(lat)[:, None, None] + jnp.einsum(
            "bp,bn->bpn", xt * dtt[:, None], bt)
        y = jnp.einsum("bpn,bn->bp", h, ct)
        return h, y

    h0 = jnp.zeros((BH, P, N), jnp.float32)
    hf, ys = jax.lax.scan(
        step, h0,
        (xf.transpose(1, 0, 2), la.astype(jnp.float32).T,
         b.astype(jnp.float32).transpose(1, 0, 2),
         c.astype(jnp.float32).transpose(1, 0, 2),
         dt.astype(jnp.float32).T))
    return ys.transpose(1, 0, 2).astype(x.dtype), hf


def lstm_cell_ref(x: jnp.ndarray, h: jnp.ndarray, c: jnp.ndarray,
                  w: jnp.ndarray, b: jnp.ndarray):
    """x: (B, Dx); h, c: (B, Dh); w: (Dx+Dh, 4Dh); b: (4Dh,)."""
    z = jnp.concatenate([x, h], axis=-1) @ w + b
    i, f, o, g = jnp.split(z, 4, axis=-1)
    c_new = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new
