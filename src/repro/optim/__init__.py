from repro.optim.optimizers import (
    Optimizer, adamw, rmsprop, sgd, clip_by_global_norm, cosine_schedule,
    constant_schedule,
)

__all__ = ["Optimizer", "adamw", "rmsprop", "sgd", "clip_by_global_norm",
           "cosine_schedule", "constant_schedule"]
