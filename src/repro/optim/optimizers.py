"""Optimizers (pure pytree transforms, optax-style init/update pairs).

``rmsprop`` matches the paper's LSTM experiment (§5: a manual RMSProp);
``adamw`` is the production default for the transformer archs.  Optimizer
state shards exactly like the parameters (same pytree structure), which is
what keeps the 42–52B MoE configs inside per-chip HBM under FSDP.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any
tmap = jax.tree_util.tree_map


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], Any]
    update: Callable[[Params, Any, Params, jnp.ndarray], Tuple[Params, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


def constant_schedule(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Callable:
    def f(step):
        step = step.astype(jnp.float32)
        warm = lr * jnp.minimum(step / max(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac * lr + (1 - final_frac) * lr * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)

    return f


def clip_by_global_norm(grads: Params, max_norm: float) -> Tuple[Params, jnp.ndarray]:
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                      for l in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return tmap(lambda g: g * scale, grads), gn


def adamw(lr: Callable | float, *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          max_grad_norm: Optional[float] = 1.0) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        return {
            "m": tmap(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "v": tmap(lambda p: jnp.zeros_like(p, jnp.float32), params),
        }

    def update(grads, state, params, step):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        g32 = tmap(lambda g: g.astype(jnp.float32), grads)
        m = tmap(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], g32)
        v = tmap(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], g32)
        t = step.astype(jnp.float32) + 1.0
        lr_t = sched(step)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)

        new_params = tmap(upd, params, m, v)
        return new_params, {"m": m, "v": v}

    return Optimizer(init=init, update=update)


def rmsprop(lr: Callable | float = 1e-3, *, decay: float = 0.9,
            eps: float = 1e-8) -> Optimizer:
    """The paper's §5 optimizer (manual RMSProp in its LSTM test case)."""
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        return {"sq": tmap(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, step):
        g32 = tmap(lambda g: g.astype(jnp.float32), grads)
        sq = tmap(lambda s, g: decay * s + (1 - decay) * g * g,
                  state["sq"], g32)
        lr_t = sched(step)
        new_params = tmap(
            lambda p, g, s: (p.astype(jnp.float32) -
                             lr_t * g / (jnp.sqrt(s) + eps)).astype(p.dtype),
            params, g32, sq)
        return new_params, {"sq": sq}

    return Optimizer(init=init, update=update)


def sgd(lr: Callable | float = 1e-2, momentum: float = 0.0) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        if momentum:
            return {"mom": tmap(lambda p: jnp.zeros_like(p, jnp.float32),
                                params)}
        return {}

    def update(grads, state, params, step):
        lr_t = sched(step)
        g32 = tmap(lambda g: g.astype(jnp.float32), grads)
        if momentum:
            mom = tmap(lambda m, g: momentum * m + g, state["mom"], g32)
            new_params = tmap(
                lambda p, m: (p.astype(jnp.float32) - lr_t * m).astype(p.dtype),
                params, mom)
            return new_params, {"mom": mom}
        new_params = tmap(
            lambda p, g: (p.astype(jnp.float32) - lr_t * g).astype(p.dtype),
            params, g32)
        return new_params, state

    return Optimizer(init=init, update=update)
