"""Train / serve step builders.

``make_train_step`` assembles the jitted training step from a ModelAPI +
optimizer, with:

* microbatch gradient accumulation (``lax.scan`` over microbatches — keeps
  the activation working set at 1/k while the paper's offload policy keeps
  the per-microbatch boundaries in host memory);
* optional int8+error-feedback cross-pod gradient reduction
  (``cross_pod="int8_ef"``) via a shard_map-manual pod axis;
* donated state buffers (in-place update on device).

``make_serve_steps`` builds the prefill and decode steps (decode donates the
cache — the KV update is in-place).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import compression as comp
from repro.models.model_factory import ModelAPI
from repro.optim.optimizers import Optimizer

Params = Any
TrainState = Dict[str, Any]  # {"params", "opt", "step", ("ef")}


def init_train_state(api: ModelAPI, optimizer: Optimizer, key,
                     error_feedback: bool = False) -> TrainState:
    params = api.init(key)
    state = {"params": params, "opt": optimizer.init(params),
             "step": jnp.zeros((), jnp.int32)}
    if error_feedback:
        state["ef"] = comp.init_error_feedback(params)
    return state


def _split_microbatches(batch: Dict[str, jnp.ndarray], k: int):
    def rs(x):
        assert x.shape[0] % k == 0, (x.shape, k)
        return x.reshape((k, x.shape[0] // k) + x.shape[1:])

    return jax.tree_util.tree_map(rs, batch)


def make_train_step(api: ModelAPI, optimizer: Optimizer, *,
                    grad_accum: int = 1, cross_pod: str = "auto",
                    mesh: Optional[Mesh] = None,
                    donate: bool = True,
                    strategy: Optional[str] = None,
                    engine: Optional[str] = None,
                    offload_opts: Optional[Dict[str, Any]] = None) -> Callable:
    """Returns ``step_fn(state, batch) -> (state, metrics)`` (un-jitted; the
    launcher jits with in/out shardings).

    ``cross_pod``: "auto" — let GSPMD insert the f32 all-reduce;
    "int8_ef" — shard_map-manual pod axis with compressed reduction
    (requires ``mesh`` with a "pod" axis and ``error_feedback`` state).

    ``strategy``: None — plain ``jax.value_and_grad`` (activation memory set
    by the model's ``remat_policy``); "multistage_async" / "revolve" /
    "conventional" — route the backward pass through
    ``repro.api.value_and_grad_offloaded`` over the model's chain
    decomposition (``api.train_chain``), keeping peak Level-1 activations
    O(interval + slots) regardless of depth/sequence length.

    ``engine`` picks the execution engine behind an offloaded strategy (it
    is merged into ``offload_opts``): the segment-compiled executor
    (``"compiled"``, default — one XLA call per interval, O(n/I) host
    dispatches per train step), the step-granular interpreter
    (``"interpreted"``), or the trace-native plan-driven scan
    (``"scan"`` — the whole step stays one XLA computation, so it is the
    one to use when the step is jitted with sharded in/out specs on a
    device mesh, and the only one that composes with ``grad_accum``).
    All three execute the same ``SegmentPlan``.  Remaining ``offload_opts``
    are forwarded (interval=, slots=, storage=, l2_capacity_bytes=, ...);
    ``storage="compressed"`` int8-quantises Level-2 boundary states on the
    executor engines, and ``storage="tiered"`` + ``l2_capacity_bytes=``
    bounds the Level-2 host-RAM footprint (cold boundaries spill to disk
    in plan-aware order).
    """

    def loss_fn(params, batch):
        return api.train_loss(params, batch)

    if engine is not None:
        offload_opts = dict(offload_opts or {}, engine=engine)

    value_and_grad = jax.value_and_grad(loss_fn)
    if strategy is not None:
        if api.train_chain is None:
            raise ValueError(
                f"model family {api.cfg.family!r} has no chain decomposition;"
                " cannot use an offloaded strategy")
        if grad_accum != 1 and \
                (offload_opts or {}).get("engine") != "scan":
            raise ValueError(
                "grad_accum with an offloaded strategy needs the "
                "trace-native engine='scan' (the executor engines escape "
                "the trace via io_callback and cannot run under the "
                "microbatch lax.scan)")
        from repro.api import value_and_grad_offloaded

        value_and_grad = value_and_grad_offloaded(
            api.train_chain, strategy=strategy, **(offload_opts or {}))

    def grads_of(params, batch):
        if grad_accum == 1:
            return value_and_grad(params, batch)
        micro = _split_microbatches(batch, grad_accum)

        def body(carry, mb):
            loss_acc, g_acc = carry
            loss, g = value_and_grad(params, mb)
            return (loss_acc + loss,
                    jax.tree_util.tree_map(jnp.add, g_acc, g)), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), zeros),
                                        micro)
        scale = 1.0 / grad_accum
        return loss * scale, jax.tree_util.tree_map(
            lambda g: g * scale, grads)

    def apply_update(state, loss, grads):
        new_params, new_opt = optimizer.update(
            grads, state["opt"], state["params"], state["step"])
        out = dict(state, params=new_params, opt=new_opt,
                   step=state["step"] + 1)
        metrics = {"loss": loss.astype(jnp.float32),
                   "grad_norm": jnp.sqrt(sum(
                       jnp.sum(jnp.square(g.astype(jnp.float32)))
                       for g in jax.tree_util.tree_leaves(grads)))}
        return out, metrics

    if cross_pod == "int8_ef":
        if mesh is None or "pod" not in mesh.axis_names:
            raise ValueError("int8_ef needs a mesh with a 'pod' axis")

        def per_pod(state, batch):
            loss, grads = grads_of(state["params"], batch)
            grads, new_ef = comp.compressed_mean(grads, "pod",
                                                 state.get("ef"))
            loss = jax.lax.pmean(loss, "pod")
            new_state, metrics = apply_update(state, loss, grads)
            if "ef" in state:
                new_state["ef"] = new_ef
            return new_state, metrics

        def step_fn(state, batch):
            # partial-manual shard_map: only the pod axis is manual; the
            # data/model axes stay under GSPMD inside the body.
            specs_state = jax.tree_util.tree_map(lambda _: P(), state)
            specs_batch = jax.tree_util.tree_map(
                lambda x: P("pod", *(None,) * (x.ndim - 1)), batch)
            return jax.shard_map(
                per_pod, mesh=mesh,
                in_specs=(specs_state, specs_batch),
                out_specs=(specs_state,
                           jax.tree_util.tree_map(lambda _: P(),
                                                  {"loss": 0, "grad_norm": 0})),
                axis_names={"pod"},
                check_vma=False,
            )(state, batch)

        return step_fn

    def step_fn(state, batch):
        loss, grads = grads_of(state["params"], batch)
        return apply_update(state, loss, grads)

    return step_fn


def make_serve_steps(api: ModelAPI, *, jit: bool = True,
                     donate_cache: bool = True):
    """(prefill_fn, decode_fn) for the serving path.

    ``batch["pos"]`` may be an int32 scalar *or* a ``(B,)`` vector of
    per-request positions — the vector form is what continuous batching
    needs once slots hold different-length sequences.

    ``donate_cache=True`` donates the cache argument to the decode jit (the
    KV update is in-place, halving cache HBM).  It MUST be off whenever a
    retry/preemption boundary is active: a faulted step would leave the
    donated input cache deleted ("Array has been deleted") with no valid
    cache to retry from.  The returned ``decode_fn`` carries a
    ``donates_cache`` attribute so schedulers can assert the wiring.
    """

    def prefill_fn(params, batch):
        return api.prefill(params, batch)

    def decode_fn(params, cache, batch):
        return api.decode(params, cache, batch)

    if jit:
        prefill_fn = jax.jit(prefill_fn)
        decode_fn = jax.jit(
            decode_fn, donate_argnums=(1,) if donate_cache else ())
    decode_fn.donates_cache = jit and donate_cache
    return prefill_fn, decode_fn
