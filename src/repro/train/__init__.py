from repro.train.step import (
    TrainState, init_train_state, make_train_step, make_serve_steps,
)

__all__ = ["TrainState", "init_train_state", "make_train_step",
           "make_serve_steps"]
