"""Plan-aware admission control for the serving scheduler.

The admission predicate is the paper's perfmodel run *before* the job:
:func:`~repro.core.perfmodel.choose_tiered_interval` picks the checkpoint
interval a train job would run at against the tenant's current fast-tier
headroom, :func:`~repro.core.perfmodel.admitted_fast_peak_model` bounds the
fast-tier bytes it will pin (including the journal's extra final state), and
:func:`~repro.core.perfmodel.t_async_tiered` predicts its wall time.  A
request that cannot keep even one boundary on the fast tier, would push its
tenant past quota, or blows its latency budget is rejected — with the
model's numbers in the error, so the caller knows *by how much*.

Everything here is a pure function of the request and a byte/time snapshot:
no storage, no clock, no jax arrays — which is what makes the scheduler unit
tests run on a fake clock in milliseconds.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

from repro.core import perfmodel

KIND_TRAIN = "train"
KIND_DECODE = "decode"


@dataclasses.dataclass(frozen=True)
class LinkTimes:
    """Per-request link/compute times feeding the §3 model (seconds).

    ``t_a``/``t_b``: per-step forward/backward compute (for decode requests
    ``t_a`` is the per-token decode step and ``t_b`` is unused);
    ``t_t_fast``/``t_t_slow``: per-boundary-state transfer time of the fast
    and slow tier.  Producers: the autotuner's measured probe on this
    hardware, or :func:`~repro.core.perfmodel.times_from_roofline`.
    """

    t_a: float
    t_b: float = 0.0
    t_t_fast: float = 0.0
    t_t_slow: float = 0.0

    def __post_init__(self):
        if self.t_a <= 0:
            raise ValueError(f"need t_a > 0, got {self.t_a}")
        for name in ("t_b", "t_t_fast", "t_t_slow"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0")


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One admission-control unit: a fine-tune gradient step or a decode
    session.  ``fast_bytes_needed``/``state_bytes`` are what the perfmodel
    sizes the fast tier from; producers use :func:`chain_dims` /
    :func:`repro.models.cache.decode_cache_bytes` so the numbers come from
    ``jax.eval_shape``, not guesses."""

    rid: str
    tenant: str
    kind: str                        # KIND_TRAIN | KIND_DECODE
    priority: int = 0                # higher preempts lower
    latency_budget_s: Optional[float] = None
    times: Optional[LinkTimes] = None
    # train: n chain steps, bytes of one boundary state
    n: int = 0
    state_bytes: int = 0
    # decode: batch slots, generation horizon, parked-session footprint
    batch: int = 0
    max_len: int = 0
    decode_steps: int = 0
    park_bytes: int = 0

    def __post_init__(self):
        if self.kind not in (KIND_TRAIN, KIND_DECODE):
            raise ValueError(f"unknown request kind {self.kind!r}")
        if self.kind == KIND_TRAIN and (self.n <= 0 or self.state_bytes <= 0):
            raise ValueError(
                f"train request {self.rid!r} needs n > 0 and state_bytes > 0"
                f" (got n={self.n}, state_bytes={self.state_bytes})")
        if self.kind == KIND_DECODE and self.park_bytes <= 0:
            raise ValueError(
                f"decode request {self.rid!r} needs park_bytes > 0")


def train_request(rid: str, tenant: str, *, n: int, state_bytes: int,
                  times: LinkTimes, priority: int = 0,
                  latency_budget_s: Optional[float] = None) -> ServeRequest:
    return ServeRequest(rid=rid, tenant=tenant, kind=KIND_TRAIN,
                        priority=priority, latency_budget_s=latency_budget_s,
                        times=times, n=n, state_bytes=int(state_bytes))


def decode_request(rid: str, tenant: str, *, batch: int, max_len: int,
                   decode_steps: int, park_bytes: int,
                   times: Optional[LinkTimes] = None, priority: int = 0,
                   latency_budget_s: Optional[float] = None) -> ServeRequest:
    return ServeRequest(rid=rid, tenant=tenant, kind=KIND_DECODE,
                        priority=priority, latency_budget_s=latency_budget_s,
                        times=times, batch=batch, max_len=max_len,
                        decode_steps=decode_steps,
                        park_bytes=int(park_bytes))


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    """What the perfmodel said at admission time.  ``predicted_fast_peak``
    is the contract the benchmark audits: the request's *measured* per-
    namespace fast-tier peak must come in at or under it."""

    rid: str
    admitted: bool
    reason: str
    interval: int = 0
    predicted_fast_peak: int = 0
    predicted_seconds: float = 0.0
    headroom_bytes: int = 0


class AdmissionRejected(RuntimeError):
    """Admission refused — the message carries the model's numbers."""

    def __init__(self, decision: AdmissionDecision):
        self.decision = decision
        super().__init__(
            f"request {decision.rid!r} rejected: {decision.reason} "
            f"(predicted_fast_peak={decision.predicted_fast_peak}B, "
            f"headroom={decision.headroom_bytes}B, "
            f"predicted_seconds={decision.predicted_seconds:.3g})")


def admission_check(req: ServeRequest, *, capacity_bytes: int,
                    quota_bytes: int, tenant_fast_bytes: int
                    ) -> AdmissionDecision:
    """The admission predicate: run the perfmodel against the tenant's
    *current* headroom and decide.

    ``capacity_bytes``: the shared tier's global fast budget;
    ``quota_bytes``: the tenant's quota; ``tenant_fast_bytes``: the
    tenant's fast-tier bytes right now.  Headroom is the min of what the
    quota and the global budget still allow — admission is conservative:
    it sizes the plan as if the request only ever gets the headroom it
    sees now (more may become free later; less cannot be taken from
    other tenants, the quota eviction rule guarantees it).
    """
    headroom = min(int(capacity_bytes),
                   int(quota_bytes) - int(tenant_fast_bytes))
    if req.kind == KIND_DECODE:
        return _check_decode(req, headroom)
    return _check_train(req, headroom)


def _reject(req: ServeRequest, reason: str, *, headroom: int,
            peak: int = 0, seconds: float = 0.0,
            interval: int = 0) -> AdmissionDecision:
    return AdmissionDecision(rid=req.rid, admitted=False, reason=reason,
                             interval=interval, predicted_fast_peak=peak,
                             predicted_seconds=seconds,
                             headroom_bytes=headroom)


def _check_decode(req: ServeRequest, headroom: int) -> AdmissionDecision:
    need = req.park_bytes
    if need > headroom:
        return _reject(
            req, f"parked session footprint {need}B exceeds tenant fast-"
            f"tier headroom {headroom}B", headroom=headroom, peak=need)
    seconds = 0.0
    if req.times is not None:
        seconds = req.decode_steps * req.times.t_a
        if req.latency_budget_s is not None and \
                seconds > req.latency_budget_s:
            return _reject(
                req, f"predicted decode time {seconds:.3g}s exceeds "
                f"latency budget {req.latency_budget_s:.3g}s",
                headroom=headroom, peak=need, seconds=seconds)
    return AdmissionDecision(rid=req.rid, admitted=True, reason="fits",
                             interval=1, predicted_fast_peak=need,
                             predicted_seconds=seconds,
                             headroom_bytes=headroom)


def _check_train(req: ServeRequest, headroom: int) -> AdmissionDecision:
    t = req.times
    if t is None:
        raise ValueError(f"train request {req.rid!r} needs times=")
    if headroom < req.state_bytes:
        # not even one boundary state can live on the fast tier: every
        # store would bypass to the slow tier and the never-stall pipeline
        # has nothing to overlap — queue/reject rather than thrash
        return _reject(
            req, f"one boundary state ({req.state_bytes}B) exceeds tenant "
            f"fast-tier headroom {headroom}B", headroom=headroom,
            peak=req.state_bytes)
    interval = perfmodel.choose_tiered_interval(
        req.n, req.state_bytes, headroom, t.t_a, t.t_t_fast, t.t_t_slow)
    slots = max(1, math.ceil(math.sqrt(max(interval, 1))))
    # journaled runs pin one extra state (FINAL_STATE_KEY) beyond the
    # ceil(n/I) segment boundaries — extra_states=1 keeps the admission
    # bound honest for preemptible jobs
    peak = perfmodel.admitted_fast_peak_model(
        req.n, interval, req.state_bytes, headroom, extra_states=1)
    seconds = perfmodel.t_async_tiered(
        req.n, interval, slots, t.t_a, t.t_b, t.t_t_fast, t.t_t_slow,
        req.state_bytes, headroom)
    if req.latency_budget_s is not None and seconds > req.latency_budget_s:
        return _reject(
            req, f"predicted step time {seconds:.3g}s at interval "
            f"{interval} exceeds latency budget "
            f"{req.latency_budget_s:.3g}s", headroom=headroom, peak=peak,
            seconds=seconds, interval=interval)
    return AdmissionDecision(rid=req.rid, admitted=True, reason="fits",
                             interval=interval, predicted_fast_peak=peak,
                             predicted_seconds=seconds,
                             headroom_bytes=headroom)


def chain_dims(chain: Any, params: Any, batch: Any) -> Tuple[int, int]:
    """(n_steps, boundary_state_bytes) of a chain via ``jax.eval_shape`` —
    no arrays are materialised, so admission can size a job it has not
    admitted yet."""
    import jax

    from repro.api.chain import chain_length
    from repro.models.cache import cache_nbytes

    spec = getattr(chain, "chain_spec", chain)
    carry, xs = jax.eval_shape(spec.prelude, params, batch)
    return chain_length(xs), cache_nbytes(carry)
