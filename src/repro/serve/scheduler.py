"""Continuous-batching scheduler over one shared capacity-bounded tier.

:class:`ServeScheduler` multiplexes concurrent requests — offloaded
fine-tune steps and decode sessions — onto ONE
:class:`~repro.core.storage.TieredStorage` under per-tenant byte quotas.
Every request passes the plan-aware admission predicate
(:func:`~repro.serve.admission.admission_check`) BEFORE it touches the
tier; requests that can never fit raise
:class:`~repro.serve.admission.AdmissionRejected` with the perfmodel's
numbers, requests that merely lack headroom *right now* queue.  Load
spikes (a queued higher-priority request that cannot admit) preempt the
lowest-priority running job: train jobs die at their next Level-2 store
through the fault machinery and resume bit-identically from their
journal; decode sessions park their slot-pool state into the tier and
unpark later.

The scheduler is single-threaded and cooperatively stepped — every
:meth:`ServeScheduler.step` runs one admission pass, one preemption pass
and one work round.  All timing goes through an injectable ``clock``
callable, so the unit tests drive it with :class:`FakeClock` in
milliseconds of wall time.
"""
from __future__ import annotations

import math
import time
from typing import Any, Dict, List, Optional

from repro.core.storage import NamespacedStorage
from repro.serve import admission as adm
from repro.serve import session as sess


class FakeClock:
    """Deterministic monotonic clock for tests: call it for the time,
    :meth:`advance` it to move time forward."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError("time only moves forward")
        self.now += float(dt)


class _Entry:
    """Internal per-request record: the request, its latest admission
    decision, and (once admitted) its namespace view + live handle."""

    def __init__(self, req: adm.ServeRequest, seq: int, submitted_at: float,
                 build):
        self.req = req
        self.seq = seq
        self.submitted_at = submitted_at
        self.build = build            # (entry, view) -> handle, on admission
        self.decision: Optional[adm.AdmissionDecision] = None
        self.reserved = 0             # fast-tier bytes reserved while running
        self.namespace: Optional[str] = None
        self.view: Optional[NamespacedStorage] = None
        self.handle: Any = None
        self.admitted_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        self.preemptions = 0

    @property
    def rid(self) -> str:
        return self.req.rid

    def sort_key(self):
        # admission order: highest priority first, then FIFO
        return (-self.req.priority, self.seq)


class ServeScheduler:
    """Multi-tenant serving loop over a shared ``TieredStorage``.

    Parameters
    ----------
    tier:
        The shared capacity-bounded store every admitted request lives in
        (quotas via :meth:`add_tenant`).
    clock:
        Monotonic time source; defaults to ``time.monotonic``.  Tests pass
        :class:`FakeClock`.
    journal_root:
        Directory receiving one write-ahead journal per train job
        (``<journal_root>/<rid>``) — required before the first
        :meth:`submit_train`.
    """

    def __init__(self, tier, *, clock=time.monotonic,
                 journal_root: Optional[str] = None):
        self.tier = tier
        self.clock = clock
        self.journal_root = journal_root
        self._seq = 0
        # Admission charges RESERVATIONS, not measured bytes: an admitted
        # job's predicted_fast_peak is debited from its tenant's quota the
        # moment it is admitted and credited back when it completes or is
        # preempted.  Measured bytes lag the plan (a job admitted this
        # round has not touched the tier yet), so charging them would
        # over-admit and then thrash.
        self.reserved: Dict[str, int] = {}
        self.waiting: List[_Entry] = []    # queued + preempted, re-admitted
        self.running: List[_Entry] = []
        self.completed: List[Dict[str, Any]] = []
        self.rejected: List[adm.AdmissionDecision] = []

    # -- tenants --------------------------------------------------------------
    def add_tenant(self, tenant: str, quota_bytes: int) -> None:
        self.tier.set_quota(tenant, quota_bytes)

    # -- submission -----------------------------------------------------------
    def submit_train(self, rid: str, tenant: str, chain, params, batch, *,
                     times: adm.LinkTimes, priority: int = 0,
                     latency_budget_s: Optional[float] = None,
                     engine: str = "compiled") -> adm.AdmissionDecision:
        """Submit one offloaded fine-tune gradient step.  Sizes the chain
        with ``jax.eval_shape``, runs the admission predicate, and either
        starts the job, queues it, or raises :class:`AdmissionRejected`."""
        if self.journal_root is None:
            raise ValueError("scheduler needs journal_root= for train jobs")
        n, state_bytes = adm.chain_dims(chain, params, batch)
        req = adm.train_request(rid, tenant, n=n, state_bytes=state_bytes,
                                times=times, priority=priority,
                                latency_budget_s=latency_budget_s)

        def build(entry: _Entry, view: NamespacedStorage) -> sess.TrainJob:
            interval = entry.decision.interval
            slots = max(1, math.ceil(math.sqrt(max(interval, 1))))
            return sess.TrainJob(
                chain, params, batch, backend=view,
                journal_dir=f"{self.journal_root}/{entry.rid}",
                interval=interval, slots=slots, engine=engine)

        return self._submit(req, build)

    def submit_decode(self, rid: str, tenant: str, api, params, *,
                      prompts, max_len: int, decode_steps: int,
                      times: Optional[adm.LinkTimes] = None,
                      priority: int = 0,
                      latency_budget_s: Optional[float] = None
                      ) -> adm.AdmissionDecision:
        """Submit one decode session (``len(prompts)`` slots).  The parked
        footprint — what preemption would pin on the tier — is sized with
        ``jax.eval_shape`` and charged against the tenant quota up front."""
        batch = len(prompts)
        park = sess.decode_park_bytes(api, batch, max_len)
        req = adm.decode_request(rid, tenant, batch=batch, max_len=max_len,
                                 decode_steps=decode_steps, park_bytes=park,
                                 times=times, priority=priority,
                                 latency_budget_s=latency_budget_s)

        def build(entry: _Entry, view: NamespacedStorage
                  ) -> sess.DecodeSession:
            s = sess.DecodeSession(api, params, batch=batch,
                                   max_len=max_len,
                                   decode_steps=decode_steps, backend=view,
                                   preemptible=True)
            for p in prompts:
                s.add_request(p)
            return s

        return self._submit(req, build)

    def _submit(self, req: adm.ServeRequest, build) -> adm.AdmissionDecision:
        if any(e.rid == req.rid for e in self.waiting + self.running):
            raise ValueError(f"duplicate request id {req.rid!r}")
        # a request the perfmodel rejects even against an EMPTY quota can
        # never run here — fail fast with the numbers instead of queueing
        # it forever
        best_case = adm.admission_check(
            req, capacity_bytes=self.tier.capacity_bytes,
            quota_bytes=self._quota(req.tenant), tenant_fast_bytes=0)
        if not best_case.admitted:
            self.rejected.append(best_case)
            raise adm.AdmissionRejected(best_case)
        entry = _Entry(req, self._seq, self.clock(), build)
        self._seq += 1
        decision = self._try_admit(entry)
        if decision is None:
            self.waiting.append(entry)
            return adm.AdmissionDecision(
                rid=req.rid, admitted=False, reason="queued: no headroom",
                headroom_bytes=self._headroom(req.tenant))
        return decision

    # -- admission ------------------------------------------------------------
    def _quota(self, tenant: str) -> int:
        q = self.tier.quota_of(tenant)
        if q is None:
            raise KeyError(f"unknown tenant {tenant!r}; add_tenant first")
        return q

    def _used(self, tenant: str, *, excluding: Optional[_Entry] = None
              ) -> int:
        """Fast-tier bytes charged to ``tenant`` for admission purposes:
        running jobs' reservations, plus any measured residency NOT covered
        by a running job's namespace (e.g. a parked session's payload that
        has not demoted yet)."""
        covered = sum(self.tier.ns_fast_bytes.get(e.namespace, 0)
                      for e in self.running if e.req.tenant == tenant)
        residual = max(0, self.tier.tenant_fast_bytes.get(tenant, 0)
                       - covered)
        if excluding is not None and excluding.namespace is not None:
            # a re-admitted entry's own residual (its parked payload) must
            # not count against itself
            residual = max(0, residual - self.tier.ns_fast_bytes.get(
                excluding.namespace, 0))
        return self.reserved.get(tenant, 0) + residual

    def _headroom(self, tenant: str) -> int:
        return min(self.tier.capacity_bytes,
                   self._quota(tenant) - self._used(tenant))

    def _reserve(self, entry: _Entry, amount: int) -> None:
        entry.reserved = int(amount)
        t = entry.req.tenant
        self.reserved[t] = self.reserved.get(t, 0) + entry.reserved

    def _release(self, entry: _Entry) -> None:
        if entry.reserved:
            self.reserved[entry.req.tenant] -= entry.reserved
            entry.reserved = 0

    def _try_admit(self, entry: _Entry) -> Optional[adm.AdmissionDecision]:
        """Run the predicate against the tenant's reserved+residual usage;
        on admission, reserve the predicted peak, bind a namespace view and
        build/unpark the handle."""
        req = entry.req
        used = self._used(req.tenant, excluding=entry)
        if entry.decision is not None:
            # re-admission of a preempted job: a resumed train step must
            # replay at its journaled interval, so the ORIGINAL decision
            # stands — just re-check that its footprint still fits
            headroom = min(self.tier.capacity_bytes,
                           self._quota(req.tenant) - used)
            if headroom < entry.decision.predicted_fast_peak:
                return None
            decision = entry.decision
        else:
            decision = adm.admission_check(
                req, capacity_bytes=self.tier.capacity_bytes,
                quota_bytes=self._quota(req.tenant), tenant_fast_bytes=used)
            if not decision.admitted:
                return None
        entry.decision = decision
        entry.admitted_at = self.clock()
        self._reserve(entry, decision.predicted_fast_peak)
        if entry.namespace is None:
            entry.namespace = f"{req.kind}_{req.rid}"
            # cap the namespace at its predicted peak: the admission
            # contract (measured <= predicted) becomes a tier invariant
            self.tier.register_namespace(
                entry.namespace, req.tenant,
                max_fast_bytes=decision.predicted_fast_peak)
            entry.view = NamespacedStorage(self.tier, entry.namespace)
        if entry.handle is None:
            entry.handle = entry.build(entry, entry.view)
        elif isinstance(entry.handle, sess.DecodeSession) and \
                entry.handle.state == sess.PREEMPTED:
            entry.handle.unpark()
        self.running.append(entry)
        return decision

    # -- preemption -----------------------------------------------------------
    def _preempt_for(self, starved: _Entry) -> bool:
        """Pick the lowest-priority same-tenant running job strictly below
        the starved request's priority and preempt it.  (Quota headroom is
        per-tenant, so only a same-tenant victim can unblock admission —
        preempting a neighbour would thrash for nothing.)  Train jobs get
        their writer killed at the next Level-2 store, surfaced by the run
        pass as a ``StorageFault``; decode sessions park their slot-pool
        state into the tier and demote it to the slow tier so it stops
        charging the quota."""
        victims = [e for e in self.running
                   if e.req.tenant == starved.req.tenant
                   and e.req.priority < starved.req.priority
                   and not (isinstance(e.handle, sess.TrainJob)
                            and e.handle.preempt_event.is_set())]
        if not victims:
            return False
        victim = min(victims, key=lambda e: (e.req.priority, -e.seq))
        victim.preemptions += 1
        if isinstance(victim.handle, sess.TrainJob):
            victim.handle.request_preempt()
        else:
            victim.handle.park()
            victim.view.demote()
            self._release(victim)
        return True

    # -- the loop -------------------------------------------------------------
    def step(self) -> Dict[str, List[str]]:
        """One scheduler round: admit, preempt, work.  Returns the rids
        that were admitted / preempted / completed this round."""
        report = {"admitted": [], "preempted": [], "completed": []}

        # 1. admission pass (highest priority first, then FIFO)
        still_waiting: List[_Entry] = []
        for entry in sorted(self.waiting, key=_Entry.sort_key):
            d = self._try_admit(entry)
            if d is None:
                still_waiting.append(entry)
            else:
                report["admitted"].append(entry.rid)
        self.waiting = still_waiting

        # 2. preemption pass: a starved higher-priority request triggers
        # eviction of the cheapest lower-priority running job
        for entry in sorted(self.waiting, key=_Entry.sort_key):
            self._preempt_for(entry)

        # 3. work round
        still_running: List[_Entry] = []
        for entry in self.running:
            if isinstance(entry.handle, sess.TrainJob):
                ok = entry.handle.run_step()
                if ok:
                    self._complete(entry, report)
                else:
                    self._release(entry)
                    report["preempted"].append(entry.rid)
                    self.waiting.append(entry)
            else:
                s: sess.DecodeSession = entry.handle
                if s.state == sess.PREEMPTED:
                    report["preempted"].append(entry.rid)
                    self.waiting.append(entry)
                    continue
                s.step()
                if s.done():
                    self._complete(entry, report)
                else:
                    still_running.append(entry)
        self.running = still_running
        return report

    def _complete(self, entry: _Entry, report) -> None:
        entry.finished_at = self.clock()
        self._release(entry)
        ns = entry.namespace
        measured_peak = self.tier.ns_fast_peak.get(ns, 0)
        record = {
            "rid": entry.rid,
            "tenant": entry.req.tenant,
            "kind": entry.req.kind,
            "priority": entry.req.priority,
            "latency_s": entry.finished_at - entry.submitted_at,
            "preemptions": entry.preemptions,
            "interval": entry.decision.interval,
            "predicted_fast_peak": entry.decision.predicted_fast_peak,
            "measured_fast_peak": measured_peak,
        }
        if isinstance(entry.handle, sess.TrainJob):
            record["result"] = entry.handle.result
        else:
            record["generated"] = list(entry.handle.generated)
            entry.handle.release()
        # release the namespace's tier bytes (train results already live in
        # the caller's hands; the journal keeps its own durable copy)
        if entry.view is not None:
            entry.view.drop()
        self.completed.append(record)
        report["completed"].append(entry.rid)

    # -- introspection --------------------------------------------------------
    def run_until_idle(self, max_steps: int = 1000) -> List[Dict[str, Any]]:
        """Step until every submitted request completed (or ``max_steps``)."""
        for _ in range(max_steps):
            if not self.waiting and not self.running:
                return self.completed
            self.step()
        raise RuntimeError(
            f"scheduler not idle after {max_steps} steps "
            f"(waiting={[e.rid for e in self.waiting]}, "
            f"running={[e.rid for e in self.running]})")
