"""Session handles: one admitted request's runtime state.

:class:`DecodeSession` is a continuous-batching slot pool — per-request
prefill joins a running batch through the model-declared cache spec
(``models.cache.write_slot``), every slot decodes at its OWN position (the
``(B,)`` ``pos`` vector), and the whole session can be *parked* into the
shared tier and resumed bit-identically (preemption for decode).

:class:`TrainJob` wraps one offloaded fine-tune gradient step over the
shared tier: ``value_and_grad_offloaded(..., backend=<namespace view>,
journal_dir=...)`` with the admission decision's interval pinned (no
autotune probes against the shared store).  Preemption reuses the fault
machinery end to end: a preempt request kills the Level-2 writer at its
next store, the run surfaces a typed ``StorageFault``, the namespace's
tier bytes are released, and ``resume_offloaded`` replays from the journal
— gradients bit-identical to the never-preempted run.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults
from repro.models.cache import (cache_nbytes, grow_cache, write_slot)

_SESSION_KEY = "session"

# Session lifecycle states (shared by DecodeSession and TrainJob).
QUEUED = "queued"
RUNNING = "running"
PREEMPTED = "preempted"
DONE = "done"


def _park_payload_struct(api, batch: int, max_len: int):
    cache = jax.eval_shape(lambda: api.init_cache(batch, max_len))
    return {
        "cache": cache,
        "pos": jax.ShapeDtypeStruct((batch,), jnp.int32),
        "tok": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
        "active": jax.ShapeDtypeStruct((batch,), jnp.bool_),
        "key": jax.ShapeDtypeStruct((2,), jnp.uint32),
    }


def decode_park_bytes(api, batch: int, max_len: int) -> int:
    """Exact byte footprint of a parked decode session (cache + per-slot
    cursors) WITHOUT allocating it — this is the number admission charges
    against the tenant quota, and the measured park put can never exceed
    it."""
    return cache_nbytes(_park_payload_struct(api, batch, max_len))


class DecodeSession:
    """A continuous-batching decode group: ``batch`` slots, each holding an
    independent request at its own position.

    ``preemptible=True`` builds the decode step WITHOUT cache donation —
    the scheduler's retry/park path must be able to re-use the last good
    cache after a faulted step (donating it would leave "Array has been
    deleted" behind, the serving twin of the launch/train.py bug PR 5
    fixed).  Non-preemptible sessions keep donation for the in-place KV
    update's memory halving.
    """

    def __init__(self, api, params, *, batch: int, max_len: int,
                 decode_steps: int, backend: Any = None,
                 preemptible: bool = False, temperature: float = 0.0,
                 seed: int = 0):
        from repro.train import make_serve_steps

        if api.prefill is None:
            raise ValueError(f"{api.cfg.name} has no serving path")
        if api.cache_spec is None:
            raise ValueError(
                f"{api.cfg.name} declares no cache spec; the slot pool "
                "cannot grow/join caches without one")
        self.api = api
        self.params = params
        self.batch = int(batch)
        self.max_len = int(max_len)
        self.decode_steps = int(decode_steps)
        self.backend = backend
        self.preemptible = bool(preemptible)
        self.temperature = float(temperature)
        self._key = jax.random.PRNGKey(seed)
        self.prefill_fn, self.decode_fn = make_serve_steps(
            api, donate_cache=not preemptible)
        self.cache = api.init_cache(self.batch, self.max_len)
        self.pos = jnp.zeros((self.batch,), jnp.int32)
        self.tok = jnp.zeros((self.batch, 1), jnp.int32)
        self.active = np.zeros((self.batch,), bool)
        self.steps_done = np.zeros((self.batch,), np.int64)
        self.generated: List[List[int]] = [[] for _ in range(self.batch)]
        self.state = RUNNING

    # -- slot pool ------------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i in range(self.batch) if not self.active[i]]

    def add_request(self, prompt: Any) -> int:
        """Prefill one prompt (1-D int tokens) and join it into a free slot
        of the running batch.  Returns the slot index."""
        slots = self.free_slots()
        if not slots:
            raise RuntimeError("no free slot (batch is full)")
        slot = slots[0]
        prompt = jnp.asarray(prompt, jnp.int32).reshape(1, -1)
        plen = prompt.shape[1]
        if plen >= self.max_len:
            raise ValueError(
                f"prompt length {plen} leaves no room under max_len="
                f"{self.max_len}")
        logits, cache1 = self.prefill_fn(self.params, {"tokens": prompt})
        cache1 = grow_cache(cache1, self.api.cache_spec, self.max_len)
        self.cache = write_slot(self.cache, self.api.cache_spec, cache1,
                                slot)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        self.tok = self.tok.at[slot].set(first[0])
        self.pos = self.pos.at[slot].set(plen)
        self.active[slot] = True
        self.steps_done[slot] = 0
        self.generated[slot] = [int(first[0, 0])]
        return slot

    # -- decode ---------------------------------------------------------------
    def step(self) -> Dict[int, int]:
        """One decode round across all active slots (mixed positions via the
        ``(B,)`` pos vector).  Returns {slot: new_token} for slots still
        active; slots that hit their horizon retire and free up."""
        if self.state != RUNNING:
            raise RuntimeError(f"session is {self.state}, not running")
        if not self.active.any():
            return {}
        logits, self.cache = self.decode_fn(
            self.params, self.cache, {"tokens": self.tok, "pos": self.pos})
        if self.temperature > 0:
            self._key, sub = jax.random.split(self._key)
            nxt = jax.random.categorical(
                sub, logits / self.temperature,
                axis=-1).astype(jnp.int32)[:, None]
        else:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out: Dict[int, int] = {}
        active = jnp.asarray(self.active)
        # inactive slots keep their token/position (their lane computes but
        # writes only to their own frozen pos — harmless by construction)
        self.tok = jnp.where(active[:, None], nxt, self.tok)
        self.pos = self.pos + active.astype(jnp.int32)
        for i in range(self.batch):
            if not self.active[i]:
                continue
            t = int(nxt[i, 0])
            self.generated[i].append(t)
            self.steps_done[i] += 1
            out[i] = t
            if self.steps_done[i] >= self.decode_steps or \
                    int(self.pos[i]) >= self.max_len:
                self.active[i] = False
        return out

    def done(self) -> bool:
        return not self.active.any()

    # -- preemption (park/unpark through the shared tier) ---------------------
    def park(self) -> int:
        """Checkpoint the session into the shared tier and drop the device
        state.  Returns the parked payload's byte size (audited against the
        admission prediction)."""
        if self.backend is None:
            raise RuntimeError("session has no backend to park into")
        if not self.preemptible:
            raise RuntimeError(
                "session was built non-preemptible (donated caches cannot "
                "be parked after a faulted step)")
        payload = {"cache": self.cache, "pos": self.pos, "tok": self.tok,
                   "active": jnp.asarray(self.active), "key": self._key}
        nb = cache_nbytes(jax.eval_shape(lambda: payload))
        self.backend.put(_SESSION_KEY, payload)
        self.cache = None
        self.pos = None
        self.tok = None
        self.state = PREEMPTED
        return nb

    def unpark(self) -> None:
        if self.state != PREEMPTED:
            raise RuntimeError(f"session is {self.state}, not preempted")
        payload = self.backend.get(_SESSION_KEY)
        self.cache = jax.tree_util.tree_map(jnp.asarray, payload["cache"])
        self.pos = jnp.asarray(payload["pos"])
        self.tok = jnp.asarray(payload["tok"])
        self.active = np.asarray(payload["active"]).copy()
        self._key = jnp.asarray(payload["key"])
        self.backend.delete(_SESSION_KEY)
        self.state = RUNNING

    def release(self) -> None:
        """Drop this session's keys from the shared tier (teardown)."""
        drop = getattr(self.backend, "drop", None)
        if drop is not None:
            drop()
        self.state = DONE


class TrainJob:
    """One preemptible offloaded fine-tune gradient step over the shared
    tier.  The admission decision's interval is pinned, so the transform
    never runs autotune probes against the shared store."""

    def __init__(self, chain, params, batch, *, backend: Any,
                 journal_dir: str, interval: int,
                 slots: Optional[int] = None, engine: str = "compiled"):
        self.chain = chain
        self.params = params
        self.batch = batch
        self.backend = backend
        self.journal_dir = journal_dir
        self.opts = dict(backend=backend, journal_dir=journal_dir,
                         interval=int(interval), slots=slots,
                         engine=engine, autotune=False)
        self.preempt_event = threading.Event()
        self.state = QUEUED
        self.result = None           # (loss, grads) when DONE
        self.preemptions = 0

    def request_preempt(self) -> None:
        """Arm preemption: the Level-2 writer dies at its next boundary
        store, which surfaces as a typed StorageFault from the running (or
        next) step — exactly the crash class the journal absorbs."""
        self.preempt_event.set()

    def run_step(self) -> bool:
        """Run (or resume) the gradient step.  Returns True when the step
        completed; False when it was preempted (state == PREEMPTED, tier
        bytes released, journal intact for resume)."""
        from repro.api import resume_offloaded, value_and_grad_offloaded

        self.state = RUNNING
        plan = faults.FaultPlan(preempt_on=self.preempt_event)
        try:
            with faults.inject(plan):
                if self.preemptions:
                    loss, grads = resume_offloaded(
                        self.chain, self.params, self.batch,
                        **self.opts)
                else:
                    vg = value_and_grad_offloaded(self.chain, **self.opts)
                    loss, grads = vg(self.params, self.batch)
        except Exception as err:
            if not faults.is_storage_fault(err):
                raise
            # Preempted (writer death at a boundary store, surfaced as a
            # typed StorageFault — possibly wrapped by io_callback).  The
            # journal keeps every durable segment; release the namespace's
            # tier bytes so the capacity goes to whoever preempted us.
            self.preemptions += 1
            self.preempt_event.clear()
            drop = getattr(self.backend, "drop", None)
            if drop is not None:
                drop()
            self.state = PREEMPTED
            return False
        self.result = (loss, grads)
        self.state = DONE
        return True
