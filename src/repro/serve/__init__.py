"""Multi-tenant continuous-batching serving over shared Level-2 tiers.

The paper's constant-overhead guarantee makes the two-tier perfmodel
*predictive*: admission control can compute a job's fast-tier footprint and
effective overhead before the job runs.  This package turns that into a
scheduler: concurrent long-sequence jobs — offloaded fine-tune steps
(``value_and_grad_offloaded``) and decode sessions alike — share ONE
capacity-bounded :class:`~repro.core.storage.TieredStorage` under per-tenant
byte quotas, with plan-aware admission, journal-backed preemption and
bit-identical resume.
"""
from repro.serve.admission import (AdmissionDecision, AdmissionRejected,
                                   LinkTimes, ServeRequest, admission_check,
                                   chain_dims, decode_request, train_request)
from repro.serve.scheduler import FakeClock, ServeScheduler
from repro.serve.session import (DecodeSession, TrainJob, decode_park_bytes)

__all__ = [
    "AdmissionDecision", "AdmissionRejected", "LinkTimes", "ServeRequest",
    "admission_check", "chain_dims", "decode_request", "train_request",
    "FakeClock", "ServeScheduler",
    "DecodeSession", "TrainJob", "decode_park_bytes",
]
