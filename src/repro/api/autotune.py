"""Schedule auto-tuning from the paper's §3 performance model.

The multistage strategy has two knobs: the Level-2 store interval ``I`` and
the Level-1 Revolve slot count ``s``.  §3 gives the optimum directly:
``I = ceil(T_T / T_A)`` — the smallest interval at which the asynchronous
Level-2 transfers keep up with compute, so the forward pass never stalls and
the recompute factor stays at the constant ``R(I, s)``.

Two ways to obtain ``(T_A, T_T)``:

* **measure** — time the jitted forward step and a Level-2 store of the
  boundary state on the live engine (done on the first call of an offloaded
  gradient function, then cached per ``(model, seq-len, hardware)``); a
  capacity-bounded tiered backend is probed per tier and ``I`` comes from
  the *effective* transfer time (``perfmodel.choose_tiered_interval``);
* **roofline** — derive them from compiled-HLO roofline terms via
  ``repro.core.perfmodel.times_from_roofline`` (the dry-run path; no
  execution needed).

The measured interval is snapped with ``snap_interval`` onto a nearby
divisor of the chain length when one exists — never below the optimum,
which is the *minimum* no-stall interval (even segments mean one
compiled/trace segment variant instead of two — uneven tails are otherwise
first-class in the ``SegmentPlan`` IR), and the result is cached so
subsequent steps pay nothing.  Every engine shares the cache; the engine is
part of the cached name (``"<spec>:compiled"`` / ``":interpreted"`` /
``":scan"``) because each engine's ``T_A``/``T_T`` probes differ.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import offload as ofl
from repro.core.perfmodel import (KNL, TPU_V5E, HardwareSpec, StepTimes,
                                  choose_interval_with_params,
                                  choose_sharded_interval,
                                  choose_tiered_interval,
                                  effective_transfer_time, optimal_interval,
                                  times_from_roofline)
from repro.core.storage import TieredStorage, tree_bytes


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """A chosen schedule plus the measurements behind it."""

    interval: int
    slots: int
    t_a: float            # forward time of one chain step (s)
    t_t: float            # Level-2 transfer time of one boundary state (s)
    state_bytes: int
    n: int
    source: str           # "measured" | "roofline" | "manual"
    # Two-tier (capacity-bounded) Level 2 only: the slow tier's per-state
    # transfer time and the fast-tier budget behind the chosen interval.
    t_t_slow: float = 0.0
    capacity_bytes: Optional[int] = None
    # Sharded Level 2 only (``ShardedStorage`` fan-out): the measured
    # single-stream transfer time of the whole (gathered) state, the
    # number of per-device streams behind the fan-out ``t_t``, and the
    # per-mesh-axis single-stream times ``((axis, T_T), ...)`` — what the
    # transfer would cost if the state were sharded along that axis alone.
    t_t_global: float = 0.0
    shard_streams: int = 0
    t_t_axes: Tuple = ()
    # Parameter streaming (``offload_params=``) only: measured Level-2
    # read-back time of one chain step's streamed parameter blobs (s).
    t_t_param: float = 0.0

    @property
    def never_stalls(self) -> bool:
        """The §3 no-stall predicate: one boundary transfer (``T_T``)
        hides completely behind its interval's compute (``I * T_A``)."""
        return self.t_t <= self.interval * self.t_a


def snap_interval(n: int, target: int) -> int:
    """Snap the §3 optimum onto the chain: prefer a nearby divisor of ``n``
    (even segments — one compiled/trace segment variant instead of two), but
    never *below* the optimum — ``I = ceil(T_T / T_A)`` is the minimum
    no-stall interval, so snapping down re-enters the stall regime the
    tuner exists to avoid.  The smallest divisor of ``n`` in
    ``[target, 2*target]`` wins; with none in range (prime-ish ``n``) the
    target itself is kept and the plan simply ends in a shorter tail
    segment (uneven tails are first-class in the
    :class:`~repro.core.schedule.SegmentPlan` IR)."""
    target = max(1, min(target, n))
    hi = min(n, 2 * target)
    for i in range(target, hi + 1):
        if n % i == 0:
            return i
    return target


def _aval_dtype(leaf: Any) -> np.dtype:
    dt = getattr(leaf, "dtype", None)
    return dt if dt is not None else np.asarray(leaf).dtype


def _aval_bytes(tree: Any) -> int:
    """``tree_bytes`` from shapes/dtypes alone — works on tracers."""
    return int(sum(
        int(np.prod(np.shape(leaf), dtype=np.int64))
        * np.dtype(_aval_dtype(leaf)).itemsize
        for leaf in jax.tree_util.tree_leaves(tree)))


def _zeros_of(tree: Any) -> Any:
    """Concrete zero-filled stand-in for a (possibly traced) pytree."""
    return jax.tree_util.tree_map(
        lambda leaf: jnp.zeros(np.shape(leaf), _aval_dtype(leaf)), tree)


def default_slots(interval: int, l1_budget_states: int = 16) -> int:
    """Level-1 slots for Revolve inside one interval.  ``interval <= s``
    degenerates to store-all within the segment (R(I, s) == 1, the paper's
    preferred operating point); larger intervals get the full budget."""
    return max(1, min(interval, l1_budget_states))


class AutoTuner:
    """Measures (T_A, T_T) once and caches the chosen schedule.

    Cache key: ``(name, n, state_bytes, level2-kind, backend)`` — the
    model/chain identity, sequence length, boundary-state size, Level-2
    medium and compute hardware, i.e. everything the §3 optimum depends on.
    """

    def __init__(self, l1_budget_states: int = 16, repeats: int = 3):
        """``l1_budget_states`` caps Level-1 slots ``s``; ``repeats`` is
        the best-of-N count each timing probe uses."""
        self.l1_budget_states = l1_budget_states
        self.repeats = repeats
        self._cache: Dict[Tuple, TuneResult] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------------ cache
    def _key(self, name: str, n: int, state_bytes: int,
             level2: str) -> Tuple:
        # T_T depends on the Level-2 medium, so the backend kind is part of
        # the identity — a RAM-tuned interval must never be reused for disk.
        return (name, n, state_bytes, level2, jax.default_backend())

    def lookup(self, name: str, n: int, state_bytes: int,
               level2: str) -> Optional[TuneResult]:
        """Return the cached schedule for this identity, or ``None``."""
        with self._lock:
            return self._cache.get(self._key(name, n, state_bytes, level2))

    def store(self, name: str, n: int, state_bytes: int, level2: str,
              result: TuneResult) -> TuneResult:
        """Cache ``result`` under this identity and return it."""
        with self._lock:
            self._cache[self._key(name, n, state_bytes, level2)] = result
        return result

    def clear(self) -> None:
        """Drop every cached schedule (tests; hardware changes)."""
        with self._lock:
            self._cache.clear()

    # ---------------------------------------------------------------- measure
    def _time(self, fn: Callable[[], Any]) -> float:
        fn()  # warmup (jit compile / first-touch)
        t0 = time.perf_counter()
        for _ in range(self.repeats):
            fn()
        return (time.perf_counter() - t0) / self.repeats

    def measure(self, name: str, *,
                forward_step: Optional[Callable[[Any, int], Any]] = None,
                state0: Any, n: int, backend: Any,
                forward_segment: Optional[Callable[[Any], Any]] = None,
                segment_len: int = 1,
                store_state0: Any = None,
                mesh: Any = None,
                param_stream_bytes: int = 0) -> TuneResult:
        """Time the forward compute and one Level-2 store; derive ``I`` per §3.

        Two probes, matching the two execution engines:

        * ``forward_step(state, k) -> state`` — the step-granular interpreter
          op; one timed call gives ``T_A`` directly (but includes the per-step
          Python dispatch overhead).
        * ``forward_segment(state) -> state`` over ``segment_len`` steps — a
          compiled ``advance_segment`` probe; ``T_A`` is the segment time
          divided by its length, i.e. the *amortised* per-step time the
          segment-compiled engine actually achieves.  This is the honest
          input to ``I = ceil(T_T/T_A)``: the compiled engine's smaller
          ``T_A`` correctly yields a larger interval.

        ``backend`` is the Level-2 storage backend the run will use (its
        put/delete pair is what we time).  A capacity-bounded
        ``TieredStorage`` backend gets a second probe of its *slow* tier,
        and the interval comes from the capacity-aware effective transfer
        time (``perfmodel.choose_tiered_interval``): if the boundaries at
        the fast-tier optimum would overflow the budget, ``I`` grows until
        either they fit or the slow tier keeps up — §3's rule applied to
        the medium that actually rate-limits the stores.

        ``store_state0`` (optional) substitutes the value fed to the
        store probes while ``state0`` still drives the compute probe and
        the cache identity.  The fused Pallas runner passes a
        host-resident copy here: its kernel has already DMA'd the
        boundary off the device by the time the store is issued, so the
        honest ``T_T`` is the un-hidden residual (serialisation +
        backend write), not a device→host transfer the kernel hides.

        A sharded backend (``ShardedStorage`` fan-out, possibly behind a
        journal) is probed twice more: once through a *single* inner
        stream with the gathered global state (``t_t_global``, the
        single-device baseline), and — when ``mesh`` is given — once per
        mesh axis with the state's leading dim cut to ``1/k``.  The
        fan-out ``T_T`` is clamped by the global time before §3's rule
        (``perfmodel.choose_sharded_interval``), so the sharded interval
        never exceeds the single-device one.

        ``param_stream_bytes`` (parameter streaming, ``offload_params=``)
        is the byte size of one chain step's streamed parameter blobs.
        When non-zero, a third probe measures their Level-2 *read-back*
        time (``t_t_param`` — the traffic the prefetch lane adds behind
        every segment) and the interval is widened per
        ``perfmodel.choose_interval_with_params`` so the boundary store
        still hides behind the compute left over after the reads.
        """
        state_bytes = tree_bytes(state0)
        level2 = type(backend).__name__
        if isinstance(backend, TieredStorage):
            # the optimum depends on the budget: key it into the cache
            level2 = f"{level2}[{backend.capacity_bytes}]"
        if param_stream_bytes:
            # added per-segment read traffic changes the optimum
            level2 = f"pstream[{param_stream_bytes}]:{level2}"
        streams = int(getattr(backend, "shard_streams", 0) or 0)
        if streams > 1:
            # the per-stream payload (hence T_T, hence I) depends on the
            # fan-out width: key it into the cache identity
            level2 = f"sharded[{streams}]:{level2}"
        cached = self.lookup(name, n, state_bytes, level2)
        if cached is not None:
            return cached

        if forward_segment is not None:
            def one_probe():
                jax.block_until_ready(forward_segment(state0))

            t_a = self._time(one_probe) / max(1, segment_len)
        else:
            if forward_step is None:
                raise TypeError("measure() needs forward_step or "
                                "forward_segment")

            def one_probe():
                jax.block_until_ready(forward_step(state0, 0))

            t_a = self._time(one_probe)

        tune_key = ("__autotune__", name)
        store_val = state0 if store_state0 is None else store_state0

        def one_store():
            backend.put(tune_key, store_val)

        t_t = self._time(one_store)
        backend.delete(tune_key)

        t_t_global = 0.0
        t_t_axes: Tuple = ()
        if streams > 1:
            inners = getattr(backend, "inners", None)
            if inners:
                # single-stream baseline: the whole (gathered) state
                # through one inner backend — what a 1-device run pays.
                host_global = jax.tree_util.tree_map(
                    lambda a: np.asarray(a), store_val)
                gkey = ("__autotune_global__", name)

                def one_global():
                    inners[0].put(gkey, host_global)

                t_t_global = self._time(one_global)
                inners[0].delete(gkey)
                if mesh is not None:
                    axes = []
                    for axis, k in dict(mesh.shape).items():
                        k = int(k)
                        if k <= 1:
                            axes.append((axis, t_t_global))
                            continue

                        def cut(a, k=k):
                            nd = getattr(a, "ndim", 0)
                            if nd and a.shape[0] % k == 0 and a.shape[0] >= k:
                                return a[: a.shape[0] // k]
                            return a

                        sliced = jax.tree_util.tree_map(cut, host_global)

                        def one_axis():
                            inners[0].put(gkey, sliced)

                        axes.append((axis, self._time(one_axis)))
                        inners[0].delete(gkey)
                    t_t_axes = tuple(axes)

        t_t_slow = 0.0
        capacity = None
        if isinstance(backend, TieredStorage):
            capacity = backend.capacity_bytes

            def one_slow_store():
                backend.slow.put(tune_key, store_val)

            t_t_slow = self._time(one_slow_store)
            backend.slow.delete(tune_key)
            if state_bytes > capacity:
                # the fast probe itself spilled: it measured the slow path,
                # so recover the fast tier's own time as the cheaper of the
                # two (everything bypasses anyway — t_t_eff is slow)
                t_t = min(t_t, t_t_slow)
            target = choose_tiered_interval(
                n, state_bytes, capacity, t_a, t_t, t_t_slow)
        elif streams > 1 and t_t_global > 0.0:
            # clamp: the fan-out streams only ever shrink the per-stream
            # payload, so a noisy-slow fan-out probe must not pick a
            # larger interval than the single-device baseline would
            t_t = min(t_t, t_t_global)
            target = choose_sharded_interval(t_a, t_t, t_t_global)
        else:
            target = optimal_interval(t_t, t_a)

        t_t_param = 0.0
        if param_stream_bytes:
            # probe the read-back path the prefetch lane uses: put one
            # step's worth of blob bytes, then time the non-promoting
            # peek (falling back to get on backends without one)
            blob = np.zeros(max(1, param_stream_bytes // 4), np.float32)
            pkey = ("__autotune_param__", name)
            backend.put(pkey, blob)
            read = getattr(backend, "peek", None) or backend.get

            def one_read():
                read(pkey)

            t_t_param = self._time(one_read)
            backend.delete(pkey)
            # widen, never shrink: T_P eats into the compute window that
            # hides the boundary store, so the tiered/sharded minimum
            # stays a floor
            target = max(target, choose_interval_with_params(
                t_a, t_t, t_t_param))

        interval = snap_interval(n, target)
        if capacity is not None and interval < target:
            # choose_tiered_interval's result is a *minimum viable*
            # interval (boundaries fit the budget, or the slow tier keeps
            # up); snapping onto a smaller divisor of n can re-enter the
            # spill-and-stall regime.  Keep the snap only if the effective
            # transfer time still hides behind the segment's compute.
            t_t_eff = effective_transfer_time(n, interval, state_bytes,
                                              capacity, t_t, t_t_slow)
            if t_t_eff > interval * t_a:
                interval = target
        slots = default_slots(interval, self.l1_budget_states)
        return self.store(name, n, state_bytes, level2, TuneResult(
            interval=interval, slots=slots, t_a=t_a, t_t=t_t,
            state_bytes=state_bytes, n=n, source="measured",
            t_t_slow=t_t_slow, capacity_bytes=capacity,
            t_t_global=t_t_global, shard_streams=streams,
            t_t_axes=t_t_axes, t_t_param=t_t_param))

    # ------------------------------------------------------- scan engine
    def measure_scan(self, name: str, *, body: Callable[..., Any],
                     params: Any, carry0: Any, xs: Any, batch: Any,
                     n: int, segment_len: int = 32) -> TuneResult:
        """Schedule for the trace-native scan engine.

        The scan engine resolves its schedule at *trace* time — ``params`` /
        ``carry0`` / ``xs`` / ``batch`` may be tracers, so every probe runs
        on zero-filled stand-ins built from shapes/dtypes alone (constant
        creation is eager even inside a trace).  Two probes:

        * ``T_A`` — the amortised per-step time of one jitted ``lax.scan``
          segment of ``segment_len`` steps, i.e. the compute rate the scan
          engine's compiled segments actually achieve;
        * ``T_T`` — a measured device->host ``device_put`` of the boundary
          state when the backend lowers host memory spaces (the XLA
          copy-start/copy-done path the offload policy compiles to),
          otherwise the §3 roofline estimate ``state_bytes / d2h_bw`` from
          the hardware table.

        Results share the cross-engine tuner cache: the key's Level-2 kind
        is ``"xla_host"`` / ``"roofline-<hw>"``, and callers put the engine
        in ``name`` (the front-end passes ``"<spec>:scan"``), so a
        scan-tuned interval is never reused for the threaded backends.
        """
        state_bytes = _aval_bytes(carry0)
        offloads = ofl.host_offload_supported()
        hw = TPU_V5E if jax.default_backend() == "tpu" else KNL
        level2 = "xla_host" if offloads else f"roofline-{hw.name}"
        cached = self.lookup(name, n, state_bytes, level2)
        if cached is not None:
            return cached

        segment_len = max(1, min(segment_len, n))
        zp, zc, zb = _zeros_of(params), _zeros_of(carry0), _zeros_of(batch)
        zxs = jax.tree_util.tree_map(
            lambda leaf: jnp.zeros(
                (segment_len,) + tuple(np.shape(leaf)[1:]), _aval_dtype(leaf)),
            xs)

        @jax.jit
        def probe(p, c, xs_, b):
            def step(c_, x):
                return body(p, c_, x, b), None

            c, _ = lax.scan(step, c, xs_)
            return c

        t_a = self._time(
            lambda: jax.block_until_ready(probe(zp, zc, zxs, zb))
        ) / segment_len

        if offloads:
            mem = jax.devices()[0].memory(ofl.HOST)

            def one_store():
                jax.block_until_ready(jax.tree_util.tree_map(
                    lambda x: jax.device_put(x, mem), zc))

            t_t = self._time(one_store)
        else:
            t_t = state_bytes / hw.d2h_bw

        interval = snap_interval(n, optimal_interval(t_t, t_a))
        slots = default_slots(interval, self.l1_budget_states)
        return self.store(name, n, state_bytes, level2, TuneResult(
            interval=interval, slots=slots, t_a=t_a, t_t=t_t,
            state_bytes=state_bytes, n=n, source="measured"))

    # --------------------------------------------------------------- roofline
    def from_roofline(self, name: str, *, n: int, step_flops: float,
                      step_hbm_bytes: float, state_bytes: int,
                      hw: HardwareSpec) -> TuneResult:
        """Analytic path: derive the schedule from compiled-HLO roofline
        terms (see ``analysis.roofline`` / ``launch.dryrun``) without running
        a step — used when planning runs on hardware we are not on."""
        level2 = f"roofline-{hw.name}"
        cached = self.lookup(name, n, state_bytes, level2)
        if cached is not None:
            return cached
        st: StepTimes = times_from_roofline(step_flops, step_hbm_bytes,
                                            state_bytes, hw)
        interval = snap_interval(n, st.interval)
        slots = default_slots(interval, self.l1_budget_states)
        return self.store(name, n, state_bytes, level2, TuneResult(
            interval=interval, slots=slots, t_a=st.t_a, t_t=st.t_t,
            state_bytes=state_bytes, n=n, source="roofline"))

    def plan_2d(self, tune: TuneResult, *, n: int, state_bytes: float,
                layer_bytes, budget_bytes: float, head_bytes: float = 0.0):
        """Pick 1D vs 2D for a measured schedule under a per-step budget.

        Couples a :meth:`measure` result (the outer axis: §3's interval
        from real ``T_A``/``T_T``) to the 2D overhead model
        (``perfmodel.choose_2d_plan``): ``layer_bytes``/``head_bytes`` are
        the chain's per-step byte profile
        (``analysis.jaxpr_cost.chain_step_byte_profile``), and the returned
        ``Plan2D`` carries the chosen inner axis (``.inner is None`` when
        time-only segmentation already fits), the modeled per-step peak and
        the combined recompute factor of both axes."""
        from repro.core import perfmodel as pm

        return pm.choose_2d_plan(
            n, t_a=tune.t_a, t_t=tune.t_t, s_l1=tune.slots,
            state_bytes=state_bytes, layer_bytes=layer_bytes,
            budget_bytes=budget_bytes, head_bytes=head_bytes,
            interval=tune.interval)

    def manual(self, name: str, *, n: int, interval: int,
               slots: Optional[int] = None,
               state_bytes: int = 0) -> TuneResult:
        """Build a pinned schedule with no measurement (``source="manual"``)
        — what the front-end uses when ``interval=``/``slots=`` are given.

        >>> AutoTuner().manual("doc", n=32, interval=8).interval
        8
        """
        return TuneResult(
            interval=max(1, min(interval, n)),
            slots=slots if slots is not None
            else default_slots(interval, self.l1_budget_states),
            t_a=0.0, t_t=0.0, state_bytes=state_bytes, n=n, source="manual")


# The process-wide tuner used by the front-end when none is supplied.
GLOBAL_TUNER = AutoTuner()
