"""Drop-in autodiff front-end for asynchronous multistage checkpointing.

``value_and_grad_offloaded(loss)`` is the paper's technique packaged the way
``jax.value_and_grad`` is: you hand it a loss, you get back a function
returning ``(loss, grads)``.  The difference is *how* the backward pass runs:

* the forward chain executes as compiled per-interval segments (one jitted
  ``lax.scan`` call each) while the ``AsyncTransferEngine`` streams every
  ``I``-th carry to Level-2 storage (host RAM, disk, int8-compressed, or a
  capacity-bounded RAM-over-disk tier) on a background thread;
* the backward pass replays segments from Level 2 with double-buffered
  prefetch, each reversed by one compiled checkpointed-vjp call — peak
  Level-1 memory is ``O(I + s)``, independent of chain length, at a constant
  recompute factor and O(n/I) host dispatches (pass ``engine="interpreted"``
  for the step-granular paper-faithful interpreter).

Mechanically this is a ``jax.custom_vjp`` whose fwd/bwd rules escape the
tracer via ``jax.experimental.io_callback``: the traced residual is just the
chain inputs plus an integer handle; the Level-2 state lives host-side in a
run registry between the two callbacks.  That makes the transform compose
with ``jax.value_and_grad`` / ``jax.jit`` like any other JAX function, while
the actual store/prefetch machinery stays the paper-faithful threaded
executor (``repro.core.executor``).

``engine="scan"`` swaps that machinery for the trace-native path: the chain
is rewritten as a plan-driven ``multistage_scan`` (``jax.checkpoint``
segments whose boundary carries the compiler offloads to pinned host
memory), so nothing escapes the trace and the transform additionally
composes with ``jax.vmap`` and mesh sharding.  All three engines execute
the same ``SegmentPlan`` (``api.last_plan()``).

The schedule ``(I, s)`` is chosen by ``repro.api.autotune`` from measured
``T_A``/``T_T`` on the first call (``I = ceil(T_T/T_A)``, §3) and cached per
(model, seq-len, hardware); pass ``interval=`` to pin it manually.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import shutil
import threading
import warnings
import weakref
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import io_callback

from repro.api import autotune as at
from repro.api.chain import (ChainSpec, chain_length, combine, diff_mask,
                             index_xs, partition, zero_cotangent, _dtype_of,
                             _is_inexact)
from repro.core import offload as ofl
from repro.core import schedule as ms
from repro.core.compiled_ops import (CompiledChainOps, CompiledSegmentRunner,
                                     PallasSegmentRunner,
                                     ParamStreamSegmentRunner,
                                     inner_chunked_body)
from repro.core.executor import (CheckpointExecutor, ExecutionStats,
                                 ParamStream)
from repro.core.multistage_scan import multistage_scan
from repro.core.storage import (AsyncTransferEngine, JournaledStorage,
                                make_backend)

STRATEGIES = ("multistage_async", "revolve", "conventional")
ENGINES = ("compiled", "interpreted", "scan")
RUNNERS = ("compiled", "pallas")
STORAGE_KINDS = ("ram", "disk", "compressed", "tiered")


@dataclasses.dataclass(frozen=True)
class OffloadConfig:
    """Static (hashable) knobs of one offloaded-gradient transform."""

    strategy: str = "multistage_async"
    interval: Optional[int] = None    # None -> autotune (I = ceil(T_T/T_A))
    slots: Optional[int] = None       # Level-1 Revolve slots; None -> budget
    storage: str = "ram"              # "ram" | "disk" | "compressed" | "tiered"
    storage_dir: Optional[str] = None
    l2_capacity_bytes: Optional[int] = None  # fast-tier budget ("tiered")
    journal_dir: Optional[str] = None  # crash-consistency WAL directory
    resume: bool = False              # resume a crashed run from the journal
    journal_repair: bool = False      # truncate a CRC-damaged journal on open
    autotune: bool = True
    tuner_id: int = 0                 # key into the tuner registry
    backend_id: int = 0               # key into the shared-backend registry
    #                                   (0 = build a private backend from
    #                                   ``storage``; nonzero = the caller
    #                                   passed backend= — a live Level-2
    #                                   store shared across transforms, e.g.
    #                                   a NamespacedStorage view of one
    #                                   capacity-bounded TieredStorage)
    engine: str = "compiled"          # "compiled" (per-segment XLA calls) |
    #                                   "interpreted" (per-step Python ops) |
    #                                   "scan" (trace-native, one XLA call)
    runner: str = "compiled"          # segment runner for engine="compiled":
    #                                   "compiled" (jitted scan per segment) |
    #                                   "pallas" (fused kernel, DMA overlap)
    mesh: Optional[Any] = None        # jax Mesh -> sharded Level-2 streams
    state_spec: Optional[Any] = None  # PartitionSpec of the boundary carry
    #                                   (None -> derive: batch axes over the
    #                                   mesh's data axes when divisible)
    step_memory_budget: Optional[int] = None  # per-step reverse-peak budget
    #                                   (bytes): when one step's activations
    #                                   exceed it, the planner goes 2D —
    #                                   inner layer/head chunks chosen by
    #                                   perfmodel.choose_2d_plan
    plan_2d: Optional[Tuple[int, int]] = None  # pin the inner axis instead:
    #                                   (layer_chunks, head_chunks)
    offload_params: Optional[str] = None  # stream these parameters through
    #                                   Level-2 alongside boundary states:
    #                                   "moe_experts" streams per-(layer,
    #                                   expert) FFN blobs with plan-aware
    #                                   prefetch one segment ahead

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(
                f"unknown strategy {self.strategy!r}; known: {STRATEGIES}")
        if self.engine not in ENGINES:
            raise ValueError(
                f"unknown engine {self.engine!r}; known: {ENGINES}")
        if self.runner not in RUNNERS:
            raise ValueError(
                f"unknown runner {self.runner!r}; known: {RUNNERS}")
        if self.runner == "pallas" and self.engine != "compiled":
            raise ValueError(
                "runner='pallas' fuses the compiled engine's per-segment "
                f"scan into a Pallas kernel; engine={self.engine!r} does "
                "not use segment runners")
        if self.storage == "tiered" and self.l2_capacity_bytes is None:
            raise ValueError(
                "storage='tiered' needs l2_capacity_bytes= (the fast-tier "
                "budget the Level-2 store must stay under)")
        if self.l2_capacity_bytes is not None and self.storage != "tiered":
            raise ValueError(
                "l2_capacity_bytes only applies to storage='tiered' "
                f"(got storage={self.storage!r}); the unbounded backends "
                "have no budget to enforce")
        if self.backend_id and self.mesh is not None:
            raise ValueError(
                "backend= hands the transform one already-built Level-2 "
                "store; sharded per-device streams (mesh=) must be built "
                "from a storage kind instead")
        if self.resume and self.journal_dir is None:
            raise ValueError(
                "resume=True needs journal_dir= (there is nothing to "
                "recover without a write-ahead journal)")
        if self.journal_dir is not None and \
                self.strategy != "multistage_async":
            raise ValueError(
                "journal_dir= journals the Level-2 boundary stores of the "
                "multistage_async strategy; strategy="
                f"{self.strategy!r} keeps no Level-2 state to journal")
        if self.state_spec is not None and self.mesh is None:
            raise ValueError(
                "state_spec= partitions the boundary carry over a mesh; "
                "pass mesh= as well")
        if self.mesh is not None:
            if self.strategy != "multistage_async":
                raise ValueError(
                    "mesh= shards the multistage_async Level-2 streams; "
                    f"strategy={self.strategy!r} keeps no Level-2 state")
            if self.engine == "scan":
                raise ValueError(
                    "engine='scan' is trace-native: shard it by jitting "
                    "with NamedSharding'd inputs instead of mesh= (the "
                    "executor engines own the sharded Level-2 streams)")
            if self.runner == "pallas":
                raise ValueError(
                    "runner='pallas' drives a single device's DMA engine; "
                    "sharded Level-2 streams (mesh=) need runner='compiled'")
        if self.step_memory_budget is not None:
            if self.plan_2d is not None:
                raise ValueError(
                    "pass either step_memory_budget= (the planner chooses "
                    "the inner axis) or plan_2d= (pin it), not both")
            if self.step_memory_budget <= 0:
                raise ValueError(
                    "step_memory_budget must be a positive byte count, got "
                    f"{self.step_memory_budget}")
        if self.plan_2d is not None:
            if len(self.plan_2d) != 2 or any(
                    int(c) < 1 for c in self.plan_2d):
                raise ValueError(
                    "plan_2d must be (layer_chunks, head_chunks) with both "
                    f">= 1, got {self.plan_2d!r}")
        if self.step_memory_budget is not None or self.plan_2d is not None:
            if self.strategy != "multistage_async":
                raise ValueError(
                    "2D plans (step_memory_budget=/plan_2d=) chunk the "
                    "multistage_async reverse sweep's per-step work; "
                    f"strategy={self.strategy!r} has no such sweep")
            if self.engine != "compiled":
                raise ValueError(
                    "2D plans execute in the compiled engine's segment "
                    f"runner; engine={self.engine!r} cannot run the inner "
                    "axis")
            if self.runner == "pallas":
                raise ValueError(
                    "runner='pallas' fuses the plain step body into its "
                    "kernel; the inner remat regions of a 2D plan need "
                    "runner='compiled'")
        if self.engine == "scan":
            if self.strategy != "multistage_async":
                raise ValueError(
                    "engine='scan' implements the multistage_async strategy "
                    f"only, got strategy={self.strategy!r}")
            if self.storage != "ram":
                raise ValueError(
                    "engine='scan' keeps Level-2 state in XLA host memory "
                    "(pinned_host); the pluggable storage backends "
                    f"({STORAGE_KINDS[1:]}) apply to the executor engines "
                    "only")
            if self.journal_dir is not None:
                raise ValueError(
                    "engine='scan' runs entirely inside XLA — its Level-2 "
                    "state cannot be journaled; use the executor engines "
                    "('compiled'/'interpreted') for crash consistency")
        if self.offload_params is not None:
            if self.offload_params != "moe_experts":
                raise ValueError(
                    f"unknown offload_params {self.offload_params!r}; "
                    "known: ('moe_experts',)")
            if self.strategy != "multistage_async":
                raise ValueError(
                    "offload_params= streams parameters through the "
                    "multistage_async Level-2 store; strategy="
                    f"{self.strategy!r} keeps no Level-2 state")
            if self.engine != "compiled" or self.runner != "compiled":
                raise ValueError(
                    "offload_params= assembles streamed parameter slices in "
                    "the compiled segment runner; it needs engine='compiled' "
                    f"with runner='compiled' (got engine={self.engine!r}, "
                    f"runner={self.runner!r})")
            if self.mesh is not None:
                raise ValueError(
                    "offload_params= drives a single Level-2 parameter lane; "
                    "sharded streams (mesh=) are not supported yet")
            if self.journal_dir is not None:
                raise ValueError(
                    "offload_params= keeps transient parameter blobs in "
                    "Level-2; journaling (journal_dir=/resume=) tracks "
                    "boundary states only and cannot replay them")
            if self.storage == "compressed":
                raise ValueError(
                    "offload_params= reads blobs back via non-promoting "
                    "peek, which storage='compressed' would return encoded; "
                    "use 'ram', 'disk' or 'tiered'")
            if self.step_memory_budget is not None or \
                    self.plan_2d is not None:
                raise ValueError(
                    "offload_params= is not supported together with 2D "
                    "plans (step_memory_budget=/plan_2d=)")


@dataclasses.dataclass(frozen=True)
class _Static:
    """Everything the custom_vjp rules need that must stay out of the trace."""

    spec: ChainSpec
    cfg: OffloadConfig
    xs_treedef: Any
    xs_mask: Tuple[bool, ...]
    inner: Optional[ms.InnerPlan] = None  # 2D plans: the resolved inner axis


# ---------------------------------------------------------------------------
# tuner + run registries (host side)
# ---------------------------------------------------------------------------

# Weak registry: a custom tuner lives exactly as long as its owner holds it
# (dropping the transform frees the tuner; lookups then fall back to the
# global tuner).  GLOBAL_TUNER itself is kept alive by its module.
_TUNERS: "weakref.WeakValueDictionary[int, at.AutoTuner]" = \
    weakref.WeakValueDictionary({0: at.GLOBAL_TUNER})
_TUNER_IDS = itertools.count(1)


def _register_tuner(tuner: Optional[at.AutoTuner]) -> int:
    if tuner is None or tuner is at.GLOBAL_TUNER:
        return 0
    tid = next(_TUNER_IDS)
    _TUNERS[tid] = tuner
    return tid


# Same weak-registry pattern for caller-supplied Level-2 backends: the
# OffloadConfig must stay a hashable frozen dataclass, so the live backend
# object is parked here and the config carries only its id.  The transform
# keeps a strong reference (``vg.backend``), so the entry lives exactly as
# long as some caller can still invoke the transform.
_SHARED_BACKENDS: "weakref.WeakValueDictionary[int, Any]" = \
    weakref.WeakValueDictionary()
_SHARED_BACKEND_IDS = itertools.count(1)


def _register_shared_backend(backend: Optional[Any]) -> int:
    if backend is None:
        return 0
    bid = next(_SHARED_BACKEND_IDS)
    _SHARED_BACKENDS[bid] = backend
    return bid


@dataclasses.dataclass
class _RunRecord:
    strategy: str
    tune: at.TuneResult
    run: Any = None                   # MultistageRun for multistage_async
    tmpdir: Optional[str] = None      # auto-created disk Level-2 directory

    def dispose(self) -> None:
        # Best-effort: a stale run's pending transfer error (engine.close
        # re-raises) must never crash the healthy call that evicted it.
        if self.run is not None:
            try:
                self.run.close()
            except Exception:
                pass
        if self.tmpdir is not None:
            shutil.rmtree(self.tmpdir, ignore_errors=True)
            self.tmpdir = None


_RUNS: Dict[int, _RunRecord] = {}
_RUNS_LOCK = threading.Lock()
_HANDLES = itertools.count(1)
# Backstop against pullbacks that are taken but never invoked (each holds an
# engine + Level-2 states).  Generous: a legitimate program holds one live
# run per offloaded chain between its forward and backward passes.
_MAX_LIVE_RUNS = 64

_LAST: Dict[str, Any] = {"stats": None, "tune": None, "plan": None}


def last_stats() -> Optional[ExecutionStats]:
    """ExecutionStats of the most recent offloaded backward pass (executor
    instrumentation: peak Level-1 states/bytes, advances, stall times).
    The scan engine has no executor stats (its schedule runs inside XLA):
    it clears this to ``None`` at *trace* time — a cached jit call leaves
    whatever an intervening executor-engine pass recorded."""
    return _LAST["stats"]


def last_tune() -> Optional[at.TuneResult]:
    """The schedule the autotuner chose for the most recent forward pass."""
    return _LAST["tune"]


def last_plan() -> Optional[ms.SegmentPlan]:
    """The :class:`~repro.core.schedule.SegmentPlan` behind the most recent
    multistage pass — the single IR every engine executes.  The executor
    engines record it per run; the scan engine records it at *trace* time
    (a cached jit call leaves it untouched).  ``None`` after a
    revolve/conventional pass."""
    return _LAST["plan"]


def _push_run(handle: int, rec: _RunRecord) -> None:
    evicted = []
    with _RUNS_LOCK:
        _RUNS[handle] = rec
        while len(_RUNS) > _MAX_LIVE_RUNS:
            evicted.append(_RUNS.pop(min(_RUNS)))
    for old in evicted:
        old.dispose()


def _pop_run(handle: int) -> _RunRecord:
    with _RUNS_LOCK:
        try:
            return _RUNS.pop(handle)
        except KeyError:
            raise RuntimeError(
                f"offloaded-chain run {handle} is no longer live (more than "
                f"{_MAX_LIVE_RUNS} pullbacks held open, or backward called "
                "twice); re-run the forward pass") from None


def _make_backend(cfg: OffloadConfig):
    """Build the Level-2 backend from the pluggable registry
    (``repro.core.storage.make_backend`` — unknown kinds raise there, so
    backends added via ``register_backend`` work here unmodified).  Returns
    (backend, tmpdir) — tmpdir is set when we created a temp Level-2
    directory that must be removed when the run is disposed."""
    if cfg.backend_id:
        backend = _SHARED_BACKENDS.get(cfg.backend_id)
        if backend is None:
            raise ValueError(
                "the backend= object this transform was built over is no "
                "longer alive; hold a reference to the transform (or the "
                "backend) for as long as it is called")
        if cfg.journal_dir is not None:
            # Journal composes OUTSIDE the shared store: the WAL records the
            # run's raw (un-namespaced) keys, so a resume replays into
            # whatever namespace the new backend view carries.
            backend = JournaledStorage(backend, cfg.journal_dir,
                                       repair=cfg.journal_repair)
        return backend, None
    tmpdir = None
    kwargs = {}
    if cfg.storage == "disk" or cfg.storage == "tiered" or (
            cfg.storage == "compressed" and cfg.storage_dir is not None):
        # tiered always gets a directory: its slow tier is the disk (the
        # paper's DRAM->SSD platform) unless the caller pinned one
        directory = cfg.storage_dir
        if directory is None:
            import tempfile

            directory = tempfile.mkdtemp(prefix="repro_l2_")
            tmpdir = directory
        kwargs["directory"] = directory
    if cfg.storage == "tiered":
        kwargs["capacity_bytes"] = cfg.l2_capacity_bytes
    if cfg.journal_dir is not None:
        kwargs["journal"] = cfg.journal_dir
        kwargs["journal_repair"] = cfg.journal_repair
    if cfg.mesh is not None:
        # one Level-2 stream per mesh device: each device's shard of every
        # boundary goes to its own inner backend on its own writer thread
        devices = list(cfg.mesh.devices.flat)
        kwargs["shards"] = len(devices)
        kwargs["devices"] = devices
    try:
        return make_backend(cfg.storage, **kwargs), tmpdir
    except BaseException:
        # construction can raise after the tempdir exists (e.g. a
        # ChecksumError from a corrupt journal): don't orphan it
        if tmpdir is not None:
            shutil.rmtree(tmpdir, ignore_errors=True)
        raise


# ---------------------------------------------------------------------------
# per-spec jitted chain operators
# ---------------------------------------------------------------------------


class _Ops:
    """Jitted operators for one (spec, xs-structure): per-step forward /
    backward for the interpreted engine, plus the per-segment compiled ops
    (``CompiledChainOps``) the segment-compiled engine dispatches.  The LRU
    over this class *is* the compile cache — a second transform over the same
    spec reuses every compiled segment."""

    def __init__(self, spec: ChainSpec, xs_treedef, xs_mask,
                 inner: Optional[ms.InnerPlan] = None):
        self.spec = spec
        rbody = None
        if inner is not None:
            # 2D plan: the reverse sweep differentiates through the
            # inner-chunked body (primal-identical — remat regions only
            # change what the backward keeps live), the forward advance
            # keeps the plain body for maximal fusion.
            rbody = inner_chunked_body(spec.layer_body, inner)
        self.cops = CompiledChainOps(spec.body, xs_treedef, xs_mask,
                                     reverse_body=rbody)

        @jax.jit
        def fwd(params, state, x, batch):
            return spec.body(params, state, x, batch)

        @jax.jit
        def scan_fwd(params, carry0, xs, batch):
            def step(c, x):
                return spec.body(params, c, x, batch), None

            carry, _ = lax.scan(step, carry0, xs)
            return carry

        @jax.jit
        def bwd(params, state, x_diff, x_nondiff, batch, dcarry, gacc):
            def f(p, c, xd):
                x = combine(xd, x_nondiff, xs_treedef, xs_mask)
                return spec.body(p, c, x, batch)

            _, vjp = jax.vjp(f, params, state, x_diff)
            dp, dc, dxd = vjp(dcarry)
            gacc = jax.tree_util.tree_map(jnp.add, gacc, dp)
            return dc, gacc, dxd

        @jax.jit
        def zero_grads(params):
            return jax.tree_util.tree_map(
                lambda p: jnp.zeros(jnp.shape(p), _dtype_of(p)), params)

        self.fwd = fwd
        self.scan_fwd = scan_fwd
        self.bwd = bwd
        self.zero_grads = zero_grads


@functools.lru_cache(maxsize=128)
def _get_ops(spec: ChainSpec, xs_treedef, xs_mask,
             inner: Optional[ms.InnerPlan] = None) -> _Ops:
    return _Ops(spec, xs_treedef, xs_mask, inner)


# ---------------------------------------------------------------------------
# 2D plans: trace-time inner-axis resolution
# ---------------------------------------------------------------------------

# The inner axis must be known when the loss is *traced* (the chunked
# readout and the inner-chunked reverse body are part of the traced
# computation), and it is a pure function of shapes — memory feasibility
# does not depend on the measured (T_A, T_T) the way the outer interval
# does.  Cached per (spec, budget, input shapes) so repeated gradient
# calls re-trace nothing.
_INNER_CACHE: Dict[Tuple, Optional[ms.InnerPlan]] = {}


def _shape_signature(*trees) -> Tuple:
    return tuple(
        (str(np.shape(leaf)), str(_dtype_of(leaf)))
        for tree in trees for leaf in jax.tree_util.tree_leaves(tree))


def _resolve_inner(spec: ChainSpec, cfg: OffloadConfig, params, carry0, xs,
                   batch) -> Optional[ms.InnerPlan]:
    """The inner (per-step) axis of the plan, or ``None`` for 1D.

    ``cfg.plan_2d`` pins it; ``cfg.step_memory_budget`` derives it from the
    chain's real per-layer byte profile (``analysis.jaxpr_cost``) through
    the Gruslys-style DP (``perfmodel.choose_2d_plan``).  Raises when the
    budget is infeasible, naming the smallest budget that would work."""
    if cfg.plan_2d is None and cfg.step_memory_budget is None:
        return None
    if not spec.supports_2d:
        raise ValueError(
            f"chain {spec.name!r} has no per-step layer decomposition — 2D "
            "plans (step_memory_budget=/plan_2d=) need "
            "ChainSpec.layer_body/n_layers (and readout_chunked for head "
            "chunking)")
    if cfg.plan_2d is not None:
        lc, hc = cfg.plan_2d
        return ms.InnerPlan(n_layers=spec.n_layers, layer_chunks=int(lc),
                            head_chunks=int(hc))
    key = (spec, cfg.step_memory_budget,
           _shape_signature(params, carry0, xs, batch))
    if key not in _INNER_CACHE:
        from repro.analysis.jaxpr_cost import chain_step_byte_profile
        from repro.core import perfmodel as pm

        state_bytes, layer_bytes, head_bytes = chain_step_byte_profile(
            spec, params, carry0, index_xs(xs, 0), batch)
        plan2d = pm.choose_2d_plan(
            chain_length(xs), t_a=1.0, t_t=0.0,
            s_l1=cfg.slots if cfg.slots is not None else 16,
            state_bytes=state_bytes, layer_bytes=layer_bytes,
            budget_bytes=cfg.step_memory_budget, head_bytes=head_bytes,
            interval=cfg.interval if cfg.interval is not None else 1)
        if not plan2d.feasible:
            need = int(np.ceil(plan2d.min_budget_bytes))
            raise ValueError(
                f"step_memory_budget={cfg.step_memory_budget} is infeasible "
                f"for chain {spec.name!r}: even layer_chunks="
                f"{spec.n_layers} peaks above it; the smallest feasible "
                f"budget is {need} bytes")
        _INNER_CACHE[key] = plan2d.inner
    return _INNER_CACHE[key]


# ---------------------------------------------------------------------------
# host-side callbacks (run outside the trace)
# ---------------------------------------------------------------------------


def _select_runner(cfg: OffloadConfig) -> str:
    """Resolve ``cfg.runner`` against the hardware actually present.

    ``runner="pallas"`` needs a Pallas lowering target (TPU, or interpret
    mode forced via ``REPRO_PALLAS_INTERPRET=1``); anywhere else it falls
    back to the plain compiled runner with a one-line warning so CPU CI
    and laptops keep working untouched.
    """
    if cfg.runner != "pallas":
        return cfg.runner
    from repro.kernels import segment_pallas as sp

    ok, reason = sp.runner_supported()
    if ok:
        return "pallas"
    warnings.warn(reason, stacklevel=3)  # one line: why + the fallback
    return "compiled"


_EXPERT_LEAF_NAMES = ("w_gate", "w_up", "w_down")


def _expert_leaf_ids(xs) -> Tuple[int, ...]:
    """Flat indices of the per-(layer, expert) MoE parameter leaves in the
    stacked chain inputs: leaves under a ``'moe'`` subtree named
    ``w_gate``/``w_up``/``w_down`` (shape ``(n_layers, n_experts, ...)``).
    ``tree_flatten_with_path`` enumerates leaves in ``tree_flatten`` order,
    so these indices address the plain flattened list too."""
    ids = []
    flat, _ = jax.tree_util.tree_flatten_with_path(xs)
    for i, (path, leaf) in enumerate(flat):
        names = [getattr(p, "key", None) for p in path]
        if "moe" in names and names and names[-1] in _EXPERT_LEAF_NAMES \
                and np.ndim(leaf) >= 2:
            ids.append(i)
    return tuple(ids)


def _resolve_schedule(static: _Static, ops: _Ops, params, carry0, xs, batch,
                      n: int, backend, runner: str = "compiled",
                      param_stream_bytes: int = 0) -> at.TuneResult:
    cfg = static.cfg
    tuner = _TUNERS.get(cfg.tuner_id, at.GLOBAL_TUNER)
    if cfg.interval is not None:
        return tuner.manual(static.spec.name, n=n, interval=cfg.interval,
                            slots=cfg.slots)
    if cfg.strategy != "multistage_async" or not cfg.autotune or \
            backend is None:
        interval = max(1, min(n, 32))
        return tuner.manual(static.spec.name, n=n, interval=interval,
                            slots=cfg.slots)

    # T_A depends on the execution engine (amortised compiled segments vs
    # per-step dispatch), so the engine — and for the compiled engine the
    # segment runner — is part of the tuner cache identity.
    tune_name = f"{static.spec.name}:{cfg.engine}"
    if runner == "pallas":
        tune_name += ":pallas"
    if param_stream_bytes:
        # param streaming adds per-segment Level-2 read traffic (T_P) to
        # the interval trade-off — keep its schedule out of the plain cache
        tune_name += ":pstream"
    if cfg.engine == "compiled":
        # T_A is the *amortised* per-step time of a compiled segment, not a
        # per-step dispatch: probe one advance_segment over a short prefix.
        # Snap the probe length onto a divisor of n so it coincides with a
        # snap_interval candidate — when the tuner then picks it, the probe
        # compile is the run's compile, not a throwaway.
        from repro.core.multistage_scan import choose_interval

        cap = max(1, min(n, 32))
        cand = choose_interval(n, cap)
        # don't let a prime-ish n shrink the probe to a few steps — the
        # amortised measurement needs a real segment
        probe_len = cand if cand >= min(cap, 8) else cap
        xs_probe = jax.tree_util.tree_map(lambda leaf: leaf[:probe_len], xs)

        store_state0 = None
        if runner == "pallas":
            # probe the *fused* path: T_A includes the in-kernel boundary
            # copy, and T_T is measured from a host-resident state because
            # the kernel has already DMA'd the boundary off the device —
            # the store only pays the un-hidden (serialisation) residual.
            from repro.kernels import segment_pallas as sp

            interp = sp.default_interpret()

            def forward_segment(state):
                out, _ = sp.fused_advance_segment(
                    ops.cops.body, ops.cops.xs_treedef, ops.cops.xs_mask,
                    params, state, xs_probe, batch,
                    chunk=probe_len, interpret=interp)
                return out

            store_state0 = jax.tree_util.tree_map(np.asarray, carry0)
        else:
            def forward_segment(state):
                if ops.cops.donates_carry:
                    # advance_segment donates its carry on accelerators;
                    # the probe reuses state0 across repeats, so feed it a
                    # copy.
                    state = jax.tree_util.tree_map(
                        lambda x: jnp.array(x, copy=True), state)
                return ops.cops.advance_segment(params, state, xs_probe,
                                                batch)

        tune = tuner.measure(tune_name,
                             forward_segment=forward_segment,
                             segment_len=probe_len,
                             state0=carry0, n=n, backend=backend,
                             store_state0=store_state0, mesh=cfg.mesh,
                             param_stream_bytes=param_stream_bytes)
    else:
        def forward_step(state, k):
            return ops.fwd(params, state, index_xs(xs, k), batch)

        tune = tuner.measure(tune_name, forward_step=forward_step,
                             state0=carry0, n=n, backend=backend,
                             mesh=cfg.mesh)
    if cfg.slots is not None:
        tune = dataclasses.replace(tune, slots=cfg.slots)
    return tune


def _mesh_place(cfg: OffloadConfig, backend, params, carry0, xs, batch,
                dcarry=None):
    """Commit the chain inputs to ``cfg.mesh`` (the io_callback hands the
    host callbacks plain numpy — any sharding the caller had is gone):
    boundary carries under the derived state sharding
    (``distributed.sharding.state_shardings``), ``xs`` split along its
    batch axis, params/batch replicated.  Records the carry shardings on
    a sharded backend first, so its per-device streams know how to split
    host-side payloads (journal replay, autotune probes) the same way.

    With the inputs placed *before* schedule resolution, the autotune
    probes run SPMD on the mesh — ``T_A`` is the real per-device rate and
    the fan-out store probe measures the true per-stream ``T_T``."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.distributed import sharding as shd

    mesh = cfg.mesh
    state_sh = shd.state_shardings(mesh, carry0, cfg.state_spec)
    if backend is not None:
        set_sh = getattr(backend, "set_state_sharding", None)
        if set_sh is not None:
            set_sh(state_sh)
    rep = NamedSharding(mesh, P())
    carry0 = jax.device_put(carry0, state_sh)
    xs = jax.device_put(xs, shd.chain_input_shardings(mesh, xs))
    params = jax.device_put(
        params, jax.tree_util.tree_map(lambda _: rep, params))
    batch = jax.device_put(
        batch, jax.tree_util.tree_map(lambda _: rep, batch))
    if dcarry is None:
        return params, carry0, xs, batch
    dcarry = jax.device_put(
        dcarry, shd.state_shardings(mesh, dcarry, cfg.state_spec))
    return params, carry0, xs, batch, dcarry


def _input_fingerprint(*trees) -> str:
    """Sampled identity of the gradient call's inputs
    (params/carry0/xs/batch): per-leaf shape+dtype+nbytes plus a CRC of
    bounded prefix/middle/suffix slices.  Written into the journal's
    BEGIN record and checked before a resume — resuming a crashed sweep
    under *different* inputs (e.g. a restart from an older model
    checkpoint with a stale journal) would silently mix two parameter
    sets into one gradient, so a mismatch falls back to a fresh,
    journaled run.

    The check is probabilistic by design: hashing every byte of a
    multi-GB pytree per gradient call is not affordable, so O(KB) per
    leaf is sampled from three spread-out slices.  Any realistic input
    change (a different batch, an optimizer step — and in the launcher
    the per-step batch differs always) lands in a sampled region with
    overwhelming probability; inputs crafted to collide outside the
    samples are out of scope (documented in the README)."""
    import zlib

    crc = 0
    for tree in trees:
        for leaf in jax.tree_util.tree_leaves(tree):
            a = np.asarray(leaf)
            crc = zlib.crc32(
                f"{a.shape}{a.dtype}{a.nbytes}".encode(), crc)
            # bound the copied bytes: slice flat views *before*
            # materialising (tobytes() on the full array would memcpy
            # multi-GB pytrees once per gradient call)
            if not a.flags.c_contiguous:
                a = np.ascontiguousarray(a)
            flat = a.reshape(-1)
            n = flat.shape[0]
            k = max(1, 2048 // max(1, a.itemsize))
            for sl in (flat[:k], flat[max(0, n // 2 - k // 2):
                                      n // 2 + k // 2 + 1], flat[-k:]):
                crc = zlib.crc32(np.ascontiguousarray(sl).tobytes(), crc)
    return f"{crc:08x}"


def _fwd_callback(static: _Static, params, carry0, xs, batch):
    spec, cfg = static.spec, static.cfg
    ops = _get_ops(spec, static.xs_treedef, static.xs_mask, static.inner)
    n = chain_length(xs)
    handle = next(_HANDLES)

    def fwd_op(state, k):
        return ops.fwd(params, state, index_xs(xs, k), batch)

    if cfg.strategy == "multistage_async":
        runner_kind = _select_runner(cfg)
        backend, tmpdir = _make_backend(cfg)
        engine = None
        try:
            if cfg.mesh is not None:
                # rebind: fwd_op's closure is late-binding, so the placed
                # (sharded) arrays drive the probes and the forward sweep
                params, carry0, xs, batch = _mesh_place(
                    cfg, backend, params, carry0, xs, batch)
            recovered = None
            fingerprint = None
            if cfg.journal_dir is not None:
                fingerprint = _input_fingerprint(params, carry0, xs, batch)
            if cfg.resume:
                # what survived the crash: durable boundary keys + the last
                # plan cursor.  Unusable recoveries (no cursor, a cleanly
                # finished run, a different chain length, or inputs that
                # do not match the crashed run's fingerprint) fall back to
                # a fresh — still journaled — run.
                recovered = backend.recover()
                cur = recovered.cursor
                old_fp = recovered.meta.get("fingerprint")
                if cur is None or cur.phase == "done" or cur.n != n or \
                        (old_fp is not None and old_fp != fingerprint):
                    recovered = None
            stream_leaves = None
            n_experts = 0
            param_stream_bytes = 0
            if cfg.offload_params is not None:
                # host copies of the streamed leaves (frozen np views feed
                # the Level-2 lane bit-exactly); the runner's xs keep 0-d
                # placeholders at those flat positions so the treedef — and
                # with it the jit cache identity — is preserved
                leaf_ids = _expert_leaf_ids(xs)
                if not leaf_ids:
                    raise ValueError(
                        "offload_params='moe_experts' found no per-expert "
                        "parameter leaves in the chain inputs (expected "
                        "stacked MoE weights w_gate/w_up/w_down under a "
                        "'moe' subtree)")
                flat_leaves = jax.tree_util.tree_leaves(xs)
                stream_leaves = {i: np.asarray(flat_leaves[i])
                                 for i in leaf_ids}
                n_experts = int(next(iter(
                    stream_leaves.values())).shape[1])
                param_stream_bytes = sum(
                    int(a[0].nbytes) for a in stream_leaves.values())
            if recovered is not None:
                # the journal cursor pins the schedule: resuming under a
                # different (I, s) than the crashed run would orphan its
                # durable boundaries
                tuner = _TUNERS.get(cfg.tuner_id, at.GLOBAL_TUNER)
                tune = tuner.manual(static.spec.name, n=n,
                                    interval=recovered.cursor.interval,
                                    slots=recovered.cursor.s_l1)
            else:
                tune = _resolve_schedule(static, ops, params, carry0, xs,
                                         batch, n, backend,
                                         runner=runner_kind,
                                         param_stream_bytes=
                                         param_stream_bytes)
            engine = AsyncTransferEngine(backend)
            ex = CheckpointExecutor(fwd_op, None)
            runner = None
            param_stream = None
            if cfg.engine == "compiled":
                # one jitted advance/reverse call per segment (O(n/I) host
                # dispatches); the runner also collects per-step input
                # cotangents segment-wise during the reverse sweep
                if runner_kind == "pallas":
                    # fused kernel: the boundary copy overlaps the next
                    # chunk's compute inside advance (advance_with_store)
                    runner = PallasSegmentRunner(ops.cops, params, xs,
                                                 batch, s_l1=tune.slots)
                elif stream_leaves is not None:
                    param_stream = ParamStream(engine, stream_leaves,
                                               n_experts=n_experts)
                    leaves, treedef = jax.tree_util.tree_flatten(xs)
                    xs_runner = jax.tree_util.tree_unflatten(treedef, [
                        np.zeros((), _dtype_of(leaf))
                        if i in stream_leaves else leaf
                        for i, leaf in enumerate(leaves)])
                    runner = ParamStreamSegmentRunner(
                        ops.cops, params, xs_runner, batch,
                        s_l1=tune.slots, stream=param_stream,
                        inner=static.inner)
                else:
                    runner = CompiledSegmentRunner(ops.cops, params, xs,
                                                   batch, s_l1=tune.slots,
                                                   inner=static.inner)
            x_n, run = ex.multistage_forward(
                carry0, n, interval=tune.interval, s_l1=tune.slots,
                engine=engine, runner=runner, resume_from=recovered,
                inner=static.inner, param_stream=param_stream,
                run_meta={"fingerprint": fingerprint}
                if fingerprint is not None else None)
        except BaseException:
            # multistage_forward treats a passed-in engine as borrowed and
            # won't close it on error — engine and backend are ours, so
            # close both here (a journaled backend holds an open WAL fd;
            # leaking it across an in-process retry loop piles up fds).
            if engine is not None:
                try:
                    engine.close()
                except Exception:
                    pass
            bclose = getattr(backend, "close", None)
            if bclose is not None:
                try:
                    bclose()
                except Exception:
                    pass
            if tmpdir is not None:
                shutil.rmtree(tmpdir, ignore_errors=True)
            raise
        # the run borrows nothing: it owns the engine and must close it
        run.own_engine = True
        _push_run(handle, _RunRecord(cfg.strategy, tune, run, tmpdir=tmpdir))
        _LAST["plan"] = run.plan
    else:
        tune = _resolve_schedule(static, ops, params, carry0, xs, batch, n,
                                 None)
        x_n = ops.scan_fwd(params, carry0, xs, batch)
        _push_run(handle, _RunRecord(cfg.strategy, tune))
        _LAST["plan"] = None
    _LAST["tune"] = tune
    return x_n, np.int32(handle)


def _bwd_callback(static: _Static, handle, params, carry0, xs, batch, dcarry):
    spec = static.spec
    rec = _pop_run(int(handle))
    ops = _get_ops(spec, static.xs_treedef, static.xs_mask, static.inner)
    n = chain_length(xs)
    if static.cfg.mesh is not None:
        # the reverse sweep reassembles boundaries under their recorded
        # shardings; place the remaining operands to match (backend=None —
        # the forward pass already recorded the carry shardings on it)
        params, carry0, xs, batch, dcarry = _mesh_place(
            static.cfg, None, params, carry0, xs, batch, dcarry)
    xs_diff, xs_nondiff = partition(xs, static.xs_mask)
    collect_dx = any(static.xs_mask)
    dx_slices: Dict[int, Any] = {}

    def fwd_op(state, k):
        return ops.fwd(params, state, index_xs(xs, k), batch)

    def bwd_op(state, adjoint, k):
        dc, gacc = adjoint
        xd = [leaf[k] for leaf in xs_diff]
        xnd = [leaf[k] for leaf in xs_nondiff]
        dc, gacc, dxd = ops.bwd(params, state, xd, xnd, batch, dc, gacc)
        if collect_dx:
            dx_slices[k] = dxd
        return dc, gacc

    ex = CheckpointExecutor(fwd_op, bwd_op)
    adjoint0 = (dcarry, ops.zero_grads(params))
    runner = rec.run.runner if rec.run is not None else None

    # Journaled runs checkpoint each reversed segment's per-step input
    # cotangents alongside the adjoint cursor, so a mid-sweep resume can
    # still stitch the full-chain dxs without re-reversing anything.
    def artifact_fn(seg):
        if isinstance(runner, CompiledSegmentRunner):
            return runner.dx_segments.get(seg.begin)
        if collect_dx:
            return {k: dx_slices[k]
                    for k in range(seg.begin, seg.end) if k in dx_slices}
        return None

    def restore_artifact_fn(begin, artifact):
        if artifact is None:
            return
        if isinstance(runner, CompiledSegmentRunner):
            runner.dx_segments[begin] = artifact
        else:
            dx_slices.update(artifact)

    try:
        if rec.strategy == "multistage_async":
            adjoint, stats = ex.multistage_reverse(
                rec.run, adjoint0, artifact_fn=artifact_fn,
                restore_artifact_fn=restore_artifact_fn)
        elif rec.strategy == "revolve":
            adjoint, stats = ex.run_revolve(carry0, n, adjoint0,
                                            s=rec.tune.slots)
        else:  # conventional
            adjoint, stats = ex.run_conventional(carry0, n, adjoint0)
    finally:
        rec.dispose()  # idempotent: reverse already closed the run's engine
    _LAST["stats"] = stats
    dcarry0, gparams = adjoint
    if not collect_dx:
        dxs_diff = []
    elif isinstance(runner, CompiledSegmentRunner):
        # per-segment stacked cotangents, stitched back into full arrays
        dxs_diff = runner.collect_dx(rec.run.plan)
    else:
        dxs_diff = [
            jnp.stack([dx_slices[k][i] for k in range(n)])
            for i in range(len(xs_diff))
        ]
    return gparams, dcarry0, dxs_diff


# ---------------------------------------------------------------------------
# the custom_vjp chain
# ---------------------------------------------------------------------------


def _sds(tree):
    return jax.tree_util.tree_map(
        lambda leaf: jax.ShapeDtypeStruct(np.shape(leaf), _dtype_of(leaf)),
        tree)


def _chain_primal(static: _Static, params, carry0, xs, batch):
    """Primal: semantically just the scan (value-only calls never pay for
    checkpointing); differentiation swaps in the executor via fwd/bwd."""
    spec = static.spec

    def step(c, x):
        return spec.body(params, c, x, batch), None

    carry, _ = lax.scan(step, carry0, xs)
    return carry


_chain = jax.custom_vjp(_chain_primal, nondiff_argnums=(0,))


def _chain_fwd(static: _Static, params, carry0, xs, batch):
    out_sds = jax.eval_shape(
        functools.partial(_chain_primal, static),
        params, carry0, xs, batch)
    for leaf in jax.tree_util.tree_leaves(out_sds):
        if not _is_inexact(leaf):
            raise TypeError(
                "chain carry leaves must be inexact (float) arrays; fold "
                "integer state into xs/batch instead")
    carry_n, handle = io_callback(
        functools.partial(_fwd_callback, static),
        (out_sds, jax.ShapeDtypeStruct((), np.int32)),
        params, carry0, xs, batch)
    return carry_n, (params, carry0, xs, batch, handle)


def _chain_bwd(static: _Static, res, dcarry):
    params, carry0, xs, batch, handle = res
    xs_diff, xs_nondiff = partition(xs, static.xs_mask)
    out_sds = (_sds(params), _sds(carry0), _sds(xs_diff))
    gparams, dcarry0, dxs_diff = io_callback(
        functools.partial(_bwd_callback, static), out_sds,
        handle, params, carry0, xs, batch, dcarry)
    dxs = combine(dxs_diff, [zero_cotangent(leaf) for leaf in xs_nondiff],
                  static.xs_treedef, static.xs_mask)
    dbatch = jax.tree_util.tree_map(zero_cotangent, batch)
    return gparams, dcarry0, dxs, dbatch


_chain.defvjp(_chain_fwd, _chain_bwd)


# ---------------------------------------------------------------------------
# the trace-native scan engine (engine="scan")
# ---------------------------------------------------------------------------


def _resolve_scan_schedule(spec: ChainSpec, cfg: OffloadConfig, params,
                           carry0, xs, batch, n: int) -> at.TuneResult:
    """Schedule for a scan-engine chain.  Runs at trace time (the arguments
    may be tracers); measurement probes use zero stand-ins built from shapes
    only, and the result lands in the shared tuner cache under the
    ``"<spec>:scan"`` engine-qualified name."""
    tuner = _TUNERS.get(cfg.tuner_id, at.GLOBAL_TUNER)
    if cfg.interval is not None:
        return tuner.manual(spec.name, n=n, interval=cfg.interval,
                            slots=cfg.slots)
    if not cfg.autotune:
        return tuner.manual(spec.name, n=n, interval=max(1, min(n, 32)),
                            slots=cfg.slots)
    tune = tuner.measure_scan(f"{spec.name}:scan", body=spec.body,
                              params=params, carry0=carry0, xs=xs,
                              batch=batch, n=n,
                              segment_len=max(1, min(n, 32)))
    if cfg.slots is not None:
        tune = dataclasses.replace(tune, slots=cfg.slots)
    return tune


def _scan_loss(spec: ChainSpec, cfg: OffloadConfig
               ) -> Callable[[Any, Any], Any]:
    """The loss with its chain segment rewritten as a plan-driven
    ``multistage_scan``: segment boundaries offload to XLA host memory
    (compiler-scheduled copy-start/copy-done — the paper's async Level-2
    transfers) and segment interiors recompute at the plan's inner chunk
    granularity.  Everything stays inside the trace — no io_callback, no run
    registry — so the transform composes with ``jax.jit``, ``jax.vmap`` and
    mesh sharding.  On backends that cannot lower host placement (CPU) the
    boundaries stay in HBM: plain plan-segmented remat, same schedule."""

    def loss(params, batch):
        carry0, xs = spec.prelude(params, batch)
        n = chain_length(xs)
        tune = _resolve_scan_schedule(spec, cfg, params, carry0, xs, batch, n)
        plan = ms.segment_plan(n, tune.interval, tune.slots)
        _LAST["tune"] = tune
        _LAST["plan"] = plan
        _LAST["stats"] = None

        def step(c, x):
            return spec.body(params, c, x, batch), None

        carry_n, _ = multistage_scan(
            step, carry0, xs, plan=plan,
            offload=ofl.host_offload_supported())
        return spec.readout(params, carry_n, batch)

    return loss


# ---------------------------------------------------------------------------
# public front-end
# ---------------------------------------------------------------------------


def _as_chain_spec(loss_fn) -> Optional[ChainSpec]:
    if isinstance(loss_fn, ChainSpec):
        return loss_fn
    return getattr(loss_fn, "chain_spec", None)


def offloaded_loss(spec: ChainSpec, cfg: OffloadConfig
                   ) -> Callable[[Any, Any], Any]:
    """The loss with its chain segment rerouted through the configured
    engine: the checkpointing executor (``engine="compiled"|"interpreted"``,
    via custom_vjp + io_callback) or the trace-native plan-driven scan
    (``engine="scan"``).  Differentiable; prelude/readout gradients flow via
    ordinary autodiff (stacked-layer cotangents scatter back into params
    through the prelude's vjp)."""

    if cfg.engine == "scan":
        return _scan_loss(spec, cfg)

    def loss(params, batch):
        carry0, xs = spec.prelude(params, batch)
        treedef, mask = diff_mask(xs)
        inner = _resolve_inner(spec, cfg, params, carry0, xs, batch)
        static = _Static(spec=spec, cfg=cfg, xs_treedef=treedef,
                         xs_mask=mask, inner=inner)
        carry_n = _chain(static, params, carry0, xs, batch)
        if inner is not None and inner.head_chunks > 1:
            if spec.readout_chunked is None:
                raise ValueError(
                    f"2D plan wants head_chunks={inner.head_chunks} but "
                    f"chain {spec.name!r} has no readout_chunked")
            return spec.readout_chunked(params, carry_n, batch,
                                        inner.head_chunks)
        return spec.readout(params, carry_n, batch)

    return loss


def value_and_grad_offloaded(
    loss_fn,
    *,
    strategy: str = "multistage_async",
    interval: Optional[int] = None,
    slots: Optional[int] = None,
    storage: str = "ram",
    storage_dir: Optional[str] = None,
    l2_capacity_bytes: Optional[int] = None,
    backend: Optional[Any] = None,
    journal_dir: Optional[str] = None,
    resume: bool = False,
    journal_repair: bool = False,
    autotune: bool = True,
    tuner: Optional[at.AutoTuner] = None,
    fallback: bool = True,
    engine: str = "compiled",
    runner: str = "compiled",
    mesh: Optional[Any] = None,
    state_spec: Optional[Any] = None,
    step_memory_budget: Optional[int] = None,
    plan_2d: Optional[Tuple[int, int]] = None,
    offload_params: Optional[str] = None,
) -> Callable[[Any, Any], Tuple[Any, Any]]:
    """Drop-in ``jax.value_and_grad`` with multistage-offloaded backprop.

    ``loss_fn`` is a :class:`ChainSpec`, or a callable carrying one as a
    ``chain_spec`` attribute (the model factory attaches these).  A plain
    callable with no chain structure falls back to ``jax.value_and_grad``
    when ``fallback=True`` (with a warning), so call sites can pass whatever
    loss they have.

    Returns ``f(params, batch) -> (loss, grads)``.

    Keyword args: ``strategy`` is one of ``multistage_async`` (the paper:
    async Level-2 stores every ``I`` steps + prefetch, Revolve inside
    intervals), ``revolve`` (single-stage baseline) or ``conventional``
    (store everything); ``interval``/``slots`` pin the schedule, otherwise
    the autotuner measures ``T_A``/``T_T`` on first call and applies §3's
    ``I = ceil(T_T/T_A)``; ``storage`` picks the Level-2 backend
    (``"ram"``, ``"disk"``, ``"compressed"`` — int8-quantised boundary
    states, ~4x smaller at a bounded precision cost — or ``"tiered"``, a
    capacity-bounded fast tier over a disk slow tier).  ``l2_capacity_bytes``
    (required with ``storage="tiered"``) is the fast-tier budget: the
    Level-2 *store* never exceeds it — cold boundaries write-behind spill
    to disk in plan-aware (Belady) order and are promoted back ahead of
    need (the reverse sweep additionally holds up to ``prefetch_depth``
    boundary states in Level-1-bound transit staging, reported as
    ``last_stats().l2_staged_peak_bytes``) — and the autotuner probes
    *both* tiers, choosing ``I`` from
    the capacity-aware effective transfer time (a budget that forces
    spills yields a larger interval so the slow tier keeps up).

    ``backend=`` bypasses the storage kinds entirely and hands the
    transform a live, already-built Level-2 store — the multi-tenant
    serving path passes a ``NamespacedStorage`` view of ONE shared
    capacity-bounded ``TieredStorage`` here, so concurrent runs obey a
    common fast-tier budget and per-tenant quotas
    (``TieredStorage.set_quota``).  Mutually exclusive with
    ``storage``/``storage_dir``/``l2_capacity_bytes``; ``journal_dir``
    still composes on top (the WAL records the run's own keys, outside the
    shared namespace).  The shared store is never closed by run disposal.

    ``journal_dir`` makes the offloaded run *crash-consistent*: every
    Level-2 store/delete is write-ahead-logged (CRC + fsync) together
    with a plan cursor checkpointed at segment granularity, so a run
    killed mid-sweep (writer-thread death, OOM, preemption, truncated
    spill) can be resumed step-exactly with :func:`resume_offloaded` —
    replaying at most one interval of forward steps
    (``last_stats().replayed_advances``) and never re-reversing a
    completed segment.  Requires an executor engine
    (``"compiled"``/``"interpreted"``); storage failures surface as typed
    :class:`repro.core.faults.StorageFault` subclasses.

    ``engine`` selects how segments execute — all three drive the same
    ``SegmentPlan`` IR (``api.last_plan()``): ``"compiled"`` (default) runs
    one jitted ``lax.scan``/checkpointed-vjp call per segment — O(n/I) host
    dispatches, compiled once per segment length; ``"interpreted"`` is the
    step-granular paper-faithful interpreter (O(n) dispatches, exact
    Revolve-optimal advance counts); ``"scan"`` stays entirely inside the
    XLA trace (one dispatch, boundaries offloaded to pinned host memory by
    the compiler where supported) and composes with ``jax.jit``,
    ``jax.vmap`` and mesh sharding — use it on pods.  The scan engine
    implements the ``multistage_async`` strategy with the XLA host backend
    only (``storage`` must stay ``"ram"``).

    ``runner`` (compiled engine only) selects the per-segment kernel:
    ``"compiled"`` (default) is one jitted scan per segment with the
    boundary store issued from the host; ``"pallas"`` fuses the segment
    into a Pallas kernel that double-buffers the boundary-state DMA to
    host memory while the next chunk computes, and reverses segments with
    Echo-style in-kernel recompute.  Requires a Pallas target (TPU, or
    ``REPRO_PALLAS_INTERPRET=1`` for interpret mode); anywhere else it
    falls back to ``"compiled"`` with a one-line warning.  Gradients are
    bit-identical across runners on matching chunking (fp32).

    ``mesh`` (executor engines only) makes the offloaded run first-class
    on a multi-device mesh: chain inputs are committed to the mesh inside
    the gradient's host callbacks, every jitted segment op runs SPMD, and
    each device streams *its shard* of every boundary state to its own
    Level-2 stream (a per-device ``ShardedStorage`` fan-out behind the
    configured ``storage`` kind — composes with the journal and the
    tiered budget).  ``state_spec`` pins the boundary carry's
    ``PartitionSpec`` (fitted per-leaf to each shape); by default the
    carry's leading axis shards over the mesh's data axes when divisible,
    else replicates.  The autotuner measures the per-stream *and*
    single-stream transfer times and applies §3 to the smaller — the
    sharded interval never exceeds the single-device one
    (``last_tune().t_t_global``, ``.shard_streams``); per-stream traffic
    shows up in ``last_stats().l2_stream_bytes``.

    ``step_memory_budget`` (compiled engine + runner only) bounds the
    *per-step* reverse peak in bytes and makes the planner two-dimensional:
    when one chain step's own activations exceed the budget — deep per-step
    layer stacks, or a logits/loss head larger than everything else — the
    step itself is chunked.  The chain's real per-layer byte profile
    (``analysis.jaxpr_cost``) feeds a Gruslys-style DP
    (``perfmodel.choose_2d_plan``) that picks the fewest rematted layer
    sub-ranges (and logits/loss head chunks) that fit; the outer interval
    stays the tuner's §3 optimum.  Needs a chain with a layer
    decomposition (``ChainSpec.layer_body``/``n_layers`` — the model
    factories attach these); an infeasible budget raises, naming the
    smallest feasible one.  ``plan_2d=(layer_chunks, head_chunks)`` pins
    the inner axis instead.  ``api.last_plan()`` reports both axes
    (``plan.inner``), ``api.last_stats()`` the per-axis recompute and peak
    counters (``inner_recomputed_layers``, ``inner_peak_bytes``).
    Gradients stay bit-identical to the 1D plan's (fp32): inner chunking
    only changes *when* interiors are recomputed, never what is computed.

    ``offload_params="moe_experts"`` (compiled engine + runner only)
    generalises the Level-2 lane from boundary states to *parameters*:
    the chain's stacked per-(layer, expert) MoE weights
    (``w_gate``/``w_up``/``w_down``) move to the Level-2 store up front
    and stream back one blob per (layer, expert) with plan-aware prefetch
    one segment ahead of both sweeps, so resident parameter memory drops
    from ``O(n_layers * n_experts)`` to ``O(I * n_experts)``.  Boundary
    states and expert blobs share one capacity budget under
    ``storage="tiered"`` (one merged ``ResourceAccessPlan`` drives Belady
    eviction for both).  Gradients are bit-identical to the non-streamed
    path; prefetch traffic shows up as ``last_stats().param_prefetches``
    / ``param_fetch_stalls`` / ``param_bytes_moved``.

    Example — a tiny chain, pinned schedule, gradients match autodiff:

    >>> import jax, jax.numpy as jnp, numpy as np
    >>> from repro import api
    >>> spec = api.ChainSpec(
    ...     prelude=lambda params, batch: (jnp.float32(0.0), batch["xs"]),
    ...     body=lambda params, c, x, batch: c + params["w"] * jnp.tanh(x + c),
    ...     readout=lambda params, c, batch: c,
    ...     name="doc-vg-chain")
    >>> params = {"w": jnp.float32(0.5)}
    >>> batch = {"xs": jnp.linspace(-1.0, 1.0, 8)}
    >>> vg = api.value_and_grad_offloaded(spec, interval=4, slots=2)
    >>> loss, grads = vg(params, batch)
    >>> ref_loss, ref_grads = jax.value_and_grad(spec.loss_fn())(params, batch)
    >>> bool(np.allclose(loss, ref_loss))
    True
    >>> bool(np.allclose(grads["w"], ref_grads["w"]))
    True
    """
    if backend is not None:
        # ``backend=`` hands the transform a live, already-built Level-2
        # store (typically a NamespacedStorage view of one shared
        # capacity-bounded TieredStorage, so concurrent runs obey a common
        # budget and per-tenant quotas).  It replaces the storage kind
        # entirely; a journal_dir still composes on top.
        if storage != "ram" or storage_dir is not None or \
                l2_capacity_bytes is not None:
            raise ValueError(
                "pass either backend= (an already-built Level-2 store) or "
                "the storage=/storage_dir=/l2_capacity_bytes= kind knobs, "
                "not both")
        storage = "shared"
    spec = _as_chain_spec(loss_fn)
    if spec is None:
        if not fallback:
            raise TypeError(
                "loss_fn has no chain decomposition (expected a ChainSpec "
                "or a callable with a .chain_spec attribute)")
        warnings.warn(
            "value_and_grad_offloaded: loss has no chain decomposition; "
            "falling back to jax.value_and_grad (no offloading)",
            stacklevel=2)
        return jax.value_and_grad(loss_fn)

    cfg = OffloadConfig(strategy=strategy, interval=interval, slots=slots,
                        storage=storage, storage_dir=storage_dir,
                        l2_capacity_bytes=l2_capacity_bytes,
                        journal_dir=journal_dir, resume=resume,
                        journal_repair=journal_repair,
                        autotune=autotune, tuner_id=_register_tuner(tuner),
                        backend_id=_register_shared_backend(backend),
                        engine=engine, runner=runner,
                        mesh=mesh, state_spec=state_spec,
                        step_memory_budget=step_memory_budget,
                        plan_2d=tuple(plan_2d) if plan_2d is not None
                        else None,
                        offload_params=offload_params)
    vg = jax.value_and_grad(offloaded_loss(spec, cfg))
    vg.chain_spec = spec
    vg.offload_config = cfg
    # keep the weak registry entries alive for as long as the transform is
    vg.tuner = tuner
    vg.backend = backend
    return vg


def resume_offloaded(
    loss_fn,
    params,
    batch,
    *,
    journal_dir: str,
    repair: bool = False,
    **opts,
) -> Tuple[Any, Any]:
    """Resume a crashed offloaded gradient from its write-ahead journal.

    Recovers the journal in ``journal_dir`` (written by a
    ``value_and_grad_offloaded(..., journal_dir=...)`` transform that was
    killed mid-run) and finishes the gradient step-exactly: a
    forward-phase crash replays from the last durable boundary (at most
    one interval of steps — ``last_stats().replayed_advances``), a
    reverse-phase crash restarts mid-sweep from the journaled adjoint
    cursor without re-reversing any completed segment.  ``params`` and
    ``batch`` must be the ones the crashed run used — determinism is what
    makes the resumed gradient bit-identical to the fault-free one.

    Returns ``(loss, grads)`` exactly like the transform would have.  If
    the journal holds nothing resumable (no cursor, or a run that already
    completed), the gradient is simply recomputed from scratch — still
    journaled, so the call is safe to use as the generic retry path.

    ``repair=True`` truncates a CRC-damaged journal back to its last good
    record instead of raising
    :class:`~repro.core.faults.ChecksumError` (resume then replays from
    whatever precedes the damage).  Remaining keyword options are those
    of :func:`value_and_grad_offloaded` — pass the same ``storage``/
    ``engine`` configuration the crashed run used.
    """
    vg = value_and_grad_offloaded(loss_fn, journal_dir=journal_dir,
                                  resume=True, journal_repair=repair,
                                  **opts)
    return vg(params, batch)


def checkpointed_bptt(
    body: Callable[[Any, Any, Any], Tuple[Any, Any]],
    **opts,
) -> Callable[[Any, Any, Any], Tuple[Any, Any]]:
    """BPTT through ``lax.scan``-style chains with offloaded checkpointing.

    ``body(params, carry, x) -> (carry, loss_k)`` is one chain step (an RNN
    time step, a transformer layer, ...).  Returns
    ``bptt(params, carry0, xs) -> (total_loss, grads)`` where ``total_loss``
    is the sum of the per-step losses and ``grads`` matches ``params`` —
    the multistage counterpart of
    ``jax.value_and_grad(lambda p: sum-of-scan(body))``.

    Keyword options are those of :func:`value_and_grad_offloaded`.

    >>> import jax, jax.numpy as jnp, numpy as np
    >>> from repro import api
    >>> def body(params, carry, x):
    ...     carry = jnp.tanh(carry + params * x)
    ...     return carry, carry ** 2
    >>> bptt = api.checkpointed_bptt(body, interval=4, slots=2)
    >>> loss, grad = bptt(jnp.float32(0.3), jnp.float32(0.0),
    ...                   jnp.linspace(0.0, 1.0, 8))
    >>> def ref(p):
    ...     def step(c, x):
    ...         c, out = body(p, c, x)
    ...         return c, out
    ...     _, outs = jax.lax.scan(step, jnp.float32(0.0),
    ...                            jnp.linspace(0.0, 1.0, 8))
    ...     return jnp.sum(outs)
    >>> ref_loss, ref_grad = jax.value_and_grad(ref)(jnp.float32(0.3))
    >>> bool(np.allclose(loss, ref_loss)), bool(np.allclose(grad, ref_grad))
    (True, True)
    """

    def prelude(params, batch):
        carry0, xs = batch
        return (carry0, jnp.zeros((), jnp.float32)), xs

    def chain_body(params, c, x, batch):
        carry, acc = c
        carry, loss_k = body(params, carry, x)
        return carry, acc + jnp.sum(loss_k).astype(jnp.float32)

    def readout(params, c, batch):
        return c[1]

    spec = ChainSpec(prelude, chain_body, readout,
                     name=getattr(body, "__name__", "bptt"))
    vg = value_and_grad_offloaded(spec, **opts)

    def bptt(params, carry0, xs):
        return vg(params, (carry0, xs))

    bptt.chain_spec = spec
    return bptt
