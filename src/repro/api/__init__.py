"""Differentiable front-end: the paper's multistage checkpointing as a
drop-in ``jax.value_and_grad``.

    from repro import api

    vg = api.value_and_grad_offloaded(model.train_loss)   # or a ChainSpec
    loss, grads = vg(params, batch)                       # O(I + s) Level-1

Gradients run on the plan -> compile -> execute engine: the chain is split
into per-interval segments (``repro.core.schedule.SegmentPlan``), each
compiled once into a jitted advance / checkpointed-vjp reverse pair
(``repro.core.compiled_ops``), and driven with asynchronous Level-2
store/prefetch by the executor — O(n/I) host dispatches per pass.  Pass
``engine="interpreted"`` for the step-granular interpreter, or
``engine="scan"`` for the trace-native path (one XLA call, composes with
``jax.jit`` / ``jax.vmap`` / mesh sharding) — all engines execute the
same ``SegmentPlan`` (``api.last_plan()``).

See ``repro.api.frontend`` for the transform, ``repro.api.chain`` for the
chain decomposition it differentiates, and ``repro.api.autotune`` for the
§3 schedule selection (``I = ceil(T_T/T_A)``) from measured or roofline
times.
"""
from repro.api.autotune import AutoTuner, GLOBAL_TUNER, TuneResult
from repro.api.chain import ChainSpec, chain_length
from repro.api.frontend import (ENGINES, STORAGE_KINDS, STRATEGIES,
                                OffloadConfig, checkpointed_bptt,
                                last_plan, last_stats, last_tune,
                                offloaded_loss, resume_offloaded,
                                value_and_grad_offloaded)
from repro.core.faults import StorageFault  # typed Level-2 failure root
from repro.core.perfmodel import Plan2D, choose_2d_plan
from repro.core.schedule import InnerPlan

__all__ = [
    "AutoTuner", "GLOBAL_TUNER", "TuneResult",
    "ChainSpec", "chain_length",
    "ENGINES", "STORAGE_KINDS", "STRATEGIES",
    "InnerPlan", "Plan2D", "choose_2d_plan",
    "OffloadConfig", "StorageFault", "checkpointed_bptt", "last_plan",
    "last_stats", "last_tune",
    "offloaded_loss", "resume_offloaded", "value_and_grad_offloaded",
]
