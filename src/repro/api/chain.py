"""Chain decomposition of a loss function.

The paper's machinery applies to any loss of the form

    carry_0, xs = prelude(params, batch)
    carry_{k+1} = body(params, carry_k, xs_k, batch)        k in [0, n)
    loss        = readout(params, carry_n, batch)

— an RNN/SSM scan over time (``xs`` = per-step tokens), or a deep network
scanned over depth (``xs`` = the stacked per-layer parameters; the layer-input
activation is the carry).  ``ChainSpec`` captures that decomposition; the
front-end (``repro.api.frontend``) differentiates through it with the
checkpointing executor instead of storing every carry.

Only ``params``, the carry, and the *inexact* (float/complex) leaves of
``xs`` are differentiated; ``batch`` and integer ``xs`` leaves (token ids)
are treated as constants.  Gradients that flow out of the chain through
``carry_0`` and ``xs`` are pulled back through ``prelude`` by ordinary
autodiff, so stacked-layer gradients scatter back into ``params`` for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import numpy as np

Params = Any
Carry = Any
Batch = Any

PreludeFn = Callable[[Params, Batch], Tuple[Carry, Any]]
BodyFn = Callable[[Params, Carry, Any, Batch], Carry]
ReadoutFn = Callable[[Params, Carry, Batch], Any]
# 2D plans: one per-step layer application (j is a static Python int) and a
# readout whose logits/loss head is evaluated in ``head_chunks`` pieces.
LayerBodyFn = Callable[[Params, Carry, Any, Batch, int], Carry]
ChunkedReadoutFn = Callable[[Params, Carry, Batch, int], Any]


@dataclasses.dataclass(frozen=True)
class ChainSpec:
    """A loss expressed as prelude -> n x body -> readout.

    Frozen (hashable) so it can ride through ``jax.custom_vjp``'s static
    arguments and key the per-spec jit caches.  ``name`` doubles as the
    autotuner cache key component.

    >>> import jax.numpy as jnp
    >>> spec = ChainSpec(
    ...     prelude=lambda params, batch: (jnp.float32(0.0), batch["xs"]),
    ...     body=lambda params, c, x, batch: c + params * jnp.tanh(x),
    ...     readout=lambda params, c, batch: c ** 2,
    ...     name="doc-chain")
    >>> loss = spec.loss_fn()   # the equivalent undecomposed callable
    >>> float(loss(jnp.float32(2.0), {"xs": jnp.zeros((5,))}))
    0.0
    """

    prelude: PreludeFn
    body: BodyFn
    readout: ReadoutFn
    name: str = "chain"
    # Optional per-step layer substructure — what makes the chain 2D-plannable
    # (``OffloadConfig(step_memory_budget=...)``).  Contract:
    # ``layer_body(params, carry, x, batch, j)`` applies the step's ``j``-th
    # layer (``j`` a static int in ``range(n_layers)``) and composing
    # ``j = 0 .. n_layers-1`` must equal one ``body`` application exactly.
    # ``readout_chunked(params, carry, batch, head_chunks)`` must equal
    # ``readout`` at ``head_chunks == 1``.
    layer_body: Optional[LayerBodyFn] = None
    n_layers: int = 0
    readout_chunked: Optional[ChunkedReadoutFn] = None

    @property
    def supports_2d(self) -> bool:
        """Whether a 2D (time x layer) plan can execute this chain."""
        return self.layer_body is not None and self.n_layers >= 1

    def loss_fn(self) -> Callable[[Params, Batch], Any]:
        """The undecomposed loss — reference semantics for the front-end
        (and the function ``jax.value_and_grad`` would differentiate)."""

        def loss(params, batch):
            carry, xs = self.prelude(params, batch)
            n = chain_length(xs)

            def step(c, x):
                return self.body(params, c, x, batch), None

            carry, _ = jax.lax.scan(step, carry, xs, length=n)
            return self.readout(params, carry, batch)

        return loss


def chain_length(xs: Any) -> int:
    """Number of chain steps — the (uniform) leading axis of ``xs``.

    >>> import numpy as np
    >>> chain_length({"tok": np.zeros((12, 4)), "tgt": np.zeros((12,))})
    12
    """
    leaves = jax.tree_util.tree_leaves(xs)
    if not leaves:
        raise ValueError("chain xs must have at least one array leaf")
    ns = {int(np.shape(leaf)[0]) for leaf in leaves}
    if len(ns) != 1:
        raise ValueError(f"inconsistent leading axes in chain xs: {ns}")
    return ns.pop()


def index_xs(xs: Any, k: int) -> Any:
    """Slice step ``k``'s per-step input out of stacked ``xs`` (host-side)."""
    return jax.tree_util.tree_map(lambda leaf: leaf[k], xs)


# ---------------------------------------------------------------------------
# inexact/nondiff partitioning (token ids ride along, but are not
# differentiated — jax.vjp rejects integer primals)
# ---------------------------------------------------------------------------


def _dtype_of(leaf: Any) -> np.dtype:
    # works for jax arrays, tracers, numpy arrays and python scalars alike
    dt = getattr(leaf, "dtype", None)
    return dt if dt is not None else np.asarray(leaf).dtype


def _is_inexact(leaf: Any) -> bool:
    dt = _dtype_of(leaf)
    return np.issubdtype(dt, np.inexact) or "float" in str(dt)


def diff_mask(tree: Any) -> Tuple[Any, Tuple[bool, ...]]:
    """(treedef, per-leaf inexact mask) for a pytree — both hashable."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return treedef, tuple(_is_inexact(leaf) for leaf in leaves)


def partition(tree: Any, mask: Tuple[bool, ...]):
    """Split flattened leaves into (diff_leaves, nondiff_leaves) lists."""
    leaves = jax.tree_util.tree_leaves(tree)
    diff = [leaf for leaf, m in zip(leaves, mask) if m]
    nondiff = [leaf for leaf, m in zip(leaves, mask) if not m]
    return diff, nondiff


def combine(diff, nondiff, treedef, mask: Tuple[bool, ...]) -> Any:
    """Inverse of :func:`partition`: re-interleave and unflatten."""
    diff_it, nondiff_it = iter(diff), iter(nondiff)
    leaves = [next(diff_it) if m else next(nondiff_it) for m in mask]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def zero_cotangent(leaf: Any):
    """The cotangent jax.custom_vjp expects for an untouched primal leaf:
    zeros for inexact dtypes, a float0 array for integer/bool dtypes."""
    shape = np.shape(leaf)
    if _is_inexact(leaf):
        import jax.numpy as jnp

        return jnp.zeros(shape, _dtype_of(leaf))
    return np.zeros(shape, jax.dtypes.float0)
