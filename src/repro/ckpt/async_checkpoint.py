"""Asynchronous sharded model checkpointing — the paper's async-store idea
applied at the job-state level (fault tolerance for 1000+ node runs).

Save path: snapshot device state to host numpy on the caller thread (cheap,
and guarantees a consistent cut), then a background writer thread serialises
per-leaf ``.npy`` files plus a JSON manifest, finishing with an atomic
``rename`` publish — a crash mid-write can never corrupt the latest
checkpoint.  ``keep_last`` old steps are retained for rollback.

Restore path: read the newest valid manifest, reconstruct the pytree, and
(optionally) reshard onto a new mesh via
``repro.distributed.fault_tolerance.reshard_state`` for elastic restarts.
On a multi-host pod each host writes only its addressable shards under
``shard_<host>/``; this single-host implementation writes shard 0.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Optional, Tuple

import jax
import numpy as np

Params = Any


def _flatten(state: Params):
    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    names, leaves = [], []
    for path, leaf in flat:
        names.append("_".join(str(getattr(k, "key", getattr(k, "idx", k)))
                              for k in path))
        leaves.append(leaf)
    return names, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep_last: int = 3):
        self.directory = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._errors: list = []
        self._stop = threading.Event()
        self._writer = threading.Thread(target=self._loop, daemon=True)
        self._writer.start()
        self.save_stall_s = 0.0

    # ---------------------------------------------------------------- save
    def save(self, state: Params, step: int) -> None:
        """Asynchronous save; returns as soon as the host snapshot is taken."""
        t0 = time.perf_counter()
        names, leaves, _ = _flatten(state)
        host = [np.asarray(l) for l in leaves]  # consistent host snapshot
        self.save_stall_s += time.perf_counter() - t0
        self._q.put(("save", step, names, host))

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                item = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            try:
                _, step, names, host = item
                self._write(step, names, host)
                self._gc()
            except Exception as e:
                self._errors.append(e)
            finally:
                self._q.task_done()

    def _write(self, step: int, names, host) -> None:
        final = os.path.join(self.directory, f"step_{step:010d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": []}
        for i, (name, arr) in enumerate(zip(names, host)):
            fn = f"{i:05d}.npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"].append(
                {"name": name, "file": fn, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_last] if self.keep_last else []:
            shutil.rmtree(os.path.join(self.directory,
                                       f"step_{s:010d}"), ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, d,
                                               "manifest.json")):
                    out.append(int(d[5:]))
        return sorted(out)

    def restore(self, like: Params, step: Optional[int] = None
                ) -> Tuple[Params, int]:
        """Restore the given (or latest) step into the structure of ``like``.

        An explicit ``step=`` must name a checkpoint that still exists:
        asking for one that was never written or has been garbage-collected
        (``keep_last``) raises ``ValueError`` listing what *is* available —
        silently handing back a different step would let a resumed job
        train from the wrong weights without anyone noticing.
        """
        steps = self.all_steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        if step is None:
            step = steps[-1]
        elif step not in steps:
            raise ValueError(
                f"checkpoint step {step} not available in "
                f"{self.directory} (available: {steps}); it was never "
                f"saved or has been garbage-collected "
                f"(keep_last={self.keep_last})")
        d = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        _, leaves, treedef = _flatten(like)
        assert len(leaves) == len(manifest["leaves"]), "structure mismatch"
        out = []
        for meta, leaf in zip(manifest["leaves"], leaves):
            arr = np.load(os.path.join(d, meta["file"]))
            assert list(arr.shape) == list(leaf.shape), (meta, leaf.shape)
            out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out), step

    def wait(self) -> None:
        self._q.join()
        if self._errors:
            raise self._errors[0]

    def close(self) -> None:
        self.wait()
        self._stop.set()
        self._writer.join(timeout=2.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
