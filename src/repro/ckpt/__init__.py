from repro.ckpt.async_checkpoint import CheckpointManager

__all__ = ["CheckpointManager"]
