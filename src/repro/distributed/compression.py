"""Gradient compression for the cross-pod (DCN) all-reduce.

The pod axis is pure data parallelism over the slowest link in the system
(DCN, ~6.25 GB/s/host vs 50 GB/s ICI), so the cross-pod gradient reduction
is the natural target for compression.  Scheme: int8 block quantisation with
a shared absmax scale and **error feedback** (the quantisation residual is
carried in optimizer-side state and added back next step), which keeps SGD
convergence unaffected in expectation.

Wire format per tensor: int8 payload (4x smaller than f32) + one f32 scale.
``compressed_mean`` is written against a named axis so it drops into any
``shard_map``-manual region; ``quantize``/``dequantize`` are exposed for
tests (round-trip error bounds, error-feedback accumulation property).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any
tmap = jax.tree_util.tree_map


def quantize(x: jnp.ndarray, axis_name: Optional[str] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """int8 absmax quantisation.  If ``axis_name`` is given the scale is the
    max over that named axis too (shared scale -> summable payloads)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    if axis_name is not None:
        amax = jax.lax.pmax(amax, axis_name)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def quantize_np(x) -> Tuple["np.ndarray", "np.float32"]:
    """Host-side (pure numpy) twin of :func:`quantize` — same scheme, same
    error bound, but runs entirely on CPU threads.  Used by the Level-2
    ``CompressedStorage`` backend, whose background writer/prefetch threads
    must never enqueue work on the accelerator stream they are meant to
    overlap with."""
    import numpy as np

    x32 = np.asarray(x, dtype=np.float32)
    amax = float(np.max(np.abs(x32))) if x32.size else 0.0
    scale = np.float32(max(amax, 1e-30) / 127.0)
    q = np.clip(np.round(x32 / scale), -127, 127)
    return q.astype(np.int8), scale


def dequantize_np(q, scale):
    import numpy as np

    return np.asarray(q, dtype=np.float32) * np.float32(scale)


def compressed_mean(tree: Params, axis_name: str,
                    error: Optional[Params] = None
                    ) -> Tuple[Params, Params]:
    """Mean of ``tree`` over the named (pod) axis with int8 payloads.

    Returns (mean_tree_f32, new_error_feedback_tree).  The all-reduce runs as
    ``psum`` on the int8 payload widened to int32 *after* a shared-scale
    quantisation — on the wire XLA moves the s8 tensor (DCN bytes / 4); the
    widening is a local op.  Error feedback: e' = g + e - dequant(q).
    """
    def one(g, e):
        g32 = g.astype(jnp.float32)
        if e is not None:
            g32 = g32 + e
        q, scale = quantize(g32, axis_name)
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
        mean = dequantize(summed, scale) / n.astype(jnp.float32)
        new_e = g32 - dequantize(q, scale)
        return mean, new_e

    if error is None:
        error = tmap(lambda _: None, tree,
                     is_leaf=lambda x: x is None)
        flat, tdef = jax.tree_util.tree_flatten(tree)
        pairs = [one(g, None) for g in flat]
    else:
        flat, tdef = jax.tree_util.tree_flatten(tree)
        eflat = jax.tree_util.tree_leaves(error)
        pairs = [one(g, e) for g, e in zip(flat, eflat)]
    means = jax.tree_util.tree_unflatten(tdef, [p[0] for p in pairs])
    errs = jax.tree_util.tree_unflatten(tdef, [p[1] for p in pairs])
    return means, errs


def init_error_feedback(params: Params) -> Params:
    return tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantization_error_bound(x: jnp.ndarray) -> float:
    """|x - dq(q(x))|_inf <= scale/2 = absmax/254 — used by property tests."""
    return float(jnp.max(jnp.abs(x)) / 254.0 + 1e-12)
