"""Sharding rules: logical-axis -> mesh-axis mapping (MaxText-style).

The production mesh is ``("data", "model")`` per pod and
``("pod", "data", "model")`` across pods:

* ``pod``   — pure data parallelism across DCN; the only cross-pod
  collective is the gradient all-reduce.
* ``data``  — FSDP: parameters and optimizer state sharded over their
  embed/d_model dimension; activations sharded over batch.
* ``model`` — tensor parallelism: attention heads, MLP hidden, vocab and the
  MoE expert axis.

``param_pspec`` derives a PartitionSpec for every parameter from its path in
the pytree + shape; ``constrain`` applies activation constraints inside model
code (identity unless a mesh context is installed, so models stay runnable on
a single CPU device).
"""
from __future__ import annotations

import re
import threading
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ctx = threading.local()


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


class MeshContext:
    """Installs mesh + activation rules for ``constrain`` calls in models.

    ``zero3=True`` additionally pins projection *outputs* to
    (batch, ..., model): with outputs batch+TP-sharded and inputs
    batch-sharded, GSPMD must all-gather the FSDP-sharded weight
    (ZeRO-3 semantics) instead of all-reducing activation partial sums —
    which on a multi-pod mesh it otherwise routes across DCN.
    """

    def __init__(self, mesh: Mesh, enable: bool = True, profile: str = "tp",
                 zero3: bool = False):
        self.mesh = mesh
        self.enable = enable
        ba = batch_axes(mesh) if profile != "dp" else tuple(mesh.axis_names)
        self.act_specs = {
            "act": P(ba, None, None),          # (B, S, D)
            "act_seq": P(None, ba, None),      # sequence-sharded (B=1 long ctx)
            "logits": P(ba, None, "model" if profile != "dp" else None),
        }
        if zero3 and profile != "dp":
            self.act_specs["proj"] = P(ba, None, "model")       # (B, S, F)
            self.act_specs["proj4"] = P(ba, None, "model", None)  # (B,S,H,hd)

    def __enter__(self):
        self.prev = getattr(_ctx, "mc", None)
        _ctx.mc = self
        return self

    def __exit__(self, *exc):
        _ctx.mc = self.prev
        return False


def constrain(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    mc: Optional[MeshContext] = getattr(_ctx, "mc", None)
    if mc is None or not mc.enable:
        return x
    spec = mc.act_specs.get(kind)
    if spec is None or len(spec) != x.ndim:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mc.mesh, spec))


# ---------------------------------------------------------------------------
# parameter sharding rules
# ---------------------------------------------------------------------------

# (path regex, rank-of-leaf-without-stack-axis) -> partition spec (per rule).
# Paths are "/"-joined pytree keys, e.g. "layers/pos0/attn/wq/w".
_PARAM_RULES: Sequence[Tuple[str, Tuple]] = (
    # embeddings: vocab over model (sharded logits), d replicated
    (r"(embed|unembed)/emb$", ("model", None)),
    # attention projections: FSDP on d_model, TP on heads
    (r"attn/w[qkv]/w$", ("data", "model")),
    (r"attn/wo/w$", ("model", "data")),
    (r"attn/w[qkvo]/b$", ("model",)),
    # dense MLP
    (r"(mlp|shared)/(gate|up)/w$", ("data", "model")),
    (r"(mlp|shared)/down/w$", ("model", "data")),
    (r"(mlp|shared)/.*/b$", (None,)),
    # MoE: experts over model (EP), FSDP on d_model
    (r"moe/w_(gate|up)$", ("model", "data", None)),
    (r"moe/w_down$", ("model", None, "data")),
    (r"moe/router/w$", ("data", None)),
    # Mamba: FSDP only (inner dim is semantically partitioned; keep local)
    (r"mamba/in_proj/w$", ("data", None)),
    (r"mamba/out_proj/w$", (None, "data")),
    (r"mamba/conv_w$", (None, None)),
    # norms / scalars / small vectors: replicated
    (r".*", None),
)


def _match_rule(path: str, ndim: int) -> Tuple:
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path):
            if spec is None:
                return tuple(None for _ in range(ndim))
            return spec
    return tuple(None for _ in range(ndim))


def param_pspec(path_keys: Sequence[Any], leaf: Any, *,
                stacked_marker: str = "layers/") -> P:
    """PartitionSpec for one parameter leaf given its tree path.  Works for
    params nested inside optimizer state too ("opt/m/layers/...")."""
    parts = []
    for k in path_keys:
        name = getattr(k, "key", None)
        parts.append(str(name if name is not None else k))
    path = "/".join(parts)
    ndim = leaf.ndim if hasattr(leaf, "ndim") else len(leaf.shape)
    stacked = stacked_marker in path  # matches layers/, enc_layers/, ...
    eff_ndim = ndim - 1 if stacked else ndim
    spec = _match_rule(path, eff_ndim)
    spec = tuple(spec[:eff_ndim]) + tuple(
        None for _ in range(eff_ndim - len(spec)))
    if stacked:
        spec = (None,) + spec  # leading n_periods axis replicated
    return P(*spec)


def _axis_size(mesh: Mesh, a) -> int:
    if a is None:
        return 1
    if isinstance(a, tuple):
        n = 1
        for x in a:
            n *= mesh.shape.get(x, 1)
        return n
    return mesh.shape.get(a, 1)


def fit_spec_to_shape(mesh: Mesh, spec, shape) -> P:
    """Drop axes missing from the mesh or not dividing the dimension —
    jit in_shardings require exact divisibility (odd vocab sizes like
    Whisper's 51865 fall back to replicated on that dim)."""
    fixed = []
    for i, a in enumerate(spec):
        if isinstance(a, tuple):
            a = tuple(x for x in a if x in mesh.axis_names) or None
        elif a is not None and a not in mesh.axis_names:
            a = None
        if a is not None and shape[i] % _axis_size(mesh, a) != 0:
            a = None
        fixed.append(a)
    return P(*fixed)


def params_shardings(mesh: Mesh, params_shape: Any,
                     profile: str = "tp") -> Any:
    """NamedShardings for a full params pytree (of arrays or
    ShapeDtypeStructs).  ``profile="dp"`` replicates every parameter (small
    models that over-shard on a 256-chip mesh — the whisper-tiny case)."""

    def one(path, leaf):
        if profile == "dp":
            return NamedSharding(mesh, P(*(None,) * len(leaf.shape)))
        spec = param_pspec(path, leaf)
        return NamedSharding(mesh, fit_spec_to_shape(mesh, spec, leaf.shape))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def cache_pspec(leaf_path: str, shape, mesh: Mesh) -> P:
    """KV / SSM cache sharding, shape-adaptive:

    * batch axis over (pod, data) when divisible; otherwise the sequence
      axis is sharded (long-context batch=1 cells);
    * kv-heads over model when divisible, else head_dim (flash-decoding
      style contraction sharding — GSPMD inserts the partial-softmax
      reductions).
    """
    ba = batch_axes(mesh)
    n_b = 1
    for a in ba:
        n_b *= mesh.shape[a]
    n_m = mesh.shape.get("model", 1)
    ndim = len(shape)

    if ndim == 5 and leaf_path.endswith(("k", "v")):
        _, B, S, G, hd = shape
        spec = [None, None, None, None, None]
        if B % n_b == 0:
            spec[1] = ba
        elif S % n_b == 0:
            spec[2] = ba
        if G % n_m == 0:
            spec[3] = "model"
        elif hd % n_m == 0:
            spec[4] = "model"
        return P(*spec)
    if leaf_path.endswith("ssm"):   # (n_periods, B, H, P, N)
        _, B, H = shape[0], shape[1], shape[2]
        return P(None, ba if B % n_b == 0 else None,
                 "model" if H % n_m == 0 else None, None, None)
    if leaf_path.endswith("conv"):  # (n_periods, B, K-1, conv_dim)
        B = shape[1]
        return P(None, ba if B % n_b == 0 else None,
                 *(None,) * (ndim - 2))
    return P(*(None,) * ndim)


def cache_shardings(mesh: Mesh, cache_shape: Any) -> Any:
    def one(path, leaf):
        parts = [str(getattr(k, "key", k)) for k in path]
        spec = cache_pspec("/".join(parts), tuple(leaf.shape), mesh)
        return NamedSharding(mesh, fit_spec_to_shape(mesh, spec, leaf.shape))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def batch_pspec(shape, mesh: Mesh, profile: str = "tp") -> P:
    """PartitionSpec for one input-batch leaf: leading axis over
    (pod, data) — or over *every* mesh axis under the ``dp`` profile;
    long-context batch-1 inputs fall back to sequence sharding over the
    ``data`` axis, but only when that axis exists *and* has size > 1
    (a size-1 or absent axis would attach a pointless — or invalid —
    ``P(None, "data", ...)`` constraint)."""
    ba = batch_axes(mesh) if profile != "dp" else tuple(mesh.axis_names)
    n_b = 1
    for a in ba:
        n_b *= mesh.shape[a]
    if not shape:  # scalars (decode position)
        return P()
    if n_b > 1 and shape[0] % n_b == 0 and shape[0] >= n_b:
        return P(ba, *(None,) * (len(shape) - 1))
    n_seq = mesh.shape.get("data", 1)
    if len(shape) >= 2 and n_seq > 1 and shape[1] % n_seq == 0:
        # batch too small: shard the sequence axis (long-context decode)
        return P(None, "data", *(None,) * (len(shape) - 2))
    return P(*(None,) * len(shape))


def batch_shardings(mesh: Mesh, batch_shape: Any,
                    profile: str = "tp") -> Any:
    """NamedShardings for an input batch pytree (see ``batch_pspec``)."""

    def one(leaf):
        return NamedSharding(mesh, batch_pspec(tuple(leaf.shape), mesh,
                                               profile))

    return jax.tree_util.tree_map(one, batch_shape)


# ---------------------------------------------------------------------------
# boundary-state (carry) sharding — the sharded-offload path
# ---------------------------------------------------------------------------


def state_pspec(shape, mesh: Mesh, spec: Optional[P] = None) -> P:
    """PartitionSpec for one boundary-state (carry) leaf.

    With an explicit ``spec`` (the ``OffloadConfig(state_spec=...)``
    override) the spec is padded/truncated to the leaf's rank and run
    through ``fit_spec_to_shape`` — same machinery as ``param_pspec``
    consumers, so axes missing from the mesh or not dividing the
    dimension degrade to replication instead of erroring.

    Without one, the derivation mirrors ``batch_pspec``'s leading-axis
    rule: carries are (batch, feature...) pytrees, so the leading axis
    shards over the batch axes when divisible and everything else
    replicates.  Scalars (loss accumulators) always replicate.
    """
    shape = tuple(shape)
    if spec is not None:
        padded = tuple(spec)[:len(shape)]
        padded = padded + (None,) * (len(shape) - len(padded))
        return fit_spec_to_shape(mesh, padded, shape)
    ba = batch_axes(mesh)
    n_b = 1
    for a in ba:
        n_b *= mesh.shape[a]
    if shape and n_b > 1 and shape[0] % n_b == 0 and shape[0] >= n_b:
        return P(ba, *(None,) * (len(shape) - 1))
    return P(*(None,) * len(shape))


def state_shardings(mesh: Mesh, state: Any,
                    spec: Optional[P] = None) -> Any:
    """NamedShardings for a boundary-state pytree — what the sharded
    Level-2 streams record and reassemble with."""

    def one(leaf):
        shape = tuple(getattr(leaf, "shape", ()) or ())
        return NamedSharding(mesh, state_pspec(shape, mesh, spec))

    return jax.tree_util.tree_map(one, state)


def chain_input_shardings(mesh: Mesh, xs: Any) -> Any:
    """NamedShardings for per-step chain inputs ``xs``: leaves are
    time-major ``(n, batch, ...)``, so axis 1 — not axis 0 — shards over
    the batch axes.  The time axis is never sharded (segments slice it
    on the host)."""
    ba = batch_axes(mesh)
    n_b = 1
    for a in ba:
        n_b *= mesh.shape[a]

    def one(leaf):
        shape = tuple(getattr(leaf, "shape", ()) or ())
        if len(shape) >= 2 and n_b > 1 and shape[1] % n_b == 0:
            return NamedSharding(mesh, P(None, ba,
                                         *(None,) * (len(shape) - 2)))
        return NamedSharding(mesh, P(*(None,) * len(shape)))

    return jax.tree_util.tree_map(one, xs)
