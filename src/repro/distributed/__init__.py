"""Distribution substrate: sharding rules, gradient compression, fault
tolerance and elastic re-meshing."""
from repro.distributed.sharding import (
    MeshContext, constrain, params_shardings, cache_shardings,
    batch_shardings, batch_axes,
)

__all__ = [
    "MeshContext", "constrain", "params_shardings", "cache_shardings",
    "batch_shardings", "batch_axes",
]
