"""Fault tolerance: straggler watchdog, elastic re-meshing, retry wrapper.

At 1000+ nodes the failure model is: (a) slow hosts (stragglers) that drag
every synchronous step, (b) lost hosts that kill the job.  The framework's
answers: per-step EMA timing with outlier detection (a), and
checkpoint/restart onto a *rebuilt* mesh from the surviving device count with
automatic state resharding (b) — combined with the async checkpointing in
``repro.ckpt`` the recovery path is restore-latest + elastic_mesh +
reshard_state.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

log = logging.getLogger("repro.ft")


@dataclass
class StragglerWatchdog:
    """EMA step-time tracker; flags steps slower than ``threshold`` x EMA.

    On a real pod each host feeds its own step time; here the single-process
    variant flags pathological steps (GC pauses, host interference) so the
    training loop can log and, on repeated hits, trigger a checkpoint.
    """

    alpha: float = 0.1
    threshold: float = 2.0
    warmup: int = 5
    ema: float = 0.0
    count: int = 0
    slow_steps: List[Tuple[int, float]] = field(default_factory=list)
    _t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> bool:
        """Returns True if this step was a straggler."""
        assert self._t0 is not None
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self.count += 1
        if self.count <= self.warmup:
            self.ema = dt if self.ema == 0 else (
                self.alpha * dt + (1 - self.alpha) * self.ema)
            return False
        slow = dt > self.threshold * self.ema
        if slow:
            self.slow_steps.append((step, dt))
            log.warning("straggler: step %d took %.3fs (ema %.3fs)",
                        step, dt, self.ema)
        else:
            self.ema = self.alpha * dt + (1 - self.alpha) * self.ema
        return slow


def elastic_mesh(n_alive: int, *, model_parallelism: int = 16,
                 axis_names: Tuple[str, ...] = ("data", "model"),
                 devices: Optional[list] = None) -> Mesh:
    """Largest (data, model) mesh buildable from the surviving devices.

    Keeps the model axis fixed (TP degree is a property of the sharded
    weights' layout) and shrinks the data axis — dropping at most
    ``model_parallelism - 1`` devices.
    """
    devices = devices if devices is not None else jax.devices()
    n_alive = min(n_alive, len(devices))
    if n_alive < 1:
        raise RuntimeError(f"cannot build a mesh from {n_alive} devices")
    tp = max(1, min(model_parallelism, n_alive))
    dp = n_alive // tp
    if dp < 1:
        raise RuntimeError(f"cannot build a mesh from {n_alive} devices")
    use = devices[: dp * tp]
    import numpy as np
    arr = np.array(use).reshape(dp, tp)
    return Mesh(arr, axis_names)


def reshard_state(state: Any, new_mesh: Mesh, pspec_fn: Callable) -> Any:
    """Re-place a restored state pytree onto a new mesh (elastic restart)."""

    def one(path, leaf):
        spec = pspec_fn(path, leaf)
        fixed = tuple(a if (a is None or a in new_mesh.axis_names) else None
                      for a in spec)
        return jax.device_put(leaf, NamedSharding(new_mesh, P(*fixed)))

    return jax.tree_util.tree_map_with_path(one, state)


def with_retries(fn: Callable, *, retries: int = 3,
                 on_retry: Optional[Callable[[int, Exception], None]] = None,
                 recover: Optional[Callable[[int, Exception], None]] = None):
    """Retry wrapper for steps that may die to transient runtime errors
    (preemption, DMA timeout, Level-2 storage faults — the typed
    ``repro.core.faults.StorageFault`` hierarchy subclasses RuntimeError
    precisely so it lands here).  Deterministic data + checkpointed state
    make the retried step bit-identical.

    ``recover(attempt, err)`` runs *before* each re-attempt (after
    ``on_retry``, which is notification-only): hook the job's recovery
    path into it — e.g. restore the train state from
    ``ckpt.CheckpointManager`` and let the offloaded-gradient journal
    (``OffloadConfig(journal_dir=...)``) resume the crashed sweep from its
    last durable boundary, so the retried step reproduces the gradient it
    would have produced, bit for bit.  An exception from ``recover``
    aborts the retry loop (a broken recovery path must not silently spin).
    """

    def wrapped(*a, **kw):
        for attempt in range(retries + 1):
            try:
                return fn(*a, **kw)
            except (RuntimeError, jax.errors.JaxRuntimeError) as e:
                if attempt == retries:
                    raise
                if on_retry is not None:
                    on_retry(attempt, e)
                log.warning("retry %d after %s", attempt + 1, e)
                if recover is not None:
                    recover(attempt, e)

    return wrapped
