"""Vanilla LSTM for text generation — the paper's §5 test case.

Faithful to the paper's experiment: a single-cell LSTM unrolled over the
sequence (one *recurrence* == one chain step == one checkpoint), char/token
prediction loss at every step, trained with RMSProp.  The chain state is
``(h, c, loss_acc)``; carrying the loss accumulator in the state lets the
checkpointing executor treat the whole thing as a pure chain with adjoint
seed ``(0, 0, 1)`` — no special-casing of the final step.

Two execution paths, both exposed here:

* ``make_operators`` — jitted forward/backward operators for
  ``repro.core.executor.CheckpointExecutor`` (the paper-faithful library
  path: Revolve / async multistage driven from the host).
* ``bptt_loss_and_grad`` — the compiled path via
  ``repro.core.multistage_scan`` (XLA offload on TPU).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.multistage_scan import multistage_scan

Params = Any


def init_lstm(key, vocab: int, d_embed: int, d_hidden: int,
              dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    scale = (d_embed + d_hidden) ** -0.5
    return {
        "emb": jax.random.normal(k1, (vocab, d_embed), dtype) * 0.1,
        "w": jax.random.normal(k2, (d_embed + d_hidden, 4 * d_hidden), dtype) * scale,
        "b": jnp.zeros((4 * d_hidden,), dtype),
        "w_out": jax.random.normal(k3, (d_hidden, vocab), dtype) * (d_hidden ** -0.5),
        "b_out": jnp.zeros((vocab,), dtype),
    }


def lstm_cell(params: Params, h: jnp.ndarray, c: jnp.ndarray,
              x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One LSTM recurrence.  x: (B, d_embed) input embedding."""
    z = jnp.concatenate([x, h], axis=-1) @ params["w"] + params["b"]
    i, f, o, g = jnp.split(z, 4, axis=-1)
    c_new = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def step_loss(params: Params, h: jnp.ndarray, c: jnp.ndarray,
              tok: jnp.ndarray, target: jnp.ndarray):
    """One chain step: consume token ``tok``, predict ``target``.
    Returns (h', c', nll)."""
    x = params["emb"][tok]
    h, c = lstm_cell(params, h, c, x)
    logits = h @ params["w_out"] + params["b_out"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, target[:, None], axis=-1)[:, 0]
    return h, c, jnp.mean(lse - gold)


def init_state(batch: int, d_hidden: int, dtype=jnp.float32):
    z = jnp.zeros((batch, d_hidden), dtype)
    return (z, z, jnp.float32(0.0))


# ---------------------------------------------------------------------------
# Executor path (paper-faithful)
# ---------------------------------------------------------------------------


def make_operators(params: Params, tokens: jnp.ndarray):
    """Build (forward_op, backward_op, grad_extract) for the checkpoint
    executor.  ``tokens``: (B, T+1) — step k consumes tokens[:, k], predicts
    tokens[:, k+1].  The adjoint is ``(dstate, grads_accum)``.
    """
    T = tokens.shape[1] - 1

    @jax.jit
    def fwd(state, k):
        h, c, acc = state
        h, c, nll = step_loss(params, h, c, tokens[:, k], tokens[:, k + 1])
        return (h, c, acc + nll)

    def _step(p, state, k):
        h, c, acc = state
        h, c, nll = step_loss(p, h, c, tokens[:, k], tokens[:, k + 1])
        return (h, c, acc + nll)

    @jax.jit
    def bwd(state, adjoint, k):
        dstate, gacc = adjoint
        _, vjp = jax.vjp(lambda p, s: _step(p, s, k), params, state)
        gp, ds = vjp(dstate)
        gacc = jax.tree_util.tree_map(jnp.add, gacc, gp)
        return (ds, gacc)

    def adjoint_seed():
        zero_g = jax.tree_util.tree_map(jnp.zeros_like, params)
        # dstate mirrors (h, c, acc): zeros for h/c, 1.0 for the loss accum.
        h0, c0, _ = init_state(tokens.shape[0], params["w"].shape[1] // 4)
        return ((jnp.zeros_like(h0), jnp.zeros_like(c0), jnp.float32(1.0)),
                zero_g)

    return fwd, bwd, adjoint_seed, T


def forward_loss(params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    """Plain scan reference (used to validate the executor paths)."""
    B, Tp1 = tokens.shape
    h0, c0, acc0 = init_state(B, params["w"].shape[1] // 4)

    def body(carry, k):
        h, c, acc = carry
        h, c, nll = step_loss(params, h, c, tokens[:, k], tokens[:, k + 1])
        return (h, c, acc + nll), None

    (h, c, acc), _ = jax.lax.scan(body, (h0, c0, acc0),
                                  jnp.arange(Tp1 - 1))
    return acc


# ---------------------------------------------------------------------------
# Chain decomposition (repro.api): time is the checkpoint chain
# ---------------------------------------------------------------------------


def train_chain(cfg=None):
    """``repro.api.ChainSpec`` for :func:`forward_loss`: one recurrence per
    chain step (the paper's §5 setup), carry ``(h, c, loss_acc)``, per-step
    inputs the (non-differentiated) token/target columns."""
    from repro.api.chain import ChainSpec

    def prelude(params, batch):
        tokens = batch["tokens"]
        B = tokens.shape[0]
        carry0 = init_state(B, params["w"].shape[1] // 4)
        xs = (tokens[:, :-1].T, tokens[:, 1:].T)  # (T, B) each
        return carry0, xs

    def body(params, carry, x, batch):
        h, c, acc = carry
        tok, tgt = x
        h, c, nll = step_loss(params, h, c, tok, tgt)
        return (h, c, acc + nll)

    def readout(params, carry, batch):
        return carry[2]

    name = f"{cfg.name}-time" if cfg is not None else "lstm-time"
    return ChainSpec(prelude, body, readout, name=name)


# ---------------------------------------------------------------------------
# Compiled path (multistage_scan)
# ---------------------------------------------------------------------------


def bptt_loss_and_grad(params: Params, tokens: jnp.ndarray, *,
                       interval: int, offload: bool = True,
                       nested_intervals=()):
    """Loss+grad over the full sequence using the compiled multistage path."""
    B, Tp1 = tokens.shape
    T = Tp1 - 1
    h0, c0, _ = init_state(B, params["w"].shape[1] // 4)
    xs = (tokens[:, :-1].T, tokens[:, 1:].T)  # (T, B) each

    def total(p):
        def body(carry, x):
            h, c = carry
            tok, tgt = x
            h, c, nll = step_loss(p, h, c, tok, tgt)
            return (h, c), nll

        _, nlls = multistage_scan(body, (h0, c0), xs, interval=interval,
                                  offload=offload,
                                  nested_intervals=nested_intervals)
        return jnp.sum(nlls)

    return jax.value_and_grad(total)(params)
