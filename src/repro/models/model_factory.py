"""Uniform model API over all families.

``get_model(cfg)`` returns a ``ModelAPI`` with four pure functions:

    init(key)                      -> params
    train_loss(params, batch)      -> scalar loss
    prefill(params, batch)         -> (logits, cache)
    decode(params, cache, batch)   -> (logits, new_cache)

``batch`` layouts per kind are produced by ``repro.configs.shapes.input_specs``
(ShapeDtypeStructs for the dry-run) and ``repro.data`` (real arrays).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from repro.configs.base import ArchConfig
from repro.models import encdec, lstm, transformer, vlm

Params = Any


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ArchConfig
    init: Callable
    train_loss: Callable
    prefill: Callable
    decode: Callable
    init_cache: Callable  # (batch, max_len) -> cache pytree
    # ChainSpec decomposition of train_loss for repro.api's offloaded
    # autodiff (None when the family has no uniform chain structure yet).
    train_chain: Any = None
    # Pytree matching init_cache's structure with models.cache.CacheAxes
    # leaves — declares which cache leaves carry a sequence axis and where,
    # so the serving layer can grow/slot caches without ndim sniffing.
    cache_spec: Any = None


def _attach_chain(loss_fn: Callable, chain_spec) -> Callable:
    """Tag a loss callable with its chain decomposition so
    ``repro.api.value_and_grad_offloaded(api.train_loss)`` just works."""
    if chain_spec is not None:
        loss_fn.chain_spec = chain_spec
    return loss_fn


def get_model(cfg: ArchConfig) -> ModelAPI:
    if cfg.family in ("dense", "moe", "hybrid", "ssm"):
        chain = transformer.train_chain(cfg)
        return ModelAPI(
            cfg=cfg,
            init=lambda key: transformer.init_lm(key, cfg),
            train_loss=_attach_chain(
                lambda p, b: transformer.train_loss(p, b, cfg), chain),
            prefill=lambda p, b: transformer.prefill(p, b["tokens"], cfg),
            decode=lambda p, c, b: transformer.decode(
                p, c, b["tokens"], b["pos"], cfg),
            init_cache=lambda batch, max_len: transformer.init_cache(
                cfg, batch, max_len),
            train_chain=chain,
            cache_spec=transformer.cache_spec(cfg),
        )
    if cfg.family == "vlm":
        return ModelAPI(
            cfg=cfg,
            init=lambda key: vlm.init_vlm(key, cfg),
            train_loss=lambda p, b: vlm.train_loss(p, b, cfg),
            prefill=lambda p, b: vlm.prefill(p, b, cfg),
            decode=lambda p, c, b: transformer.decode(
                p, c, b["tokens"], b["pos"], cfg),
            init_cache=lambda batch, max_len: transformer.init_cache(
                cfg, batch, max_len),
            cache_spec=transformer.cache_spec(cfg),
        )
    if cfg.family == "encdec":
        return ModelAPI(
            cfg=cfg,
            init=lambda key: encdec.init_encdec(key, cfg),
            train_loss=lambda p, b: encdec.train_loss(p, b, cfg),
            prefill=lambda p, b: encdec.prefill(p, b, cfg,
                                                max_len=b["tokens"].shape[1]),
            decode=lambda p, c, b: encdec.decode(
                p, c, b["tokens"], b["pos"], cfg),
            init_cache=lambda batch, max_len: encdec.init_cache(
                cfg, batch, max_len, s_enc=1500),
            cache_spec=encdec.cache_spec(cfg),
        )
    if cfg.family == "lstm":
        def _loss(p, b):
            return lstm.forward_loss(p, b["tokens"])

        chain = lstm.train_chain(cfg)
        return ModelAPI(
            cfg=cfg,
            init=lambda key: lstm.init_lstm(key, cfg.vocab, cfg.d_model,
                                            cfg.d_ff),
            train_loss=_attach_chain(_loss, chain),
            prefill=None, decode=None, init_cache=None,
            train_chain=chain,
        )
    raise ValueError(f"unknown family {cfg.family!r}")
