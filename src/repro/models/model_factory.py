"""Uniform model API over all families.

``get_model(cfg)`` returns a ``ModelAPI`` with four pure functions:

    init(key)                      -> params
    train_loss(params, batch)      -> scalar loss
    prefill(params, batch)         -> (logits, cache)
    decode(params, cache, batch)   -> (logits, new_cache)

``batch`` layouts per kind are produced by ``repro.configs.shapes.input_specs``
(ShapeDtypeStructs for the dry-run) and ``repro.data`` (real arrays).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, lstm, transformer, vlm

Params = Any


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ArchConfig
    init: Callable
    train_loss: Callable
    prefill: Callable
    decode: Callable
    init_cache: Callable  # (batch, max_len) -> cache pytree


def get_model(cfg: ArchConfig) -> ModelAPI:
    if cfg.family in ("dense", "moe", "hybrid", "ssm"):
        return ModelAPI(
            cfg=cfg,
            init=lambda key: transformer.init_lm(key, cfg),
            train_loss=lambda p, b: transformer.train_loss(p, b, cfg),
            prefill=lambda p, b: transformer.prefill(p, b["tokens"], cfg),
            decode=lambda p, c, b: transformer.decode(
                p, c, b["tokens"], b["pos"], cfg),
            init_cache=lambda batch, max_len: transformer.init_cache(
                cfg, batch, max_len),
        )
    if cfg.family == "vlm":
        return ModelAPI(
            cfg=cfg,
            init=lambda key: vlm.init_vlm(key, cfg),
            train_loss=lambda p, b: vlm.train_loss(p, b, cfg),
            prefill=lambda p, b: vlm.prefill(p, b, cfg),
            decode=lambda p, c, b: transformer.decode(
                p, c, b["tokens"], b["pos"], cfg),
            init_cache=lambda batch, max_len: transformer.init_cache(
                cfg, batch, max_len),
        )
    if cfg.family == "encdec":
        return ModelAPI(
            cfg=cfg,
            init=lambda key: encdec.init_encdec(key, cfg),
            train_loss=lambda p, b: encdec.train_loss(p, b, cfg),
            prefill=lambda p, b: encdec.prefill(p, b, cfg,
                                                max_len=b["tokens"].shape[1]),
            decode=lambda p, c, b: encdec.decode(
                p, c, b["tokens"], b["pos"], cfg),
            init_cache=lambda batch, max_len: encdec.init_cache(
                cfg, batch, max_len, s_enc=1500),
        )
    if cfg.family == "lstm":
        def _loss(p, b):
            return lstm.forward_loss(p, b["tokens"])

        return ModelAPI(
            cfg=cfg,
            init=lambda key: lstm.init_lstm(key, cfg.vocab, cfg.d_model,
                                            cfg.d_ff),
            train_loss=_loss,
            prefill=None, decode=None, init_cache=None,
        )
    raise ValueError(f"unknown family {cfg.family!r}")
