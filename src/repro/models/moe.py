"""Feed-forward layers: dense gated MLPs and Mixture-of-Experts.

Two MoE dispatch implementations, both capacity-based and fully static-shaped
(GSPMD-friendly):

* ``einsum`` — GShard-style one-hot dispatch/combine einsums.  The classic
  TPU formulation; simple and robust, but the (tokens x experts x capacity)
  dispatch einsums cost O(k * N^2 * d / E) FLOPs — visible in the roofline's
  useful-compute ratio.
* ``sorted``  — argsort-based bucketing: tokens are sorted by expert, gathered
  into (E, C) buckets, run through a batched expert matmul, and scattered
  back.  Same numerics for non-dropped tokens, ~O(N log N) dispatch cost.
  This is the beyond-paper optimisation evaluated in EXPERIMENTS §Perf.

Routing: top-k softmax gating with optional renormalisation, load-balance aux
loss (Switch/GShard), deterministic tie-breaking, token dropping at capacity.
"""
from __future__ import annotations

import math
from typing import Any, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.layers import (
    DTypes, DEFAULT_DTYPES, dense, init_dense, swiglu, geglu,
)

Params = Any


# ---------------------------------------------------------------------------
# dense gated MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, *, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_dense(k1, d_model, d_ff, dtype=dtype),
        "up": init_dense(k2, d_model, d_ff, dtype=dtype),
        "down": init_dense(k3, d_ff, d_model, dtype=dtype),
    }


def mlp(p: Params, x: jnp.ndarray, *, act: str = "silu",
        dt: DTypes = DEFAULT_DTYPES) -> jnp.ndarray:
    from repro.distributed.sharding import constrain
    g, u = dense(p["gate"], x, dt), dense(p["up"], x, dt)
    g, u = constrain(g, "proj"), constrain(u, "proj")  # zero3 (no-op unless on)
    h = swiglu(g, u) if act == "silu" else geglu(g, u)
    return dense(p["down"], h, dt)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def init_moe(key, d_model: int, d_ff: int, n_experts: int, *,
             shared_expert: bool = False, shared_d_ff: Optional[int] = None,
             dtype=jnp.float32) -> Params:
    kr, ke, ks = jax.random.split(key, 3)
    scale = d_model ** -0.5
    keys = jax.random.split(ke, 3)
    p = {
        "router": init_dense(kr, d_model, n_experts, dtype=dtype),
        # stacked experts: leading expert axis (EP shards this axis)
        "w_gate": jax.random.normal(keys[0], (n_experts, d_model, d_ff), dtype) * scale,
        "w_up": jax.random.normal(keys[1], (n_experts, d_model, d_ff), dtype) * scale,
        "w_down": jax.random.normal(keys[2], (n_experts, d_ff, d_model), dtype) * (d_ff ** -0.5),
    }
    if shared_expert:
        p["shared"] = init_mlp(ks, d_model, shared_d_ff or d_ff, dtype=dtype)
    return p


def _route(p, xg, n_experts: int, top_k: int):
    """Top-k softmax routing.  xg: (G, S, d) grouped tokens.  Returns
    (weights (G,S,k), indices (G,S,k), aux_loss)."""
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, n_experts, dtype=jnp.float32), axis=2),
        axis=(0, 1))
    aux = n_experts * jnp.sum(me * ce)
    return weights, idx, aux


def _capacity(group_tokens: int, n_experts: int, top_k: int,
              capacity_factor: float) -> int:
    c = int(math.ceil(group_tokens * top_k * capacity_factor / n_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 for tiling


def _expert_ffn(p, expert_in: jnp.ndarray, act: str,
                dt: DTypes) -> jnp.ndarray:
    """expert_in: (..., E, C, d) -> same, via the stacked expert weights."""
    g = jnp.einsum("...ecd,edf->...ecf", expert_in, dt.c(p["w_gate"]))
    u = jnp.einsum("...ecd,edf->...ecf", expert_in, dt.c(p["w_up"]))
    h = swiglu(g, u) if act == "silu" else geglu(g, u)
    return jnp.einsum("...ecf,efd->...ecd", h, dt.c(p["w_down"]))


def moe_einsum(p: Params, x: jnp.ndarray, *, n_experts: int, top_k: int,
               capacity_factor: float = 1.25, act: str = "silu",
               dt: DTypes = DEFAULT_DTYPES, with_stats: bool = False):
    """GShard one-hot dispatch, *grouped*: each batch row is one expert group
    with its own capacity (the standard GSPMD-shardable formulation — the
    group axis shards over data, the expert axis over model).
    x: (B, S, d).  Returns (y, aux_loss), or (y, aux_loss, stats) with
    ``with_stats=True`` — ``stats`` holds per-expert routed/kept counts and
    the ``dropped_tokens`` overflow that :func:`_capacity` would otherwise
    drop silently (see :func:`routing_stats`)."""
    G, S, d = x.shape
    xg = x
    weights, idx, aux = _route(p, xg, n_experts, top_k)
    C = _capacity(S, n_experts, top_k, capacity_factor)

    dispatch = jnp.zeros((G, S, n_experts, C), dtype=dt.compute)
    combine = jnp.zeros((G, S, n_experts, C), dtype=jnp.float32)
    prior = jnp.zeros((G, n_experts), jnp.int32)
    routed_e = jnp.zeros((n_experts,), jnp.int32)
    kept_e = jnp.zeros((n_experts,), jnp.int32)
    for i in range(top_k):
        mask_i = jax.nn.one_hot(idx[..., i], n_experts, dtype=jnp.int32)
        pos_i = jnp.cumsum(mask_i, axis=1) - 1 + prior[:, None, :]
        prior = prior + jnp.sum(mask_i, axis=1)
        keep = (pos_i < C) & (mask_i > 0)
        if with_stats:
            routed_e = routed_e + jnp.sum(mask_i, axis=(0, 1))
            kept_e = kept_e + jnp.sum(keep.astype(jnp.int32), axis=(0, 1))
        oh_pos = jax.nn.one_hot(jnp.where(keep, pos_i, C), C + 1,
                                dtype=dt.compute)[..., :C]  # (G,S,E,C)
        d_i = oh_pos * keep.astype(dt.compute)[..., None]
        dispatch = dispatch + d_i
        combine = combine + d_i.astype(jnp.float32) * \
            weights[..., i, None, None]

    expert_in = jnp.einsum("gsd,gsec->gecd", xg.astype(dt.compute), dispatch)
    expert_out = _expert_ffn(p, expert_in, act, dt)
    y = jnp.einsum("gecd,gsec->gsd", expert_out.astype(jnp.float32), combine)
    y = y.astype(x.dtype)
    if "shared" in p:
        y = y + mlp(p["shared"], xg, act=act, dt=dt)
    if with_stats:
        stats = {"expert_counts": kept_e, "routed_counts": routed_e,
                 "dropped_tokens": jnp.sum(routed_e - kept_e),
                 "capacity": C}
        return y, aux, stats
    return y, aux


def moe_sorted(p: Params, x: jnp.ndarray, *, n_experts: int, top_k: int,
               capacity_factor: float = 1.25, act: str = "silu",
               dt: DTypes = DEFAULT_DTYPES, with_stats: bool = False):
    """Sort-based dispatch: same grouping/capacity semantics as
    ``moe_einsum`` (up to drop order) without the O(S*E*C) one-hot dispatch
    tensors.  Dispatch AND combine are pure gathers: the combine uses the
    inverse sort permutation to look up each token's k expert-output slots
    (a scatter-add here replicates under GSPMD and floods the mesh with
    all-reduces — measured in EXPERIMENTS §Perf, llama4 round 1).
    ``with_stats=True`` appends the same routed/kept/``dropped_tokens``
    stats dict as :func:`moe_einsum` (drop *order* differs between the two
    impls, but the per-expert counts are identical)."""
    G, S, d = x.shape
    weights, idx, aux = _route(p, x, n_experts, top_k)
    C = _capacity(S, n_experts, top_k, capacity_factor)

    def one_group(xg, ig):
        # xg: (S, d); ig: (S, k)
        flat_e = ig.reshape(-1)                      # (S*k,)
        flat_tok = jnp.repeat(jnp.arange(S), top_k)
        order = jnp.argsort(flat_e, stable=True)
        inv = jnp.argsort(order)                     # slot -> sorted pos
        se, st = flat_e[order], flat_tok[order]
        counts = jnp.bincount(flat_e, length=n_experts)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(S * top_k) - starts[se]
        keep = rank < C
        # expert input buckets (E, C): token index per slot (S == empty)
        bucket_tok = jnp.full((n_experts, C), S, jnp.int32)
        bucket_tok = bucket_tok.at[se, jnp.where(keep, rank, 0)].set(
            jnp.where(keep, st, S).astype(jnp.int32), mode="drop")
        # inverse map: original slot j -> flat bucket position (E*C = dropped)
        pos = inv
        slot_bucket = jnp.where(keep[pos], se[pos] * C + rank[pos],
                                n_experts * C).astype(jnp.int32)  # (S*k,)
        # kept per expert: routed count clamped at capacity (sorted ranks
        # are contiguous per expert, so exactly min(count, C) slots keep)
        kept = jnp.minimum(counts, C)
        return bucket_tok, slot_bucket, counts, kept

    bucket_tok, slot_bucket, routed_g, kept_g = \
        jax.vmap(one_group)(x, idx)                  # (G,E,C),(G,S*k),(G,E)x2
    x_pad = jnp.concatenate(
        [x.astype(dt.compute), jnp.zeros((G, 1, d), dt.compute)], axis=1)
    expert_in = jnp.take_along_axis(
        x_pad[:, :, None, :], bucket_tok.reshape(G, -1, 1, 1), axis=1
    ).reshape(G, n_experts, C, d)
    expert_out = _expert_ffn(p, expert_in, act, dt)
    # combine: gather each token's k slots from the flat expert outputs
    out_flat = jnp.concatenate(
        [expert_out.reshape(G, n_experts * C, d),
         jnp.zeros((G, 1, d), expert_out.dtype)], axis=1)
    tok_out = jnp.take_along_axis(
        out_flat[:, :, None, :], slot_bucket.reshape(G, -1, 1, 1), axis=1
    ).reshape(G, S, top_k, d)
    y = jnp.einsum("gskd,gsk->gsd", tok_out.astype(jnp.float32),
                   weights).astype(x.dtype)
    if "shared" in p:
        y = y + mlp(p["shared"], x, act=act, dt=dt)
    if with_stats:
        routed_e = jnp.sum(routed_g, axis=0).astype(jnp.int32)
        kept_e = jnp.sum(kept_g, axis=0).astype(jnp.int32)
        stats = {"expert_counts": kept_e, "routed_counts": routed_e,
                 "dropped_tokens": jnp.sum(routed_e - kept_e),
                 "capacity": C}
        return y, aux, stats
    return y, aux


def moe_apply(p: Params, x: jnp.ndarray, *, n_experts: int, top_k: int,
              capacity_factor: float = 1.25, act: str = "silu",
              impl: str = "einsum", dt: DTypes = DEFAULT_DTYPES,
              with_stats: bool = False):
    """Dispatch to the selected MoE impl.  Returns ``(y, aux_loss)``, or
    ``(y, aux_loss, stats)`` with ``with_stats=True`` — the opt-in keeps the
    two-tuple contract every existing caller (``models.transformer._ffn``)
    relies on, while making the capacity overflow observable: ``stats``
    carries ``dropped_tokens`` (tokens silently zeroed by :func:`_capacity`)
    plus per-expert ``expert_counts``/``routed_counts``."""
    fn = {"einsum": moe_einsum, "sorted": moe_sorted}[impl]
    return fn(p, x, n_experts=n_experts, top_k=top_k,
              capacity_factor=capacity_factor, act=act, dt=dt,
              with_stats=with_stats)


def routing_stats(p: Params, x, *, n_experts: int, top_k: int,
                  capacity_factor: float = 1.25) -> dict:
    """Host-side routing statistics of one MoE layer application — the
    load-accurate export the plan-aware expert streamer consumes
    (:func:`repro.core.schedule.expert_access_plan` orders each step's
    experts busiest-first from these counts).

    Returns plain-numpy ``{"expert_counts", "routed_counts",
    "dropped_tokens", "capacity"}``; ``expert_counts`` are post-capacity
    *kept* loads, so dropped overflow tokens never inflate an expert's
    apparent heat (the satellite fix to ``_capacity``'s silent drop)."""
    x = jnp.asarray(x)
    G, S, _ = x.shape
    _, idx, _ = _route(p, x, n_experts, top_k)
    C = _capacity(S, n_experts, top_k, capacity_factor)
    prior = jnp.zeros((G, n_experts), jnp.int32)
    routed_e = jnp.zeros((n_experts,), jnp.int32)
    kept_e = jnp.zeros((n_experts,), jnp.int32)
    for i in range(top_k):
        mask_i = jax.nn.one_hot(idx[..., i], n_experts, dtype=jnp.int32)
        pos_i = jnp.cumsum(mask_i, axis=1) - 1 + prior[:, None, :]
        prior = prior + jnp.sum(mask_i, axis=1)
        keep = (pos_i < C) & (mask_i > 0)
        routed_e = routed_e + jnp.sum(mask_i, axis=(0, 1))
        kept_e = kept_e + jnp.sum(keep.astype(jnp.int32), axis=(0, 1))
    kept = np.asarray(kept_e)
    routed = np.asarray(routed_e)
    return {"expert_counts": kept, "routed_counts": routed,
            "dropped_tokens": int(routed.sum() - kept.sum()),
            "capacity": int(C)}
