"""Model substrate: pure-functional JAX definitions for every assigned
architecture family (dense / MoE / hybrid / SSM decoder LMs, encoder-decoder,
VLM, and the paper's LSTM)."""
from repro.models.model_factory import ModelAPI, get_model

__all__ = ["ModelAPI", "get_model"]
