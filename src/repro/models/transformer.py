"""Decoder-only LM assembly covering the dense / MoE / hybrid / SSM families.

The layer stack is organised in *periods* (``cfg.layer_pattern``): parameters
for each pattern position are stacked over ``n_periods`` and the stack is a
single ``lax.scan`` whose carry is the hidden state — i.e. the model depth is
literally the paper's checkpoint chain, with uniform per-period states.  The
remat/offload policy (``cfg.remat_policy``) decides where each period
boundary lives (HBM / pinned host), turning the paper's asynchronous
multistage checkpointing into a one-line config knob.

Three entry points per model: ``train_loss``, ``prefill`` and ``decode``
(one token against caches).  All are pure functions of (params, batch).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.core.layer_policy import remat_layer
from repro.distributed.sharding import constrain
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    DTypes, chunked_ce_loss, embed, init_embedding, init_rmsnorm, lm_logits,
    rmsnorm, rope_table,
)

Params = Any


def _dtypes(cfg: ArchConfig) -> DTypes:
    return DTypes(compute=jnp.bfloat16)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ArchConfig, kind: str) -> Params:
    d = cfg.d_model
    keys = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": init_rmsnorm(d)}
    if kind.startswith("attn"):
        p["attn"] = attn_mod.init_attention(
            keys[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            qkv_bias=cfg.qkv_bias)
    else:  # mamba
        s = cfg.ssm
        p["mamba"] = ssm_mod.init_mamba2(
            keys[0], d, d_state=s.d_state, headdim=s.headdim,
            expand=s.expand, ngroups=s.ngroups, conv_k=s.conv_k)
    if cfg.use_post_norm:
        p["ln1_post"] = init_rmsnorm(d)
    has_ffn = kind in ("attn", "attn_local", "attn_moe", "mamba_moe")
    if has_ffn:
        p["ln2"] = init_rmsnorm(d)
        if kind.endswith("_moe"):
            p["moe"] = moe_mod.init_moe(
                keys[1], d, cfg.d_ff, cfg.moe.n_experts,
                shared_expert=cfg.moe.shared_expert)
        else:
            p["mlp"] = moe_mod.init_mlp(keys[1], d, cfg.d_ff)
        if cfg.use_post_norm:
            p["ln2_post"] = init_rmsnorm(d)
    return p


def init_lm(key, cfg: ArchConfig) -> Params:
    ke, kl, ku = jax.random.split(key, 3)
    params: Dict[str, Any] = {
        "embed": init_embedding(ke, cfg.padded_vocab, cfg.d_model),
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = init_embedding(ku, cfg.padded_vocab, cfg.d_model)
    layer_keys = jax.random.split(kl, cfg.period)
    layers = {}
    for j, kind in enumerate(cfg.layer_pattern):
        pkeys = jax.random.split(layer_keys[j], cfg.n_periods)
        layers[f"pos{j}"] = jax.vmap(
            lambda k: _init_layer(k, cfg, kind))(pkeys)
    params["layers"] = layers
    return params


def unembed_weight(params: Params, cfg: ArchConfig) -> jnp.ndarray:
    return (params["embed"]["emb"] if cfg.tie_embeddings
            else params["unembed"]["emb"])


# ---------------------------------------------------------------------------
# layer application (full-sequence path)
# ---------------------------------------------------------------------------


def _post(p, name, y, cfg, dt):
    return rmsnorm(p[name], y, dt=dt) if cfg.use_post_norm else y


def _ffn(p, h, kind, cfg, dt):
    if not any(k in p for k in ("mlp", "moe")):
        return h, jnp.float32(0.0)
    y = rmsnorm(p["ln2"], h, dt=dt)
    if "moe" in p:
        y, aux = moe_mod.moe_apply(
            p["moe"], y, n_experts=cfg.moe.n_experts, top_k=cfg.moe.top_k,
            capacity_factor=cfg.moe.capacity_factor, act=cfg.mlp_act,
            impl=cfg.moe_impl, dt=dt)
    else:
        y, aux = moe_mod.mlp(p["mlp"], y, act=cfg.mlp_act, dt=dt), jnp.float32(0.0)
    return h + _post(p, "ln2_post", y, cfg, dt), aux


def _apply_layer_seq(p, x, kind, cfg: ArchConfig, rope, dt,
                     causal: bool = True):
    """Full-sequence layer (training / prefill compute, no cache output)."""
    y = rmsnorm(p["ln1"], x, dt=dt)
    if kind.startswith("attn"):
        window = cfg.window if kind == "attn_local" else None
        y = attn_mod.attention(
            p["attn"], y, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, rope=rope, causal=causal, window=window,
            softcap=cfg.attn_softcap, scale=cfg.query_scale,
            chunk=cfg.attn_chunk, dt=dt)
    else:
        s = cfg.ssm
        y = ssm_mod.mamba2_block(
            p["mamba"], y, d_state=s.d_state, headdim=s.headdim,
            expand=s.expand, ngroups=s.ngroups, conv_k=s.conv_k,
            chunk=s.chunk, dt=dt)
    h = x + _post(p, "ln1_post", y, cfg, dt)
    return _ffn(p, h, kind, cfg, dt)


def _scan_stack(params, x, cfg: ArchConfig, rope, dt, causal=True):
    """Scan the period-stacked layers; returns (x, total_aux)."""

    def period_body(lp, x):
        aux_t = jnp.float32(0.0)
        for j, kind in enumerate(cfg.layer_pattern):
            x, aux = _apply_layer_seq(lp[f"pos{j}"], x, kind, cfg, rope, dt,
                                      causal)
            aux_t += aux
        return x, aux_t

    wrapped = remat_layer(period_body, cfg.remat_policy, tag_input=True)

    def body(carry, lp):
        x, aux_t = carry
        x, aux = wrapped(lp, x)
        return (x, aux_t + aux), None

    (x, aux_t), _ = lax.scan(body, (x, jnp.float32(0.0)), params["layers"],
                             unroll=cfg.scan_unroll)
    return x, aux_t


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


def train_loss(params: Params, batch: Dict[str, jnp.ndarray],
               cfg: ArchConfig) -> jnp.ndarray:
    """batch["tokens"]: (B, S+1) int32.  Mean next-token NLL (+ MoE aux)."""
    dt = _dtypes(cfg)
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    S = inp.shape[1]
    h = embed(params["embed"], inp, dt)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, dt.compute)
    h = constrain(h, "act")
    rope = rope_table(S, cfg.hd, cfg.rope_theta)
    h, aux = _scan_stack(params, h, cfg, rope, dt)
    h = rmsnorm(params["final_norm"], h, dt=dt)
    loss = chunked_ce_loss(h, unembed_weight(params, cfg), labels,
                           chunk=cfg.ce_chunk, logit_cap=cfg.logit_softcap,
                           mask=batch.get("mask"),
                           valid_vocab=cfg.vocab)
    coef = cfg.moe.aux_coef if cfg.moe else 0.0
    return loss + coef * aux / max(1, cfg.n_layers)


# ---------------------------------------------------------------------------
# chain decomposition (repro.api): depth is the checkpoint chain
# ---------------------------------------------------------------------------


def train_chain(cfg: ArchConfig):
    """``repro.api.ChainSpec`` decomposition of :func:`train_loss`.

    The chain axis is *depth*: one period of the layer pattern is one chain
    step, the hidden state (plus the MoE aux accumulator) is the carry, and
    the stacked per-period parameters are the per-step inputs ``xs`` — so
    their gradients flow back into ``params["layers"]`` through the
    prelude's vjp.  Values and gradients match ``train_loss`` exactly; only
    the activation-memory strategy differs.
    """
    from repro.api.chain import ChainSpec

    dt = _dtypes(cfg)

    def prelude(params, batch):
        inp = batch["tokens"][:, :-1]
        h = embed(params["embed"], inp, dt)
        if cfg.embed_scale:
            h = h * jnp.asarray(cfg.d_model ** 0.5, dt.compute)
        h = constrain(h, "act")
        return (h, jnp.zeros((), jnp.float32)), params["layers"]

    def layer_body(params, carry, lp, batch, j):
        # one layer of the period — the 2D planner's inner-axis unit (the
        # rope table is rebuilt per layer; it is deterministic and tiny, and
        # XLA CSEs the rebuilds away within a remat region)
        x, aux_t = carry
        S = batch["tokens"].shape[1] - 1
        rope = rope_table(S, cfg.hd, cfg.rope_theta)
        kind = cfg.layer_pattern[j]
        x, aux = _apply_layer_seq(lp[f"pos{j}"], x, kind, cfg, rope, dt)
        return x, aux_t + aux

    def body(params, carry, lp, batch):
        for j in range(len(cfg.layer_pattern)):
            carry = layer_body(params, carry, lp, batch, j)
        return carry

    def readout_chunked(params, carry, batch, head_chunks):
        x, aux_t = carry
        labels = batch["tokens"][:, 1:]
        S = labels.shape[1]
        h = rmsnorm(params["final_norm"], x, dt=dt)
        chunk = cfg.ce_chunk if head_chunks <= 1 \
            else max(1, -(-S // head_chunks))
        loss = chunked_ce_loss(h, unembed_weight(params, cfg), labels,
                               chunk=chunk, logit_cap=cfg.logit_softcap,
                               mask=batch.get("mask"),
                               valid_vocab=cfg.vocab)
        coef = cfg.moe.aux_coef if cfg.moe else 0.0
        return loss + coef * aux_t / max(1, cfg.n_layers)

    def readout(params, carry, batch):
        return readout_chunked(params, carry, batch, 1)

    return ChainSpec(prelude, body, readout, name=f"{cfg.name}-depth",
                     layer_body=layer_body,
                     n_layers=len(cfg.layer_pattern),
                     readout_chunked=readout_chunked)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def cache_spec(cfg: ArchConfig) -> Params:
    """Axis roles for every :func:`init_cache` leaf (see ``models.cache``).

    Leading axis of every leaf is ``n_periods`` (the scan axis), so batch is
    axis 1.  Only attention KV carries a sequence axis (axis 2); Mamba-2
    conv/SSM state is length-independent and must never be padded.
    """
    from repro.models.cache import CacheAxes
    spec: Dict[str, Any] = {}
    for j, kind in enumerate(cfg.layer_pattern):
        if kind.startswith("attn"):
            spec[f"pos{j}"] = {"k": CacheAxes(batch=1, seq=2),
                               "v": CacheAxes(batch=1, seq=2)}
        else:
            spec[f"pos{j}"] = {"conv": CacheAxes(batch=1, seq=None),
                               "ssm": CacheAxes(batch=1, seq=None)}
    return spec


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    """Zero caches for decode.  Leading axis of every leaf: n_periods."""
    cache: Dict[str, Any] = {}
    for j, kind in enumerate(cfg.layer_pattern):
        if kind.startswith("attn"):
            shape = (cfg.n_periods, batch, max_len, cfg.n_kv_heads, cfg.hd)
            cache[f"pos{j}"] = {"k": jnp.zeros(shape, jnp.bfloat16),
                                "v": jnp.zeros(shape, jnp.bfloat16)}
        else:
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            nheads = d_in // s.headdim
            conv_dim = d_in + 2 * s.ngroups * s.d_state
            cache[f"pos{j}"] = {
                "conv": jnp.zeros((cfg.n_periods, batch, s.conv_k - 1,
                                   conv_dim), jnp.float32),
                "ssm": jnp.zeros((cfg.n_periods, batch, nheads, s.headdim,
                                  s.d_state), jnp.float32),
            }
    return cache


# ---------------------------------------------------------------------------
# decode (one token against the cache)
# ---------------------------------------------------------------------------


def _apply_layer_decode(p, x, kind, cfg: ArchConfig, cache_j, pos, dt):
    y = rmsnorm(p["ln1"], x, dt=dt)
    if kind.startswith("attn"):
        window = cfg.window if kind == "attn_local" else None
        y, ck, cv = attn_mod.decode_attention(
            p["attn"], y, cache_j["k"], cache_j["v"], pos,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
            rope_theta=cfg.rope_theta, window=window,
            softcap=cfg.attn_softcap, scale=cfg.query_scale, dt=dt)
        new_cache = {"k": ck, "v": cv}
    else:
        s = cfg.ssm
        y, conv, sst = ssm_mod.mamba2_decode_step(
            p["mamba"], y, cache_j["conv"], cache_j["ssm"],
            d_state=s.d_state, headdim=s.headdim, expand=s.expand,
            ngroups=s.ngroups, conv_k=s.conv_k, dt=dt)
        new_cache = {"conv": conv, "ssm": sst}
    h = x + _post(p, "ln1_post", y, cfg, dt)
    h, _ = _ffn(p, h, kind, cfg, dt)
    return h, new_cache


def decode(params: Params, cache: Params, tokens: jnp.ndarray,
           pos: jnp.ndarray, cfg: ArchConfig):
    """One decode step.  tokens: (B, 1); pos: int32 scalar or (B,) vector of
    per-request current lengths (mixed-length continuous batching).
    Returns (logits (B, V) fp32, new_cache)."""
    dt = _dtypes(cfg)
    h = embed(params["embed"], tokens, dt)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, dt.compute)

    def body(carry, xs):
        x = carry
        lp, cache_p = xs
        new_cache_p = {}
        for j, kind in enumerate(cfg.layer_pattern):
            x, nc = _apply_layer_decode(lp[f"pos{j}"], x, kind, cfg,
                                        cache_p[f"pos{j}"], pos, dt)
            new_cache_p[f"pos{j}"] = nc
        return x, new_cache_p

    h, new_cache = lax.scan(body, h, (params["layers"], cache))
    h = rmsnorm(params["final_norm"], h, dt=dt)
    logits = lm_logits(h[:, 0], unembed_weight(params, cfg),
                       cfg.logit_softcap, valid_vocab=cfg.vocab)
    return logits, new_cache


# ---------------------------------------------------------------------------
# prefill (full sequence -> caches + last-position logits)
# ---------------------------------------------------------------------------


def _prefill_layer(p, x, kind, cfg: ArchConfig, rope, dt):
    y = rmsnorm(p["ln1"], x, dt=dt)
    if kind.startswith("attn"):
        window = cfg.window if kind == "attn_local" else None
        B, S, _ = y.shape
        q, k, v = attn_mod._project_qkv(p["attn"], y, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.hd, dt)
        from repro.models.layers import apply_rope
        q, k = apply_rope(q, *rope), apply_rope(k, *rope)
        if S > 2048:
            o = attn_mod.chunked_attention(q, k, v, True, window,
                                           cfg.attn_softcap, cfg.attn_chunk,
                                           cfg.query_scale)
        else:
            o = attn_mod.reference_attention(q, k, v, True, window,
                                             cfg.attn_softcap, cfg.query_scale)
        from repro.models.layers import dense
        y = dense(p["attn"]["wo"], o.reshape(B, S, cfg.n_heads * cfg.hd), dt)
        new_cache = {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}
    else:
        s = cfg.ssm
        y, (conv_st, ssm_st) = ssm_mod.mamba2_block(
            p["mamba"], y, d_state=s.d_state, headdim=s.headdim,
            expand=s.expand, ngroups=s.ngroups, conv_k=s.conv_k,
            chunk=s.chunk, dt=dt, return_state=True)
        new_cache = {"conv": conv_st, "ssm": ssm_st}
    h = x + _post(p, "ln1_post", y, cfg, dt)
    h, _ = _ffn(p, h, kind, cfg, dt)
    return h, new_cache


def prefill(params: Params, tokens: jnp.ndarray, cfg: ArchConfig):
    """Process the prompt.  tokens: (B, S).  Returns (last_logits, cache)."""
    dt = _dtypes(cfg)
    h = embed(params["embed"], tokens, dt)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, dt.compute)
    return prefill_from_hidden(params, h, cfg)


def prefill_from_hidden(params: Params, h: jnp.ndarray, cfg: ArchConfig):
    """Prefill from already-embedded hidden states (shared with the VLM)."""
    dt = _dtypes(cfg)
    S = h.shape[1]
    h = constrain(h, "act")
    rope = rope_table(S, cfg.hd, cfg.rope_theta)

    def period_body(lp, x):
        caches = {}
        for j, kind in enumerate(cfg.layer_pattern):
            x, nc = _prefill_layer(lp[f"pos{j}"], x, kind, cfg, rope, dt)
            caches[f"pos{j}"] = nc
        return x, caches

    wrapped = remat_layer(
        lambda lp, x: period_body(lp, x), cfg.remat_policy, tag_input=True)

    def body(x, lp):
        x, caches = wrapped(lp, x)
        return x, caches

    h, cache = lax.scan(body, h, params["layers"])
    h = rmsnorm(params["final_norm"], h, dt=dt)
    logits = lm_logits(h[:, -1], unembed_weight(params, cfg),
                       cfg.logit_softcap, valid_vocab=cfg.vocab)
    return logits, cache
