"""Encoder-decoder backbone (Whisper-family).

The audio frontend (log-mel + conv downsampling) is a STUB per the
assignment: ``input_specs`` supplies precomputed frame embeddings
``(B, S_enc, d_model)``.  Norm/positional details are adapted to this
codebase's RMSNorm+RoPE substrate (noted in DESIGN §2); the layer/head/ff
dimensions follow the published config exactly.

Encoder: non-causal self-attention blocks.  Decoder: causal self-attention +
cross-attention to the encoder output + MLP.  Decode path caches decoder
self-attention KV and the (static) cross-attention KV.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.core.layer_policy import remat_layer
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models.layers import (
    DTypes, chunked_ce_loss, dense, embed, init_embedding, init_rmsnorm,
    lm_logits, rmsnorm, rope_table, apply_rope,
)

Params = Any


def _dtypes(cfg: ArchConfig) -> DTypes:
    return DTypes(compute=jnp.bfloat16)


def _init_enc_layer(key, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model),
        "attn": attn_mod.init_attention(k1, cfg.d_model, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.hd),
        "ln2": init_rmsnorm(cfg.d_model),
        "mlp": moe_mod.init_mlp(k2, cfg.d_model, cfg.d_ff),
    }


def _init_dec_layer(key, cfg: ArchConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_rmsnorm(cfg.d_model),
        "attn": attn_mod.init_attention(k1, cfg.d_model, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.hd),
        "ln_x": init_rmsnorm(cfg.d_model),
        "xattn": attn_mod.init_attention(k2, cfg.d_model, cfg.n_heads,
                                         cfg.n_kv_heads, cfg.hd),
        "ln2": init_rmsnorm(cfg.d_model),
        "mlp": moe_mod.init_mlp(k3, cfg.d_model, cfg.d_ff),
    }


def init_encdec(key, cfg: ArchConfig) -> Params:
    ke, kenc, kdec, kt = jax.random.split(key, 4)
    enc_keys = jax.random.split(kenc, cfg.n_enc_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": init_embedding(ke, cfg.vocab, cfg.d_model),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "enc_norm": init_rmsnorm(cfg.d_model),
        "final_norm": init_rmsnorm(cfg.d_model),
    }


def encode(params: Params, frames: jnp.ndarray, cfg: ArchConfig):
    """frames: (B, S_enc, d_model) — precomputed frame embeddings (stub)."""
    dt = _dtypes(cfg)
    x = frames.astype(dt.compute)
    rope = rope_table(frames.shape[1], cfg.hd, cfg.rope_theta)

    def layer(lp, x):
        y = rmsnorm(lp["ln1"], x, dt=dt)
        y = attn_mod.attention(lp["attn"], y, n_heads=cfg.n_heads,
                               n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                               rope=rope, causal=False, chunk=cfg.attn_chunk,
                               dt=dt)
        x = x + y
        y = moe_mod.mlp(lp["mlp"], rmsnorm(lp["ln2"], x, dt=dt),
                        act=cfg.mlp_act, dt=dt)
        return x + y

    wrapped = remat_layer(layer, cfg.remat_policy)

    def body(x, lp):
        return wrapped(lp, x), None

    x, _ = lax.scan(body, x, params["enc_layers"])
    return rmsnorm(params["enc_norm"], x, dt=dt)


def _dec_layer_seq(lp, x, enc, rope, cfg, dt):
    y = rmsnorm(lp["ln1"], x, dt=dt)
    y = attn_mod.attention(lp["attn"], y, n_heads=cfg.n_heads,
                           n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                           rope=rope, causal=True, chunk=cfg.attn_chunk,
                           dt=dt)
    x = x + y
    y = attn_mod.cross_attention(lp["xattn"], rmsnorm(lp["ln_x"], x, dt=dt),
                                 enc, n_heads=cfg.n_heads,
                                 n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                                 dt=dt)
    x = x + y
    y = moe_mod.mlp(lp["mlp"], rmsnorm(lp["ln2"], x, dt=dt),
                    act=cfg.mlp_act, dt=dt)
    return x + y


def train_loss(params: Params, batch: Dict[str, jnp.ndarray],
               cfg: ArchConfig) -> jnp.ndarray:
    """batch: frames (B, S_enc, d), tokens (B, S_dec+1)."""
    dt = _dtypes(cfg)
    enc = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    x = embed(params["embed"], inp, dt)
    rope = rope_table(inp.shape[1], cfg.hd, cfg.rope_theta)
    wrapped = remat_layer(
        lambda lp, x: _dec_layer_seq(lp, x, enc, rope, cfg, dt),
        cfg.remat_policy)

    def body(x, lp):
        return wrapped(lp, x), None

    x, _ = lax.scan(body, x, params["dec_layers"])
    x = rmsnorm(params["final_norm"], x, dt=dt)
    return chunked_ce_loss(x, params["embed"]["emb"], labels,
                           chunk=cfg.ce_chunk)


def cache_spec(cfg: ArchConfig) -> Params:
    """Axis roles for :func:`init_cache` leaves (see ``models.cache``).

    Self-attention KV grows with decode length; the cross-attention KV is
    computed once from the encoder and is static — no sequence axis.
    """
    from repro.models.cache import CacheAxes
    return {"k": CacheAxes(batch=1, seq=2), "v": CacheAxes(batch=1, seq=2),
            "xk": CacheAxes(batch=1, seq=None),
            "xv": CacheAxes(batch=1, seq=None)}


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               s_enc: int) -> Params:
    L = cfg.n_layers
    kv = (L, batch, max_len, cfg.n_kv_heads, cfg.hd)
    xkv = (L, batch, s_enc, cfg.n_kv_heads, cfg.hd)
    return {
        "k": jnp.zeros(kv, jnp.bfloat16), "v": jnp.zeros(kv, jnp.bfloat16),
        "xk": jnp.zeros(xkv, jnp.bfloat16), "xv": jnp.zeros(xkv, jnp.bfloat16),
    }


def prefill(params: Params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig,
            max_len: int):
    """Encode frames + consume the decoder prompt.  Returns (logits, cache)."""
    dt = _dtypes(cfg)
    enc = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed(params["embed"], tokens, dt)
    rope = rope_table(S, cfg.hd, cfg.rope_theta)

    def layer(lp, x):
        y = rmsnorm(lp["ln1"], x, dt=dt)
        q, k, v = attn_mod._project_qkv(lp["attn"], y, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.hd, dt)
        q, k = apply_rope(q, *rope), apply_rope(k, *rope)
        o = attn_mod.reference_attention(q, k, v, causal=True) if S <= 2048 \
            else attn_mod.chunked_attention(q, k, v, True, None, None,
                                            cfg.attn_chunk, None)
        y = dense(lp["attn"]["wo"], o.reshape(B, S, cfg.n_heads * cfg.hd), dt)
        x = x + y
        xq = rmsnorm(lp["ln_x"], x, dt=dt)
        Sk = enc.shape[1]
        q2 = dense(lp["xattn"]["wq"], xq, dt).reshape(B, S, cfg.n_heads, cfg.hd)
        xk = dense(lp["xattn"]["wk"], enc, dt).reshape(B, Sk, cfg.n_kv_heads, cfg.hd)
        xv = dense(lp["xattn"]["wv"], enc, dt).reshape(B, Sk, cfg.n_kv_heads, cfg.hd)
        o2 = attn_mod.reference_attention(q2, xk, xv, causal=False)
        x = x + dense(lp["xattn"]["wo"], o2.reshape(B, S, cfg.n_heads * cfg.hd), dt)
        y = moe_mod.mlp(lp["mlp"], rmsnorm(lp["ln2"], x, dt=dt),
                        act=cfg.mlp_act, dt=dt)
        pad = max_len - S
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16)
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16)
        return x + y, {"k": kc, "v": vc, "xk": xk.astype(jnp.bfloat16),
                       "xv": xv.astype(jnp.bfloat16)}

    x, cache = lax.scan(lambda x, lp: layer(lp, x), x, params["dec_layers"])
    x = rmsnorm(params["final_norm"], x, dt=dt)
    logits = lm_logits(x[:, -1], params["embed"]["emb"])
    return logits, cache


def decode(params: Params, cache: Params, tokens: jnp.ndarray,
           pos: jnp.ndarray, cfg: ArchConfig):
    """One decode step.  tokens: (B, 1).  Cross-KV in the cache is static."""
    dt = _dtypes(cfg)
    x = embed(params["embed"], tokens, dt)

    def body(x, xs):
        lp, c = xs
        y = rmsnorm(lp["ln1"], x, dt=dt)
        y, ck, cv = attn_mod.decode_attention(
            lp["attn"], y, c["k"], c["v"], pos, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
            rope_theta=cfg.rope_theta, dt=dt)
        x = x + y
        # cross attention to the static encoder KV
        B = x.shape[0]
        xq = rmsnorm(lp["ln_x"], x, dt=dt)
        q = dense(lp["xattn"]["wq"], xq, dt).reshape(B, 1, cfg.n_heads, cfg.hd)
        o = attn_mod.reference_attention(q, c["xk"], c["xv"], causal=False)
        x = x + dense(lp["xattn"]["wo"], o.reshape(B, 1, cfg.n_heads * cfg.hd), dt)
        y = moe_mod.mlp(lp["mlp"], rmsnorm(lp["ln2"], x, dt=dt),
                        act=cfg.mlp_act, dt=dt)
        return x + y, {"k": ck, "v": cv, "xk": c["xk"], "xv": c["xv"]}

    x, new_cache = lax.scan(body, x, (params["dec_layers"], cache))
    x = rmsnorm(params["final_norm"], x, dt=dt)
    return lm_logits(x[:, 0], params["embed"]["emb"]), new_cache
