"""VLM backbone (InternVL2-family): LM decoder with prepended patch
embeddings.  The vision tower is a STUB per the assignment — ``input_specs``
supplies precomputed patch embeddings ``(B, n_patches, d_model)``; a learned
projection maps them into the text embedding space (the real model's MLP
projector), then the standard decoder-only stack runs over
``[patches | text]`` with loss on text positions only.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tf
from repro.models.layers import (
    chunked_ce_loss, dense, embed, init_dense, rmsnorm, rope_table,
)

Params = Any


def init_vlm(key, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    params = tf.init_lm(k1, cfg)
    params["patch_proj"] = init_dense(k2, cfg.d_model, cfg.d_model)
    return params


def train_loss(params: Params, batch: Dict[str, jnp.ndarray],
               cfg: ArchConfig) -> jnp.ndarray:
    """batch: tokens (B, S_text+1) int32, patch_embeds (B, P, d_model)."""
    dt = tf._dtypes(cfg)
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    B, S_text = inp.shape
    patches = dense(params["patch_proj"], batch["patch_embeds"].astype(dt.compute), dt)
    text = embed(params["embed"], inp, dt)
    h = jnp.concatenate([patches, text], axis=1)
    S = h.shape[1]
    from repro.distributed.sharding import constrain
    h = constrain(h, "act")
    rope = rope_table(S, cfg.hd, cfg.rope_theta)
    h, aux = tf._scan_stack(params, h, cfg, rope, dt)
    h = rmsnorm(params["final_norm"], h, dt=dt)
    h_text = h[:, -S_text:]
    return chunked_ce_loss(h_text, tf.unembed_weight(params, cfg), labels,
                           chunk=cfg.ce_chunk, logit_cap=cfg.logit_softcap,
                           valid_vocab=cfg.vocab)


def prefill(params: Params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig):
    """Prompt = patches + text tokens; returns (last_logits, cache)."""
    dt = tf._dtypes(cfg)
    tokens = batch["tokens"]
    patches = dense(params["patch_proj"], batch["patch_embeds"].astype(dt.compute), dt)
    text = embed(params["embed"], tokens, dt)
    h = jnp.concatenate([patches, text], axis=1)
    # Reuse the LM prefill machinery below the embedding layer.
    return tf.prefill_from_hidden(params, h, cfg)


decode = tf.decode  # identical to the LM decode path (text tokens only)
