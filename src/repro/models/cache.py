"""Model-declared decode-cache layout.

Every model family lays its decode cache out differently: transformer KV
leaves are ``(n_periods, B, S_max, G, D)``, Mamba-2 conv state is
``(n_periods, B, conv_k - 1, conv_dim)`` with *no* sequence axis at all, and
the encoder-decoder keeps static cross-KV leaves that must never be padded.
Sniffing ``ndim`` to find "the sequence axis" is therefore wrong the moment a
non-attention leaf shows up — the seed serving launcher padded the Mamba SSM
state's *head* axis out to ``max_len`` and silently corrupted decode.

The fix is declarative: each family exposes a *cache spec* — a pytree with
the same structure as its cache whose leaves are :class:`CacheAxes`, naming
the batch axis and (optionally) the sequence axis of the matching cache
leaf.  Everything the serving layer needs (growing a prompt-length cache to
``max_len``, slicing batch slots in and out for continuous batching, byte
accounting for admission control) is derived from the spec here, with no
per-family code in the serving path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

Cache = Any


@dataclasses.dataclass(frozen=True)
class CacheAxes:
    """Axis roles for one cache leaf.

    ``batch``: index of the batch axis (every leaf has one).
    ``seq``: index of the sequence axis, or ``None`` for leaves whose shape
    is independent of generated length (SSM/conv state, static cross-KV).
    """
    batch: int
    seq: Optional[int] = None


def _zip_spec(cache: Cache, spec: Cache):
    """Pairs (leaf, axes) — validates the spec structurally matches."""
    leaves, treedef = jax.tree_util.tree_flatten(cache)
    axes_leaves = treedef.flatten_up_to(spec)
    for x, ax in zip(leaves, axes_leaves):
        if not isinstance(ax, CacheAxes):
            raise TypeError(f"cache spec leaf {ax!r} is not CacheAxes")
        if ax.batch >= x.ndim or (ax.seq is not None and ax.seq >= x.ndim):
            raise ValueError(f"axes {ax} out of range for leaf shape "
                             f"{x.shape}")
    return leaves, axes_leaves, treedef


def grow_cache(cache: Cache, spec: Cache, new_len: int) -> Cache:
    """Zero-pad every sequence-carrying leaf out to ``new_len``.

    Leaves without a sequence axis pass through untouched — this is the
    correct generalisation of the seed launcher's ndim-sniffing pad.
    """
    leaves, axes_leaves, treedef = _zip_spec(cache, spec)

    def g(x, ax):
        if ax.seq is None:
            return x
        pad = new_len - x.shape[ax.seq]
        if pad < 0:
            raise ValueError(
                f"cannot shrink cache seq axis {x.shape[ax.seq]} -> "
                f"{new_len}")
        if pad == 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[ax.seq] = (0, pad)
        return jnp.pad(x, widths)

    return treedef.unflatten([g(x, ax) for x, ax in zip(leaves, axes_leaves)])


def cache_batch_size(cache: Cache, spec: Cache) -> int:
    """Batch size shared by every leaf (validated)."""
    leaves, axes_leaves, _ = _zip_spec(cache, spec)
    sizes = {x.shape[ax.batch] for x, ax in zip(leaves, axes_leaves)}
    if len(sizes) != 1:
        raise ValueError(f"inconsistent batch sizes across leaves: {sizes}")
    return sizes.pop()


def cache_seq_len(cache: Cache, spec: Cache) -> Optional[int]:
    """Max-length of the sequence-carrying leaves (None if there are none)."""
    leaves, axes_leaves, _ = _zip_spec(cache, spec)
    lens = {x.shape[ax.seq] for x, ax in zip(leaves, axes_leaves)
            if ax.seq is not None}
    if not lens:
        return None
    if len(lens) != 1:
        raise ValueError(f"inconsistent seq lengths across leaves: {lens}")
    return lens.pop()


def read_slots(cache: Cache, spec: Cache,
               indices: Sequence[int]) -> Cache:
    """Extract batch slots ``indices`` from every leaf (batch axis kept)."""
    idx = jnp.asarray(list(indices), jnp.int32)
    leaves, axes_leaves, treedef = _zip_spec(cache, spec)
    return treedef.unflatten([jnp.take(x, idx, axis=ax.batch)
                              for x, ax in zip(leaves, axes_leaves)])


def write_slot(cache: Cache, spec: Cache, slot_cache: Cache,
               index: int) -> Cache:
    """Insert a batch-1 ``slot_cache`` into batch slot ``index``.

    This is the continuous-batching join: a freshly prefilled request's cache
    (grown to the session's max_len first — see :func:`grow_cache`) is
    written into a free slot of the running batch without touching the other
    slots.
    """
    leaves, axes_leaves, treedef = _zip_spec(cache, spec)
    _, src_axes, _ = _zip_spec(slot_cache, spec)
    src_leaves = jax.tree_util.tree_leaves(slot_cache)

    def w(dst, src, ax):
        if src.shape[ax.batch] != 1:
            raise ValueError(f"slot cache batch axis must be 1, got "
                             f"{src.shape[ax.batch]}")
        sl = [slice(None)] * dst.ndim
        sl[ax.batch] = index
        return dst.at[tuple(sl)].set(jnp.squeeze(src, axis=ax.batch)
                                     .astype(dst.dtype))

    return treedef.unflatten([w(d, s, ax) for d, s, ax in
                              zip(leaves, src_leaves, axes_leaves)])


def cache_nbytes(cache: Cache) -> int:
    """Total bytes of a cache pytree (arrays or ShapeDtypeStructs)."""
    total = 0
    for x in jax.tree_util.tree_leaves(cache):
        size = 1
        for d in x.shape:
            size *= d
        total += size * jnp.dtype(x.dtype).itemsize
    return total


def decode_cache_bytes(api, batch: int, max_len: int) -> int:
    """Byte footprint of ``api.init_cache(batch, max_len)`` WITHOUT
    allocating it — admission control calls this before saying yes."""
    shapes = jax.eval_shape(lambda: api.init_cache(batch, max_len))
    return cache_nbytes(shapes)
