"""GQA attention: memory-efficient chunked (flash-style) training path with a
custom VJP, plus a KV-cache decode path.

The chunked path is the XLA-portable twin of ``repro.kernels.flash_attention``
(the Pallas TPU kernel): an online-softmax scan over KV chunks that never
materialises the (S x S) score matrix, with a flash-style backward that
recomputes probabilities from the saved logsumexp instead of letting JAX
stack per-chunk scan residuals.  On real TPUs the Pallas kernel is selected
via ``repro.kernels.ops``; everywhere else (CPU tests, dry-run lowering) this
module is the implementation.

Supports: grouped KV heads, causal masking, sliding windows (Gemma-2 local
layers), attention logit softcapping, QKV bias (Qwen), non-causal encoder
attention (Whisper encoder) and cross attention.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import (
    DTypes, DEFAULT_DTYPES, apply_rope, apply_rope_at, dense, init_dense,
)

Params = Any
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# chunked attention with flash-style custom VJP
# ---------------------------------------------------------------------------


def _mask_chunk(q_pos: jnp.ndarray, k_pos: jnp.ndarray, causal: bool,
                window: Optional[int]) -> jnp.ndarray:
    """(Sq, Sk_chunk) boolean validity mask."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def _scores(q, kc, cap):
    # q: (B,G,Hg,Sq,D) kc: (B,G,C,D) -> (B,G,Hg,Sq,C), fp32
    s = jnp.einsum("bghsd,bgcd->bghsc", q, kc,
                   preferred_element_type=jnp.float32)
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    return s


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      causal: bool = True, window: Optional[int] = None,
                      softcap: Optional[float] = None, chunk: int = 1024,
                      scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B, Sq, H, D); k, v: (B, Sk, G, D) with H % G == 0.
    Returns (B, Sq, H, D).  Never materialises (Sq, Sk)."""
    out, _ = _chunked_fwd(q, k, v, causal, window, softcap, chunk, scale)
    return out


def _layout(q, k, v, scale):
    B, Sq, H, D = q.shape
    G = k.shape[2]
    Hg = H // G
    scale = (D ** -0.5) if scale is None else scale
    qt = (q * scale).transpose(0, 2, 1, 3).reshape(B, G, Hg, Sq, D)
    kt = k.transpose(0, 2, 1, 3)  # (B, G, Sk, D)
    vt = v.transpose(0, 2, 1, 3)
    return qt, kt, vt, (B, Sq, H, D, G, Hg)


def _chunked_fwd(q, k, v, causal, window, softcap, chunk, scale):
    qt, kt, vt, (B, Sq, H, D, G, Hg) = _layout(q, k, v, scale)
    Sk = kt.shape[2]
    if Sk % chunk != 0:
        chunk = Sk
    n_chunks = Sk // chunk
    q_pos = jnp.arange(Sq) + (Sk - Sq)  # queries sit at the end of the keys
    kc = kt.reshape(B, G, n_chunks, chunk, D).transpose(2, 0, 1, 3, 4)
    vc = vt.reshape(B, G, n_chunks, chunk, D).transpose(2, 0, 1, 3, 4)

    def body(carry, args):
        acc, m, l = carry
        kj, vj, j = args
        k_pos = j * chunk + jnp.arange(chunk)
        s = _scores(qt, kj, softcap)  # (B,G,Hg,Sq,C) fp32
        mask = _mask_chunk(q_pos, k_pos, causal, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bghsc,bgcd->bghsd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, G, Hg, Sq, D), jnp.float32)
    m0 = jnp.full((B, G, Hg, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, G, Hg, Sq), jnp.float32)
    (acc, m, l), _ = lax.scan(body, (acc0, m0, l0),
                              (kc, vc, jnp.arange(n_chunks)))
    l = jnp.maximum(l, 1e-30)
    out = (acc / l[..., None]).astype(q.dtype)
    lse = m + jnp.log(l)
    out_std = out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
    return out_std, (q, k, v, out_std, lse)


def _chunked_bwd(causal, window, softcap, chunk, scale, res, dout):
    q, k, v, out, lse = res
    qt, kt, vt, (B, Sq, H, D, G, Hg) = _layout(q, k, v, scale)
    sc = (D ** -0.5) if scale is None else scale
    Sk = kt.shape[2]
    if Sk % chunk != 0:
        chunk = Sk
    n_chunks = Sk // chunk
    q_pos = jnp.arange(Sq) + (Sk - Sq)
    do = dout.transpose(0, 2, 1, 3).reshape(B, G, Hg, Sq, D)
    ot = out.transpose(0, 2, 1, 3).reshape(B, G, Hg, Sq, D)
    Dv = jnp.sum(do.astype(jnp.float32) * ot.astype(jnp.float32), axis=-1)
    kc = kt.reshape(B, G, n_chunks, chunk, D).transpose(2, 0, 1, 3, 4)
    vc = vt.reshape(B, G, n_chunks, chunk, D).transpose(2, 0, 1, 3, 4)

    def body(dq_acc, args):
        kj, vj, j = args
        k_pos = j * chunk + jnp.arange(chunk)
        s_raw = jnp.einsum("bghsd,bgcd->bghsc", qt, kj,
                           preferred_element_type=jnp.float32)
        if softcap is not None:
            t = jnp.tanh(s_raw / softcap)
            s = softcap * t
            dcap = 1.0 - t * t
        else:
            s, dcap = s_raw, None
        mask = _mask_chunk(q_pos, k_pos, causal, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])  # (B,G,Hg,Sq,C)
        dv_j = jnp.einsum("bghsc,bghsd->bgcd", p.astype(do.dtype), do,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bghsd,bgcd->bghsc", do, vj,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - Dv[..., None])
        if dcap is not None:
            ds = ds * dcap
        ds = jnp.where(mask[None, None, None], ds, 0.0)
        dq_j = jnp.einsum("bghsc,bgcd->bghsd", ds.astype(kj.dtype), kj,
                          preferred_element_type=jnp.float32)
        dk_j = jnp.einsum("bghsc,bghsd->bgcd", ds.astype(qt.dtype), qt,
                          preferred_element_type=jnp.float32)
        return dq_acc + dq_j, (dk_j, dv_j)

    dq0 = jnp.zeros((B, G, Hg, Sq, D), jnp.float32)
    dq, (dk_c, dv_c) = lax.scan(body, dq0, (kc, vc, jnp.arange(n_chunks)))
    dq = (dq * sc).reshape(B, H, Sq, D).transpose(0, 2, 1, 3).astype(q.dtype)
    dk = dk_c.transpose(1, 2, 0, 3, 4).reshape(B, G, Sk, D)
    dk = dk.transpose(0, 2, 1, 3).astype(k.dtype)
    dv = dv_c.transpose(1, 2, 0, 3, 4).reshape(B, G, Sk, D)
    dv = dv.transpose(0, 2, 1, 3).astype(v.dtype)
    return dq, dk, dv


chunked_attention.defvjp(_chunked_fwd, _chunked_bwd)


def reference_attention(q, k, v, causal=True, window=None, softcap=None,
                        scale=None) -> jnp.ndarray:
    """Naive O(S^2)-memory oracle used by tests and tiny smoke shapes."""
    B, Sq, H, D = q.shape
    G = k.shape[2]
    Hg = H // G
    sc = (D ** -0.5) if scale is None else scale
    qt = (q * sc).transpose(0, 2, 1, 3).reshape(B, G, Hg, Sq, D)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    s = jnp.einsum("bghsd,bgtd->bghst", qt, kt,
                   preferred_element_type=jnp.float32)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    Sk = k.shape[1]
    q_pos = jnp.arange(Sq) + (Sk - Sq)
    k_pos = jnp.arange(Sk)
    mask = _mask_chunk(q_pos, k_pos, causal, window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bghst,bgtd->bghsd", p, vt)
    return o.reshape(B, H, Sq, D).transpose(0, 2, 1, 3).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (projections + rope + cache plumbing)
# ---------------------------------------------------------------------------


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, *, qkv_bias: bool = False,
                   dtype=jnp.float32) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": init_dense(kq, d_model, n_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wk": init_dense(kk, d_model, n_kv_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wv": init_dense(kv, d_model, n_kv_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wo": init_dense(ko, n_heads * head_dim, d_model, bias=False, dtype=dtype),
    }


def _project_qkv(p, x, n_heads, n_kv_heads, head_dim, dt):
    from repro.distributed.sharding import constrain
    B, S, _ = x.shape
    q = dense(p["wq"], x, dt).reshape(B, S, n_heads, head_dim)
    k = dense(p["wk"], x, dt).reshape(B, S, n_kv_heads, head_dim)
    v = dense(p["wv"], x, dt).reshape(B, S, n_kv_heads, head_dim)
    # zero3 variant: pin outputs to (batch, ..., heads@model) so the FSDP-
    # sharded weights are all-gathered rather than contracted-and-reduced.
    q, k, v = (constrain(t, "proj4") for t in (q, k, v))
    return q, k, v


def attention(p: Params, x: jnp.ndarray, *, n_heads: int, n_kv_heads: int,
              head_dim: int, rope: Optional[Tuple[jnp.ndarray, jnp.ndarray]],
              causal: bool = True, window: Optional[int] = None,
              softcap: Optional[float] = None, scale: Optional[float] = None,
              chunk: int = 1024, use_chunked: Optional[bool] = None,
              dt: DTypes = DEFAULT_DTYPES) -> jnp.ndarray:
    """Self-attention over a full sequence (training / prefill compute)."""
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim, dt)
    if rope is not None:
        cos, sin = rope
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    S = x.shape[1]
    if use_chunked is None:
        use_chunked = S > 2048
    if use_chunked:
        o = chunked_attention(q, k, v, causal, window, softcap, chunk, scale)
    else:
        o = reference_attention(q, k, v, causal, window, softcap, scale)
    B = x.shape[0]
    return dense(p["wo"], o.reshape(B, S, n_heads * head_dim), dt)


def cross_attention(p: Params, x: jnp.ndarray, kv_src: jnp.ndarray, *,
                    n_heads: int, n_kv_heads: int, head_dim: int,
                    dt: DTypes = DEFAULT_DTYPES) -> jnp.ndarray:
    """Encoder-decoder cross attention (non-causal over kv_src)."""
    B, S, _ = x.shape
    Sk = kv_src.shape[1]
    q = dense(p["wq"], x, dt).reshape(B, S, n_heads, head_dim)
    k = dense(p["wk"], kv_src, dt).reshape(B, Sk, n_kv_heads, head_dim)
    v = dense(p["wv"], kv_src, dt).reshape(B, Sk, n_kv_heads, head_dim)
    o = reference_attention(q, k, v, causal=False)
    return dense(p["wo"], o.reshape(B, S, n_heads * head_dim), dt)


# ---------------------------------------------------------------------------
# KV cache (decode path)
# ---------------------------------------------------------------------------


def init_kv_cache(batch: int, max_len: int, n_kv_heads: int, head_dim: int,
                  n_layers: int, dtype=jnp.bfloat16) -> Params:
    shape = (n_layers, batch, max_len, n_kv_heads, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_attention(p: Params, x: jnp.ndarray, cache_k: jnp.ndarray,
                     cache_v: jnp.ndarray, pos: jnp.ndarray, *,
                     n_heads: int, n_kv_heads: int, head_dim: int,
                     rope_theta: Optional[float] = 10000.0,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None,
                     scale: Optional[float] = None,
                     dt: DTypes = DEFAULT_DTYPES):
    """One decode step.  x: (B, 1, d); cache_k/v: (B, S_max, G, D);
    pos: int32 scalar or (B,) vector — per-request current lengths, so batch
    slots holding different-length sequences (continuous batching) each
    write/rope/mask at their own position.
    Returns (y, new_cache_k, new_cache_v)."""
    B = x.shape[0]
    q = dense(p["wq"], x, dt).reshape(B, 1, n_heads, head_dim)
    k = dense(p["wk"], x, dt).reshape(B, 1, n_kv_heads, head_dim)
    v = dense(p["wv"], x, dt).reshape(B, 1, n_kv_heads, head_dim)
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    if rope_theta is not None:
        q = apply_rope_at(q, posb, head_dim, rope_theta)
        k = apply_rope_at(k, posb, head_dim, rope_theta)
    ck = cache_k.at[jnp.arange(B), posb].set(k[:, 0].astype(cache_k.dtype))
    cv = cache_v.at[jnp.arange(B), posb].set(v[:, 0].astype(cache_v.dtype))
    S = ck.shape[1]
    G, Hg = n_kv_heads, n_heads // n_kv_heads
    sc = (head_dim ** -0.5) if scale is None else scale
    qt = (q * sc).transpose(0, 2, 1, 3).reshape(B, G, Hg, 1, head_dim)
    s = jnp.einsum("bghsd,bgtd->bghst", qt, ck.transpose(0, 2, 1, 3),
                   preferred_element_type=jnp.float32)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    k_pos = jnp.arange(S)
    valid = k_pos[None, :] <= posb[:, None]  # (B, S)
    if window is not None:
        valid &= k_pos[None, :] > posb[:, None] - window
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1).astype(cv.dtype)
    o = jnp.einsum("bghst,bgtd->bghsd", pattn, cv.transpose(0, 2, 1, 3))
    o = o.reshape(B, n_heads, 1, head_dim).transpose(0, 2, 1, 3)
    y = dense(p["wo"], o.reshape(B, 1, n_heads * head_dim), dt)
    return y, ck, cv
