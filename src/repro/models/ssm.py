"""Mamba-2 (SSD — state-space duality) blocks, pure JAX.

The SSD recurrence per head (state N, head dim P)::

    h_t = a_t * h_{t-1} + dt_t * B_t  (outer) x_t         h: (P, N)
    y_t = h_t @ C_t + D * x_t                             a_t = exp(dt_t * A)

``ssd_chunked`` evaluates it with the chunked algorithm of the Mamba-2 paper:
intra-chunk terms as batched matmuls (MXU-friendly), inter-chunk state passed
through a short ``lax.scan``.  This is the sub-quadratic sequence mixer that
makes the ``long_500k`` shape feasible, and the chain whose per-chunk states
are exactly the paper's uniform checkpoints: ``multistage_scan`` over the
chunk axis offloads every I-th chunk state to host memory.

``ssd_sequential`` is the O(T) oracle used by tests; the Pallas kernel in
``repro.kernels.ssd_scan`` mirrors ``ssd_chunked`` on-chip.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import DTypes, DEFAULT_DTYPES, dense, init_dense

Params = Any


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def ssd_sequential(x, dt, A, B, C, h0=None):
    """Oracle recurrence.  x: (b,t,h,p); dt: (b,t,h); A: (h,);
    B, C: (b,t,g,n) with heads mapped to groups h -> h % g... heads per group
    = H // G contiguous blocks.  Returns (y (b,t,h,p), h_final (b,h,p,n))."""
    b, t, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2)  # (b,t,H,n)
    Ch = jnp.repeat(C, rep, axis=2)
    a = jnp.exp(dt * A[None, None, :])  # (b,t,H)
    if h0 is None:
        h0 = jnp.zeros((b, H, P, N), jnp.float32)

    def step(h, args):
        xt, at, dtt, Bt, Ct = args
        upd = jnp.einsum("bhp,bhn->bhpn", xt * dtt[..., None], Bt)
        h = h * at[..., None, None] + upd
        y = jnp.einsum("bhpn,bhn->bhp", h, Ct)
        return h, y

    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          a.transpose(1, 0, 2), dt.transpose(1, 0, 2),
          Bh.transpose(1, 0, 2, 3).astype(jnp.float32),
          Ch.transpose(1, 0, 2, 3).astype(jnp.float32))
    hf, ys = lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3), hf


def ssd_chunked(x, dt, A, B, C, *, chunk: int = 64, h0=None):
    """Chunked SSD (Mamba-2 alg.).  Same contract as ``ssd_sequential``."""
    b, T, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    if T % chunk != 0:
        chunk = T
    nc = T // chunk

    xf = x.astype(jnp.float32).reshape(b, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(b, nc, chunk, H)
    Bf = B.astype(jnp.float32).reshape(b, nc, chunk, G, N)
    Cf = C.astype(jnp.float32).reshape(b, nc, chunk, G, N)
    la = dtf * A[None, None, None, :]          # log a  (b,c,l,h)
    ca = jnp.cumsum(la, axis=2)                # cumulative within chunk
    xbar = xf * dtf[..., None]                 # dt-weighted input

    # ---- intra-chunk (dual / attention-like form) --------------------------
    Bh = jnp.repeat(Bf, rep, axis=3)           # (b,c,l,H,n)
    Ch = jnp.repeat(Cf, rep, axis=3)
    cb = jnp.einsum("bclhn,bcshn->bchls", Ch, Bh)
    seg = ca[..., :, None, :] - ca[..., None, :, :]        # (b,c,l,s,h)
    li = jnp.arange(chunk)
    causal = li[:, None] >= li[None, :]
    # mask BEFORE exp: exp of masked (positive) entries overflows and the
    # where-VJP would produce 0 * inf = NaN gradients otherwise.
    seg = jnp.where(causal[None, None, :, :, None], seg, -jnp.inf)
    decay = jnp.exp(seg)
    M = cb * decay.transpose(0, 1, 4, 2, 3)                # (b,c,h,l,s)
    y_intra = jnp.einsum("bchls,bcshp->bclhp", M, xbar)

    # ---- chunk states -------------------------------------------------------
    last = ca[:, :, -1:, :]                                 # (b,c,1,h)
    dec_to_end = jnp.exp(last - ca)                         # (b,c,l,h)
    S_c = jnp.einsum("bclhn,bclhp->bchpn", Bh * dec_to_end[..., None], xbar)

    # ---- inter-chunk scan ----------------------------------------------------
    chunk_decay = jnp.exp(last[:, :, 0, :])                 # (b,c,h)
    if h0 is None:
        h0 = jnp.zeros((b, H, P, N), jnp.float32)

    def pass_state(h, args):
        s_c, dec = args
        h_next = h * dec[..., None, None] + s_c
        return h_next, h  # emit the state *entering* the chunk

    (hf, h_before) = lax.scan(
        pass_state, h0,
        (S_c.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_before = h_before.transpose(1, 0, 2, 3, 4)            # (b,c,h,p,n)

    y_inter = jnp.einsum("bclhn,bchpn->bclhp", Ch * jnp.exp(ca)[..., None],
                         h_before)
    y = (y_intra + y_inter).reshape(b, T, H, P)
    return y.astype(x.dtype), hf


# ---------------------------------------------------------------------------
# Mamba-2 block
# ---------------------------------------------------------------------------


def init_mamba2(key, d_model: int, *, d_state: int = 128, headdim: int = 64,
                expand: int = 2, ngroups: int = 1, conv_k: int = 4,
                dtype=jnp.float32) -> Params:
    d_inner = expand * d_model
    nheads = d_inner // headdim
    conv_dim = d_inner + 2 * ngroups * d_state
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d_proj = 2 * d_inner + 2 * ngroups * d_state + nheads
    return {
        "in_proj": init_dense(k1, d_model, d_proj, dtype=dtype),
        "conv_w": jax.random.normal(k2, (conv_k, conv_dim), dtype) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads).astype(dtype)),
        "D": jnp.ones((nheads,), dtype),
        "dt_bias": jnp.zeros((nheads,), dtype),
        "norm_scale": jnp.zeros((d_inner,), dtype),
        "out_proj": init_dense(k4, d_inner, d_model, dtype=dtype),
    }


def _split_proj(z, d_inner, ngroups, d_state, nheads):
    zs = [d_inner, d_inner, ngroups * d_state, ngroups * d_state, nheads]
    idx = [0]
    for s in zs:
        idx.append(idx[-1] + s)
    return tuple(z[..., idx[i]:idx[i + 1]] for i in range(5))


def _gated_norm(p, y, z, eps=1e-6):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * lax.rsqrt(var + eps) *
            (1.0 + p["norm_scale"].astype(jnp.float32)))


def mamba2_block(p: Params, x: jnp.ndarray, *, d_state: int = 128,
                 headdim: int = 64, expand: int = 2, ngroups: int = 1,
                 conv_k: int = 4, chunk: int = 64,
                 dt: DTypes = DEFAULT_DTYPES, state=None,
                 return_state: bool = False):
    """Full-sequence (training/prefill) Mamba-2 mixer.  x: (B, T, d).

    ``state`` / ``return_state``: optional (conv_state (B, K-1, conv_dim),
    ssm_state (B, H, P, N)) for chunked long-sequence processing — this is
    the uniform carry that ``multistage_scan`` offloads when BPTT-ing over
    sequence segments (the paper's RNN case, on an SSM).
    """
    Bsz, T, d_model = x.shape
    d_inner = expand * d_model
    nheads = d_inner // headdim
    zxbcdt = dense(p["in_proj"], x, dt)
    z, xi, Bc, Cc, dt_raw = _split_proj(zxbcdt, d_inner, ngroups, d_state, nheads)

    # causal depthwise conv over (x, B, C); prev conv window via `state`
    xbc = jnp.concatenate([xi, Bc, Cc], axis=-1)
    conv_state_in = (state[0] if state is not None else
                     jnp.zeros((Bsz, conv_k - 1, xbc.shape[-1]), xbc.dtype))
    pad = jnp.concatenate([conv_state_in.astype(xbc.dtype), xbc], axis=1)
    conv = sum(
        pad[:, i:i + T, :] * dt.c(p["conv_w"][i])[None, None, :]
        for i in range(conv_k)
    ) + dt.c(p["conv_b"])
    conv = jax.nn.silu(conv)
    new_conv_state = pad[:, T:, :]
    xi = conv[..., :d_inner]
    Bc = conv[..., d_inner:d_inner + ngroups * d_state]
    Cc = conv[..., d_inner + ngroups * d_state:]

    dts = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                          p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xi.reshape(Bsz, T, nheads, headdim)
    Bg = Bc.reshape(Bsz, T, ngroups, d_state)
    Cg = Cc.reshape(Bsz, T, ngroups, d_state)
    h0 = state[1].astype(jnp.float32) if state is not None else None
    y, hf = ssd_chunked(xh, dts, A, Bg, Cg, chunk=chunk, h0=h0)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(Bsz, T, d_inner)
    y = _gated_norm(p, y, z).astype(dt.compute)
    out = dense(p["out_proj"], y, dt)
    if return_state:
        return out, (new_conv_state.astype(jnp.float32), hf)
    return out


# ---------------------------------------------------------------------------
# decode path (single-token recurrence)
# ---------------------------------------------------------------------------


def init_ssm_cache(batch: int, d_model: int, *, d_state: int = 128,
                   headdim: int = 64, expand: int = 2, ngroups: int = 1,
                   conv_k: int = 4, n_layers: int = 1,
                   dtype=jnp.float32) -> Params:
    d_inner = expand * d_model
    nheads = d_inner // headdim
    conv_dim = d_inner + 2 * ngroups * d_state
    return {
        "conv": jnp.zeros((n_layers, batch, conv_k - 1, conv_dim), dtype),
        "ssm": jnp.zeros((n_layers, batch, nheads, headdim, d_state), dtype),
    }


def mamba2_decode_step(p: Params, x: jnp.ndarray, conv_state, ssm_state, *,
                       d_state: int = 128, headdim: int = 64, expand: int = 2,
                       ngroups: int = 1, conv_k: int = 4,
                       dt: DTypes = DEFAULT_DTYPES):
    """One token.  x: (B, 1, d); conv_state: (B, conv_k-1, conv_dim);
    ssm_state: (B, H, P, N).  Returns (y, conv_state, ssm_state)."""
    Bsz, _, d_model = x.shape
    d_inner = expand * d_model
    nheads = d_inner // headdim
    zxbcdt = dense(p["in_proj"], x, dt)[:, 0]
    z, xi, Bc, Cc, dt_raw = _split_proj(zxbcdt, d_inner, ngroups, d_state, nheads)

    xbc = jnp.concatenate([xi, Bc, Cc], axis=-1)  # (B, conv_dim)
    window = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)
    conv = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                      p["conv_w"].astype(jnp.float32)) + \
        p["conv_b"].astype(jnp.float32)
    conv = jax.nn.silu(conv)
    new_conv_state = window[:, 1:, :]
    xi = conv[..., :d_inner]
    Bc = conv[..., d_inner:d_inner + ngroups * d_state]
    Cc = conv[..., d_inner + ngroups * d_state:]

    dts = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                          p["dt_bias"].astype(jnp.float32))  # (B, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dts * A[None, :])  # (B, H)
    rep = nheads // ngroups
    xh = xi.reshape(Bsz, nheads, headdim)
    Bh = jnp.repeat(Bc.reshape(Bsz, ngroups, d_state), rep, axis=1)
    Ch = jnp.repeat(Cc.reshape(Bsz, ngroups, d_state), rep, axis=1)
    upd = jnp.einsum("bhp,bhn->bhpn", xh * dts[..., None], Bh)
    new_ssm = ssm_state * a[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_ssm, Ch)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(Bsz, d_inner)
    y = _gated_norm(p, y, z).astype(dt.compute)
    y = dense(p["out_proj"], y[:, None, :], dt)
    return y, new_conv_state.astype(conv_state.dtype), new_ssm.astype(ssm_state.dtype)
