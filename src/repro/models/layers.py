"""Shared neural-net building blocks (pure functional JAX).

Conventions:
* params are nested dicts of jnp arrays; every module is an
  ``init_*(key, ...) -> params`` / ``*_apply(params, x, ...)`` pair.
* parameters are stored in ``param_dtype`` (default fp32) and cast to
  ``compute_dtype`` (default bf16) at use — the usual mixed-precision setup.
* stacked-layer parameters carry a leading layer axis (built with vmap over
  per-layer keys) so depth is always a ``lax.scan``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Params = Any


# ---------------------------------------------------------------------------
# dtype policy
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DTypes:
    param: Any = jnp.float32
    compute: Any = jnp.bfloat16

    def c(self, x):
        return x.astype(self.compute)


DEFAULT_DTYPES = DTypes()


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def init_dense(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.float32, scale: Optional[float] = None) -> Params:
    scale = (d_in ** -0.5) if scale is None else scale
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jnp.ndarray, dt: DTypes = DEFAULT_DTYPES) -> jnp.ndarray:
    y = x @ dt.c(p["w"])
    if "b" in p:
        y = y + dt.c(p["b"])
    return y


def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.zeros((d,), dtype)}  # stored as (scale - 1)


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6,
            dt: DTypes = DEFAULT_DTYPES) -> jnp.ndarray:
    # Gemma-style: normalise in fp32, weight stored as offset from 1.
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + p["scale"].astype(jnp.float32))
    return y.astype(dt.compute)


def init_embedding(key, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"emb": jax.random.normal(key, (vocab, d), dtype) * (d ** -0.5)}


def embed(p: Params, tokens: jnp.ndarray, dt: DTypes = DEFAULT_DTYPES) -> jnp.ndarray:
    return jnp.take(dt.c(p["emb"]), tokens, axis=0)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def swiglu(gate: jnp.ndarray, up: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.silu(gate) * up


def geglu(gate: jnp.ndarray, up: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.gelu(gate, approximate=True) * up


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_table(seq_len: int, head_dim: int, theta: float = 10000.0,
               dtype=jnp.float32) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (cos, sin) of shape (seq_len, head_dim // 2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=dtype) / half)
    angles = jnp.arange(seq_len, dtype=dtype)[:, None] * freqs[None, :]
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, D); cos/sin: (S, D/2) — rotate-half convention."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    cos = cos[..., :, None, :]  # (S, 1, D/2) broadcast over heads
    sin = sin[..., :, None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def apply_rope_at(x: jnp.ndarray, pos: jnp.ndarray, head_dim: int,
                  theta: float = 10000.0) -> jnp.ndarray:
    """Rope for decode: x (B, 1, H, D), pos (B,) absolute positions."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = pos[:, None].astype(jnp.float32) * freqs[None, :]  # (B, D/2)
    cos = jnp.cos(angles)[:, None, None, :]
    sin = jnp.sin(angles)[:, None, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked cross-entropy (never materialises full (B, S, V) logits)
# ---------------------------------------------------------------------------


def chunked_ce_loss(h: jnp.ndarray, emb_w: jnp.ndarray, labels: jnp.ndarray,
                    *, chunk: int = 512, logit_cap: Optional[float] = None,
                    mask: Optional[jnp.ndarray] = None,
                    valid_vocab: Optional[int] = None) -> jnp.ndarray:
    """Mean next-token cross entropy, computed over sequence chunks so the
    full logits tensor (B, S, V) never exists.  ``emb_w``: (V, d) output
    embedding (possibly tied).  ``mask``: optional (B, S) validity mask.
    ``valid_vocab``: logical vocab when the table is padded for sharding —
    padded logits are masked out of the partition function.

    Memory: O(B * chunk * V) per step — with vocab sharded over the model
    axis this is what keeps the loss layer inside HBM at 150k-vocab scale.
    A sequence length that does not divide into ``chunk`` gets a shorter
    remainder chunk (no divisibility requirement), so the bound holds for
    every (S, chunk) pair.
    """
    B, S, D = h.shape
    chunk = min(chunk, S)
    num_full, rem = divmod(S, chunk)
    V = emb_w.shape[0]
    pad_mask = None
    if valid_vocab is not None and valid_vocab < V:
        pad_mask = (jnp.arange(V) < valid_vocab)
    if mask is None:
        mask = jnp.ones((B, S), dtype=jnp.float32)
    wt = emb_w.astype(h.dtype)

    def terms(hk, lk, mk):
        logits = hk @ wt.T  # (B, chunk, V)
        logits = softcap(logits.astype(jnp.float32), logit_cap)
        if pad_mask is not None:
            logits = jnp.where(pad_mask[None, None, :], logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lk[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mk
        return jnp.sum(nll), jnp.sum(mk)

    def body(acc, args):
        t, c = terms(*args)
        return (acc[0] + t, acc[1] + c), None

    Sf = num_full * chunk
    hc = h[:, :Sf].reshape(B, num_full, chunk, D).transpose(1, 0, 2, 3)
    lc = labels[:, :Sf].reshape(B, num_full, chunk).transpose(1, 0, 2)
    mc = mask[:, :Sf].reshape(B, num_full, chunk).transpose(1, 0, 2)
    (tot, cnt), _ = lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                             (hc, lc, mc))
    if rem:
        t, c = terms(h[:, Sf:], labels[:, Sf:], mask[:, Sf:])
        tot, cnt = tot + t, cnt + c
    return tot / jnp.maximum(cnt, 1.0)


def lm_logits(h: jnp.ndarray, emb_w: jnp.ndarray,
              logit_cap: Optional[float] = None,
              valid_vocab: Optional[int] = None) -> jnp.ndarray:
    logits = h @ emb_w.astype(h.dtype).T
    logits = softcap(logits.astype(jnp.float32), logit_cap)
    if valid_vocab is not None and valid_vocab < logits.shape[-1]:
        logits = logits[..., :valid_vocab]
    return logits
