"""Jaxpr-level cost accounting.

``compiled.cost_analysis()`` counts each ``while`` body **once**, so any
scanned program (layer stacks, attention chunk loops, CE chunk loops) is
wildly under-reported.  This walker recurses through the closed jaxpr
multiplying ``scan`` bodies by their trip count, giving exact *executed*
FLOPs — including remat recomputation (the grad-of-checkpoint recompute is
explicit in the jaxpr), MoE capacity slack, and masked-attention waste.

FLOPs: 2*M*N*K for dot_general (batch dims folded into M), window products
for convs, 1/element for elementwise, a small constant for transcendentals.

Bytes: a *materialization model* — every equation output is counted as one
HBM write + one read (2x out_bytes), except view-like ops (reshape,
broadcast, transpose, convert, slicing) which XLA folds into layouts or
fusions.  This approximates post-fusion HBM traffic to within a small
factor; it is exact in its scan multiplicity, which is what the compiled
cost_analysis gets wrong.  Used for the roofline *memory term* and for
variant-over-variant deltas (same model, same bias).
"""
from __future__ import annotations

import dataclasses
from functools import reduce
from typing import Any, Dict

import jax
from jax.extend import core

_VIEW_OPS = {
    "reshape", "broadcast_in_dim", "transpose", "squeeze", "expand_dims",
    "convert_element_type", "slice", "rev", "bitcast_convert_type",
    "copy", "stop_gradient", "name",
}
_TRANSCENDENTAL = {"exp", "log", "tanh", "logistic", "erf", "sin", "cos",
                   "rsqrt", "sqrt", "pow", "exp2", "log1p", "expm1",
                   "cbrt", "erf_inv", "digamma", "lgamma", "atan2"}
_FREE_OPS = {"name", "stop_gradient", "copy", "device_put",
             "sharding_constraint", "optimization_barrier", "pvary"}


def _nelems(v) -> int:
    return reduce(lambda a, b: a * b, v.aval.shape, 1)


def _nbytes(v) -> int:
    dt = v.aval.dtype
    return _nelems(v) * dt.itemsize


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0          # total materialization model
    bytes_major: float = 0.0    # dots/convs/gather/scatter/stacked only
    transcendentals: float = 0.0

    def __add__(self, o):
        return Cost(self.flops + o.flops, self.bytes + o.bytes,
                    self.bytes_major + o.bytes_major,
                    self.transcendentals + o.transcendentals)

    def __mul__(self, k):
        return Cost(self.flops * k, self.bytes * k, self.bytes_major * k,
                    self.transcendentals * k)


def _dot_flops(eqn) -> float:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval.shape
    k = 1
    for d in lc:
        k *= lhs[d]
    out = _nelems(eqn.outvars[0])
    return 2.0 * out * k


def _conv_flops(eqn) -> float:
    rhs = eqn.invars[1].aval.shape
    dn = eqn.params["dimension_numbers"]
    # window size x input features per group, times every output element
    window = 1
    for d in dn.rhs_spec[2:]:
        window *= rhs[d]
    cin = rhs[dn.rhs_spec[1]]
    out = _nelems(eqn.outvars[0])
    return 2.0 * out * window * cin


def _as_closed(v):
    if isinstance(v, core.ClosedJaxpr):
        return v
    if isinstance(v, core.Jaxpr):
        return core.ClosedJaxpr(v, ())
    return None


def _sub_jaxprs(params: Dict[str, Any]):
    for v in params.values():
        cj = _as_closed(v)
        if cj is not None:
            yield cj
        elif isinstance(v, (tuple, list)):
            for x in v:
                cj = _as_closed(x)
                if cj is not None:
                    yield cj
        elif isinstance(v, dict):
            # custom-call style params sometimes tuck bodies inside dicts
            for x in v.values():
                cj = _as_closed(x)
                if cj is not None:
                    yield cj


def _pallas_trips(eqn) -> float:
    """Grid trip count of a ``pallas_call`` — the kernel body executes once
    per grid point, so its cost must be multiplied accordingly."""
    gm = eqn.params.get("grid_mapping")
    grid = getattr(gm, "grid", ()) if gm is not None else ()
    trips = 1.0
    for g in grid:
        try:
            trips *= float(g)
        except (TypeError, ValueError):
            # symbolic / dynamic grid axis — count it once (lower bound)
            pass
    return max(trips, 1.0)


def jaxpr_cost(cj: core.ClosedJaxpr) -> Cost:
    total = Cost()
    for eqn in cj.jaxpr.eqns:
        name = eqn.primitive.name
        if name == "scan":
            body = eqn.params["jaxpr"]
            length = eqn.params["length"]
            inner = jaxpr_cost(body)
            total = total + inner * length
            # stacked ys are materialized across iterations
            for ov in eqn.outvars[eqn.params["num_carry"]:]:
                total.bytes += 2.0 * _nbytes(ov)
                total.bytes_major += 2.0 * _nbytes(ov)
            continue
        if name == "while":
            body = eqn.params["body_jaxpr"]
            cond = eqn.params["cond_jaxpr"]
            total = total + jaxpr_cost(body) + jaxpr_cost(cond)
            continue
        if name == "cond":
            branches = eqn.params["branches"]
            costs = [jaxpr_cost(b) for b in branches]
            total = total + max(costs, key=lambda c: c.flops)
            continue
        if name == "pallas_call":
            # The kernel body runs once per grid point; counting it once
            # (what the generic sub-jaxpr walk would do) under-reports any
            # gridded kernel by the full trip count.
            trips = _pallas_trips(eqn)
            for s in _sub_jaxprs(eqn.params):
                total = total + jaxpr_cost(s) * trips
            for ov in eqn.outvars:
                total.bytes += 2.0 * _nbytes(ov)
                total.bytes_major += 2.0 * _nbytes(ov)
            continue
        subs = list(_sub_jaxprs(eqn.params))
        if subs:
            for s in subs:
                total = total + jaxpr_cost(s)
            continue
        if name in _FREE_OPS or name in _VIEW_OPS:
            continue
        out_b = sum(_nbytes(ov) for ov in eqn.outvars)
        out_n = sum(_nelems(ov) for ov in eqn.outvars)
        if name == "dot_general":
            total.flops += _dot_flops(eqn)
            total.bytes += 2.0 * out_b
            total.bytes_major += 2.0 * out_b
        elif name == "conv_general_dilated":
            total.flops += _conv_flops(eqn)
            total.bytes += 2.0 * out_b
            total.bytes_major += 2.0 * out_b
        elif name in _TRANSCENDENTAL:
            total.flops += out_n
            total.transcendentals += out_n
            total.bytes += 2.0 * out_b
        elif name in ("gather", "scatter", "scatter-add", "scatter_add",
                      "dynamic_slice", "dynamic_update_slice", "sort",
                      "concatenate", "pad", "argmax", "argmin", "iota",
                      "cumsum", "cumlogsumexp", "cummax", "cumprod"):
            total.bytes += 2.0 * out_b
            total.bytes_major += 2.0 * out_b
        else:
            # elementwise / reduce / everything else: 1 flop per output elem
            total.flops += out_n
            total.bytes += 2.0 * out_b
    return total


def _tree_sds(tree):
    """Shape/dtype stand-ins for a pytree — the arguments may be tracers
    (trace-time planning) or concrete arrays; only shapes matter here."""
    import numpy as np

    def sds(leaf):
        dt = getattr(leaf, "dtype", None)
        if dt is None:
            dt = np.asarray(leaf).dtype
        return jax.ShapeDtypeStruct(np.shape(leaf), dt)

    return jax.tree_util.tree_map(sds, tree)


def _tree_aval_bytes(tree) -> int:
    import numpy as np

    return int(sum(
        int(np.prod(np.shape(leaf), dtype=np.int64))
        * np.dtype(getattr(leaf, "dtype", None) or np.asarray(leaf).dtype
                   ).itemsize
        for leaf in jax.tree_util.tree_leaves(tree)))


def chain_step_byte_profile(spec, params, carry0, x0, batch):
    """Per-step byte profile of a 2D-plannable chain: what the Gruslys-style
    inner DP (``schedule.gruslys_split`` via ``perfmodel.choose_2d_plan``)
    allocates against.

    Returns ``(state_bytes, layer_bytes, head_bytes)``:

    * ``state_bytes`` — one carry (an inner chunk-boundary state);
    * ``layer_bytes[j]`` — materialization-model bytes of one
      ``spec.layer_body(..., j)`` application (the activations that go live
      when layer ``j``'s chunk is rematerialised);
    * ``head_bytes`` — the ``spec.readout`` head's bytes (what head
      chunking divides).

    Shapes only: every argument may be a tracer — each layer is traced once
    on ShapeDtypeStruct stand-ins and the carry's shapes are threaded
    through ``jax.eval_shape``, so no FLOP executes.
    """
    p, c, x, b = (_tree_sds(t) for t in (params, carry0, x0, batch))
    state_bytes = _tree_aval_bytes(carry0)
    layer_bytes = []
    for j in range(spec.n_layers):
        def f(pp, cc, xx, bb, j=j):
            return spec.layer_body(pp, cc, xx, bb, j)

        layer_bytes.append(float(jaxpr_cost(jax.make_jaxpr(f)(p, c, x, b)
                                            ).bytes))
        c = _tree_sds(jax.eval_shape(f, p, c, x, b))
    head_bytes = float(jaxpr_cost(jax.make_jaxpr(spec.readout)(p, c, b)
                                  ).bytes)
    return state_bytes, tuple(layer_bytes), head_bytes


def cost_of_fn(fn, *args, **kwargs) -> Cost:
    """Trace ``fn`` on ShapeDtypeStructs and return its executed cost.

    Top-level inputs (params, caches, batch) are charged one HBM read each —
    equation outputs only cover *produced* tensors, so without this the
    weight-streaming traffic that dominates decode would be invisible.
    """
    cj = jax.make_jaxpr(fn)(*args, **kwargs)
    cost = jaxpr_cost(cj)
    in_bytes = float(sum(_nbytes(v) for v in cj.jaxpr.invars))
    cost.bytes += in_bytes
    cost.bytes_major += in_bytes
    return cost
