"""Roofline analysis from the compiled dry-run artifact.

Three terms per (arch x shape x mesh), in seconds-per-step on the target
chip (TPU v5e class: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI):

    compute    = HLO_FLOPs(per device)    / peak_FLOP/s
    memory     = HLO_bytes(per device)    / HBM_bw
    collective = collective_bytes(device) / (links x link_bw)

HLO_FLOPs and HLO_bytes come from ``compiled.cost_analysis()`` on the
SPMD-partitioned per-device module.  collective_bytes is not in
cost_analysis: we parse the optimized HLO text and sum the **operand** sizes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (ragged variants included).  The dominant term is the
step-time lower bound; ``useful_ratio = MODEL_FLOPS / HLO_FLOPs`` exposes
recompute / dispatch / masking waste.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

from repro.core.perfmodel import HardwareSpec, TPU_V5E

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"%\S+\s*=\s*(\(?[a-z0-9\[\]{},/ ]+?\)?)\s+"
    r"((?:ragged-)?(?:all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute))(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_V1_RE.search(line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device collective traffic from the optimized (post-SPMD) HLO.

    XLA:CPU (and TPU) print collectives with only the *result* type inline,
    so we parse the result shape and convert to **operand** bytes through the
    op semantics (all-gather result = operand x group; reduce-scatter result
    = operand / group; the rest are size-preserving).  For async
    ``-start``/``-done`` pairs the last tuple element is the result and only
    the start op is counted.  ``wire`` additionally estimates physical
    link bytes per device for a ring schedule (all-reduce moves ~2x its
    operand; gathers/scatters ~1x the large side).
    """
    out = {k: 0 for k in _COLLECTIVES}
    by_group: Dict[str, int] = {}
    wire = 0.0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        kind = m.group(2).removeprefix("ragged-")
        shapes = _SHAPE_RE.findall(m.group(1))
        if not shapes:
            continue
        res = _shape_bytes(*shapes[-1])  # last tuple element == result
        n = _group_size(line)
        if kind == "all-gather":
            operand = res // max(n, 1)
            wire += res * (n - 1) / max(n, 1)
        elif kind == "reduce-scatter":
            operand = res * n
            wire += res * (n - 1)
        elif kind == "all-reduce":
            operand = res
            wire += 2 * res * (n - 1) / max(n, 1)
        else:  # all-to-all / collective-permute
            operand = res
            wire += res
        out[kind] += operand
        gk = f"group{n}"
        by_group[gk] = by_group.get(gk, 0) + operand
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["wire"] = int(wire)
    out.update(by_group)
    return out


def split_fabric(coll: Dict[str, int], n_pods: int, data: int = 16,
                 model: int = 16) -> Dict[str, float]:
    """Split collective bytes into ICI vs DCN by replica-group size: on the
    (pod, data, model) mesh any group involving the pod axis (sizes n_pods,
    n_pods*data, n_pods*data*model) crosses DCN."""
    dcn_sizes = {n_pods, n_pods * data, n_pods * data * model} if n_pods > 1 \
        else set()
    ici = dcn = 0.0
    for k, v in coll.items():
        if not k.startswith("group"):
            continue
        g = int(k[5:])
        if g in dcn_sizes:
            dcn += v
        else:
            ici += v
    if ici + dcn == 0:  # no group info: attribute everything to ICI
        ici = float(coll.get("total", 0))
    return {"ici": ici, "dcn": dcn}


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    # per-device terms (jaxpr cost model / n_chips; see jaxpr_cost docs)
    flops_per_device: float
    hbm_bytes_per_device: float           # materialization model, XLA path
    hbm_bytes_kernel_adjusted: float      # minus VMEM-resident kernel traffic
    collective_bytes_per_device: float    # HLO operand bytes, loop-corrected
    collective_breakdown: Dict[str, int]
    peak_bytes_per_device: Optional[float]  # memory_analysis of full program
    t_compute: float
    t_memory: float
    t_memory_kernel: float
    t_collective: float
    model_flops_per_device: float
    useful_ratio: float
    bottleneck: str
    hardware: str = "tpu-v5e"
    variant: str = "baseline"
    xla_flops_raw: float = 0.0            # cost_analysis (while bodies x1)
    collective_bytes_raw: float = 0.0     # full-program parse, uncorrected
    jaxpr_bytes_global: float = 0.0       # raw materialization model (global)
    jaxpr_bytes_major_global: float = 0.0

    @property
    def t_bound(self) -> float:
        """Step-time lower bound with the Pallas kernels installed."""
        return max(self.t_compute, self.t_memory_kernel, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step-time bound spent on *useful* model FLOPs —
        the headline score: 1.0 means the chip does nothing but model math."""
        if self.t_bound <= 0:
            return 0.0
        return (self.model_flops_per_device / TPU_V5E.peak_flops) / self.t_bound

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        d["t_bound"] = self.t_bound
        d["roofline_fraction"] = self.roofline_fraction
        return d


FUSION_DISCOUNT = 0.25  # fraction of fusable (elementwise) outputs that
                        # actually hit HBM after XLA fusion


def build_report(*, arch: str, shape: str, mesh_name: str, n_chips: int,
                 jaxpr_flops: float, jaxpr_bytes: float,
                 score_bytes: float, coll_bytes: float,
                 coll_breakdown: Dict[str, int],
                 model_flops_total: float,
                 jaxpr_bytes_major: Optional[float] = None,
                 peak_bytes: Optional[float] = None,
                 xla_flops_raw: float = 0.0,
                 coll_bytes_raw: float = 0.0,
                 n_pods: int = 1,
                 hw: HardwareSpec = TPU_V5E,
                 variant: str = "baseline") -> RooflineReport:
    """Assemble the three-term report.  jaxpr terms are GLOBAL; divided by
    n_chips here (ideal-sharding assumption, noted in DESIGN).  HBM traffic
    uses the fusion-discounted materialization model:
    ``major + FUSION_DISCOUNT * elementwise``."""
    if jaxpr_bytes_major is None:
        jaxpr_bytes_major = jaxpr_bytes
    eff_bytes = jaxpr_bytes_major + FUSION_DISCOUNT * (
        jaxpr_bytes - jaxpr_bytes_major)
    flops = jaxpr_flops / n_chips
    hbm = eff_bytes / n_chips
    hbm_k = max(hbm - score_bytes / n_chips,
                0.2 * hbm)  # floor: params/activations always move
    t_c = flops / hw.peak_flops
    t_m = hbm / hw.hbm_bw
    t_mk = hbm_k / hw.hbm_bw
    fabric = split_fabric(coll_breakdown, n_pods)
    # ICI and DCN transfers overlap; the slower fabric bounds the term.
    t_x = max(fabric["ici"] / (hw.num_ici_links * hw.ici_bw),
              fabric["dcn"] / hw.dcn_bw)
    model_flops_dev = model_flops_total / n_chips
    bottleneck = max((("compute", t_c), ("memory", t_mk),
                      ("collective", t_x)), key=lambda kv: kv[1])[0]
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, n_chips=n_chips,
        flops_per_device=flops, hbm_bytes_per_device=hbm,
        hbm_bytes_kernel_adjusted=hbm_k,
        collective_bytes_per_device=coll_bytes,
        collective_breakdown={k: int(v) for k, v in coll_breakdown.items()},
        peak_bytes_per_device=peak_bytes,
        t_compute=t_c, t_memory=t_m, t_memory_kernel=t_mk, t_collective=t_x,
        model_flops_per_device=model_flops_dev,
        useful_ratio=(model_flops_dev / flops) if flops else 0.0,
        bottleneck=bottleneck, hardware=hw.name, variant=variant,
        xla_flops_raw=xla_flops_raw, collective_bytes_raw=coll_bytes_raw,
        jaxpr_bytes_global=jaxpr_bytes,
        jaxpr_bytes_major_global=jaxpr_bytes_major)


def save_report(path: str, report: RooflineReport) -> None:
    try:
        with open(path) as f:
            data = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        data = {}
    key = f"{report.arch}|{report.shape}|{report.mesh}|{report.variant}"
    data[key] = report.to_json()
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
