from repro.analysis.roofline import RooflineReport, build_report, collective_bytes, save_report
from repro.analysis.jaxpr_cost import Cost, jaxpr_cost, cost_of_fn

__all__ = ["RooflineReport", "build_report", "collective_bytes",
           "save_report", "Cost", "jaxpr_cost", "cost_of_fn"]
