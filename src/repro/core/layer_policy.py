"""Per-layer activation policies for layered models (transformers).

For transformers the paper's "sequence" axis is *depth*: one decoder layer is
one chain step, the layer-input activation is the state.  This module wraps a
layer function in the appropriate remat/offload policy and exposes a scanned
layer-stack combinator used by every architecture in ``repro.models``.

Policies (see ``repro.core.offload`` for the registry):

* ``none``                    — store all activations (naive baseline).
* ``full``                    — remat everything, save only layer boundaries
                                 in HBM (single-stage checkpointing).
* ``offload_layer``           — boundaries to pinned host memory (the paper's
                                 multistage strategy over depth).
* ``offload_layer_save_dots`` — boundaries to host, matmul outputs in HBM
                                 (beyond-paper hybrid: trades a little HBM for
                                 less recompute — see EXPERIMENTS §Perf).
* ``dots`` / ``dots_no_batch``— classic XLA-friendly balanced policies.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
from jax import lax

from repro.core import offload as ofl

LayerFn = Callable[[Any, Any, Any], Any]  # (layer_params, x, extras) -> x


def validate_policy_name(policy_name: str) -> None:
    """Raise ``ValueError`` listing the registry for an unknown policy.

    Called eagerly at every combinator entry point so a typo fails at call
    time with the full menu, not deep inside a trace."""
    known = ("none", *ofl.policy_names())
    if policy_name not in known:
        raise ValueError(
            f"unknown layer policy {policy_name!r}; known policies: "
            f"{list(known)}"
        )


def remat_layer(layer_fn: Callable, policy_name: str = "offload_layer",
                tag_input: bool = True) -> Callable:
    """Wrap ``layer_fn(params, x, *extras) -> x`` in a remat region whose
    input activation is tagged ``LAYER_INPUT`` (the offloaded state)."""
    validate_policy_name(policy_name)
    if policy_name == "none":
        return layer_fn

    policy = ofl.make_policy(policy_name)

    def tagged(params, x, *extras):
        if tag_input:
            x = ofl.tag(x, ofl.LAYER_INPUT)
        return layer_fn(params, x, *extras)

    return jax.checkpoint(tagged, policy=policy, prevent_cse=False)


def scan_layers(
    layer_fn: Callable,
    stacked_params: Any,
    x: Any,
    *extras: Any,
    policy_name: str = "offload_layer",
    unroll: int = 1,
) -> Any:
    """Apply ``num_layers`` stacked layers to ``x`` via ``lax.scan`` with the
    given activation policy.  ``stacked_params`` has a leading layer axis on
    every leaf.  ``extras`` are broadcast (non-scanned) arguments such as
    rotary tables or attention masks.

    This is the depth-direction instance of the paper's technique: the scan
    carry is the layer-input activation; the remat policy decides whether each
    boundary lives in HBM or host memory, and XLA turns host placements into
    asynchronous DMA transfers overlapped with compute.
    """
    validate_policy_name(policy_name)
    wrapped = remat_layer(layer_fn, policy_name)

    def body(carry, lp):
        y = wrapped(lp, carry, *extras)
        return y, None

    out, _ = lax.scan(body, x, stacked_params, unroll=unroll)
    return out


def scan_layers_collect(
    layer_fn: Callable,
    stacked_params: Any,
    x: Any,
    *extras: Any,
    policy_name: str = "offload_layer",
    unroll: int = 1,
) -> Tuple[Any, Any]:
    """Like ``scan_layers`` but the layer returns ``(x, aux)`` and the stacked
    aux is returned (used for MoE balance losses, per-layer KV caches)."""
    validate_policy_name(policy_name)
    wrapped = remat_layer(layer_fn, policy_name)

    def body(carry, lp):
        y, aux = wrapped(lp, carry, *extras)
        return y, aux

    return lax.scan(body, x, stacked_params, unroll=unroll)
