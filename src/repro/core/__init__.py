"""Core: asynchronous multistage checkpointing (the paper's contribution).

Two first-class paths:

* **Executor path** (`executor`, `storage`, `revolve`, `schedule`) — the
  paper-faithful library: a pyrevolve-style schedule interpreter with real
  asynchronous store/prefetch threads over RAM/disk Level-2 backends.
* **Compiled path** (`multistage_scan`, `layer_policy`, `offload`) — the
  TPU-native incarnation: segmented scans whose boundary states XLA offloads
  to pinned host memory with async DMA, recomputing segment interiors.

`perfmodel` carries the paper's §3 analysis, coupled to the roofline terms of
the compiled dry-run.
"""
from repro.core.revolve import (
    beta, optimal_advances, recompute_factor, revolve_schedule,
)
from repro.core.schedule import (
    RunCursor, SegmentPlan, SegmentSpec, multistage_recompute_factor,
    multistage_schedule, segment_plan,
)
from repro.core.faults import (
    ChecksumError, FaultPlan, InjectedFault, StorageFault, TornRecordError,
    WriterCrashError,
)
from repro.core.journal import RecoveredRun
from repro.core.perfmodel import (
    HardwareSpec, TPU_V5E, optimal_interval, t_inf, t_revolve, t_async,
    times_from_roofline,
)
from repro.core.storage import (
    AsyncTransferEngine, CompressedStorage, DiskStorage, JournaledStorage,
    RAMStorage, TieredStorage, make_backend, register_backend,
)
from repro.core.executor import (
    CheckpointExecutor, ExecutionStats, InterpretedSegmentRunner,
    MultistageRun,
)
from repro.core.compiled_ops import CompiledChainOps, CompiledSegmentRunner
from repro.core.multistage_scan import multistage_scan, bptt_grad, choose_interval
from repro.core.layer_policy import remat_layer, scan_layers, scan_layers_collect
from repro.core import offload

__all__ = [
    "beta", "optimal_advances", "recompute_factor", "revolve_schedule",
    "RunCursor", "SegmentPlan", "SegmentSpec", "segment_plan",
    "multistage_schedule", "multistage_recompute_factor",
    "ChecksumError", "FaultPlan", "InjectedFault", "StorageFault",
    "TornRecordError", "WriterCrashError", "RecoveredRun",
    "HardwareSpec", "TPU_V5E", "optimal_interval", "t_inf", "t_revolve",
    "t_async", "times_from_roofline",
    "RAMStorage", "DiskStorage", "CompressedStorage", "JournaledStorage",
    "TieredStorage", "AsyncTransferEngine",
    "make_backend", "register_backend",
    "CheckpointExecutor", "ExecutionStats", "InterpretedSegmentRunner",
    "MultistageRun",
    "CompiledChainOps", "CompiledSegmentRunner",
    "multistage_scan", "bptt_grad", "choose_interval",
    "remat_layer", "scan_layers", "scan_layers_collect",
    "offload",
]
