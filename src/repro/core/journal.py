"""Write-ahead journal file format for crash-consistent Level-2 storage.

One append-only binary file records every Level-2 mutation of a multistage
run: ``STORE``/``DELETE`` of boundary states (payload = pickled host
pytree), ``CURSOR`` checkpoints of the executor's plan position, and
``BEGIN``/``END`` markers bracketing one gradient run (an *epoch*).  Each
record carries a CRC-32 of its key+payload.  Durability is **group
commit** at segment granularity: bulk records (``STORE``/``DELETE``) may
defer their fsync (``append(..., sync=False)``), and the next commit
barrier — a ``CURSOR``/``BEGIN``/``END`` append or an explicit
:meth:`JournalFile.flush` — fsyncs the shared fd, landing every deferred
record before it.  Prefix semantics are preserved: by the time a cursor is
durable, every store written before it is durable too, so a recovered
cursor can never claim a non-durable boundary (the fsync-per-record WAL
guarantee at ~one fsync per segment instead of one per record).

Record layout (little-endian)::

    magic   4s   b"RJ1\\0"
    op      B    1=BEGIN 2=STORE 3=DELETE 4=CURSOR 5=END
    key_len I    length of the pickled key
    pay_len Q    length of the payload
    crc     I    crc32(op_byte + key_bytes + payload_bytes)
    hcrc    I    crc32 of the preceding header bytes (framing guard)
    key     key_len bytes
    payload pay_len bytes

``hcrc`` exists so the damage taxonomy below cannot be fooled by bit rot
in a *length* field: without it, a flipped ``pay_len`` would make the
record extend past EOF and be misclassified as a torn tail (silently
truncated) instead of surfacing as checksum damage.

Damage model (what :func:`scan` distinguishes):

* a record whose header or body extends past EOF is **torn** — the
  expected artifact of a crash mid-``write``; the valid prefix ends at the
  record's start and the tail is discardable (``JournaledStorage``
  truncates it on open);
* a *complete* record whose CRC does not match is a **checksum** failure —
  bit rot or tampering, never produced by a clean crash; surfaced as a
  typed :class:`~repro.core.faults.ChecksumError` unless the caller asked
  for repair (truncate back to the last good record).

Everything after the first damaged record is suspect (framing may be
lost), so the valid prefix always ends there — standard WAL semantics.

The file is accessed through ``os.pread``/``os.pwrite`` on a single fd so
concurrent readers (prefetch threads re-hydrating states) never race the
appender's file position.
"""
from __future__ import annotations

import os
import pickle
import struct
import threading
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.core.faults import ChecksumError, TornRecordError

MAGIC = b"RJ1\x00"
OP_BEGIN, OP_STORE, OP_DELETE, OP_CURSOR, OP_END = 1, 2, 3, 4, 5
OP_NAMES = {OP_BEGIN: "BEGIN", OP_STORE: "STORE", OP_DELETE: "DELETE",
            OP_CURSOR: "CURSOR", OP_END: "END"}

_HEADER = struct.Struct("<4sBIQII")  # magic, op, key_len, pay_len, crc, hcrc

# The commit barrier primitive.  fdatasync flushes the data and the
# metadata needed to read it back (file size) but skips timestamp-only
# metadata — measurably cheaper per barrier than fsync on journaling
# filesystems, with identical WAL durability for an append-only log.
_sync_fd = getattr(os, "fdatasync", os.fsync)


def _crc(op: int, key: bytes, payload: bytes) -> int:
    c = zlib.crc32(bytes([op]))
    c = zlib.crc32(key, c)
    return zlib.crc32(payload, c)


def _pack_header(op: int, key: bytes, payload: bytes) -> bytes:
    head = struct.pack("<4sBIQI", MAGIC, op, len(key), len(payload),
                       _crc(op, key, payload))
    return head + struct.pack("<I", zlib.crc32(head))


def _unpack_header(header: bytes):
    """Returns (op, key_len, pay_len, crc) or None when the framing
    fields themselves fail their CRC (bit rot in the header)."""
    magic, op, key_len, pay_len, crc, hcrc = _HEADER.unpack(header)
    if magic != MAGIC or zlib.crc32(header[:-4]) != hcrc:
        return None
    return op, key_len, pay_len, crc


@dataclass(frozen=True)
class Record:
    """One decoded journal record; ``payload_off`` locates the raw payload
    bytes in the file so large states can be re-read lazily."""

    op: int
    key: Any
    payload: bytes
    start: int          # file offset of the record header
    payload_off: int    # file offset of the payload bytes
    end: int            # file offset one past the record


@dataclass(frozen=True)
class Damage:
    """Where and how a scan stopped trusting the journal."""

    kind: str       # "torn" | "checksum"
    offset: int     # start of the damaged record == end of the valid prefix
    detail: str = ""


@dataclass
class ScanResult:
    records: List[Record] = field(default_factory=list)
    damage: Optional[Damage] = None
    valid_end: int = 0   # offset one past the last intact record


@dataclass(frozen=True)
class RecoveredRun:
    """What survived the crash, as reconstructed from the journal's last
    epoch: the durable boundary keys (journal order == store order), the
    last plan cursor, and any per-segment reverse artifacts the executor
    checkpointed alongside it (e.g. per-step input cotangents).

    ``keys`` + ``cursor`` imply the plan position a resume can restart
    from: forward resumes replay from the largest durable boundary (at
    most one interval behind the cursor), reverse resumes restart at
    ``cursor.segment_index`` with the cursor's adjoint — see
    ``CheckpointExecutor.multistage_forward(resume_from=...)``.
    """

    keys: Tuple[Any, ...]
    cursor: Any = None                      # last RunCursor, or None
    artifacts: Dict[Any, Any] = None        # segment begin -> reverse artifact
    meta: Dict[str, Any] = None             # BEGIN record metadata
    torn: bool = False                      # a torn tail was discarded on open
    journal_bytes: int = 0

    def __post_init__(self):
        object.__setattr__(self, "artifacts", dict(self.artifacts or {}))
        object.__setattr__(self, "meta", dict(self.meta or {}))


class JournalFile:
    """The raw record file: append (durable), pread, scan, truncate.

    Thread-safe: one lock serialises appends/truncations; reads go through
    ``os.pread`` and never touch the shared file position.
    """

    def __init__(self, path: str, *, fsync: bool = True):
        self.path = path
        self.fsync = fsync
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._fd = os.open(path, os.O_RDWR | os.O_CREAT, 0o644)
        self._lock = threading.Lock()
        self._end = os.fstat(self._fd).st_size
        self._dirty = False      # pwrite'd bytes not yet fsync'd
        self.fsync_count = 0     # instrumentation: actual fsync calls

    # ------------------------------------------------------------------ write
    def append(self, op: int, key: bytes = b"", payload: bytes = b"",
               *, sync: Optional[bool] = None) -> Tuple[int, int]:
        """Append one record; returns its ``(start, end)`` extent.

        ``sync=None`` (default) fsyncs per the file's ``fsync`` setting —
        the classic one-fsync-per-record WAL.  ``sync=False`` defers the
        fsync: the bytes are written (visible to in-process ``pread``)
        but only made durable by the next syncing append or an explicit
        :meth:`flush` — the group-commit path.  ``sync=True`` forces a
        commit barrier: because all records share one fd, this fsync also
        lands every deferred record written before it (WAL prefix
        semantics are preserved — a durable barrier implies a durable
        prefix).  ``fsync=False`` files never sync regardless of ``sync``.
        """
        data = _pack_header(op, key, payload) + key + payload
        with self._lock:
            start = self._end
            os.pwrite(self._fd, data, start)
            do_sync = self.fsync if sync is None else (sync and self.fsync)
            if do_sync:
                _sync_fd(self._fd)
                self.fsync_count += 1
                self._dirty = False
            else:
                self._dirty = True
            self._end = start + len(data)
            return start, self._end

    def flush(self) -> None:
        """Group-commit barrier: fsync any deferred appends (no-op when
        nothing is pending or the file runs with ``fsync=False``)."""
        with self._lock:
            if self._dirty and self.fsync:
                _sync_fd(self._fd)
                self.fsync_count += 1
            self._dirty = False

    def truncate(self, offset: int) -> None:
        with self._lock:
            os.ftruncate(self._fd, offset)
            if self.fsync:
                _sync_fd(self._fd)
                self.fsync_count += 1
            self._dirty = False
            self._end = offset

    # ------------------------------------------------------------------- read
    def pread(self, length: int, offset: int) -> bytes:
        return os.pread(self._fd, length, offset)

    @property
    def size(self) -> int:
        with self._lock:
            return self._end

    def scan(self) -> ScanResult:
        """Decode records from offset 0 until EOF or the first damage."""
        out = ScanResult()
        size = os.fstat(self._fd).st_size
        off = 0
        while off < size:
            header = self.pread(_HEADER.size, off)
            if len(header) < _HEADER.size:
                out.damage = Damage("torn", off, "truncated header")
                break
            decoded = _unpack_header(header)
            if decoded is None or decoded[0] not in OP_NAMES:
                # the header CRC separates bit rot in framing fields from
                # a genuinely short tail: a complete-but-rotted header is
                # corruption, never a crash artifact
                out.damage = Damage("checksum", off,
                                    f"header at {off} fails its CRC")
                break
            op, key_len, pay_len, crc = decoded
            body_off = off + _HEADER.size
            end = body_off + key_len + pay_len
            if end > size:
                out.damage = Damage("torn", off, "truncated body")
                break
            body = self.pread(key_len + pay_len, body_off)
            key_b, payload = body[:key_len], body[key_len:]
            if _crc(op, key_b, payload) != crc:
                out.damage = Damage(
                    "checksum", off,
                    f"{OP_NAMES[op]} record at {off} fails its CRC")
                break
            key = pickle.loads(key_b) if key_b else None
            out.records.append(Record(op=op, key=key, payload=payload,
                                      start=off,
                                      payload_off=body_off + key_len,
                                      end=end))
            out.valid_end = end
            off = end
        return out

    def read_payload(self, rec_off: int) -> bytes:
        """Re-read (and re-verify) one record's payload by header offset —
        used to serve ``get`` lazily from the journal after recovery."""
        header = self.pread(_HEADER.size, rec_off)
        if len(header) < _HEADER.size:
            raise TornRecordError(
                f"journal record at {rec_off} is truncated")
        decoded = _unpack_header(header)
        if decoded is None:
            raise ChecksumError(
                f"journal record at {rec_off}: header fails its CRC")
        op, key_len, pay_len, crc = decoded
        body = self.pread(key_len + pay_len, rec_off + _HEADER.size)
        if len(body) < key_len + pay_len:
            raise TornRecordError(
                f"journal record at {rec_off} is truncated")
        key_b, payload = body[:key_len], body[key_len:]
        if _crc(op, key_b, payload) != crc:
            raise ChecksumError(
                f"journal {OP_NAMES.get(op, op)} record at {rec_off} "
                "fails its CRC (torn or corrupted)")
        return payload

    def close(self) -> None:
        with self._lock:
            if self._fd >= 0:
                os.close(self._fd)
                self._fd = -1

    # -- fault-injection hooks (tests only) -----------------------------------
    def debug_flip_byte(self, offset: int) -> None:
        """Flip one byte in place (simulated bit rot)."""
        b = self.pread(1, offset)
        if b:
            os.pwrite(self._fd, bytes([b[0] ^ 0xFF]), offset)
            if self.fsync:
                _sync_fd(self._fd)

    def debug_truncate(self, offset: int) -> None:
        """Tear the file mid-record (simulated crash mid-write)."""
        self.truncate(offset)


def iter_epoch(records: List[Record]) -> Iterator[Record]:
    """Yield the records of the *last* epoch (after the final BEGIN)."""
    start = 0
    for i, rec in enumerate(records):
        if rec.op == OP_BEGIN:
            start = i
    return iter(records[start:])
