"""Classic Revolve (Griewank & Walther, Algorithm 799) — optimal single-stage
binomial checkpointing.

Conventions
-----------
A *chain* of ``n`` sequential steps ``F_1 .. F_n`` maps state ``x_0`` to
``x_n``.  Reversal needs the states ``x_{n-1}, ..., x_0`` in reverse order.
``s`` snapshot slots are available, *including* the slot that permanently
holds the initial state of the (sub-)chain being reversed.

``t(n, s)`` is the minimal number of forward ADVANCE operations needed to
reverse the chain (every advance is counted, including the first sweep).
Griewank--Walther closed form::

    beta(s, r) = C(s + r, s)
    r  = min r such that beta(s, r) >= n       (the "repetition number")
    t(n, s) = r * n - beta(s + 1, r - 1)

A *recompute factor* of 1 means no recomputation: reversing ``n`` steps
requires at least ``n - 1`` advances (to reach ``x_{n-1}``), so::

    R(n, s) = t(n, s) / (n - 1)      for n > 1, else 1.0

This is the quantity plotted in the paper's Figures 3 and 5 (R grows ~log(n)
for fixed ``s``).

The schedule generator emits an action stream executed by
``repro.core.executor.CheckpointExecutor``.
"""
from __future__ import annotations

import enum
import functools
import math
from dataclasses import dataclass
from typing import Iterator, List


# ---------------------------------------------------------------------------
# Closed-form optimal cost
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def beta(s: int, r: int) -> int:
    """beta(s, r) = C(s + r, s): max chain length reversible with ``s`` slots
    and repetition number ``r`` (each step advanced at most ``r`` times)."""
    if r < 0:
        return 1 if r == -1 else 0  # beta(s, -1) == 1 by the GW convention
    return math.comb(s + r, s)


@functools.lru_cache(maxsize=None)
def repetition_number(n: int, s: int) -> int:
    """Smallest r with beta(s, r) >= n."""
    if n <= 0:
        raise ValueError(f"need n >= 1, got {n}")
    if s <= 0:
        raise ValueError(f"need s >= 1, got {s}")
    r = 0
    while beta(s, r) < n:
        r += 1
    return r


def optimal_advances(n: int, s: int) -> int:
    """t(n, s): minimal total forward advances to reverse an n-step chain with
    s snapshot slots (closed form, exact)."""
    if n == 1:
        return 0
    r = repetition_number(n, s)
    return r * n - beta(s + 1, r - 1)


def recompute_factor(n: int, s: int) -> float:
    """R(n, s) with R == 1.0 meaning no recomputation (paper's convention)."""
    if n <= 1:
        return 1.0
    return optimal_advances(n, s) / (n - 1)


def optimal_advances_dp(n: int, s: int) -> int:
    """O(n^2 s) dynamic program for t(n, s) — used by tests to validate the
    closed form on small inputs."""

    @functools.lru_cache(maxsize=None)
    def t(n_: int, s_: int) -> int:
        if n_ == 1:
            return 0
        if s_ == 1:
            return n_ * (n_ - 1) // 2
        return min(m + t(n_ - m, s_ - 1) + t(m, s_) for m in range(1, n_))

    return t(n, s)


# ---------------------------------------------------------------------------
# Schedule generation
# ---------------------------------------------------------------------------


class Op(enum.Enum):
    """Actions understood by the executor.

    ADVANCE   — run forward steps ``begin..end`` (exclusive), carrying state.
    STORE     — snapshot the current state (index attached) into a slot.
    RESTORE   — load the snapshot of state ``index`` into the current state.
    FREE      — release the slot holding state ``index``.
    BACKWARD  — run the combined forward+backward for step ``index + 1``
                (consumes state ``x_index``, produces adjoint contribution).
    """

    ADVANCE = "advance"
    STORE = "store"
    RESTORE = "restore"
    FREE = "free"
    BACKWARD = "backward"


@dataclass(frozen=True)
class Action:
    op: Op
    index: int  # state index (STORE/RESTORE/FREE/BACKWARD) or begin (ADVANCE)
    end: int = -1  # exclusive end state index for ADVANCE

    def __repr__(self) -> str:  # compact, for debugging / golden tests
        if self.op is Op.ADVANCE:
            return f"A({self.index}->{self.end})"
        return f"{self.op.name[0]}({self.index})"


def _optimal_split(n: int, s: int) -> int:
    """Position (offset from chain begin) of the first checkpoint for an
    optimal reversal of an n-step chain with s slots.

    Tries the well-known closed-form candidates first and verifies each via
    the closed-form cost; falls back to a scan (only ever needed for small n).
    """
    r = repetition_number(n, s)
    target = optimal_advances(n, s)
    cands = {
        beta(s - 1, r - 1),
        beta(s - 1, r - 1) + beta(s - 1, r - 2),
        n - beta(s, r - 1),
        beta(s, r - 1),
    }
    for m in sorted(c for c in cands if 1 <= c < n):
        if m + optimal_advances(n - m, s - 1) + optimal_advances(m, s) == target:
            return m
    # exhaustive fallback (closed-form costs, O(n) with O(1) evals)
    for m in range(1, n):
        if m + optimal_advances(n - m, s - 1) + optimal_advances(m, s) == target:
            return m
    raise AssertionError(f"no optimal split found for n={n}, s={s}")


def revolve_schedule(n: int, s: int, offset: int = 0) -> List[Action]:
    """Full optimal reversal schedule for an ``n``-step chain with ``s``
    snapshot slots.  State ``x_offset`` is assumed stored on entry (it
    occupies one of the ``s`` slots).

    The returned action stream reverses steps ``offset+n .. offset+1``.
    Executing it performs exactly ``optimal_advances(n, s)`` ADVANCE steps
    (asserted in tests) and ``n`` BACKWARD steps.
    """
    if n < 1:
        raise ValueError(f"need n >= 1, got {n}")
    if s < 1:
        raise ValueError(f"need s >= 1, got {s}")
    out: List[Action] = []
    _revolve(offset, offset + n, s, out)
    return out


def _revolve(b: int, e: int, s: int, out: List[Action]) -> None:
    """Reverse steps b+1..e given x_b stored, with s slots (incl. x_b's)."""
    n = e - b
    if n == 1:
        out.append(Action(Op.RESTORE, b))
        out.append(Action(Op.BACKWARD, b))
        return
    if s == 1:
        # No free slots: replay from x_b for every backward step.
        for k in range(e - 1, b - 1, -1):
            out.append(Action(Op.RESTORE, b))
            if k > b:
                out.append(Action(Op.ADVANCE, b, k))
            out.append(Action(Op.BACKWARD, k))
        return
    if n <= s:
        # Everything fits: sweep forward storing each state, then reverse.
        out.append(Action(Op.RESTORE, b))
        for k in range(b + 1, e):
            out.append(Action(Op.ADVANCE, k - 1, k))
            if k < e - 1:
                out.append(Action(Op.STORE, k))
        out.append(Action(Op.BACKWARD, e - 1))
        for k in range(e - 2, b, -1):
            out.append(Action(Op.RESTORE, k))
            out.append(Action(Op.BACKWARD, k))
            out.append(Action(Op.FREE, k))
        out.append(Action(Op.RESTORE, b))
        out.append(Action(Op.BACKWARD, b))
        return
    m = _optimal_split(n, s)
    mid = b + m
    out.append(Action(Op.RESTORE, b))
    out.append(Action(Op.ADVANCE, b, mid))
    out.append(Action(Op.STORE, mid))
    _revolve(mid, e, s - 1, out)
    out.append(Action(Op.FREE, mid))
    _revolve(b, mid, s, out)


@functools.lru_cache(maxsize=1024)
def revolve_subplan(n: int, s: int, offset: int = 0) -> tuple:
    """Immutable Revolve sub-plan for one multistage segment.

    Same action stream as :func:`revolve_schedule`, but returned as a tuple so
    it can live inside the frozen ``SegmentPlan`` IR (``repro.core.schedule``)
    and be shared across runs — segments of equal length and offset are
    planned exactly once per process.
    """
    return tuple(revolve_schedule(n, s, offset=offset))


# ---------------------------------------------------------------------------
# Schedule accounting (used by tests and the perf model)
# ---------------------------------------------------------------------------


def count_advances(schedule: List[Action]) -> int:
    return sum(a.end - a.index for a in schedule if a.op is Op.ADVANCE)


def count_backwards(schedule: List[Action]) -> int:
    return sum(1 for a in schedule if a.op is Op.BACKWARD)


def peak_slots(schedule: List[Action], initial: int = 1) -> int:
    """Max number of simultaneously live snapshot slots while executing."""
    live = initial  # the initial state of the chain is stored on entry
    peak = live
    for a in schedule:
        if a.op is Op.STORE:
            live += 1
            peak = max(peak, live)
        elif a.op is Op.FREE:
            live -= 1
    return peak


def iter_backward_indices(schedule: List[Action]) -> Iterator[int]:
    for a in schedule:
        if a.op is Op.BACKWARD:
            yield a.index
