"""Level-2 storage backends with asynchronous store / prefetch threads.

This is the paper-faithful substrate: background threads move state pytrees
between the compute level (Level 1: this process's arrays) and a Level-2
store (host RAM dict, or files on disk standing in for an SSD).  The threads
release the GIL during I/O and ``np.copy``, so transfers genuinely overlap
with jitted compute — the same mechanism (python threading around numpy
buffers) the paper's pyrevolve implementation uses.

All backends speak the same protocol::

    put(key, pytree)          # blocking store
    get(key)                  # blocking load
    delete(key), __contains__, keys()

Backends are pluggable through a registry: ``make_backend("ram" | "disk" |
"compressed" | "tiered", ...)`` builds one by name (``register_backend``
adds new kinds), and ``CompressedStorage`` wraps any inner backend with int8
block-quantisation of the host copy (reusing
``repro.distributed.compression``), shrinking Level-2 footprint ~4x at a
bounded, measured precision cost.

``TieredStorage`` is the capacity-bounded realisation of the paper's "any
size" claim: a fast tier (host RAM, ``capacity_bytes=``) that write-behind
evicts cold resources to a slow tier (disk, optionally compressed).
Eviction is plan-aware: ``set_plan`` accepts either a ``SegmentPlan``
(legacy — its exact reverse-order access sequence) or any
``ResourceAccessPlan``-shaped object exposing ``distances()`` (the generic
resource IR from ``repro.core.schedule``), so boundary states and other
offloadable resources — e.g. MoE expert parameter blobs — share one
capacity budget with the victim always the key whose next use is farthest
away (Belady's rule).  Keys the current plan does not mention fall back to
LRU/FIFO order and evict *before* any plan key; ``untracked_keys`` counts
how many resident keys each ``set_plan`` call left in that fallback class.
The fast tier never exceeds its budget; states larger than the whole
budget bypass it and go straight to the slow tier.

Stored pytrees are frozen to read-only numpy arrays: ``get`` can then hand
back the canonical copy without a defensive deep-copy, and a caller that
tries to mutate a checkpoint in place gets a loud ``ValueError`` instead of
silently corrupting the next Revolve replay.

``JournaledStorage`` composes over any of the above
(``make_backend(kind, journal=directory)``) and makes the Level-2 store
*crash-consistent*: every store/delete is write-ahead-logged with a
per-record CRC and fsynced before it is acknowledged, the executor's plan
cursor is checkpointed through the same log, torn tails are detected (and
repaired) on open, and ``recover()`` returns the surviving boundary keys +
plan position so a crashed reverse sweep resumes from the last durable
boundary instead of t=0 (see ``repro.core.journal`` for the format and
``CheckpointExecutor.multistage_forward(resume_from=...)`` for the replay).

``AsyncTransferEngine`` wraps a backend with a writer thread + per-key
prefetch threads and exposes the async verbs the multistage executor needs:
``store_async``, ``wait_stores``, ``prefetch_async``, ``wait_prefetch``.
``delete`` invalidates any staged prefetch of the key (delete + re-store
can never hand back a stale value), and staged-prefetch bytes are counted
(``staged_bytes`` / ``staged_peak_bytes``).  ``cursor_async`` /
``delete_async`` route journal cursor checkpoints and boundary frees
through the same FIFO writer queue, so the journal can never record a
segment as complete before its boundary store is durable.  Fault injection
(``repro.core.faults``) hooks the writer/fetch paths behind a
zero-overhead-when-disabled ``is not None`` test.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import queue
import threading
import time
from typing import Any, Callable, Dict, Iterable, Optional

import numpy as np

import jax

from repro.core import faults as _faults
from repro.core import journal as _journal
from repro.core.faults import (ChecksumError, StorageFault, WriterCrashError,
                               WriterKilled)
from repro.core.journal import RecoveredRun


def _to_host(tree: Any) -> Any:
    """Deep-copy a pytree of arrays to plain numpy (detaches from Level 1)."""
    return jax.tree_util.tree_map(lambda x: np.array(x, copy=True), tree)


def _freeze(tree: Any) -> Any:
    """Deep-copy a pytree to *read-only* numpy arrays.

    The frozen copy is the backend's canonical checkpoint: ``get`` may
    return it by reference (no per-read deep copy), because any caller
    attempting in-place mutation raises ``ValueError`` instead of silently
    corrupting the state the next Revolve replay starts from.
    """
    def f(x):
        a = np.array(x, copy=True)
        a.setflags(write=False)
        return a

    return jax.tree_util.tree_map(f, tree)


def _freeze_in_place(tree: Any) -> Any:
    """Mark a *freshly materialised* pytree read-only without copying.

    For arrays no one else references (pickle/decode output, or already
    frozen), clearing the writeable flag is enough — copying would just
    double the transfer cost the caller is trying to hide.
    """
    def f(x):
        a = np.asarray(x)
        if a.flags.writeable:
            a.setflags(write=False)
        return a

    return jax.tree_util.tree_map(f, tree)


def tree_bytes(tree: Any) -> int:
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        # nbytes fast path: accounting on a mesh-sharded jax Array must not
        # gather it to host (np.asarray would)
        nb = getattr(x, "nbytes", None)
        total += int(nb) if nb is not None else np.asarray(x).nbytes
    return total


class RAMStorage:
    """Level-2 store in host RAM (the KNL MCDRAM->DRAM platform).

    ``bandwidth`` (bytes/s), if set, throttles transfers so the paper's
    T_T-vs-T_A trade-off can be reproduced deterministically on any machine.
    """

    def __init__(self, bandwidth: Optional[float] = None):
        self._data: Dict[Any, Any] = {}
        self._sizes: Dict[Any, int] = {}
        self._lock = threading.Lock()
        self.bandwidth = bandwidth
        self.bytes_written = 0
        self.bytes_read = 0
        self.live_bytes = 0
        self.peak_bytes = 0   # high-water Level-2 footprint across the run

    def _throttle(self, nbytes: int) -> None:
        if self.bandwidth:
            time.sleep(nbytes / self.bandwidth)

    def put(self, key: Any, tree: Any) -> None:
        host = _freeze(tree)
        nb = tree_bytes(host)
        self._throttle(nb)
        with self._lock:
            self._data[key] = host
            self.bytes_written += nb
            self.live_bytes += nb - self._sizes.get(key, 0)
            self._sizes[key] = nb
            self.peak_bytes = max(self.peak_bytes, self.live_bytes)

    def get(self, key: Any) -> Any:
        """Return the stored pytree.  Leaves are read-only numpy arrays
        (the canonical checkpoint copy): mutating them raises, so the
        aliasing can never corrupt a later replay."""
        with self._lock:
            host = self._data[key]
        nb = tree_bytes(host)
        self._throttle(nb)
        with self._lock:
            self.bytes_read += nb
        return host

    def delete(self, key: Any) -> None:
        with self._lock:
            self._data.pop(key, None)
            self.live_bytes -= self._sizes.pop(key, 0)

    def __contains__(self, key: Any) -> bool:
        with self._lock:
            return key in self._data

    def keys(self) -> Iterable[Any]:
        with self._lock:
            return list(self._data)


class DiskStorage:
    """Level-2 store on disk (the CPU DRAM->SSD platform).  One pickle file
    per checkpoint, written/read by the background threads through the
    filesystem API — exactly the paper's CPU-platform mechanism."""

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self._keys: Dict[Any, str] = {}
        self._sizes: Dict[Any, int] = {}
        self.bytes_written = 0
        self.bytes_read = 0
        self.live_bytes = 0
        self.peak_bytes = 0   # high-water Level-2 footprint across the run

    def _path(self, key: Any) -> str:
        return os.path.join(self.directory, f"ckpt_{key}.pkl")

    def put(self, key: Any, tree: Any) -> None:
        host = _to_host(tree)
        path = self._path(key)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            pickle.dump(host, f, protocol=pickle.HIGHEST_PROTOCOL)
        os.replace(tmp, path)  # atomic publish
        nb = tree_bytes(host)
        with self._lock:
            self._keys[key] = path
            self.bytes_written += nb
            self.live_bytes += nb - self._sizes.get(key, 0)
            self._sizes[key] = nb
            self.peak_bytes = max(self.peak_bytes, self.live_bytes)

    def get(self, key: Any) -> Any:
        with self._lock:
            path = self._keys[key]
        with open(path, "rb") as f:
            host = pickle.load(f)
        with self._lock:
            self.bytes_read += tree_bytes(host)
        return host

    def delete(self, key: Any) -> None:
        with self._lock:
            path = self._keys.pop(key, None)
            self.live_bytes -= self._sizes.pop(key, 0)
        if path and os.path.exists(path):
            os.remove(path)

    def __contains__(self, key: Any) -> bool:
        with self._lock:
            return key in self._keys

    def keys(self) -> Iterable[Any]:
        with self._lock:
            return list(self._keys)


class CompressedStorage:
    """Level-2 wrapper that int8-quantises float leaves before handing the
    tree to an inner backend (host RAM by default, disk when ``directory``
    is given).

    Encoding reuses ``repro.distributed.compression``'s absmax block
    quantisation: each float array >= ``min_bytes`` becomes an int8 payload
    plus one f32 scale (~4x smaller on the wire and in Level 2); integer
    leaves and small arrays are stored raw.  Decoding restores the original
    dtype.  The round-trip error per leaf is bounded by
    ``compression.quantization_error_bound`` — checkpoint states are replay
    *starting points*, so this trades a measured, bounded precision loss for
    4x Level-2 capacity (the same trade DRAM->SSD platforms make with
    filesystem compression).
    """

    def __init__(self, inner: Any = None, directory: Optional[str] = None,
                 min_bytes: int = 256):
        if inner is None:
            inner = DiskStorage(directory) if directory else RAMStorage()
        self.inner = inner
        self.min_bytes = min_bytes
        # _lock guards every mutable field of *this* wrapper (the inner
        # backend has its own lock): put runs on the AsyncTransferEngine
        # writer thread while callers read counters — the same backend-lock
        # pattern RAMStorage uses.
        self._lock = threading.Lock()
        self._raw_bytes = 0         # pre-compression payload, for ratio tests
        self._treedefs: Dict[Any, Any] = {}   # key -> original structure

    # -- per-leaf codec -------------------------------------------------------
    # A quantised leaf is the tuple (q_int8, scale_f32, dtype_exemplar);
    # everything else (ints, bools, small floats) is stored raw.  Flattened
    # leaves are always arrays, so the tuple tag is unambiguous.
    def _encode_leaf(self, x: Any) -> Any:
        # numpy twin of the wire codec: background threads must stay off
        # the accelerator stream they are overlapping with
        from repro.distributed.compression import quantize_np

        arr = np.asarray(x)
        if arr.dtype.kind == "f" and arr.nbytes >= self.min_bytes:
            q, scale = quantize_np(arr)
            return (q, scale, np.zeros((), arr.dtype))
        return arr

    @staticmethod
    def _decode_leaf(enc: Any) -> np.ndarray:
        from repro.distributed.compression import dequantize_np

        if not isinstance(enc, tuple):
            return enc
        q, scale, exemplar = enc
        return np.asarray(dequantize_np(q, scale), dtype=exemplar.dtype)

    # -- backend protocol -----------------------------------------------------
    def put(self, key: Any, tree: Any) -> None:
        # No _to_host here: _encode_leaf materialises each leaf to host via
        # np.asarray, and the inner backend's own put deep-copies the
        # (already ~4x smaller) encoded payload — a full-size extra copy on
        # the writer thread would just inflate T_T.
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        nb = tree_bytes(leaves)
        with self._lock:
            self._raw_bytes += nb
            self._treedefs[key] = treedef
        payload = [self._encode_leaf(x) for x in leaves]
        # the pickled treedef rides along as a tiny uint8 leaf, so a fresh
        # process re-hydrating from a journaled inner store can unflatten
        # without this instance's in-memory treedef map
        payload.append(np.frombuffer(
            pickle.dumps(treedef, protocol=pickle.HIGHEST_PROTOCOL),
            dtype=np.uint8))
        self.inner.put(key, payload)

    def get(self, key: Any) -> Any:
        encs = self.inner.get(key)
        encs, td_arr = encs[:-1], encs[-1]
        with self._lock:
            treedef = self._treedefs.get(key)
        if treedef is None:  # crash recovery: decode the journaled treedef
            treedef = pickle.loads(np.asarray(td_arr).tobytes())
            with self._lock:
                self._treedefs[key] = treedef
        return jax.tree_util.tree_unflatten(
            treedef, [self._decode_leaf(x) for x in encs])

    def delete(self, key: Any) -> None:
        self.inner.delete(key)
        with self._lock:
            self._treedefs.pop(key, None)

    def __contains__(self, key: Any) -> bool:
        return key in self.inner

    def keys(self) -> Iterable[Any]:
        return self.inner.keys()

    @property
    def raw_bytes(self) -> int:
        """Pre-compression payload bytes (locked read: the writer thread
        updates it concurrently with callers polling the ratio)."""
        with self._lock:
            return self._raw_bytes

    @property
    def bytes_written(self) -> int:  # compressed (on-the-wire) accounting
        return self.inner.bytes_written

    @property
    def bytes_read(self) -> int:
        return self.inner.bytes_read

    @property
    def live_bytes(self) -> int:
        return self.inner.live_bytes

    @property
    def peak_bytes(self) -> int:
        return self.inner.peak_bytes

    def __getattr__(self, name: str):
        # Pass unknown verbs through to the inner backend (journal verbs
        # for a hand-built CompressedStorage(inner=JournaledStorage(...))
        # composition, instrumentation attributes otherwise).
        inner = self.__dict__.get("inner")
        if inner is None:
            raise AttributeError(name)
        return getattr(inner, name)


class TieredStorage:
    """Capacity-bounded two-tier Level-2 store: fast tier (host RAM,
    ``capacity_bytes``) + slow tier (disk when ``directory`` is given,
    otherwise a RAM stand-in; ``compress=True`` int8-quantises the slow
    copies).

    This is the paper's "memory can be reduced to *any* size" made literal:
    ``put`` lands in the fast tier and, when the budget would overflow,
    write-behind evicts the *coldest* resident to the slow tier.  Cold is
    plan-aware — :meth:`set_plan` records the ``SegmentPlan``'s reverse
    access sequence (boundaries are consumed in descending ``begin`` order),
    so the victim is always the key whose next use is farthest away
    (Belady's rule: the smallest begin).  Keys outside the plan (autotune
    probes) evict first; with no plan, eviction is FIFO — identical to the
    plan rule for the forward sweep's ascending stores.

    ``get`` serves fast-tier hits by reference (frozen read-only arrays) and
    *promotes* slow-tier hits back into the fast tier (demand promotion;
    the executor additionally promotes ahead of need via its plan-driven
    prefetch distance, see :meth:`plan_prefetch_distance`).  Promoted
    entries are clean — evicting them again drops the fast copy without a
    second slow-tier write.

    Invariant (asserted in tests and the capacity-sweep benchmark):
    ``fast_live_bytes <= capacity_bytes`` at every instant — a state larger
    than the whole budget bypasses the fast tier entirely.
    """

    def __init__(self, capacity_bytes: int, slow: Any = None,
                 directory: Optional[str] = None, compress: bool = False,
                 bandwidth: Optional[float] = None):
        if capacity_bytes <= 0:
            raise ValueError(
                f"need capacity_bytes > 0, got {capacity_bytes}")
        if slow is None:
            slow = DiskStorage(directory) if directory else RAMStorage()
        if compress:
            slow = CompressedStorage(inner=slow)
        self.slow = slow
        self.capacity_bytes = int(capacity_bytes)
        self.bandwidth = bandwidth          # fast-tier throttle (bytes/s)
        self._lock = threading.Lock()
        self._fast: Dict[Any, Any] = {}
        self._sizes: Dict[Any, int] = {}    # sizes of fast-resident entries
        self._clean: set = set()            # fast entries also valid in slow
        # Write-behind pipeline.  _writing holds the latest pending payload
        # per key as (generation, tree); _wb_active is the set of keys some
        # thread is currently draining (per-key drain loops keep slow-tier
        # writes of the same key ordered, so an old eviction can never land
        # after — and overwrite — a newer one); _wb_deleted tombstones keys
        # deleted while a writeback was mid-flight.
        self._writing: Dict[Any, Any] = {}
        self._wb_active: set = set()
        self._wb_deleted: set = set()
        self._seq: Dict[Any, int] = {}      # insertion order (FIFO fallback)
        self._next_seq = 0
        self._distance: Dict[Any, int] = {}  # plan key -> reverse-use distance
        # Multi-tenant fast-tier quotas.  Keys of the form
        # ``(namespace, inner_key)`` whose namespace was registered via
        # :meth:`register_namespace` are charged to that namespace's tenant;
        # a tenant over its quota evicts ITS OWN coldest residents (never
        # another tenant's), so one tenant's burst cannot push a
        # well-behaved neighbour's boundaries to the slow tier.
        self._quota: Dict[Any, int] = {}        # tenant -> fast byte quota
        self._ns_tenant: Dict[Any, Any] = {}    # namespace -> tenant
        self._ns_cap: Dict[Any, int] = {}       # namespace -> fast byte cap
        self.tenant_fast_bytes: Dict[Any, int] = {}
        self.tenant_fast_peak: Dict[Any, int] = {}
        self.ns_fast_bytes: Dict[Any, int] = {}
        self.ns_fast_peak: Dict[Any, int] = {}
        # -- instrumentation ---------------------------------------------------
        self.fast_live_bytes = 0
        self.fast_peak_bytes = 0   # high-water fast tier: must obey capacity
        self.evictions = 0         # fast -> slow write-behind spills
        self.promotions = 0        # slow -> fast demand/prefetch promotions
        self.fast_hits = 0
        self.slow_hits = 0
        self.bytes_written = 0     # total put payload (fast + direct-to-slow)
        self.bytes_read = 0
        self.untracked_keys = 0    # resident keys the last set_plan() missed
        self._peak_total = 0

    def _throttle(self, nbytes: int) -> None:
        if self.bandwidth:
            time.sleep(nbytes / self.bandwidth)

    # -- plan awareness -------------------------------------------------------
    def set_plan(self, plan: Any) -> None:
        """Record the future access order of an offload plan:
        ``distance[key]`` = how many accesses until ``key`` is needed
        (0 = needed first).  The eviction victim maximises this distance.

        Accepts two plan shapes (duck-typed — *migration note*: the
        parameter used to be a ``SegmentPlan`` only):

        * anything exposing ``distances() -> {key: rank}`` — the generic
          ``ResourceAccessPlan`` IR (``repro.core.schedule``), which lets
          boundary states and expert parameter blobs share one Belady
          order (build joint orders with ``merge_access_plans``);
        * a legacy ``SegmentPlan`` via ``reverse_access_order()``.

        Resident keys (fast tier, pending writebacks, or slow tier) that
        the new plan does *not* mention keep working but degrade to the
        documented LRU/FIFO fallback — they rank above every plan key and
        evict first, oldest insertion first (see :meth:`_evict_rank`).
        Each call counts them into the ``untracked_keys`` stat so silently
        demoted keys are observable instead of invisible."""
        dist_fn = getattr(plan, "distances", None)
        if dist_fn is not None:
            dist = dict(dist_fn())
        else:
            dist = {key: d
                    for d, key in enumerate(plan.reverse_access_order())}
        with self._lock:
            self._distance = dist
            held = set(self._fast) | set(self._writing)
        held |= set(self.slow.keys())
        with self._lock:
            self.untracked_keys += sum(1 for k in held if k not in dist)

    def plan_prefetch_distance(self, plan: Any) -> int:
        """How many segments ahead of need the reverse sweep should promote
        boundaries (the executor's prefetch depth).  The policy lives in
        ``SegmentPlan.tier_plan`` — this method only supplies the observed
        boundary-state size; when nothing is resident yet (or every state
        bypassed the fast tier), it assumes spill."""
        if not hasattr(plan, "boundaries"):
            # Generic ResourceAccessPlan IR: no segment structure to hand to
            # tier_plan, so derive depth from its own residency accounting —
            # everything resident means no spill (distance 1), else look two
            # accesses ahead.
            resident, spilled, _ = plan.tier_residency(self.capacity_bytes)
            n_keys = len(plan.keys())
            return 1 if spilled == 0 else min(max(n_keys, 1), 2)
        m = len(plan.boundaries())
        with self._lock:
            sizes = [self._sizes.get(k) for k in plan.boundaries()]
            state = max((s for s in sizes if s is not None), default=0)
        if state == 0:   # no resident boundary to size from: assume spill
            return min(m, 2) if m else 1
        return plan.tier_plan(self.capacity_bytes,
                              state).prefetch_distance

    # -- multi-tenant quotas --------------------------------------------------
    def set_quota(self, tenant: Any, max_fast_bytes: int) -> None:
        """Cap ``tenant``'s fast-tier residency at ``max_fast_bytes``.

        The quota bounds *fast-tier* bytes only (the point of the two-tier
        design: the slow tier absorbs any amount); a single state larger
        than the quota bypasses the fast tier entirely, exactly like the
        global-capacity bypass, so ``tenant_fast_bytes[t] <= quota[t]``
        holds at every instant."""
        if max_fast_bytes <= 0:
            raise ValueError(
                f"need max_fast_bytes > 0, got {max_fast_bytes}")
        with self._lock:
            self._quota[tenant] = int(max_fast_bytes)
            self.tenant_fast_bytes.setdefault(tenant, 0)
            self.tenant_fast_peak.setdefault(tenant, 0)

    def quota_of(self, tenant: Any) -> Optional[int]:
        with self._lock:
            return self._quota.get(tenant)

    def register_namespace(self, namespace: Any, tenant: Any,
                           max_fast_bytes: Optional[int] = None) -> None:
        """Charge keys of the form ``(namespace, *)`` to ``tenant``'s quota
        (namespaces are how :class:`NamespacedStorage` keeps concurrent
        runs' integer keys from colliding on the shared tier).

        ``max_fast_bytes`` additionally caps THIS namespace's fast-tier
        residency — the serving scheduler registers every admitted request
        with its perfmodel-predicted peak here, which is what makes the
        admission contract (*measured* per-request fast peak never exceeds
        the *predicted* one) structural rather than aspirational: a run
        with spare tenant quota still cannot hold more fast bytes than its
        plan was sized for."""
        with self._lock:
            if tenant not in self._quota:
                raise KeyError(f"unknown tenant {tenant!r}: set_quota first")
            self._ns_tenant[namespace] = tenant
            if max_fast_bytes is not None:
                if max_fast_bytes <= 0:
                    raise ValueError(
                        f"need max_fast_bytes > 0, got {max_fast_bytes}")
                self._ns_cap[namespace] = int(max_fast_bytes)
            self.ns_fast_bytes.setdefault(namespace, 0)
            self.ns_fast_peak.setdefault(namespace, 0)

    def _owner_locked(self, key: Any):
        """(namespace, tenant) charged for ``key`` — (None, None) for
        untenanted keys (single-tenant use is unchanged)."""
        if isinstance(key, tuple) and len(key) >= 2:
            tenant = self._ns_tenant.get(key[0])
            if tenant is not None:
                return key[0], tenant
        return None, None

    def _account_fast_add_locked(self, key: Any, nb: int) -> None:
        ns, t = self._owner_locked(key)
        if t is None:
            return
        self.tenant_fast_bytes[t] += nb
        self.ns_fast_bytes[ns] += nb

    def _note_fast_peaks_locked(self) -> None:
        # Peaks observe the post-eviction steady state, exactly like the
        # global capacity invariant: a put that is transiently over quota
        # inside the lock (insert, then _pick_victims_locked spills) is not
        # a peak the outside world can ever read — so the admission
        # contract ``measured peak <= predicted peak`` stays honest.
        self.fast_peak_bytes = max(self.fast_peak_bytes,
                                   self.fast_live_bytes)
        for t, b in self.tenant_fast_bytes.items():
            self.tenant_fast_peak[t] = max(self.tenant_fast_peak[t], b)
        for ns, b in self.ns_fast_bytes.items():
            self.ns_fast_peak[ns] = max(self.ns_fast_peak[ns], b)

    def _account_fast_drop_locked(self, key: Any, nb: int) -> None:
        ns, t = self._owner_locked(key)
        if t is None:
            return
        self.tenant_fast_bytes[t] -= nb
        self.ns_fast_bytes[ns] -= nb

    def update_plan(self, namespace: Any, distances: Dict[Any, int]) -> None:
        """Merge one namespace's Belady distances into the shared eviction
        order, replacing only that namespace's previous entries.  With
        concurrent runs a bare :meth:`set_plan` would demote every *other*
        run's keys to the evict-first fallback; per-namespace merge keeps
        each run plan-aware.  (Distances from different plans are ranks in
        their own access sequences — comparing them across namespaces is a
        heuristic, but each namespace's *internal* victim order stays
        exactly Belady's.)"""
        def _ours(k):
            return isinstance(k, tuple) and len(k) >= 2 and k[0] == namespace
        with self._lock:
            self._distance = {k: v for k, v in self._distance.items()
                              if not _ours(k)}
            self._distance.update(distances)

    def drop_namespace(self, namespace: Any) -> int:
        """Delete every key in ``namespace`` from BOTH tiers (preemption:
        the journal above this backend retains the payloads, so a resumed
        run re-hydrates from the WAL — this only releases quota/capacity).
        Returns the number of keys dropped."""
        dropped = 0
        for k in list(self.keys()):
            if isinstance(k, tuple) and len(k) >= 2 and k[0] == namespace:
                self.delete(k)
                dropped += 1
        with self._lock:
            self._distance = {
                k: v for k, v in self._distance.items()
                if not (isinstance(k, tuple) and len(k) >= 2
                        and k[0] == namespace)}
        return dropped

    def demote_namespace(self, namespace: Any) -> int:
        """Synchronously push every fast-resident key of ``namespace`` down
        to the slow tier (decode preemption: a parked session must stop
        occupying its tenant's fast-tier quota while it waits).  Payloads
        stay readable — this releases quota, not data.  Returns the number
        of keys demoted."""
        with self._lock:
            mine = [k for k in self._fast
                    if isinstance(k, tuple) and len(k) >= 2
                    and k[0] == namespace]
            to_drain = []
            for k in mine:
                d = self._evict_one_locked(k)
                if d is not None:
                    to_drain.append(d)
        self._write_behind(to_drain)
        return len(mine)

    def _evict_rank(self, key: Any):
        """Sort key for victim selection: largest wins.  Plan keys rank by
        reverse-use distance; unknown keys (not in any future access
        sequence) rank above every plan key, oldest first."""
        d = self._distance.get(key)
        if d is None:
            return (1, -self._seq.get(key, 0))
        return (0, d)

    def _evict_one_locked(self, victim: Any) -> Optional[Any]:
        """Move one fast resident to the write-behind staging map.  Returns
        the key if this thread must start its drain loop, else None."""
        tree = self._fast.pop(victim)
        nb = self._sizes.pop(victim)
        self.fast_live_bytes -= nb
        self._account_fast_drop_locked(victim, nb)
        self._seq.pop(victim, None)
        if victim in self._clean:     # slow copy already valid: drop
            self._clean.discard(victim)
            return None
        self._writing[victim] = tree
        if victim not in self._wb_active:
            self._wb_active.add(victim)
            return victim
        return None

    def _pick_victims_locked(self) -> list:
        """Pop residents (coldest first) until the budget holds.  Victims
        move to the ``_writing`` staging map — still readable, no longer
        counted against the fast tier.  Returns the keys whose drain loop
        this thread must run (a key already being drained keeps its drainer;
        only the pending payload is replaced, preserving per-key order)."""
        to_drain = []
        while self.fast_live_bytes > self.capacity_bytes and self._fast:
            victim = max(self._fast, key=self._evict_rank)
            d = self._evict_one_locked(victim)
            if d is not None:
                to_drain.append(d)
        # Per-tenant quotas: an over-quota tenant spills its own coldest
        # residents; other tenants' fast entries are untouchable.
        for tenant, quota in self._quota.items():
            while self.tenant_fast_bytes.get(tenant, 0) > quota:
                mine = [k for k in self._fast
                        if self._owner_locked(k)[1] == tenant]
                if not mine:
                    break
                victim = max(mine, key=self._evict_rank)
                d = self._evict_one_locked(victim)
                if d is not None:
                    to_drain.append(d)
        # Per-namespace caps (the admission contract): a request over its
        # own predicted fast peak spills its own coldest residents.
        for ns, cap in self._ns_cap.items():
            while self.ns_fast_bytes.get(ns, 0) > cap:
                mine = [k for k in self._fast
                        if self._owner_locked(k)[0] == ns]
                if not mine:
                    break
                victim = max(mine, key=self._evict_rank)
                d = self._evict_one_locked(victim)
                if d is not None:
                    to_drain.append(d)
        return to_drain

    def _write_behind(self, keys: list) -> None:
        """Drain each key's pending write-behind payload(s).  One drainer
        per key at a time (``_wb_active``): a re-eviction of the same key
        while its writeback is mid-flight just replaces the pending payload,
        and this loop writes it afterwards — slow-tier writes of a key are
        therefore ordered, so a stale payload can never land on top of a
        newer one."""
        for key in keys:
            while True:
                with self._lock:
                    tree = self._writing.get(key)   # peek: stays readable
                    deleted = False
                    if tree is None:
                        deleted = key in self._wb_deleted
                        self._wb_deleted.discard(key)
                        if not deleted:         # drained: retire this drainer
                            self._wb_active.discard(key)
                            self._note_total_peak_locked()
                            break
                if tree is None:
                    # deleted while a writeback was mid-flight: remove the
                    # slow copy *while still registered as the drainer* — a
                    # concurrent re-store + re-eviction queues its payload
                    # behind us and the next iteration writes it after this
                    # delete, never the other way round
                    self.slow.delete(key)
                    continue
                self.slow.put(key, tree)
                with self._lock:
                    self.evictions += 1
                    if self._writing.get(key) is tree:   # not replaced/deleted
                        self._writing.pop(key)

    def _note_total_peak_locked(self) -> None:
        # nested acquisition fast-lock -> slow-lock is safe: the slow
        # backend never calls back into this wrapper
        total = (self.fast_live_bytes
                 + sum(tree_bytes(t) for t in self._writing.values())
                 + self.slow.live_bytes)
        self._peak_total = max(getattr(self, "_peak_total", 0), total)

    # -- backend protocol -----------------------------------------------------
    def put(self, key: Any, tree: Any) -> None:
        host = _freeze(tree)
        nb = tree_bytes(host)
        self._throttle(nb)
        with self._lock:
            ns, tenant = self._owner_locked(key)
            quota = self._quota.get(tenant) if tenant is not None else None
            ns_cap = self._ns_cap.get(ns) if ns is not None else None
        if nb > self.capacity_bytes or (quota is not None and nb > quota) \
                or (ns_cap is not None and nb > ns_cap):
            # One state alone overflows the budget (global capacity, its
            # tenant's quota, or its namespace's admission cap): bypass the
            # fast tier (the capacity invariant holds unconditionally).
            with self._lock:
                self.bytes_written += nb
                self._drop_fast_locked(key)
                self._wb_deleted.discard(key)   # re-store revokes a tombstone
                if key in self._wb_active:
                    # an older writeback of this key is mid-flight: queue
                    # the new value behind it (per-key order) instead of
                    # racing it to the slow tier
                    self._writing[key] = host
                    self._note_total_peak_locked()
                    return
            self.slow.put(key, host)
            with self._lock:
                self._note_total_peak_locked()
            return
        with self._lock:
            self.bytes_written += nb
            self._drop_fast_locked(key)
            self._wb_deleted.discard(key)   # re-store revokes the tombstone
            self._fast[key] = host
            self._sizes[key] = nb
            self.fast_live_bytes += nb
            self._account_fast_add_locked(key, nb)
            self._seq[key] = self._next_seq
            self._next_seq += 1
            to_drain = self._pick_victims_locked()
            self._note_fast_peaks_locked()
            self._note_total_peak_locked()
        self._write_behind(to_drain)

    def _drop_fast_locked(self, key: Any) -> None:
        """Remove any fast-resident copy of ``key`` (re-store/overwrite)."""
        if key in self._fast:
            self._fast.pop(key)
            nb = self._sizes.pop(key)
            self.fast_live_bytes -= nb
            self._account_fast_drop_locked(key, nb)
            self._seq.pop(key, None)
        self._clean.discard(key)

    def get(self, key: Any) -> Any:
        with self._lock:
            host = self._fast.get(key)
            if host is None:
                host = self._writing.get(key)
            if host is not None:
                nb = tree_bytes(host)
                self.fast_hits += 1
                self.bytes_read += nb
        if host is not None:
            self._throttle(nb)
            return host
        # slow-tier hit: fetch outside the lock, then promote.  Disk and
        # compressed slow tiers materialise fresh arrays per get (and a RAM
        # one returns already-frozen arrays), so freezing in place costs
        # nothing — no defensive copy on the promotion hot path.
        host = _freeze_in_place(self.slow.get(key))
        nb = tree_bytes(host)
        with self._lock:
            self.slow_hits += 1
            self.bytes_read += nb
            to_drain = []
            ns, tenant = self._owner_locked(key)
            quota = self._quota.get(tenant) if tenant is not None else None
            ns_cap = self._ns_cap.get(ns) if ns is not None else None
            if nb <= self.capacity_bytes and \
                    (quota is None or nb <= quota) and \
                    (ns_cap is None or nb <= ns_cap) and \
                    key not in self._fast:
                self.promotions += 1
                self._fast[key] = host
                self._sizes[key] = nb
                self.fast_live_bytes += nb
                self._account_fast_add_locked(key, nb)
                self._seq[key] = self._next_seq
                self._next_seq += 1
                self._clean.add(key)   # slow copy stays valid
                to_drain = self._pick_victims_locked()
                self._note_fast_peaks_locked()
            self._note_total_peak_locked()
        self._write_behind(to_drain)
        return host

    def peek(self, key: Any) -> Any:
        """Read ``key`` *without* promotion: fast-tier hits come back by
        reference like :meth:`get`, but a slow-tier hit is returned directly
        — it is never copied into the fast tier, so ``peek`` cannot evict
        anything and leaves ``fast_live_bytes`` / ``fast_peak_bytes``
        untouched.  This is the read path for streamed resources whose
        residency is decided at ``put`` time by the plan's Belady order
        (promote-on-read would let the *reader* mutate the fast tier and
        break the exact replay the perfmodel's peak simulator relies on).
        Hit/byte counters are still maintained."""
        with self._lock:
            host = self._fast.get(key)
            if host is None:
                host = self._writing.get(key)
            if host is not None:
                nb = tree_bytes(host)
                self.fast_hits += 1
                self.bytes_read += nb
        if host is not None:
            self._throttle(nb)
            return host
        host = _freeze_in_place(self.slow.get(key))
        with self._lock:
            self.slow_hits += 1
            self.bytes_read += tree_bytes(host)
        return host

    def delete(self, key: Any) -> None:
        with self._lock:
            self._drop_fast_locked(key)
            self._writing.pop(key, None)    # cancel any pending writeback
            if key in self._wb_active:
                # a writeback is mid-flight: tombstone the key so its
                # drainer removes the slow copy the moment it lands
                self._wb_deleted.add(key)
            self._distance.pop(key, None)
        self.slow.delete(key)

    def __contains__(self, key: Any) -> bool:
        with self._lock:
            if key in self._fast or key in self._writing:
                return True
        return key in self.slow

    def keys(self) -> Iterable[Any]:
        with self._lock:
            fast = set(self._fast) | set(self._writing)
        return list(fast | set(self.slow.keys()))

    # -- accounting (backend protocol: live/peak span both tiers) -------------
    @property
    def live_bytes(self) -> int:
        with self._lock:
            writing = sum(tree_bytes(t) for t in self._writing.values())
            fast = self.fast_live_bytes
        return fast + writing + self.slow.live_bytes

    @property
    def peak_bytes(self) -> int:
        # High-water mark of the *total* Level-2 footprint (both tiers;
        # clean fast copies duplicate slow bytes, so this is an upper
        # bound).  The budgeted quantity is fast_peak_bytes.
        with self._lock:
            return max(getattr(self, "_peak_total", 0),
                       self.fast_peak_bytes, self.slow.peak_bytes)


class _NamespacedPlan:
    """Key-translating view of an offload plan: every key the plan names is
    rewritten to ``(namespace, key)`` so the shared tier's Belady order can
    hold several runs' plans at once.  Attribute *presence* mirrors the
    wrapped plan (properties raise ``AttributeError`` when the underlying
    verb is missing) — :meth:`TieredStorage.set_plan` and
    :meth:`TieredStorage.plan_prefetch_distance` duck-type on exactly
    that."""

    def __init__(self, plan: Any, namespace: Any):
        self._plan = plan
        self._ns = namespace

    def _t(self, key: Any):
        return (self._ns, key)

    @property
    def distances(self):
        f = getattr(self._plan, "distances", None)
        if f is None:
            raise AttributeError("distances")
        return lambda: {self._t(k): v for k, v in dict(f()).items()}

    @property
    def reverse_access_order(self):
        f = getattr(self._plan, "reverse_access_order", None)
        if f is None:
            raise AttributeError("reverse_access_order")
        return lambda: [self._t(k) for k in f()]

    @property
    def boundaries(self):
        f = getattr(self._plan, "boundaries", None)
        if f is None:
            raise AttributeError("boundaries")
        return lambda: [self._t(k) for k in f()]

    def __getattr__(self, name: str):
        return getattr(self.__dict__["_plan"], name)


class NamespacedStorage:
    """Key-prefixing view of a shared backend: every key becomes
    ``(namespace, key)`` on the inner store.

    This is what lets N concurrent offloaded runs share ONE capacity-bounded
    :class:`TieredStorage`: the executor's boundary keys are bare segment
    ints (``seg.begin``) plus ``FINAL_STATE_KEY``, identical across runs —
    namespacing keeps them from colliding, and the namespace doubles as the
    tier's per-tenant quota charging unit (:meth:`TieredStorage.
    register_namespace`).

    Every key-taking verb is translated EXPLICITLY (``__getattr__``
    delegation would silently bypass translation); ``set_plan`` merges into
    the shared Belady order via :meth:`TieredStorage.update_plan` when the
    inner store supports it.  :meth:`close` is deliberately a no-op — run
    disposal must never close the shared tier under its neighbours.
    """

    def __init__(self, inner: Any, namespace: Any):
        self.inner = inner
        self.namespace = namespace

    def _k(self, key: Any):
        return (self.namespace, key)

    # -- backend protocol -----------------------------------------------------
    def put(self, key: Any, tree: Any) -> None:
        self.inner.put(self._k(key), tree)

    def get(self, key: Any) -> Any:
        return self.inner.get(self._k(key))

    def peek(self, key: Any) -> Any:
        f = getattr(self.inner, "peek", None)
        if f is None:
            return self.inner.get(self._k(key))
        return f(self._k(key))

    def delete(self, key: Any) -> None:
        self.inner.delete(self._k(key))

    def __contains__(self, key: Any) -> bool:
        return self._k(key) in self.inner

    def keys(self) -> Iterable[Any]:
        return [k[1] for k in self.inner.keys()
                if isinstance(k, tuple) and len(k) == 2
                and k[0] == self.namespace]

    # -- plan awareness -------------------------------------------------------
    def set_plan(self, plan: Any) -> None:
        wrapped = _NamespacedPlan(plan, self.namespace)
        update = getattr(self.inner, "update_plan", None)
        if update is not None:
            update(self.namespace, wrapped.distances()
                   if hasattr(wrapped, "distances")
                   else {k: d for d, k in
                         enumerate(wrapped.reverse_access_order())})
            return
        self.inner.set_plan(wrapped)

    def plan_prefetch_distance(self, plan: Any) -> int:
        f = getattr(self.inner, "plan_prefetch_distance", None)
        if f is None:
            return 1
        return f(_NamespacedPlan(plan, self.namespace))

    def drop(self) -> int:
        """Release this namespace's keys from both tiers of the shared
        store (preemption / session teardown)."""
        f = getattr(self.inner, "drop_namespace", None)
        if f is not None:
            return f(self.namespace)
        n = 0
        for k in list(self.keys()):
            self.delete(k)
            n += 1
        return n

    def demote(self) -> int:
        """Push this namespace's fast-resident keys down to the slow tier
        (they stay readable; only the quota charge moves)."""
        f = getattr(self.inner, "demote_namespace", None)
        if f is not None:
            return f(self.namespace)
        return 0

    def close(self) -> None:
        """No-op: the shared inner store outlives any one run."""

    # -- instrumentation: this namespace's slice of the shared tier -----------
    @property
    def fast_live_bytes(self) -> int:
        ns = getattr(self.inner, "ns_fast_bytes", None)
        if ns is not None and self.namespace in ns:
            return ns[self.namespace]
        return getattr(self.inner, "fast_live_bytes", 0)

    @property
    def fast_peak_bytes(self) -> int:
        ns = getattr(self.inner, "ns_fast_peak", None)
        if ns is not None and self.namespace in ns:
            return ns[self.namespace]
        return getattr(self.inner, "fast_peak_bytes", 0)

    def __getattr__(self, name: str):
        inner = self.__dict__.get("inner")
        if inner is None:
            raise AttributeError(name)
        return getattr(inner, name)


class JournaledStorage:
    """Crash-consistent wrapper: write-ahead journal over any inner backend.

    Every ``put``/``delete`` appends a CRC'd record to
    ``<directory>/wal.log`` *before* touching the inner backend, whatever
    the inner backend does with the bytes (host RAM evaporates with the
    process; the journal does not).  Durability is **group-commit** at
    segment granularity: bulk records defer their fsync, and the next
    cursor/BEGIN/END append (or :meth:`commit`/:meth:`close`) is the
    commit barrier that lands them — one fsync per segment instead of one
    per record, with the same recovery guarantee: a durable cursor implies
    every store appended before it is durable (shared-fd fsync + WAL
    prefix semantics), so recovery can never claim a non-durable boundary.
    ``get`` serves from the inner backend when it has the key and
    re-hydrates from the journal otherwise (a fresh process after a
    crash), verifying the record CRC on that path.

    One gradient run is an *epoch*: ``begin_run(meta)`` marks the start
    (truncating the file when the previous epoch completed cleanly, so a
    healthy training loop's journal stays one run long), ``put_cursor``
    checkpoints the executor's :class:`~repro.core.schedule.RunCursor` at
    segment granularity, ``end_run`` marks clean completion, and
    ``recover()`` returns a :class:`~repro.core.journal.RecoveredRun`
    (surviving keys, last cursor, per-segment reverse artifacts).

    Damage semantics on open: a torn tail (crash mid-write) is silently
    truncated — that is the artifact journaling exists to absorb; a
    CRC-failing *complete* record is corruption and raises a typed
    :class:`~repro.core.faults.ChecksumError` unless ``repair=True``
    (truncate back to the last good record and recover what precedes it).

    Unknown attributes delegate to the inner backend, so plan-aware verbs
    (``set_plan``, ``plan_prefetch_distance``) and instrumentation
    (``bytes_written``, ``fast_peak_bytes``, ...) pass straight through.
    """

    # The journal must WAL the *global* payload (recovery re-splits it), so
    # the engine's pre-split snapshot hook is disabled through this wrapper:
    # a class-level None stops attribute lookup before __getattr__ can
    # delegate to a sharded inner's ``snapshot``.
    snapshot = None

    def __init__(self, inner: Any, directory: str, *, fsync: bool = True,
                 repair: bool = False, faults: Any = None):
        self.inner = inner
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        self._journal = _journal.JournalFile(
            os.path.join(directory, "wal.log"), fsync=fsync)
        self._faults = faults if faults is not None else _faults.active()
        self._lock = threading.Lock()
        self._index: Dict[Any, int] = {}   # key -> journal record offset
        self._cursor: Any = None
        self._artifacts: Dict[Any, Any] = {}
        self._meta: Dict[str, Any] = {}
        self._torn = False
        self._ended = False
        self._load(repair=repair)

    # ------------------------------------------------------------------- open
    def _load(self, repair: bool) -> None:
        scan = self._journal.scan()
        if scan.damage is not None:
            if scan.damage.kind == "checksum" and not repair:
                raise ChecksumError(
                    f"journal {self._journal.path}: {scan.damage.detail} "
                    "(reopen with repair=True to truncate to the last good "
                    "record and recover what precedes it)")
            # torn tail (normal crash artifact) or explicit repair: discard
            # everything from the damaged record on — framing is lost there
            self._journal.truncate(scan.valid_end)
            self._torn = True
        for rec in _journal.iter_epoch(scan.records):
            if rec.op == _journal.OP_BEGIN:
                self._index.clear()
                self._cursor = None
                self._artifacts.clear()
                self._ended = False
                self._meta = pickle.loads(rec.payload) if rec.payload else {}
            elif rec.op == _journal.OP_STORE:
                self._index[rec.key] = rec.start
            elif rec.op == _journal.OP_DELETE:
                self._index.pop(rec.key, None)
            elif rec.op == _journal.OP_CURSOR:
                self._note_cursor(pickle.loads(rec.payload))
            elif rec.op == _journal.OP_END:
                self._ended = True

    def _note_cursor(self, cursor: Any) -> None:
        self._cursor = cursor
        payload = getattr(cursor, "payload", None)
        if isinstance(payload, dict) and payload.get("artifact") is not None:
            self._artifacts[payload.get("artifact_key")] = payload["artifact"]

    # -------------------------------------------------------------- run verbs
    def begin_run(self, meta: Optional[Dict[str, Any]] = None) -> None:
        """Open a new epoch.  When the previous epoch completed cleanly
        (END seen and nothing left stored) the file is truncated first, so
        repeated training steps do not grow the journal without bound."""
        with self._lock:
            if self._ended and not self._index:
                self._journal.truncate(0)
            self._journal.append(
                _journal.OP_BEGIN,
                payload=pickle.dumps(dict(meta or {}),
                                     protocol=pickle.HIGHEST_PROTOCOL))
            self._index.clear()
            self._cursor = None
            self._artifacts.clear()
            self._meta = dict(meta or {})
            self._ended = False

    def put_cursor(self, cursor: Any, *, sync: bool = True) -> None:
        """Durably checkpoint the executor's plan cursor (FIFO-ordered
        behind the boundary stores when routed through the engine's
        writer queue — a cursor can never claim a segment whose boundary
        is not yet durable).

        ``sync=False`` defers the commit barrier: the record is written
        in order but fsyncs with the *next* barrier (cursor coalescing —
        the engine passes it when a newer cursor is already queued, so a
        burst of cursors costs one sync).  Consistency is unaffected
        (recovery reads a file prefix, and file order is unchanged); only
        the crash window widens from one cursor to the in-flight burst.
        """
        payload = pickle.dumps(cursor, protocol=pickle.HIGHEST_PROTOCOL)
        with self._lock:
            self._journal.append(_journal.OP_CURSOR, payload=payload,
                                 sync=True if sync else False)
            self._note_cursor(cursor)

    def end_run(self) -> None:
        with self._lock:
            self._journal.append(_journal.OP_END)
            self._ended = True
            if not self._index:
                # Compact: a completed epoch's bulk (boundary payloads,
                # per-segment adjoint cursors) is dead weight — rewrite it
                # as a tiny done-marker epoch so the next open (every step
                # in the launcher's standing-resume mode) scans O(bytes of
                # one cursor) instead of re-reading and re-CRC-ing the
                # whole previous sweep's Level-2 traffic.
                self._journal.truncate(0)
                # group commit inside the compaction too: the rewritten
                # epoch only matters as a whole, so its BEGIN/CURSOR defer
                # to the closing END barrier (one sync, not three)
                self._journal.append(
                    _journal.OP_BEGIN,
                    payload=pickle.dumps(dict(self._meta),
                                         protocol=pickle.HIGHEST_PROTOCOL),
                    sync=False)
                if self._cursor is not None:
                    self._journal.append(
                        _journal.OP_CURSOR,
                        payload=pickle.dumps(
                            self._cursor,
                            protocol=pickle.HIGHEST_PROTOCOL),
                        sync=False)
                self._journal.append(_journal.OP_END)

    def recover(self) -> RecoveredRun:
        """The last epoch's durable state (keys in store order, last
        cursor, reverse artifacts).  A cleanly-ended epoch still reports
        its cursor — callers treat ``phase == "done"`` as nothing-to-do."""
        with self._lock:
            return RecoveredRun(keys=tuple(self._index),
                                cursor=self._cursor,
                                artifacts=dict(self._artifacts),
                                meta=dict(self._meta),
                                torn=self._torn,
                                journal_bytes=self._journal.size)

    @property
    def cursor(self) -> Any:
        with self._lock:
            return self._cursor

    @property
    def journal_bytes(self) -> int:
        return self._journal.size

    @property
    def journal_path(self) -> str:
        return self._journal.path

    # -------------------------------------------------------- backend protocol
    def put(self, key: Any, tree: Any) -> None:
        host = jax.tree_util.tree_map(np.asarray, tree)
        key_b = pickle.dumps(key, protocol=pickle.HIGHEST_PROTOCOL)
        payload = pickle.dumps(host, protocol=pickle.HIGHEST_PROTOCOL)
        with self._lock:
            # group commit: the store's fsync is deferred to the segment's
            # cursor barrier (put_cursor / begin_run / end_run / commit) —
            # one fsync per batch, same durability at segment granularity
            start, end = self._journal.append(_journal.OP_STORE, key_b,
                                              payload, sync=False)
            self._index[key] = start
        if self._faults is not None:
            # may tear/corrupt the record just written and/or kill the
            # writing thread (simulated crash mid-spill)
            self._faults.on_journal_store(self._journal, start, end)
        self.inner.put(key, tree)

    def get(self, key: Any) -> Any:
        if key in self.inner:
            return self.inner.get(key)
        # Re-hydrate from the journal (fresh process after a crash), then
        # serve through the inner backend: for a lossy inner (compressed)
        # the put/get round-trip reproduces exactly the decoded values the
        # fault-free run read back, so resumed reverse sweeps stay
        # bit-identical.  The record CRC is re-verified on the journal
        # read -> typed ChecksumError.
        self.inner.put(key, self._read_journal(key))
        return self.inner.get(key)

    def get_exact(self, key: Any) -> Any:
        """The raw journaled payload, bypassing any lossy inner codec.

        The executor's resume path loads its restart state through this:
        the crashed run advanced from the *exact* running state at the
        boundary (lossy encoding only ever applied to what the reverse
        sweep reads back), so a bit-identical forward replay must start
        from the raw journal record, not from a decode(encode(x))
        round-trip."""
        with self._lock:
            off = self._index.get(key)
        if off is not None:
            return self._read_journal(key)
        return self.inner.get(key)   # not journaled (shouldn't happen)

    def _read_journal(self, key: Any) -> Any:
        with self._lock:
            off = self._index.get(key)
        if off is None:
            raise KeyError(key)
        return _freeze_in_place(
            pickle.loads(self._journal.read_payload(off)))

    def delete(self, key: Any) -> None:
        key_b = pickle.dumps(key, protocol=pickle.HIGHEST_PROTOCOL)
        with self._lock:
            # deferred like put: a retired boundary's delete only matters
            # once a later cursor (which fsyncs) has superseded it
            self._journal.append(_journal.OP_DELETE, key_b, sync=False)
            self._index.pop(key, None)
        self.inner.delete(key)

    def commit(self) -> None:
        """Explicit group-commit barrier: fsync any deferred STORE/DELETE
        records now (no-op when nothing is pending).  The run verbs
        (``put_cursor``/``begin_run``/``end_run``) are themselves barriers,
        so the executor never needs this — it exists for callers driving
        the backend directly."""
        self._journal.flush()

    def __contains__(self, key: Any) -> bool:
        if key in self.inner:
            return True
        with self._lock:
            return key in self._index

    def keys(self) -> Iterable[Any]:
        with self._lock:
            journal_keys = set(self._index)
        return list(journal_keys | set(self.inner.keys()))

    def close(self) -> None:
        # land any deferred records before the fd goes away: close is a
        # commit barrier too
        try:
            self._journal.flush()
        finally:
            self._journal.close()

    def __getattr__(self, name: str):
        inner = self.__dict__.get("inner")
        if inner is None:
            raise AttributeError(name)
        return getattr(inner, name)


class _ShardWorker:
    """One persistent writer/reader thread per Level-2 shard stream."""

    def __init__(self, idx: int):
        self.q: "queue.Queue" = queue.Queue()
        self.t = threading.Thread(target=self._loop, daemon=True,
                                  name=f"l2-shard-{idx}")
        self.t.start()

    def _loop(self) -> None:
        while True:
            item = self.q.get()
            if item is None:
                return
            fn, out, ev = item
            try:
                out.append(fn())
            except Exception as e:  # re-raised by the fan-out joiner
                out.append(e)
                out.append(_SHARD_ERR)
            finally:
                ev.set()

    def submit(self, fn):
        ev = threading.Event()
        out: list = []
        self.q.put((fn, out, ev))
        return ev, out

    def stop(self) -> None:
        self.q.put(None)


_SHARD_ERR = object()   # sentinel tagging a worker result as an exception


@dataclasses.dataclass
class _ShardedPayload:
    """A boundary state pre-split into per-stream host shards.

    Produced by :meth:`ShardedStorage.snapshot` on the executor's thread
    (so the device->host copies of the *local* shards happen before the
    payload rides the async writer queue) and consumed by
    :meth:`ShardedStorage.put`, which fans the per-stream trees out to the
    inner backends in parallel.
    """
    streams: list    # per-stream {str(leaf_idx): np.ndarray}
    layout: tuple    # per-leaf ("rep"|"shard", sharding, shape, dtype)
    treedef: Any


class ShardedStorage:
    """Fan-out Level-2 wrapper: one inner backend per mesh device.

    Each device's shard of every boundary state streams to its *own*
    Level-2 stream (inner backend + dedicated worker thread), so transfer
    time scales with the **local** shard bytes, not the global state —
    the mesh-aware refinement of the paper's ``I = ceil(T_T/T_A)`` rule.

    Splitting is sharding-driven: a leaf that is a mesh-sharded
    ``jax.Array`` contributes its ``addressable_shards`` directly (one
    device->host copy per shard, no global gather); a host leaf splits
    along the ``NamedSharding`` recorded via :meth:`set_state_sharding`
    (the journal re-hydration path).  Replicated leaves (and whole trees
    with nothing sharded) go to stream 0 only.  ``get`` fetches every
    stream in parallel and reassembles: committed per-device arrays via
    ``jax.make_array_from_single_device_arrays`` when the recorded
    sharding names real devices (so the reverse sweep's jitted segment
    ops resume SPMD without a broadcast), host concatenation otherwise.

    Composes under :class:`JournaledStorage` (the WAL keeps the global
    payload; re-split happens on the inner put) and over any registered
    inner kind — ``make_backend(kind, shards=N, devices=...)``.
    """

    def __init__(self, inners: Iterable[Any], devices: Optional[list] = None):
        self.inners = list(inners)
        if not self.inners:
            raise ValueError("ShardedStorage needs at least one inner backend")
        self.devices = list(devices) if devices is not None else None
        if self.devices is not None and len(self.devices) != len(self.inners):
            raise ValueError(
                f"{len(self.devices)} devices for {len(self.inners)} shard "
                "streams: need exactly one inner backend per device")
        self._lock = threading.Lock()
        self._layouts: Dict[Any, Any] = {}   # key -> (treedef, layout)
        self._state_sharding_leaves: Optional[list] = None
        self._workers = [_ShardWorker(i) for i in range(len(self.inners))]

    # -- fan-out machinery ----------------------------------------------------
    def _fanout(self, fns) -> list:
        pending = [w.submit(fn) for w, fn in zip(self._workers, fns)]
        results, err = [], None
        for ev, out in pending:
            ev.wait()
            if len(out) == 2 and out[1] is _SHARD_ERR:
                err = err or out[0]
                results.append(None)
            else:
                results.append(out[0])
        if err is not None:
            raise err
        return results

    # -- sharding bookkeeping -------------------------------------------------
    def set_state_sharding(self, shardings: Any) -> None:
        """Record the boundary-state pytree of shardings (one per carry
        leaf) used to split host trees and reassemble fetched shards."""
        # None entries mean "replicated leaf" and must stay leaves (a bare
        # flatten would drop them and misalign the per-leaf zip)
        leaves, _ = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: x is None)
        with self._lock:
            self._state_sharding_leaves = leaves
        for s in leaves:
            if not getattr(s, "is_fully_replicated", True):
                self._adopt_devices(s)
                break

    def _adopt_devices(self, sharding) -> Optional[list]:
        """The stream->device mapping; adopted from the first sharding seen
        when not pinned at construction.  None when no 1:1 mapping exists
        (the caller degrades that leaf to replicated/stream-0)."""
        if self.devices is not None:
            return self.devices
        devs = getattr(sharding, "addressable_devices", None)
        if not devs:
            return None
        devs = sorted(devs, key=lambda d: getattr(d, "id", 0))
        if len(devs) != len(self.inners):
            return None
        self.devices = devs
        return devs

    def _recorded_shardings(self, n_leaves: int) -> list:
        with self._lock:
            rec = self._state_sharding_leaves
        if rec is not None and len(rec) == n_leaves:
            return rec
        return [None] * n_leaves

    def _leaf_split_info(self, leaf, recorded):
        sh = getattr(leaf, "sharding", None)
        if sh is not None and not getattr(sh, "is_fully_replicated", True) \
                and getattr(leaf, "addressable_shards", None):
            return ("jax", sh)
        if recorded is not None and not getattr(
                recorded, "is_fully_replicated", True):
            return ("spec", recorded)
        return ("rep", None)

    # -- split / assemble -----------------------------------------------------
    def _split(self, tree: Any) -> Optional[_ShardedPayload]:
        """Split a pytree into per-stream host trees; None when nothing in
        it is sharded (degenerate single-stream case)."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        recorded = self._recorded_shardings(len(leaves))
        sources, layout, any_shard = [], [], False
        for leaf, rec in zip(leaves, recorded):
            kind, sh = self._leaf_split_info(leaf, rec)
            if kind != "rep" and self._adopt_devices(sh) is None:
                kind, sh = "rep", None   # no stream<->device mapping
            if kind == "jax":
                by_dev = {s.device: s.data for s in leaf.addressable_shards}
                if any(d not in by_dev for d in self.devices):
                    kind, sh = "rep", None   # foreign device set: gather
                else:
                    sources.append(("jax", by_dev))
            if kind == "spec":
                idx_map = sh.addressable_devices_indices_map(
                    tuple(leaf.shape))
                sources.append(("spec", (np.asarray(leaf), idx_map)))
            if kind == "rep":
                sources.append(("rep", leaf))
                layout.append(("rep", None, None, None))
            else:
                any_shard = True
                layout.append(("shard", sh, tuple(leaf.shape),
                               np.dtype(leaf.dtype)))
        if not any_shard:
            return None
        devices = self.devices

        def extract(i: int) -> Dict[str, np.ndarray]:
            dev, out = devices[i], {}
            for li, (kind, src) in enumerate(sources):
                if kind == "jax":
                    out[str(li)] = np.asarray(src[dev])
                elif kind == "spec":
                    host, idx_map = src
                    out[str(li)] = np.ascontiguousarray(host[idx_map[dev]])
                elif i == 0:   # replicated leaves live on stream 0 only
                    out[str(li)] = np.array(src, copy=True)
            return out

        streams = self._fanout(
            [(lambda i=i: extract(i)) for i in range(len(self.inners))])
        return _ShardedPayload(streams=streams, layout=tuple(layout),
                               treedef=treedef)

    def snapshot(self, tree: Any) -> Any:
        """Pre-split host snapshot for ``AsyncTransferEngine.store_async``
        (replaces its ``_to_host``): per-device shard copies happen here,
        on the caller's thread, in parallel across the shard workers."""
        payload = self._split(tree)
        return payload if payload is not None else _to_host(tree)

    def _assemble_leaf(self, sharding, shape, dtype, parts):
        if isinstance(sharding, jax.sharding.Sharding):
            arrays = [jax.device_put(parts[i], d)
                      for i, d in enumerate(self.devices)]
            return jax.make_array_from_single_device_arrays(
                tuple(shape), sharding, arrays)
        # duck-typed sharding (tests without devices): host reassembly
        out = np.empty(tuple(shape), dtype)
        idx_map = sharding.addressable_devices_indices_map(tuple(shape))
        for i, dev in enumerate(self.devices):
            out[idx_map[dev]] = parts[i]
        out.setflags(write=False)
        return out

    # -- backend protocol -----------------------------------------------------
    def put(self, key: Any, tree: Any) -> None:
        payload = tree if isinstance(tree, _ShardedPayload) \
            else self._split(tree)
        if payload is None:
            with self._lock:
                self._layouts.pop(key, None)
            self.inners[0].put(key, tree)
            return
        streams = payload.streams
        self._fanout([(lambda i=i: self.inners[i].put(key, streams[i]))
                      for i in range(len(self.inners))])
        with self._lock:
            self._layouts[key] = (payload.treedef, payload.layout)

    def get(self, key: Any) -> Any:
        with self._lock:
            layout = self._layouts.get(key)
        if layout is None:
            return self.inners[0].get(key)
        treedef, entries = layout
        streams = self._fanout([(lambda i=i: self.inners[i].get(key))
                                for i in range(len(self.inners))])
        leaves = []
        for li, (kind, sh, shape, dtype) in enumerate(entries):
            if kind == "rep":
                leaves.append(streams[0][str(li)])
            else:
                parts = [streams[i][str(li)] for i in range(len(streams))]
                leaves.append(self._assemble_leaf(sh, shape, dtype, parts))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def delete(self, key: Any) -> None:
        with self._lock:
            self._layouts.pop(key, None)
        self._fanout([(lambda i=i: self.inners[i].delete(key))
                      for i in range(len(self.inners))])

    def __contains__(self, key: Any) -> bool:
        with self._lock:
            if key in self._layouts:
                return True
        return any(key in inner for inner in self.inners)

    def keys(self) -> Iterable[Any]:
        out: set = set()
        for inner in self.inners:
            out |= set(inner.keys())
        with self._lock:
            out |= set(self._layouts)
        return list(out)

    # -- plan awareness (forwarded to tiered inners) --------------------------
    def set_plan(self, plan: Any) -> None:
        for inner in self.inners:
            sp = getattr(inner, "set_plan", None)
            if sp is not None:
                sp(plan)

    def plan_prefetch_distance(self, plan: Any) -> int:
        fns = [getattr(i, "plan_prefetch_distance", None)
               for i in self.inners]
        vals = [f(plan) for f in fns if f is not None]
        return max(vals) if vals else 1

    # -- accounting -----------------------------------------------------------
    @property
    def shard_streams(self) -> int:
        return len(self.inners)

    def stream_bytes_written(self) -> list:
        return [int(i.bytes_written) for i in self.inners]

    def stream_bytes_read(self) -> list:
        return [int(i.bytes_read) for i in self.inners]

    @property
    def bytes_written(self) -> int:
        return sum(i.bytes_written for i in self.inners)

    @property
    def bytes_read(self) -> int:
        return sum(i.bytes_read for i in self.inners)

    @property
    def live_bytes(self) -> int:
        return sum(i.live_bytes for i in self.inners)

    @property
    def peak_bytes(self) -> int:
        # sum of per-stream peaks: an upper bound on the simultaneous
        # global high-water mark (streams peak together on this schedule)
        return sum(i.peak_bytes for i in self.inners)

    def close(self) -> None:
        for w in self._workers:
            w.stop()
        for w in self._workers:
            w.t.join(timeout=2.0)
        for inner in self.inners:
            c = getattr(inner, "close", None)
            if c is not None:
                c()


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

_BACKENDS: Dict[str, Callable[..., Any]] = {}


def register_backend(name: str, factory: Callable[..., Any]) -> None:
    """Register a Level-2 backend factory under ``name`` (overwrites)."""
    _BACKENDS[name] = factory


def make_backend(kind: str, *, journal: Optional[str] = None,
                 journal_fsync: bool = True, journal_repair: bool = False,
                 shards: Optional[int] = None, devices: Optional[list] = None,
                 **kwargs: Any) -> Any:
    """Build a Level-2 backend by name.

    Built-ins: ``"ram"`` (``bandwidth=`` optional throttle), ``"disk"``
    (``directory=`` required), ``"compressed"`` (int8-quantised wrapper;
    ``directory=`` switches the inner store from RAM to disk), ``"tiered"``
    (``capacity_bytes=`` required fast-tier budget; ``directory=`` puts the
    slow tier on disk, ``compress=True`` int8-quantises the spilled copies).

    ``journal=<directory>`` composes a :class:`JournaledStorage` over the
    backend: every store/delete is write-ahead-logged (CRC + fsync, see
    ``journal_fsync``) so the run is crash-consistent and resumable.  The
    journal always records the *raw* boundary payloads (a lossy inner
    codec like ``"compressed"`` costs its ~4x saving in the WAL): the
    resume path restarts forward replay from the exact pre-crash state
    (:meth:`JournaledStorage.get_exact`), while re-hydrated reverse-sweep
    reads round-trip through the inner backend so they reproduce exactly
    the (possibly lossy-decoded) values the fault-free run read back.
    ``journal_repair=True`` truncates a CRC-damaged journal back to its
    last good record on open instead of raising
    :class:`~repro.core.faults.ChecksumError`.

    ``shards=N`` wraps N instances of the backend in a
    :class:`ShardedStorage` — one Level-2 stream per mesh device
    (``devices=`` pins the stream->device mapping; disk directories get a
    per-stream ``shard<i>`` suffix and a tiered ``capacity_bytes`` budget
    is divided evenly across streams).  The journal composes *outside*
    the fan-out, so the WAL stays a single global crash-consistency
    domain.
    """
    try:
        factory = _BACKENDS[kind]
    except KeyError:
        raise ValueError(
            f"unknown Level-2 backend {kind!r}; known: "
            f"{sorted(_BACKENDS)}") from None
    if shards is None:
        backend = factory(**kwargs)
    else:
        if shards < 1:
            raise ValueError(f"need shards >= 1, got {shards}")
        inners = []
        for i in range(shards):
            kw = dict(kwargs)
            if kw.get("directory"):
                kw["directory"] = os.path.join(kw["directory"], f"shard{i}")
            if kw.get("capacity_bytes"):
                kw["capacity_bytes"] = max(
                    1, int(kw["capacity_bytes"]) // shards)
            inners.append(factory(**kw))
        backend = ShardedStorage(inners, devices=devices)
    if journal is None:
        return backend
    return JournaledStorage(backend, journal,
                            fsync=journal_fsync, repair=journal_repair)


register_backend("ram", lambda bandwidth=None: RAMStorage(bandwidth))
register_backend("disk", lambda directory: DiskStorage(directory))
register_backend(
    "compressed",
    lambda directory=None, min_bytes=256, inner=None: CompressedStorage(
        inner=inner, directory=directory, min_bytes=min_bytes))
register_backend(
    "tiered",
    lambda capacity_bytes, directory=None, slow=None, compress=False,
    bandwidth=None: TieredStorage(
        capacity_bytes, slow=slow, directory=directory, compress=compress,
        bandwidth=bandwidth))


class AsyncTransferEngine:
    """Async store/prefetch around a Level-2 backend.

    * One writer thread drains a store queue (FIFO, preserves the schedule's
      store order).
    * Prefetches run one thread per outstanding key; results land in a
      staging dict that ``wait_prefetch`` joins on.

    Instruments stall time so experiments can report how often compute waited
    on Level 2 (zero at the paper's operating point I >= ceil(T_T/T_A)).
    Counters (``num_stores``/``num_prefetches``, staged-byte accounting) are
    guarded by the engine lock — callers may issue verbs from any thread.

    ``delete(key)`` invalidates any staged prefetch of ``key`` and detaches
    its in-flight prefetch job, so a delete + re-store + prefetch sequence
    always observes the re-stored value, never a stale staged one.
    """

    def __init__(self, backend, faults: Any = None):
        self.backend = backend
        # fault injection (tests): read once at construction; every hook
        # site below is a single `is not None` test, so the disabled path
        # costs nothing
        self.faults = faults if faults is not None else _faults.active()
        self._store_q: "queue.Queue" = queue.Queue()
        self._prefetched: Dict[Any, Any] = {}
        self._prefetch_events: Dict[Any, threading.Event] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._errors: list = []
        self.store_stall_s = 0.0
        self.prefetch_stall_s = 0.0
        self.num_stores = 0
        self.num_prefetches = 0
        self.staged_bytes = 0       # host RAM held by staged prefetches
        self.staged_peak_bytes = 0  # its high-water mark across the run
        # Parameter prefetch lane (streamed resources, e.g. MoE expert
        # blobs): separate staging so a burst of small param fetches can
        # never invalidate / race the boundary-state prefetch protocol.
        # All lane reads go through ``peek`` when the backend offers it, so
        # fetching a spilled blob never promotes it into the fast tier.
        self._param_staged: Dict[Any, Any] = {}
        self._param_events: Dict[Any, threading.Event] = {}
        self.num_param_prefetches = 0   # prefetch batches issued (per segment)
        self.param_fetch_stalls = 0     # wait_param calls that had to wait
        self.param_bytes_moved = 0      # bytes fetched through the lane
        self.param_stall_s = 0.0
        # When set, boundary prefetches also read via ``peek`` — the
        # executor enables this in param-streaming mode so reads cannot
        # perturb the fast tier's plan-driven residency.
        self.prefetch_via_peek = False
        self._pending_cursors = 0   # queued cursors (for commit coalescing)
        self._writer = threading.Thread(target=self._writer_loop, daemon=True)
        self._writer.start()

    # -- store path -----------------------------------------------------------
    def _writer_loop(self) -> None:
        while not self._stop.is_set():
            try:
                item = self._store_q.get(timeout=0.05)
            except queue.Empty:
                continue
            kind = item[0]
            if kind == "stop":
                # close() wake-up sentinel: exit now instead of sleeping
                # out the remainder of the 50ms poll window (which used to
                # add its residue to every run's shutdown latency)
                self._store_q.task_done()
                return
            try:
                if kind == "put":
                    _, key, tree = item
                    if self.faults is not None:
                        self.faults.on_writer_store(key)
                    self.backend.put(key, tree)
                elif kind == "cursor":
                    cur = item[1]
                    with self._lock:
                        self._pending_cursors -= 1
                        # coalesce: a newer cursor is already queued, so
                        # this one's commit barrier can ride with it (one
                        # sync per burst; file order — hence recovery
                        # consistency — is unchanged)
                        last = self._pending_cursors == 0
                    payload = getattr(cur, "payload", None)
                    if payload:
                        # Host-convert the payload trees here, not on the
                        # caller's thread: np.array on a jax array blocks
                        # until the value is ready and copies it, which
                        # used to serialise every reverse segment with its
                        # cursor checkpoint.  The trees are immutable jax
                        # arrays (fresh per segment), so deferring the
                        # snapshot is safe.  Scalar fields (artifact_key)
                        # stay untouched — they key dict lookups.
                        payload = dict(payload)
                        for f in ("adjoint", "artifact"):
                            if payload.get(f) is not None:
                                payload[f] = _to_host(payload[f])
                        cur = dataclasses.replace(cur, payload=payload)
                    self.backend.put_cursor(cur, sync=last)
                else:  # "delete"
                    self.backend.delete(item[1])
            except WriterKilled:
                # simulated abrupt writer death: leave the item un-done so
                # joins observe exactly what a killed thread leaves behind
                return
            except Exception as e:  # surfaced on wait_stores
                self._errors.append(e)
                self._store_q.task_done()
            else:
                self._store_q.task_done()

    def store_async(self, key: Any, tree: Any) -> None:
        # Snapshot on the caller's thread (cheap) so later in-place mutation
        # of the running state can never corrupt the checkpoint.  A backend
        # that pre-splits per-device shards (ShardedStorage) supplies its
        # own snapshot; JournaledStorage pins ``snapshot = None`` so
        # journaled runs fall back to the global host copy the WAL needs.
        snap = getattr(self.backend, "snapshot", None)
        payload = snap(tree) if snap is not None else _to_host(tree)
        self._store_q.put(("put", key, payload))
        with self._lock:
            self.num_stores += 1

    def cursor_async(self, cursor: Any) -> None:
        """Enqueue a journal cursor checkpoint behind the pending stores.

        FIFO ordering through the writer queue is the consistency
        argument: a durable cursor implies every store enqueued before it
        is durable too, so recovery can trust the cursor's plan position.
        Requires a journaled backend (one with ``put_cursor``).
        """
        with self._lock:
            self._pending_cursors += 1
        self._store_q.put(("cursor", cursor))

    def delete_async(self, key: Any) -> None:
        """Like :meth:`delete`, but the backend delete rides the writer
        queue (FIFO behind any cursor checkpoint that still references the
        key's segment).  Staged/in-flight prefetches of the key are still
        invalidated synchronously."""
        with self._lock:
            self._prefetch_events.pop(key, None)
            dropped = self._prefetched.pop(key, None)
            if dropped is not None:
                self.staged_bytes -= tree_bytes(dropped)
            self._param_events.pop(key, None)
            self._param_staged.pop(key, None)
        self._store_q.put(("delete", key))

    def _raise_pending(self) -> None:
        if self._errors:
            raise self._errors.pop(0)

    def _join_stores(self, timeout: Optional[float] = None) -> bool:
        """Wait until every queued store is done — without deadlocking if the
        writer thread died mid-item (a bare ``Queue.join()`` would hang
        forever on its unfinished-task counter).  Waits on the queue's
        ``all_tasks_done`` condition (woken by ``task_done``, so completion
        is observed immediately), with a short wake-up to notice writer
        death.  Records a RuntimeError in the pending-error list on writer
        death or timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout
        q = self._store_q
        with q.all_tasks_done:
            while q.unfinished_tasks:
                if not self._writer.is_alive():
                    self._errors.append(WriterCrashError(
                        f"Level-2 writer thread died with "
                        f"{q.unfinished_tasks} store(s) outstanding"))
                    return False
                if deadline is not None and time.monotonic() >= deadline:
                    self._errors.append(RuntimeError(
                        f"timed out after {timeout:.1f}s waiting for "
                        f"{q.unfinished_tasks} outstanding Level-2 "
                        "store(s)"))
                    return False
                q.all_tasks_done.wait(timeout=0.05)
        return True

    def wait_stores(self) -> None:
        t0 = time.perf_counter()
        self._join_stores()
        self.store_stall_s += time.perf_counter() - t0
        self._raise_pending()

    # -- prefetch path --------------------------------------------------------
    def _backend_get(self, key: Any) -> Any:
        """All engine-level fetches funnel through here: fault-injection
        hook, plus writer-death diagnosis — a bare ``KeyError`` from a key
        whose store is stuck behind a dead writer thread is re-raised as a
        typed :class:`WriterCrashError` naming the real cause."""
        if self.faults is not None:
            self.faults.on_get(key)   # may raise InjectedFault
        try:
            return self.backend.get(key)
        except StorageFault:
            raise
        except Exception as e:
            if not self._writer.is_alive() and not self._stop.is_set():
                raise WriterCrashError(
                    f"Level-2 writer thread died before {key!r} was "
                    f"readable ({self._store_q.unfinished_tasks} store(s) "
                    "outstanding)") from e
            raise

    def _backend_peek(self, key: Any) -> Any:
        """Like :meth:`_backend_get` but non-promoting: prefers the
        backend's ``peek`` (``TieredStorage``) so the read cannot mutate
        fast-tier residency; plain backends fall back to ``get``, which for
        ram/disk has no promotion side effect anyway."""
        if self.faults is not None:
            self.faults.on_get(key)   # may raise InjectedFault
        fetch = getattr(self.backend, "peek", None) or self.backend.get
        try:
            return fetch(key)
        except StorageFault:
            raise
        except Exception as e:
            if not self._writer.is_alive() and not self._stop.is_set():
                raise WriterCrashError(
                    f"Level-2 writer thread died before {key!r} was "
                    f"readable ({self._store_q.unfinished_tasks} store(s) "
                    "outstanding)") from e
            raise

    def _fetch(self, key: Any) -> Any:
        if self.prefetch_via_peek:
            return self._backend_peek(key)
        return self._backend_get(key)

    def prefetch_async(self, key: Any) -> None:
        with self._lock:
            if key in self._prefetched or key in self._prefetch_events:
                return
            ev = threading.Event()
            self._prefetch_events[key] = ev
            self.num_prefetches += 1

        def _job() -> None:
            # The staged result (and any error) is only published while this
            # job's event is still the registered one for the key: a delete
            # (or delete + re-store + new prefetch) in the meantime detaches
            # this job, so its value can never be observed stale.
            try:
                val = self._fetch(key)
                with self._lock:
                    if self._prefetch_events.get(key) is ev:
                        self._prefetched[key] = val
                        self.staged_bytes += tree_bytes(val)
                        self.staged_peak_bytes = max(self.staged_peak_bytes,
                                                     self.staged_bytes)
            except Exception as e:
                with self._lock:
                    if self._prefetch_events.get(key) is ev:
                        self._errors.append(e)
            finally:
                ev.set()

        threading.Thread(target=_job, daemon=True).start()

    def wait_prefetch(self, key: Any) -> Any:
        with self._lock:
            ev = self._prefetch_events.get(key)
        if ev is None:  # never prefetched: demand-fetch (counts as full stall)
            # Surface any async error first — a failed store means the key
            # may be missing and a bare KeyError would hide the real cause.
            self._raise_pending()
            t0 = time.perf_counter()
            val = self._fetch(key)
            self.prefetch_stall_s += time.perf_counter() - t0
            self._raise_pending()
            return val
        t0 = time.perf_counter()
        ev.wait()
        self.prefetch_stall_s += time.perf_counter() - t0
        self._raise_pending()
        _MISSING = object()
        with self._lock:
            if self._prefetch_events.get(key) is ev:
                self._prefetch_events.pop(key)
            val = self._prefetched.pop(key, _MISSING)
            if val is not _MISSING:
                self.staged_bytes -= tree_bytes(val)
        if val is _MISSING:
            # the staged value was invalidated (delete raced this wait):
            # fall back to a demand fetch of the current backend state
            t0 = time.perf_counter()
            val = self._fetch(key)
            self.prefetch_stall_s += time.perf_counter() - t0
            self._raise_pending()
        return val

    # -- parameter lane -------------------------------------------------------
    def prefetch_params_async(self, keys: Iterable[Any]) -> None:
        """Fetch a batch of resource blobs (one segment's expert params)
        behind the current segment's compute.  One worker thread drains the
        whole batch in order, staging each blob under its own key — a
        ``wait_param`` for the first key can therefore succeed while later
        keys are still in flight.  Keys already staged or in flight are
        skipped (idempotent re-issue)."""
        with self._lock:
            todo = []
            for k in keys:
                if k in self._param_staged or k in self._param_events:
                    continue
                ev = threading.Event()
                self._param_events[k] = ev
                todo.append((k, ev))
            if todo:
                self.num_param_prefetches += 1
        if not todo:
            return

        def _job() -> None:
            for k, ev in todo:
                try:
                    val = self._backend_peek(k)
                    with self._lock:
                        if self._param_events.get(k) is ev:
                            self._param_staged[k] = val
                            self.param_bytes_moved += tree_bytes(val)
                except Exception as e:
                    with self._lock:
                        if self._param_events.get(k) is ev:
                            self._errors.append(e)
                finally:
                    ev.set()

        threading.Thread(target=_job, daemon=True).start()

    def wait_param(self, key: Any) -> Any:
        """Consume one staged resource blob.  A blob still in flight waits
        on its event; a blob never prefetched (or invalidated by a delete)
        demand-peeks the backend — both count as ``param_fetch_stalls``."""
        _MISSING = object()
        with self._lock:
            ev = self._param_events.get(key)
            val = self._param_staged.pop(key, _MISSING)
            if val is not _MISSING:
                self._param_events.pop(key, None)
        if val is not _MISSING:
            return val
        if ev is None:   # never prefetched: demand peek, full stall
            self._raise_pending()
            with self._lock:
                self.param_fetch_stalls += 1
            t0 = time.perf_counter()
            val = self._backend_peek(key)
            self.param_stall_s += time.perf_counter() - t0
            with self._lock:
                self.param_bytes_moved += tree_bytes(val)
            self._raise_pending()
            return val
        stalled = not ev.is_set()
        t0 = time.perf_counter()
        ev.wait()
        self.param_stall_s += time.perf_counter() - t0
        self._raise_pending()
        with self._lock:
            if stalled:
                self.param_fetch_stalls += 1
            if self._param_events.get(key) is ev:
                self._param_events.pop(key)
            val = self._param_staged.pop(key, _MISSING)
        if val is _MISSING:
            # invalidated between set and pop (delete raced this wait)
            t0 = time.perf_counter()
            val = self._backend_peek(key)
            self.param_stall_s += time.perf_counter() - t0
            with self._lock:
                self.param_bytes_moved += tree_bytes(val)
            self._raise_pending()
        return val

    def delete(self, key: Any) -> None:
        """Drop ``key`` from Level 2 *and* invalidate any staged or
        in-flight prefetch of it — a later re-store + prefetch must observe
        the new value, never the stale staging entry.  Both staging lanes
        (boundary and parameter) are invalidated."""
        with self._lock:
            self._prefetch_events.pop(key, None)   # detaches in-flight jobs
            dropped = self._prefetched.pop(key, None)
            if dropped is not None:
                self.staged_bytes -= tree_bytes(dropped)
            self._param_events.pop(key, None)
            self._param_staged.pop(key, None)
        self.backend.delete(key)

    def close(self) -> None:
        """Drain outstanding stores (bounded — never deadlocks on a dead
        writer thread), stop the writer, drop staged prefetches that were
        never waited on (and their events), and re-raise any pending
        transfer error so failures can't vanish silently at shutdown.

        In-flight fetch jobs are joined (bounded) *before* the staging
        dicts are cleared: a job publishes its error only while its event
        is still the registered one for the key, so clearing first would
        detach the job and drop a pending failure on the floor — close()
        during an in-flight demand fetch after writer death used to return
        cleanly instead of raising the typed fault (regression-tested).
        """
        self._join_stores(timeout=10.0)
        self._stop.set()
        # Wake the writer immediately: after the last real item it parks in
        # q.get(timeout=...), and joining without a wake-up pays the
        # remainder of that poll window (~50ms) on every close.
        self._store_q.put(("stop",))
        self._writer.join(timeout=2.0)
        with self._lock:
            events = (list(self._prefetch_events.values())
                      + list(self._param_events.values()))
        for ev in events:
            ev.wait(timeout=2.0)
        with self._lock:
            self._prefetched.clear()
            self._prefetch_events.clear()
            self._param_staged.clear()
            self._param_events.clear()
            self.staged_bytes = 0
        self._raise_pending()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, *exc):
        if exc_type is not None:
            # an exception is already unwinding: close best-effort so a
            # pending transfer error cannot replace the real failure
            try:
                self.close()
            except Exception:
                pass
            return False
        self.close()
        return False
