"""Level-2 storage backends with asynchronous store / prefetch threads.

This is the paper-faithful substrate: background threads move state pytrees
between the compute level (Level 1: this process's arrays) and a Level-2
store (host RAM dict, or files on disk standing in for an SSD).  The threads
release the GIL during I/O and ``np.copy``, so transfers genuinely overlap
with jitted compute — the same mechanism (python threading around numpy
buffers) the paper's pyrevolve implementation uses.

All backends speak the same protocol::

    put(key, pytree)          # blocking store
    get(key)                  # blocking load
    delete(key), __contains__, keys()

``AsyncTransferEngine`` wraps a backend with a writer thread + per-key
prefetch threads and exposes the async verbs the multistage executor needs:
``store_async``, ``wait_stores``, ``prefetch_async``, ``wait_prefetch``.
"""
from __future__ import annotations

import os
import pickle
import queue
import threading
import time
from typing import Any, Dict, Iterable, Optional

import numpy as np

import jax


def _to_host(tree: Any) -> Any:
    """Deep-copy a pytree of arrays to plain numpy (detaches from Level 1)."""
    return jax.tree_util.tree_map(lambda x: np.array(x, copy=True), tree)


def tree_bytes(tree: Any) -> int:
    return sum(
        np.asarray(x).nbytes for x in jax.tree_util.tree_leaves(tree)
    )


class RAMStorage:
    """Level-2 store in host RAM (the KNL MCDRAM->DRAM platform).

    ``bandwidth`` (bytes/s), if set, throttles transfers so the paper's
    T_T-vs-T_A trade-off can be reproduced deterministically on any machine.
    """

    def __init__(self, bandwidth: Optional[float] = None):
        self._data: Dict[Any, Any] = {}
        self._lock = threading.Lock()
        self.bandwidth = bandwidth
        self.bytes_written = 0
        self.bytes_read = 0

    def _throttle(self, nbytes: int) -> None:
        if self.bandwidth:
            time.sleep(nbytes / self.bandwidth)

    def put(self, key: Any, tree: Any) -> None:
        host = _to_host(tree)
        nb = tree_bytes(host)
        self._throttle(nb)
        with self._lock:
            self._data[key] = host
            self.bytes_written += nb

    def get(self, key: Any) -> Any:
        with self._lock:
            host = self._data[key]
        nb = tree_bytes(host)
        self._throttle(nb)
        with self._lock:
            self.bytes_read += nb
        return host

    def delete(self, key: Any) -> None:
        with self._lock:
            self._data.pop(key, None)

    def __contains__(self, key: Any) -> bool:
        with self._lock:
            return key in self._data

    def keys(self) -> Iterable[Any]:
        with self._lock:
            return list(self._data)


class DiskStorage:
    """Level-2 store on disk (the CPU DRAM->SSD platform).  One pickle file
    per checkpoint, written/read by the background threads through the
    filesystem API — exactly the paper's CPU-platform mechanism."""

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self._keys: Dict[Any, str] = {}
        self.bytes_written = 0
        self.bytes_read = 0

    def _path(self, key: Any) -> str:
        return os.path.join(self.directory, f"ckpt_{key}.pkl")

    def put(self, key: Any, tree: Any) -> None:
        host = _to_host(tree)
        path = self._path(key)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            pickle.dump(host, f, protocol=pickle.HIGHEST_PROTOCOL)
        os.replace(tmp, path)  # atomic publish
        with self._lock:
            self._keys[key] = path
            self.bytes_written += tree_bytes(host)

    def get(self, key: Any) -> Any:
        with self._lock:
            path = self._keys[key]
        with open(path, "rb") as f:
            host = pickle.load(f)
        with self._lock:
            self.bytes_read += tree_bytes(host)
        return host

    def delete(self, key: Any) -> None:
        with self._lock:
            path = self._keys.pop(key, None)
        if path and os.path.exists(path):
            os.remove(path)

    def __contains__(self, key: Any) -> bool:
        with self._lock:
            return key in self._keys

    def keys(self) -> Iterable[Any]:
        with self._lock:
            return list(self._keys)


class AsyncTransferEngine:
    """Async store/prefetch around a Level-2 backend.

    * One writer thread drains a store queue (FIFO, preserves the schedule's
      store order).
    * Prefetches run one thread per outstanding key; results land in a
      staging dict that ``wait_prefetch`` joins on.

    Instruments stall time so experiments can report how often compute waited
    on Level 2 (zero at the paper's operating point I >= ceil(T_T/T_A)).
    """

    def __init__(self, backend):
        self.backend = backend
        self._store_q: "queue.Queue" = queue.Queue()
        self._prefetched: Dict[Any, Any] = {}
        self._prefetch_events: Dict[Any, threading.Event] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._errors: list = []
        self.store_stall_s = 0.0
        self.prefetch_stall_s = 0.0
        self.num_stores = 0
        self.num_prefetches = 0
        self._writer = threading.Thread(target=self._writer_loop, daemon=True)
        self._writer.start()

    # -- store path -----------------------------------------------------------
    def _writer_loop(self) -> None:
        while not self._stop.is_set():
            try:
                item = self._store_q.get(timeout=0.05)
            except queue.Empty:
                continue
            key, tree = item
            try:
                self.backend.put(key, tree)
            except Exception as e:  # surfaced on wait_stores
                self._errors.append(e)
            finally:
                self._store_q.task_done()

    def store_async(self, key: Any, tree: Any) -> None:
        # Snapshot on the caller's thread (cheap) so later in-place mutation
        # of the running state can never corrupt the checkpoint.
        self._store_q.put((key, _to_host(tree)))
        self.num_stores += 1

    def wait_stores(self) -> None:
        t0 = time.perf_counter()
        self._store_q.join()
        self.store_stall_s += time.perf_counter() - t0
        if self._errors:
            raise self._errors[0]

    # -- prefetch path --------------------------------------------------------
    def prefetch_async(self, key: Any) -> None:
        with self._lock:
            if key in self._prefetched or key in self._prefetch_events:
                return
            ev = threading.Event()
            self._prefetch_events[key] = ev
        self.num_prefetches += 1

        def _job() -> None:
            try:
                val = self.backend.get(key)
                with self._lock:
                    self._prefetched[key] = val
            except Exception as e:
                self._errors.append(e)
            finally:
                ev.set()

        threading.Thread(target=_job, daemon=True).start()

    def wait_prefetch(self, key: Any) -> Any:
        with self._lock:
            ev = self._prefetch_events.get(key)
        if ev is None:  # never prefetched: demand-fetch (counts as full stall)
            t0 = time.perf_counter()
            val = self.backend.get(key)
            self.prefetch_stall_s += time.perf_counter() - t0
            return val
        t0 = time.perf_counter()
        ev.wait()
        self.prefetch_stall_s += time.perf_counter() - t0
        if self._errors:
            raise self._errors[0]
        with self._lock:
            self._prefetch_events.pop(key, None)
            return self._prefetched.pop(key)

    def delete(self, key: Any) -> None:
        self.backend.delete(key)

    def close(self) -> None:
        self._store_q.join()
        self._stop.set()
        self._writer.join(timeout=2.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
