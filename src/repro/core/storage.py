"""Level-2 storage backends with asynchronous store / prefetch threads.

This is the paper-faithful substrate: background threads move state pytrees
between the compute level (Level 1: this process's arrays) and a Level-2
store (host RAM dict, or files on disk standing in for an SSD).  The threads
release the GIL during I/O and ``np.copy``, so transfers genuinely overlap
with jitted compute — the same mechanism (python threading around numpy
buffers) the paper's pyrevolve implementation uses.

All backends speak the same protocol::

    put(key, pytree)          # blocking store
    get(key)                  # blocking load
    delete(key), __contains__, keys()

Backends are pluggable through a registry: ``make_backend("ram" | "disk" |
"compressed", ...)`` builds one by name (``register_backend`` adds new
kinds), and ``CompressedStorage`` wraps any inner backend with int8
block-quantisation of the host copy (reusing
``repro.distributed.compression``), shrinking Level-2 footprint ~4x at a
bounded, measured precision cost.

``AsyncTransferEngine`` wraps a backend with a writer thread + per-key
prefetch threads and exposes the async verbs the multistage executor needs:
``store_async``, ``wait_stores``, ``prefetch_async``, ``wait_prefetch``.
"""
from __future__ import annotations

import os
import pickle
import queue
import threading
import time
from typing import Any, Callable, Dict, Iterable, Optional

import numpy as np

import jax


def _to_host(tree: Any) -> Any:
    """Deep-copy a pytree of arrays to plain numpy (detaches from Level 1)."""
    return jax.tree_util.tree_map(lambda x: np.array(x, copy=True), tree)


def tree_bytes(tree: Any) -> int:
    return sum(
        np.asarray(x).nbytes for x in jax.tree_util.tree_leaves(tree)
    )


class RAMStorage:
    """Level-2 store in host RAM (the KNL MCDRAM->DRAM platform).

    ``bandwidth`` (bytes/s), if set, throttles transfers so the paper's
    T_T-vs-T_A trade-off can be reproduced deterministically on any machine.
    """

    def __init__(self, bandwidth: Optional[float] = None):
        self._data: Dict[Any, Any] = {}
        self._sizes: Dict[Any, int] = {}
        self._lock = threading.Lock()
        self.bandwidth = bandwidth
        self.bytes_written = 0
        self.bytes_read = 0
        self.live_bytes = 0
        self.peak_bytes = 0   # high-water Level-2 footprint across the run

    def _throttle(self, nbytes: int) -> None:
        if self.bandwidth:
            time.sleep(nbytes / self.bandwidth)

    def put(self, key: Any, tree: Any) -> None:
        host = _to_host(tree)
        nb = tree_bytes(host)
        self._throttle(nb)
        with self._lock:
            self._data[key] = host
            self.bytes_written += nb
            self.live_bytes += nb - self._sizes.get(key, 0)
            self._sizes[key] = nb
            self.peak_bytes = max(self.peak_bytes, self.live_bytes)

    def get(self, key: Any) -> Any:
        with self._lock:
            host = self._data[key]
        nb = tree_bytes(host)
        self._throttle(nb)
        with self._lock:
            self.bytes_read += nb
        return host

    def delete(self, key: Any) -> None:
        with self._lock:
            self._data.pop(key, None)
            self.live_bytes -= self._sizes.pop(key, 0)

    def __contains__(self, key: Any) -> bool:
        with self._lock:
            return key in self._data

    def keys(self) -> Iterable[Any]:
        with self._lock:
            return list(self._data)


class DiskStorage:
    """Level-2 store on disk (the CPU DRAM->SSD platform).  One pickle file
    per checkpoint, written/read by the background threads through the
    filesystem API — exactly the paper's CPU-platform mechanism."""

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self._keys: Dict[Any, str] = {}
        self._sizes: Dict[Any, int] = {}
        self.bytes_written = 0
        self.bytes_read = 0
        self.live_bytes = 0
        self.peak_bytes = 0   # high-water Level-2 footprint across the run

    def _path(self, key: Any) -> str:
        return os.path.join(self.directory, f"ckpt_{key}.pkl")

    def put(self, key: Any, tree: Any) -> None:
        host = _to_host(tree)
        path = self._path(key)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            pickle.dump(host, f, protocol=pickle.HIGHEST_PROTOCOL)
        os.replace(tmp, path)  # atomic publish
        nb = tree_bytes(host)
        with self._lock:
            self._keys[key] = path
            self.bytes_written += nb
            self.live_bytes += nb - self._sizes.get(key, 0)
            self._sizes[key] = nb
            self.peak_bytes = max(self.peak_bytes, self.live_bytes)

    def get(self, key: Any) -> Any:
        with self._lock:
            path = self._keys[key]
        with open(path, "rb") as f:
            host = pickle.load(f)
        with self._lock:
            self.bytes_read += tree_bytes(host)
        return host

    def delete(self, key: Any) -> None:
        with self._lock:
            path = self._keys.pop(key, None)
            self.live_bytes -= self._sizes.pop(key, 0)
        if path and os.path.exists(path):
            os.remove(path)

    def __contains__(self, key: Any) -> bool:
        with self._lock:
            return key in self._keys

    def keys(self) -> Iterable[Any]:
        with self._lock:
            return list(self._keys)


class CompressedStorage:
    """Level-2 wrapper that int8-quantises float leaves before handing the
    tree to an inner backend (host RAM by default, disk when ``directory``
    is given).

    Encoding reuses ``repro.distributed.compression``'s absmax block
    quantisation: each float array >= ``min_bytes`` becomes an int8 payload
    plus one f32 scale (~4x smaller on the wire and in Level 2); integer
    leaves and small arrays are stored raw.  Decoding restores the original
    dtype.  The round-trip error per leaf is bounded by
    ``compression.quantization_error_bound`` — checkpoint states are replay
    *starting points*, so this trades a measured, bounded precision loss for
    4x Level-2 capacity (the same trade DRAM->SSD platforms make with
    filesystem compression).
    """

    def __init__(self, inner: Any = None, directory: Optional[str] = None,
                 min_bytes: int = 256):
        if inner is None:
            inner = DiskStorage(directory) if directory else RAMStorage()
        self.inner = inner
        self.min_bytes = min_bytes
        self.raw_bytes = 0          # pre-compression payload, for ratio tests
        self._treedefs: Dict[Any, Any] = {}   # key -> original structure
        self._td_lock = threading.Lock()

    # -- per-leaf codec -------------------------------------------------------
    # A quantised leaf is the tuple (q_int8, scale_f32, dtype_exemplar);
    # everything else (ints, bools, small floats) is stored raw.  Flattened
    # leaves are always arrays, so the tuple tag is unambiguous.
    def _encode_leaf(self, x: Any) -> Any:
        # numpy twin of the wire codec: background threads must stay off
        # the accelerator stream they are overlapping with
        from repro.distributed.compression import quantize_np

        arr = np.asarray(x)
        if arr.dtype.kind == "f" and arr.nbytes >= self.min_bytes:
            q, scale = quantize_np(arr)
            return (q, scale, np.zeros((), arr.dtype))
        return arr

    @staticmethod
    def _decode_leaf(enc: Any) -> np.ndarray:
        from repro.distributed.compression import dequantize_np

        if not isinstance(enc, tuple):
            return enc
        q, scale, exemplar = enc
        return np.asarray(dequantize_np(q, scale), dtype=exemplar.dtype)

    # -- backend protocol -----------------------------------------------------
    def put(self, key: Any, tree: Any) -> None:
        # No _to_host here: _encode_leaf materialises each leaf to host via
        # np.asarray, and the inner backend's own put deep-copies the
        # (already ~4x smaller) encoded payload — a full-size extra copy on
        # the writer thread would just inflate T_T.
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        self.raw_bytes += tree_bytes(leaves)
        with self._td_lock:
            self._treedefs[key] = treedef
        self.inner.put(key, [self._encode_leaf(x) for x in leaves])

    def get(self, key: Any) -> Any:
        encs = self.inner.get(key)
        with self._td_lock:
            treedef = self._treedefs[key]
        return jax.tree_util.tree_unflatten(
            treedef, [self._decode_leaf(x) for x in encs])

    def delete(self, key: Any) -> None:
        self.inner.delete(key)
        with self._td_lock:
            self._treedefs.pop(key, None)

    def __contains__(self, key: Any) -> bool:
        return key in self.inner

    def keys(self) -> Iterable[Any]:
        return self.inner.keys()

    @property
    def bytes_written(self) -> int:  # compressed (on-the-wire) accounting
        return self.inner.bytes_written

    @property
    def bytes_read(self) -> int:
        return self.inner.bytes_read

    @property
    def live_bytes(self) -> int:
        return self.inner.live_bytes

    @property
    def peak_bytes(self) -> int:
        return self.inner.peak_bytes


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

_BACKENDS: Dict[str, Callable[..., Any]] = {}


def register_backend(name: str, factory: Callable[..., Any]) -> None:
    """Register a Level-2 backend factory under ``name`` (overwrites)."""
    _BACKENDS[name] = factory


def make_backend(kind: str, **kwargs: Any) -> Any:
    """Build a Level-2 backend by name.

    Built-ins: ``"ram"`` (``bandwidth=`` optional throttle), ``"disk"``
    (``directory=`` required), ``"compressed"`` (int8-quantised wrapper;
    ``directory=`` switches the inner store from RAM to disk).
    """
    try:
        factory = _BACKENDS[kind]
    except KeyError:
        raise ValueError(
            f"unknown Level-2 backend {kind!r}; known: "
            f"{sorted(_BACKENDS)}") from None
    return factory(**kwargs)


register_backend("ram", lambda bandwidth=None: RAMStorage(bandwidth))
register_backend("disk", lambda directory: DiskStorage(directory))
register_backend(
    "compressed",
    lambda directory=None, min_bytes=256, inner=None: CompressedStorage(
        inner=inner, directory=directory, min_bytes=min_bytes))


class AsyncTransferEngine:
    """Async store/prefetch around a Level-2 backend.

    * One writer thread drains a store queue (FIFO, preserves the schedule's
      store order).
    * Prefetches run one thread per outstanding key; results land in a
      staging dict that ``wait_prefetch`` joins on.

    Instruments stall time so experiments can report how often compute waited
    on Level 2 (zero at the paper's operating point I >= ceil(T_T/T_A)).
    """

    def __init__(self, backend):
        self.backend = backend
        self._store_q: "queue.Queue" = queue.Queue()
        self._prefetched: Dict[Any, Any] = {}
        self._prefetch_events: Dict[Any, threading.Event] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._errors: list = []
        self.store_stall_s = 0.0
        self.prefetch_stall_s = 0.0
        self.num_stores = 0
        self.num_prefetches = 0
        self._writer = threading.Thread(target=self._writer_loop, daemon=True)
        self._writer.start()

    # -- store path -----------------------------------------------------------
    def _writer_loop(self) -> None:
        while not self._stop.is_set():
            try:
                item = self._store_q.get(timeout=0.05)
            except queue.Empty:
                continue
            key, tree = item
            try:
                self.backend.put(key, tree)
            except Exception as e:  # surfaced on wait_stores
                self._errors.append(e)
            finally:
                self._store_q.task_done()

    def store_async(self, key: Any, tree: Any) -> None:
        # Snapshot on the caller's thread (cheap) so later in-place mutation
        # of the running state can never corrupt the checkpoint.
        self._store_q.put((key, _to_host(tree)))
        self.num_stores += 1

    def _raise_pending(self) -> None:
        if self._errors:
            raise self._errors.pop(0)

    def _join_stores(self, timeout: Optional[float] = None) -> bool:
        """Wait until every queued store is done — without deadlocking if the
        writer thread died mid-item (a bare ``Queue.join()`` would hang
        forever on its unfinished-task counter).  Waits on the queue's
        ``all_tasks_done`` condition (woken by ``task_done``, so completion
        is observed immediately), with a short wake-up to notice writer
        death.  Records a RuntimeError in the pending-error list on writer
        death or timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout
        q = self._store_q
        with q.all_tasks_done:
            while q.unfinished_tasks:
                if not self._writer.is_alive():
                    self._errors.append(RuntimeError(
                        f"Level-2 writer thread died with "
                        f"{q.unfinished_tasks} store(s) outstanding"))
                    return False
                if deadline is not None and time.monotonic() >= deadline:
                    self._errors.append(RuntimeError(
                        f"timed out after {timeout:.1f}s waiting for "
                        f"{q.unfinished_tasks} outstanding Level-2 "
                        "store(s)"))
                    return False
                q.all_tasks_done.wait(timeout=0.05)
        return True

    def wait_stores(self) -> None:
        t0 = time.perf_counter()
        self._join_stores()
        self.store_stall_s += time.perf_counter() - t0
        self._raise_pending()

    # -- prefetch path --------------------------------------------------------
    def prefetch_async(self, key: Any) -> None:
        with self._lock:
            if key in self._prefetched or key in self._prefetch_events:
                return
            ev = threading.Event()
            self._prefetch_events[key] = ev
        self.num_prefetches += 1

        def _job() -> None:
            try:
                val = self.backend.get(key)
                with self._lock:
                    self._prefetched[key] = val
            except Exception as e:
                self._errors.append(e)
            finally:
                ev.set()

        threading.Thread(target=_job, daemon=True).start()

    def wait_prefetch(self, key: Any) -> Any:
        with self._lock:
            ev = self._prefetch_events.get(key)
        if ev is None:  # never prefetched: demand-fetch (counts as full stall)
            # Surface any async error first — a failed store means the key
            # may be missing and a bare KeyError would hide the real cause.
            self._raise_pending()
            t0 = time.perf_counter()
            val = self.backend.get(key)
            self.prefetch_stall_s += time.perf_counter() - t0
            self._raise_pending()
            return val
        t0 = time.perf_counter()
        ev.wait()
        self.prefetch_stall_s += time.perf_counter() - t0
        self._raise_pending()
        with self._lock:
            self._prefetch_events.pop(key, None)
            return self._prefetched.pop(key)

    def delete(self, key: Any) -> None:
        self.backend.delete(key)

    def close(self) -> None:
        """Drain outstanding stores (bounded — never deadlocks on a dead
        writer thread), stop the writer, and re-raise any pending transfer
        error so failures can't vanish silently at shutdown."""
        self._join_stores(timeout=10.0)
        self._stop.set()
        self._writer.join(timeout=2.0)
        self._raise_pending()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, *exc):
        if exc_type is not None:
            # an exception is already unwinding: close best-effort so a
            # pending transfer error cannot replace the real failure
            try:
                self.close()
            except Exception:
                pass
            return False
        self.close()
        return False
