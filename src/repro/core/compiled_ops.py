"""Segment-compiled chain operators — the *compile* stage of the
plan -> compile -> execute engine.

The step-granular executor pays one Python dispatch (a jitted call) per chain
step: O(n) host overhead that dwarfs ``T_A`` for any real kernel.  Here each
*segment* of the :class:`~repro.core.schedule.SegmentPlan` becomes one
compiled XLA computation instead:

* ``advance_segment`` — a jitted ``lax.scan`` over the interval (the carry is
  donated on accelerators, so the running state updates in place);
* ``reverse_segment`` — a jitted checkpointed ``jax.vjp`` over the scanned
  segment: it consumes the Level-2 boundary state and the incoming cotangent
  in **one** call and returns the segment-entry cotangent, the accumulated
  parameter gradients and the per-step input cotangents.

Both are compiled **once per (step_fn, segment_length)** — ``jax.jit``'s
cache is keyed by the static segment length plus leaf shapes/dtypes, so an
uneven tail segment costs exactly one extra trace and repeated runs cost
none.  ``advance_traces`` / ``reverse_traces`` count actual retraces (the
counters increment inside the traced Python body, which only runs when XLA
compiles) and are asserted in tests.

Memory inside ``reverse_segment`` tracks the paper's Level-1 budget: when
the segment fits (``length <= s_l1``) the scan's own residuals give store-all
replay; otherwise the segment is split into at most ``s_l1`` chunks each
wrapped in ``jax.checkpoint`` — the single-level compiled analogue of
Revolve inside the interval (see :func:`chunk_length` for the exact
peak-state characterisation and the ``s_l1 < 2`` degenerate case).

``CompiledSegmentRunner`` adapts these ops to the executor's pluggable
segment-runner protocol: one host dispatch per segment, O(n/I) total.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.schedule import InnerPlan, SegmentSpec, chunk_length

__all__ = ["CompiledChainOps", "CompiledSegmentRunner",
           "ParamStreamSegmentRunner", "PallasSegmentRunner", "chunk_length",
           "inner_chunked_body"]

tree_map = jax.tree_util.tree_map


def inner_chunked_body(layer_body, inner: InnerPlan):
    """Build a chain-step body that executes the per-step layer stack in the
    2D plan's inner sub-ranges, each under ``jax.checkpoint``.

    ``layer_body(params, carry, x, batch, j)`` is the
    :class:`~repro.api.chain.ChainSpec` per-layer contract; composing
    ``j = 0 .. n_layers-1`` equals one plain ``body`` application, so the
    returned function is primal-identical to the 1D body — remat only
    changes *when* interiors are computed.  During the segment vjp only the
    ``layer_chunks`` sub-range entry states are saved per step; each chunk
    interior is rematerialised exactly once when the step is backwarded
    (StreamBP-style exact chunking, constant overhead).
    """
    ranges = inner.chunk_ranges()

    def body(params, carry, x, batch):
        for lo, hi in ranges:
            def chunk_fn(p, c, x_, lo=lo, hi=hi):
                for j in range(lo, hi):
                    c = layer_body(p, c, x_, batch, j)
                return c

            carry = jax.checkpoint(chunk_fn, prevent_cse=False)(
                params, carry, x)
        return carry

    return body


class CompiledChainOps:
    """Per-segment compiled advance/reverse for one chain body.

    ``body(params, carry, x, batch) -> carry`` is one chain step (the
    ``repro.api.chain.ChainSpec`` contract).  ``xs_treedef``/``xs_mask`` are
    the flattened structure of the per-step inputs and their per-leaf
    inexact (differentiable) mask — both static, they key the trace.

    The instance is the compile cache: build one per (body, xs-structure)
    and reuse it across runs (``repro.api.frontend`` holds them in an LRU).
    """

    def __init__(self, body, xs_treedef, xs_mask: Tuple[bool, ...],
                 reverse_body=None):
        self.body = body
        # 2D plans reverse through an inner-chunked body
        # (:func:`inner_chunked_body`) — primal-identical to ``body``, so
        # the forward advance keeps the plain (fusion-friendliest) one.
        self.reverse_body = body if reverse_body is None else reverse_body
        rbody = self.reverse_body
        self.xs_treedef = xs_treedef
        self.xs_mask = tuple(xs_mask)
        self.advance_traces = 0
        self.reverse_traces = 0
        # donation is a no-op (with a warning) on CPU; only ask off-CPU
        donate = (1,) if jax.default_backend() != "cpu" else ()
        self.donates_carry = bool(donate)  # callers must not reuse the carry

        def _combine(xd_leaves, xnd_leaves):
            xd_it, xnd_it = iter(xd_leaves), iter(xnd_leaves)
            leaves = [next(xd_it) if m else next(xnd_it)
                      for m in self.xs_mask]
            return jax.tree_util.tree_unflatten(self.xs_treedef, leaves)

        def _advance(params, carry, xs_seg, batch):
            self.advance_traces += 1  # traced-body side effect: 1 per compile

            def step(c, x):
                return body(params, c, x, batch), None

            carry, _ = lax.scan(step, carry, xs_seg)
            return carry

        def _reverse(seg_len, s_l1, params, carry_b, xd, xnd, batch,
                     dcarry, gacc):
            self.reverse_traces += 1
            chunk = chunk_length(seg_len, s_l1)

            def seg(p, c, xd_):
                def step(c_, x):
                    xd_k, xnd_k = x
                    return rbody(p, c_, _combine(xd_k, xnd_k), batch), None

                xs = (tuple(xd_), tuple(xnd))
                if chunk is None or chunk >= seg_len:
                    c, _ = lax.scan(step, c, xs, length=seg_len)
                    return c
                # Checkpoint at chunk granularity: full chunks go through a
                # scanned remat region; a shorter remainder chunk (uneven
                # lengths need no divisor) gets its own remat call.  Saved
                # boundaries <= s_l1 for every segment length.
                num_full, rem = divmod(seg_len, chunk)
                xs_full = tree_map(
                    lambda a: a[:num_full * chunk].reshape(
                        (num_full, chunk) + a.shape[1:]), xs)

                def chunk_body(c_, xs_chunk):
                    c_, _ = lax.scan(step, c_, xs_chunk, length=chunk)
                    return c_, None

                c, _ = lax.scan(
                    jax.checkpoint(chunk_body, prevent_cse=False), c,
                    xs_full, length=num_full)
                if rem:
                    xs_tail = tree_map(lambda a: a[num_full * chunk:], xs)

                    def tail_body(c_, xs_t):
                        c_, _ = lax.scan(step, c_, xs_t, length=rem)
                        return c_

                    c = jax.checkpoint(tail_body, prevent_cse=False)(
                        c, xs_tail)
                return c

            _, vjp = jax.vjp(seg, params, carry_b, list(xd))
            dp, dc, dxd = vjp(dcarry)
            gacc = tree_map(jnp.add, gacc, dp)
            return dc, gacc, dxd

        self._advance = jax.jit(_advance, donate_argnums=donate)
        self._reverse = jax.jit(_reverse, static_argnums=(0, 1))

    # -- public ops -----------------------------------------------------------
    def advance_segment(self, params, carry, xs_seg, batch):
        """carry -> carry over one segment: a single compiled scan call."""
        return self._advance(params, carry, xs_seg, batch)

    def reverse_segment(self, params, carry_b, xs_seg, batch, dcarry, gacc,
                        *, s_l1: int):
        """Reverse one segment from its Level-2 boundary state in one call.

        Returns ``(dcarry_at_begin, gacc + segment param grads,
        dxs_diff_leaves)`` — the cotangents of the segment's inexact
        per-step inputs, stacked along the step axis.
        """
        leaves = jax.tree_util.tree_leaves(xs_seg)
        xd = [l for l, m in zip(leaves, self.xs_mask) if m]
        xnd = [l for l, m in zip(leaves, self.xs_mask) if not m]
        seg_len = int(np.shape(leaves[0])[0])
        return self._reverse(seg_len, int(s_l1), params, carry_b, xd, xnd,
                             batch, dcarry, gacc)


class CompiledSegmentRunner:
    """Executor plug-in that replaces the per-step interpreter with one
    compiled call per segment (O(n/I) host dispatches).

    The adjoint is the front-end's ``(dcarry, param_grad_accum)`` pair; the
    per-step input cotangents land in ``dx_segments`` keyed by segment begin
    (the caller stitches them back together after the sweep).
    """

    def __init__(self, ops: CompiledChainOps, params, xs, batch, *,
                 s_l1: int, inner: "InnerPlan | None" = None):
        self.ops = ops
        self.params = params
        self.xs = xs
        self.batch = batch
        self.s_l1 = s_l1
        self.inner = inner
        self.dx_segments: Dict[int, List[Any]] = {}

    def _slice(self, seg: SegmentSpec):
        return tree_map(lambda leaf: leaf[seg.begin:seg.end], self.xs)

    def advance(self, state, seg: SegmentSpec, stats):
        if self.ops.donates_carry and seg.begin == 0:
            # segment 0's carry is the caller's state0 — donating it would
            # invalidate a buffer the caller may reuse; copy once per run.
            # (Later carries are runner-produced and safe to donate: the
            # engine snapshots each boundary to host before the advance.)
            state = tree_map(lambda x: jnp.array(x, copy=True), state)
        state = self.ops.advance_segment(self.params, state,
                                         self._slice(seg), self.batch)
        stats.advances += seg.length
        stats.host_dispatches += 1
        return state

    def reverse(self, x_b, adjoint, seg: SegmentSpec, slots, stats):
        dcarry, gacc = adjoint
        dc, gacc, dxd = self.ops.reverse_segment(
            self.params, x_b, self._slice(seg), self.batch, dcarry, gacc,
            s_l1=self.s_l1)
        self.dx_segments[seg.begin] = dxd
        # logical advance accounting (the work is hidden inside XLA): the
        # vjp replays the segment once while linearising, and chunked
        # checkpointing rematerialises each chunk interior once more
        # during the backward
        replay = seg.length
        if chunk_length(seg.length, self.s_l1) is not None:
            replay += seg.length
        stats.advances += replay
        stats.backwards += seg.length
        stats.host_dispatches += 1
        if self.inner is not None:
            # inner-axis accounting: each backwarded step remats its whole
            # layer stack once, saving layer_chunks sub-range entry states
            # (the entry state is the same pytree as the carry, measured
            # from the actual boundary arrays in hand)
            from repro.core.storage import tree_bytes
            stats.inner_recomputed_layers += \
                seg.length * self.inner.n_layers
            bnd = self.inner.layer_chunks * tree_bytes(x_b)
            stats.inner_peak_bytes = max(stats.inner_peak_bytes, bnd)
        return dc, gacc

    def collect_dx(self, plan) -> List[Any]:
        """Stitch per-segment input cotangents back into full-chain arrays
        (one stacked array per inexact xs leaf, step axis leading)."""
        begins = [seg.begin for seg in plan.segments]
        if not begins or not self.dx_segments:
            return []
        num_leaves = len(self.dx_segments[begins[0]])
        return [
            jnp.concatenate([self.dx_segments[b][i] for b in begins])
            for i in range(num_leaves)
        ]


class ParamStreamSegmentRunner(CompiledSegmentRunner):
    """Compiled segment runner whose streamed xs leaves come from Level 2.

    ``stream`` is a :class:`~repro.core.executor.ParamStream`; ``xs`` holds
    0-d placeholder leaves at the streamed flat positions (so the treedef —
    which keys the compile cache — is unchanged).  ``_slice`` assembles the
    streamed leaves' segment slices from prefetched expert blobs and slices
    the resident leaves as usual, so the arrays entering
    ``advance_segment``/``reverse_segment`` are numerically identical to the
    non-streaming runner's — gradients stay bit-identical (the jit cache is
    keyed by shapes/dtypes, which the reassembly preserves exactly).
    """

    def __init__(self, ops: CompiledChainOps, params, xs, batch, *,
                 s_l1: int, stream, inner: "InnerPlan | None" = None):
        super().__init__(ops, params, xs, batch, s_l1=s_l1, inner=inner)
        self.stream = stream

    def _slice(self, seg: SegmentSpec):
        leaves, treedef = jax.tree_util.tree_flatten(self.xs)
        streamed = self.stream.leaf_ids
        out = [self.stream.gather(i, seg) if i in streamed
               else leaf[seg.begin:seg.end]
               for i, leaf in enumerate(leaves)]
        return jax.tree_util.tree_unflatten(treedef, out)


class PallasSegmentRunner(CompiledSegmentRunner):
    """The fourth segment runner: fused Pallas kernels with double-buffered
    boundary DMA (``repro.kernels.segment_pallas``).

    Same executor protocol as :class:`CompiledSegmentRunner` (which it
    subclasses, so front-end ``isinstance`` dispatch — artifact collection,
    ``collect_dx`` stitching — applies unchanged), plus
    :meth:`advance_with_store`: the executor-side hook that lets the
    segment-entry boundary come *out of the kernel* (already streamed to the
    boundary buffer by DMA while the first chunk computed) instead of being
    snapshotted host-side before the advance.  Gradients are bit-identical
    to the compiled runner's (asserted in ``tests/test_kernels.py``) because
    both formulate every chunk as the same ``lax.scan``/vjp-of-scan.

    ``interpret=None`` resolves per backend (compiled on TPU, interpreted
    elsewhere — the CPU-test configuration); the front-end gates the runner
    behind :func:`repro.kernels.segment_pallas.runner_supported` so plain
    CPU runs fall back to the compiled runner instead of paying
    interpret-mode kernel cost.  Unlike the compiled advance, the fused
    kernels never donate the carry, so no segment-0 defensive copy is
    needed.
    """

    def __init__(self, ops: CompiledChainOps, params, xs, batch, *,
                 s_l1: int, interpret: "bool | None" = None):
        super().__init__(ops, params, xs, batch, s_l1=s_l1)
        from repro.kernels import segment_pallas as sp
        self._sp = sp
        self.interpret = sp.default_interpret() if interpret is None \
            else bool(interpret)

    def _chunk(self, seg: SegmentSpec) -> int:
        # the reverse MUST chunk exactly like the compiled runner's
        # checkpointed vjp for bitwise gradient parity; the forward shares
        # the layout so one boundary stream serves both
        return chunk_length(seg.length, self.s_l1) or seg.length

    def _advance_fused(self, state, seg: SegmentSpec, stats):
        state, boundaries = self._sp.fused_advance_segment(
            self.ops.body, self.ops.xs_treedef, self.ops.xs_mask,
            self.params, state, self._slice(seg), self.batch,
            chunk=self._chunk(seg), interpret=self.interpret)
        stats.advances += seg.length
        stats.host_dispatches += 1
        stats.fused_segments += 1
        nc = int(jax.tree_util.tree_leaves(boundaries)[0].shape[0])
        stats.fused_boundary_copies += nc
        return state, boundaries

    def advance(self, state, seg: SegmentSpec, stats):
        state, _ = self._advance_fused(state, seg, stats)
        return state

    def advance_with_store(self, state, seg: SegmentSpec, stats):
        """Advance one segment and return ``(new_state, entry_boundary)``.

        ``entry_boundary`` equals the pre-advance carry bit for bit — it is
        the kernel's ``boundary[0]`` DMA stream, so on hardware the Level-2
        copy overlapped the first chunk's compute instead of serialising
        before the segment."""
        state, boundaries = self._advance_fused(state, seg, stats)
        bnd0 = tree_map(lambda leaf: leaf[0], boundaries)
        return state, bnd0

    def reverse(self, x_b, adjoint, seg: SegmentSpec, slots, stats):
        dcarry, gacc = adjoint
        dc, dp, dxd = self._sp.fused_reverse_segment(
            self.ops.body, self.ops.xs_treedef, self.ops.xs_mask,
            self.params, x_b, self._slice(seg), self.batch, dcarry,
            chunk=self._chunk(seg), interpret=self.interpret)
        gacc = tree_map(jnp.add, gacc, dp)
        self.dx_segments[seg.begin] = dxd
        # same logical accounting as the compiled runner: the fused vjp
        # replays the segment once (phase A recompute) and chunked
        # checkpointing rematerialises chunk interiors during the backward
        replay = seg.length
        if chunk_length(seg.length, self.s_l1) is not None:
            replay += seg.length
        stats.advances += replay
        stats.backwards += seg.length
        stats.host_dispatches += 1
        stats.fused_segments += 1
        nc = -(-seg.length // self._chunk(seg))
        stats.fused_boundary_copies += 2 * nc  # spill out + prefetch back in
        return dc, gacc
