"""Host-offload primitives: the TPU-native incarnation of the paper's
Level-1/Level-2 transfer machinery.

On TPU, the asynchronous store/prefetch threads of the paper map onto XLA
async ``copy-start``/``copy-done`` pairs between HBM (``"device"``) and host
RAM (``"pinned_host"``), scheduled by the latency-hiding scheduler to overlap
with MXU compute.  JAX exposes this through

* ``checkpoint_name`` tags on intermediate values, and
* ``save_and_offload_only_these_names`` remat policies,

which together tell XLA *which* residuals of a rematerialised region live on
the host.  This module centralises those knobs.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Sequence

import jax
from jax.ad_checkpoint import checkpoint_name
from jax.sharding import NamedSharding, PartitionSpec

# Residual-name vocabulary (shared with models/ and core/multistage_scan).
BOUNDARY = "ms_boundary"          # segment-boundary carry -> Level 2
INNER_BOUNDARY = "ms_inner"       # nested sub-segment boundary -> Level 1
LAYER_INPUT = "layer_input"       # transformer layer input activation
ATTN_OUT = "attn_out"
MLP_OUT = "mlp_out"
QKV = "qkv_proj"
FFN_PRE = "ffn_pre"

DEVICE = "device"
HOST = "pinned_host"


def tag(x: Any, name: str) -> Any:
    """Tag every leaf of a pytree with a residual name (identity op)."""
    return jax.tree_util.tree_map(lambda v: checkpoint_name(v, name), x)


# ---------------------------------------------------------------------------
# Remat policies
# ---------------------------------------------------------------------------


def offload_policy(offload_names: Sequence[str],
                   save_names: Sequence[str] = ()) -> Any:
    """Save ``save_names`` in HBM, offload ``offload_names`` to pinned host
    memory, recompute everything else."""
    return jax.checkpoint_policies.save_and_offload_only_these_names(
        names_which_can_be_saved=list(save_names),
        names_which_can_be_offloaded=list(offload_names),
        offload_src=DEVICE,
        offload_dst=HOST,
    )


def save_policy(save_names: Sequence[str]) -> Any:
    """Save ``save_names`` in HBM, recompute everything else (single-stage)."""
    return jax.checkpoint_policies.save_only_these_names(*save_names)


def segment_policy(offload: bool, boundary_name: str = BOUNDARY) -> Any:
    """Per-segment remat policy for the trace-native scan engine: the
    segment-boundary carry goes to pinned host memory (the paper's Level-2
    store, compiled) when ``offload``, or stays in HBM (plain segmented
    remat) when the backend cannot lower host placement — see
    :func:`host_offload_supported`."""
    if offload:
        return offload_policy([boundary_name])
    return save_policy([boundary_name])


def _offload_plus(offload_pol, bool_pol):
    """Combine an Offloadable-returning policy with a boolean one —
    ``save_from_both_policies`` rejects mixed return types, and the
    name-based policies return a *truthy* RecomputeType sentinel for
    unmatched primitives, so only an explicit type check composes."""

    def policy(prim, *args, **kwargs):
        r = offload_pol(prim, *args, **kwargs)
        if type(r).__name__ == "RecomputeType":
            return bool_pol(prim, *args, **kwargs)
        return r

    return policy


_POLICIES = {
    # name -> thunk building the policy
    "none": lambda: jax.checkpoint_policies.everything_saveable,
    "full": lambda: jax.checkpoint_policies.nothing_saveable,
    "dots": lambda: jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": lambda: jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
    "save_boundary": lambda: save_policy([BOUNDARY]),
    "offload_boundary": lambda: offload_policy([BOUNDARY]),
    "offload_boundary_save_inner": lambda: offload_policy([BOUNDARY], [INNER_BOUNDARY]),
    "save_layer": lambda: save_policy([LAYER_INPUT]),
    "offload_layer": lambda: offload_policy([LAYER_INPUT]),
    "offload_layer_save_dots": lambda: _offload_plus(
        offload_policy([LAYER_INPUT]),
        jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
    ),
    "offload_layer_save_all_dots": lambda: _offload_plus(
        offload_policy([LAYER_INPUT]),
        jax.checkpoint_policies.dots_saveable,
    ),
    "offload_layer_save_attn": lambda: offload_policy([LAYER_INPUT], [ATTN_OUT]),
}


def make_policy(name: str) -> Any:
    try:
        return _POLICIES[name]()
    except KeyError:
        raise ValueError(
            f"unknown remat policy {name!r}; known: {sorted(_POLICIES)}"
        ) from None


def policy_names() -> Sequence[str]:
    return sorted(_POLICIES)


@functools.lru_cache(maxsize=1)
def host_offload_supported() -> bool:
    """Whether this backend/jaxlib lowers offload remat policies to host
    memory-space transfers.

    TPU (and recent GPU) runtimes do; CPU builds typically reject the
    ``TransferToMemoryKind`` placement or silently keep residuals on device.
    Callers (``repro.api`` strategy selection, platform-dependent tests) use
    this to fall back to the thread-based executor path, which works
    everywhere.
    """
    import jax.numpy as jnp

    def f(x):
        x = checkpoint_name(x, LAYER_INPUT)
        return jnp.sum(jnp.tanh(x) ** 2)

    try:
        pol = make_policy("offload_layer")
        jaxpr = str(jax.make_jaxpr(
            jax.grad(jax.checkpoint(f, policy=pol)))(jnp.ones((2, 2))))
        return "<host>" in jaxpr
    except Exception:
        return False


# ---------------------------------------------------------------------------
# Explicit host placement (serving path: KV-cache paging, optimizer state)
# ---------------------------------------------------------------------------


def host_sharding(mesh: jax.sharding.Mesh,
                  spec: PartitionSpec) -> NamedSharding:
    return NamedSharding(mesh, spec, memory_kind=HOST)


def device_sharding(mesh: jax.sharding.Mesh,
                    spec: PartitionSpec) -> NamedSharding:
    return NamedSharding(mesh, spec, memory_kind=DEVICE)


def to_host(x: Any, mesh: Optional[jax.sharding.Mesh] = None,
            spec: Optional[PartitionSpec] = None) -> Any:
    """Move a pytree to host memory (async under jit via device_put)."""
    if mesh is not None:
        sh = host_sharding(mesh, spec if spec is not None else PartitionSpec())
        return jax.tree_util.tree_map(lambda v: jax.device_put(v, sh), x)
    dev = jax.devices()[0]
    mem = dev.memory(HOST)
    return jax.tree_util.tree_map(lambda v: jax.device_put(v, mem), x)


def to_device(x: Any, mesh: Optional[jax.sharding.Mesh] = None,
              spec: Optional[PartitionSpec] = None) -> Any:
    if mesh is not None:
        sh = device_sharding(mesh, spec if spec is not None else PartitionSpec())
        return jax.tree_util.tree_map(lambda v: jax.device_put(v, sh), x)
    dev = jax.devices()[0]
    mem = dev.memory(DEVICE)
    return jax.tree_util.tree_map(lambda v: jax.device_put(v, mem), x)


# ---------------------------------------------------------------------------
# Per-shard host transfer (the sharded Level-2 streams)
# ---------------------------------------------------------------------------


def local_shards(x: jax.Array) -> dict:
    """device -> host shard for one mesh-sharded array: each addressable
    shard copies out independently (``jax.device_get`` of the per-device
    buffer), so no global gather ever materialises on one host thread."""
    import numpy as np
    return {s.device: np.asarray(s.data) for s in x.addressable_shards}


def assemble_shards(shape, sharding: NamedSharding, parts: dict) -> jax.Array:
    """Inverse of :func:`local_shards`: commit each host shard back to its
    device and reassemble the global array under ``sharding`` — the
    ``NamedSharding`` recorded when the boundary was split."""
    arrays = [jax.device_put(part, dev) for dev, part in parts.items()]
    return jax.make_array_from_single_device_arrays(
        tuple(shape), sharding, arrays)
