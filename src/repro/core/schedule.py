"""Asynchronous multistage checkpointing schedule (the paper's §2).

Two storage levels:

* **Level 1** — fast, small (MCDRAM / HBM / this process's RAM): holds the
  running state plus up to ``s`` snapshots used by Revolve inside an interval.
* **Level 2** — large, slow (DRAM / SSD / host RAM): receives every ``I``-th
  state via an *asynchronous* store during the forward pass, and serves
  asynchronous prefetches during the backward pass.

The schedule below is the action stream the executor interprets.  Stores and
prefetches are explicitly asynchronous: ``STORE_L2`` / ``PREFETCH_L2`` enqueue
a transfer, ``WAIT_STORE`` / ``WAIT_PREFETCH`` join it.  Prefetches are
double-buffered: while interval ``j`` is being reversed, interval ``j-1``'s
checkpoint is already in flight.
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field
from typing import Any, List, Optional, Tuple

from repro.core import revolve as rv
from repro.core.revolve import Action


def chunk_length(seg_len: int, s_l1: int) -> Optional[int]:
    """Chunk size for single-level checkpointed recomputation inside one
    segment: ``ceil(seg_len / s_l1)``, so at most ``s_l1`` chunk boundaries
    are ever saved (a shorter remainder chunk absorbs the leftover steps — no
    divisibility requirement).  ``None`` means no chunking: either the
    segment fits in Level 1 (store-all), or ``s_l1 < 2`` — a single-level
    checkpoint cannot beat store-all with one slot (the one chunk's interior
    rematerialises in full during its backward anyway), so we skip the
    pointless recompute.

    This is the planner's compiled/trace-native projection of the Revolve
    sub-plan: where :func:`segment_plan` attaches a step-granular Revolve
    action stream (exact optimal advance counts, driven by the interpreted
    engine), the XLA engines map the same segment onto ``jax.checkpoint``
    regions of this chunk length.  Peak Level-1 states for a chunked
    reversal are ``num_chunks + chunk`` (boundaries plus one chunk's
    interior during its backward) — the single-level analogue of
    Revolve-inside-the-interval, not its strict ``s`` bound."""
    if seg_len <= s_l1 or s_l1 < 2:
        return None
    return math.ceil(seg_len / s_l1)


# ---------------------------------------------------------------------------
# Inner (per-step) axis — the second dimension of a 2D plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InnerPlan:
    """Inner axis of a 2D plan: how one chain step's own computation is
    chunked during the reverse sweep.

    The outer axis (segments + Revolve) bounds how many *steps'* states are
    live; when a *single step's* activations exceed the budget — deep layer
    stacks per step, or a huge logits/loss head — the step itself must be
    chunked.  ``layer_chunks`` sub-ranges of the per-step layer stack are
    each wrapped in a remat region (only the ``layer_chunks`` sub-range
    entry states are saved; interiors are recomputed once during the step's
    backward, StreamBP-style exact chunking), and the logits/loss head is
    evaluated in ``head_chunks`` sequence chunks so the full logits tensor
    never materialises.

    ``boundaries`` are the chunk *start* layer indices chosen by the
    Gruslys-style DP (:func:`gruslys_split`): strictly increasing, first
    element 0, length ``layer_chunks``.
    """

    n_layers: int
    layer_chunks: int
    head_chunks: int = 1
    boundaries: Tuple[int, ...] = ()

    def __post_init__(self):
        if self.n_layers < 1:
            raise ValueError(f"need n_layers >= 1, got {self.n_layers}")
        if not 1 <= self.layer_chunks <= self.n_layers:
            raise ValueError(
                f"need 1 <= layer_chunks <= n_layers ({self.n_layers}), "
                f"got {self.layer_chunks}")
        if self.head_chunks < 1:
            raise ValueError(f"need head_chunks >= 1, got {self.head_chunks}")
        if not self.boundaries:
            # uniform split by default
            per = self.n_layers / self.layer_chunks
            object.__setattr__(
                self, "boundaries",
                tuple(int(round(i * per)) for i in range(self.layer_chunks)))
        if len(self.boundaries) != self.layer_chunks \
                or self.boundaries[0] != 0 \
                or list(self.boundaries) != sorted(set(self.boundaries)) \
                or self.boundaries[-1] >= self.n_layers:
            raise ValueError(
                f"boundaries must be {self.layer_chunks} strictly increasing "
                f"layer indices starting at 0 and < {self.n_layers}; got "
                f"{self.boundaries}")

    def chunk_ranges(self) -> Tuple[Tuple[int, int], ...]:
        """``(lo, hi)`` half-open layer sub-ranges, in application order."""
        ends = (*self.boundaries[1:], self.n_layers)
        return tuple(zip(self.boundaries, ends))

    @property
    def id_suffix(self) -> str:
        return f":L={self.layer_chunks}:H={self.head_chunks}"


def _minmax_partition(vals: Tuple[float, ...], k: int):
    """Partition ``vals`` into ``k`` contiguous chunks minimising the largest
    chunk sum.  Returns ``(best_max, boundaries)`` with ``boundaries`` the
    chunk start indices.  O(k * n^2) DP — n is a layer count, tiny."""
    n = len(vals)
    prefix = [0.0]
    for v in vals:
        prefix.append(prefix[-1] + float(v))

    def rng(i, j):  # sum of vals[i:j]
        return prefix[j] - prefix[i]

    INF = float("inf")
    # f[j][i]: minimal max-chunk-sum splitting vals[:i] into j chunks
    f = [[INF] * (n + 1) for _ in range(k + 1)]
    cut = [[0] * (n + 1) for _ in range(k + 1)]
    f[0][0] = 0.0
    for j in range(1, k + 1):
        for i in range(j, n + 1):
            for m in range(j - 1, i):
                cand = max(f[j - 1][m], rng(m, i))
                if cand < f[j][i]:
                    f[j][i] = cand
                    cut[j][i] = m
    bounds = []
    i = n
    for j in range(k, 0, -1):
        m = cut[j][i]
        bounds.append(m)
        i = m
    return f[k][n], tuple(reversed(bounds))


def gruslys_split(layer_bytes, budget_bytes: float,
                  state_bytes: float) -> Optional[InnerPlan]:
    """Gruslys-style slot allocation for the inner axis: the smallest number
    of rematted layer sub-ranges whose reverse-time peak fits the budget.

    The peak while one step is backwarded with ``k`` chunks is

        ``k * state_bytes``  (saved sub-range entry states)
        ``+ max chunk activation bytes``  (the chunk being rematerialised),

    so for each candidate ``k`` the DP places boundaries to minimise the
    largest chunk (:func:`_minmax_partition` — the minmax analogue of
    Gruslys et al.'s optimal slot placement, arXiv:1606.03401), and the
    smallest feasible ``k`` wins: recompute cost is one extra forward of the
    step regardless of ``k`` (every chunk interior replays exactly once), so
    fewer chunks means fewer saved states and larger fusion regions at the
    same recompute.  Returns ``None`` when even ``k = n_layers`` does not
    fit — :func:`min_step_budget_bytes` names the smallest budget that would.
    """
    vals = tuple(float(b) for b in layer_bytes)
    n = len(vals)
    if n < 1:
        raise ValueError("need at least one layer cost")
    for k in range(1, n + 1):
        worst, bounds = _minmax_partition(vals, k)
        if k * float(state_bytes) + worst <= float(budget_bytes):
            return InnerPlan(n_layers=n, layer_chunks=k, boundaries=bounds)
    return None


def min_step_budget_bytes(layer_bytes, state_bytes: float) -> float:
    """Smallest per-step budget any inner split can satisfy (used by the
    launcher's infeasibility error)."""
    vals = tuple(float(b) for b in layer_bytes)
    best = float("inf")
    for k in range(1, len(vals) + 1):
        worst, _ = _minmax_partition(vals, k)
        best = min(best, k * float(state_bytes) + worst)
    return best


class MOp(enum.Enum):
    ADVANCE = "advance"          # forward steps [index, end)
    STORE_L2 = "store_l2"        # async: current state (== x_index) -> Level 2
    WAIT_STORES = "wait_stores"  # join all outstanding Level-2 stores
    PREFETCH_L2 = "prefetch_l2"  # async: x_index Level 2 -> Level 1 staging
    WAIT_PREFETCH = "wait_pref"  # join the prefetch of x_index; load into state
    FREE_L2 = "free_l2"          # drop x_index from Level 2
    REVERSE_SEGMENT = "reverse"  # reverse steps [index, end) with x_index in hand


@dataclass(frozen=True)
class MAction:
    op: MOp
    index: int = -1
    end: int = -1

    def __repr__(self) -> str:
        if self.op in (MOp.ADVANCE, MOp.REVERSE_SEGMENT):
            return f"{self.op.name}({self.index}->{self.end})"
        return f"{self.op.name}({self.index})"


# ---------------------------------------------------------------------------
# SegmentPlan IR — the *plan* stage of the plan -> compile -> execute engine
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SegmentSpec:
    """One interval of the chain, with everything needed to run it.

    The forward phase stores ``x_begin`` to Level 2 and advances
    ``[begin, end)``; the reverse phase prefetches ``x_begin`` back and
    reverses the segment.  ``revolve`` is the intra-segment Revolve sub-plan
    (``None`` when the whole segment fits in Level 1, i.e. store-all).
    """

    sid: int                 # segment ordinal, forward order
    begin: int               # first step of the segment (== L2 boundary key)
    end: int                 # exclusive
    revolve: Optional[Tuple[Action, ...]] = None

    @property
    def length(self) -> int:
        return self.end - self.begin

    def __repr__(self) -> str:
        mode = "revolve" if self.revolve is not None else "store-all"
        return f"Segment#{self.sid}[{self.begin}:{self.end}|{mode}]"


@dataclass(frozen=True)
class TierPlan:
    """Plan-derived Level-2 tier annotations for a capacity-bounded
    (tiered) backend: which segment boundaries are expected fast-tier
    resident when their reverse turn comes, and how far ahead of need the
    reverse sweep should promote spilled boundaries.

    Built by :meth:`SegmentPlan.tier_plan`.  ``resident[j]`` refers to
    segment ``j`` in *forward* order; the reverse sweep consumes boundaries
    in descending ``begin`` order, so under the plan-aware (Belady) eviction
    rule the fast tier holds the ``fast_slots`` *largest* begins at the end
    of the forward sweep — exactly the boundaries needed first.
    """

    fast_slots: int               # boundary states the fast tier can hold
    resident: Tuple[bool, ...]    # per segment (forward order): fast at need?
    spilled: int                  # boundaries that must come from the slow tier
    prefetch_distance: int        # segments of lead for promotions (>= 1)

    @property
    def num_segments(self) -> int:
        return len(self.resident)


# ---------------------------------------------------------------------------
# ResourceAccessPlan IR — generic offloadable-resource access traces
# ---------------------------------------------------------------------------
#
# Historically the tiered backend consumed ``SegmentPlan.reverse_access_order``
# directly, hard-coding Level 2 to boundary states.  The IR below generalises
# that contract to *any* resource class with a predictable access schedule: an
# access plan is an ordered trace of ``(resource_key, use_index)`` entries,
# and any producer can emit one — ``SegmentPlan.resource_access_plan`` for
# boundary states, :func:`expert_access_plan` for MoE expert parameter blobs
# (per-expert next-use order derived from routing statistics).  Plans merged
# with :func:`merge_access_plans` put heterogeneous resource classes under one
# capacity budget with a single farthest-next-use (Belady) order.


@dataclass(frozen=True)
class ResourceAccess:
    """One entry of a :class:`ResourceAccessPlan`: resource ``key`` is
    consumed at trace position ``use_index`` (smaller = needed sooner).
    ``size_bytes`` (0 = unknown) feeds heterogeneous-size residency
    accounting (:meth:`ResourceAccessPlan.tier_residency`)."""

    key: Any
    use_index: int
    size_bytes: int = 0


@dataclass(frozen=True)
class ResourceAccessPlan:
    """Typed access trace over Level-2 resources — the generic IR behind
    plan-aware eviction.

    ``use_index`` is the rank of the consuming event (for executor-produced
    plans: the rank of the consuming segment in its phase), so plans from
    different producers interleave correctly under
    :func:`merge_access_plans` (a stable merge: ties keep producer order).
    A key may appear multiple times; eviction ranks use its *first* (i.e.
    soonest) use.
    """

    accesses: Tuple[ResourceAccess, ...]

    @property
    def num_accesses(self) -> int:
        return len(self.accesses)

    def _first_uses(self) -> dict:
        first: dict = {}
        for pos, a in enumerate(self.accesses):
            if a.key not in first:
                first[a.key] = (a.use_index, pos)
        return first

    def keys(self) -> Tuple[Any, ...]:
        """Unique keys, soonest first use first."""
        first = self._first_uses()
        return tuple(sorted(first, key=first.get))

    def distances(self) -> dict:
        """Belady distance map ``{key: rank}`` — 0 is needed first; the
        eviction victim maximises this rank.  This is what a capacity-bounded
        backend's ``set_plan`` consumes."""
        return {k: d for d, k in enumerate(self.keys())}

    def sizes(self) -> dict:
        """``{key: size_bytes}`` from each key's first access entry."""
        first = self._first_uses()
        out: dict = {}
        for a in self.accesses:
            if a.key not in out and a.key in first:
                out[a.key] = int(a.size_bytes)
        return out

    def shift(self, offset: int) -> "ResourceAccessPlan":
        """The same trace displaced ``offset`` use ranks later — how a
        producer whose consumption starts after another's is composed
        (e.g. boundary states, only read in the reverse phase, shifted
        past all forward expert uses)."""
        return ResourceAccessPlan(accesses=tuple(
            ResourceAccess(a.key, a.use_index + int(offset), a.size_bytes)
            for a in self.accesses))

    def tier_residency(self, capacity_bytes: int):
        """Heterogeneous-size Belady residency: admit keys in ascending
        next-use order while their bytes fit the budget.  Returns
        ``(resident_keys, spilled_count, resident_bytes)`` — the generic
        analogue of :meth:`SegmentPlan.tier_plan`'s uniform-state slot
        accounting (zero-sized keys are admitted for free)."""
        sizes = self.sizes()
        resident, used, spilled = [], 0, 0
        for k in self.keys():
            nb = max(0, int(sizes.get(k, 0)))
            if used + nb <= int(capacity_bytes):
                resident.append(k)
                used += nb
            else:
                spilled += 1
        return tuple(resident), spilled, used


def merge_access_plans(*plans: ResourceAccessPlan) -> ResourceAccessPlan:
    """Stable merge by ``use_index``: one joint farthest-next-use order over
    every resource class (ties resolve in producer-argument order)."""
    acc = [a for p in plans for a in p.accesses]
    acc.sort(key=lambda a: a.use_index)  # stable: ties keep producer order
    return ResourceAccessPlan(accesses=tuple(acc))


def expert_key(leaf_id: int, step: int, expert: int) -> tuple:
    """Level-2 key of one expert's parameter blob for one chain step:
    ``("xp", leaf_id, step, expert)``.  Deliberately non-``int``: the
    executor's resume path classifies durable *boundary* keys by int-ness,
    and ``MultistageRun.close`` purges expert keys separately."""
    return ("xp", int(leaf_id), int(step), int(expert))


def expert_access_plan(plan: "SegmentPlan", leaf_ids, n_experts: int,
                       expert_counts=None, *, phase: str = "reverse",
                       blob_bytes=0) -> ResourceAccessPlan:
    """Producer 2 of the generic resource IR: MoE expert parameter blobs in
    the order the given phase consumes them.

    ``phase="forward"`` ranks accesses by segment ``sid`` (each segment's
    compute reads its steps' experts); ``phase="reverse"`` by reverse rank
    (and steps within a segment in descending order, matching the vjp's
    consumption).  Within one step, experts are ordered by *descending
    routed-token count* from ``expert_counts`` (an ``(n, n_experts)`` array
    of routing statistics, e.g. ``models.moe.routing_stats``): the busiest
    experts rank soonest, so under joint Belady eviction the lightest-loaded
    experts spill first.  ``expert_counts=None`` falls back to uniform
    (expert-index) order.  ``blob_bytes`` is an int or a ``{leaf_id: bytes}``
    map."""
    if phase not in ("forward", "reverse"):
        raise ValueError(f"phase must be 'forward' or 'reverse', got {phase}")

    def blob(li):
        return int(blob_bytes[li]) if isinstance(blob_bytes, dict) \
            else int(blob_bytes)

    segs = plan.segments if phase == "forward" \
        else tuple(reversed(plan.segments))
    accesses = []
    for rank, seg in enumerate(segs):
        steps = range(seg.begin, seg.end)
        if phase == "reverse":
            steps = reversed(range(seg.begin, seg.end))
        for k in steps:
            order = list(range(n_experts))
            if expert_counts is not None:
                row = expert_counts[k]
                order.sort(key=lambda e: (-int(row[e]), e))
            for e in order:
                for li in leaf_ids:
                    accesses.append(ResourceAccess(
                        key=expert_key(li, k, e), use_index=rank,
                        size_bytes=blob(li)))
    return ResourceAccessPlan(accesses=tuple(accesses))


@dataclass(frozen=True)
class RunCursor:
    """Serializable position of a multistage run inside its plan —
    checkpointed through the Level-2 journal at segment granularity so a
    crashed run resumes from its last durable segment instead of t=0.

    Semantics by ``phase``:

    * ``"forward"`` — ``segment_index`` segments have completed their
      advance; the chain position in steps is
      :meth:`SegmentPlan.cursor_position`.  A durable forward cursor also
      guarantees (writer-queue FIFO) that every boundary store enqueued
      before it is durable, so resume replays at most one interval.
    * ``"reverse"`` — ``segment_index`` is the *next* segment to reverse
      (``num_segments - 1`` at sweep start, ``-1`` when done);
      ``payload["adjoint"]`` is the host-snapshot adjoint ready for that
      segment, ``payload["artifact"]``/``payload["artifact_key"]`` carry
      the just-reversed segment's runner artifact (e.g. per-step input
      cotangents) so the front-end can stitch full-chain cotangents after
      a resume.
    * ``"done"`` — the reverse sweep completed; nothing to resume.

    ``revolve_pos`` reserves sub-segment granularity (position inside the
    segment's Revolve sub-plan); the executor currently checkpoints at
    segment boundaries only, so it is always 0.
    """

    plan_id: str
    n: int
    interval: int
    s_l1: int
    phase: str            # "forward" | "reverse" | "done"
    segment_index: int
    revolve_pos: int = 0
    payload: Any = None

    def matches(self, plan: "SegmentPlan") -> bool:
        return self.plan_id == plan.plan_id and self.n == plan.n \
            and self.interval == plan.interval and self.s_l1 == plan.s_l1


@dataclass(frozen=True)
class SegmentPlan:
    """Per-interval plan for an ``n``-step chain: the IR the executor drives
    and the compile cache is keyed from.

    Segments are listed in forward order; the reverse sweep walks them
    backwards with double-buffered Level-2 prefetch (while segment ``j`` is
    reversed, segment ``j-1``'s boundary is already in flight).  The legacy
    flat ``MAction`` stream (``multistage_schedule``) is *derived* from this
    plan, so the two can never disagree.

    ``inner`` is the optional second axis (:class:`InnerPlan`): when set,
    the plan is 2D — the per-step computation itself is chunked during the
    reverse.  A 1D plan's ``plan_id`` is byte-identical to what it was
    before the second axis existed, so journaled cursors from 1D runs stay
    valid; a 2D plan appends ``:L=<layer_chunks>:H=<head_chunks>``.
    """

    n: int
    interval: int
    s_l1: int
    segments: Tuple[SegmentSpec, ...]
    inner: Optional[InnerPlan] = None

    @property
    def num_segments(self) -> int:
        return len(self.segments)

    @property
    def plan_id(self) -> str:
        """Stable identity of this plan — what a journaled
        :class:`RunCursor` is validated against on resume."""
        base = f"plan:n={self.n}:I={self.interval}:s={self.s_l1}"
        return base + self.inner.id_suffix if self.inner is not None else base

    def cursor(self, phase: str, segment_index: int,
               payload: Any = None) -> RunCursor:
        return RunCursor(plan_id=self.plan_id, n=self.n,
                         interval=self.interval, s_l1=self.s_l1,
                         phase=phase, segment_index=segment_index,
                         payload=payload)

    def cursor_position(self, cursor: RunCursor) -> int:
        """Chain position (in steps) a forward-phase cursor attests to."""
        if cursor.segment_index >= self.num_segments:
            return self.n
        return self.segments[cursor.segment_index].begin

    def boundaries(self) -> List[int]:
        return [seg.begin for seg in self.segments]

    def store_events(self) -> List[int]:
        """Level-2 store events (one per segment boundary, forward order) —
        identical across engines by construction: the executor engines issue
        one ``store_async`` per entry, the scan engine tags one offloaded
        boundary carry per entry."""
        return self.boundaries()

    def reverse_access_order(self) -> Tuple[int, ...]:
        """Boundary keys in the exact order the reverse sweep consumes them
        (descending ``begin``).  This is what makes Level-2 eviction
        plan-aware: the next-needed boundary is always the *largest*
        remaining begin, so the Belady victim is the smallest."""
        return tuple(seg.begin for seg in reversed(self.segments))

    def resource_access_plan(self, state_bytes: int = 0) -> ResourceAccessPlan:
        """Producer 1 of the generic resource IR
        (:class:`ResourceAccessPlan`): this plan's boundary states in exact
        reverse consumption order — :meth:`reverse_access_order` expressed
        as a typed access trace, one use per reverse segment rank, so it
        merges (``merge_access_plans``) with other resource classes' traces
        into one joint eviction order."""
        return ResourceAccessPlan(accesses=tuple(
            ResourceAccess(key=b, use_index=r, size_bytes=int(state_bytes))
            for r, b in enumerate(self.reverse_access_order())))

    def tier_plan(self, capacity_bytes: int, state_bytes: int,
                  t_t_slow: Optional[float] = None,
                  t_seg_reverse: Optional[float] = None) -> TierPlan:
        """Tier residency / prefetch-distance annotations for a
        capacity-bounded Level-2 backend holding one ``state_bytes``
        boundary per segment.

        With ``k = capacity_bytes // state_bytes`` fast-tier slots and
        plan-aware eviction, the end-of-forward resident set is the ``k``
        largest begins; each is freed right after its reverse turn, so a
        segment is served from the fast tier iff it is among the last ``k``
        (``resident[j] == (num_segments - j <= k)``).  The other
        ``spilled`` boundaries are promoted back ahead of need; the
        prefetch distance is ``ceil(t_t_slow / t_seg_reverse)`` segments of
        reverse work when the two times are given (the §3 overlap rule
        applied to the slow tier), else 2 — one segment of extra lead over
        the plain double-buffer — and 1 when nothing spills.
        """
        m = self.num_segments
        k = m if state_bytes <= 0 else \
            min(m, int(capacity_bytes) // int(state_bytes))
        resident = tuple(m - j <= k for j in range(m))
        spilled = m - k
        if spilled <= 0:
            distance = 1
        elif t_t_slow is not None and t_seg_reverse is not None \
                and t_seg_reverse > 0:
            distance = max(1, min(m, math.ceil(t_t_slow / t_seg_reverse)))
        else:
            distance = min(m, 2)
        return TierPlan(fast_slots=k, resident=resident,
                        spilled=max(0, spilled),
                        prefetch_distance=distance)

    def segment_lengths(self) -> Tuple[int, ...]:
        """Distinct segment lengths, descending — one compiled
        advance/reverse pair exists per entry (the tail adds at most one)."""
        return tuple(sorted({seg.length for seg in self.segments},
                            reverse=True))

    def inner_chunk(self, seg: SegmentSpec) -> Optional[int]:
        """The XLA engines' projection of ``seg``'s Revolve sub-plan: the
        ``jax.checkpoint`` chunk length for recomputation inside the segment
        (``None`` when the segment fits in Level 1 and is replayed
        store-all)."""
        if seg.revolve is None:
            return None
        return chunk_length(seg.length, self.s_l1)

    def reverse_advances(self) -> int:
        total = 0
        for seg in self.segments:
            if seg.revolve is None:   # store-all replay: len-1 advances
                total += seg.length - 1
            else:
                total += rv.count_advances(list(seg.revolve))
        return total

    def total_advances(self) -> int:
        return self.n + self.reverse_advances()


def segment_plan(n: int, interval: int, s_l1: int,
                 inner: Optional[InnerPlan] = None) -> SegmentPlan:
    """Build the SegmentPlan IR for an n-step chain (validates arguments;
    uneven tail segments are first-class — the last segment is simply
    shorter).  Pass ``inner`` to make the plan 2D."""
    if n < 1:
        raise ValueError(f"need n >= 1, got {n}")
    if interval < 1:
        raise ValueError(f"need interval >= 1, got {interval}")
    if s_l1 < 1:
        raise ValueError(f"need s_l1 >= 1, got {s_l1}")
    segments = []
    for sid, b in enumerate(range(0, n, interval)):
        e = min(b + interval, n)
        sub = rv.revolve_subplan(e - b, s_l1, offset=b) if e - b > s_l1 \
            else None
        segments.append(SegmentSpec(sid=sid, begin=b, end=e, revolve=sub))
    return SegmentPlan(n=n, interval=interval, s_l1=s_l1,
                       segments=tuple(segments), inner=inner)


@dataclass
class MultistageSchedule:
    """Schedule for reversing an ``n``-step chain with interval ``I`` and
    ``s_l1`` Level-1 snapshot slots per interval.

    ``segment_schedules`` maps a segment start index to the Revolve action
    stream used inside that segment (only populated when the segment does not
    fit entirely in Level-1 memory, i.e. ``segment_len > s_l1``).
    """

    n: int
    interval: int
    s_l1: int
    actions: List[MAction] = field(default_factory=list)
    segment_schedules: dict = field(default_factory=dict)

    # -- accounting used by tests and the perf model --------------------------
    @property
    def num_segments(self) -> int:
        return math.ceil(self.n / self.interval)

    def forward_advances(self) -> int:
        return sum(
            a.end - a.index for a in self.actions if a.op is MOp.ADVANCE
        )

    def reverse_advances(self) -> int:
        total = 0
        for a in self.actions:
            if a.op is not MOp.REVERSE_SEGMENT:
                continue
            seg = self.segment_schedules.get(a.index)
            if seg is None:  # store-all-in-L1 reversal: len-1 advances
                total += (a.end - a.index) - 1
            else:
                total += rv.count_advances(seg)
        return total

    def total_advances(self) -> int:
        return self.forward_advances() + self.reverse_advances()

    def recompute_factor(self) -> float:
        """Total forward advances / (n - 1); 1.0 == no recomputation, matching
        ``revolve.recompute_factor``'s convention.  Includes the initial
        forward sweep (n advances), so the minimum for multistage is n/(n-1).
        """
        if self.n <= 1:
            return 1.0
        return self.total_advances() / (self.n - 1)

    def l2_stores(self) -> int:
        return sum(1 for a in self.actions if a.op is MOp.STORE_L2)


def multistage_schedule(n: int, interval: int, s_l1: int) -> MultistageSchedule:
    """Build the asynchronous multistage schedule for an n-step chain.

    Forward: advance in segments of ``interval``; asynchronously store each
    segment-boundary state to Level 2.  Reverse: prefetch boundary states
    (double-buffered) and reverse each segment with Revolve(segment_len, s_l1)
    — which degenerates to store-all when ``segment_len <= s_l1``.

    If ``n <= interval`` there is only one segment and the schedule degenerates
    to classic Revolve, as §3 of the paper notes.

    The flat action stream is derived from the :class:`SegmentPlan` IR
    (``segment_plan``) — the plan is the single source of truth; this view of
    it exists for accounting, tests and debugging.
    """
    plan = segment_plan(n, interval, s_l1)
    sched = MultistageSchedule(n=n, interval=interval, s_l1=s_l1)
    acts = sched.actions
    segs = plan.segments

    # ---- forward phase ------------------------------------------------------
    for seg in segs:
        acts.append(MAction(MOp.STORE_L2, seg.begin))
        acts.append(MAction(MOp.ADVANCE, seg.begin, seg.end))
    acts.append(MAction(MOp.WAIT_STORES))

    # ---- reverse phase ------------------------------------------------------
    # Prefetch the last boundary immediately; then double-buffer.
    acts.append(MAction(MOp.PREFETCH_L2, segs[-1].begin))
    for j in range(len(segs) - 1, -1, -1):
        seg = segs[j]
        if j > 0:
            acts.append(MAction(MOp.PREFETCH_L2, segs[j - 1].begin))
        acts.append(MAction(MOp.WAIT_PREFETCH, seg.begin))
        acts.append(MAction(MOp.REVERSE_SEGMENT, seg.begin, seg.end))
        acts.append(MAction(MOp.FREE_L2, seg.begin))
        if seg.revolve is not None:
            # Segment does not fit in L1: Revolve within the interval.
            sched.segment_schedules[seg.begin] = list(seg.revolve)

    return sched


def multistage_recompute_factor(n: int, interval: int, s_l1: int) -> float:
    """Physical recompute factor of the multistage strategy: ALL forward
    advances (the initial sweep + the per-segment reversal replays) over
    (n - 1).  Constant in n for fixed ``interval``:
    R -> 1 + t(I, s)/I ~ 2 - 1/I for I <= s+1.
    """
    if n <= 1:
        return 1.0
    total = n  # initial forward sweep
    for b in range(0, n, interval):
        seg = min(interval, n - b)
        total += rv.optimal_advances(seg, s_l1) if seg > 1 else 0
    return total / (n - 1)


def multistage_recompute_factor_paper(n: int, interval: int,
                                      s_l1: int) -> float:
    """The paper's §3 convention: R(I, s) — the Revolve factor *within* one
    interval (1.0 == segment fits in Level 1; the initial forward sweep is
    counted as the baseline, not as recomputation).  This is what the
    paper's Figure 3 plots: flat in n, == classic Revolve's R(I, s).
    """
    if n <= 1:
        return 1.0
    adv = 0
    base = 0
    for b in range(0, n, interval):
        seg = min(interval, n - b)
        adv += rv.optimal_advances(seg, s_l1) if seg > 1 else 0
        base += max(seg - 1, 1)
    return adv / base if base else 1.0
