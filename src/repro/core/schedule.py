"""Asynchronous multistage checkpointing schedule (the paper's §2).

Two storage levels:

* **Level 1** — fast, small (MCDRAM / HBM / this process's RAM): holds the
  running state plus up to ``s`` snapshots used by Revolve inside an interval.
* **Level 2** — large, slow (DRAM / SSD / host RAM): receives every ``I``-th
  state via an *asynchronous* store during the forward pass, and serves
  asynchronous prefetches during the backward pass.

The schedule below is the action stream the executor interprets.  Stores and
prefetches are explicitly asynchronous: ``STORE_L2`` / ``PREFETCH_L2`` enqueue
a transfer, ``WAIT_STORE`` / ``WAIT_PREFETCH`` join it.  Prefetches are
double-buffered: while interval ``j`` is being reversed, interval ``j-1``'s
checkpoint is already in flight.
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field
from typing import List

from repro.core import revolve as rv


class MOp(enum.Enum):
    ADVANCE = "advance"          # forward steps [index, end)
    STORE_L2 = "store_l2"        # async: current state (== x_index) -> Level 2
    WAIT_STORES = "wait_stores"  # join all outstanding Level-2 stores
    PREFETCH_L2 = "prefetch_l2"  # async: x_index Level 2 -> Level 1 staging
    WAIT_PREFETCH = "wait_pref"  # join the prefetch of x_index; load into state
    FREE_L2 = "free_l2"          # drop x_index from Level 2
    REVERSE_SEGMENT = "reverse"  # reverse steps [index, end) with x_index in hand


@dataclass(frozen=True)
class MAction:
    op: MOp
    index: int = -1
    end: int = -1

    def __repr__(self) -> str:
        if self.op in (MOp.ADVANCE, MOp.REVERSE_SEGMENT):
            return f"{self.op.name}({self.index}->{self.end})"
        return f"{self.op.name}({self.index})"


@dataclass
class MultistageSchedule:
    """Schedule for reversing an ``n``-step chain with interval ``I`` and
    ``s_l1`` Level-1 snapshot slots per interval.

    ``segment_schedules`` maps a segment start index to the Revolve action
    stream used inside that segment (only populated when the segment does not
    fit entirely in Level-1 memory, i.e. ``segment_len > s_l1``).
    """

    n: int
    interval: int
    s_l1: int
    actions: List[MAction] = field(default_factory=list)
    segment_schedules: dict = field(default_factory=dict)

    # -- accounting used by tests and the perf model --------------------------
    @property
    def num_segments(self) -> int:
        return math.ceil(self.n / self.interval)

    def forward_advances(self) -> int:
        return sum(
            a.end - a.index for a in self.actions if a.op is MOp.ADVANCE
        )

    def reverse_advances(self) -> int:
        total = 0
        for a in self.actions:
            if a.op is not MOp.REVERSE_SEGMENT:
                continue
            seg = self.segment_schedules.get(a.index)
            if seg is None:  # store-all-in-L1 reversal: len-1 advances
                total += (a.end - a.index) - 1
            else:
                total += rv.count_advances(seg)
        return total

    def total_advances(self) -> int:
        return self.forward_advances() + self.reverse_advances()

    def recompute_factor(self) -> float:
        """Total forward advances / (n - 1); 1.0 == no recomputation, matching
        ``revolve.recompute_factor``'s convention.  Includes the initial
        forward sweep (n advances), so the minimum for multistage is n/(n-1).
        """
        if self.n <= 1:
            return 1.0
        return self.total_advances() / (self.n - 1)

    def l2_stores(self) -> int:
        return sum(1 for a in self.actions if a.op is MOp.STORE_L2)


def multistage_schedule(n: int, interval: int, s_l1: int) -> MultistageSchedule:
    """Build the asynchronous multistage schedule for an n-step chain.

    Forward: advance in segments of ``interval``; asynchronously store each
    segment-boundary state to Level 2.  Reverse: prefetch boundary states
    (double-buffered) and reverse each segment with Revolve(segment_len, s_l1)
    — which degenerates to store-all when ``segment_len <= s_l1``.

    If ``n <= interval`` there is only one segment and the schedule degenerates
    to classic Revolve, as §3 of the paper notes.
    """
    if n < 1:
        raise ValueError(f"need n >= 1, got {n}")
    if interval < 1:
        raise ValueError(f"need interval >= 1, got {interval}")
    if s_l1 < 1:
        raise ValueError(f"need s_l1 >= 1, got {s_l1}")

    sched = MultistageSchedule(n=n, interval=interval, s_l1=s_l1)
    acts = sched.actions
    starts = list(range(0, n, interval))

    # ---- forward phase ------------------------------------------------------
    for b in starts:
        e = min(b + interval, n)
        acts.append(MAction(MOp.STORE_L2, b))
        acts.append(MAction(MOp.ADVANCE, b, e))
    acts.append(MAction(MOp.WAIT_STORES))

    # ---- reverse phase ------------------------------------------------------
    # Prefetch the last boundary immediately; then double-buffer.
    acts.append(MAction(MOp.PREFETCH_L2, starts[-1]))
    for j in range(len(starts) - 1, -1, -1):
        b = starts[j]
        e = min(b + interval, n)
        if j > 0:
            acts.append(MAction(MOp.PREFETCH_L2, starts[j - 1]))
        acts.append(MAction(MOp.WAIT_PREFETCH, b))
        acts.append(MAction(MOp.REVERSE_SEGMENT, b, e))
        acts.append(MAction(MOp.FREE_L2, b))
        seg_len = e - b
        if seg_len > s_l1:
            # Segment does not fit in L1: Revolve within the interval.
            sched.segment_schedules[b] = rv.revolve_schedule(seg_len, s_l1, offset=b)

    return sched


def multistage_recompute_factor(n: int, interval: int, s_l1: int) -> float:
    """Physical recompute factor of the multistage strategy: ALL forward
    advances (the initial sweep + the per-segment reversal replays) over
    (n - 1).  Constant in n for fixed ``interval``:
    R -> 1 + t(I, s)/I ~ 2 - 1/I for I <= s+1.
    """
    if n <= 1:
        return 1.0
    total = n  # initial forward sweep
    for b in range(0, n, interval):
        seg = min(interval, n - b)
        total += rv.optimal_advances(seg, s_l1) if seg > 1 else 0
    return total / (n - 1)


def multistage_recompute_factor_paper(n: int, interval: int,
                                      s_l1: int) -> float:
    """The paper's §3 convention: R(I, s) — the Revolve factor *within* one
    interval (1.0 == segment fits in Level 1; the initial forward sweep is
    counted as the baseline, not as recomputation).  This is what the
    paper's Figure 3 plots: flat in n, == classic Revolve's R(I, s).
    """
    if n <= 1:
        return 1.0
    adv = 0
    base = 0
    for b in range(0, n, interval):
        seg = min(interval, n - b)
        adv += rv.optimal_advances(seg, s_l1) if seg > 1 else 0
        base += max(seg - 1, 1)
    return adv / base if base else 1.0
