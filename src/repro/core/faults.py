"""Fault injection for the Level-2 storage stack (chaos testing).

A multi-hour reverse sweep dies in a handful of well-defined ways: the
Level-2 writer thread is killed mid-store (OOM-killer, preemption), a
demand fetch fails (evicted page, flaky SSD), a spilled record is torn by
a crash mid-write, or bytes rot on disk and trip a checksum.  This module
makes every one of those injectable *deterministically*, so the
crash-consistency machinery (``JournaledStorage`` + ``resume_from=``) can
be tested as a property: a faulted run either completes with gradients
bit-identical to the fault-free run, or raises a typed
:class:`StorageFault` — and a resume afterwards always reproduces the
fault-free gradient exactly.

Injection is a *zero-overhead-when-disabled* hook: ``AsyncTransferEngine``
and ``JournaledStorage`` read the module-global injector once at
construction (``faults.inject(plan)`` context manager, or an explicit
``faults=`` argument) and each hook site is a single ``is not None`` test.
Production code paths never pay more than that.

Typed fault taxonomy (all subclass :class:`StorageFault`, itself a
``RuntimeError`` so retry wrappers keyed on RuntimeError keep working):

* :class:`WriterCrashError` — the Level-2 writer thread died with stores
  outstanding (detected at join/demand-fetch time).
* :class:`TornRecordError` — a journal record was truncated mid-write
  (reported by the journal scanner; the torn *tail* of a crash is
  repaired silently, a torn interior is an error).
* :class:`ChecksumError` — a complete record whose payload fails its
  CRC (bit rot / injected flip).
* :class:`InjectedFault` — the generic injected transfer failure
  (demand-fetch / put faults).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Iterator, Optional


class StorageFault(RuntimeError):
    """Base class of every typed Level-2 storage failure.

    Subclasses ``RuntimeError`` so existing retry wrappers
    (``distributed.fault_tolerance.with_retries``) treat storage faults as
    retryable without modification.
    """


class WriterCrashError(StorageFault):
    """The Level-2 writer thread died with work outstanding."""


class TornRecordError(StorageFault):
    """A journal record was cut short by a crash mid-write."""


class ChecksumError(StorageFault):
    """A journal record's payload does not match its CRC."""


class InjectedFault(StorageFault):
    """A deliberately injected transfer failure (tests only)."""


class WriterKilled(Exception):
    """Raised *inside* the writer thread to simulate abrupt death.

    Deliberately NOT a :class:`StorageFault`: nothing downstream should
    ever observe it — the writer loop catches it and returns without
    marking the queue item done, exactly as if the thread had been killed
    by the OS.  (If it escapes on a synchronous code path, that is a test
    wiring bug, and the loud generic exception is the right outcome.)
    """


@dataclass(frozen=True)
class FaultPlan:
    """Declarative description of which fault to inject and when.

    All counters are 0-based and count events of their own kind across the
    lifetime of one :class:`FaultInjector` (i.e. one ``inject()`` block).

    * ``kill_writer_at_store`` — the writer thread dies immediately before
      executing its ``k``-th queued store (the item is left un-done, so
      joins report :class:`WriterCrashError`).
    * ``fail_get_at`` — the ``k``-th engine-level fetch (prefetch job or
      demand fetch) raises :class:`InjectedFault` instead of reading.
    * ``truncate_journal_at_store`` — the ``k``-th journaled STORE record
      is torn in half on disk and the writing thread dies on the spot
      (crash mid-``write(2)``).
    * ``flip_byte_at_store`` — one payload byte of the ``k``-th journaled
      STORE record is flipped *after* it was written and fsynced (silent
      bit rot: the run continues; the corruption trips
      :class:`ChecksumError` when the record is next read or scanned).
    """

    kill_writer_at_store: Optional[int] = None
    fail_get_at: Optional[int] = None
    truncate_journal_at_store: Optional[int] = None
    flip_byte_at_store: Optional[int] = None
    # Event-driven preemption: while the event is set, the writer thread
    # dies at its next store (same observable outcome as
    # ``kill_writer_at_store``, but triggered asynchronously by a
    # scheduler instead of at a precomputed count).  The serving layer uses
    # this to preempt a running offloaded train step at a clean journal
    # boundary: the run raises WriterCrashError, the journal keeps every
    # fsynced segment, and ``resume_from=`` replays bit-identically.
    preempt_on: Optional[threading.Event] = None

    def __post_init__(self):
        for name in ("kill_writer_at_store", "fail_get_at",
                     "truncate_journal_at_store", "flip_byte_at_store"):
            v = getattr(self, name)
            if v is not None and v < 0:
                raise ValueError(f"{name} must be >= 0, got {v}")


class FaultInjector:
    """Counts events and fires the faults a :class:`FaultPlan` asks for.

    Thread-safe: hooks are called from the writer thread, prefetch threads
    and the caller thread concurrently.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._lock = threading.Lock()
        self.writer_stores = 0      # stores seen by the writer thread
        self.gets = 0               # engine-level fetches
        self.journal_stores = 0     # STORE records appended to a journal
        self.fired: list = []       # (kind, index) of every injected fault

    def _count(self, field: str) -> int:
        with self._lock:
            k = getattr(self, field)
            setattr(self, field, k + 1)
            return k

    def _fire(self, kind: str, k: int) -> None:
        with self._lock:
            self.fired.append((kind, k))

    # -- hook sites (each guarded by `injector is not None` at the caller) --
    def on_writer_store(self, key) -> None:
        k = self._count("writer_stores")
        if k == self.plan.kill_writer_at_store:
            self._fire("kill_writer", k)
            raise WriterKilled(
                f"injected writer death at store {k} (key {key!r})")
        if self.plan.preempt_on is not None and self.plan.preempt_on.is_set():
            self._fire("preempt", k)
            raise WriterKilled(
                f"preemption requested; writer dying at store {k} "
                f"(key {key!r})")

    def on_get(self, key) -> None:
        k = self._count("gets")
        if k == self.plan.fail_get_at:
            self._fire("fail_get", k)
            raise InjectedFault(
                f"injected Level-2 fetch failure at get {k} (key {key!r})")

    def on_journal_store(self, journal, start: int, end: int) -> None:
        """Called by ``JournaledStorage`` right after a STORE record has
        been written and fsynced; ``[start, end)`` is the record's extent.
        May mutate the journal file through the two private fault hooks the
        journal exposes, and/or kill the writing thread."""
        k = self._count("journal_stores")
        if k == self.plan.flip_byte_at_store:
            self._fire("flip_byte", k)
            journal.debug_flip_byte(end - 1)   # last payload byte: CRC trips
        if k == self.plan.truncate_journal_at_store:
            self._fire("truncate", k)
            journal.debug_truncate(start + (end - start) // 2)
            raise WriterKilled(
                f"injected crash tearing journal record {k}")


# ---------------------------------------------------------------------------
# module-global injector (read once at engine/backend construction)
# ---------------------------------------------------------------------------

_ACTIVE: Optional[FaultInjector] = None


def active() -> Optional[FaultInjector]:
    """The currently installed injector (``None`` almost always)."""
    return _ACTIVE


@contextmanager
def inject(plan: FaultPlan) -> Iterator[FaultInjector]:
    """Install a fault plan for the duration of the block.

    Engines and journaled backends constructed inside the block pick the
    injector up; ones constructed outside are unaffected (zero overhead
    when disabled — the hook is a single ``is not None`` test).
    """
    global _ACTIVE
    injector = FaultInjector(plan)
    prev, _ACTIVE = _ACTIVE, injector
    try:
        yield injector
    finally:
        _ACTIVE = prev


def is_storage_fault(err: BaseException) -> bool:
    """True if ``err`` is (or transitively wraps) a typed StorageFault.

    Host exceptions crossing ``jax.io_callback`` come back as
    ``XlaRuntimeError`` with the original type name embedded in the
    message, so this matches both the ``__cause__``/``__context__`` chain
    and the text — the predicate retry/preemption handlers use to decide
    whether a failed step is resumable."""
    seen = set()
    e: Optional[BaseException] = err
    while e is not None and id(e) not in seen:
        seen.add(id(e))
        if isinstance(e, StorageFault):
            return True
        e = e.__cause__ or e.__context__
    return any(name in str(err) for name in
               ("StorageFault", "WriterCrashError", "ChecksumError",
                "TornRecordError", "InjectedFault"))
