"""``multistage_scan`` — the paper's technique as a composable JAX transform
(the *compiled* path that runs on pods).

A chain computation ``carry_{k+1} = body(carry_k, x_k)`` of length ``n`` is
split into ``n / I`` segments.  Each segment is wrapped in ``jax.checkpoint``
with a policy that **offloads the segment-boundary carry to pinned host
memory** and recomputes everything inside the segment during the backward
pass.  On TPU, XLA lowers the offloads to asynchronous ``copy-start`` /
``copy-done`` DMA pairs overlapped with compute — precisely the paper's
asynchronous Level-2 store (forward) and prefetch (backward), but scheduled
by the compiler instead of Python threads.

Memory behaviour (matches the paper's model):

* Level-2 (host) footprint: ``(n / I) x state_bytes`` — grows with ``n`` but
  lives in cheap, large memory.
* Level-1 (HBM) footprint: one segment of activations at a time, i.e.
  O(I) — **constant in n**.
* Recompute overhead: one extra forward per segment interior — constant in
  ``n`` (the compiled counterpart of ``R(I, s)``; with nested intervals the
  inner recompute mimics Revolve-within-the-interval).

``nested_intervals=(I2, ...)`` recursively segments each segment, saving
sub-boundaries in HBM and recomputing at finer granularity — the compiled
analogue of running Revolve inside each interval when a full segment of
activations does not fit in Level 1.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import offload as ofl

Body = Callable[[Any, Any], Tuple[Any, Any]]


def choose_interval(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= max(target, 1); falls back to 1.

    Used to snap the perf-model's optimal interval ``ceil(T_T/T_A)`` onto the
    divisibility constraint of the segmented scan.
    """
    target = max(1, min(target, n))
    for i in range(target, 0, -1):
        if n % i == 0:
            return i
    return 1


def _split_xs(xs: Any, num_segments: int, interval: int) -> Any:
    def rs(x):
        return x.reshape((num_segments, interval) + x.shape[1:])

    return jax.tree_util.tree_map(rs, xs)


def _merge_ys(ys: Any, n: int) -> Any:
    def rs(y):
        return y.reshape((n,) + y.shape[2:])

    return jax.tree_util.tree_map(rs, ys)


def multistage_scan(
    body: Body,
    carry: Any,
    xs: Any = None,
    *,
    length: Optional[int] = None,
    interval: int,
    offload: bool = True,
    nested_intervals: Sequence[int] = (),
    unroll: int = 1,
    boundary_name: str = ofl.BOUNDARY,
) -> Tuple[Any, Any]:
    """Drop-in replacement for ``lax.scan(body, carry, xs)`` implementing
    asynchronous multistage checkpointing.

    Args:
      body: ``(carry, x) -> (carry, y)`` — one chain step (an RNN/SSM time
        step, or one transformer layer when scanning over depth).
      carry: initial carry (the chain state; this is what gets offloaded).
      xs: stacked per-step inputs with leading axis ``n`` (or None).
      length: chain length when ``xs is None``.
      interval: the checkpointing interval ``I``; must divide ``n``.
      offload: if True, boundary carries go to pinned host memory (Level 2);
        if False they are saved in HBM (plain segmented remat — the
        single-stage baseline).
      nested_intervals: optional inner intervals for Revolve-like nested
        recomputation inside each segment.
      unroll: unroll factor for the innermost scan.

    Returns: ``(final_carry, ys)`` identical (up to float assoc.) to
      ``lax.scan``.
    """
    n = length if xs is None else jax.tree_util.tree_leaves(xs)[0].shape[0]
    if n is None:
        raise ValueError("need xs or length")
    if n % interval != 0:
        raise ValueError(
            f"interval {interval} must divide chain length {n}; "
            f"use choose_interval(n, target) to snap it"
        )
    if interval == n and not nested_intervals:
        # Single segment: degenerates to one rematted scan (classic remat).
        seg = _make_segment(body, interval, offload, nested_intervals, unroll,
                            boundary_name)
        return seg(carry, xs)

    num_segments = n // interval
    xs_seg = None if xs is None else _split_xs(xs, num_segments, interval)
    seg = _make_segment(body, interval, offload, nested_intervals, unroll,
                        boundary_name)
    carry, ys = lax.scan(seg, carry, xs_seg, length=num_segments)
    return carry, (None if ys is None else _merge_ys(ys, n))


def _make_segment(
    body: Body,
    interval: int,
    offload: bool,
    nested_intervals: Sequence[int],
    unroll: int,
    boundary_name: str,
) -> Callable[[Any, Any], Tuple[Any, Any]]:
    """One segment: remat region whose boundary carry is offloaded/saved."""

    if offload:
        policy = ofl.offload_policy([boundary_name])
    else:
        policy = ofl.save_policy([boundary_name])

    def segment(carry, xs_seg):
        # Tag the *input* carry: this is the every-I-th state the paper
        # stores to Level 2.  All consumers read the tagged value, so remat
        # saves (offloads) exactly this tensor and recomputes the rest.
        carry = ofl.tag(carry, boundary_name)
        if nested_intervals:
            inner_i, *rest = nested_intervals
            carry, ys = multistage_scan(
                body, carry, xs_seg,
                length=None if xs_seg is not None else interval,
                interval=inner_i if interval % inner_i == 0 else
                choose_interval(interval, inner_i),
                offload=False,
                nested_intervals=rest,
                unroll=unroll,
                boundary_name=ofl.INNER_BOUNDARY,
            )
        else:
            carry, ys = lax.scan(body, carry, xs_seg, length=interval,
                                 unroll=unroll)
        return carry, ys

    return jax.checkpoint(segment, policy=policy, prevent_cse=False)


# ---------------------------------------------------------------------------
# BPTT convenience wrapper
# ---------------------------------------------------------------------------


def bptt_grad(
    step_loss: Callable[[Any, Any, Any], Tuple[Any, Any]],
    params: Any,
    carry0: Any,
    xs: Any,
    *,
    interval: int,
    offload: bool = True,
    nested_intervals: Sequence[int] = (),
) -> Tuple[Any, Any]:
    """Gradient of a summed per-step loss over a long sequence, computed with
    multistage checkpointing.

    ``step_loss(params, carry, x) -> (new_carry, loss_k)``.

    Returns ``(total_loss, grads)`` — the multi-level counterpart of
    ``jax.grad`` over ``lax.scan``.
    """

    def total_loss(p):
        def body(carry, x):
            new_carry, l = step_loss(p, carry, x)
            return new_carry, l

        _, losses = multistage_scan(
            body, carry0, xs, interval=interval, offload=offload,
            nested_intervals=nested_intervals,
        )
        return jnp.sum(losses)

    return jax.value_and_grad(total_loss)(params)
