"""``multistage_scan`` — the paper's technique as a composable JAX transform
(the *trace-native* engine that runs on pods: ``engine="scan"`` behind
``repro.api``).

A chain computation ``carry_{k+1} = body(carry_k, x_k)`` of length ``n`` is
split into the segments of a :class:`~repro.core.schedule.SegmentPlan` — the
same planning IR the compiled and interpreted executor engines drive.  Each
segment is wrapped in ``jax.checkpoint`` with a policy that **offloads the
segment-boundary carry to pinned host memory** and recomputes everything
inside the segment during the backward pass.  On TPU, XLA lowers the
offloads to asynchronous ``copy-start`` / ``copy-done`` DMA pairs overlapped
with compute — precisely the paper's asynchronous Level-2 store (forward)
and prefetch (backward), but scheduled by the compiler instead of Python
threads.

Because everything stays inside the trace (no ``io_callback``, no host-side
run registry), the transform composes with ``jax.jit``, ``jax.vmap`` and
mesh sharding (``NamedSharding`` / ``shard_map``) like any other JAX
function.

Memory behaviour (matches the paper's model):

* Level-2 (host) footprint: ``num_segments x state_bytes`` — grows with
  ``n`` but lives in cheap, large memory.
* Level-1 (HBM) footprint: one segment of activations at a time, i.e.
  O(I) — **constant in n**.
* Recompute overhead: one extra forward per segment interior — constant in
  ``n`` (the compiled counterpart of ``R(I, s)``; plan segments that
  overflow the Level-1 budget are recomputed at the plan's inner chunk
  granularity, the trace-native projection of Revolve-within-the-interval).

Plans need no divisibility: an ``n % I != 0`` chain simply ends in a shorter
tail segment (one extra trace, nothing else).  The legacy
``nested_intervals=(I2, ...)`` knob still recursively segments each segment
explicitly; when a :class:`SegmentPlan` is supplied the inner intervals come
from the plan's Revolve sub-plans instead (via ``SegmentPlan.inner_chunk``).
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import offload as ofl
from repro.core.schedule import SegmentPlan, segment_plan

Body = Callable[[Any, Any], Tuple[Any, Any]]

tree_map = jax.tree_util.tree_map


def choose_interval(n: int, target: int) -> int:
    """Best Level-2 store interval <= ``target`` for an ``n``-step chain.

    Prefers the largest divisor of ``n`` in ``[ceil(target/2), target]``
    (even segments mean one compiled segment variant instead of two), but
    never degrades below half the requested interval: when no divisor is in
    range — prime or odd ``n`` — the target itself is returned and the plan
    simply ends in a shorter tail segment.  (The old divisor-snapping
    fallback silently returned ``I=1`` for prime ``n``: per-step Level-2
    stores, the worst-case recompute/transfer regime.  Uneven tails are
    first-class in the :class:`SegmentPlan` IR, so the divisibility
    constraint is gone.)
    """
    target = max(1, min(target, n))
    floor = max(1, -(-target // 2))
    for i in range(target, floor - 1, -1):
        if n % i == 0:
            return i
    return target


def _split_xs(xs: Any, num_segments: int, interval: int) -> Any:
    def rs(x):
        return x.reshape((num_segments, interval) + x.shape[1:])

    return tree_map(rs, xs)


def _merge_ys(ys: Any, n: int) -> Any:
    def rs(y):
        return y.reshape((n,) + y.shape[2:])

    return tree_map(rs, ys)


def multistage_scan(
    body: Body,
    carry: Any,
    xs: Any = None,
    *,
    length: Optional[int] = None,
    interval: Optional[int] = None,
    plan: Optional[SegmentPlan] = None,
    s_l1: Optional[int] = None,
    offload: bool = True,
    nested_intervals: Sequence[int] = (),
    unroll: int = 1,
    boundary_name: str = ofl.BOUNDARY,
) -> Tuple[Any, Any]:
    """Drop-in replacement for ``lax.scan(body, carry, xs)`` implementing
    asynchronous multistage checkpointing, driven by a
    :class:`~repro.core.schedule.SegmentPlan`.

    Args:
      body: ``(carry, x) -> (carry, y)`` — one chain step (an RNN/SSM time
        step, or one transformer layer when scanning over depth).
      carry: initial carry (the chain state; this is what gets offloaded).
      xs: stacked per-step inputs with leading axis ``n`` (or None).
      length: chain length when ``xs is None``.
      interval: the checkpointing interval ``I``.  Any value in ``[1, n]``
        works — a non-dividing interval yields a shorter tail segment.
      plan: an explicit :class:`SegmentPlan` to execute (overrides
        ``interval``/``s_l1``; segment boundaries, uneven tails and inner
        recompute granularity all come from the plan).
      s_l1: Level-1 snapshot budget.  When given (and ``plan`` is not), the
        plan is built via ``segment_plan(n, interval, s_l1)`` and segments
        that overflow the budget are recomputed at the plan's inner chunk
        granularity.
      offload: if True, boundary carries go to pinned host memory (Level 2);
        if False they are saved in HBM (plain segmented remat — the
        single-stage baseline).
      nested_intervals: optional explicit inner intervals for Revolve-like
        nested recomputation inside each segment (legacy knob; ignored when
        the inner structure comes from ``plan``/``s_l1``).
      unroll: unroll factor for the innermost scan.

    Returns: ``(final_carry, ys)`` identical (up to float assoc.) to
      ``lax.scan``.
    """
    if xs is None:
        n = length
    else:
        n = int(jax.tree_util.tree_leaves(xs)[0].shape[0])
    if n is None:
        raise ValueError("need xs or length")

    if plan is not None:
        if plan.n != n:
            raise ValueError(
                f"plan is for an n={plan.n} chain, got xs of length {n}")
        groups = _plan_groups(plan)
    else:
        if interval is None:
            raise ValueError("need interval= or plan=")
        interval = max(1, min(interval, n))
        if s_l1 is not None:
            groups = _plan_groups(segment_plan(n, interval, s_l1))
        else:
            # Legacy explicit path: uniform segments (+ uneven tail), with
            # the caller's nested_intervals applied inside every segment.
            nested = tuple(nested_intervals)
            num_full, tail = divmod(n, interval)
            groups = [(num_full, interval, nested)]
            if tail:
                groups.append((1, tail, nested))

    return _run_groups(body, carry, xs, groups, offload=offload,
                       unroll=unroll, boundary_name=boundary_name)


def _plan_groups(plan: SegmentPlan) -> List[Tuple[int, int, Tuple[int, ...]]]:
    """Collapse a plan into runs of equal-length segments: ``(count, length,
    nested_intervals)`` triples in forward order.  ``segment_plan`` emits
    uniform intervals plus at most one shorter tail, so the trace contains
    one ``lax.scan``-over-segments region per distinct length — O(I) trace
    size regardless of ``n``.  The inner recompute interval is the plan's
    projection of its Revolve sub-plan (``SegmentPlan.inner_chunk``)."""
    groups: List[Tuple[int, int, Tuple[int, ...]]] = []
    for seg in plan.segments:
        chunk = plan.inner_chunk(seg)
        nested = (chunk,) if chunk is not None else ()
        if groups and groups[-1][1] == seg.length and \
                groups[-1][2] == nested:
            count, ln, nst = groups[-1]
            groups[-1] = (count + 1, ln, nst)
        else:
            groups.append((1, seg.length, nested))
    return groups


def _run_groups(body: Body, carry: Any, xs: Any, groups, *, offload: bool,
                unroll: int, boundary_name: str) -> Tuple[Any, Any]:
    """Execute ``(count, length, nested)`` segment groups in order: each
    group with ``count > 1`` is one ``lax.scan`` over its reshaped inputs;
    a singleton group (the uneven tail, or a single-segment chain) is one
    direct segment call."""
    ys_parts: List[Any] = []
    offset = 0
    for count, seg_len, nested in groups:
        seg_fn = _make_segment(body, seg_len, offload, nested, unroll,
                               boundary_name)
        end = offset + count * seg_len
        xs_grp = None if xs is None else \
            tree_map(lambda a: a[offset:end], xs)
        if count == 1:
            carry, ys = seg_fn(carry, xs_grp)
        else:
            xs_seg = None if xs_grp is None else \
                _split_xs(xs_grp, count, seg_len)
            carry, ys = lax.scan(seg_fn, carry, xs_seg, length=count)
            ys = None if ys is None else _merge_ys(ys, count * seg_len)
        ys_parts.append(ys)
        offset = end
    if len(ys_parts) == 1:
        return carry, ys_parts[0]
    if any(y is None for y in ys_parts):
        return carry, None
    return carry, tree_map(lambda *ps: jnp.concatenate(ps, axis=0),
                           *ys_parts)


def _make_segment(
    body: Body,
    seg_len: int,
    offload: bool,
    nested_intervals: Sequence[int],
    unroll: int,
    boundary_name: str,
) -> Callable[[Any, Any], Tuple[Any, Any]]:
    """One segment: remat region whose boundary carry is offloaded/saved."""

    policy = ofl.segment_policy(offload, boundary_name)

    def segment(carry, xs_seg):
        # Tag the *input* carry: this is the every-I-th state the paper
        # stores to Level 2.  All consumers read the tagged value, so remat
        # saves (offloads) exactly this tensor and recomputes the rest.
        carry = ofl.tag(carry, boundary_name)
        if nested_intervals:
            inner_i, *rest = nested_intervals
            carry, ys = multistage_scan(
                body, carry, xs_seg,
                length=None if xs_seg is not None else seg_len,
                interval=min(inner_i, seg_len),
                offload=False,
                nested_intervals=rest,
                unroll=unroll,
                boundary_name=ofl.INNER_BOUNDARY,
            )
        else:
            carry, ys = lax.scan(body, carry, xs_seg, length=seg_len,
                                 unroll=unroll)
        return carry, ys

    return jax.checkpoint(segment, policy=policy, prevent_cse=False)


# ---------------------------------------------------------------------------
# BPTT convenience wrapper
# ---------------------------------------------------------------------------


def bptt_grad(
    step_loss: Callable[[Any, Any, Any], Tuple[Any, Any]],
    params: Any,
    carry0: Any,
    xs: Any,
    *,
    interval: int,
    s_l1: Optional[int] = None,
    offload: bool = True,
    nested_intervals: Sequence[int] = (),
) -> Tuple[Any, Any]:
    """Gradient of a summed per-step loss over a long sequence, computed with
    multistage checkpointing.

    ``step_loss(params, carry, x) -> (new_carry, loss_k)``.

    Returns ``(total_loss, grads)`` — the multi-level counterpart of
    ``jax.grad`` over ``lax.scan``.
    """

    def total_loss(p):
        def body(carry, x):
            new_carry, l = step_loss(p, carry, x)
            return new_carry, l

        _, losses = multistage_scan(
            body, carry0, xs, interval=interval, s_l1=s_l1, offload=offload,
            nested_intervals=nested_intervals,
        )
        return jnp.sum(losses)

    return jax.value_and_grad(total_loss)(params)
