"""Checkpoint execution engine (the paper's §4, generalised) — the *execute*
stage of the plan -> compile -> execute pipeline.

The executor drives a *forward operator* and a *backward operator* through a
checkpointing schedule, exactly like pyrevolve: the user supplies the two
operators plus an initial state, and the executor owns when states are
computed, snapshotted, offloaded, prefetched and freed.

Operator contract (functional — JAX-friendly)::

    state_{k+1} = forward_op(state_k, k)            # k in [0, n)
    adjoint     = backward_op(state_k, adjoint, k)  # reverse of step k,
                                                    # consumes x_k

``backward_op`` receives the *input* state of step ``k`` (it re-runs the step
forward internally, e.g. via ``jax.vjp``) and threads an arbitrary adjoint
pytree (commonly ``(dL/dstate, accumulated param grads)``).

Three strategies:

* ``run_conventional`` — store every state (the naive baseline; peak Level-1
  memory grows linearly in ``n``).
* ``run_revolve``      — classic single-stage Revolve with ``s`` Level-1
  slots (recompute factor grows ~log n).
* ``run_multistage``   — the paper's contribution: asynchronous Level-2
  stores every ``interval`` steps + prefetch during the reverse sweep;
  Revolve only *inside* intervals (recompute factor constant in ``n``).

The multistage strategy is a thin driver over the
:class:`~repro.core.schedule.SegmentPlan` IR: it interleaves
``AsyncTransferEngine`` store/prefetch events with per-segment work delegated
to a pluggable **segment runner**:

* :class:`InterpretedSegmentRunner` (default) — walks the segment step by
  step through ``forward_op``/``backward_op`` (O(n) host dispatches; the
  paper-faithful interpreter, exact Revolve-optimal advance counts);
* :class:`~repro.core.compiled_ops.CompiledSegmentRunner` — one jitted call
  per segment (O(n/I) host dispatches; the fast path the API front-end uses);
* :class:`~repro.core.compiled_ops.PallasSegmentRunner` — fused Pallas
  kernels: the boundary store streams out over double-buffered DMA *inside*
  the segment kernel (``advance_with_store``), and the reverse fuses
  recompute + transpose Echo-style; bit-identical to the compiled runner.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import numpy as np

from repro.core import revolve as rv
from repro.core import schedule as ms
from repro.core.faults import StorageFault
from repro.core.journal import RecoveredRun
from repro.core.revolve import Op
from repro.core.schedule import SegmentPlan, SegmentSpec
from repro.core.storage import (AsyncTransferEngine, RAMStorage, _to_host,
                                tree_bytes)

ForwardOp = Callable[[Any, int], Any]
BackwardOp = Callable[[Any, Any, int], Any]

# Journal key of the end-of-chain state x_n: stored by journaled forward
# passes so a crash during the *reverse* sweep can resume without redoing
# the O(n) forward (the loss/readout is recomputed from x_n on resume).
FINAL_STATE_KEY = "__final__"


def _journal_backend(engine: AsyncTransferEngine):
    """The engine's backend if it speaks the journal protocol, else None
    (duck-typed: ``put_cursor`` is the discriminating verb; wrappers like
    ``CompressedStorage`` delegate it to a journaled inner store)."""
    backend = engine.backend
    return backend if hasattr(backend, "put_cursor") else None


def _exact_get(backend, key):
    """Resume state load: prefer the backend's exact (raw-journal) read
    over the normal get, which may round-trip a lossy codec."""
    exact = getattr(backend, "get_exact", None)
    return exact(key) if exact is not None else backend.get(key)


@dataclass
class ExecutionStats:
    n: int = 0
    advances: int = 0
    backwards: int = 0
    replayed_advances: int = 0   # resume: re-executed forward steps (<= I)
    host_dispatches: int = 0     # Python-level op/segment invocations
    peak_l1_states: int = 0
    peak_l1_bytes: int = 0
    l2_stores: int = 0
    l2_prefetches: int = 0
    l2_peak_bytes: int = 0       # high-water Level-2 (host) footprint
    l2_fast_peak_bytes: int = 0  # tiered backend: fast-tier high-water mark
    l2_evictions: int = 0        # tiered backend: fast -> slow spills
    l2_promotions: int = 0       # tiered backend: slow -> fast promotions
    l2_staged_peak_bytes: int = 0  # engine prefetch staging high-water mark
    l2_shard_streams: int = 0    # sharded backend: per-device Level-2 streams
    l2_stream_bytes: tuple = ()  # sharded backend: bytes written per stream
    prefetch_depth: int = 1      # segments of prefetch lead in the reverse
    # -- parameter streaming lane (offload_params=, e.g. MoE experts) ------
    param_prefetches: int = 0    # prefetch batches issued (per segment/phase)
    param_fetch_stalls: int = 0  # param waits that actually blocked compute
    param_bytes_moved: int = 0   # bytes fetched through the param lane
    fused_segments: int = 0      # pallas runner: segments run as fused kernels
    fused_boundary_copies: int = 0  # pallas runner: DMA boundary copies
    #                                 overlapped with in-kernel compute
    # -- 2D (time x layer) plans: inner-axis counters ----------------------
    inner_layer_chunks: int = 0  # rematted layer sub-ranges per step (0 = 1D)
    inner_head_chunks: int = 0   # chunked logits/loss head chunks (0 = 1D)
    inner_layers: int = 0        # layer applications per chain step
    inner_recomputed_layers: int = 0  # layer applications replayed by the
    #                                   inner remat during the reverse sweep
    inner_peak_bytes: int = 0    # per-step saved inner-boundary high-water
    store_stall_s: float = 0.0
    prefetch_stall_s: float = 0.0
    wall_s: float = 0.0

    @property
    def recompute_factor(self) -> float:
        return self.advances / max(1, self.n - 1)

    @property
    def inner_recompute_factor(self) -> float:
        """Extra forwards of the per-step layer stack per chain step
        (0.0 for a 1D plan, 1.0 for the exact inner chunking)."""
        denom = self.n * self.inner_layers
        return self.inner_recomputed_layers / denom if denom else 0.0


class _L1Slots:
    """Level-1 snapshot slots with live-byte accounting."""

    def __init__(self, stats: ExecutionStats):
        self._slots: Dict[int, Any] = {}
        self._stats = stats
        self._extra_bytes = 0  # running state + staged prefetch

    def _update_peak(self) -> None:
        n_states = len(self._slots)
        self._stats.peak_l1_states = max(self._stats.peak_l1_states, n_states)
        total = sum(tree_bytes(v) for v in self._slots.values())
        self._stats.peak_l1_bytes = max(
            self._stats.peak_l1_bytes, total + self._extra_bytes
        )

    def note_extra(self, nbytes: int) -> None:
        self._extra_bytes = nbytes
        self._update_peak()

    def store(self, idx: int, state: Any) -> None:
        self._slots[idx] = state
        self._update_peak()

    def restore(self, idx: int) -> Any:
        return self._slots[idx]

    def free(self, idx: int) -> None:
        self._slots.pop(idx, None)

    def __contains__(self, idx: int) -> bool:
        return idx in self._slots

    def __len__(self) -> int:
        return len(self._slots)


def _exec_revolve(forward_op: ForwardOp, backward_op: BackwardOp, sched,
                  slots: _L1Slots, adjoint: Any,
                  stats: ExecutionStats) -> Any:
    """Interpret a Revolve action stream (used for the single-stage strategy
    and for Revolve-inside-an-interval sub-plans)."""
    current: Any = None
    current_idx = -1
    for a in sched:
        if a.op is Op.RESTORE:
            current = slots.restore(a.index)
            current_idx = a.index
        elif a.op is Op.ADVANCE:
            assert current_idx == a.index, (current_idx, a)
            for k in range(a.index, a.end):
                current = forward_op(current, k)
                stats.advances += 1
                stats.host_dispatches += 1
            current_idx = a.end
        elif a.op is Op.STORE:
            assert current_idx == a.index, (current_idx, a)
            slots.store(a.index, current)
        elif a.op is Op.FREE:
            slots.free(a.index)
        elif a.op is Op.BACKWARD:
            assert current_idx == a.index, (current_idx, a)
            adjoint = backward_op(current, adjoint, a.index)
            stats.backwards += 1
            stats.host_dispatches += 1
    return adjoint


class InterpretedSegmentRunner:
    """Step-granular segment runner: the paper-faithful Python interpreter.

    One ``forward_op``/``backward_op`` dispatch per chain step; reversal uses
    the segment's Revolve sub-plan when it does not fit in Level 1, store-all
    replay otherwise.  Advance counts are exactly Revolve-optimal (asserted
    in tests); host dispatch count is O(n).
    """

    def __init__(self, forward_op: ForwardOp,
                 backward_op: Optional[BackwardOp]):
        self.forward_op = forward_op
        self.backward_op = backward_op

    def advance(self, state: Any, seg: SegmentSpec,
                stats: ExecutionStats) -> Any:
        for k in range(seg.begin, seg.end):
            state = self.forward_op(state, k)
            stats.advances += 1
            stats.host_dispatches += 1
        return state

    def reverse(self, x_b: Any, adjoint: Any, seg: SegmentSpec,
                slots: _L1Slots, stats: ExecutionStats) -> Any:
        b, e = seg.begin, seg.end
        if seg.revolve is not None:  # Revolve inside the interval
            slots.store(b, x_b)
            adjoint = _exec_revolve(self.forward_op, self.backward_op,
                                    seg.revolve, slots, adjoint, stats)
            slots.free(b)
            return adjoint
        # Store-all replay: the whole segment fits in Level 1.
        states = {b: x_b}
        current = x_b
        for k in range(b + 1, e):
            current = self.forward_op(current, k - 1)
            stats.advances += 1
            stats.host_dispatches += 1
            states[k] = current
            slots.store(k, current)  # accounting only
        for k in range(e - 1, b - 1, -1):
            adjoint = self.backward_op(states[k], adjoint, k)
            stats.backwards += 1
            stats.host_dispatches += 1
            slots.free(k)
        return adjoint


class ParamStream:
    """Streams large per-step parameter blobs (MoE expert weights) through
    Level 2 alongside boundary states — the generic "offloadable resource"
    realisation of the paper's overlap discipline, applied to parameters
    (vDNN-style weight offload under the multistage schedule).

    ``leaves_by_id`` maps a chain-input leaf id (its ``tree_flatten``
    position) to a host array of shape ``(n, n_experts, ...)`` — one blob
    per (step, expert).  Blobs live in the engine's backend under
    :func:`~repro.core.schedule.expert_key` keys and share the backend's
    capacity budget with boundary states through merged
    :class:`~repro.core.schedule.ResourceAccessPlan` orders.

    Determinism contract (what makes the perfmodel's fast-tier peak
    *exact*): :meth:`populate` writes every blob synchronously on the
    caller's thread in :meth:`population_order`; after that the only fast
    tier writers are the engine's single FIFO store thread (boundary
    states) — all streamed reads go through non-promoting ``peek`` — so
    the backend's put sequence, and hence its Belady eviction trace and
    ``fast_peak_bytes``, is replayable by
    ``perfmodel.fast_peak_bytes_resources``.

    ``expert_counts`` (optional ``(n, n_experts)`` routing statistics from
    ``models.moe.routing_stats``) orders experts busiest-first within each
    step, so the lightest-loaded experts spill first under eviction.
    """

    def __init__(self, engine: AsyncTransferEngine, leaves_by_id: Dict[int, Any],
                 n_experts: int, expert_counts: Any = None, lead: int = 1):
        self.engine = engine
        self.leaves_by_id = {int(k): np.asarray(v)
                             for k, v in leaves_by_id.items()}
        if not self.leaves_by_id:
            raise ValueError("ParamStream needs at least one streamed leaf")
        self.leaf_ids = tuple(sorted(self.leaves_by_id))
        self.n_experts = int(n_experts)
        self.expert_counts = None if expert_counts is None \
            else np.asarray(expert_counts)
        self.lead = max(1, int(lead))
        self.plan: Optional[SegmentPlan] = None
        self.state_bytes = 0   # boundary-state size, recorded by the forward
        self.blob_bytes = {li: int(arr[0, 0].nbytes)
                           for li, arr in self.leaves_by_id.items()}
        self.step_param_bytes = sum(
            int(arr[0].nbytes) for arr in self.leaves_by_id.values())

    # -- plan production ------------------------------------------------------
    def bind(self, plan: SegmentPlan) -> None:
        self.plan = plan

    def access_plan(self, phase: str) -> "ms.ResourceAccessPlan":
        """This stream's slice of the generic resource IR for one phase."""
        assert self.plan is not None, "bind(plan) first"
        return ms.expert_access_plan(self.plan, self.leaf_ids, self.n_experts,
                                     self.expert_counts, phase=phase,
                                     blob_bytes=self.blob_bytes)

    def _expert_order(self, step: int) -> list:
        order = list(range(self.n_experts))
        if self.expert_counts is not None:
            row = self.expert_counts[step]
            order.sort(key=lambda e: (-int(row[e]), e))
        return order

    def segment_keys(self, seg: SegmentSpec, phase: str = "reverse") -> list:
        """One segment's blob keys in the given phase's consumption order
        (steps reversed for the reverse phase; experts busiest-first within
        a step — identical ordering to :func:`expert_access_plan`)."""
        steps = range(seg.begin, seg.end)
        if phase == "reverse":
            steps = reversed(list(steps))
        out = []
        for k in steps:
            for e in self._expert_order(k):
                for li in self.leaf_ids:
                    out.append(ms.expert_key(li, k, e))
        return out

    def population_order(self) -> tuple:
        """Canonical Level-2 write order of :meth:`populate` (each unique
        key once, soonest forward use first).  The perfmodel's exact-peak
        replay (``fast_peak_bytes_resources``) consumes the same order."""
        return self.access_plan("forward").keys()

    # -- Level-2 verbs --------------------------------------------------------
    def populate(self) -> None:
        """Synchronously write every blob to Level 2 (main thread, canonical
        order) so the forward sweep streams them back instead of holding the
        full expert stack live."""
        backend = self.engine.backend
        for key in self.population_order():
            _, li, step, e = key
            backend.put(key, self.leaves_by_id[li][step, e])

    def prefetch_segment(self, seg: SegmentSpec,
                         phase: str = "reverse") -> None:
        self.engine.prefetch_params_async(self.segment_keys(seg, phase))

    def gather(self, leaf_id: int, seg: SegmentSpec) -> np.ndarray:
        """Assemble one leaf's ``(seg_len, n_experts, ...)`` slice from
        streamed blobs (consuming the staged prefetches)."""
        wait = self.engine.wait_param
        rows = []
        for step in range(seg.begin, seg.end):
            rows.append(np.stack([
                wait(ms.expert_key(leaf_id, step, e))
                for e in range(self.n_experts)]))
        return np.stack(rows)

    def delete_segment(self, seg: SegmentSpec) -> None:
        """Retire a reversed segment's blobs (their last use is done)."""
        for key in self.segment_keys(seg, phase="reverse"):
            self.engine.delete(key)

    def purge(self) -> None:
        """Best-effort removal of every streamed blob (run teardown)."""
        if self.plan is None:
            return
        for key in self.population_order():
            try:
                self.engine.delete(key)
            except Exception:
                pass


@dataclass
class MultistageRun:
    """In-flight state of a split forward/reverse multistage execution.

    Produced by :meth:`CheckpointExecutor.multistage_forward`; consumed by
    :meth:`CheckpointExecutor.multistage_reverse`.  Holds the engine with the
    (possibly still in-flight) Level-2 boundary stores, so the reverse sweep
    can start from Level 2 alone — no Level-1 state survives between phases.

    ``plan`` is the :class:`~repro.core.schedule.SegmentPlan` IR both phases
    drive; ``runner`` is the segment runner chosen at forward time (``None``
    means the reversing executor builds an interpreted runner from its own
    operators).
    """

    n: int
    interval: int
    s_l1: int
    engine: AsyncTransferEngine
    stats: ExecutionStats
    slots: "_L1Slots"
    plan: SegmentPlan
    runner: Any = None
    own_engine: bool = True
    closed: bool = False
    resume: Optional[RecoveredRun] = None   # set when this run is a resume
    param_stream: Optional[ParamStream] = None  # streamed-resource lane

    def close(self) -> None:
        """Release this run's Level-2 state (idempotent).

        Boundary keys created by this run are purged from the backend
        (they are useless once the run is abandoned or finished) — except
        when the backend is journaled: there the boundaries ARE the crash
        recovery state, and purging them on an error path would destroy
        exactly what ``resume_from=`` needs; a journaled run's keys are
        retired by the reverse sweep's ordered deletes (or superseded by
        the next ``begin_run``).  The engine is only closed when this run
        owns it.  ``engine.close()`` re-raises pending transfer errors —
        callers cleaning up after another exception should swallow those
        (see the executor's error paths).
        """
        if self.closed:
            return
        self.closed = True
        journaled = _journal_backend(self.engine) is not None
        try:
            if not journaled:
                for seg in self.plan.segments:
                    try:
                        self.engine.delete(seg.begin)
                    except Exception:
                        pass
            if self.param_stream is not None:
                self.param_stream.purge()
        finally:
            if self.own_engine:
                try:
                    self.engine.close()
                finally:
                    bclose = getattr(self.engine.backend, "close", None)
                    if bclose is not None:
                        try:
                            bclose()
                        except Exception:
                            pass


class CheckpointExecutor:
    def __init__(self, forward_op: Optional[ForwardOp] = None,
                 backward_op: Optional[BackwardOp] = None):
        self.forward_op = forward_op
        self.backward_op = backward_op

    # ------------------------------------------------------------------ utils
    def _advance(self, state: Any, b: int, e: int, stats: ExecutionStats) -> Any:
        for k in range(b, e):
            state = self.forward_op(state, k)
            stats.advances += 1
            stats.host_dispatches += 1
        return state

    # ------------------------------------------------------------ strategies
    def run_conventional(self, state0: Any, n: int, adjoint0: Any,
                         final_hook: Optional[Callable[[Any], Any]] = None):
        """Store-everything baseline.  Returns (adjoint, stats)."""
        stats = ExecutionStats(n=n)
        slots = _L1Slots(stats)
        t0 = time.perf_counter()
        state = state0
        for k in range(n):
            slots.store(k, state)
            state = self.forward_op(state, k)
            stats.advances += 1
            stats.host_dispatches += 1
        if final_hook is not None:
            adjoint0 = final_hook(state)
        adjoint = adjoint0
        for k in range(n - 1, -1, -1):
            adjoint = self.backward_op(slots.restore(k), adjoint, k)
            stats.backwards += 1
            stats.host_dispatches += 1
            slots.free(k)
        stats.wall_s = time.perf_counter() - t0
        return adjoint, stats

    def run_revolve(self, state0: Any, n: int, adjoint0: Any, s: int,
                    final_hook: Optional[Callable[[Any], Any]] = None):
        """Classic Revolve with ``s`` Level-1 slots.  Returns (adjoint, stats).

        ``final_hook(x_n)`` (if given) observes the final state — e.g. compute
        the loss and seed the adjoint — after the initial forward sweep.
        """
        stats = ExecutionStats(n=n)
        slots = _L1Slots(stats)
        t0 = time.perf_counter()
        slots.store(0, state0)
        if final_hook is not None:
            # Initial sweep to the end to seed the adjoint; Revolve's own
            # replays then start from stored snapshots.
            xn = self._advance(state0, 0, n, stats)
            adjoint0 = final_hook(xn)
        sched = rv.revolve_schedule(n, s)
        adjoint = _exec_revolve(self.forward_op, self.backward_op, sched,
                                slots, adjoint0, stats)
        stats.wall_s = time.perf_counter() - t0
        return adjoint, stats

    def multistage_forward(self, state0: Any, n: int, *, interval: int,
                           s_l1: int,
                           engine: Optional[AsyncTransferEngine] = None,
                           runner: Any = None,
                           resume_from: Optional[RecoveredRun] = None,
                           run_meta: Optional[Dict[str, Any]] = None,
                           inner: Any = None,
                           param_stream: Optional[ParamStream] = None,
                           ) -> "tuple[Any, MultistageRun]":
        """Phase 1 of the split multistage API: advance the chain to ``x_n``
        while the engine asynchronously streams every ``interval``-th state to
        Level 2.  Returns ``(x_n, run)``; hand ``run`` to
        :meth:`multistage_reverse` (or call ``run.close()`` to abandon it).

        ``runner`` selects the segment execution backend — ``None`` builds an
        :class:`InterpretedSegmentRunner` over this executor's operators; pass
        a :class:`~repro.core.compiled_ops.CompiledSegmentRunner` for one
        compiled call per segment.

        With a journaled backend (``make_backend(..., journal=...)``) the
        forward pass is crash-consistent: a ``RunCursor`` rides the writer
        queue after each segment (FIFO => a durable cursor implies durable
        boundaries), and ``x_n`` is journaled under ``FINAL_STATE_KEY``.
        ``resume_from=`` (a :class:`~repro.core.journal.RecoveredRun` from
        ``backend.recover()``) restarts a crashed run: a forward-phase
        crash replays from the largest durable boundary — at most one
        interval of re-executed steps, counted in
        ``ExecutionStats.replayed_advances`` — and a reverse-phase crash
        skips the forward entirely (``x_n`` comes back from the journal;
        :meth:`multistage_reverse` then restarts mid-sweep from the
        cursor's adjoint).

        The split exists so a differentiable front-end (``repro.api``) can run
        the forward pass when autodiff requests the primal and the reverse
        sweep later, when the cotangent arrives — with the Level-2 stores
        still in flight in between.
        """
        own_engine = engine is None
        if engine is None:
            engine = AsyncTransferEngine(RAMStorage())
        stats = ExecutionStats(n=n)
        slots = _L1Slots(stats)
        plan = ms.segment_plan(n, interval, s_l1, inner=inner)
        if inner is not None:
            stats.inner_layer_chunks = inner.layer_chunks
            stats.inner_head_chunks = inner.head_chunks
            stats.inner_layers = inner.n_layers
        jb = _journal_backend(engine)
        run = MultistageRun(n=n, interval=interval, s_l1=s_l1, engine=engine,
                            stats=stats, slots=slots, plan=plan,
                            runner=runner, own_engine=own_engine,
                            param_stream=param_stream)
        fwd_runner = runner if runner is not None else \
            InterpretedSegmentRunner(self.forward_op, self.backward_op)
        # Plan-aware Level 2: hand a capacity-bounded (tiered) backend the
        # plan's reverse access order so its eviction victim is always the
        # boundary needed farthest in the future (Belady's rule).  With a
        # parameter stream the order is the merged resource IR instead:
        # expert blobs rank by their forward consumption, boundary states
        # (only read back in the reverse phase) shift past all of them.
        set_plan = getattr(engine.backend, "set_plan", None)
        if param_stream is not None:
            try:
                param_stream.bind(plan)
                param_stream.state_bytes = tree_bytes(state0)
                if set_plan is not None:
                    set_plan(ms.merge_access_plans(
                        param_stream.access_plan("forward"),
                        plan.resource_access_plan(param_stream.state_bytes)
                            .shift(len(plan.segments))))
                # Boundary prefetches must not perturb plan-driven fast-tier
                # residency either: read via non-promoting peek.
                engine.prefetch_via_peek = True
                param_stream.populate()
            except BaseException:
                try:
                    run.close()
                except Exception:
                    pass
                raise
        elif set_plan is not None:
            set_plan(plan)
        cursor0 = None
        if resume_from is not None:
            if jb is None:
                raise ValueError(
                    "resume_from= requires a journaled Level-2 backend "
                    "(make_backend(..., journal=directory))")
            cursor0 = resume_from.cursor
            if cursor0 is not None and cursor0.phase == "done":
                # previous run completed cleanly: nothing to resume
                cursor0, resume_from = None, None
            if cursor0 is not None and not cursor0.matches(plan):
                raise StorageFault(
                    f"journal cursor is for {cursor0.plan_id}, cannot "
                    f"resume it under {plan.plan_id}")
        t0 = time.perf_counter()
        try:
            if cursor0 is not None and cursor0.phase == "reverse":
                # Forward completed before the crash: everything the sweep
                # needs is durable.  Validate, re-hydrate x_n, and let
                # multistage_reverse restart mid-sweep from the cursor.
                needed = [seg.begin for seg in
                          plan.segments[:cursor0.segment_index + 1]]
                missing = [b for b in needed if b not in engine.backend]
                if missing or FINAL_STATE_KEY not in engine.backend:
                    raise StorageFault(
                        f"cannot resume reverse sweep: journal is missing "
                        f"boundaries {missing or [FINAL_STATE_KEY]}")
                # exact raw record, not a lossy-codec round-trip: x_n
                # seeds the (recomputed) loss/readout and must match the
                # crashed run's in-memory state bit for bit
                current = _exact_get(engine.backend, FINAL_STATE_KEY)
                run.resume = resume_from
                stats.l2_stores = engine.num_stores
                stats.wall_s += time.perf_counter() - t0
                return current, run
            durable = set()
            start_idx = 0
            current = state0
            if resume_from is not None:
                run.resume = resume_from
                durable = {k for k in resume_from.keys
                           if isinstance(k, (int, np.integer))}
                # restart boundary: end of the *contiguous* durable prefix
                # (everything below it must be fetchable in the reverse)
                prefix_end = -1
                for seg in plan.segments:
                    if seg.begin in durable:
                        prefix_end = seg.sid
                    else:
                        break
                if prefix_end >= 0:
                    start_idx = prefix_end
                    b_star = plan.segments[start_idx].begin
                    # the crashed run advanced from the *exact* running
                    # state at b_star (lossy encodings only affect what
                    # the reverse sweep reads back), so a bit-identical
                    # replay must start from the raw journal record
                    current = _exact_get(engine.backend, b_star)
                    if cursor0 is not None:
                        # steps the pre-crash run provably completed and we
                        # now re-execute: last durable boundary up to the
                        # cursor's attested position — at most one interval
                        stats.replayed_advances = max(
                            0, plan.cursor_position(cursor0) - b_star)
            elif jb is not None:
                # run_meta rides the BEGIN record (e.g. the front-end's
                # input fingerprint, checked before a later resume)
                jb.begin_run({"plan_id": plan.plan_id, "n": n,
                              "interval": interval, "s_l1": s_l1,
                              **(run_meta or {})})
            # Fused runners (pallas) produce the segment-entry boundary *from
            # the kernel* — the DMA copy overlapped the segment's compute —
            # so the store is enqueued after the advance with the kernel's
            # boundary instead of snapshotting `current` before it.  The
            # writer-queue FIFO still orders the store before the segment's
            # cursor, so journal durability semantics are unchanged.
            aws = getattr(fwd_runner, "advance_with_store", None)
            if param_stream is not None:
                # Warm the param lane: the first `lead` segments' expert
                # blobs start moving before any compute does.
                for pseg in plan.segments[start_idx:start_idx
                                          + param_stream.lead]:
                    param_stream.prefetch_segment(pseg, phase="forward")
            for seg in plan.segments[start_idx:]:
                if param_stream is not None:
                    # Rolling lead: segment k+lead's blobs fetch behind
                    # segment k's compute (the paper's overlap discipline,
                    # applied to parameters).
                    nxt = seg.sid + param_stream.lead
                    if nxt < len(plan.segments):
                        param_stream.prefetch_segment(plan.segments[nxt],
                                                      phase="forward")
                if seg.begin in durable:
                    current = fwd_runner.advance(current, seg, stats)
                elif aws is not None:
                    current, boundary = aws(current, seg, stats)
                    engine.store_async(seg.begin, boundary)
                else:
                    engine.store_async(seg.begin, current)
                    current = fwd_runner.advance(current, seg, stats)
                slots.note_extra(tree_bytes(current))
                if jb is not None:
                    engine.cursor_async(plan.cursor("forward", seg.sid + 1))
            if jb is not None:
                engine.store_async(FINAL_STATE_KEY, current)
        except BaseException:
            try:  # don't leak the writer thread / Level-2 states; don't
                run.close()  # let cleanup errors mask the original one
            except Exception:
                pass
            raise
        stats.l2_stores = engine.num_stores
        stats.wall_s += time.perf_counter() - t0
        return current, run

    def multistage_reverse(self, run: "MultistageRun", adjoint0: Any, *,
                           resume_from: Optional[RecoveredRun] = None,
                           artifact_fn: Optional[Callable[
                               [SegmentSpec], Any]] = None,
                           restore_artifact_fn: Optional[Callable[
                               [int, Any], None]] = None):
        """Phase 2: join outstanding stores, then reverse the chain segment by
        segment with prefetched Level-2 boundaries and per-segment work
        delegated to the run's segment runner.  Returns ``(adjoint, stats)``
        and closes the engine if this run owns it.

        The prefetch lead defaults to 1 segment (double-buffering, the
        paper's schedule).  A capacity-bounded (tiered) backend can ask for
        more via ``plan_prefetch_distance``: boundaries evicted to the slow
        tier are then promoted back ``d`` segments ahead of need, so the
        slow fetch overlaps earlier segments' reverse work.

        With a journaled backend the sweep is crash-consistent: after each
        segment a ``RunCursor`` carrying the host-snapshot adjoint (plus
        the runner's per-segment artifact from ``artifact_fn``, e.g.
        per-step input cotangents) is enqueued *before* the boundary's
        delete — writer-queue FIFO keeps the journal's cursor/delete order
        honest.  ``resume_from=`` (or a resume recorded on the run by
        :meth:`multistage_forward`) restarts mid-sweep: already-reversed
        segments are never re-run (their contribution lives in the
        cursor's adjoint; their artifacts are replayed through
        ``restore_artifact_fn``), so the resume cost is bounded by one
        segment regardless of chain length.
        """
        engine, stats, slots = run.engine, run.stats, run.slots
        runner = run.runner if run.runner is not None else \
            InterpretedSegmentRunner(self.forward_op, self.backward_op)
        segs = run.plan.segments
        jb = _journal_backend(engine)
        rec = resume_from if resume_from is not None else run.resume
        t0 = time.perf_counter()
        ps = run.param_stream
        try:
            adjoint = adjoint0
            engine.wait_stores()
            if ps is not None:
                # The forward's store sequence is fully drained (writer
                # FIFO), so swap in the reverse phase's merged access order:
                # boundary states and expert blobs interleave by reverse
                # segment rank under one Belady order.
                set_plan = getattr(engine.backend, "set_plan", None)
                if set_plan is not None:
                    set_plan(ms.merge_access_plans(
                        run.plan.resource_access_plan(ps.state_bytes),
                        ps.access_plan("reverse")))
            j_start = len(segs) - 1
            cursor = rec.cursor if rec is not None else None
            if cursor is not None and cursor.phase == "reverse":
                # restart mid-sweep: the cursor's adjoint already folds in
                # every segment above segment_index
                j_start = cursor.segment_index
                payload = cursor.payload or {}
                adjoint = payload.get("adjoint", adjoint0)
                if restore_artifact_fn is not None:
                    for b, art in rec.artifacts.items():
                        restore_artifact_fn(b, art)
            elif jb is not None:
                # durable mark: the sweep has begun with this seed adjoint
                # (a crash before the first segment completes resumes here)
                # adjoint trees ride to the writer thread as-is (immutable
                # jax arrays); the engine host-converts them there, off
                # the reverse sweep's critical path
                engine.cursor_async(run.plan.cursor(
                    "reverse", j_start,
                    payload={"adjoint": adjoint}))
            # Prefetch lead: 1 (double-buffer) unless the backend derives a
            # larger plan-aware distance (sizes are known now — the stores
            # above have all landed).
            depth = 1
            hint = getattr(engine.backend, "plan_prefetch_distance", None)
            if hint is not None:
                depth = max(1, int(hint(run.plan)))
            stats.prefetch_depth = depth
            # Warm the pipeline with the last `depth` boundaries; then keep
            # `depth` segments of lead while walking backwards.
            for idx in range(j_start, max(j_start - depth, -1), -1):
                engine.prefetch_async(segs[idx].begin)
            if ps is not None:
                for idx in range(j_start, max(j_start - ps.lead, -1), -1):
                    ps.prefetch_segment(segs[idx], phase="reverse")
            for j in range(j_start, -1, -1):
                seg = segs[j]
                if j - depth >= 0:
                    engine.prefetch_async(segs[j - depth].begin)
                if ps is not None and j - ps.lead >= 0:
                    ps.prefetch_segment(segs[j - ps.lead], phase="reverse")
                x_b = engine.wait_prefetch(seg.begin)
                slots.note_extra(tree_bytes(x_b))
                adjoint = runner.reverse(x_b, adjoint, seg, slots, stats)
                if ps is not None:
                    ps.delete_segment(seg)   # last use of these blobs
                if jb is not None:
                    artifact = artifact_fn(seg) if artifact_fn is not None \
                        else None
                    engine.cursor_async(run.plan.cursor(
                        "reverse", j - 1,
                        payload={"adjoint": adjoint,
                                 "artifact": artifact,
                                 "artifact_key": seg.begin}))
                    engine.delete_async(seg.begin)
                else:
                    engine.delete(seg.begin)
            if jb is not None:
                # done-cursor strictly BEFORE the final-state delete: a
                # crash between them recovers as phase=="done" (clean
                # fresh run); the reverse order would leave a journal
                # whose reverse cursor needs a FINAL_STATE_KEY that is
                # already gone — permanently unresumable
                engine.cursor_async(run.plan.cursor("done", -1))
                engine.delete_async(FINAL_STATE_KEY)
                engine.wait_stores()
                jb.end_run()
            stats.l2_stores = engine.num_stores
            stats.l2_prefetches = engine.num_prefetches
            backend = engine.backend
            stats.l2_peak_bytes = getattr(backend, "peak_bytes", 0)
            stats.l2_fast_peak_bytes = getattr(backend, "fast_peak_bytes", 0)
            stats.l2_evictions = getattr(backend, "evictions", 0)
            stats.l2_promotions = getattr(backend, "promotions", 0)
            # sharded fan-out: stream count + per-stream traffic (delegated
            # through journal/compressed wrappers by their __getattr__)
            stats.l2_shard_streams = int(getattr(backend, "shard_streams", 0))
            sbw = getattr(backend, "stream_bytes_written", None)
            if callable(sbw):
                stats.l2_stream_bytes = tuple(int(b) for b in sbw())
            stats.l2_staged_peak_bytes = engine.staged_peak_bytes
            stats.store_stall_s = engine.store_stall_s
            stats.prefetch_stall_s = engine.prefetch_stall_s
            stats.param_prefetches = engine.num_param_prefetches
            stats.param_fetch_stalls = engine.param_fetch_stalls
            stats.param_bytes_moved = engine.param_bytes_moved
        except BaseException:
            try:
                run.close()
            except Exception:
                pass
            raise
        run.close()
        stats.wall_s += time.perf_counter() - t0
        return adjoint, stats

    def run_multistage(self, state0: Any, n: int, adjoint0: Any, *,
                       interval: int, s_l1: int,
                       engine: Optional[AsyncTransferEngine] = None,
                       runner: Any = None,
                       final_hook: Optional[Callable[[Any], Any]] = None):
        """The paper's asynchronous multistage strategy (single-shot form:
        forward phase, optional loss/adjoint seeding hook on ``x_n``, reverse
        phase).  Returns (adjoint, stats).  ``engine`` defaults to an async
        engine over host-RAM Level-2 storage.
        """
        x_n, run = self.multistage_forward(state0, n, interval=interval,
                                           s_l1=s_l1, engine=engine,
                                           runner=runner)
        if final_hook is not None:
            try:
                adjoint0 = final_hook(x_n)
            except BaseException:
                try:
                    run.close()
                except Exception:
                    pass
                raise
        return self.multistage_reverse(run, adjoint0)
