"""Checkpoint execution engine (the paper's §4, generalised) — the *execute*
stage of the plan -> compile -> execute pipeline.

The executor drives a *forward operator* and a *backward operator* through a
checkpointing schedule, exactly like pyrevolve: the user supplies the two
operators plus an initial state, and the executor owns when states are
computed, snapshotted, offloaded, prefetched and freed.

Operator contract (functional — JAX-friendly)::

    state_{k+1} = forward_op(state_k, k)            # k in [0, n)
    adjoint     = backward_op(state_k, adjoint, k)  # reverse of step k,
                                                    # consumes x_k

``backward_op`` receives the *input* state of step ``k`` (it re-runs the step
forward internally, e.g. via ``jax.vjp``) and threads an arbitrary adjoint
pytree (commonly ``(dL/dstate, accumulated param grads)``).

Three strategies:

* ``run_conventional`` — store every state (the naive baseline; peak Level-1
  memory grows linearly in ``n``).
* ``run_revolve``      — classic single-stage Revolve with ``s`` Level-1
  slots (recompute factor grows ~log n).
* ``run_multistage``   — the paper's contribution: asynchronous Level-2
  stores every ``interval`` steps + prefetch during the reverse sweep;
  Revolve only *inside* intervals (recompute factor constant in ``n``).

The multistage strategy is a thin driver over the
:class:`~repro.core.schedule.SegmentPlan` IR: it interleaves
``AsyncTransferEngine`` store/prefetch events with per-segment work delegated
to a pluggable **segment runner**:

* :class:`InterpretedSegmentRunner` (default) — walks the segment step by
  step through ``forward_op``/``backward_op`` (O(n) host dispatches; the
  paper-faithful interpreter, exact Revolve-optimal advance counts);
* :class:`~repro.core.compiled_ops.CompiledSegmentRunner` — one jitted call
  per segment (O(n/I) host dispatches; the fast path the API front-end uses).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

from repro.core import revolve as rv
from repro.core import schedule as ms
from repro.core.revolve import Op
from repro.core.schedule import SegmentPlan, SegmentSpec
from repro.core.storage import AsyncTransferEngine, RAMStorage, tree_bytes

ForwardOp = Callable[[Any, int], Any]
BackwardOp = Callable[[Any, Any, int], Any]


@dataclass
class ExecutionStats:
    n: int = 0
    advances: int = 0
    backwards: int = 0
    host_dispatches: int = 0     # Python-level op/segment invocations
    peak_l1_states: int = 0
    peak_l1_bytes: int = 0
    l2_stores: int = 0
    l2_prefetches: int = 0
    l2_peak_bytes: int = 0       # high-water Level-2 (host) footprint
    l2_fast_peak_bytes: int = 0  # tiered backend: fast-tier high-water mark
    l2_evictions: int = 0        # tiered backend: fast -> slow spills
    l2_promotions: int = 0       # tiered backend: slow -> fast promotions
    l2_staged_peak_bytes: int = 0  # engine prefetch staging high-water mark
    prefetch_depth: int = 1      # segments of prefetch lead in the reverse
    store_stall_s: float = 0.0
    prefetch_stall_s: float = 0.0
    wall_s: float = 0.0

    @property
    def recompute_factor(self) -> float:
        return self.advances / max(1, self.n - 1)


class _L1Slots:
    """Level-1 snapshot slots with live-byte accounting."""

    def __init__(self, stats: ExecutionStats):
        self._slots: Dict[int, Any] = {}
        self._stats = stats
        self._extra_bytes = 0  # running state + staged prefetch

    def _update_peak(self) -> None:
        n_states = len(self._slots)
        self._stats.peak_l1_states = max(self._stats.peak_l1_states, n_states)
        total = sum(tree_bytes(v) for v in self._slots.values())
        self._stats.peak_l1_bytes = max(
            self._stats.peak_l1_bytes, total + self._extra_bytes
        )

    def note_extra(self, nbytes: int) -> None:
        self._extra_bytes = nbytes
        self._update_peak()

    def store(self, idx: int, state: Any) -> None:
        self._slots[idx] = state
        self._update_peak()

    def restore(self, idx: int) -> Any:
        return self._slots[idx]

    def free(self, idx: int) -> None:
        self._slots.pop(idx, None)

    def __contains__(self, idx: int) -> bool:
        return idx in self._slots

    def __len__(self) -> int:
        return len(self._slots)


def _exec_revolve(forward_op: ForwardOp, backward_op: BackwardOp, sched,
                  slots: _L1Slots, adjoint: Any,
                  stats: ExecutionStats) -> Any:
    """Interpret a Revolve action stream (used for the single-stage strategy
    and for Revolve-inside-an-interval sub-plans)."""
    current: Any = None
    current_idx = -1
    for a in sched:
        if a.op is Op.RESTORE:
            current = slots.restore(a.index)
            current_idx = a.index
        elif a.op is Op.ADVANCE:
            assert current_idx == a.index, (current_idx, a)
            for k in range(a.index, a.end):
                current = forward_op(current, k)
                stats.advances += 1
                stats.host_dispatches += 1
            current_idx = a.end
        elif a.op is Op.STORE:
            assert current_idx == a.index, (current_idx, a)
            slots.store(a.index, current)
        elif a.op is Op.FREE:
            slots.free(a.index)
        elif a.op is Op.BACKWARD:
            assert current_idx == a.index, (current_idx, a)
            adjoint = backward_op(current, adjoint, a.index)
            stats.backwards += 1
            stats.host_dispatches += 1
    return adjoint


class InterpretedSegmentRunner:
    """Step-granular segment runner: the paper-faithful Python interpreter.

    One ``forward_op``/``backward_op`` dispatch per chain step; reversal uses
    the segment's Revolve sub-plan when it does not fit in Level 1, store-all
    replay otherwise.  Advance counts are exactly Revolve-optimal (asserted
    in tests); host dispatch count is O(n).
    """

    def __init__(self, forward_op: ForwardOp,
                 backward_op: Optional[BackwardOp]):
        self.forward_op = forward_op
        self.backward_op = backward_op

    def advance(self, state: Any, seg: SegmentSpec,
                stats: ExecutionStats) -> Any:
        for k in range(seg.begin, seg.end):
            state = self.forward_op(state, k)
            stats.advances += 1
            stats.host_dispatches += 1
        return state

    def reverse(self, x_b: Any, adjoint: Any, seg: SegmentSpec,
                slots: _L1Slots, stats: ExecutionStats) -> Any:
        b, e = seg.begin, seg.end
        if seg.revolve is not None:  # Revolve inside the interval
            slots.store(b, x_b)
            adjoint = _exec_revolve(self.forward_op, self.backward_op,
                                    seg.revolve, slots, adjoint, stats)
            slots.free(b)
            return adjoint
        # Store-all replay: the whole segment fits in Level 1.
        states = {b: x_b}
        current = x_b
        for k in range(b + 1, e):
            current = self.forward_op(current, k - 1)
            stats.advances += 1
            stats.host_dispatches += 1
            states[k] = current
            slots.store(k, current)  # accounting only
        for k in range(e - 1, b - 1, -1):
            adjoint = self.backward_op(states[k], adjoint, k)
            stats.backwards += 1
            stats.host_dispatches += 1
            slots.free(k)
        return adjoint


@dataclass
class MultistageRun:
    """In-flight state of a split forward/reverse multistage execution.

    Produced by :meth:`CheckpointExecutor.multistage_forward`; consumed by
    :meth:`CheckpointExecutor.multistage_reverse`.  Holds the engine with the
    (possibly still in-flight) Level-2 boundary stores, so the reverse sweep
    can start from Level 2 alone — no Level-1 state survives between phases.

    ``plan`` is the :class:`~repro.core.schedule.SegmentPlan` IR both phases
    drive; ``runner`` is the segment runner chosen at forward time (``None``
    means the reversing executor builds an interpreted runner from its own
    operators).
    """

    n: int
    interval: int
    s_l1: int
    engine: AsyncTransferEngine
    stats: ExecutionStats
    slots: "_L1Slots"
    plan: SegmentPlan
    runner: Any = None
    own_engine: bool = True
    closed: bool = False

    def close(self) -> None:
        """Release this run's Level-2 state (idempotent).

        Boundary keys created by this run are always purged from the backend
        (they are useless once the run is abandoned or finished); the engine
        itself is only closed when this run owns it.  ``engine.close()``
        re-raises pending transfer errors — callers cleaning up after another
        exception should swallow those (see the executor's error paths).
        """
        if self.closed:
            return
        self.closed = True
        try:
            for seg in self.plan.segments:
                try:
                    self.engine.delete(seg.begin)
                except Exception:
                    pass
        finally:
            if self.own_engine:
                self.engine.close()


class CheckpointExecutor:
    def __init__(self, forward_op: Optional[ForwardOp] = None,
                 backward_op: Optional[BackwardOp] = None):
        self.forward_op = forward_op
        self.backward_op = backward_op

    # ------------------------------------------------------------------ utils
    def _advance(self, state: Any, b: int, e: int, stats: ExecutionStats) -> Any:
        for k in range(b, e):
            state = self.forward_op(state, k)
            stats.advances += 1
            stats.host_dispatches += 1
        return state

    # ------------------------------------------------------------ strategies
    def run_conventional(self, state0: Any, n: int, adjoint0: Any,
                         final_hook: Optional[Callable[[Any], Any]] = None):
        """Store-everything baseline.  Returns (adjoint, stats)."""
        stats = ExecutionStats(n=n)
        slots = _L1Slots(stats)
        t0 = time.perf_counter()
        state = state0
        for k in range(n):
            slots.store(k, state)
            state = self.forward_op(state, k)
            stats.advances += 1
            stats.host_dispatches += 1
        if final_hook is not None:
            adjoint0 = final_hook(state)
        adjoint = adjoint0
        for k in range(n - 1, -1, -1):
            adjoint = self.backward_op(slots.restore(k), adjoint, k)
            stats.backwards += 1
            stats.host_dispatches += 1
            slots.free(k)
        stats.wall_s = time.perf_counter() - t0
        return adjoint, stats

    def run_revolve(self, state0: Any, n: int, adjoint0: Any, s: int,
                    final_hook: Optional[Callable[[Any], Any]] = None):
        """Classic Revolve with ``s`` Level-1 slots.  Returns (adjoint, stats).

        ``final_hook(x_n)`` (if given) observes the final state — e.g. compute
        the loss and seed the adjoint — after the initial forward sweep.
        """
        stats = ExecutionStats(n=n)
        slots = _L1Slots(stats)
        t0 = time.perf_counter()
        slots.store(0, state0)
        if final_hook is not None:
            # Initial sweep to the end to seed the adjoint; Revolve's own
            # replays then start from stored snapshots.
            xn = self._advance(state0, 0, n, stats)
            adjoint0 = final_hook(xn)
        sched = rv.revolve_schedule(n, s)
        adjoint = _exec_revolve(self.forward_op, self.backward_op, sched,
                                slots, adjoint0, stats)
        stats.wall_s = time.perf_counter() - t0
        return adjoint, stats

    def multistage_forward(self, state0: Any, n: int, *, interval: int,
                           s_l1: int,
                           engine: Optional[AsyncTransferEngine] = None,
                           runner: Any = None,
                           ) -> "tuple[Any, MultistageRun]":
        """Phase 1 of the split multistage API: advance the chain to ``x_n``
        while the engine asynchronously streams every ``interval``-th state to
        Level 2.  Returns ``(x_n, run)``; hand ``run`` to
        :meth:`multistage_reverse` (or call ``run.close()`` to abandon it).

        ``runner`` selects the segment execution backend — ``None`` builds an
        :class:`InterpretedSegmentRunner` over this executor's operators; pass
        a :class:`~repro.core.compiled_ops.CompiledSegmentRunner` for one
        compiled call per segment.

        The split exists so a differentiable front-end (``repro.api``) can run
        the forward pass when autodiff requests the primal and the reverse
        sweep later, when the cotangent arrives — with the Level-2 stores
        still in flight in between.
        """
        own_engine = engine is None
        if engine is None:
            engine = AsyncTransferEngine(RAMStorage())
        stats = ExecutionStats(n=n)
        slots = _L1Slots(stats)
        plan = ms.segment_plan(n, interval, s_l1)
        run = MultistageRun(n=n, interval=interval, s_l1=s_l1, engine=engine,
                            stats=stats, slots=slots, plan=plan,
                            runner=runner, own_engine=own_engine)
        fwd_runner = runner if runner is not None else \
            InterpretedSegmentRunner(self.forward_op, self.backward_op)
        # Plan-aware Level 2: hand a capacity-bounded (tiered) backend the
        # plan's reverse access order so its eviction victim is always the
        # boundary needed farthest in the future (Belady's rule).
        set_plan = getattr(engine.backend, "set_plan", None)
        if set_plan is not None:
            set_plan(plan)
        t0 = time.perf_counter()
        try:
            current = state0
            for seg in plan.segments:
                engine.store_async(seg.begin, current)
                current = fwd_runner.advance(current, seg, stats)
                slots.note_extra(tree_bytes(current))
        except BaseException:
            try:  # don't leak the writer thread / Level-2 states; don't
                run.close()  # let cleanup errors mask the original one
            except Exception:
                pass
            raise
        stats.l2_stores = engine.num_stores
        stats.wall_s += time.perf_counter() - t0
        return current, run

    def multistage_reverse(self, run: "MultistageRun", adjoint0: Any):
        """Phase 2: join outstanding stores, then reverse the chain segment by
        segment with prefetched Level-2 boundaries and per-segment work
        delegated to the run's segment runner.  Returns ``(adjoint, stats)``
        and closes the engine if this run owns it.

        The prefetch lead defaults to 1 segment (double-buffering, the
        paper's schedule).  A capacity-bounded (tiered) backend can ask for
        more via ``plan_prefetch_distance``: boundaries evicted to the slow
        tier are then promoted back ``d`` segments ahead of need, so the
        slow fetch overlaps earlier segments' reverse work.
        """
        engine, stats, slots = run.engine, run.stats, run.slots
        runner = run.runner if run.runner is not None else \
            InterpretedSegmentRunner(self.forward_op, self.backward_op)
        segs = run.plan.segments
        t0 = time.perf_counter()
        try:
            adjoint = adjoint0
            engine.wait_stores()
            # Prefetch lead: 1 (double-buffer) unless the backend derives a
            # larger plan-aware distance (sizes are known now — the stores
            # above have all landed).
            depth = 1
            hint = getattr(engine.backend, "plan_prefetch_distance", None)
            if hint is not None:
                depth = max(1, int(hint(run.plan)))
            stats.prefetch_depth = depth
            # Warm the pipeline with the last `depth` boundaries; then keep
            # `depth` segments of lead while walking backwards.
            for idx in range(len(segs) - 1,
                             max(len(segs) - 1 - depth, -1), -1):
                engine.prefetch_async(segs[idx].begin)
            for j in range(len(segs) - 1, -1, -1):
                seg = segs[j]
                if j - depth >= 0:
                    engine.prefetch_async(segs[j - depth].begin)
                x_b = engine.wait_prefetch(seg.begin)
                slots.note_extra(tree_bytes(x_b))
                adjoint = runner.reverse(x_b, adjoint, seg, slots, stats)
                engine.delete(seg.begin)
            stats.l2_stores = engine.num_stores
            stats.l2_prefetches = engine.num_prefetches
            backend = engine.backend
            stats.l2_peak_bytes = getattr(backend, "peak_bytes", 0)
            stats.l2_fast_peak_bytes = getattr(backend, "fast_peak_bytes", 0)
            stats.l2_evictions = getattr(backend, "evictions", 0)
            stats.l2_promotions = getattr(backend, "promotions", 0)
            stats.l2_staged_peak_bytes = engine.staged_peak_bytes
            stats.store_stall_s = engine.store_stall_s
            stats.prefetch_stall_s = engine.prefetch_stall_s
        except BaseException:
            try:
                run.close()
            except Exception:
                pass
            raise
        run.close()
        stats.wall_s += time.perf_counter() - t0
        return adjoint, stats

    def run_multistage(self, state0: Any, n: int, adjoint0: Any, *,
                       interval: int, s_l1: int,
                       engine: Optional[AsyncTransferEngine] = None,
                       runner: Any = None,
                       final_hook: Optional[Callable[[Any], Any]] = None):
        """The paper's asynchronous multistage strategy (single-shot form:
        forward phase, optional loss/adjoint seeding hook on ``x_n``, reverse
        phase).  Returns (adjoint, stats).  ``engine`` defaults to an async
        engine over host-RAM Level-2 storage.
        """
        x_n, run = self.multistage_forward(state0, n, interval=interval,
                                           s_l1=s_l1, engine=engine,
                                           runner=runner)
        if final_hook is not None:
            try:
                adjoint0 = final_hook(x_n)
            except BaseException:
                try:
                    run.close()
                except Exception:
                    pass
                raise
        return self.multistage_reverse(run, adjoint0)
