"""pyrevolve-style checkpoint executor (the paper's §4, generalised).

The executor drives a *forward operator* and a *backward operator* through a
checkpointing schedule, exactly like pyrevolve: the user supplies the two
operators plus an initial state, and the executor owns when states are
computed, snapshotted, offloaded, prefetched and freed.

Operator contract (functional — JAX-friendly)::

    state_{k+1} = forward_op(state_k, k)            # k in [0, n)
    adjoint     = backward_op(state_k, adjoint, k)  # reverse of step k,
                                                    # consumes x_k

``backward_op`` receives the *input* state of step ``k`` (it re-runs the step
forward internally, e.g. via ``jax.vjp``) and threads an arbitrary adjoint
pytree (commonly ``(dL/dstate, accumulated param grads)``).

Three strategies:

* ``run_conventional`` — store every state (the naive baseline; peak Level-1
  memory grows linearly in ``n``).
* ``run_revolve``      — classic single-stage Revolve with ``s`` Level-1
  slots (recompute factor grows ~log n).
* ``run_multistage``   — the paper's contribution: asynchronous Level-2
  stores every ``interval`` steps + prefetch during the reverse sweep;
  Revolve only *inside* intervals (recompute factor constant in ``n``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

from repro.core import revolve as rv
from repro.core import schedule as ms
from repro.core.revolve import Action, Op
from repro.core.schedule import MAction, MOp
from repro.core.storage import AsyncTransferEngine, RAMStorage, tree_bytes

ForwardOp = Callable[[Any, int], Any]
BackwardOp = Callable[[Any, Any, int], Any]


@dataclass
class ExecutionStats:
    n: int = 0
    advances: int = 0
    backwards: int = 0
    peak_l1_states: int = 0
    peak_l1_bytes: int = 0
    l2_stores: int = 0
    l2_prefetches: int = 0
    store_stall_s: float = 0.0
    prefetch_stall_s: float = 0.0
    wall_s: float = 0.0

    @property
    def recompute_factor(self) -> float:
        return self.advances / max(1, self.n - 1)


class _L1Slots:
    """Level-1 snapshot slots with live-byte accounting."""

    def __init__(self, stats: ExecutionStats):
        self._slots: Dict[int, Any] = {}
        self._stats = stats
        self._extra_bytes = 0  # running state + staged prefetch

    def _update_peak(self) -> None:
        n_states = len(self._slots)
        self._stats.peak_l1_states = max(self._stats.peak_l1_states, n_states)
        total = sum(tree_bytes(v) for v in self._slots.values())
        self._stats.peak_l1_bytes = max(
            self._stats.peak_l1_bytes, total + self._extra_bytes
        )

    def note_extra(self, nbytes: int) -> None:
        self._extra_bytes = nbytes
        self._update_peak()

    def store(self, idx: int, state: Any) -> None:
        self._slots[idx] = state
        self._update_peak()

    def restore(self, idx: int) -> Any:
        return self._slots[idx]

    def free(self, idx: int) -> None:
        self._slots.pop(idx, None)

    def __contains__(self, idx: int) -> bool:
        return idx in self._slots

    def __len__(self) -> int:
        return len(self._slots)


@dataclass
class MultistageRun:
    """In-flight state of a split forward/reverse multistage execution.

    Produced by :meth:`CheckpointExecutor.multistage_forward`; consumed by
    :meth:`CheckpointExecutor.multistage_reverse`.  Holds the engine with the
    (possibly still in-flight) Level-2 boundary stores, so the reverse sweep
    can start from Level 2 alone — no Level-1 state survives between phases.
    """

    n: int
    interval: int
    s_l1: int
    engine: AsyncTransferEngine
    stats: ExecutionStats
    slots: "_L1Slots"
    sched: ms.MultistageSchedule
    rev_actions: list = field(default_factory=list)
    own_engine: bool = True
    closed: bool = False

    def close(self) -> None:
        """Release the Level-2 engine (idempotent; no-op for borrowed
        engines)."""
        if not self.closed and self.own_engine:
            self.engine.close()
        self.closed = True


class CheckpointExecutor:
    def __init__(self, forward_op: ForwardOp, backward_op: BackwardOp):
        self.forward_op = forward_op
        self.backward_op = backward_op

    # ------------------------------------------------------------------ utils
    def _advance(self, state: Any, b: int, e: int, stats: ExecutionStats) -> Any:
        for k in range(b, e):
            state = self.forward_op(state, k)
            stats.advances += 1
        return state

    # ------------------------------------------------------------ strategies
    def run_conventional(self, state0: Any, n: int, adjoint0: Any,
                         final_hook: Optional[Callable[[Any], Any]] = None):
        """Store-everything baseline.  Returns (adjoint, stats)."""
        stats = ExecutionStats(n=n)
        slots = _L1Slots(stats)
        t0 = time.perf_counter()
        state = state0
        for k in range(n):
            slots.store(k, state)
            state = self.forward_op(state, k)
            stats.advances += 1
        if final_hook is not None:
            adjoint0 = final_hook(state)
        adjoint = adjoint0
        for k in range(n - 1, -1, -1):
            adjoint = self.backward_op(slots.restore(k), adjoint, k)
            stats.backwards += 1
            slots.free(k)
        stats.wall_s = time.perf_counter() - t0
        return adjoint, stats

    def run_revolve(self, state0: Any, n: int, adjoint0: Any, s: int,
                    final_hook: Optional[Callable[[Any], Any]] = None):
        """Classic Revolve with ``s`` Level-1 slots.  Returns (adjoint, stats).

        ``final_hook(x_n)`` (if given) observes the final state — e.g. compute
        the loss and seed the adjoint — after the initial forward sweep.
        """
        stats = ExecutionStats(n=n)
        slots = _L1Slots(stats)
        t0 = time.perf_counter()
        slots.store(0, state0)
        if final_hook is not None:
            # Initial sweep to the end to seed the adjoint; Revolve's own
            # replays then start from stored snapshots.
            xn = self._advance(state0, 0, n, stats)
            adjoint0 = final_hook(xn)
        sched = rv.revolve_schedule(n, s)
        adjoint = self._exec_revolve(sched, slots, adjoint0, stats)
        stats.wall_s = time.perf_counter() - t0
        return adjoint, stats

    def _exec_revolve(self, sched, slots: _L1Slots, adjoint: Any,
                      stats: ExecutionStats) -> Any:
        current: Any = None
        current_idx = -1
        for a in sched:
            if a.op is Op.RESTORE:
                current = slots.restore(a.index)
                current_idx = a.index
            elif a.op is Op.ADVANCE:
                assert current_idx == a.index, (current_idx, a)
                current = self._advance(current, a.index, a.end, stats)
                current_idx = a.end
            elif a.op is Op.STORE:
                assert current_idx == a.index, (current_idx, a)
                slots.store(a.index, current)
            elif a.op is Op.FREE:
                slots.free(a.index)
            elif a.op is Op.BACKWARD:
                assert current_idx == a.index, (current_idx, a)
                adjoint = self.backward_op(current, adjoint, a.index)
                stats.backwards += 1
        return adjoint

    def multistage_forward(self, state0: Any, n: int, *, interval: int,
                           s_l1: int,
                           engine: Optional[AsyncTransferEngine] = None,
                           ) -> "tuple[Any, MultistageRun]":
        """Phase 1 of the split multistage API: advance the chain to ``x_n``
        while the engine asynchronously streams every ``interval``-th state to
        Level 2.  Returns ``(x_n, run)``; hand ``run`` to
        :meth:`multistage_reverse` (or call ``run.close()`` to abandon it).

        The split exists so a differentiable front-end (``repro.api``) can run
        the forward pass when autodiff requests the primal and the reverse
        sweep later, when the cotangent arrives — with the Level-2 stores
        still in flight in between.
        """
        own_engine = engine is None
        if engine is None:
            engine = AsyncTransferEngine(RAMStorage())
        stats = ExecutionStats(n=n)
        slots = _L1Slots(stats)
        sched = ms.multistage_schedule(n, interval, s_l1)
        fwd_actions, rev_actions = self._split_schedule(sched)
        run = MultistageRun(n=n, interval=interval, s_l1=s_l1, engine=engine,
                            stats=stats, slots=slots, sched=sched,
                            rev_actions=rev_actions, own_engine=own_engine)
        t0 = time.perf_counter()
        try:
            current = state0
            current_idx = 0
            for a in fwd_actions:
                if a.op is MOp.STORE_L2:
                    assert current_idx == a.index, (current_idx, a)
                    engine.store_async(a.index, current)
                elif a.op is MOp.ADVANCE:
                    assert current_idx == a.index, (current_idx, a)
                    current = self._advance(current, a.index, a.end, stats)
                    current_idx = a.end
                    slots.note_extra(tree_bytes(current))
        except BaseException:
            run.close()  # don't leak the writer thread / Level-2 states
            raise
        stats.l2_stores = engine.num_stores
        stats.wall_s += time.perf_counter() - t0
        return current, run

    def multistage_reverse(self, run: "MultistageRun", adjoint0: Any):
        """Phase 2: join outstanding stores, then reverse the chain segment by
        segment with double-buffered Level-2 prefetch and Revolve inside each
        interval.  Returns ``(adjoint, stats)`` and closes the engine if this
        run owns it.
        """
        engine, stats, slots = run.engine, run.stats, run.slots
        t0 = time.perf_counter()
        try:
            current: Any = None
            current_idx = -1
            adjoint = adjoint0
            for a in run.rev_actions:
                if a.op is MOp.WAIT_STORES:
                    engine.wait_stores()
                elif a.op is MOp.PREFETCH_L2:
                    engine.prefetch_async(a.index)
                elif a.op is MOp.WAIT_PREFETCH:
                    current = engine.wait_prefetch(a.index)
                    current_idx = a.index
                    slots.note_extra(tree_bytes(current))
                elif a.op is MOp.FREE_L2:
                    engine.delete(a.index)
                elif a.op is MOp.REVERSE_SEGMENT:
                    assert current_idx == a.index, (current_idx, a)
                    adjoint = self._reverse_segment(
                        a.index, a.end, current, adjoint, run.sched, slots,
                        stats
                    )
                    current_idx = -1  # consumed
            stats.l2_stores = engine.num_stores
            stats.l2_prefetches = engine.num_prefetches
            stats.store_stall_s = engine.store_stall_s
            stats.prefetch_stall_s = engine.prefetch_stall_s
        finally:
            run.close()
        stats.wall_s += time.perf_counter() - t0
        return adjoint, stats

    @staticmethod
    def _split_schedule(sched: ms.MultistageSchedule):
        """Partition the flat action stream at the forward/reverse boundary
        (the WAIT_STORES barrier emitted by ``multistage_schedule``)."""
        for i, a in enumerate(sched.actions):
            if a.op is MOp.WAIT_STORES:
                return sched.actions[:i], sched.actions[i:]
        return list(sched.actions), []

    def run_multistage(self, state0: Any, n: int, adjoint0: Any, *,
                       interval: int, s_l1: int,
                       engine: Optional[AsyncTransferEngine] = None,
                       final_hook: Optional[Callable[[Any], Any]] = None):
        """The paper's asynchronous multistage strategy (single-shot form:
        forward phase, optional loss/adjoint seeding hook on ``x_n``, reverse
        phase).  Returns (adjoint, stats).  ``engine`` defaults to an async
        engine over host-RAM Level-2 storage.
        """
        x_n, run = self.multistage_forward(state0, n, interval=interval,
                                           s_l1=s_l1, engine=engine)
        if final_hook is not None:
            try:
                adjoint0 = final_hook(x_n)
            except BaseException:
                run.close()
                raise
        return self.multistage_reverse(run, adjoint0)

    def _reverse_segment(self, b: int, e: int, x_b: Any, adjoint: Any,
                         sched: ms.MultistageSchedule, slots: _L1Slots,
                         stats: ExecutionStats) -> Any:
        seg = sched.segment_schedules.get(b)
        if seg is not None:  # Revolve inside the interval
            slots.store(b, x_b)
            adjoint = self._exec_revolve(seg, slots, adjoint, stats)
            slots.free(b)
            return adjoint
        # Store-all replay: the whole segment fits in Level 1.
        states = {b: x_b}
        current = x_b
        for k in range(b + 1, e):
            current = self.forward_op(current, k - 1)
            stats.advances += 1
            states[k] = current
            slots.store(k, current)  # accounting only
        for k in range(e - 1, b - 1, -1):
            adjoint = self.backward_op(states[k], adjoint, k)
            stats.backwards += 1
            slots.free(k)
        return adjoint
