"""Performance model (paper §3), parameterised over hardware.

Times for one training (forward+backward) iteration of an ``n``-step chain:

    T_inf     = n * T_A + n * T_B                          (no memory limit)
    T_revolve = n * R(n, s) * T_A + n * T_B                (single-stage)
    T_async   = n * R(I, s) * T_A + n * T_B                (multistage, async)

with ``I = ceil(T_T / T_A)`` the smallest interval at which the Level-2
transfers (``T_T`` per state) keep up with compute.  ``R(I, s) <= R(n, s)``
whenever ``I <= n``, so the asynchronous strategy is never slower — and its
overhead is constant in ``n`` (paper's headline claim).

If a *smaller* interval is forced (I < ceil(T_T/T_A)), stores cannot keep up
and the forward pass stalls; ``t_async`` models that with a
``max(I*T_A, T_T)`` per-segment forward time so the trade-off is visible.

``HardwareSpec`` carries the roofline constants for the target chip; the
dry-run couples this model to measured HLO terms via ``times_from_roofline``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core import revolve as rv


@dataclass(frozen=True)
class HardwareSpec:
    """Roofline constants. Defaults: TPU v5e-class chip."""

    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # HBM bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per ICI link
    d2h_bw: float = 25e9              # device->host offload bytes/s per chip
    dcn_bw: float = 1.5625e9          # cross-pod bytes/s per chip
                                      # (6.25 GB/s host NIC / 4 chips/host)
    hbm_bytes: float = 16e9           # HBM capacity per chip
    num_ici_links: int = 4


TPU_V5E = HardwareSpec()
# The paper's platforms, for reproducing its tables on the executor path.
KNL = HardwareSpec(name="knl", peak_flops=3.0e12, hbm_bw=450e9,
                   d2h_bw=90e9, hbm_bytes=16e9)          # MCDRAM -> DRAM
CPU_SSD = HardwareSpec(name="cpu-ssd", peak_flops=1.0e12, hbm_bw=100e9,
                       d2h_bw=2e9, hbm_bytes=64e9)       # DRAM -> SSD


# ---------------------------------------------------------------------------


def optimal_interval(t_transfer: float, t_advance: float) -> int:
    """I = ceil(T_T / T_A): smallest interval that never stalls compute."""
    if t_advance <= 0:
        raise ValueError("t_advance must be positive")
    return max(1, math.ceil(t_transfer / t_advance))


def t_inf(n: int, t_a: float, t_b: float) -> float:
    return n * (t_a + t_b)


def t_revolve(n: int, s: int, t_a: float, t_b: float) -> float:
    return n * rv.recompute_factor(n, s) * t_a + n * t_b


def t_async(n: int, interval: int, s: int, t_a: float, t_b: float,
            t_t: float) -> float:
    """Multistage runtime.  At the paper's operating point
    (interval >= ceil(T_T/T_A)) this reduces to
    ``n * R(I, s) * T_A + n * T_B``; for smaller intervals the per-segment
    forward time is transfer-bound and the stall appears explicitly.

    With n <= interval the strategy degenerates to classic Revolve (§3).
    """
    if n <= interval:
        return t_revolve(n, s, t_a, t_b)
    segments = math.ceil(n / interval)
    fwd_per_seg = max(interval * t_a, t_t)     # stall if transfers lag
    # reverse: per segment, Revolve(I, s) recomputation + backward steps; the
    # prefetch of the next segment overlaps, costing time only if it exceeds
    # the segment's reverse work.
    seg_recompute = rv.optimal_advances(min(interval, n), s) if interval > 1 else 0
    rev_per_seg = max(seg_recompute * t_a + interval * t_b, t_t)
    return segments * (fwd_per_seg + rev_per_seg)


def speedup_vs_revolve(n: int, interval: int, s: int, t_a: float,
                       t_b: float, t_t: float) -> float:
    return t_revolve(n, s, t_a, t_b) / t_async(n, interval, s, t_a, t_b, t_t)


# ---------------------------------------------------------------------------
# Coupling to the roofline terms of a compiled program
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StepTimes:
    """Per-chain-step times derived from compiled-HLO roofline terms."""

    t_a: float   # forward time of one step (layer / sequence chunk)
    t_b: float   # backward time of one step
    t_t: float   # Level-2 transfer time of one boundary state
    interval: int

    @property
    def never_stalls(self) -> bool:
        return self.t_t <= self.interval * self.t_a


def times_from_roofline(step_flops: float, step_hbm_bytes: float,
                        state_bytes: float, hw: HardwareSpec,
                        bwd_fwd_ratio: float = 2.0) -> StepTimes:
    """Derive (T_A, T_B, T_T, I) for one chain step from its roofline terms.

    ``T_A`` is the max of the compute and memory roofline times (the step runs
    at whichever bound dominates); ``T_B`` defaults to 2x forward (one step of
    backprop does ~2x the forward FLOPs); ``T_T`` is the boundary-state
    offload time at the device->host bandwidth.
    """
    t_a = max(step_flops / hw.peak_flops, step_hbm_bytes / hw.hbm_bw)
    t_b = bwd_fwd_ratio * t_a
    t_t = state_bytes / hw.d2h_bw
    return StepTimes(t_a=t_a, t_b=t_b, t_t=t_t,
                     interval=optimal_interval(t_t, t_a))
