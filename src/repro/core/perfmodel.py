"""Performance model (paper §3), parameterised over hardware.

Times for one training (forward+backward) iteration of an ``n``-step chain:

    T_inf     = n * T_A + n * T_B                          (no memory limit)
    T_revolve = n * R(n, s) * T_A + n * T_B                (single-stage)
    T_async   = n * R(I, s) * T_A + n * T_B                (multistage, async)

with ``I = ceil(T_T / T_A)`` the smallest interval at which the Level-2
transfers (``T_T`` per state) keep up with compute.  ``R(I, s) <= R(n, s)``
whenever ``I <= n``, so the asynchronous strategy is never slower — and its
overhead is constant in ``n`` (paper's headline claim).

If a *smaller* interval is forced (I < ceil(T_T/T_A)), stores cannot keep up
and the forward pass stalls; ``t_async`` models that with a
``max(I*T_A, T_T)`` per-segment forward time so the trade-off is visible.

``HardwareSpec`` carries the roofline constants for the target chip; the
dry-run couples this model to measured HLO terms via ``times_from_roofline``.

The two-tier section below extends §3 to a capacity-bounded Level 2
(``TieredStorage``): once boundaries overflow the fast tier, the effective
per-state transfer time is the write-behind bottleneck ``max(T_T_fast,
T_T_slow)``, and ``choose_tiered_interval`` applies ``I = ceil(T_T/T_A)``
to that effective time.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core import revolve as rv


@dataclass(frozen=True)
class HardwareSpec:
    """Roofline constants. Defaults: TPU v5e-class chip."""

    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # HBM bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per ICI link
    d2h_bw: float = 25e9              # device->host offload bytes/s per chip
    dcn_bw: float = 1.5625e9          # cross-pod bytes/s per chip
                                      # (6.25 GB/s host NIC / 4 chips/host)
    hbm_bytes: float = 16e9           # HBM capacity per chip
    num_ici_links: int = 4


TPU_V5E = HardwareSpec()
# The paper's platforms, for reproducing its tables on the executor path.
KNL = HardwareSpec(name="knl", peak_flops=3.0e12, hbm_bw=450e9,
                   d2h_bw=90e9, hbm_bytes=16e9)          # MCDRAM -> DRAM
CPU_SSD = HardwareSpec(name="cpu-ssd", peak_flops=1.0e12, hbm_bw=100e9,
                       d2h_bw=2e9, hbm_bytes=64e9)       # DRAM -> SSD


# ---------------------------------------------------------------------------


def optimal_interval(t_transfer: float, t_advance: float) -> int:
    """I = ceil(T_T / T_A): smallest interval that never stalls compute."""
    if t_advance <= 0:
        raise ValueError("t_advance must be positive")
    return max(1, math.ceil(t_transfer / t_advance))


def t_inf(n: int, t_a: float, t_b: float) -> float:
    return n * (t_a + t_b)


def t_revolve(n: int, s: int, t_a: float, t_b: float) -> float:
    return n * rv.recompute_factor(n, s) * t_a + n * t_b


def t_async(n: int, interval: int, s: int, t_a: float, t_b: float,
            t_t: float) -> float:
    """Multistage runtime.  At the paper's operating point
    (interval >= ceil(T_T/T_A)) this reduces to
    ``n * R(I, s) * T_A + n * T_B``; for smaller intervals the per-segment
    forward time is transfer-bound and the stall appears explicitly.

    With n <= interval the strategy degenerates to classic Revolve (§3).
    """
    if n <= interval:
        return t_revolve(n, s, t_a, t_b)
    segments = math.ceil(n / interval)
    fwd_per_seg = max(interval * t_a, t_t)     # stall if transfers lag
    # reverse: per segment, Revolve(I, s) recomputation + backward steps; the
    # prefetch of the next segment overlaps, costing time only if it exceeds
    # the segment's reverse work.
    seg_recompute = rv.optimal_advances(min(interval, n), s) if interval > 1 else 0
    rev_per_seg = max(seg_recompute * t_a + interval * t_b, t_t)
    return segments * (fwd_per_seg + rev_per_seg)


def speedup_vs_revolve(n: int, interval: int, s: int, t_a: float,
                       t_b: float, t_t: float) -> float:
    return t_revolve(n, s, t_a, t_b) / t_async(n, interval, s, t_a, t_b, t_t)


# ---------------------------------------------------------------------------
# Two-tier (capacity-bounded) Level-2 model
# ---------------------------------------------------------------------------
#
# A TieredStorage Level 2 has a fast tier of ``capacity_bytes`` and a slow
# tier behind it.  While every boundary fits the fast tier, the per-state
# transfer time is the fast tier's T_T.  Once ceil(n/I) boundaries overflow
# the budget, steady state is write-behind: every new fast-tier store forces
# an eviction through the slow tier, so the *effective* per-boundary
# transfer time is rate-limited by the slower medium — and §3's
# I = ceil(T_T/T_A) must be applied to that effective time.


def fast_tier_slots(capacity_bytes: float, state_bytes: float) -> int:
    """Boundary states the fast tier can hold (0 when one state alone
    overflows the budget and every boundary bypasses to the slow tier)."""
    if state_bytes <= 0:
        raise ValueError("state_bytes must be positive")
    return int(capacity_bytes // state_bytes)


def effective_transfer_time(n: int, interval: int, state_bytes: float,
                            capacity_bytes: float, t_t_fast: float,
                            t_t_slow: float) -> float:
    """Capacity-aware per-boundary transfer time: the fast tier's ``T_T``
    while all ``ceil(n/I)`` boundaries fit, else the write-behind pipeline's
    bottleneck ``max(T_T_fast, T_T_slow)`` (fast store and slow eviction
    overlap, so the slower stage sets the rate)."""
    segments = math.ceil(n / interval)
    if segments <= fast_tier_slots(capacity_bytes, state_bytes):
        return t_t_fast
    return max(t_t_fast, t_t_slow)


def choose_tiered_interval(n: int, state_bytes: float, capacity_bytes: float,
                           t_a: float, t_t_fast: float,
                           t_t_slow: float) -> int:
    """§3's ``I = ceil(T_T/T_A)`` applied to the *effective* two-tier
    transfer time.

    Candidates, smallest viable wins:

    * ``I_fast = ceil(T_T_fast/T_A)`` — valid only if all ``ceil(n/I_fast)``
      boundaries fit the fast tier (no spill, fast-tier rate);
    * otherwise the smaller of ``I_fit`` (the smallest interval at which the
      boundaries all fit — paying recompute to stay on the fast medium) and
      ``I_slow = ceil(max(T_T_fast,T_T_slow)/T_A)`` (accepting the spill and
      sizing the interval so the slow tier keeps up — the paper's DRAM->SSD
      operating point).
    """
    i_fast = optimal_interval(t_t_fast, t_a)
    k = fast_tier_slots(capacity_bytes, state_bytes)
    if k >= 1 and math.ceil(n / i_fast) <= k:
        return i_fast
    i_slow = optimal_interval(max(t_t_fast, t_t_slow), t_a)
    if k < 1:                      # nothing ever fits: slow tier sets I
        return max(i_fast, i_slow)
    i_fit = math.ceil(n / k)
    return max(i_fast, min(i_fit, i_slow))


def t_async_tiered(n: int, interval: int, s: int, t_a: float, t_b: float,
                   t_t_fast: float, t_t_slow: float, state_bytes: float,
                   capacity_bytes: float) -> float:
    """Two-tier multistage runtime: :func:`t_async` evaluated at the
    capacity-aware effective transfer time.  At ``I >= ceil(T_T_eff/T_A)``
    this is ``n * R(I, s) * T_A + n * T_B`` — the overhead stays constant
    in ``n`` even when most boundaries live on the slow tier, which is the
    tiered backend's headline claim (wall time flat while the fast tier
    obeys any budget)."""
    t_t_eff = effective_transfer_time(n, interval, state_bytes,
                                      capacity_bytes, t_t_fast, t_t_slow)
    return t_async(n, interval, s, t_a, t_b, t_t_eff)


def fast_peak_bytes_model(n: int, interval: int, state_bytes: int,
                          capacity_bytes: int) -> int:
    """Model of the fast tier's high-water mark: every boundary when they
    fit, else exactly the budget's worth of whole states (plan-aware
    eviction keeps the tier full of the soonest-needed boundaries)."""
    segments = math.ceil(n / interval)
    k = fast_tier_slots(capacity_bytes, state_bytes)
    return min(segments, k) * int(state_bytes)


def admitted_fast_peak_model(n: int, interval: int, state_bytes: int,
                             capacity_bytes: int, *,
                             extra_states: int = 0) -> int:
    """Admission-control upper bound on a run's fast-tier footprint.

    :func:`fast_peak_bytes_model` counts segment boundaries only; a
    *journaled* run additionally stores the final carry under
    ``FINAL_STATE_KEY``, so a scheduler admitting a preemptible train job
    must budget ``extra_states=1`` or the measured peak can exceed the
    prediction by one state and falsify the admission contract.  Decode
    sessions use ``extra_states=0`` with ``n == interval`` (their cache is
    one resident "state").
    """
    if extra_states < 0:
        raise ValueError(f"extra_states must be >= 0, got {extra_states}")
    segments = math.ceil(n / interval) + extra_states
    k = fast_tier_slots(capacity_bytes, state_bytes)
    return min(segments, k) * int(state_bytes)


# ---------------------------------------------------------------------------
# Streamed-resource (expert parameter) extension of the two-tier model
# ---------------------------------------------------------------------------
#
# With ``offload_params`` the Level-2 link moves two resource classes: one
# boundary state per segment (as above) plus every segment's expert-parameter
# working set (``interval * step_param_bytes`` fetched behind the previous
# segment's compute, forward AND reverse).  §3's never-stall rule gains the
# param term: the link must clear ``T_T_state + I * t_p`` inside ``I * T_A``.
# The fast tier is shared — ``fast_peak_bytes_resources`` replays the
# backend's exact put sequence under the merged plan's Belady order, so the
# modeled peak equals the measured ``fast_peak_bytes`` bit for bit.


def expert_traffic_model(n: int, interval: int, step_param_bytes: float,
                         state_bytes: float, capacity_bytes: float) -> dict:
    """Level-2 traffic and residency of an expert-streaming run.

    One forward+reverse pass populates every blob once (``n *
    step_param_bytes``) and reads each twice (once per phase), on top of
    the boundary-state traffic; residency-wise the streamed working set and
    the ``ceil(n/I)`` boundaries compete for one ``capacity_bytes`` budget,
    so ``spilled_bytes`` is what the write-behind pipeline must cycle
    through the slow tier."""
    segments = math.ceil(n / interval)
    seg_param_bytes = interval * float(step_param_bytes)
    total_param_bytes = n * float(step_param_bytes)
    resident_demand = total_param_bytes + segments * float(state_bytes)
    spilled = max(0.0, resident_demand - float(capacity_bytes))
    return {
        "segments": segments,
        "seg_param_bytes": seg_param_bytes,
        "total_param_bytes": total_param_bytes,
        # populate once + forward reads + reverse reads
        "moved_param_bytes": 3 * total_param_bytes,
        "resident_demand_bytes": resident_demand,
        "spilled_bytes": spilled,
    }


def choose_interval_with_params(t_a: float, t_t_state: float,
                                t_p: float) -> int:
    """§3's ``I = ceil(T_T/T_A)`` extended with per-step parameter traffic.

    ``t_p`` is the transfer time of one step's expert working set
    (``step_param_bytes / bandwidth``).  A segment of ``I`` steps gives the
    link ``I * T_A`` to move one boundary state *and* the next segment's
    params: ``I * T_A >= T_T_state + I * t_p``, i.e.
    ``I = ceil(T_T_state / (T_A - t_p))``.  When params alone saturate the
    link (``t_p >= T_A``) no interval avoids stalls — fall back to the
    state-only rule (the stall then shows up in ``param_fetch_stalls``
    rather than being hidden by an unboundedly large interval)."""
    if t_a <= 0:
        raise ValueError("t_a must be positive")
    if t_p >= t_a:
        return optimal_interval(t_t_state, t_a)
    return max(1, math.ceil(t_t_state / (t_a - t_p)))


def fast_peak_bytes_resources(puts, distances: dict,
                              capacity_bytes: int) -> int:
    """*Exact* replay of ``TieredStorage``'s fast tier over a heterogeneous
    put sequence — the streamed-resource generalisation of
    :func:`fast_peak_bytes_model`.

    ``puts`` is the backend's put order as ``(key, nbytes)`` pairs (for an
    ``offload_params`` run: the ``ParamStream.population_order`` blobs, then
    one boundary state per segment — population is synchronous and boundary
    stores drain through the single FIFO writer, so the order is
    deterministic); ``distances`` is the merged forward access plan's
    ``ResourceAccessPlan.distances()``.  The replay mirrors the backend
    exactly: oversize puts bypass, a re-store drops the old copy first,
    eviction pops the max-rank victim (unknown keys first, LRU; then
    farthest next use) until the budget holds, and the peak is recorded
    *after* eviction — so the returned value must equal the measured
    ``fast_peak_bytes`` exactly, which the expert_stream bench asserts at
    every sweep point."""
    capacity = int(capacity_bytes)
    fast: dict = {}
    seq: dict = {}
    next_seq = 0
    fill = 0
    peak = 0

    def rank(k):
        d = distances.get(k)
        if d is None:
            return (1, -seq.get(k, 0))
        return (0, d)

    for key, nb in puts:
        nb = int(nb)
        if nb > capacity:
            continue                      # bypasses the fast tier
        if key in fast:                   # re-store replaces the old copy
            fill -= fast.pop(key)
            seq.pop(key, None)
        fast[key] = nb
        fill += nb
        seq[key] = next_seq
        next_seq += 1
        while fill > capacity and fast:
            victim = max(fast, key=rank)
            fill -= fast.pop(victim)
            seq.pop(victim, None)
        peak = max(peak, fill)
    return peak


# ---------------------------------------------------------------------------
# Sharded (per-device Level-2 streams) model
# ---------------------------------------------------------------------------
#
# On a mesh, every device owns a shard of each boundary state and streams it
# to its *own* Level-2 stream, so the per-stream payload is the local shard
# — ``state_bytes / num_shards`` when the state is evenly sharded — and the
# streams run concurrently.  §3's rule then applies to the per-stream
# transfer time, which is never larger than the global one, hence
# ``I_sharded <= I_single`` whenever the fan-out actually parallelises.


def local_shard_bytes(state_bytes: float, num_shards: int) -> float:
    """Per-stream payload of one boundary state on an even mesh split."""
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    return state_bytes / num_shards


def sharded_transfer_time(t_t_global: float, num_shards: int,
                          efficiency: float = 1.0) -> float:
    """Per-stream ``T_T`` predicted from the single-stream time: the
    payload divides by ``num_shards`` and the streams overlap, degraded
    by ``efficiency`` in (0, 1] for host-side contention (shared PCIe
    root, one filesystem behind N writer threads)."""
    if not 0.0 < efficiency <= 1.0:
        raise ValueError(f"efficiency must be in (0, 1], got {efficiency}")
    return local_shard_bytes(t_t_global, num_shards) / efficiency


def choose_sharded_interval(t_a: float, t_t_stream: float,
                            t_t_global: float | None = None) -> int:
    """§3's ``I = ceil(T_T/T_A)`` at the *per-stream* transfer time,
    clamped by the global time: ``min(T_T_stream, T_T_global)`` is
    monotone in both arguments, so the sharded interval can never exceed
    the single-device one even when a measured fan-out probe comes back
    noisy-slow (contended CI machine)."""
    t_t = t_t_stream if t_t_global is None else min(t_t_stream, t_t_global)
    return optimal_interval(t_t, t_a)


def t_async_sharded(n: int, interval: int, s: int, t_a: float, t_b: float,
                    t_t_global: float, num_shards: int,
                    efficiency: float = 1.0) -> float:
    """Multistage runtime with per-device Level-2 streams: :func:`t_async`
    at the per-stream transfer time.  With ``num_shards == 1`` this is
    exactly the single-device model."""
    t_t = sharded_transfer_time(t_t_global, num_shards, efficiency)
    return t_async(n, interval, s, t_a, t_b, t_t)


def mesh_axis_transfer_times(state_bytes: float, mesh_shape: dict,
                             d2h_bw: float) -> dict:
    """Roofline per-axis ``T_T``: the per-stream time if the state were
    sharded along each mesh axis alone (``mesh_shape`` is the
    ``{axis: size}`` dict of a ``jax.sharding.Mesh``).  The dry-run uses
    this to pick which axis to put in ``state_spec`` before measuring."""
    return {axis: local_shard_bytes(state_bytes, max(1, int(k))) / d2h_bw
            for axis, k in mesh_shape.items()}


# ---------------------------------------------------------------------------
# 2D (time x layer) plan model
# ---------------------------------------------------------------------------
#
# The outer axis bounds how many *steps'* states are live; when a single
# step's own activations exceed the per-step budget (deep per-step layer
# stacks, huge logits/loss heads — the regime ROADMAP's StreamBP x Gruslys
# item names), the step must be chunked too.  ``choose_2d_plan`` decides
# 1D-vs-2D from real per-layer costs (``analysis.jaxpr_cost``), allocates
# inner slots with the Gruslys-style DP (``schedule.gruslys_split``) and
# models both the recompute factor and the per-step peak as functions of
# both axes; the bench asserts the executor's counters match count-exactly.


def inner_boundary_bytes_model(inner, state_bytes: float) -> float:
    """Saved inner sub-range entry states while one step is backwarded:
    ``layer_chunks * state_bytes`` (0 for a 1D plan).  This is the
    measurable half of the per-step peak — the executor counts exactly the
    boundary saves it dispatches."""
    if inner is None:
        return 0.0
    return inner.layer_chunks * float(state_bytes)


def inner_peak_bytes_model(inner, layer_bytes, state_bytes: float) -> float:
    """Modeled reverse-time per-step peak of a 2D plan: the saved sub-range
    boundaries plus the largest chunk's activations (the chunk being
    rematerialised).  For a 1D plan (``inner is None``) the whole step's
    activations are live at once."""
    vals = tuple(float(b) for b in layer_bytes)
    if inner is None:
        return sum(vals)
    peak = inner_boundary_bytes_model(inner, state_bytes)
    worst = max(sum(vals[lo:hi]) for lo, hi in inner.chunk_ranges())
    return peak + worst


def inner_recomputed_layers_model(n: int, inner) -> int:
    """Count-exact model of the inner axis's recompute: every chunk interior
    replays exactly once when its step is backwarded, so a full reverse
    sweep re-runs ``n * n_layers`` layer applications (0 for 1D)."""
    if inner is None:
        return 0
    return int(n) * int(inner.n_layers)


def recompute_factor_2d(n: int, interval: int, s_l1: int, inner) -> float:
    """Combined recompute factor of a 2D plan, in the physical
    (``multistage_recompute_factor``) convention: the outer factor plus one
    extra forward of every step's layer stack for the inner remat —
    independent of ``layer_chunks`` (exact chunking, constant overhead,
    StreamBP-style)."""
    from repro.core.schedule import multistage_recompute_factor
    base = multistage_recompute_factor(n, interval, s_l1)
    if inner is None:
        return base
    return base + n / max(1, n - 1)


@dataclass(frozen=True)
class Plan2D:
    """Outcome of the 1D-vs-2D decision for one chain under a per-step
    budget.  ``inner is None`` means time-only segmentation suffices."""

    interval: int
    inner: object                  # Optional[schedule.InnerPlan]
    step_bytes_1d: float           # one step's activations, unchunked
    step_peak_bytes: float         # modeled per-step reverse peak (chosen plan)
    inner_boundary_bytes: float    # measurable: saved inner boundaries
    recompute_factor: float        # both axes, physical convention
    feasible: bool
    min_budget_bytes: float        # smallest budget any inner split satisfies

    @property
    def is_2d(self) -> bool:
        return self.inner is not None


def choose_2d_plan(n: int, *, t_a: float, t_t: float, s_l1: int,
                   state_bytes: float, layer_bytes,
                   budget_bytes: float, head_bytes: float = 0.0,
                   interval: "int | None" = None) -> Plan2D:
    """Pick 1D vs 2D for an ``n``-step chain under ``budget_bytes`` of
    per-step memory.

    The outer interval stays §3's ``I = ceil(T_T/T_A)`` (outer boundaries
    live in Level 2; the budget constrains the *per-step* reverse peak, not
    the boundary count).  If one step's unchunked activations
    (``sum(layer_bytes) + head_bytes``) fit the budget, the answer is 1D.
    Otherwise the Gruslys-style DP (:func:`~repro.core.schedule.gruslys_split`)
    finds the fewest layer sub-ranges whose peak fits, and the logits/loss
    head is split into the fewest sequence chunks that fit.  ``feasible`` is
    False when even ``layer_chunks == n_layers`` overflows;
    ``min_budget_bytes`` then names the smallest budget that would work
    (what the launcher error reports).
    """
    from repro.core import schedule as sched
    if interval is None:
        interval = optimal_interval(t_t, t_a)
    vals = tuple(float(b) for b in layer_bytes)
    step_1d = sum(vals) + float(head_bytes)
    min_budget = sched.min_step_budget_bytes(vals, state_bytes)
    if step_1d <= budget_bytes:
        return Plan2D(interval=interval, inner=None, step_bytes_1d=step_1d,
                      step_peak_bytes=step_1d, inner_boundary_bytes=0.0,
                      recompute_factor=recompute_factor_2d(
                          n, interval, s_l1, None),
                      feasible=True, min_budget_bytes=min_budget)
    inner = sched.gruslys_split(vals, budget_bytes, state_bytes)
    if inner is None:
        return Plan2D(interval=interval, inner=None, step_bytes_1d=step_1d,
                      step_peak_bytes=step_1d, inner_boundary_bytes=0.0,
                      recompute_factor=recompute_factor_2d(
                          n, interval, s_l1, None),
                      feasible=False, min_budget_bytes=min_budget)
    if head_bytes > 0 and budget_bytes > 0:
        head_chunks = max(1, math.ceil(float(head_bytes) / budget_bytes))
        if head_chunks > 1:
            inner = sched.InnerPlan(
                n_layers=inner.n_layers, layer_chunks=inner.layer_chunks,
                head_chunks=head_chunks, boundaries=inner.boundaries)
    return Plan2D(
        interval=interval, inner=inner, step_bytes_1d=step_1d,
        step_peak_bytes=inner_peak_bytes_model(inner, vals, state_bytes),
        inner_boundary_bytes=inner_boundary_bytes_model(inner, state_bytes),
        recompute_factor=recompute_factor_2d(n, interval, s_l1, inner),
        feasible=True, min_budget_bytes=min_budget)


# ---------------------------------------------------------------------------
# Coupling to the roofline terms of a compiled program
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StepTimes:
    """Per-chain-step times derived from compiled-HLO roofline terms."""

    t_a: float   # forward time of one step (layer / sequence chunk)
    t_b: float   # backward time of one step
    t_t: float   # Level-2 transfer time of one boundary state
    interval: int

    @property
    def never_stalls(self) -> bool:
        return self.t_t <= self.interval * self.t_a


def times_from_roofline(step_flops: float, step_hbm_bytes: float,
                        state_bytes: float, hw: HardwareSpec,
                        bwd_fwd_ratio: float = 2.0) -> StepTimes:
    """Derive (T_A, T_B, T_T, I) for one chain step from its roofline terms.

    ``T_A`` is the max of the compute and memory roofline times (the step runs
    at whichever bound dominates); ``T_B`` defaults to 2x forward (one step of
    backprop does ~2x the forward FLOPs); ``T_T`` is the boundary-state
    offload time at the device->host bandwidth.
    """
    t_a = max(step_flops / hw.peak_flops, step_hbm_bytes / hw.hbm_bw)
    t_b = bwd_fwd_ratio * t_a
    t_t = state_bytes / hw.d2h_bw
    return StepTimes(t_a=t_a, t_b=t_b, t_t=t_t,
                     interval=optimal_interval(t_t, t_a))
