"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig

_MODULES = {
    "qwen1.5-4b": "qwen1_5_4b",
    "gemma2-2b": "gemma2_2b",
    "yi-6b": "yi_6b",
    "granite-3-2b": "granite_3_2b",
    "internvl2-1b": "internvl2_1b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "whisper-tiny": "whisper_tiny",
    "llama4-scout-17b-16e": "llama4_scout_17b_16e",
    "phi3.5-moe-42b": "phi3_5_moe",
    "mamba2-370m": "mamba2_370m",
    "lstm-paper": "lstm_paper",
}

ASSIGNED: List[str] = [k for k in _MODULES if k != "lstm-paper"]


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> Dict[str, ArchConfig]:
    return {n: get_config(n, smoke) for n in ASSIGNED}
