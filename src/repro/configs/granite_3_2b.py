"""Granite-3.0-2B [dense]: 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155 — GQA, tied embeddings.  [hf:ibm-granite/granite-3.0-2b-base; hf]
(The scalar logits/residual/embedding multipliers of Granite are folded into
initialisation; noted in DESIGN §2.)"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8, d_ff=8192,
    vocab=49155, rope_theta=1e4, tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="granite-3-2b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=512, ce_chunk=32, attn_chunk=16,
)
