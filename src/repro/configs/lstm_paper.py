"""The paper's own §5 test case: a vanilla LSTM for character-level text
generation, trained with RMSProp.  d_model = embedding dim, d_ff = hidden."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="lstm-paper", family="lstm",
    n_layers=1, d_model=64, n_heads=1, n_kv_heads=1, d_ff=256,
    vocab=96, tie_embeddings=False, sub_quadratic=True,
)

SMOKE = CONFIG.replace(name="lstm-paper-smoke", d_model=16, d_ff=32,
                       vocab=64)
