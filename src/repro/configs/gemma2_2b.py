"""Gemma2-2B [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000
— local+global alternating attention, logit softcaps, GeGLU, post-norms,
head_dim=256, query scale 256^-0.5.  [arXiv:2408.00118; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_ff=9216,
    vocab=256000, head_dim=256,
    layer_pattern=("attn_local", "attn"), window=4096,
    attn_softcap=50.0, logit_softcap=30.0, query_scale=256 ** -0.5,
    mlp_act="gelu", use_post_norm=True, embed_scale=True,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="gemma2-2b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128, vocab=512, window=8, query_scale=16 ** -0.5,
    ce_chunk=32, attn_chunk=16,
)
