"""Yi-6B [dense]: 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000 —
llama-arch GQA.  [arXiv:2403.04652; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, d_ff=11008,
    vocab=64000, rope_theta=5e6, tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    name="yi-6b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=512, ce_chunk=32, attn_chunk=16,
)
