"""Llama4-Scout-17B-16E [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
(expert), vocab=202048, MoE 16e top-1 + shared expert — early fusion
multimodal in the published model; the text backbone is built here and the
fusion frontend is out of assigned scope (text shapes only).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="llama4-scout-17b-16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab=202048, rope_theta=5e5, tie_embeddings=False,
    layer_pattern=("attn_moe",),
    moe=MoECfg(n_experts=16, top_k=1, shared_expert=True,
               capacity_factor=2.0),
)

SMOKE = CONFIG.replace(
    name="llama4-scout-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=512,
    moe=MoECfg(n_experts=4, top_k=1, shared_expert=True, capacity_factor=2.0),
    ce_chunk=32, attn_chunk=16,
)
