"""Config system: architecture + shape suite + runtime knobs.

Every assigned architecture is one ``<id>.py`` module exporting ``CONFIG``
(the exact published configuration) and ``SMOKE`` (a reduced same-family
variant for CPU smoke tests).  ``repro.configs.registry`` collects them.

``layer_pattern`` describes one *period* of the layer stack; the stack is
``n_layers / len(layer_pattern)`` repetitions of the pattern, scanned with
stacked parameters (so heterogeneous stacks — Gemma-2's local/global
alternation, Jamba's Mamba:attention interleave — become uniform chains,
which is exactly the uniform-checkpoint-size assumption the paper's strategy
wants; see DESIGN §2).

Layer kinds:
  ``attn``        attention + dense MLP
  ``attn_local``  sliding-window attention + dense MLP
  ``attn_moe``    attention + MoE FFN
  ``mamba``       Mamba-2 mixer (no FFN)
  ``mamba_moe``   Mamba-2 mixer + MoE FFN
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    shared_expert: bool = False
    aux_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    ngroups: int = 1
    conv_k: int = 4
    chunk: int = 64


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm | lstm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    layer_pattern: Tuple[str, ...] = ("attn",)
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: Optional[int] = None     # sliding-window size for *_local layers
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    query_scale: Optional[float] = None
    mlp_act: str = "silu"            # silu (SwiGLU) | gelu (GeGLU)
    use_post_norm: bool = False      # Gemma-2 style post-norms
    embed_scale: bool = False        # multiply embeddings by sqrt(d_model)
    tie_embeddings: bool = True
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    dec_len: int = 448               # decoder length for train/prefill shapes
    # vlm
    n_patches: int = 0
    # --- runtime knobs (hillclimbed in EXPERIMENTS §Perf) -------------------
    remat_policy: str = "offload_layer"
    moe_impl: str = "einsum"
    attn_chunk: int = 1024
    ce_chunk: int = 512
    scan_unroll: int = 1
    sharding_profile: str = "tp"     # tp | dp (replicate params, batch over
                                     # every mesh axis — small models)
    pad_vocab_multiple: int = 0      # pad embedding rows so vocab shards
                                     # evenly (0 = exact published vocab)
    zero3: bool = False              # constrain projection outputs so FSDP
                                     # weights are all-gathered, never
                                     # resolved by activation all-reduces
    sub_quadratic: bool = False      # True -> runs the long_500k shape

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_multiple
        if not m:
            return self.vocab
        return -(-self.vocab // m) * m

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_periods(self) -> int:
        assert self.n_layers % self.period == 0, (self.name, self.n_layers,
                                                  self.period)
        return self.n_layers // self.period

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# Reduced shape used by per-arch smoke tests (CPU, one real device).
SMOKE_SHAPE = ShapeSpec("smoke", 32, 2, "train")


def applicable_shapes(cfg: ArchConfig):
    """The shape cells this architecture runs (skips per assignment rules)."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue  # needs sub-quadratic attention; skip noted in DESIGN.md
        out.append(s)
    return out


def param_count(cfg: ArchConfig) -> Tuple[int, int]:
    """(total_params, active_params) — analytic, used for MODEL_FLOPS."""
    d, hd = cfg.d_model, cfg.hd
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    total = emb
    active = emb
    for kind in cfg.layer_pattern:
        attn = d * (cfg.n_heads * hd) + 2 * d * (cfg.n_kv_heads * hd) \
            + (cfg.n_heads * hd) * d
        dense_ffn = 3 * d * cfg.d_ff
        if cfg.ssm is not None:
            s = cfg.ssm
            d_in = s.expand * d
            nheads = d_in // s.headdim
            mamba = d * (2 * d_in + 2 * s.ngroups * s.d_state + nheads) \
                + d_in * d + s.conv_k * (d_in + 2 * s.ngroups * s.d_state)
        else:
            mamba = 0
        if kind in ("attn", "attn_local"):
            lt = la = attn + dense_ffn
        elif kind == "attn_moe":
            m = cfg.moe
            lt = attn + m.n_experts * dense_ffn \
                + (dense_ffn if m.shared_expert else 0) + d * m.n_experts
            la = attn + m.top_k * dense_ffn \
                + (dense_ffn if m.shared_expert else 0) + d * m.n_experts
        elif kind == "mamba":
            lt = la = mamba
        elif kind == "mamba_moe":
            m = cfg.moe
            lt = mamba + m.n_experts * dense_ffn + d * m.n_experts
            la = mamba + m.top_k * dense_ffn + d * m.n_experts
        else:
            raise ValueError(kind)
        total += lt * cfg.n_periods
        active += la * cfg.n_periods
    if cfg.n_enc_layers:
        enc = cfg.n_enc_layers * (4 * d * cfg.n_heads * hd + 3 * d * cfg.d_ff)
        xattn = cfg.n_layers * (2 * d * cfg.n_heads * hd +
                                2 * d * cfg.n_kv_heads * hd)
        total += enc + xattn
        active += enc + xattn
    return total, active


def _attn_layer_counts(cfg: ArchConfig):
    """(n_global_attn, n_local_attn) layers in the decoder stack."""
    ng = sum(1 for k in cfg.layer_pattern
             if k in ("attn", "attn_moe")) * cfg.n_periods
    nl = sum(1 for k in cfg.layer_pattern
             if k == "attn_local") * cfg.n_periods
    return ng, nl


def model_flops(cfg: ArchConfig, spec: ShapeSpec) -> float:
    """Useful model FLOPs per step: 6·N_active·D (train) / 2·N_active·D
    (inference) plus the quadratic attention term (4·B·H·hd·S·S_eff per
    layer, halved for causal masking, windowed for local layers; x3 for the
    backward pass in training).  SSD linear-time mixing is inside the 6ND
    term.  This is the numerator of the roofline's useful-compute ratio.
    """
    _, active = param_count(cfg)
    B, S = spec.global_batch, spec.seq_len
    hd, H = cfg.hd, cfg.n_heads
    ng, nl = _attn_layer_counts(cfg)
    win = min(cfg.window or S, S)

    if spec.kind == "train":
        tokens = B * (cfg.dec_len if cfg.family == "encdec" else S)
        attn = 2 * B * H * hd * (ng * S * S + nl * S * win)  # causal half
        if cfg.family == "encdec":
            s_enc = S // 2
            attn = 2 * B * H * hd * cfg.n_enc_layers * s_enc * s_enc * 2 \
                + 2 * B * H * hd * cfg.n_layers * (
                    cfg.dec_len * cfg.dec_len + 2 * cfg.dec_len * s_enc)
        return 6.0 * active * tokens + 3.0 * attn
    if spec.kind == "prefill":
        tokens = B * (cfg.dec_len if cfg.family == "encdec" else S)
        attn = 2 * B * H * hd * (ng * S * S + nl * S * win)
        if cfg.family == "encdec":
            s_enc = S // 2
            attn = 2 * B * H * hd * cfg.n_enc_layers * s_enc * s_enc * 2 \
                + 2 * B * H * hd * cfg.n_layers * (
                    cfg.dec_len * cfg.dec_len + 2 * cfg.dec_len * s_enc)
        return 2.0 * active * tokens + attn
    # decode: one token; attention reads the full cache (or window)
    attn = 4.0 * B * H * hd * (ng * S + nl * win)
    if cfg.family == "encdec":
        attn = 4.0 * B * H * hd * cfg.n_layers * (S + 1500)
    return 2.0 * active * B + attn


def score_materialization_bytes(cfg: ArchConfig, spec: ShapeSpec) -> float:
    """HBM bytes the XLA-portable chunked attention / SSD paths spend on f32
    score (resp. intra-chunk decay) tensors — traffic that the Pallas TPU
    kernels keep VMEM-resident.  Subtracting this from the (fusion-
    discounted) jaxpr-model bytes gives the kernel-adjusted memory term
    in §Roofline.

    Tensor counts match the implementations under the fusion-discounted
    model (major score tensors + 0.25x the fusable ones): attention — fwd
    materializes the score dot `s`; bwd re-materializes `s`, `dp`, `ds`
    (4 major, ~1 discounted elementwise) -> 4 effective train, 1 inference.
    SSD — `cb` fwd + `dcb`/`dM` bwd -> 4 train, 1.5 inference.  Each counted
    as one write + one read of f32.
    """
    B, S = spec.global_batch, spec.seq_len
    H = cfg.n_heads
    ng, nl = _attn_layer_counts(cfg)
    win = min(cfg.window or S, S)
    n_attn = 4.0 if spec.kind == "train" else 1.0
    n_ssd = 4.0 if spec.kind == "train" else 1.5
    total = 0.0
    if spec.kind in ("train", "prefill"):
        attn_elems = B * H * (ng * S * S + nl * S * win)
        if cfg.family == "encdec":
            s_enc = S // 2
            attn_elems = B * H * (
                cfg.n_enc_layers * s_enc * s_enc
                + cfg.n_layers * (cfg.dec_len * cfg.dec_len
                                  + cfg.dec_len * s_enc))
        total += n_attn * 2 * 4.0 * attn_elems
        if cfg.ssm is not None:
            n_mamba = sum(1 for k in cfg.layer_pattern
                          if k.startswith("mamba")) * cfg.n_periods
            s_ssm = cfg.ssm
            d_in = s_ssm.expand * cfg.d_model
            heads = d_in // s_ssm.headdim
            # (b, n_chunks, L, L, h) decay/cb tensors, f32
            total += n_ssd * 2 * 4.0 * B * (S // max(s_ssm.chunk, 1)) * \
                s_ssm.chunk * s_ssm.chunk * heads * n_mamba
    else:  # decode: (B, H, 1, S) rows — small but counted
        total += n_attn * 2 * 4.0 * B * H * (ng * S + nl * win)
    return total
