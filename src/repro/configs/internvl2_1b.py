"""InternVL2-1B [vlm]: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655
— InternViT frontend (STUB: precomputed patch embeddings) + Qwen2-0.5B-style
LM backbone (QKV bias).  [arXiv:2404.16821; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab=151655, qkv_bias=True, rope_theta=1e6, tie_embeddings=True,
    n_patches=1024,
)

SMOKE = CONFIG.replace(
    name="internvl2-1b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=512, n_patches=8, ce_chunk=32,
    attn_chunk=16,
)
