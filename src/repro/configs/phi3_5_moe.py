"""Phi-3.5-MoE-42B (6.6B active) [moe]: 32L d_model=4096 32H (GQA kv=8)
d_ff=6400, vocab=32064, 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.configs.base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="phi3.5-moe-42b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
    vocab=32064, rope_theta=1e4, tie_embeddings=False,
    layer_pattern=("attn_moe",),
    moe=MoECfg(n_experts=16, top_k=2),
)

SMOKE = CONFIG.replace(
    name="phi3.5-moe-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=512, moe=MoECfg(n_experts=4, top_k=2), ce_chunk=32,
    attn_chunk=16,
)
