"""Whisper-tiny [audio/encdec]: 4L(enc)+4L(dec) d_model=384 6H d_ff=1536
vocab=51865 — conv frontend STUB (precomputed frame embeddings).
[arXiv:2212.04356; unverified]  Norms/positions adapted to the RMSNorm+RoPE
substrate (DESIGN §2); dims follow the published config."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, n_enc_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865, mlp_act="gelu", tie_embeddings=True,
    dec_len=448,
)

SMOKE = CONFIG.replace(
    name="whisper-tiny-smoke", n_layers=2, n_enc_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=512, dec_len=16, ce_chunk=16,
    attn_chunk=16,
)
