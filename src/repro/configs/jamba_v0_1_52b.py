"""Jamba-v0.1-52B [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 — Mamba:attention 1:7 interleave, MoE every 2nd
layer.  [arXiv:2403.19887; hf]

Period of 8 layers: one attention layer per period (1:7), MoE on every odd
position.  Jamba's attention uses no positional embedding (the Mamba layers
carry position); we keep RoPE off by setting theta on the attention layers
only through the shared config — adaptation noted in DESIGN §2.
"""
from repro.configs.base import ArchConfig, MoECfg, SSMCfg

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=65536, tie_embeddings=False,
    layer_pattern=("mamba", "mamba_moe", "mamba", "attn_moe",
                   "mamba", "mamba_moe", "mamba", "mamba_moe"),
    moe=MoECfg(n_experts=16, top_k=2),
    ssm=SSMCfg(d_state=16, headdim=64, expand=2, ngroups=1, conv_k=4),
    sub_quadratic=True,
)

SMOKE = CONFIG.replace(
    name="jamba-v0.1-52b-smoke", n_layers=8, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=512,
    moe=MoECfg(n_experts=4, top_k=2),
    ssm=SSMCfg(d_state=8, headdim=16, expand=2, ngroups=1, conv_k=4, chunk=8),
    ce_chunk=32, attn_chunk=16,
)
